"""Binary encoding + Hamming tests (core/binary.py, paper §III-D)."""
import jax
import jax.numpy as jnp
import numpy as np

from tests._hypothesis_compat import given, settings, st

from repro.core import binary


def test_bits_for_k():
    assert binary.bits_for_k(128) == 7
    assert binary.bits_for_k(256) == 8
    assert binary.bits_for_k(512) == 9   # paper's b=9 example


def test_hamming_matches_python_popcount(rng):
    a = jax.random.randint(rng, (50,), 0, 512)
    b = jax.random.randint(jax.random.PRNGKey(1), (50,), 0, 512)
    h = binary.hamming_distance(a, b, bits=9)
    expect = [bin((int(x) ^ int(y)) & 0x1FF).count("1")
              for x, y in zip(a, b)]
    np.testing.assert_array_equal(np.asarray(h), expect)


@settings(max_examples=25, deadline=None)
@given(bits=st.integers(1, 16), n=st.integers(1, 200))
def test_property_pack_unpack_roundtrip(bits, n):
    rng = np.random.default_rng(bits * 1000 + n)
    codes = rng.integers(0, 2 ** bits, n).astype(np.uint32)
    packed = binary.pack_codes(codes, bits)
    assert packed.nbytes == binary.packed_nbytes(n, bits)
    out = binary.unpack_codes(packed, bits, n)
    np.testing.assert_array_equal(codes, out)


def test_paper_table3_compression_arithmetic():
    """Table III reconstruction (see EXPERIMENTS.md §Storage).

    The paper's text says '512 B / 1 B = 32x', which is arithmetically
    inconsistent (that ratio is 512x); its *table* numbers (2.56 GB ->
    0.08 GB = 32x, -> 0.045 GB = 57x) are consistent only under a
    product-quantization reading: 16 sub-quantizers x 1 B = 16 B/patch
    (32x), and 8 sub-quantizers x 9 bits = 9 B/patch (57x). We reproduce
    the table's numbers with PQ and additionally report the single-code
    512x variant the text describes.
    """
    n_patches = 100_000 * 50
    float_bytes = n_patches * 128 * 4
    assert float_bytes == 2.56e9
    # single 1-byte code (the paper's *text*): 512x
    assert float_bytes / n_patches == 512.0
    # PQ-16 x uint8 (the paper's *table* row "32x"): 0.08 GB
    pq16 = n_patches * 16
    assert pq16 / 1e9 == 0.08 and float_bytes / pq16 == 32.0
    # PQ-8 x 9-bit packed (the table's binary row "57x"): 0.045 GB
    pq8_bin = binary.packed_nbytes(n_patches * 8, 9)
    assert abs(pq8_bin / 1e9 - 0.045) < 0.001
    assert 56 < float_bytes / pq8_bin < 58


def test_hamming_sim_matrix_bounds(rng):
    q = jax.random.randint(rng, (2, 4), 0, 256)
    d = jax.random.randint(jax.random.PRNGKey(2), (3, 5), 0, 256)
    sim = binary.hamming_sim_matrix(q[:, None], d[None], 8)
    assert sim.shape == (2, 3, 4, 5)
    assert int(sim.max()) <= 8 and int(sim.min()) >= 0


def test_u16_pair_packing_roundtrip(rng):
    codes = jax.random.randint(rng, (4, 10), 0, 65536).astype(jnp.uint32)
    packed = binary.pack_u16_pairs(codes)
    assert packed.shape == (4, 5)
    out = binary.unpack_u16_pairs(packed)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))
