"""Pallas kernel validation: interpret=True vs pure-jnp oracles, swept over
shapes and dtypes (deliverable (c) kernel requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantization as quant
from repro.kernels import ops

SHAPES = [  # (B, Mq, D, N, Md)
    (1, 4, 16, 16, 8),
    (2, 8, 32, 48, 10),
    (3, 5, 64, 64, 17),   # odd Md, padding path
    (2, 16, 128, 32, 32),
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_maxsim_kernel(shape, dtype):
    b, mq, d, n, md = shape
    key = jax.random.PRNGKey(sum(shape))
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, mq, d), dtype)
    docs = jax.random.normal(ks[1], (n, md, d), dtype)
    qm = jax.random.uniform(ks[2], (b, mq)) > 0.2
    dm = jax.random.uniform(ks[3], (n, md)) > 0.2
    got = ops.maxsim(q, qm, docs, dm, impl="interpret", block_docs=16)
    want = ops.maxsim(q, qm, docs, dm, impl="ref")
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("k", [16, 256])
def test_quantized_maxsim_kernel(shape, k):
    b, mq, d, n, md = shape
    key = jax.random.PRNGKey(sum(shape) + k)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, mq, d))
    cb = jax.random.normal(ks[1], (k, d))
    codes = jax.random.randint(ks[2], (n, md), 0, k)
    qm = jnp.ones((b, mq), bool)
    dm = jax.random.uniform(ks[3], (n, md)) > 0.2
    got = ops.quantized_maxsim(q, qm, codes, dm, cb, impl="interpret",
                               block_docs=16)
    want = ops.quantized_maxsim(q, qm, codes, dm, cb, impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4,
                               rtol=1e-4)


@pytest.mark.parametrize("bits", [4, 8, 9, 16])
@pytest.mark.parametrize("shape", SHAPES[:3])
def test_hamming_kernel(shape, bits):
    b, mq, d, n, md = shape
    key = jax.random.PRNGKey(bits)
    ks = jax.random.split(key, 4)
    qc = jax.random.randint(ks[0], (b, mq), 0, 2 ** bits)
    dc = jax.random.randint(ks[1], (n, md), 0, 2 ** bits)
    qm = jax.random.uniform(ks[2], (b, mq)) > 0.3
    dm = jax.random.uniform(ks[3], (n, md)) > 0.3
    got = ops.hamming_maxsim(qc, qm, dc, dm, bits=bits, impl="interpret",
                             block_docs=16)
    want = ops.hamming_maxsim(qc, qm, dc, dm, bits=bits, impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0)


@pytest.mark.parametrize("n,d,k", [(64, 16, 8), (100, 32, 16), (256, 128, 64),
                                   (130, 8, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kmeans_assign_kernel(n, d, k, dtype):
    key = jax.random.PRNGKey(n + d + k)
    x = jax.random.normal(key, (n, d), dtype)
    c = jax.random.normal(jax.random.PRNGKey(1), (k, d), dtype)
    got = ops.kmeans_assign(x, c, impl="interpret", block_n=32)
    want = ops.kmeans_assign(x, c, impl="ref")
    # bf16 ties can flip argmin; allow tiny disagreement for bf16
    agree = float(np.mean(np.asarray(got) == np.asarray(want)))
    assert agree >= (1.0 if dtype == jnp.float32 else 0.98)


def test_kernel_consistency_with_core_library(rng):
    """ops.quantized_maxsim (kernel path) == core.late_interaction ADC."""
    from repro.core import late_interaction as li
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (2, 6, 16))
    docs = jax.random.normal(ks[1], (24, 9, 16))
    cb = jax.random.normal(ks[2], (32, 16))
    codes = quant.quantize(docs, cb)
    qm = jnp.ones((2, 6), bool)
    dm = jnp.ones((24, 9), bool)
    a = ops.quantized_maxsim(q, qm, codes, dm, cb, impl="interpret",
                             block_docs=8)
    b = li.quantized_maxsim(q, qm, codes, dm, cb)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
