"""Pallas kernel validation: interpret=True vs pure-jnp oracles, swept over
shapes and dtypes (deliverable (c) kernel requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantization as quant
from repro.kernels import ops

SHAPES = [  # (B, Mq, D, N, Md)
    (1, 4, 16, 16, 8),
    (2, 8, 32, 48, 10),
    (3, 5, 64, 64, 17),   # odd Md, padding path
    (2, 16, 128, 32, 32),
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_maxsim_kernel(shape, dtype):
    b, mq, d, n, md = shape
    key = jax.random.PRNGKey(sum(shape))
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, mq, d), dtype)
    docs = jax.random.normal(ks[1], (n, md, d), dtype)
    qm = jax.random.uniform(ks[2], (b, mq)) > 0.2
    dm = jax.random.uniform(ks[3], (n, md)) > 0.2
    got = ops.maxsim(q, qm, docs, dm, impl="interpret", block_docs=16)
    want = ops.maxsim(q, qm, docs, dm, impl="ref")
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("k", [16, 256])
def test_quantized_maxsim_kernel(shape, k):
    b, mq, d, n, md = shape
    key = jax.random.PRNGKey(sum(shape) + k)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, mq, d))
    cb = jax.random.normal(ks[1], (k, d))
    codes = jax.random.randint(ks[2], (n, md), 0, k)
    qm = jnp.ones((b, mq), bool)
    dm = jax.random.uniform(ks[3], (n, md)) > 0.2
    got = ops.quantized_maxsim(q, qm, codes, dm, cb, impl="interpret",
                               block_docs=16)
    want = ops.quantized_maxsim(q, qm, codes, dm, cb, impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4,
                               rtol=1e-4)


@pytest.mark.parametrize("bits", [4, 8, 9, 16])
@pytest.mark.parametrize("shape", SHAPES[:3])
def test_hamming_kernel(shape, bits):
    b, mq, d, n, md = shape
    key = jax.random.PRNGKey(bits)
    ks = jax.random.split(key, 4)
    qc = jax.random.randint(ks[0], (b, mq), 0, 2 ** bits)
    dc = jax.random.randint(ks[1], (n, md), 0, 2 ** bits)
    qm = jax.random.uniform(ks[2], (b, mq)) > 0.3
    dm = jax.random.uniform(ks[3], (n, md)) > 0.3
    got = ops.hamming_maxsim(qc, qm, dc, dm, bits=bits, impl="interpret",
                             block_docs=16)
    want = ops.hamming_maxsim(qc, qm, dc, dm, bits=bits, impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0)


@pytest.mark.parametrize("n,d,k", [(64, 16, 8), (100, 32, 16), (256, 128, 64),
                                   (130, 8, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kmeans_assign_kernel(n, d, k, dtype):
    key = jax.random.PRNGKey(n + d + k)
    x = jax.random.normal(key, (n, d), dtype)
    c = jax.random.normal(jax.random.PRNGKey(1), (k, d), dtype)
    got = ops.kmeans_assign(x, c, impl="interpret", block_n=32)
    want = ops.kmeans_assign(x, c, impl="ref")
    # bf16 ties can flip argmin; allow tiny disagreement for bf16
    agree = float(np.mean(np.asarray(got) == np.asarray(want)))
    assert agree >= (1.0 if dtype == jnp.float32 else 0.98)


def test_kernel_consistency_with_core_library(rng):
    """ops.quantized_maxsim (kernel path) == core.late_interaction ADC."""
    from repro.core import late_interaction as li
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (2, 6, 16))
    docs = jax.random.normal(ks[1], (24, 9, 16))
    cb = jax.random.normal(ks[2], (32, 16))
    codes = quant.quantize(docs, cb)
    qm = jnp.ones((2, 6), bool)
    dm = jnp.ones((24, 9), bool)
    a = ops.quantized_maxsim(q, qm, codes, dm, cb, impl="interpret",
                             block_docs=8)
    b = li.quantized_maxsim(q, qm, codes, dm, cb)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


# ---------------------------------------------------------------------------
# Streaming scan engine (core/scan.py): blocked score + top-k fusion
# ---------------------------------------------------------------------------

from repro.core import index as index_mod  # noqa: E402
from repro.core import late_interaction as li  # noqa: E402
from repro.core import scan as scan_mod  # noqa: E402

N_STREAM = 50  # deliberately not a multiple of any swept block size


def _adc_case(seed, n=N_STREAM, b=3, mq=5, d=16, md=7, k_cb=32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (b, mq, d))
    cb = jax.random.normal(ks[1], (k_cb, d))
    codes = jax.random.randint(ks[2], (n, md), 0, k_cb)
    qm = jax.random.uniform(ks[3], (b, mq)) > 0.2
    dm = jax.random.uniform(ks[4], (n, md)) > 0.2
    dm = dm.at[:, 0].set(True)           # no accidental all-masked docs
    # plant an exact tie: docs 10 and 20 share codes AND mask
    codes = codes.at[20].set(codes[10])
    dm = dm.at[20].set(dm[10])
    return q, qm, codes, dm, cb


def _oracle_topk(scores, k):
    s, i = jax.lax.top_k(scores, k)
    return np.asarray(s), np.asarray(i)


@pytest.mark.parametrize("block", [1, 3, 7, 16, 50, 256])
def test_streaming_adc_blocked_equals_unblocked(block):
    """Blocked sweep == unblocked oracle (jnp impl), incl. ragged
    N % block tails and lowest-index tie-breaking. Ids must match
    exactly; scores are bit-exact per block but XLA may reassociate the
    Mq-sum across different block shapes, so the cross-block comparison
    allows ULP-level tolerance. A block covering the whole corpus is the
    single-block case and must be bit-exact end to end."""
    q, qm, codes, dm, cb = _adc_case(0)
    want_s, want_i = _oracle_topk(
        li.quantized_maxsim(q, qm, codes, dm, cb), k=10)
    got_s, got_i = scan_mod.quantized_maxsim_topk(
        q, qm, codes, dm, cb, k=10,
        scan=scan_mod.ScanConfig(block_docs=block, impl="jnp"))
    np.testing.assert_array_equal(np.asarray(got_i), want_i)
    if block >= N_STREAM:
        np.testing.assert_array_equal(np.asarray(got_s), want_s)
    else:
        np.testing.assert_allclose(np.asarray(got_s), want_s,
                                   atol=1e-5, rtol=1e-6)


@pytest.mark.parametrize("block", [7, 16, 50])
def test_streaming_adc_interpret_parity(block):
    """The Pallas block scorer (interpret mode) matches the jnp engine
    up to merge-order tolerance; ids agree exactly."""
    q, qm, codes, dm, cb = _adc_case(1)
    ref_s, ref_i = scan_mod.quantized_maxsim_topk(
        q, qm, codes, dm, cb, k=10,
        scan=scan_mod.ScanConfig(block_docs=block, impl="jnp"))
    got_s, got_i = scan_mod.quantized_maxsim_topk(
        q, qm, codes, dm, cb, k=10,
        scan=scan_mod.ScanConfig(block_docs=block, impl="interpret"))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(ref_i))
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(ref_s),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("impl", ["jnp", "interpret"])
def test_streaming_float_blocked_equals_unblocked(impl):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = jax.random.normal(ks[0], (2, 6, 16))
    docs = jax.random.normal(ks[1], (37, 9, 16))
    qm = jax.random.uniform(ks[2], (2, 6)) > 0.2
    dm = jax.random.uniform(ks[3], (37, 9)) > 0.2
    dm = dm.at[:, 0].set(True)
    want_s, want_i = _oracle_topk(li.maxsim(q, qm, docs, dm), k=8)
    got_s, got_i = scan_mod.maxsim_topk(
        q, qm, docs, dm, k=8, scan=scan_mod.ScanConfig(16, impl))
    np.testing.assert_array_equal(np.asarray(got_i), want_i)
    tol = 0 if impl == "jnp" else 1e-4
    np.testing.assert_allclose(np.asarray(got_s), want_s, atol=tol, rtol=tol)


@pytest.mark.parametrize("impl", ["jnp", "interpret"])
def test_streaming_hamming_blocked_equals_unblocked(impl):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    bits = 5
    qc = jax.random.randint(ks[0], (2, 6), 0, 2 ** bits)
    dc = jax.random.randint(ks[1], (41, 9), 0, 2 ** bits)
    qm = jax.random.uniform(ks[2], (2, 6)) > 0.3
    dm = jax.random.uniform(ks[3], (41, 9)) > 0.3
    dm = dm.at[:, 0].set(True)
    want_s, want_i = _oracle_topk(
        li.binary_maxsim(qc, qm, dc, dm, bits), k=8)
    got_s, got_i = scan_mod.hamming_maxsim_topk(
        qc, qm, dc, dm, bits=bits, k=8, scan=scan_mod.ScanConfig(16, impl))
    # integer scores tie freely: require the scores bit-equal and the ids
    # equal (blocks sweep in doc order, so ties still break lowest-first);
    # dtype is int32 on every impl (the pallas f32 output is cast back)
    assert got_s.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got_s), want_s)
    np.testing.assert_array_equal(np.asarray(got_i), want_i)


def test_streaming_all_masked_docs_match_oracle():
    """Docs with every patch masked still surface (hugely negative but
    finite scores) exactly as the unblocked oracle ranks them."""
    q, qm, codes, dm, cb = _adc_case(4, n=12)
    dm = dm.at[3].set(False).at[11].set(False)
    want_s, want_i = _oracle_topk(
        li.quantized_maxsim(q, qm, codes, dm, cb), k=12)
    got_s, got_i = scan_mod.quantized_maxsim_topk(
        q, qm, codes, dm, cb, k=12, scan=scan_mod.ScanConfig(5, "jnp"))
    np.testing.assert_array_equal(np.asarray(got_i), want_i)
    np.testing.assert_allclose(np.asarray(got_s), want_s, rtol=1e-6)
    assert set(np.asarray(got_i)[0]) == set(range(12))  # nobody dropped


def test_streaming_valid_mask_and_sentinel_tail():
    """valid=False rows score NEG_INF with id -1; k beyond the valid pool
    fills with the sub-NEG_INF sentinel instead of crashing."""
    q, qm, codes, dm, cb = _adc_case(5, n=8)
    valid = jnp.array([True, False] * 4)
    got_s, got_i = scan_mod.quantized_maxsim_topk(
        q, qm, codes, dm, cb, k=8, valid=valid,
        scan=scan_mod.ScanConfig(3, "jnp"))
    got_s, got_i = np.asarray(got_s), np.asarray(got_i)
    assert set(got_i[0, :4]) == {0, 2, 4, 6}       # valid docs first
    np.testing.assert_array_equal(got_i[:, 4:], -1)
    assert np.all(got_s[:, 4:] <= li.NEG_INF)


def test_streaming_per_query_candidates_match_vmapped_oracle():
    """ivf/hnsw/rerank layout: (B, P, Md) per-query pools, bit-exact."""
    b, p, md, k_cb, mq, d = 3, 11, 6, 16, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(6), 6)
    q = jax.random.normal(ks[0], (b, mq, d))
    cb = jax.random.normal(ks[1], (k_cb, d))
    codes = jax.random.randint(ks[2], (b, p, md), 0, k_cb)
    qm = jnp.ones((b, mq), bool)
    dm = jax.random.uniform(ks[3], (b, p, md)) > 0.2
    dm = dm.at[..., 0].set(True)
    ids = jax.random.permutation(ks[4], 100)[:b * p].reshape(b, p)
    valid = jax.random.uniform(ks[5], (b, p)) > 0.2

    def oracle_one(qi, qmi, c, m, v, idr):
        s = li.quantized_maxsim(qi[None], qmi[None], c, m, cb)[0]
        s = jnp.where(v, s, li.NEG_INF)
        top_s, top_j = jax.lax.top_k(s, 5)
        return top_s, jnp.where(top_s > li.NEG_INF, idr[top_j], -1)

    want_s, want_i = jax.vmap(oracle_one)(q, qm, codes, dm, valid, ids)
    got_s, got_i = scan_mod.quantized_maxsim_topk(
        q, qm, codes, dm, cb, k=5, doc_ids=ids, valid=valid,
        scan=scan_mod.ScanConfig(4, "jnp"))
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))


def test_streaming_per_query_interpret_parity():
    b, p, md, k_cb, mq, d = 2, 9, 6, 16, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    q = jax.random.normal(ks[0], (b, mq, d))
    cb = jax.random.normal(ks[1], (k_cb, d))
    codes = jax.random.randint(ks[2], (b, p, md), 0, k_cb)
    qm = jnp.ones((b, mq), bool)
    dm = jax.random.uniform(ks[3], (b, p, md)) > 0.2
    dm = dm.at[..., 0].set(True)
    ref_s, ref_i = scan_mod.quantized_maxsim_topk(
        q, qm, codes, dm, cb, k=4, scan=scan_mod.ScanConfig(4, "jnp"))
    got_s, got_i = scan_mod.quantized_maxsim_topk(
        q, qm, codes, dm, cb, k=4, scan=scan_mod.ScanConfig(4, "interpret"))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(ref_i))
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(ref_s),
                               atol=1e-4, rtol=1e-4)


# --- k > N sentinel regression (the lax.top_k crash bugfix) ----------------

def _tiny_flat_index(seed, n=5, md=4, k_cb=8, d=8):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    cb = jax.random.normal(ks[0], (k_cb, d))
    codes = jax.random.randint(ks[1], (n, md), 0, k_cb).astype(jnp.uint8)
    mask = jnp.ones((n, md), bool)
    q = jax.random.normal(ks[2], (2, 3, d))
    qm = jnp.ones((2, 3), bool)
    return q, qm, codes, mask, cb


def test_search_flat_k_exceeds_corpus():
    q, qm, codes, mask, cb = _tiny_flat_index(0)
    ix = index_mod.build_flat(codes, mask, cb)
    s, i = index_mod.search_flat(ix, q, qm, k=9)      # v0: top_k crash
    s, i = np.asarray(s), np.asarray(i)
    assert i.shape == (2, 9)
    want_s, want_i = _oracle_topk(
        li.quantized_maxsim(q, qm, codes, mask, cb), k=5)
    np.testing.assert_array_equal(i[:, :5], want_i)
    np.testing.assert_array_equal(s[:, :5], want_s)
    np.testing.assert_array_equal(i[:, 5:], -1)
    assert np.all(s[:, 5:] <= li.NEG_INF)


def test_search_float_flat_k_exceeds_corpus():
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    docs = jax.random.normal(ks[0], (4, 3, 8))
    mask = jnp.ones((4, 3), bool)
    q = jax.random.normal(ks[1], (2, 3, 8))
    qm = jnp.ones((2, 3), bool)
    ix = index_mod.build_float_flat(docs, mask)
    s, i = index_mod.search_float_flat(ix, q, qm, k=7)
    s, i = np.asarray(s), np.asarray(i)
    want_s, want_i = _oracle_topk(li.maxsim(q, qm, docs, mask), k=4)
    np.testing.assert_array_equal(i[:, :4], want_i)
    np.testing.assert_array_equal(s[:, :4], want_s)
    np.testing.assert_array_equal(i[:, 4:], -1)
    assert np.all(s[:, 4:] <= li.NEG_INF)


def test_search_hamming_k_exceeds_corpus():
    bits = 4
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    dc = jax.random.randint(ks[0], (5, 4), 0, 2 ** bits)
    mask = jnp.ones((5, 4), bool)
    qc = jax.random.randint(ks[1], (2, 3), 0, 2 ** bits)
    qm = jnp.ones((2, 3), bool)
    ix = index_mod.build_hamming(dc, mask, bits)
    s, i = index_mod.search_hamming(ix, qc, qm, bits=bits, k=8)
    s, i = np.asarray(s), np.asarray(i)
    assert i.shape == (2, 8)
    want_s, want_i = _oracle_topk(
        li.binary_maxsim(qc, qm, ix.codes, mask, bits), k=5)
    np.testing.assert_array_equal(i[:, :5], want_i)
    np.testing.assert_array_equal(s[:, :5], want_s)
    np.testing.assert_array_equal(i[:, 5:], -1)
    assert np.all(s[:, 5:] == np.iinfo(np.int32).min)


# --- memory regression: the scan must never build O(N*Mq) ------------------

def test_streaming_scan_never_materializes_corpus_scores():
    """Acceptance: at N = 2**20 the old unblocked path's similarity
    tensor alone would be B*Mq*N*Md*4 = 2.1 GB; the streaming scan's
    budget is gated by the `search_flat` manifest (jaxpr shape
    inspection in repro.analysis), and a large-N CPU run must actually
    complete."""
    from repro.analysis import analyze_manifest, get_manifest

    m = get_manifest("search_flat")
    old_sim_bytes = 8 * 8 * m.n * 16 * 4
    assert old_sim_bytes > 30 * m.max_block_bytes
    violations = analyze_manifest(m)
    assert violations == [], [str(v) for v in violations]

    # live run at an N where the unblocked similarity tensor (~128 MB at
    # these shapes x ~4 batch copies in flight) would dwarf the blocked
    # path's footprint; plant a known best doc and retrieve it
    n_live, md, d, k_cb = 1 << 17, 16, 16, 16
    ks = jax.random.split(jax.random.PRNGKey(8), 2)
    cb = jax.random.normal(ks[0], (k_cb, d))
    cb = cb.at[3].mul(10.0)                     # self-dot dominates
    # random docs draw from every code EXCEPT 3 — only the planted doc
    # holds the loud centroid, so its top-1 win is untied
    codes = jax.random.randint(ks[1], (n_live, md), 0, k_cb - 1)
    codes = jnp.where(codes >= 3, codes + 1, codes)
    codes = codes.at[77777].set(3).astype(jnp.uint8)
    ix = index_mod.build_flat(codes.astype(jnp.uint8),
                              jnp.ones((n_live, md), bool), cb)
    q = jnp.tile(cb[3][None, None], (1, 4, 1))   # query = loud centroid
    qm = jnp.ones((1, 4), bool)
    s, i = index_mod.search_flat(ix, q, qm, k=3,
                                 scan=scan_mod.ScanConfig(512, "jnp"))
    assert int(np.asarray(i)[0, 0]) == 77777
