"""Planted JAX02 fixture: host sync inside a jitted body (never run)."""
import jax


@jax.jit
def leaky_mean(x):
    return x.mean().item()
