"""Planted JAX03 fixture: undeclared known-static param (never run)."""
import jax


@jax.jit
def head(q, k):
    return q[:k]
