"""Planted JAX05 fixture: event-loop-blocking syncs in async defs (never run)."""
import asyncio

import numpy as np


async def respond(scores):
    scores.block_until_ready()
    total = scores.sum().item()
    host = np.asarray(scores)
    return total, host


async def respond_host(meta):
    await asyncio.sleep(0)
    return np.asarray(meta)  # noqa: JAX05 - host-side metadata, no device sync


def sync_compute(scores):
    # non-async scope: the same calls are fine on an executor thread
    scores.block_until_ready()
    return np.asarray(scores)
