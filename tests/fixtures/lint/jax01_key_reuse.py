"""Planted JAX01 fixture: key reused without a split (never executed)."""
import jax


def correlated_noise():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (4,))
    b = jax.random.normal(key, (4,))
    return a + b
