"""Clean fixture: no findings from any fallback or JAX rule."""
import jax
import jax.numpy as jnp


def fresh_noise():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    return jax.random.normal(k1, (4,)) + jax.random.normal(k2, (4,))


@jax.jit
def on_device_mean(x):
    return jnp.mean(x)
