"""Planted JAX04 fixture: bare lax.top_k off the scan path (never run)."""
from jax import lax


def best(scores):
    return lax.top_k(scores, 5)


def best_guarded(scores):
    return lax.top_k(scores, 1)  # noqa: JAX04 - k=1 <= any input length
