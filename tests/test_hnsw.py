"""HNSW graph backend tests (core/graph.py, retrieval/hnsw.py).

Covers: build determinism under a fixed key, graph structural invariants
(degree caps, left-packed rows, connectivity), save/load round-trip
parity, sharding, recall vs `ivf` at an equal scanned-candidate budget,
`ef_search` monotonicity, and the -1 sentinel contract.
"""
from collections import deque

import jax
import numpy as np
import pytest

from benchmarks.ann_compare import tie_aware_recall_at_k
from repro.core import graph as graph_mod
from repro.core import late_interaction as li
from repro.core.graph import HNSWConfig
from repro.core.index import IVFConfig
from repro.data import synthetic
from repro.retrieval import Corpus, HPCConfig, Query, Retriever

K = 10
HNSW_CFG = HNSWConfig(m=8, ef_construction=48, ef_search=64, levels=4)
BASE = dict(k=64, p=60.0, prune_side="doc", kmeans_iters=10)


@pytest.fixture(scope="module")
def data():
    spec = synthetic.CorpusSpec(n_docs=256, n_queries=32, n_patches=16,
                                n_q_patches=4, dim=32, n_topics=8,
                                dup_per_doc=3)
    return synthetic.make_retrieval_corpus(jax.random.PRNGKey(0), spec)


def _corpus(data):
    return Corpus(data.doc_patches, data.doc_mask, data.doc_salience)


def _queries(data):
    return Query(data.query_patches, data.query_mask, data.query_salience)


@pytest.fixture(scope="module")
def hnsw_state(data):
    r = Retriever(HPCConfig(backend="hnsw", hnsw=HNSW_CFG, **BASE))
    return r, r.build(jax.random.PRNGKey(1), _corpus(data))


@pytest.fixture(scope="module")
def flat_oracle(data):
    """Exhaustive fused scan over the same codebook (same build key)."""
    r = Retriever(HPCConfig(backend="flat", **BASE))
    state = r.build(jax.random.PRNGKey(1), _corpus(data))
    scores, ids = r.search(state, _queries(data), k=K)
    return np.asarray(scores), np.asarray(ids)


# ---------------------------------------------------------------------------
# Build: determinism + structural invariants
# ---------------------------------------------------------------------------

def test_graph_build_deterministic(hnsw_state):
    _, state = hnsw_state
    ix = state.backend_state.index
    key = jax.random.PRNGKey(42)
    g1 = graph_mod.build_hnsw(key, ix.codes, ix.mask, ix.codebook, HNSW_CFG)
    g2 = graph_mod.build_hnsw(key, ix.codes, ix.mask, ix.codebook, HNSW_CFG)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_graph_invariants(hnsw_state):
    _, state = hnsw_state
    ix = state.backend_state.index
    nbrs = np.asarray(ix.neighbors)
    n = ix.doc_vecs.shape[0]
    assert nbrs.shape == (HNSW_CFG.levels, n, 2 * HNSW_CFG.m)
    assert nbrs.min() >= -1 and nbrs.max() < n
    for lev in range(HNSW_CFG.levels):
        rows = nbrs[lev]
        # no self-loops
        assert not np.any(rows == np.arange(n)[:, None])
        # rows are left-packed: no valid id to the right of a -1 slot
        filled = rows >= 0
        assert np.all(filled[:, :-1] | ~filled[:, 1:])
        # upper levels respect the m (not 2m) degree cap
        if lev >= 1:
            assert filled.sum(axis=1).max() <= HNSW_CFG.m
    # level-0 graph is one undirected component (reachable everywhere)
    adj = [set() for _ in range(n)]
    for i in range(n):
        for v in nbrs[0, i]:
            if v >= 0:
                adj[i].add(int(v))
                adj[int(v)].add(i)
    seen = {0}
    dq = deque([0])
    while dq:
        u = dq.popleft()
        for v in adj[u]:
            if v not in seen:
                seen.add(v)
                dq.append(v)
    assert len(seen) == n


def test_build_stats(hnsw_state):
    r, state = hnsw_state
    stats = r.build_stats(state)
    assert 0 < stats["mean_degree_l0"] <= 2 * HNSW_CFG.m
    assert stats["levels"] == HNSW_CFG.levels
    assert stats["entry_level"] == int(
        np.asarray(state.backend_state.index.node_level).max())


# ---------------------------------------------------------------------------
# save / load + sharding
# ---------------------------------------------------------------------------

def test_save_load_roundtrip(data, hnsw_state, tmp_path):
    r, state = hnsw_state
    path = r.save(str(tmp_path / "hnsw_idx"), state)
    restored = r.load(path)
    assert restored.backend_state.ef_search == HNSW_CFG.ef_search
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    s0, i0 = r.search(state, _queries(data), k=K)
    s1, i1 = r.search(restored, _queries(data), k=K)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


def test_shard_places_state_and_preserves_results(data, hnsw_state):
    r, state = hnsw_state
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    s0, i0 = r.search(state, _queries(data), k=K)
    sharded = r.shard(state, mesh)
    for leaf in jax.tree.leaves(sharded):
        assert leaf.sharding.mesh.shape == mesh.shape
    s1, i1 = r.search(sharded, _queries(data), k=K)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), atol=1e-5)


# ---------------------------------------------------------------------------
# Recall vs ivf at an equal scanned-candidate budget
# ---------------------------------------------------------------------------

def test_recall_meets_ivf_at_equal_budget(data, hnsw_state, flat_oracle):
    """Acceptance: at the same number of candidates through the fused
    scan (ef_search == n_probe * bucket_cap < n_docs), the graph router
    must meet or beat the centroid router's recall@10 against the
    exhaustive scan over the same codebook (tie-aware: near-duplicate
    docs quantize to identical codes, so equal-scored substitutes count).
    """
    r_h, st_h = hnsw_state
    oracle_scores, _ = flat_oracle
    r_i = Retriever(HPCConfig(
        backend="ivf", ivf=IVFConfig(n_list=16, n_probe=2, iters=8), **BASE))
    st_i = r_i.build(jax.random.PRNGKey(1), _corpus(data))
    cap = st_i.backend_state.index.bucket_codes.shape[1]
    budget = 2 * cap
    assert budget == HNSW_CFG.ef_search        # equal scanned budgets
    assert budget < data.doc_patches.shape[0]  # strictly less than flat

    s_h, i_h = r_h.search(st_h, _queries(data), k=K)
    s_i, i_i = r_i.search(st_i, _queries(data), k=K)
    rec_h = tie_aware_recall_at_k(np.asarray(s_h), np.asarray(i_h),
                                  oracle_scores, K)
    rec_i = tie_aware_recall_at_k(np.asarray(s_i), np.asarray(i_i),
                                  oracle_scores, K)
    assert rec_h >= rec_i, (rec_h, rec_i)
    assert rec_h >= 0.9, rec_h

    # Against the *float* (ColPali-Full) oracle both routers sit at the
    # quantization ceiling and differ only through which member of a
    # quantization-tied group they surface — require hnsw within noise.
    fs = np.asarray(li.maxsim(data.query_patches, data.query_mask,
                              data.doc_patches, data.doc_mask))
    thresh = np.sort(fs, axis=1)[:, ::-1][:, K - 1]

    def float_recall(ids):
        out = []
        for qi in range(ids.shape[0]):
            v = np.asarray(ids[qi][:K])
            v = v[v >= 0]
            tol = 1e-5 * max(abs(float(thresh[qi])), 1.0)
            out.append(np.sum(fs[qi, v] >= thresh[qi] - tol) / K)
        return float(np.mean(out))

    assert float_recall(np.asarray(i_h)) >= float_recall(np.asarray(i_i)) - 0.05


def test_ef_search_monotonicity(data, hnsw_state, flat_oracle):
    """Recall is non-decreasing as the beam widens (same built graph)."""
    _, state = hnsw_state
    ix = state.backend_state.index
    oracle_scores, _ = flat_oracle
    q = _queries(data)
    prev = -1.0
    for ef in (10, 16, 32, 64, 128):
        s, ids = graph_mod.search_hnsw(ix, q.embeddings, q.mask,
                                       ef_search=ef, k=K)
        rec = tie_aware_recall_at_k(np.asarray(s), np.asarray(ids),
                                    oracle_scores, K)
        assert rec >= prev, (ef, rec, prev)
        prev = rec
    assert prev >= 0.95  # the widest beam is near-exhaustive


# ---------------------------------------------------------------------------
# Sentinel contract
# ---------------------------------------------------------------------------

def test_sentinel_rows_when_beam_exceeds_corpus():
    """k > n_docs: the tail rows must be -1 ids with NEG_INF scores."""
    spec = synthetic.CorpusSpec(n_docs=12, n_queries=4, n_patches=8,
                                n_q_patches=4, dim=16, n_topics=2,
                                dup_per_doc=1)
    data = synthetic.make_retrieval_corpus(jax.random.PRNGKey(2), spec)
    cfg = HPCConfig(k=8, p=100.0, prune_side="none", kmeans_iters=5,
                    backend="hnsw",
                    hnsw=HNSWConfig(m=4, ef_construction=16, ef_search=32,
                                    levels=2))
    r = Retriever(cfg)
    state = r.build(jax.random.PRNGKey(3), _corpus(data))
    scores, ids = r.search(state, _queries(data), k=16)
    ids, scores = np.asarray(ids), np.asarray(scores)
    assert np.all(np.sum(ids >= 0, axis=1) == 12)   # every real doc found
    assert np.all(ids[:, 12:] == -1)                # tail is sentinel
    assert np.all(scores[ids < 0] <= li.NEG_INF / 2)
    # k beyond the ef_search budget pads (matching search_ivf), not fails
    s2, i2 = r.search(state, _queries(data), k=40)
    s2, i2 = np.asarray(s2), np.asarray(i2)
    assert i2.shape == (4, 40)
    assert np.all(np.sum(i2 >= 0, axis=1) == 12)
    assert np.all(s2[i2 < 0] <= li.NEG_INF / 2)
    # metrics accounting must ignore the sentinel rows, not index with -1
    from benchmarks.common import retrieval_metrics
    m = retrieval_metrics(ids, np.asarray(data.relevance), 10)
    assert 0.0 <= m["hit@10"] <= 1.0
