"""LM transformer tests: every assigned arch's smoke config trains, and
prefill/decode agree with the full forward exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.dist.sharding import is_logical_spec
from repro.models import transformer as T
from repro.optim import optimizer as opt

LM_ARCHS = [a for a, s in registry.ARCHS.items() if s.family == "lm"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_spec_tree_matches_params(arch):
    cfg = registry.get(arch).smoke_config
    params = T.init(jax.random.PRNGKey(0), cfg)
    specs = T.param_specs(cfg)
    assert (jax.tree.structure(params)
            == jax.tree.structure(specs, is_leaf=is_logical_spec))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_train_step(arch):
    """Reduced config: one train step on CPU, shapes + finite (assignment
    requirement f)."""
    cfg = registry.get(arch).smoke_config
    key = jax.random.PRNGKey(0)
    params = T.init(key, cfg)
    ocfg = opt.AdamWConfig(lr=1e-3, total_steps=50)
    ostate = opt.init(ocfg, params)
    B, S = 2, 32
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tok, "targets": jnp.roll(tok, -1, 1)}
    step = jax.jit(lambda p, o, b: T.train_step(p, o, b, cfg, ocfg))
    p2, o2, m = step(params, ostate, batch)
    assert jnp.isfinite(m["loss"])
    l0 = float(m["loss"])
    for _ in range(8):
        p2, o2, m = step(p2, o2, batch)
    assert float(m["loss"]) < l0  # memorises the fixed batch
    for leaf in jax.tree.leaves(p2):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_prefill_decode_match_forward(arch):
    import dataclasses
    cfg = registry.get(arch).smoke_config
    if cfg.is_moe:
        # capacity dropping makes teacher-forced forward differ from
        # incremental decode by design; equivalence is provable (and
        # tested) in the no-drop regime.
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    key = jax.random.PRNGKey(1)
    params = T.init(key, cfg)
    B, S = 2, 16
    max_len = 24 if cfg.attn_chunk <= 0 else (
        (S + cfg.attn_chunk) // cfg.attn_chunk * cfg.attn_chunk)
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab)
    logits_pre, cache = T.prefill(params, tok, cfg, max_len=max_len)
    h, _, _ = T.forward(params, tok, cfg)
    ref = T.logits_fn(params, h, cfg)[:, -1]
    np.testing.assert_allclose(np.asarray(logits_pre), np.asarray(ref),
                               atol=2e-4, rtol=1e-3)
    # 4 decode steps stay consistent with teacher-forced forward
    toks = [tok]
    logits = logits_pre
    for pos in range(S, min(S + 4, max_len - 1)):
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        toks.append(nxt[:, None])
        logits, cache = T.decode_step(params, nxt, cache, jnp.int32(pos),
                                      cfg)
    all_toks = jnp.concatenate(toks, 1)
    h2, _, _ = T.forward(params, all_toks, cfg)
    ref2 = T.logits_fn(params, h2, cfg)[:, -1]
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref2),
                               atol=2e-4, rtol=1e-3)


def test_unroll_equals_scan():
    """Cost-analysis (unrolled) lowering is numerically identical."""
    cfg = registry.get("qwen2-1.5b").smoke_config
    import dataclasses
    cfg_u = dataclasses.replace(cfg, unroll=True)
    key = jax.random.PRNGKey(2)
    params = T.init(key, cfg)
    tok = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    tgt = jnp.roll(tok, -1, 1)
    l1, _ = T.loss_fn(params, tok, tgt, cfg)
    l2, _ = T.loss_fn(params, tok, tgt, cfg_u)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_loss_mask_ignores_negative_targets():
    cfg = registry.get("llama3.2-3b").smoke_config
    key = jax.random.PRNGKey(3)
    params = T.init(key, cfg)
    tok = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    tgt = jnp.roll(tok, -1, 1)
    l_all, _ = T.loss_fn(params, tok, tgt, cfg)
    # masking half the targets changes the average only via the subset
    tgt_masked = tgt.at[:, ::2].set(-1)
    l_half, _ = T.loss_fn(params, tok, tgt_masked, cfg)
    assert jnp.isfinite(l_half) and float(l_half) != float(l_all)


def test_param_counts_match_assigned_configs():
    """Full configs hit their published parameter classes."""
    expect = {"glm4-9b": (8.5e9, 10.5e9),
              "qwen2-1.5b": (1.3e9, 1.8e9),
              "llama3.2-3b": (3.0e9, 3.9e9),
              "llama4-scout-17b-a16e": (100e9, 115e9),
              "kimi-k2-1t-a32b": (0.95e12, 1.1e12)}
    active = {"llama4-scout-17b-a16e": (15e9, 19e9),
              "kimi-k2-1t-a32b": (28e9, 36e9)}
    for arch, (lo, hi) in expect.items():
        n = registry.get(arch).config.param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo},{hi}]"
    for arch, (lo, hi) in active.items():
        n = registry.get(arch).config.active_param_count()
        assert lo <= n <= hi, f"{arch} active: {n/1e9:.2f}B"


def test_moe_load_balance_loss_positive():
    cfg = registry.get("kimi-k2-1t-a32b").smoke_config
    key = jax.random.PRNGKey(4)
    params = T.init(key, cfg)
    tok = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    _, parts = T.loss_fn(params, tok, jnp.roll(tok, -1, 1), cfg)
    assert float(parts["aux"]) > 0.0
