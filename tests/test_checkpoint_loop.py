"""Checkpointing (atomic/async/elastic) + fault-tolerant loop tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ck
from repro.data.pipeline import PrefetchPipeline
from repro.optim import optimizer as opt
from repro.train import loop as train_loop


@pytest.fixture
def tree(rng):
    return {"a": jax.random.normal(rng, (8, 4)),
            "nested": {"b": jnp.arange(6).reshape(2, 3).astype(jnp.int32)},
            "scalar": jnp.float32(3.5)}


def test_save_restore_roundtrip(tmp_path, tree):
    path = ck.save(str(tmp_path), 7, tree)
    out = ck.restore(path, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uncommitted_checkpoint_rejected(tmp_path, tree):
    path = ck.save(str(tmp_path), 1, tree)
    os.remove(os.path.join(path, "COMMIT"))
    assert ck.latest_step(str(tmp_path)) is None
    with pytest.raises(FileNotFoundError):
        ck.restore(path, tree)


def test_shape_mismatch_rejected(tmp_path, tree):
    path = ck.save(str(tmp_path), 1, tree)
    bad = dict(tree)
    bad["a"] = jnp.zeros((9, 4))
    with pytest.raises(ValueError):
        ck.restore(path, bad)


def test_manager_gc_and_resume(tmp_path, tree):
    mgr = ck.CheckpointManager(str(tmp_path), keep=2)
    for s in (10, 20, 30):
        mgr.save(s, jax.tree.map(lambda x: x + s, tree))
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path))
    assert steps == [20, 30]
    step, out = mgr.restore_latest(tree)
    assert step == 30
    np.testing.assert_allclose(np.asarray(out["a"]),
                               np.asarray(tree["a"] + 30))


def test_async_save(tmp_path, tree):
    mgr = ck.CheckpointManager(str(tmp_path))
    mgr.save_async(5, tree)
    mgr.wait()
    assert ck.latest_step(str(tmp_path)) == 5


def _toy_step():
    cfg = opt.AdamWConfig(lr=1e-2, weight_decay=0.0)

    def loss(p, b):
        return jnp.mean((p["w"] @ b["x"] - b["y"]) ** 2)

    @jax.jit
    def step(params, state, batch):
        l, g = jax.value_and_grad(loss)(params, batch)
        params, state, m = opt.update(cfg, g, state, params)
        return params, state, {"loss": l, **m}

    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (3, 3))}
    state = opt.init(cfg, params)
    batch = {"x": jax.random.normal(key, (3, 16)),
             "y": jax.random.normal(jax.random.PRNGKey(1), (3, 16))}
    return step, params, state, batch


def _batches(batch):
    while True:
        yield batch


def test_loop_runs_and_checkpoints(tmp_path):
    step, params, state, batch = _toy_step()
    cfg = train_loop.LoopConfig(total_steps=20, ckpt_every=10,
                                ckpt_dir=str(tmp_path), log_every=0)
    out = train_loop.run(step, params, state, _batches(batch), cfg,
                         log_fn=lambda *_: None)
    assert out["step"] == 20
    assert out["history"][-1]["loss"] < out["history"][0]["loss"]
    assert ck.latest_step(str(tmp_path)) == 20


def test_loop_resumes_after_preemption(tmp_path):
    """Simulated preemption: first run stops at step 10 (ckpt), second run
    resumes from it and continues to 20 without repeating steps."""
    step, params, state, batch = _toy_step()
    cfg = train_loop.LoopConfig(total_steps=10, ckpt_every=5,
                                ckpt_dir=str(tmp_path), log_every=0)
    out1 = train_loop.run(step, params, state, _batches(batch), cfg,
                          log_fn=lambda *_: None)
    # "preempted" here; restart with total_steps=20 from fresh inits
    cfg2 = train_loop.LoopConfig(total_steps=20, ckpt_every=5,
                                 ckpt_dir=str(tmp_path), log_every=0)
    logs = []
    out2 = train_loop.run(step, params, state, _batches(batch), cfg2,
                          log_fn=logs.append)
    assert any("resumed from step 10" in l for l in logs)
    assert out2["step"] == 20
    # loss continued from the first run's trajectory
    assert out2["history"][0]["loss"] <= out1["history"][0]["loss"]


def test_loop_nan_guard_skips(tmp_path):
    step, params, state, batch = _toy_step()

    calls = {"n": 0}

    def poisoned(p, s, b):
        calls["n"] += 1
        p2, s2, m = step(p, s, b)
        if calls["n"] == 3:          # inject one bad step
            m = dict(m)
            m["loss"] = jnp.float32(jnp.nan)
        return p2, s2, m

    cfg = train_loop.LoopConfig(total_steps=6, ckpt_every=0,
                                ckpt_dir=str(tmp_path), log_every=0)
    out = train_loop.run(poisoned, params, state, _batches(batch), cfg,
                         log_fn=lambda *_: None)
    assert out["stats"]["skipped"] == 1
    for leaf in jax.tree.leaves(out["params"]):
        assert bool(jnp.isfinite(leaf).all())


def test_prefetch_pipeline_straggler_reserve():
    import time

    def slow_iter():
        yield {"i": 1}
        time.sleep(1.0)          # straggler
        yield {"i": 2}
        yield {"i": 3}

    pipe = PrefetchPipeline(slow_iter(), depth=1, timeout_s=0.2)
    got = [next(pipe)["i"] for _ in range(4)]
    assert got[0] == 1
    assert 1 in got[1:]          # straggler window re-served batch 1
    assert pipe.stats["repeats"] >= 1
    pipe.close()


def test_elastic_reshard_plan(tree):
    from repro.dist.sharding import Sharder
    from repro.train import elastic
    from tests.test_sharding import fake_mesh
    s1 = Sharder(fake_mesh((16, 16), ("data", "model")))
    s2 = Sharder(fake_mesh((2, 16, 16), ("pod", "data", "model")))
    specs = {"a": ("batch", None), "nested": {"b": (None, None)},
             "scalar": ()}
    template = {"a": jnp.zeros((256, 4)), "nested": {"b": jnp.zeros((2, 3))},
                "scalar": jnp.float32(0)}
    plan = elastic.reshard_plan(s1, s2, specs, template)
    assert any("a" in k for k in plan)       # batch gains the pod axis
    assert not any("scalar" in k for k in plan)
