"""Logical-axis sharding resolver tests (dist/sharding.py) — these run on
the single CPU device; Mesh construction with 1 device is fine for
resolution logic (axis sizes are what matter)."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from tests._hypothesis_compat import given, settings, st

from repro.dist.sharding import DEFAULT_RULES, Sharder, is_logical_spec


def fake_mesh(shape, axes):
    """Mesh over a fake device grid (resolution only needs axis sizes)."""
    devs = np.empty(shape, dtype=object)
    it = np.nditer(devs, flags=["multi_index", "refs_ok"])
    d = jax.devices()[0]
    while not it.finished:
        devs[it.multi_index] = d
        it.iternext()
    return Mesh(devs, axes)


@pytest.fixture
def sharder():
    return Sharder(fake_mesh((16, 16), ("data", "model")))


@pytest.fixture
def sharder_mp():
    return Sharder(fake_mesh((2, 16, 16), ("pod", "data", "model")))


def test_divisible_dims_shard(sharder):
    assert sharder.resolve(("embed", "mlp"), (4096, 13696)) == P(None, "model")
    assert sharder.resolve(("batch", None), (256, 4096)) == P("data", None)


def test_divisibility_fallback_replicates(sharder):
    # qwen2: 12 heads on a 16-way axis -> replicate
    assert sharder.resolve(("batch", None, "heads", None),
                           (256, 4096, 12, 128)) == P("data", None, None, None)
    # but the fused qkv_out dim (1536) shards
    assert sharder.resolve(("embed", "qkv_out"), (1536, 1536)) == \
        P(None, "model")


def test_multi_axis_drop_from_right(sharder_mp):
    # batch=(pod,data): 256 % 32 == 0 -> both axes
    assert sharder_mp.resolve(("batch",), (256,)) == P(("pod", "data"))
    # edges: 61859140 not divisible by model/data products -> pod only
    got = sharder_mp.resolve(("edge",), (61859140,))
    assert got == P(("pod",))


def test_axis_conflict_avoided(sharder):
    # two dims both wanting "model": second one drops it
    spec = sharder.resolve(("mlp", "vocab"), (512, 1600))
    assert spec == P("model", None)


def test_missing_axes_ignored(sharder):
    # "pod" not in single-pod mesh -> skipped silently
    assert sharder.resolve(("batch",), (256,)) == P("data")


def test_scalar_and_empty(sharder):
    assert sharder.resolve((), ()) == P()
    assert sharder.resolve((None,), (7,)) == P(None)


@settings(max_examples=50, deadline=None)
@given(dim=st.integers(1, 10000))
def test_property_resolved_dims_always_divide(dim):
    sharder = Sharder(fake_mesh((16, 16), ("data", "model")))
    spec = sharder.resolve(("mlp",), (dim,))
    axes = spec[0]
    if axes is not None:
        names = (axes,) if isinstance(axes, str) else axes
        prod = 1
        for a in names:
            prod *= dict(zip(sharder.mesh.axis_names,
                             sharder.mesh.devices.shape))[a]
        assert dim % prod == 0


def test_is_logical_spec():
    from repro.models.transformer import KVCache
    assert is_logical_spec(("embed", "mlp"))
    assert is_logical_spec((None, "model"))
    assert is_logical_spec(())
    assert not is_logical_spec(KVCache((None,), (None,)))
    assert not is_logical_spec(("embed", 3))


def test_all_rule_axes_exist_in_production_meshes():
    for name, axes in DEFAULT_RULES.items():
        for a in axes:
            assert a in ("pod", "data", "model"), (name, a)
