"""Index + end-to-end pipeline tests (core/index.py, core/pipeline.py)."""
import jax
import numpy as np
import pytest

from repro.core import index as idx
from repro.core import pipeline as pipe
from repro.data import synthetic


@pytest.fixture(scope="module")
def corpus():
    key = jax.random.PRNGKey(0)
    spec = synthetic.CorpusSpec(n_docs=256, n_queries=32, n_patches=16,
                                n_q_patches=4, dim=32, n_topics=8,
                                dup_per_doc=3)
    return synthetic.make_retrieval_corpus(key, spec)


def _target_hit_rate(ids, relevance):
    from benchmarks.common import HIT_RELEVANCE  # single shared threshold
    hits = 0
    for i in range(ids.shape[0]):
        rel = np.asarray(relevance[i])
        row = np.asarray(ids[i])
        row = row[row >= 0]       # -1 = "no document" sentinel, not doc N-1
        hits += int((rel[row] >= HIT_RELEVANCE).any())
    return hits / ids.shape[0]


@pytest.mark.parametrize("mode,index", [
    ("float", "flat"), ("quantized", "flat"), ("quantized", "ivf"),
    ("binary", "flat")])
def test_pipeline_modes_retrieve_relevant(corpus, mode, index):
    key = jax.random.PRNGKey(1)
    cfg = pipe.HPCConfig(k=64, p=60.0, mode=mode, index=index,
                         prune_side="doc", kmeans_iters=10,
                         rerank=16 if mode == "quantized" else 0,
                         ivf=idx.IVFConfig(n_list=16, n_probe=8, iters=8))
    hpc_index = pipe.build_index(key, corpus.doc_patches, corpus.doc_mask,
                                 corpus.doc_salience, cfg)
    scores, ids = pipe.query(hpc_index, corpus.query_patches,
                             corpus.query_mask, corpus.query_salience,
                             cfg, k=10)
    assert ids.shape == (32, 10)
    hit = _target_hit_rate(ids, corpus.relevance)
    # planted corpus: relevant docs must surface in top-10
    floor = {"float": 0.9, "quantized": 0.7, "binary": 0.5}[mode]
    assert hit >= floor, f"{mode}/{index}: hit@10 {hit}"


def test_storage_ordering(corpus):
    """float > quantized > binary payloads (paper Table III ordering)."""
    key = jax.random.PRNGKey(1)
    sizes = {}
    # binary uses K=64 (b=6 bits) so the bit-packing is visible; uint8
    # codes and 8-bit binary coincide at K=256 by construction.
    for mode, k in (("float", 256), ("quantized", 256), ("binary", 64)):
        cfg = pipe.HPCConfig(k=k, p=100.0, mode=mode, prune_side="none",
                             kmeans_iters=3)
        index = pipe.build_index(key, corpus.doc_patches, corpus.doc_mask,
                                 corpus.doc_salience, cfg)
        sizes[mode] = pipe.storage_bytes(index, cfg)["payload"]
    n_codes = 256 * 16
    assert sizes["float"] == n_codes * 32 * 4
    assert sizes["quantized"] == n_codes            # 1 B/code -> 128x here
    assert sizes["binary"] == (n_codes * 6 + 7) // 8
    assert sizes["float"] > sizes["quantized"] > sizes["binary"]


def test_pruning_reduces_index_payload(corpus):
    key = jax.random.PRNGKey(1)
    cfgs = [pipe.HPCConfig(k=64, p=p, mode="quantized", prune_side="doc",
                           kmeans_iters=3) for p in (100.0, 60.0, 40.0)]
    payloads = []
    for cfg in cfgs:
        index = pipe.build_index(key, corpus.doc_patches, corpus.doc_mask,
                                 corpus.doc_salience, cfg)
        payloads.append(pipe.storage_bytes(index, cfg)["payload"])
    assert payloads[0] > payloads[1] > payloads[2]
    assert payloads[1] == pytest.approx(payloads[0] * 0.625, rel=0.01)


def test_ivf_probes_subset_but_recovers(corpus):
    key = jax.random.PRNGKey(2)
    cfg = pipe.HPCConfig(k=64, p=100.0, mode="quantized", index="ivf",
                         prune_side="none", kmeans_iters=8,
                         ivf=idx.IVFConfig(n_list=16, n_probe=16, iters=8))
    index = pipe.build_index(key, corpus.doc_patches, corpus.doc_mask,
                             corpus.doc_salience, cfg)
    assert idx.ivf_drop_rate(index.ivf, 256) < 0.01
    # probing all lists == flat search results (same top-1)
    cfg_flat = pipe.HPCConfig(k=64, p=100.0, mode="quantized", index="flat",
                              prune_side="none", kmeans_iters=8)
    index_flat = pipe.build_index(key, corpus.doc_patches, corpus.doc_mask,
                                  corpus.doc_salience, cfg_flat)
    s_ivf, ids_ivf = pipe.query(index, corpus.query_patches,
                                corpus.query_mask, corpus.query_salience,
                                cfg, k=1)
    s_flat, ids_flat = pipe.query(index_flat, corpus.query_patches,
                                  corpus.query_mask, corpus.query_salience,
                                  cfg_flat, k=1)
    # near-duplicate docs quantize to identical codes -> top-1 ids can tie;
    # the SCORES must agree when every bucket is probed.
    np.testing.assert_allclose(np.asarray(s_ivf), np.asarray(s_flat),
                               atol=1e-3)
    agree = float(np.mean(np.asarray(ids_ivf) == np.asarray(ids_flat)))
    assert agree > 0.5


def test_ivf_routing_metric_matches_build():
    """Regression (metric mismatch): queries must route by the same L2
    metric documents were bucketed with. Unnormalized centroids where max
    inner product and L2-nearest disagree: c0=(10,0) wins the dot product
    against q=(0.5,0.9), but c1=(0,1) is L2-nearest. The query's true
    match sits in c1's bucket — MIP routing (the v0 bug) probed c0."""
    import jax.numpy as jnp
    index = idx.IVFIndex(
        routing_centroids=jnp.array([[10.0, 0.0], [0.0, 1.0]]),
        bucket_codes=jnp.array([[[1]], [[0]]], jnp.uint8),  # c0 holds doc 1
        bucket_mask=jnp.ones((2, 1, 1), bool),
        bucket_valid=jnp.ones((2, 1), bool),
        bucket_doc_ids=jnp.array([[1], [0]], jnp.int32),
        codebook=jnp.array([[0.5, 0.9], [10.0, 0.0]]))
    q = jnp.array([[[0.5, 0.9]]])
    q_mask = jnp.ones((1, 1), bool)
    _, ids = idx.search_ivf(index, q, q_mask, n_probe=1, k=1)
    assert int(ids[0, 0]) == 0   # doc 0 (decodes to q) via the L2 bucket


def test_ivf_full_probe_bit_consistent_with_flat():
    """Acceptance regression: at n_probe == n_list (every bucket probed)
    the IVF ranking is bit-consistent with the flat exhaustive scan —
    the score vectors are bit-identical, and the returned ids agree
    exactly up to permutation *within* exactly-tied score groups (ADC
    scores are K table values max-reduced per patch, so distinct docs
    can tie bit-exactly; the two scans enumerate candidates in different
    orders, which is the only freedom ties leave)."""
    import jax.numpy as jnp
    from repro.retrieval import Corpus, Query, Retriever
    key = jax.random.PRNGKey(4)
    k1, k2 = jax.random.split(key)
    corpus_ = Corpus(jax.random.normal(k1, (128, 12, 24)),
                     jnp.ones((128, 12), bool), jnp.ones((128, 12)))
    queries = Query(jax.random.normal(k2, (16, 4, 24)),
                    jnp.ones((16, 4), bool), jnp.ones((16, 4)))
    base = dict(k=64, p=100.0, prune_side="none", kmeans_iters=8)
    cfg_ivf = pipe.HPCConfig(
        backend="ivf", ivf=idx.IVFConfig(n_list=8, n_probe=8, iters=8,
                                         bucket_cap=128), **base)
    cfg_flat = pipe.HPCConfig(backend="flat", **base)
    bk = jax.random.PRNGKey(5)
    s_i, i_i = Retriever(cfg_ivf).search(
        Retriever(cfg_ivf).build(bk, corpus_), queries, k=10)
    s_f, i_f = Retriever(cfg_flat).search(
        Retriever(cfg_flat).build(bk, corpus_), queries, k=10)
    s_i, i_i = np.asarray(s_i), np.asarray(i_i)
    s_f, i_f = np.asarray(s_f), np.asarray(i_f)
    np.testing.assert_array_equal(s_i, s_f)      # ranked scores bit-equal
    for q in range(s_f.shape[0]):
        # Tie groups fully inside the top-k must hold the same id sets.
        # A group tied exactly AT the k-th score may straddle the cut —
        # either member is a correct answer there, and the bit-equal
        # score rows above already pin that slot's score.
        for s in np.unique(s_f[q]):
            if s == s_f[q, -1]:
                continue
            grp = s_f[q] == s
            np.testing.assert_array_equal(np.sort(i_i[q][grp]),
                                          np.sort(i_f[q][grp]))


def test_ivf_drop_rate_enforced(corpus):
    """Regression: the promised drop-rate check actually runs at build."""
    from repro.retrieval import Corpus, Retriever
    corpus_ = Corpus(corpus.doc_patches, corpus.doc_mask,
                     corpus.doc_salience)
    cfg = pipe.HPCConfig(k=32, p=100.0, backend="ivf", prune_side="none",
                         kmeans_iters=5,
                         ivf=idx.IVFConfig(n_list=4, n_probe=2, iters=5,
                                           bucket_cap=8))
    with pytest.raises(ValueError, match="bucket overflow"):
        Retriever(cfg).build(jax.random.PRNGKey(6), corpus_)
    # a healthy build reports its (zero) drop rate through build_stats
    ok = pipe.HPCConfig(k=32, p=100.0, backend="ivf", prune_side="none",
                        kmeans_iters=5,
                        ivf=idx.IVFConfig(n_list=8, n_probe=4, iters=5))
    r = Retriever(ok)
    stats = r.build_stats(r.build(jax.random.PRNGKey(6), corpus_))
    assert stats["ivf_drop_rate"] <= ok.ivf.max_drop_rate


def test_ivf_overflow_scatter_preserves_kept_docs():
    """Regression: an overflowing doc must be discarded, not scattered
    onto slot cap-1 where it clobbers the doc legitimately stored there
    (16 identical docs into one 8-slot bucket must keep exactly 8)."""
    import jax.numpy as jnp
    codes = jnp.zeros((16, 4), jnp.uint8)      # 16 identical docs
    mask = jnp.ones((16, 4), bool)
    codebook = jnp.ones((8, 8), jnp.float32)
    cfg = idx.IVFConfig(n_list=2, n_probe=1, iters=2, restarts=1,
                        bucket_cap=8)
    index = idx.build_ivf(jax.random.PRNGKey(0), codes, mask, codebook, cfg)
    assert int(np.asarray(index.bucket_valid).sum()) == 8   # == cap, not 7
    assert idx.ivf_drop_rate(index, 16) == pytest.approx(0.5)
    stored = np.asarray(index.bucket_doc_ids)
    assert sorted(stored[stored >= 0].tolist()) == list(range(8))


def test_ivf_sentinel_ids_masked(corpus):
    """Regression: slots beyond the probed buckets' contents are -1 ids
    with NEG_INF scores, and hit accounting ignores them."""
    from repro.core import late_interaction as li
    from repro.retrieval import Corpus, Query, Retriever
    spec = synthetic.CorpusSpec(n_docs=32, n_queries=8, n_patches=8,
                                n_q_patches=4, dim=16, n_topics=4,
                                dup_per_doc=1)
    data = synthetic.make_retrieval_corpus(jax.random.PRNGKey(7), spec)
    cfg = pipe.HPCConfig(k=16, p=100.0, backend="ivf", prune_side="none",
                         kmeans_iters=5,
                         ivf=idx.IVFConfig(n_list=8, n_probe=1, iters=5))
    r = Retriever(cfg)
    state = r.build(jax.random.PRNGKey(8),
                    Corpus(data.doc_patches, data.doc_mask,
                           data.doc_salience))
    # one probed bucket holds far fewer than k=16 docs
    scores, ids = r.search(state, Query(data.query_patches, data.query_mask,
                                        data.query_salience), k=16)
    scores, ids = np.asarray(scores), np.asarray(ids)
    assert (ids < 0).any()                       # sentinel rows exist
    assert np.all(scores[ids < 0] <= li.NEG_INF / 2)
    hit = _target_hit_rate(ids, data.relevance)  # must not index with -1
    assert 0.0 <= hit <= 1.0


def test_rerank_never_hurts_target_rank(corpus):
    key = jax.random.PRNGKey(3)
    base = pipe.HPCConfig(k=64, p=40.0, mode="quantized", prune_side="doc",
                          kmeans_iters=8, rerank=0)
    rr = pipe.HPCConfig(k=64, p=40.0, mode="quantized", prune_side="doc",
                        kmeans_iters=8, rerank=32)
    i1 = pipe.build_index(key, corpus.doc_patches, corpus.doc_mask,
                          corpus.doc_salience, base)
    _, ids0 = pipe.query(i1, corpus.query_patches, corpus.query_mask,
                         corpus.query_salience, base, k=10)
    _, ids1 = pipe.query(i1, corpus.query_patches, corpus.query_mask,
                         corpus.query_salience, rr, k=10)
    h0 = _target_hit_rate(ids0, corpus.relevance)
    h1 = _target_hit_rate(ids1, corpus.relevance)
    assert h1 >= h0 - 0.05  # rerank on unpruned codes shouldn't hurt
