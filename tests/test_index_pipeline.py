"""Index + end-to-end pipeline tests (core/index.py, core/pipeline.py)."""
import jax
import numpy as np
import pytest

from repro.core import index as idx
from repro.core import pipeline as pipe
from repro.data import synthetic


@pytest.fixture(scope="module")
def corpus():
    key = jax.random.PRNGKey(0)
    spec = synthetic.CorpusSpec(n_docs=256, n_queries=32, n_patches=16,
                                n_q_patches=4, dim=32, n_topics=8,
                                dup_per_doc=3)
    return synthetic.make_retrieval_corpus(key, spec)


def _target_hit_rate(ids, relevance):
    from benchmarks.common import HIT_RELEVANCE  # single shared threshold
    hits = 0
    for i in range(ids.shape[0]):
        rel = np.asarray(relevance[i])
        hits += int((rel[np.asarray(ids[i])] >= HIT_RELEVANCE).any())
    return hits / ids.shape[0]


@pytest.mark.parametrize("mode,index", [
    ("float", "flat"), ("quantized", "flat"), ("quantized", "ivf"),
    ("binary", "flat")])
def test_pipeline_modes_retrieve_relevant(corpus, mode, index):
    key = jax.random.PRNGKey(1)
    cfg = pipe.HPCConfig(k=64, p=60.0, mode=mode, index=index,
                         prune_side="doc", kmeans_iters=10,
                         rerank=16 if mode == "quantized" else 0,
                         ivf=idx.IVFConfig(n_list=16, n_probe=8, iters=8))
    hpc_index = pipe.build_index(key, corpus.doc_patches, corpus.doc_mask,
                                 corpus.doc_salience, cfg)
    scores, ids = pipe.query(hpc_index, corpus.query_patches,
                             corpus.query_mask, corpus.query_salience,
                             cfg, k=10)
    assert ids.shape == (32, 10)
    hit = _target_hit_rate(ids, corpus.relevance)
    # planted corpus: relevant docs must surface in top-10
    floor = {"float": 0.9, "quantized": 0.7, "binary": 0.5}[mode]
    assert hit >= floor, f"{mode}/{index}: hit@10 {hit}"


def test_storage_ordering(corpus):
    """float > quantized > binary payloads (paper Table III ordering)."""
    key = jax.random.PRNGKey(1)
    sizes = {}
    # binary uses K=64 (b=6 bits) so the bit-packing is visible; uint8
    # codes and 8-bit binary coincide at K=256 by construction.
    for mode, k in (("float", 256), ("quantized", 256), ("binary", 64)):
        cfg = pipe.HPCConfig(k=k, p=100.0, mode=mode, prune_side="none",
                             kmeans_iters=3)
        index = pipe.build_index(key, corpus.doc_patches, corpus.doc_mask,
                                 corpus.doc_salience, cfg)
        sizes[mode] = pipe.storage_bytes(index, cfg)["payload"]
    n_codes = 256 * 16
    assert sizes["float"] == n_codes * 32 * 4
    assert sizes["quantized"] == n_codes            # 1 B/code -> 128x here
    assert sizes["binary"] == (n_codes * 6 + 7) // 8
    assert sizes["float"] > sizes["quantized"] > sizes["binary"]


def test_pruning_reduces_index_payload(corpus):
    key = jax.random.PRNGKey(1)
    cfgs = [pipe.HPCConfig(k=64, p=p, mode="quantized", prune_side="doc",
                           kmeans_iters=3) for p in (100.0, 60.0, 40.0)]
    payloads = []
    for cfg in cfgs:
        index = pipe.build_index(key, corpus.doc_patches, corpus.doc_mask,
                                 corpus.doc_salience, cfg)
        payloads.append(pipe.storage_bytes(index, cfg)["payload"])
    assert payloads[0] > payloads[1] > payloads[2]
    assert payloads[1] == pytest.approx(payloads[0] * 0.625, rel=0.01)


def test_ivf_probes_subset_but_recovers(corpus):
    key = jax.random.PRNGKey(2)
    cfg = pipe.HPCConfig(k=64, p=100.0, mode="quantized", index="ivf",
                         prune_side="none", kmeans_iters=8,
                         ivf=idx.IVFConfig(n_list=16, n_probe=16, iters=8))
    index = pipe.build_index(key, corpus.doc_patches, corpus.doc_mask,
                             corpus.doc_salience, cfg)
    assert idx.ivf_drop_rate(index.ivf, 256) < 0.01
    # probing all lists == flat search results (same top-1)
    cfg_flat = pipe.HPCConfig(k=64, p=100.0, mode="quantized", index="flat",
                              prune_side="none", kmeans_iters=8)
    index_flat = pipe.build_index(key, corpus.doc_patches, corpus.doc_mask,
                                  corpus.doc_salience, cfg_flat)
    s_ivf, ids_ivf = pipe.query(index, corpus.query_patches,
                                corpus.query_mask, corpus.query_salience,
                                cfg, k=1)
    s_flat, ids_flat = pipe.query(index_flat, corpus.query_patches,
                                  corpus.query_mask, corpus.query_salience,
                                  cfg_flat, k=1)
    # near-duplicate docs quantize to identical codes -> top-1 ids can tie;
    # the SCORES must agree when every bucket is probed.
    np.testing.assert_allclose(np.asarray(s_ivf), np.asarray(s_flat),
                               atol=1e-3)
    agree = float(np.mean(np.asarray(ids_ivf) == np.asarray(ids_flat)))
    assert agree > 0.5


def test_rerank_never_hurts_target_rank(corpus):
    key = jax.random.PRNGKey(3)
    base = pipe.HPCConfig(k=64, p=40.0, mode="quantized", prune_side="doc",
                          kmeans_iters=8, rerank=0)
    rr = pipe.HPCConfig(k=64, p=40.0, mode="quantized", prune_side="doc",
                        kmeans_iters=8, rerank=32)
    i1 = pipe.build_index(key, corpus.doc_patches, corpus.doc_mask,
                          corpus.doc_salience, base)
    _, ids0 = pipe.query(i1, corpus.query_patches, corpus.query_mask,
                         corpus.query_salience, base, k=10)
    _, ids1 = pipe.query(i1, corpus.query_patches, corpus.query_mask,
                         corpus.query_salience, rr, k=10)
    h0 = _target_hit_rate(ids0, corpus.relevance)
    h1 = _target_hit_rate(ids1, corpus.relevance)
    assert h1 >= h0 - 0.05  # rerank on unpruned codes shouldn't hurt
