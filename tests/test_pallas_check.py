"""Tests for the static Pallas kernel verifier (repro.analysis.pallas_check).

The registered production geometries must verify clean, and each rule
(PAL01 VMEM overflow, PAL02 tiling divisibility, PAL03 output-block
coverage, PAL04 dtype contract) is proven live on a planted kernel
defined in THIS file — every finding must anchor at the planted
kernel's def line here, exact (file, rule).

Also covers the runtime half of the contract (kernels/vmem.py): the
kernels' bare asserts became ValueErrors carrying the computed VMEM
footprint, and the scan engine's tile picker shrinks the doc tile until
the footprint fits — the docstring's formerly unchecked "K <= 512 keeps
it in VMEM" envelope.
"""
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest
from jax.experimental import pallas as pl

from repro.analysis.pallas_check import (KernelSite, capture_calls,
                                         check_all, check_site,
                                         kernel_sites)
from repro.kernels import quantized_maxsim as qk
from repro.kernels import vmem

sds = jax.ShapeDtypeStruct
HERE = Path(__file__).name


# --- the repo registry verifies clean -------------------------------------

def test_registered_kernel_sites_are_clean():
    sites = kernel_sites()
    assert {s.name for s in sites} >= {
        "qmaxsim_manifest", "qmaxsim_serving", "qmaxsim_k512",
        "maxsim_serving", "hamming_serving", "kmeans_assign_default"}
    assert check_all() == []


def test_capture_sees_blockspecs_and_kernel_temporaries():
    site = next(s for s in kernel_sites() if s.name == "qmaxsim_serving")
    fn, args = site.build()
    calls = capture_calls(fn, args)
    assert len(calls) == 1
    call = calls[0]
    assert call.path.endswith("src/repro/kernels/quantized_maxsim.py")
    assert call.kernel_name == "_qmaxsim_kernel"
    assert call.grid and all(g >= 1 for g in call.grid)
    # the one-hot (block_docs*Md, K) f32 expansion alone: the jaxpr pass
    # must see at least that much in-kernel VMEM (the part BlockSpecs
    # cannot)
    tile = call.in_blocks[2].block_shape[0]
    md, k = call.in_blocks[2].block_shape[1], call.in_blocks[0].block_shape[2]
    assert call.kernel_tmp_bytes >= tile * md * k * 4
    assert call.vmem_bytes() <= vmem.VMEM_BUDGET_BYTES


# --- planted violations: each rule fires at exact (file, rule) ------------

def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def _bf16_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].astype(jnp.bfloat16)


def _site(fn, args, out_dtypes=("float32",), name="planted"):
    return KernelSite(name, lambda: (fn, args), out_dtypes)


def _findings_for(fn, args, **kw):
    return check_site(_site(fn, args, **kw))


def test_pal01_vmem_overflow_fires_here():
    # one (2048, 2048) f32 block in + out = 32 MiB, double-buffered to
    # 64 MiB against the 16 MiB budget
    shape = (2048, 2048)

    def fn(x):
        return pl.pallas_call(
            _copy_kernel,
            out_shape=sds(shape, jnp.float32),
            grid=(1,),
            in_specs=[pl.BlockSpec(shape, lambda i: (0, 0))],
            out_specs=pl.BlockSpec(shape, lambda i: (0, 0)),
        )(x)

    findings = _findings_for(fn, (sds(shape, jnp.float32),))
    assert [f.code for f in findings] == ["PAL01"]
    f = findings[0]
    assert Path(f.path).name == HERE
    assert f.line == _copy_kernel.__code__.co_firstlineno
    assert "VMEM footprint" in f.msg and "MiB" in f.msg


def test_pal02_non_divisible_block_fires_here():
    # 100 rows against an 8-row block: the grid drops 4 trailing rows
    def fn(x):
        return pl.pallas_call(
            _copy_kernel,
            out_shape=sds((100, 8), jnp.float32),
            grid=(12,),
            in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((8, 8), lambda i: (i, 0)),
        )(x)

    findings = _findings_for(fn, (sds((100, 8), jnp.float32),))
    assert {f.code for f in findings} == {"PAL02"}
    assert len(findings) == 2          # operand 0 and output 0
    assert all(Path(f.path).name == HERE for f in findings)
    assert "not divisible" in findings[0].msg
    assert "4 row(s)" in findings[0].msg


def test_pal03_uncovered_and_multiwritten_blocks_fire_here():
    # 4 output blocks, but every grid step lands on block (0, 0): three
    # blocks never written, one written four times
    def fn(x):
        return pl.pallas_call(
            _copy_kernel,
            out_shape=sds((64, 8), jnp.float32),
            grid=(4,),
            in_specs=[pl.BlockSpec((16, 8), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((16, 8), lambda i: (0, 0)),
        )(x)

    findings = _findings_for(fn, (sds((64, 8), jnp.float32),))
    assert [f.code for f in findings] == ["PAL03", "PAL03"]
    assert all(Path(f.path).name == HERE for f in findings)
    missing = [f for f in findings if "never written" in f.msg]
    multi = [f for f in findings if "written 4 times" in f.msg]
    assert len(missing) == 1 and "3 block(s)" in missing[0].msg
    assert len(multi) == 1


def test_pal04_output_dtype_contract_fires_here():
    shape = (64, 8)

    def fn(x):
        return pl.pallas_call(
            _bf16_kernel,
            out_shape=sds(shape, jnp.bfloat16),
            grid=(4,),
            in_specs=[pl.BlockSpec((16, 8), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((16, 8), lambda i: (i, 0)),
        )(x)

    findings = _findings_for(fn, (sds(shape, jnp.float32),),
                             out_dtypes=("float32",))
    assert [f.code for f in findings] == ["PAL04"]
    f = findings[0]
    assert Path(f.path).name == HERE
    assert f.line == _bf16_kernel.__code__.co_firstlineno
    assert "bfloat16" in f.msg and "float32" in f.msg


def test_planted_over_vmem_blockspec_rejected_in_registry_shape():
    """Acceptance: the same over-VMEM geometry packaged exactly like a
    registry site is rejected by check_all when passed explicitly."""
    shape = (4096, 1024)

    def fn(x):
        return pl.pallas_call(
            _copy_kernel,
            out_shape=sds(shape, jnp.float32),
            grid=(1,),
            in_specs=[pl.BlockSpec(shape, lambda i: (0, 0))],
            out_specs=pl.BlockSpec(shape, lambda i: (0, 0)),
        )(x)

    site = _site(fn, (sds(shape, jnp.float32),), name="planted_overflow")
    findings = check_all([site] + list(kernel_sites()))
    assert [f.code for f in findings] == ["PAL01"]
    assert "[planted_overflow]" in findings[0].msg


# --- the runtime contract: ValueErrors with computed footprints -----------

def test_qmaxsim_k512_default_tile_overflows_and_raises():
    """The docstring's old claim ("K <= 512 keeps the one-hot tile in
    VMEM") is false at the default 32-doc tile with Md=128 — the entry
    point must now say so instead of silently spilling."""
    need = qk.qmaxsim_vmem_bytes(32, 32, 512, 128)
    assert need > vmem.VMEM_BUDGET_BYTES

    def call():
        return qk.quantized_maxsim_pallas(
            jnp.zeros((8, 32, 512)), jnp.ones((8, 32)),
            jnp.zeros((256, 128), jnp.int32), jnp.ones((256, 128)),
            block_docs=32)
    with pytest.raises(ValueError, match="VMEM footprint") as ei:
        jax.eval_shape(call)
    assert "one-hot tile is (4096, 512)" in str(ei.value)


def test_scan_tile_picker_shrinks_k512_to_fit():
    from repro.core.scan import _kernel_tile
    fits = lambda t: vmem.fits(qk.qmaxsim_vmem_bytes(t, 32, 512, 128))
    tile = _kernel_tile(256, 32, fits=fits)
    assert tile == 16
    assert vmem.fits(qk.qmaxsim_vmem_bytes(tile, 32, 512, 128))
    # and the static verifier agrees: the k512 registry site is clean
    site = next(s for s in kernel_sites() if s.name == "qmaxsim_k512")
    assert check_site(site) == []


def test_check_divisible_is_a_valueerror_not_an_assert():
    with pytest.raises(ValueError, match="quantized_maxsim_pallas"):
        jax.eval_shape(lambda: qk.quantized_maxsim_pallas(
            jnp.zeros((2, 4, 16)), jnp.ones((2, 4)),
            jnp.zeros((100, 8), jnp.int32), jnp.ones((100, 8)),
            block_docs=32))
    with pytest.raises(ValueError, match="block_docs"):
        vmem.check_divisible(64, 0, kernel="k")
