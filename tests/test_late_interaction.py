"""MaxSim late-interaction tests (core/late_interaction.py)."""
import jax
import jax.numpy as jnp
import numpy as np

from tests._hypothesis_compat import given, settings, st

from repro.core import late_interaction as li
from repro.core import quantization as quant


def _data(key, b=3, mq=5, n=16, md=7, d=8, k=16):
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, mq, d))
    docs = jax.random.normal(ks[1], (n, md, d))
    qm = jax.random.uniform(ks[2], (b, mq)) > 0.2
    dm = jax.random.uniform(ks[3], (n, md)) > 0.2
    cb = jax.random.normal(ks[0], (k, d))
    codes = quant.quantize(docs, cb)
    return q, qm, docs, dm, cb, codes


def test_maxsim_brute_force_equivalence(rng):
    q, qm, docs, dm, cb, codes = _data(rng)
    got = li.maxsim(q, qm, docs, dm)
    # O(B*N*Mq*Md) python reference
    b, n = q.shape[0], docs.shape[0]
    for bi in range(b):
        for ni in range(n):
            s = 0.0
            for i in range(q.shape[1]):
                if not qm[bi, i]:
                    continue
                best = -1e30
                for j in range(docs.shape[1]):
                    if dm[ni, j]:
                        best = max(best, float(q[bi, i] @ docs[ni, j]))
                s += best
            assert abs(float(got[bi, ni]) - s) < 1e-3


def test_adc_equals_decode_equals_float(rng):
    q, qm, docs, dm, cb, codes = _data(rng)
    dec = quant.decode(codes, cb)
    s_float = li.maxsim(q, qm, dec, dm)
    s_adc = li.quantized_maxsim(q, qm, codes, dm, cb)
    s_dec = li.quantized_maxsim_decode(q, qm, codes, dm, cb)
    np.testing.assert_allclose(np.asarray(s_adc), np.asarray(s_dec),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_adc), np.asarray(s_float),
                               atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000))
def test_property_masked_patches_never_contribute(seed):
    """Appending masked-out patches must not change any score."""
    key = jax.random.PRNGKey(seed)
    q, qm, docs, dm, cb, codes = _data(key, b=2, n=4)
    s0 = li.maxsim(q, qm, docs, dm)
    # append garbage patches with mask False
    garbage = 100.0 + jax.random.normal(key, (4, 3, 8))
    docs2 = jnp.concatenate([docs, garbage], axis=1)
    dm2 = jnp.concatenate([dm, jnp.zeros((4, 3), bool)], axis=1)
    s1 = li.maxsim(q, qm, docs2, dm2)
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000))
def test_property_maxsim_monotone_in_doc_patches(seed):
    """Adding a VALID doc patch can only increase (or keep) the score."""
    key = jax.random.PRNGKey(seed)
    q, qm, docs, dm, cb, codes = _data(key, b=2, n=4)
    dm_all = jnp.ones_like(dm)
    s0 = li.maxsim(q, qm, docs[:, :5], dm_all[:, :5])
    s1 = li.maxsim(q, qm, docs, dm_all)
    assert bool(jnp.all(s1 >= s0 - 1e-5))


def test_binary_maxsim_score_bounds(rng):
    q, qm, docs, dm, cb, codes = _data(rng, k=16)
    qc = quant.quantize(q, cb, code_dtype=jnp.uint16)
    s = li.binary_maxsim(qc, qm, codes, dm, bits=4)
    max_possible = 4 * int(jnp.sum(qm, axis=1).max())
    assert int(s.max()) <= max_possible


def test_single_vector_baseline_shape(rng):
    q, qm, docs, dm, *_ = _data(rng)
    s = li.single_vector_score(q, qm, docs, dm)
    assert s.shape == (3, 16)
    assert bool(jnp.all(jnp.abs(s) <= 1.0 + 1e-5))  # cosine in [-1, 1]


def test_flops_accounting():
    full = li.late_interaction_flops(32, 1024, 128, 10_000)
    adc = li.adc_flops(32, 1024, 128, 256, 10_000)
    assert adc < full / 1000  # ADC removes per-doc matmuls entirely
