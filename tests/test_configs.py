"""Registry / config-surface tests: the 10 assigned archs x their shapes."""

from repro.configs import registry
from repro.configs.base import ArchSpec


ASSIGNED = ["glm4-9b", "qwen2-1.5b", "llama3.2-3b",
            "llama4-scout-17b-a16e", "kimi-k2-1t-a32b",
            "pna", "din", "dlrm-mlperf", "dien", "dcn-v2"]


def test_all_assigned_archs_registered():
    for a in ASSIGNED:
        assert isinstance(registry.get(a), ArchSpec)
    assert "colpali-hpc" in registry.ARCHS     # the paper's own system


def test_cell_counts():
    all_incl = list(registry.all_cells(include_skipped=True,
                                       include_colpali=False))
    assert len(all_incl) == 40                 # 10 archs x 4 shapes
    runnable = list(registry.all_cells(include_colpali=False))
    # long_500k skipped for 4 pure full-attention LM archs
    assert len(runnable) == 36
    skipped = [c for a, c in all_incl if c.skip]
    assert len(skipped) == 4
    assert all(c.name == "long_500k" for c in skipped)


def test_llama4_runs_long_context_cell():
    spec = registry.get("llama4-scout-17b-a16e")
    long_cell = [c for c in spec.shapes if c.name == "long_500k"][0]
    assert long_cell.skip is None
    assert spec.config.attn_chunk == 8192


def test_exact_assigned_configs():
    """Spot-check the exact public numbers from the assignment block."""
    g = registry.get("glm4-9b").config
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads,
            g.d_ff, g.vocab) == (40, 4096, 32, 2, 13696, 151552)
    q = registry.get("qwen2-1.5b").config
    assert (q.n_layers, q.d_model, q.n_heads, q.n_kv_heads, q.d_ff,
            q.vocab, q.qkv_bias) == (28, 1536, 12, 2, 8960, 151936, True)
    l = registry.get("llama3.2-3b").config
    assert (l.n_layers, l.d_model, l.n_heads, l.n_kv_heads, l.d_ff,
            l.vocab) == (28, 3072, 24, 8, 8192, 128256)
    s = registry.get("llama4-scout-17b-a16e").config
    assert (s.n_layers, s.d_model, s.n_heads, s.n_kv_heads, s.vocab,
            s.n_experts, s.moe_top_k) == (48, 5120, 40, 8, 202048, 16, 1)
    k = registry.get("kimi-k2-1t-a32b").config
    assert (k.n_layers, k.d_model, k.n_heads, k.n_kv_heads, k.vocab,
            k.n_experts, k.moe_top_k, k.moe_d_ff) == (
        61, 7168, 64, 8, 163840, 384, 8, 2048)
    p = registry.get("pna").config
    assert (p.n_layers, p.d_hidden) == (4, 75)
    d = registry.get("dlrm-mlperf").config
    assert (d.n_dense, d.n_sparse, d.embed_dim) == (13, 26, 128)
    assert d.bot_mlp == (512, 256, 128)
    assert d.top_mlp == (1024, 1024, 512, 256, 1)
    c = registry.get("dcn-v2").config
    assert (c.n_cross_layers, c.embed_dim, c.n_sparse) == (3, 16, 26)
    di = registry.get("din").config
    assert (di.embed_dim, di.seq_len, di.attn_mlp, di.top_mlp) == (
        18, 100, (80, 40), (200, 80))
    de = registry.get("dien").config
    assert (de.gru_dim, de.embed_dim) == (108, 18)


def test_recsys_tables_shard_cleanly():
    """Padded rows divide the 16-way model axis (docs/design.md §6)."""
    for a in ("dlrm-mlperf", "dcn-v2", "din", "dien"):
        for r in registry.get(a).config.table_rows:
            assert r % 512 == 0


def test_gnn_edges_padded_for_sharding():
    for cell in registry.get("pna").shapes:
        assert cell.dims["n_edges"] % 4096 == 0


def test_lm_shape_dims_match_assignment():
    for a in ("glm4-9b", "qwen2-1.5b", "llama3.2-3b",
              "llama4-scout-17b-a16e", "kimi-k2-1t-a32b"):
        shapes = {c.name: c.dims for c in registry.get(a).shapes}
        assert shapes["train_4k"] == {"seq_len": 4096, "global_batch": 256}
        assert shapes["prefill_32k"] == {"seq_len": 32768,
                                         "global_batch": 32}
        assert shapes["decode_32k"] == {"seq_len": 32768,
                                        "global_batch": 128}
        assert shapes["long_500k"] == {"seq_len": 524288, "global_batch": 1}


def test_recsys_shape_dims_match_assignment():
    for a in ("din", "dlrm-mlperf", "dien", "dcn-v2"):
        shapes = {c.name: c.dims for c in registry.get(a).shapes}
        assert shapes["train_batch"]["batch"] == 65536
        assert shapes["serve_p99"]["batch"] == 512
        assert shapes["serve_bulk"]["batch"] == 262144
        assert shapes["retrieval_cand"]["n_candidates"] == 1_000_000
