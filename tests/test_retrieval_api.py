"""Retriever API v1 tests: registry, parity with the v0 pipeline,
save/load round-trips, sharding, and the HPCConfig deprecation shim.

The parity reference below is a frozen inline copy of the v0
`build_index`/`query`/`storage_bytes` path (core/pipeline.py at the seed),
so the refactor is pinned to be *numerically identical*, not just
plausible.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import binary as binary_mod
from repro.core import index as index_mod
from repro.core import late_interaction as li
from repro.core import pruning
from repro.core import quantization as quant
from repro.data import synthetic
from repro.retrieval import (Corpus, HPCConfig, Query, Retriever,
                             available_backends, code_dtype, get_backend)


# ---------------------------------------------------------------------------
# Frozen v0 reference (verbatim semantics of the seed pipeline)
# ---------------------------------------------------------------------------

def _legacy_build(key, doc_emb, doc_mask, doc_salience, config):
    n, md, d = doc_emb.shape
    k_cb, k_ivf = jax.random.split(key)

    if config.mode == "float":
        emb, mask = doc_emb, doc_mask
        if config.prune_side in ("doc", "both"):
            pr = pruning.prune_topp(doc_emb, doc_salience, doc_mask,
                                    p=config.p)
            emb, mask = pr.embeddings, pr.mask
        return {"codebook": jnp.zeros((1, d), doc_emb.dtype),
                "float_flat": index_mod.build_float_flat(emb, mask)}

    flat = doc_emb.reshape(-1, d)
    flat_mask = doc_mask.reshape(-1)
    valid_idx = jnp.argsort(~flat_mask, stable=True)
    n_valid = jnp.sum(flat_mask)
    gather_idx = jnp.where(
        jnp.arange(flat.shape[0]) < n_valid,
        valid_idx,
        valid_idx[jnp.mod(jnp.arange(flat.shape[0]),
                          jnp.maximum(n_valid, 1))])
    train_x = flat[gather_idx]
    codebook, _ = quant.kmeans_fit(
        k_cb, train_x,
        quant.KMeansConfig(k=config.k, iters=config.kmeans_iters))
    codes_full = quant.quantize(doc_emb, codebook,
                                code_dtype=jnp.uint8 if config.k <= 256
                                else jnp.uint16)
    if config.prune_side in ("doc", "both"):
        codes, _, mask, _ = pruning.prune_topp_codes(
            codes_full, doc_salience, doc_mask, p=config.p)
    else:
        codes, mask = codes_full, doc_mask

    out = {"codebook": codebook, "rerank_codes": codes_full,
           "rerank_mask": doc_mask}
    if config.mode == "binary":
        out["hamming"] = index_mod.build_hamming(codes, mask, config.bits)
    elif config.index == "ivf":
        out["ivf"] = index_mod.build_ivf(k_ivf, codes, mask, codebook,
                                         config.ivf)
    else:
        out["flat"] = index_mod.build_flat(codes, mask, codebook)
    return out


def _legacy_query(ix, q_emb, q_mask, q_salience, config, *, k):
    if config.prune_side in ("query", "both"):
        pr = pruning.prune_topp(q_emb, q_salience, q_mask, p=config.p)
        q_emb, q_mask = pr.embeddings, pr.mask

    n_cand = k if config.rerank == 0 else max(k, config.rerank)
    if config.mode == "float":
        scores, ids = index_mod.search_float_flat(
            ix["float_flat"], q_emb, q_mask, k=n_cand)
    elif config.mode == "binary":
        # v0 quirk: queries always quantized to uint16 (values identical)
        q_codes = quant.quantize(q_emb, ix["codebook"],
                                 code_dtype=jnp.uint16)
        scores, ids = index_mod.search_hamming(
            ix["hamming"], q_codes, q_mask, bits=config.bits, k=n_cand)
    elif config.index == "ivf":
        scores, ids = index_mod.search_ivf(
            ix["ivf"], q_emb, q_mask, n_probe=config.ivf.n_probe, k=n_cand)
    else:
        scores, ids = index_mod.search_flat(ix["flat"], q_emb, q_mask,
                                            k=n_cand)

    if config.rerank and config.mode != "float":
        cand_codes = ix["rerank_codes"][ids]
        cand_mask = ix["rerank_mask"][ids]

        def rerank_one(qi, qmi, codes, msk):
            return li.quantized_maxsim(qi[None], qmi[None], codes, msk,
                                       ix["codebook"])[0]

        re_scores = jax.vmap(rerank_one)(q_emb, q_mask, cand_codes,
                                         cand_mask)
        re_scores = jnp.where(ids >= 0, re_scores, li.NEG_INF)
        top_s, top_i = jax.lax.top_k(re_scores, k)
        return top_s, jnp.take_along_axis(ids, top_i, axis=1)
    return scores[:, :k], ids[:, :k]


def _legacy_storage(ix, config):
    out = {}
    if config.mode == "float":
        e = ix["float_flat"].embeddings
        out["payload"] = e.size * e.dtype.itemsize
    elif config.mode == "binary":
        n_codes = int(ix["hamming"].codes.size)
        out["payload"] = binary_mod.packed_nbytes(n_codes, config.bits)
        out["codebook"] = (ix["codebook"].size
                           * ix["codebook"].dtype.itemsize)
    else:
        codes = (ix["flat"].codes if "flat" in ix
                 else ix["ivf"].bucket_codes)
        out["payload"] = codes.size * codes.dtype.itemsize
        out["codebook"] = (ix["codebook"].size
                           * ix["codebook"].dtype.itemsize)
    return out


# ---------------------------------------------------------------------------
# Fixtures / configs
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def data():
    key = jax.random.PRNGKey(0)
    spec = synthetic.CorpusSpec(n_docs=128, n_queries=16, n_patches=12,
                                n_q_patches=4, dim=24, n_topics=8,
                                dup_per_doc=2)
    return synthetic.make_retrieval_corpus(key, spec)


CONFIGS = {
    "float_flat": HPCConfig(backend="float_flat", p=60.0, prune_side="doc",
                            kmeans_iters=5),
    "flat": HPCConfig(k=32, p=60.0, backend="flat", prune_side="doc",
                      kmeans_iters=8, rerank=12),
    "ivf": HPCConfig(k=32, p=100.0, backend="ivf", prune_side="none",
                     kmeans_iters=8, rerank=12,
                     ivf=index_mod.IVFConfig(n_list=8, n_probe=4, iters=5)),
    "hamming": HPCConfig(k=32, p=60.0, backend="hamming", prune_side="doc",
                         kmeans_iters=8),
}


def _corpus(data):
    return Corpus(data.doc_patches, data.doc_mask, data.doc_salience)


def _queries(data):
    return Query(data.query_patches, data.query_mask, data.query_salience)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_resolves_all_backends():
    assert available_backends() == ("cascade", "flat", "float_flat",
                                    "hamming", "hnsw", "ivf")
    for name in available_backends():
        b = get_backend(name)
        assert b.name == name
        assert get_backend(name) is b          # singleton


def test_registry_unknown_backend_raises():
    with pytest.raises(KeyError, match="scann"):
        get_backend("scann")


def test_out_of_tree_backend_with_legacy_search_signature(data):
    """An out-of-tree backend written against the pre-scan contract
    search(state, query, *, k) must keep working: registration now warns
    once (DeprecationWarning) and installs a kwargs-stripping shim, so
    the facade can always pass `scan=` without sniffing signatures."""
    from repro.retrieval import base as base_mod

    with pytest.warns(DeprecationWarning, match="scan"):
        @base_mod.register_backend("legacy_sig")
        class LegacyBackend(base_mod.IndexBackend):
            exact_scores = True

            def build(self, key, corpus, cfg, mesh=None):
                n = corpus.embeddings.shape[0]
                return base_mod.RetrieverState(
                    jnp.zeros((1, 1)), jnp.arange(n, dtype=jnp.int32),
                    jnp.zeros((n, 1), jnp.uint8), jnp.zeros((n, 1), bool))

            def search(self, state, query, *, k):      # no `scan` kwarg
                b = query.embeddings.shape[0]
                ids = jnp.tile(state.backend_state[None, :k], (b, 1))
                return jnp.zeros((b, k)), ids

            def storage_bytes(self, state):
                return {}

    try:
        r = Retriever(HPCConfig(backend="legacy_sig"))
        state = r.build(jax.random.PRNGKey(0), _corpus(data))
        scores, ids = r.search(state, _queries(data), k=3)
        assert ids.shape == (data.query_patches.shape[0], 3)
        np.testing.assert_array_equal(np.asarray(ids[0]), [0, 1, 2])
        # the shim accepts (and drops) the scan kwarg explicitly too
        from repro.core.scan import ScanConfig
        s2, i2 = get_backend("legacy_sig").search(
            state, _queries(data), k=3, scan=ScanConfig(block_docs=7))
        np.testing.assert_array_equal(np.asarray(i2), np.asarray(ids))
    finally:
        base_mod._REGISTRY.pop("legacy_sig", None)


def test_modern_backend_registration_does_not_warn(recwarn):
    """Backends that accept scan= (or **kwargs) register silently."""
    from repro.retrieval import base as base_mod

    @base_mod.register_backend("modern_sig")
    class ModernBackend(base_mod.IndexBackend):
        def search(self, state, query, *, k, scan=None):
            raise NotImplementedError

        def storage_bytes(self, state):
            return {}

    try:
        assert not [w for w in recwarn
                    if issubclass(w.category, DeprecationWarning)]
    finally:
        base_mod._REGISTRY.pop("modern_sig", None)


def test_code_dtype_boundary():
    assert code_dtype(128) == jnp.uint8
    assert code_dtype(256) == jnp.uint8
    assert code_dtype(257) == jnp.uint16
    assert code_dtype(512) == jnp.uint16


# ---------------------------------------------------------------------------
# Parity with the frozen v0 path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(CONFIGS))
def test_parity_with_v0_pipeline(data, name):
    cfg = CONFIGS[name]
    key = jax.random.PRNGKey(7)
    r = Retriever(cfg)

    state = r.build(key, _corpus(data))
    s_new, i_new = r.search(state, _queries(data), k=5)

    legacy_ix = _legacy_build(key, data.doc_patches, data.doc_mask,
                              data.doc_salience, cfg)
    s_old, i_old = _legacy_query(legacy_ix, data.query_patches,
                                 data.query_mask, data.query_salience,
                                 cfg, k=5)

    np.testing.assert_array_equal(np.asarray(i_new), np.asarray(i_old))
    np.testing.assert_allclose(np.asarray(s_new), np.asarray(s_old),
                               rtol=0, atol=0)
    assert r.storage_bytes(state) == _legacy_storage(legacy_ix, cfg)


def test_pipeline_wrappers_match_retriever(data):
    """The v0 entry points in core/pipeline.py are exact wrappers."""
    from repro.core import pipeline as pipe
    cfg = CONFIGS["flat"]
    key = jax.random.PRNGKey(3)
    ix = pipe.build_index(key, data.doc_patches, data.doc_mask,
                          data.doc_salience, cfg)
    s_w, i_w = pipe.query(ix, data.query_patches, data.query_mask,
                          data.query_salience, cfg, k=5)
    r = Retriever(cfg)
    state = r.build(key, _corpus(data))
    s_r, i_r = r.search(state, _queries(data), k=5)
    np.testing.assert_array_equal(np.asarray(i_w), np.asarray(i_r))
    np.testing.assert_array_equal(np.asarray(s_w), np.asarray(s_r))
    assert pipe.storage_bytes(ix, cfg) == r.storage_bytes(state)
    # v0 compat accessors on the tagged state
    assert ix.flat is not None
    assert ix.ivf is None and ix.hamming is None and ix.float_flat is None


# ---------------------------------------------------------------------------
# save / load
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(CONFIGS))
def test_save_load_roundtrip(data, name, tmp_path):
    cfg = CONFIGS[name]
    key = jax.random.PRNGKey(11)
    r = Retriever(cfg)
    state = r.build(key, _corpus(data))
    path = r.save(str(tmp_path / f"{name}_idx"), state)

    restored = r.load(path)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    s0, i0 = r.search(state, _queries(data), k=5)
    s1, i1 = r.search(restored, _queries(data), k=5)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


def test_load_rejects_wrong_backend(data, tmp_path):
    r = Retriever(CONFIGS["flat"])
    state = r.build(jax.random.PRNGKey(0), _corpus(data))
    path = r.save(str(tmp_path / "idx"), state)
    with pytest.raises(ValueError, match="saved by backend"):
        Retriever(CONFIGS["hamming"]).load(path)


# ---------------------------------------------------------------------------
# sharding
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(CONFIGS))
def test_shard_places_state_and_preserves_results(data, name):
    cfg = CONFIGS[name]
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    r = Retriever(cfg)
    state = r.build(jax.random.PRNGKey(5), _corpus(data))
    s0, i0 = r.search(state, _queries(data), k=5)

    sharded = r.shard(state, mesh)
    # every leaf got a mesh placement
    for leaf in jax.tree.leaves(sharded):
        assert leaf.sharding.mesh.shape == mesh.shape
    s1, i1 = r.search(sharded, _queries(data), k=5)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), atol=1e-5)


@pytest.mark.parametrize("name", ["flat", "ivf", "hamming"])
def test_build_on_1dev_mesh_matches_single_host(data, name):
    """Acceptance: a 1-device-mesh sharded build (codebook through the
    distributed k-means, quantization shard-mapped) must reproduce the
    single-host codebook within tolerance and the same search answers."""
    cfg = CONFIGS[name]
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    r = Retriever(cfg)
    st_mesh = r.build(jax.random.PRNGKey(5), _corpus(data), mesh=mesh)
    st_local = r.build(jax.random.PRNGKey(5), _corpus(data))
    np.testing.assert_allclose(np.asarray(st_mesh.codebook),
                               np.asarray(st_local.codebook), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(st_mesh.rerank_codes),
                                  np.asarray(st_local.rerank_codes))
    s_m, i_m = r.search(st_mesh, _queries(data), k=5)
    s_l, i_l = r.search(st_local, _queries(data), k=5)
    np.testing.assert_allclose(np.asarray(s_m), np.asarray(s_l), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i_m), np.asarray(i_l))


def test_shard_specs_corpus_axis(data):
    """The primary structure shards over the corpus logical axis."""
    r = Retriever(CONFIGS["flat"])
    state = r.build(jax.random.PRNGKey(5), _corpus(data))
    specs = r.backend.shard_specs(state)
    assert specs.backend_state.codes == ("corpus", None)
    assert specs.backend_state.codebook == (None, None)
    assert specs.rerank_codes == ("corpus", None)
    assert specs.codebook == (None, None)


# ---------------------------------------------------------------------------
# HPCConfig deprecation shim
# ---------------------------------------------------------------------------

def test_config_mode_index_derive_backend(monkeypatch):
    from repro.retrieval import config as config_mod

    # the deprecation warns once per process; reset the flag per assert
    monkeypatch.setattr(config_mod, "_mode_index_warned", False)
    with pytest.warns(DeprecationWarning, match="removed in v2.0"):
        cfg = HPCConfig(mode="binary")
    assert cfg.backend == "hamming"
    monkeypatch.setattr(config_mod, "_mode_index_warned", False)
    with pytest.warns(DeprecationWarning):
        cfg = HPCConfig(mode="quantized", index="ivf")
    assert cfg.backend == "ivf"
    monkeypatch.setattr(config_mod, "_mode_index_warned", False)
    with pytest.warns(DeprecationWarning):
        cfg = HPCConfig(mode="float")
    assert cfg.backend == "float_flat"


def test_config_mode_index_warns_once_per_process(monkeypatch, recwarn):
    from repro.retrieval import config as config_mod

    monkeypatch.setattr(config_mod, "_mode_index_warned", False)
    with pytest.warns(DeprecationWarning):
        HPCConfig(mode="binary")
    recwarn.clear()
    HPCConfig(mode="binary")               # second construction: silent
    assert not [w for w in recwarn
                if issubclass(w.category, DeprecationWarning)]


def test_config_backend_wins_and_populates_aliases():
    cfg = HPCConfig(backend="ivf")
    assert (cfg.mode, cfg.index) == ("quantized", "ivf")
    cfg = HPCConfig(backend="hamming")
    assert cfg.mode == "binary"
    # defaults stay quantized/flat with no warning
    cfg = HPCConfig()
    assert cfg.backend == "flat"
    assert (cfg.mode, cfg.index) == ("quantized", "flat")


def test_config_replace_keeps_backend():
    cfg = HPCConfig(backend="flat", rerank=8)
    cfg2 = dataclasses.replace(cfg, rerank=16)
    assert cfg2.backend == "flat" and cfg2.rerank == 16
