"""Async serving v2: padding ladder, concurrent submitters, drain-on-close.

The ladder property test runs under hypothesis when installed and under
the deterministic shim (tests/_hypothesis_compat) otherwise.
"""
import asyncio
import time

import jax
import numpy as np
import pytest

from repro.data import synthetic
from repro.retrieval import Corpus, HPCConfig, Query, Retriever
from repro.serving.server import (AsyncRetrievalServer, RetrievalServer,
                                  ServeConfig, ServerClosed, padding_ladder)
from tests._hypothesis_compat import given, settings, st

LADDER = (1, 2, 4, 8)
N_QUERIES = 8
_CACHE = {}


def _index():
    """Small flat-backend index + jitted search, built once per session."""
    if "search" not in _CACHE:
        key = jax.random.PRNGKey(0)
        spec = synthetic.CorpusSpec(n_docs=128, n_queries=N_QUERIES,
                                    n_patches=8, n_q_patches=4, dim=16,
                                    n_topics=4)
        data = synthetic.make_retrieval_corpus(key, spec)
        cfg = HPCConfig(k=64, p=60.0, backend="flat", prune_side="doc",
                        rerank=16, kmeans_iters=5)
        retriever = Retriever(cfg)
        state = retriever.build(key, Corpus(data.doc_patches, data.doc_mask,
                                            data.doc_salience))

        @jax.jit
        def search(q, qm, qs):
            return retriever.search(state, Query(q, qm, qs), k=5)

        _CACHE["search"], _CACHE["data"] = search, data
    return _CACHE["search"], _CACHE["data"]


def _search_at_rung(qi: int, rung: int, fill_real: bool):
    """Run query qi padded to `rung` rows; returns its (scores, ids) row.

    fill_real=True packs other live queries behind it (a coalesced batch);
    False zero-pads (a straggler) — results must not depend on either.
    """
    search, data = _index()
    q = np.zeros((rung,) + data.query_patches[qi].shape,
                 np.asarray(data.query_patches).dtype)
    qm = np.zeros((rung,) + data.query_mask[qi].shape, bool)
    qs = np.zeros((rung,) + data.query_salience[qi].shape,
                  np.asarray(data.query_salience).dtype)
    q[0] = data.query_patches[qi]
    qm[0] = data.query_mask[qi]
    qs[0] = data.query_salience[qi]
    if fill_real:
        for j in range(1, rung):
            k2 = (qi + j) % N_QUERIES
            q[j] = data.query_patches[k2]
            qm[j] = data.query_mask[k2]
            qs[j] = data.query_salience[k2]
    s, i = search(q, qm, qs)
    return np.asarray(s[0]), np.asarray(i[0])


def _fake_search(q, qm, qs):
    b = q.shape[0]
    return (np.zeros((b, 5), np.float32),
            np.tile(np.arange(5, dtype=np.int64), (b, 1)))


def test_padding_ladder_and_rung_selection():
    assert padding_ladder(1) == (1,)
    assert padding_ladder(8) == (1, 2, 4, 8)
    assert padding_ladder(6) == (1, 2, 4, 6)
    srv = AsyncRetrievalServer(_fake_search, ServeConfig(max_batch=8))
    assert [srv.rung_for(n) for n in (1, 2, 3, 4, 5, 8)] == [1, 2, 4, 4, 8, 8]
    with pytest.raises(ValueError):
        ServeConfig(max_batch=8, ladder=(2, 4)).resolved_ladder()
    with pytest.raises(ValueError):
        padding_ladder(0)


@settings(deadline=None, max_examples=16)
@given(qi=st.integers(min_value=0, max_value=N_QUERIES - 1),
       rung_idx=st.integers(min_value=0, max_value=len(LADDER) - 1),
       fill_real=st.booleans())
def test_ladder_rungs_bitwise_identical(qi, rung_idx, fill_real):
    """A query's scores/ids are bitwise-identical at every ladder rung —
    which compiled shape served it, and what padded the remaining rows,
    must be unobservable."""
    ref_s, ref_i = _search_at_rung(qi, 1, fill_real=False)
    s, i = _search_at_rung(qi, LADDER[rung_idx], fill_real)
    np.testing.assert_array_equal(s, ref_s)
    np.testing.assert_array_equal(i, ref_i)


def test_async_server_matches_direct_search():
    search, data = _index()
    ref_s, ref_i = search(data.query_patches, data.query_mask,
                          data.query_salience)

    async def go():
        srv = AsyncRetrievalServer(
            search, ServeConfig(max_batch=4, max_wait_ms=5.0))
        srv.warm_shapes(data.query_patches[0], data.query_mask[0],
                        data.query_salience[0])
        outs = await asyncio.gather(*[
            srv.query(data.query_patches[i], data.query_mask[i],
                      data.query_salience[i]) for i in range(N_QUERIES)])
        st = srv.stats()
        await srv.aclose()
        return outs, st

    outs, st = asyncio.run(go())
    for i, (s, ids) in enumerate(outs):
        np.testing.assert_array_equal(s, np.asarray(ref_s[i]))
        np.testing.assert_array_equal(ids, np.asarray(ref_i[i]))
    assert st["n"] == N_QUERIES
    # every served batch landed on a rung of the max_batch=4 ladder
    assert set(st["rungs"]) <= {1, 2, 4}


def test_stats_survive_concurrent_async_submitters():
    async def go():
        srv = AsyncRetrievalServer(
            _fake_search, ServeConfig(max_batch=8, max_wait_ms=1.0))

        async def client(n):
            for _ in range(n):
                await srv.query(np.zeros((4, 16), np.float32),
                                np.ones(4, bool), np.zeros(4, np.float32))

        await asyncio.gather(*[client(8) for _ in range(4)])
        st = srv.stats()
        await srv.aclose()
        return st

    st = asyncio.run(go())
    assert st["n"] == 32
    assert st["qps"] > 0.0
    assert 0.0 <= st["p50_ms"] <= st["p99_ms"]
    assert st["mean_batch"] >= 1.0
    # rung accounting is consistent: occupancies in (0, 1] and the
    # per-rung occupied slots sum back to the request count
    total_reqs = sum(round(v["occupancy"] * b * v["batches"])
                     for b, v in st["rungs"].items())
    assert total_reqs == 32
    for b, v in st["rungs"].items():
        assert 0.0 < v["occupancy"] <= 1.0
        assert b in padding_ladder(8)


def test_close_drains_queued_requests_with_terminal_error():
    def slow_search(q, qm, qs):
        time.sleep(0.1)
        return (np.zeros((q.shape[0], 5)),
                np.zeros((q.shape[0], 5), np.int64))

    server = RetrievalServer(slow_search,
                             ServeConfig(max_batch=1, max_wait_ms=0.5))
    reqs = [server.submit(np.zeros((4, 8)), np.ones(4, bool),
                          np.zeros(4)) for _ in range(6)]
    time.sleep(0.05)                    # first batch is inside search_fn
    t0 = time.perf_counter()
    server.close()
    took = time.perf_counter() - t0
    assert took < 10.0                  # not the 30 s client timeout
    served = errored = 0
    for r in reqs:
        assert r.event.wait(5.0)        # every waiter is released
        if r.error is not None:
            assert isinstance(r.error, ServerClosed)
            errored += 1
        else:
            assert r.result is not None
            served += 1
    assert served + errored == 6
    assert errored >= 1                 # queued tail got the terminal error
    assert served >= 1                  # in-flight batch still delivered
    # submit after close fails fast with the terminal error, no timeout
    r = server.submit(np.zeros((4, 8)), np.ones(4, bool), np.zeros(4))
    assert r.event.wait(1.0) and isinstance(r.error, ServerClosed)
    server.close()                      # idempotent


def test_staging_error_fails_batch_but_not_server():
    """Two coalesced queries with mismatched Mq can't be stacked: that
    batch must error out, but the dispatcher survives and later
    well-formed queries (and aclose) still work."""
    async def go():
        srv = AsyncRetrievalServer(
            _fake_search, ServeConfig(max_batch=4, max_wait_ms=50.0))
        bad = await asyncio.gather(
            srv.query(np.zeros((4, 16), np.float32), np.ones(4, bool),
                      np.zeros(4, np.float32)),
            srv.query(np.zeros((8, 16), np.float32), np.ones(8, bool),
                      np.zeros(8, np.float32)),
            return_exceptions=True)
        assert any(isinstance(r, Exception) for r in bad)
        s, ids = await srv.query(np.zeros((4, 16), np.float32),
                                 np.ones(4, bool), np.zeros(4, np.float32))
        assert s.shape == (5,) and ids.shape == (5,)
        await srv.aclose()

    asyncio.run(go())


def test_async_query_after_aclose_raises():
    async def go():
        srv = AsyncRetrievalServer(_fake_search, ServeConfig(max_batch=2))
        await srv.query(np.zeros((4, 16), np.float32), np.ones(4, bool),
                        np.zeros(4, np.float32))
        await srv.aclose()
        with pytest.raises(ServerClosed):
            await srv.query(np.zeros((4, 16), np.float32),
                            np.ones(4, bool), np.zeros(4, np.float32))

    asyncio.run(go())


def test_warm_shapes_precompiles_every_rung():
    srv = AsyncRetrievalServer(_fake_search, ServeConfig(max_batch=8))
    srv.warm_shapes(np.zeros((4, 16), np.float32), np.ones(4, bool),
                    np.zeros(4, np.float32))
    assert {(b, 4) for b in (1, 2, 4, 8)} <= srv.compiled_shapes


def test_single_shape_config_reproduces_v1_padding():
    """ladder=(max_batch,) pads every batch to the single compiled shape."""
    server = RetrievalServer(
        _fake_search,
        ServeConfig(max_batch=8, max_wait_ms=2.0, ladder=(8,)))
    reqs = [server.submit(np.zeros((4, 16), np.float32), np.ones(4, bool),
                          np.zeros(4, np.float32)) for _ in range(3)]
    for r in reqs:
        assert r.event.wait(10.0) and r.error is None
    st = server.stats()
    assert list(st["rungs"]) == [8]     # stragglers still pay B=8
    server.close()
