"""Multi-device distribution tests (subprocess with virtual CPU devices —
the main process keeps its single real device, per the assignment)."""
import pytest

from tests.conftest import run_subprocess


@pytest.mark.slow
def test_sharded_search_matches_unsharded():
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import distributed as D, late_interaction as li, quantization as quant

mesh = jax.make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
ks = jax.random.split(key, 4)
N, Md, Mq, B, dim, K = 64, 6, 4, 3, 16, 16
docs = jax.random.normal(ks[0], (N, Md, dim))
cb, _ = quant.kmeans_fit(ks[1], docs.reshape(-1, dim), quant.KMeansConfig(k=K, iters=5))
codes = quant.quantize(docs, cb).astype(jnp.int32)
mask = jnp.ones((N, Md), jnp.float32)
ids = jnp.arange(N, dtype=jnp.int32)
q = jax.random.normal(ks[2], (B, Mq, dim))
qm = jnp.ones((B, Mq), jnp.float32)

search = D.sharded_search_fn(mesh, ("data", "model"), k=8)
s_sh, i_sh = search(q, qm, codes, mask, ids, cb)

ref = li.quantized_maxsim(q, qm, codes, mask, cb)
top_s, top_i = jax.lax.top_k(ref, 8)
np.testing.assert_allclose(np.asarray(s_sh), np.asarray(top_s), atol=1e-4)
# ids may differ on exact ties (duplicate-code docs); every returned id's
# true score must equal the reported score.
true = np.take_along_axis(np.asarray(ref), np.asarray(i_sh), axis=1)
np.testing.assert_allclose(true, np.asarray(s_sh), atol=1e-4)
print("SHARDED_SEARCH_OK")
""")
    assert "SHARDED_SEARCH_OK" in out


@pytest.mark.slow
def test_sharded_kmeans_matches_local():
    """The full v2 trainer (k-means++ seeds, Lloyd + empty-cluster repair,
    best-iterate, restarts) sharded over 8 devices must match the
    single-host `kmeans_fit` within psum tolerance."""
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import distributed as D, quantization as quant

mesh = jax.make_mesh((8,), ("data",))
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (512, 8))
cfg = quant.KMeansConfig(k=16, iters=10, n_restarts=2)
c_sh, hist_sh = D.sharded_kmeans_fit(mesh, key, x, cfg)
c_ref, hist_ref = quant.kmeans_fit(key, x, cfg)
np.testing.assert_allclose(np.asarray(c_sh), np.asarray(c_ref), atol=1e-4)
np.testing.assert_allclose(np.asarray(hist_sh), np.asarray(hist_ref), atol=1e-4)
codes_sh = D.sharded_quantize(mesh, x.reshape(64, 8, 8), c_ref, jnp.uint8)
codes_ref = quant.quantize(x.reshape(64, 8, 8), c_ref)
assert bool(jnp.all(codes_sh == codes_ref))
print("SHARDED_KMEANS_OK")
""")
    assert "SHARDED_KMEANS_OK" in out


@pytest.mark.slow
def test_sharded_retriever_build_matches_local():
    """Retriever.build(mesh=...) across 8 devices: codebook within psum
    tolerance of the single-host build, identical search answers."""
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.data import synthetic
from repro.retrieval import Corpus, HPCConfig, Query, Retriever

mesh = jax.make_mesh((2, 4), ("data", "model"))
spec = synthetic.CorpusSpec(n_docs=128, n_queries=8, n_patches=8,
                            n_q_patches=4, dim=16, n_topics=4)
data = synthetic.make_retrieval_corpus(jax.random.PRNGKey(1), spec)
cfg = HPCConfig(k=32, p=60.0, backend="flat", prune_side="doc",
                kmeans_iters=8, rerank=12)
r = Retriever(cfg)
corpus = Corpus(data.doc_patches, data.doc_mask, data.doc_salience)
st_mesh = r.build(jax.random.PRNGKey(2), corpus, mesh=mesh)
st_local = r.build(jax.random.PRNGKey(2), corpus)
np.testing.assert_allclose(np.asarray(st_mesh.codebook),
                           np.asarray(st_local.codebook), atol=1e-4)
q = Query(data.query_patches, data.query_mask, data.query_salience)
s_m, i_m = r.search(st_mesh, q, k=5)
s_l, i_l = r.search(st_local, q, k=5)
np.testing.assert_allclose(np.asarray(s_m), np.asarray(s_l), atol=1e-4)
np.testing.assert_array_equal(np.asarray(i_m), np.asarray(i_l))
print("SHARDED_BUILD_OK")
""")
    assert "SHARDED_BUILD_OK" in out


@pytest.mark.slow
def test_ring_allgather_matmul():
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.dist.collectives import ring_allgather_matmul

mesh = jax.make_mesh((4,), ("model",))
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (16, 8))
w = jax.random.normal(jax.random.PRNGKey(1), (8, 12))
f = ring_allgather_matmul(mesh, "model")
y = f(x, w)
np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), atol=1e-4)
print("RING_OK")
""")
    assert "RING_OK" in out


@pytest.mark.slow
def test_pipeline_parallel_matches_sequential():
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.train.loop import make_pipelined_fn

mesh = jax.make_mesh((4,), ("pipe",))
key = jax.random.PRNGKey(0)
n_stages, mb, n_micro, d = 4, 4, 8, 16
ws = jax.random.normal(key, (n_stages, d, d)) / jnp.sqrt(d)

def stage_fn(sp, x):
    return jnp.tanh(x @ sp["w"])

x = jax.random.normal(jax.random.PRNGKey(1), (n_micro * mb, d))
piped = make_pipelined_fn(mesh, stage_fn, n_microbatches=n_micro)
y = piped({"w": ws}, x)

ref = x
for i in range(n_stages):
    ref = jnp.tanh(ref @ ws[i])
np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)
print("PIPE_OK")
""")
    assert "PIPE_OK" in out


@pytest.mark.slow
def test_elastic_restore_across_meshes(tmp_path):
    out = run_subprocess(f"""
import jax, jax.numpy as jnp, numpy as np
from repro.ckpt import checkpoint as ck
from repro.train import elastic
from repro.dist.sharding import Sharder

tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
specs = {{"w": ("batch", "mlp")}}
ck.save("{tmp_path}", 3, tree)

mesh = jax.make_mesh((2, 4), ("data", "model"))
got = elastic.restore_elastic("{tmp_path}", jax.tree.map(jnp.zeros_like, tree), specs, mesh)
step, restored = got
assert step == 3
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
shard_shape = restored["w"].sharding.shard_shape(restored["w"].shape)
assert shard_shape == (4, 2), shard_shape
print("ELASTIC_OK")
""")
    assert "ELASTIC_OK" in out


@pytest.mark.slow
def test_dryrun_smoke_cells_compile_on_small_mesh():
    """The dry-run machinery itself (build_cell + lower + compile) on a
    small virtual mesh with smoke configs — one cell per family."""
    out = run_subprocess("""
import jax
from jax.sharding import Mesh
from repro.configs import registry
from repro.launch import cells as cm
import numpy as np

mesh = jax.make_mesh((2, 4), ("data", "model"))
for arch, shape in [("qwen2-1.5b", "train_4k"), ("llama4-scout-17b-a16e", "decode_32k"),
                    ("pna", "molecule"), ("dlrm-mlperf", "serve_p99"),
                    ("dien", "retrieval_cand"), ("colpali-hpc", "serve_query")]:
    spec = registry.get(arch)
    cell = [c for c in spec.shapes if c.name == shape][0]
    with mesh:
        built = cm.build_cell(spec, cell, mesh, smoke=True)
        if built.in_shardings is None:
            jitted = built.fn
        else:
            jitted = jax.jit(built.fn, in_shardings=built.in_shardings,
                             out_shardings=built.out_shardings,
                             donate_argnums=built.donate_argnums)
        compiled = jitted.lower(*built.args).compile()
        assert compiled.memory_analysis() is not None
    print("OK", arch, shape)
print("DRYRUN_SMOKE_OK")
""", n_devices=8, timeout=900)
    assert "DRYRUN_SMOKE_OK" in out


@pytest.mark.slow
def test_grouped_moe_ep_matches_unsharded():
    """moe-2 (EXPERIMENTS.md §Perf): the grouped expert-parallel dispatch
    must be numerically exact under a real sharded mesh (g=4) vs the
    unsharded reference (g=1), in the no-drop capacity regime."""
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models import layers as L
from repro.dist.sharding import Sharder, NULL

mesh = jax.make_mesh((4, 2), ("data", "model"))
sharder = Sharder(mesh)
key = jax.random.PRNGKey(0)
T, D, E, K, F = 64, 16, 8, 2, 24
p = L.moe_init(key, D, F, E, 0, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (T, D))

ref, aux_ref = L.moe_apply(p, x, top_k=K, capacity_factor=16.0, shd=NULL)

with mesh:
    f = jax.jit(lambda pp, xx: L.moe_apply(pp, xx, top_k=K,
                                           capacity_factor=16.0,
                                           shd=sharder),
                in_shardings=(None, NamedSharding(mesh, P("data", None))))
    got, aux = f(p, x)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5,
                           rtol=2e-5)
np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-5)
# gradients agree too (the a2a constraints must be transparent to AD)
g_ref = jax.grad(lambda pp: jnp.sum(L.moe_apply(pp, x, top_k=K,
                 capacity_factor=16.0, shd=NULL)[0] ** 2))(p)
with mesh:
    g_sh = jax.jit(jax.grad(lambda pp: jnp.sum(L.moe_apply(pp, x, top_k=K,
                   capacity_factor=16.0, shd=sharder)[0] ** 2)))(p)
for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_sh)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5,
                               rtol=5e-5)
print("GROUPED_MOE_EP_OK")
""")
    assert "GROUPED_MOE_EP_OK" in out
