"""Cascade backend + composable search-stage API tests.

Covers the PR 7 contract: `search_candidates` restricted scoring on the
stage-capable backends (flat / float_flat / hamming) against restricted
brute-force oracles, the staged funnel's equivalence to `float_flat`
at full budgets, the -1 sentinel at stage boundaries (k > P, p2 > p1),
budget monotonicity, nested-state persistence (tuple aux, no pickle),
1-device-mesh sharding, per-query scan layouts across block sizes, and
the ivf/hnsw declines.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import index as index_mod
from repro.core import late_interaction as li
from repro.core import scan as scan_mod
from repro.data import synthetic
from repro.retrieval import (CascadeConfig, Corpus, HPCConfig, Query,
                             Retriever, get_backend)
from repro.retrieval.cascade import STAGES, CascadeState


@pytest.fixture(scope="module")
def data():
    key = jax.random.PRNGKey(0)
    spec = synthetic.CorpusSpec(n_docs=96, n_queries=12, n_patches=10,
                                n_q_patches=4, dim=24, n_topics=6,
                                dup_per_doc=2)
    return synthetic.make_retrieval_corpus(key, spec)


@pytest.fixture(scope="module")
def nodup_data():
    """Duplicate-free corpus: no exact float-score ties, so top-k id
    comparisons are order-stable across candidate permutations."""
    key = jax.random.PRNGKey(3)
    spec = synthetic.CorpusSpec(n_docs=96, n_queries=12, n_patches=10,
                                n_q_patches=4, dim=24, n_topics=6,
                                dup_per_doc=0)
    return synthetic.make_retrieval_corpus(key, spec)


def _corpus(d):
    return Corpus(d.doc_patches, d.doc_mask, d.doc_salience)


def _queries(d):
    return Query(d.query_patches, d.query_mask, d.query_salience)


def _cfg(backend, **kw):
    kw.setdefault("k", 32)
    kw.setdefault("kmeans_iters", 6)
    return HPCConfig(p=60.0, backend=backend, prune_side="doc", **kw)


def _random_pools(key, n_docs, b, p, frac_invalid=0.2):
    """(B, P) candidate pools: distinct positions + some -1 slots."""
    keys = jax.random.split(key, b)
    rows = [jax.random.permutation(kq, n_docs)[:p] for kq in keys]
    ids = jnp.stack(rows).astype(jnp.int32)
    drop = jax.random.uniform(key, (b, p)) < frac_invalid
    return jnp.where(drop, -1, ids)


# ---------------------------------------------------------------------------
# search_candidates vs restricted brute-force oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["flat", "hamming", "float_flat"])
def test_search_candidates_matches_restricted_oracle(data, backend):
    """Restricted search == full search with non-candidates masked out."""
    r = Retriever(_cfg(backend))
    state = r.build(jax.random.PRNGKey(1), _corpus(data))
    b_end = get_backend(backend)
    q = _queries(data)
    n = data.doc_patches.shape[0]
    b = q.embeddings.shape[0]
    k = 8

    pools = _random_pools(jax.random.PRNGKey(2), n, b, 40)
    s_r, i_r = b_end.search_candidates(state, q, pools, k=k)
    s_r, i_r = np.asarray(s_r), np.asarray(i_r)

    # oracle: score every doc via full search, then restrict per pool.
    # Compare scores (tie-robust: duplicate docs / int hamming scores
    # can tie, making exact id order ambiguous) and id->score
    # consistency rather than raw id sequences.
    s_full, i_full = b_end.search(state, q, k=n)
    s_full, i_full = np.asarray(s_full), np.asarray(i_full)
    for qi in range(b):
        score = {int(i): float(s) for i, s in
                 zip(i_full[qi], s_full[qi])}
        pool = set(int(x) for x in np.asarray(pools[qi]) if x >= 0)
        want = sorted((score[p] for p in pool), reverse=True)[:k]
        got_valid = i_r[qi] >= 0
        assert int(got_valid.sum()) == min(k, len(pool))
        assert not got_valid[int(got_valid.sum()):].any()
        for rid, rs in zip(i_r[qi][got_valid], s_r[qi][got_valid]):
            assert int(rid) in pool
            np.testing.assert_allclose(float(rs), score[int(rid)],
                                       rtol=1e-5)
        np.testing.assert_allclose(s_r[qi][got_valid], want, rtol=1e-5)


@pytest.mark.parametrize("backend", ["flat", "hamming", "float_flat"])
def test_search_candidates_full_pool_equals_search(nodup_data, backend):
    """candidate_ids = the whole corpus -> identical to plain search."""
    r = Retriever(_cfg(backend))
    state = r.build(jax.random.PRNGKey(1), _corpus(nodup_data))
    b_end = get_backend(backend)
    q = _queries(nodup_data)
    n = nodup_data.doc_patches.shape[0]
    b = q.embeddings.shape[0]
    all_ids = jnp.tile(jnp.arange(n, dtype=jnp.int32)[None], (b, 1))

    s0, i0 = b_end.search(state, q, k=7)
    s1, i1 = b_end.search_candidates(state, q, all_ids, k=7)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), rtol=1e-6)


def test_search_candidates_none_falls_back_to_search(data):
    for name in ("flat", "hamming", "float_flat", "ivf", "hnsw"):
        cfg = _cfg(name)
        r = Retriever(cfg)
        state = r.build(jax.random.PRNGKey(1), _corpus(data))
        b_end = get_backend(name)
        s0, i0 = b_end.search(state, _queries(data), k=5)
        s1, i1 = b_end.search_candidates(state, _queries(data), None, k=5)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))


def test_routing_backends_decline_candidates(data):
    for name in ("ivf", "hnsw"):
        r = Retriever(_cfg(name))
        state = r.build(jax.random.PRNGKey(1), _corpus(data))
        pools = jnp.zeros((12, 4), jnp.int32)
        with pytest.raises(NotImplementedError, match=name):
            get_backend(name).search_candidates(state, _queries(data),
                                                pools, k=3)


def test_base_class_default_declines_candidates():
    from repro.retrieval.base import IndexBackend
    be = IndexBackend()
    with pytest.raises(NotImplementedError, match="search_candidates"):
        be.search_candidates(None, None, jnp.zeros((1, 1), jnp.int32), k=1)


# ---------------------------------------------------------------------------
# The cascade funnel
# ---------------------------------------------------------------------------

def test_cascade_full_budgets_match_float_flat(nodup_data):
    """p1 = p2 = N degenerates the funnel to the exact float scan."""
    n = nodup_data.doc_patches.shape[0]
    r_c = Retriever(_cfg("cascade", cascade=CascadeConfig(p1=n, p2=n)))
    r_f = Retriever(_cfg("float_flat"))
    key = jax.random.PRNGKey(4)
    st_c = r_c.build(key, _corpus(nodup_data))
    st_f = r_f.build(key, _corpus(nodup_data))
    s_c, i_c = r_c.search(st_c, _queries(nodup_data), k=10)
    s_f, i_f = r_f.search(st_f, _queries(nodup_data), k=10)
    np.testing.assert_array_equal(np.asarray(i_c), np.asarray(i_f))
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_f), rtol=1e-5)


def test_cascade_recall_against_flat_oracle(data):
    """At a 33%/12% funnel the cascade must track the exhaustive ADC
    scan (same codebook) on ground-truth recall — the smoke-gate
    criterion at test scale. p2 must exceed the recall depth (k=10),
    else the final stage caps recall structurally."""
    from benchmarks.common import retrieval_metrics

    key = jax.random.PRNGKey(5)
    r_flat = Retriever(_cfg("flat"))
    st_flat = r_flat.build(key, _corpus(data))
    _, i_flat = r_flat.search(st_flat, _queries(data), k=10)
    m_flat = retrieval_metrics(np.asarray(i_flat),
                               np.asarray(data.relevance), 10)

    r_c = Retriever(_cfg("cascade", cascade=CascadeConfig(p1=32, p2=12)))
    st_c = r_c.build(key, _corpus(data))
    _, i_c = r_c.search(st_c, _queries(data), k=10)
    m_c = retrieval_metrics(np.asarray(i_c), np.asarray(data.relevance), 10)
    assert m_c["recall@10"] >= 0.95 * m_flat["recall@10"]


def test_cascade_sentinel_padding_at_stage_boundaries(data):
    """k > p2 > p1: every stage hands -1 rows downstream untouched and
    the final tail is sentinel-padded, not fabricated."""
    r = Retriever(_cfg("cascade", cascade=CascadeConfig(p1=4, p2=16)))
    state = r.build(jax.random.PRNGKey(6), _corpus(data))
    k = 24                                    # k > p2 > p1
    scores, ids = r.search(state, _queries(data), k=k)
    scores, ids = np.asarray(scores), np.asarray(ids)
    assert ids.shape == (12, k)
    # only p1=4 candidates can survive stage 1 -> exactly 4 valid rows
    for qi in range(ids.shape[0]):
        valid = ids[qi] >= 0
        assert valid.sum() == 4
        assert not valid[4:].any()            # valid rows sort first
        # contract: NEG_INF-or-below (stages may emit -1e30 or -inf)
        assert np.all(scores[qi][~valid] <= -1e30)
        assert len(set(ids[qi][valid])) == valid.sum()   # no duplicates


def test_cascade_k_exceeds_corpus(data):
    n = data.doc_patches.shape[0]
    r = Retriever(_cfg("cascade", cascade=CascadeConfig(p1=n, p2=n)))
    state = r.build(jax.random.PRNGKey(6), _corpus(data))
    scores, ids = r.search(state, _queries(data), k=n + 8)
    ids = np.asarray(ids)
    assert ids.shape[1] == n + 8
    assert np.all(ids[:, n:] == -1)


def _oracle_recall(ids, oracle_ids):
    """Mean |returned ∩ oracle top-k| / k per query."""
    ids, oracle_ids = np.asarray(ids), np.asarray(oracle_ids)
    hits = [len(set(r[r >= 0]) & set(o)) / oracle_ids.shape[1]
            for r, o in zip(ids, oracle_ids)]
    return float(np.mean(hits))


def test_cascade_budget_monotonicity(nodup_data):
    """Wider budgets never lower recall against the exact-float oracle.

    The guarantee is set-theoretic: the pool reaching the float rerank
    is nested as a budget widens (hamming top-p1 at p2 >= p1; ADC
    top-p2 of a fixed hamming pool as p2 grows), and any float-oracle
    top-k member inside a pool always survives the float rerank. It
    holds only against the FLOAT oracle — ground-truth recall can
    legitimately dip when a wider p1 lets the noisier ADC middle stage
    displace candidates (quantization noise), which is why the smoke
    gate measures both sides against ground truth instead of assuming
    monotonicity there.
    """
    n = nodup_data.doc_patches.shape[0]
    key = jax.random.PRNGKey(7)
    r_oracle = Retriever(_cfg("float_flat"))
    st_o = r_oracle.build(key, _corpus(nodup_data))
    _, oracle_ids = r_oracle.search(st_o, _queries(nodup_data), k=10)

    # p1 ladder with the ADC stage wide open (p2 = n): pool = hamming
    # top-p1, nested in p1.
    r_p1 = []
    for p1 in (8, 24, 48, n):
        r = Retriever(_cfg("cascade", cascade=CascadeConfig(p1=p1, p2=n)))
        st = r.build(key, _corpus(nodup_data))
        _, ids = r.search(st, _queries(nodup_data), k=10)
        r_p1.append(_oracle_recall(ids, oracle_ids))
    assert all(b >= a for a, b in zip(r_p1, r_p1[1:])), r_p1
    assert r_p1[-1] == 1.0                      # full budget = exact scan

    # p2 ladder at fixed p1: pool = ADC top-p2 of one fixed hamming
    # pool, nested in p2.
    r_p2 = []
    for p2 in (4, 12, 24, 48):
        r = Retriever(_cfg("cascade", cascade=CascadeConfig(p1=48, p2=p2)))
        st = r.build(key, _corpus(nodup_data))
        _, ids = r.search(st, _queries(nodup_data), k=10)
        r_p2.append(_oracle_recall(ids, oracle_ids))
    assert all(b >= a for a, b in zip(r_p2, r_p2[1:])), r_p2


# ---------------------------------------------------------------------------
# Persistence, sharding, accounting
# ---------------------------------------------------------------------------

def test_cascade_save_load_roundtrip(data, tmp_path):
    cfg = _cfg("cascade", cascade=CascadeConfig(p1=24, p2=8))
    r = Retriever(cfg)
    state = r.build(jax.random.PRNGKey(8), _corpus(data))
    path = r.save(str(tmp_path / "casc_idx"), state)

    restored = r.load(path)
    assert isinstance(restored.backend_state, CascadeState)
    assert restored.backend_state.p1 == 24
    assert restored.backend_state.p2 == 8
    assert restored.backend_state.members[0].bits == cfg.bits
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    s0, i0 = r.search(state, _queries(data), k=5)
    s1, i1 = r.search(restored, _queries(data), k=5)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), rtol=1e-6)


def test_cascade_load_rejects_other_backend_file(data, tmp_path):
    r_flat = Retriever(_cfg("flat"))
    state = r_flat.build(jax.random.PRNGKey(8), _corpus(data))
    path = r_flat.save(str(tmp_path / "flat_idx"), state)
    with pytest.raises(ValueError, match="flat"):
        get_backend("cascade").load(path)


def test_cascade_shard_places_state_and_preserves_results(data):
    cfg = _cfg("cascade", cascade=CascadeConfig(p1=24, p2=8))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    r = Retriever(cfg)
    state = r.build(jax.random.PRNGKey(9), _corpus(data))
    s0, i0 = r.search(state, _queries(data), k=5)

    sharded = r.shard(state, mesh)
    for leaf in jax.tree.leaves(sharded):
        assert leaf.sharding.mesh.shape == mesh.shape
    s1, i1 = r.search(sharded, _queries(data), k=5)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), atol=1e-5)


def test_cascade_build_on_1dev_mesh_matches_single_host(data):
    cfg = _cfg("cascade", cascade=CascadeConfig(p1=24, p2=8))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    r = Retriever(cfg)
    st_mesh = r.build(jax.random.PRNGKey(9), _corpus(data), mesh=mesh)
    st_local = r.build(jax.random.PRNGKey(9), _corpus(data))
    s_m, i_m = r.search(st_mesh, _queries(data), k=5)
    s_l, i_l = r.search(st_local, _queries(data), k=5)
    np.testing.assert_array_equal(np.asarray(i_m), np.asarray(i_l))
    np.testing.assert_allclose(np.asarray(s_m), np.asarray(s_l), atol=1e-5)


def test_cascade_storage_and_stats_compose(data):
    r = Retriever(_cfg("cascade", cascade=CascadeConfig(p1=24, p2=8)))
    state = r.build(jax.random.PRNGKey(10), _corpus(data))
    sb = r.storage_bytes(state)
    assert set(f"stage_{s}" for s in STAGES) <= set(sb)
    assert sb["payload"] == sum(sb[f"stage_{s}"] for s in STAGES)
    stats = r.build_stats(state)
    assert stats["p1"] == 24.0 and stats["p2"] == 8.0


def test_cascade_manifest_registered():
    from repro.analysis.manifests import get_manifest
    m = get_manifest("search_cascade")
    fn, args = m.trace(1 << 12)
    scores, ids = jax.eval_shape(fn, *args)
    assert scores.dtype == jnp.float32 and ids.dtype == jnp.int32


# ---------------------------------------------------------------------------
# Per-query scan layouts (the engine primitives under the stage API)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block_docs", [3, 7, 64])
def test_maxsim_topk_per_query_matches_oracle(block_docs):
    key = jax.random.PRNGKey(11)
    kq, kd, km = jax.random.split(key, 3)
    b, p, mq, md, d, k = 3, 17, 4, 6, 8, 5
    q = jax.random.normal(kq, (b, mq, d))
    qm = jnp.ones((b, mq), bool)
    docs = jax.random.normal(kd, (b, p, md, d))
    dm = jax.random.uniform(km, (b, p, md)) > 0.2
    ids = jnp.tile(jnp.arange(p, dtype=jnp.int32)[None], (b, 1))
    valid = jnp.ones((b, p), bool)

    s, i = scan_mod.maxsim_topk(
        q, qm, docs, dm, k=k, doc_ids=ids, valid=valid,
        scan=scan_mod.ScanConfig(block_docs=block_docs, impl="jnp"))
    # oracle: unblocked per-query float maxsim
    want = jnp.stack([li.maxsim(q[j:j + 1], qm[j:j + 1], docs[j],
                                dm[j])[0] for j in range(b)])
    want_s, want_i = jax.lax.top_k(want, k)
    np.testing.assert_allclose(np.asarray(s), np.asarray(want_s), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(want_i))


@pytest.mark.parametrize("block_docs", [3, 7, 64])
def test_hamming_topk_per_query_matches_oracle(block_docs):
    key = jax.random.PRNGKey(12)
    kq, kd, km = jax.random.split(key, 3)
    b, p, mq, md, bits, k = 3, 17, 4, 6, 5, 5
    q_codes = jax.random.randint(kq, (b, mq), 0, 1 << bits, jnp.uint16)
    qm = jnp.ones((b, mq), bool)
    d_codes = jax.random.randint(kd, (b, p, md), 0, 1 << bits, jnp.uint16)
    dm = jax.random.uniform(km, (b, p, md)) > 0.2

    s, i = scan_mod.hamming_maxsim_topk(
        q_codes, qm, d_codes, dm, bits=bits, k=k,
        scan=scan_mod.ScanConfig(block_docs=block_docs, impl="jnp"))
    want = jnp.stack([li.binary_maxsim(q_codes[j:j + 1], qm[j:j + 1],
                                       d_codes[j], dm[j], bits)[0]
                      for j in range(b)])
    want_s, want_i = jax.lax.top_k(want, k)
    assert s.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(s), np.asarray(want_s))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(want_i))


@pytest.mark.parametrize("impl", ["jnp", "interpret"])
def test_per_query_layouts_impl_parity(impl):
    """The Pallas (interpreter) block scorer agrees with the jnp path on
    the new per-query float/hamming layouts."""
    key = jax.random.PRNGKey(13)
    kq, kd = jax.random.split(key)
    b, p, mq, md, d, k = 2, 9, 3, 4, 8, 4
    q = jax.random.normal(kq, (b, mq, d))
    qm = jnp.ones((b, mq), bool)
    docs = jax.random.normal(kd, (b, p, md, d))
    dm = jnp.ones((b, p, md), bool)
    s, i = scan_mod.maxsim_topk(
        q, qm, docs, dm, k=k,
        scan=scan_mod.ScanConfig(block_docs=4, impl=impl))
    s_ref, i_ref = scan_mod.maxsim_topk(
        q, qm, docs, dm, k=k,
        scan=scan_mod.ScanConfig(block_docs=4, impl="jnp"))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))

    q_codes = jax.random.randint(kq, (b, mq), 0, 32, jnp.uint16)
    d_codes = jax.random.randint(kd, (b, p, md), 0, 32, jnp.uint16)
    s, i = scan_mod.hamming_maxsim_topk(
        q_codes, qm, d_codes, dm, bits=5, k=k,
        scan=scan_mod.ScanConfig(block_docs=4, impl=impl))
    s_ref, i_ref = scan_mod.hamming_maxsim_topk(
        q_codes, qm, d_codes, dm, bits=5, k=k,
        scan=scan_mod.ScanConfig(block_docs=4, impl="jnp"))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s_ref))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))


def test_gather_candidates_sentinel_contract():
    """-1 pool slots gather row 0 safely but stay invalid/-1 in output."""
    ids = jnp.array([[2, -1, 0], [-1, -1, 1]], jnp.int32)
    doc_ids = jnp.array([10, 11, 12], jnp.int32)
    payload = jnp.arange(3 * 2).reshape(3, 2)
    out_ids, valid, (g,) = index_mod._gather_candidates(ids, doc_ids,
                                                        payload)
    np.testing.assert_array_equal(np.asarray(out_ids),
                                  [[12, -1, 10], [-1, -1, 11]])
    np.testing.assert_array_equal(np.asarray(valid),
                                  [[True, False, True],
                                   [False, False, True]])
    assert g.shape == (2, 3, 2)
