"""Segmented LSM corpus store: mutation API across every backend.

Contract under test (docs/design.md §9):

  * `Retriever.add` / `delete` / `compact` work on all six backends
    without a rebuild; a grown-and-pruned index answers with recall@10
    within 1% of a from-scratch rebuild of the same live corpus.
  * Deleted doc ids never surface; when k exceeds the live-doc count the
    tail is padded with ``-1`` sentinels.
  * Delete-then-add of the same doc_id resolves to the newest segment.
  * Search after `compact` is bit-consistent with search before it.
    For ivf "bit-consistent" means score-bit-consistent: compaction
    re-buckets through the (unchanged) routing centroids, which changes
    scan order, so `lax.top_k`'s position-based tie-breaking may permute
    ids *within an equal-score tie group* — scores stay bit-identical.
    All other backends preserve scan order under compaction
    (`gather_live_rows` keeps slot order) and are bit-exact on both
    scores and ids.
  * Segmented states round-trip through `save`/`load` (format v2);
    future-versioned files and non-index files fail with clear errors.
  * Accounting: `build_stats` reports live vs tombstoned docs,
    `storage_bytes` reports live-only per-segment payload.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.graph import HNSWConfig
from repro.core.index import IVFConfig
from repro.data import synthetic
from repro.retrieval import Corpus, HPCConfig, Query, Retriever

BACKENDS = ["flat", "float_flat", "hamming", "ivf", "hnsw", "cascade"]

# ivf compaction re-buckets (scan order changes), so equal-score ties may
# permute; every other backend folds segments in scan order and is
# bit-exact on ids too.
BITEXACT_IDS = {"flat", "float_flat", "hamming", "hnsw", "cascade"}

SPEC = synthetic.CorpusSpec(n_docs=240, n_queries=32, n_patches=8,
                            n_q_patches=4, dim=32, n_topics=6,
                            patches_per_topic=8, noise=0.1)
N_BASE, N_D1, N_TOTAL = 180, 220, 240
DEAD = [3, 10, 181, 200, 224]
UPSERT_ID, UPSERT_SRC = 5, 220   # doc 5 := doc 220's content


def _cfg(backend):
    return HPCConfig(k=64, p=80.0, backend=backend, kmeans_iters=10,
                     kmeans_restarts=2,
                     ivf=IVFConfig(n_list=8, n_probe=6, bucket_cap=64),
                     hnsw=HNSWConfig(m=6, ef_construction=32, ef_search=64,
                                     levels=3),
                     rerank=32)


def _recall_vs(ids, gt, k=10):
    hits, tot = 0, 0
    for a, b in zip(np.asarray(ids)[:, :k], gt):
        hits += len(set(int(x) for x in a if x >= 0) & set(b[:k].tolist()))
        tot += k
    return hits / tot


def _gt_topk(q_emb, q_mask, d_emb, d_mask, ids, k=10):
    out = []
    for b in range(q_emb.shape[0]):
        sims = np.einsum("md,npd->mnp", q_emb[b], d_emb)
        sims = np.where(d_mask[None, :, :], sims, -np.inf)
        score = (sims.max(-1) * q_mask[b][:, None]).sum(0)
        out.append(ids[np.argsort(-score)[:k]])
    return out


@pytest.fixture(scope="module")
def data():
    return synthetic.make_retrieval_corpus(jax.random.PRNGKey(7), SPEC)


def _slice(data, lo, hi):
    return Corpus(jnp.asarray(np.asarray(data.doc_patches)[lo:hi]),
                  jnp.asarray(np.asarray(data.doc_mask)[lo:hi]),
                  jnp.asarray(np.asarray(data.doc_salience)[lo:hi]))


@pytest.fixture(scope="module", params=BACKENDS)
def churned(request, data):
    """One full mutation lifecycle per backend, computed once per module.

    build(0..180) -> add(180..220) -> add(220..240) -> delete(DEAD)
    -> upsert(doc 5 := doc 220) ; plus a from-scratch rebuild of the same
    live corpus and the exact float-MaxSim ground truth over it.
    """
    backend = request.param
    query = Query(data.query_patches, data.query_mask, data.query_salience)
    r = Retriever(_cfg(backend))
    key = jax.random.PRNGKey(0)

    st = r.build(key, _slice(data, 0, N_BASE))
    st = r.add(st, _slice(data, N_BASE, N_D1))       # ids 180..219
    st = r.add(st, _slice(data, N_D1, N_TOTAL))      # ids 220..239
    st = r.delete(st, np.array(DEAD))
    st = r.add(st, _slice(data, UPSERT_SRC, UPSERT_SRC + 1),
               doc_ids=np.array([UPSERT_ID]))
    s_seg, i_seg = r.search(st, query, k=10)

    # live corpus with the upsert applied, for both rebuild and oracle
    emb = np.asarray(data.doc_patches).copy()
    msk = np.asarray(data.doc_mask).copy()
    sal = np.asarray(data.doc_salience).copy()
    emb[UPSERT_ID], msk[UPSERT_ID], sal[UPSERT_ID] = (
        emb[UPSERT_SRC], msk[UPSERT_SRC], sal[UPSERT_SRC])
    live_ids = np.array([i for i in range(N_TOTAL) if i not in DEAD])
    rb_state = r.build(key, Corpus(jnp.asarray(emb[live_ids]),
                                   jnp.asarray(msk[live_ids]),
                                   jnp.asarray(sal[live_ids])))
    _, i_rb = r.search(rb_state, query, k=10)
    i_rb = np.asarray(i_rb)
    i_rb_global = np.where(i_rb >= 0, live_ids[np.maximum(i_rb, 0)], -1)

    gt = _gt_topk(np.asarray(query.embeddings), np.asarray(query.mask),
                  emb[live_ids], msk[live_ids], live_ids)

    st_c = r.compact(st)
    s_c, i_c = r.search(st_c, query, k=10)

    return {"backend": backend, "retriever": r, "query": query,
            "state": st, "state_compact": st_c, "live_ids": live_ids,
            "scores": np.asarray(s_seg), "ids": np.asarray(i_seg),
            "scores_compact": np.asarray(s_c), "ids_compact": np.asarray(i_c),
            "ids_rebuild": i_rb_global, "gt": gt}


# ---------------------------------------------------------------------------
# Recall parity with a from-scratch rebuild (the tentpole acceptance bar)
# ---------------------------------------------------------------------------

def test_churn_recall_within_1pct_of_rebuild(churned):
    rec_seg = _recall_vs(churned["ids"], churned["gt"])
    rec_rb = _recall_vs(churned["ids_rebuild"], churned["gt"])
    assert rec_seg >= rec_rb - 0.01, (churned["backend"], rec_seg, rec_rb)


def test_compact_preserves_recall(churned):
    rec_seg = _recall_vs(churned["ids"], churned["gt"])
    rec_c = _recall_vs(churned["ids_compact"], churned["gt"])
    assert rec_c >= rec_seg - 0.01, (churned["backend"], rec_c, rec_seg)


def test_deleted_ids_never_surface(churned):
    surfaced = set(churned["ids"].ravel().tolist())
    surfaced |= set(churned["ids_compact"].ravel().tolist())
    assert not (surfaced & set(DEAD)), (churned["backend"],
                                        surfaced & set(DEAD))


# ---------------------------------------------------------------------------
# Compact bit-consistency (scores everywhere; ids except ivf tie groups)
# ---------------------------------------------------------------------------

def test_compact_bit_consistency(churned):
    backend = churned["backend"]
    s0, s1 = churned["scores"], churned["scores_compact"]
    i0, i1 = churned["ids"], churned["ids_compact"]
    assert np.array_equal(s0, s1), backend
    if backend in BITEXACT_IDS:
        assert np.array_equal(i0, i1), backend
    else:
        # ivf: ids may permute only inside an equal-score tie group —
        # every differing position's score must be tied (duplicated)
        # within its row
        for b, j in np.argwhere(i0 != i1):
            row = s0[b]
            assert np.sum(row == row[j]) >= 2, (backend, b, j, row)


# ---------------------------------------------------------------------------
# Mass deletion: k > live-doc-count pads -1 sentinels
# ---------------------------------------------------------------------------

def test_k_exceeding_live_docs_pads_sentinels(churned, data):
    r = Retriever(_cfg(churned["backend"]))
    st = r.build(jax.random.PRNGKey(0), _slice(data, 0, 5))
    st = r.delete(st, np.arange(3))
    _, ids = r.search(st, churned["query"], k=10)
    ids = np.asarray(ids)
    valid = ids[ids >= 0]
    assert set(valid.tolist()) <= {3, 4}, (churned["backend"], ids)
    assert (ids >= 0).sum(axis=1).max() <= 2, (churned["backend"], ids)


# ---------------------------------------------------------------------------
# Delete-then-add of the same doc_id resolves to the newest segment
# ---------------------------------------------------------------------------

def test_delete_then_add_newest_wins(churned):
    backend = churned["backend"]
    rng = np.random.default_rng(11)
    dim, n, m = 32, 10, 8

    def unit(shape):
        x = rng.standard_normal(shape).astype(np.float32)
        return x / np.linalg.norm(x, axis=-1, keepdims=True)

    # unit-norm patches: a doc's own patches are its best match (self
    # dot = 1, cross dots < 1 whp at dim 32)
    emb = unit((n, m, dim))
    new = unit((1, m, dim))
    mask = np.ones((n, m), bool)
    sal = np.ones((n, m), np.float32)

    r = Retriever(_cfg(backend))
    st = r.build(jax.random.PRNGKey(0),
                 Corpus(jnp.asarray(emb), jnp.asarray(mask),
                        jnp.asarray(sal)))
    st = r.delete(st, np.array([2]))
    st = r.add(st, Corpus(jnp.asarray(new), jnp.asarray(mask[:1]),
                          jnp.asarray(sal[:1])),
               doc_ids=np.array([2]))

    def top1(patches):
        q = Query(jnp.asarray(patches[None]), jnp.asarray(mask[:1]),
                  jnp.asarray(sal[:1]))
        _, ids = r.search(st, q, k=3)
        return int(np.asarray(ids)[0, 0])

    # querying with the new content's own patches must hit the re-added
    # doc; the old (tombstoned-then-replaced) content must not
    assert top1(new[0]) == 2, backend
    assert top1(emb[2]) != 2, backend


# ---------------------------------------------------------------------------
# Accounting: live/tombstone stats and live-only per-segment payload
# ---------------------------------------------------------------------------

def test_build_stats_live_and_tombstones(churned):
    r = churned["retriever"]
    stats = r.build_stats(churned["state"])
    n_live = len(churned["live_ids"])
    assert stats["live_docs"] == n_live, (churned["backend"], stats)
    assert stats["tombstoned_docs"] >= len(DEAD), (churned["backend"], stats)
    total = stats["live_docs"] + stats["tombstoned_docs"]
    assert stats["tombstone_frac"] == pytest.approx(
        stats["tombstoned_docs"] / total)
    # hnsw grows in place (one capacity-padded graph segment); everyone
    # else appends one immutable segment per add
    min_segments = 1 if churned["backend"] == "hnsw" else 2
    assert stats["segments"] >= min_segments

    stats_c = r.build_stats(churned["state_compact"])
    assert stats_c["live_docs"] == n_live
    assert stats_c["tombstoned_docs"] == 0
    assert stats_c["segments"] == 1


def test_storage_reports_per_segment_live_payload(churned, data):
    r = churned["retriever"]
    stor = r.storage_bytes(churned["state"])
    if churned["backend"] == "cascade":
        # cascade reports per-stage totals; each member accounts its own
        # segments internally
        assert any(k.startswith("stage_") for k in stor), stor
    else:
        seg_keys = [k for k in stor if k.startswith("segment_")]
        assert seg_keys, stor
        assert stor["payload"] == sum(stor[k] for k in seg_keys)

    # live-only accounting: deleting shrinks payload with no other change
    query = churned["query"]
    del query  # unused; keep fixture ordering explicit
    r2 = Retriever(_cfg(churned["backend"]))
    st = r2.build(jax.random.PRNGKey(0), _slice(data, 0, 64))
    st = r2.add(st, _slice(data, 64, 80))
    before = r2.storage_bytes(st)["payload"]
    st = r2.delete(st, np.arange(20))
    after = r2.storage_bytes(st)["payload"]
    assert after < before, (churned["backend"], before, after)


# ---------------------------------------------------------------------------
# Persistence: segmented round-trip + version gates
# ---------------------------------------------------------------------------

def test_save_load_roundtrip_segmented(churned, tmp_path):
    r = churned["retriever"]
    path = r.save(str(tmp_path / "seg_idx"), churned["state"])
    loaded = r.load(path)
    s2, i2 = r.search(loaded, churned["query"], k=10)
    assert np.array_equal(np.asarray(s2), churned["scores"])
    assert np.array_equal(np.asarray(i2), churned["ids"])


def test_load_rejects_future_format_version(churned, tmp_path):
    r = churned["retriever"]
    path = r.save(str(tmp_path / "seg_idx"), churned["state"])
    with np.load(path) as z:
        payload = {k: z[k] for k in z.files}
    payload["format_version"] = np.asarray(99, np.int64)
    np.savez(path, **payload)
    with pytest.raises(ValueError, match="format version 99"):
        r.load(path)


def test_load_rejects_non_index_file(tmp_path):
    path = str(tmp_path / "junk.npz")
    np.savez(path, stuff=np.zeros(3))
    with pytest.raises(ValueError, match="no 'backend' key"):
        Retriever(_cfg("flat")).load(path)


# ---------------------------------------------------------------------------
# Serving: interleaved mutations never mint a recompile off the ladder
# ---------------------------------------------------------------------------

def test_live_session_mutations_keep_ladder_rung_set(data):
    from repro.serving import LiveIndexSession, ServeConfig

    r = Retriever(HPCConfig(k=32, p=80.0, backend="flat", kmeans_iters=4,
                            kmeans_restarts=2, rerank=16))
    state = r.build(jax.random.PRNGKey(0), _slice(data, 0, 60))
    sess = LiveIndexSession(r, state,
                            ServeConfig(max_batch=4, top_k=5,
                                        guard_recompiles=True,
                                        max_wait_ms=1.0))
    qe = np.asarray(data.query_patches)
    qm = np.asarray(data.query_mask)
    qs = np.asarray(data.query_salience)
    try:
        sess.warm_shapes(qe[0], qm[0], qs[0])
        sess.server.reset_stats()
        for i in range(6):
            sess.query(qe[i], qm[i], qs[i])
            if i == 1:
                sess.add(_slice(data, 60, 70))          # ids 60..69
            if i == 2:
                sess.delete(np.array([0, 5, 63]))
            if i == 3:
                sess.add(_slice(data, 70, 71),
                         doc_ids=np.array([7]))         # upsert doc 7
            if i == 4:
                sess.compact()
        _, ids = sess.query(qe[6], qm[6], qs[6])
        assert not ({0, 5, 63} & set(int(x) for x in np.asarray(ids)))
        # the compiled rung set after adds/deletes/upsert/compact is
        # exactly a subset of the padding ladder — mutations swap index
        # state without minting a single off-ladder signature
        sentry = sess.server.recompile_sentry
        assert sentry.signatures, "sentry saw no traffic"
        for key in sentry.signatures:
            assert key[0] in sess.server.ladder, (key, sess.server.ladder)
        # the state-shape registry stays pow2-bucketed and bounded
        assert len(sess.state_signatures()) <= 6
    finally:
        sess.close()


def test_load_reads_v1_monolithic_file(data, tmp_path):
    # a v1 file is one saved before the format_version field existed:
    # monolithic state, no "format_version" / "segments" keys
    r = Retriever(_cfg("flat"))
    st = r.build(jax.random.PRNGKey(0), _slice(data, 0, 32))
    path = r.save(str(tmp_path / "v1_idx"), st)
    with np.load(path) as z:
        payload = {k: z[k] for k in z.files if k != "format_version"}
    np.savez(path, **payload)
    loaded = r.load(path)
    q = Query(data.query_patches, data.query_mask, data.query_salience)
    s0, i0 = r.search(st, q, k=5)
    s1, i1 = r.search(loaded, q, k=5)
    assert np.array_equal(np.asarray(s0), np.asarray(s1))
    assert np.array_equal(np.asarray(i0), np.asarray(i1))
