"""K-Means quantization unit + property tests (core/quantization.py)."""
import jax
import jax.numpy as jnp
import numpy as np

from tests._hypothesis_compat import given, settings, st

from repro.core import quantization as quant


def test_kmeans_reduces_mse(rng):
    x = jax.random.normal(rng, (512, 16))
    cfg = quant.KMeansConfig(k=32, iters=15)
    cents, mses = quant.kmeans_fit(rng, x, cfg)
    assert cents.shape == (32, 16)
    assert float(mses[-1]) <= float(mses[0])
    # codebook should beat a random codebook
    rand_cents = jax.random.normal(jax.random.PRNGKey(9), (32, 16))
    assert (quant.quantization_error(x, cents)
            < quant.quantization_error(x, rand_cents))


def test_kmeans_recovers_planted_clusters(rng):
    centers = jax.random.normal(rng, (8, 8)) * 5
    idx = jax.random.randint(jax.random.PRNGKey(1), (1024,), 0, 8)
    x = centers[idx] + 0.05 * jax.random.normal(jax.random.PRNGKey(2),
                                                (1024, 8))
    cents, _ = quant.kmeans_fit(rng, x, quant.KMeansConfig(k=8, iters=25))
    err = quant.quantization_error(x, cents)
    assert float(err) < 0.1  # ~noise floor (8 dims * 0.05^2 = 0.02)


def test_assign_is_nearest(rng):
    x = jax.random.normal(rng, (64, 4))
    c = jax.random.normal(jax.random.PRNGKey(3), (7, 4))
    codes = quant.assign(x, c)
    d = jnp.sum((x[:, None] - c[None]) ** 2, -1)
    np.testing.assert_array_equal(np.asarray(codes), np.argmin(d, -1))


def test_quantize_decode_shapes_and_dtype(rng):
    x = jax.random.normal(rng, (10, 6, 16))
    c = jax.random.normal(jax.random.PRNGKey(3), (256, 16))
    codes = quant.quantize(x, c)
    assert codes.shape == (10, 6) and codes.dtype == jnp.uint8
    dec = quant.decode(codes, c)
    assert dec.shape == x.shape


@settings(max_examples=20, deadline=None)
@given(k=st.sampled_from([128, 256, 512]))
def test_paper_bits_arithmetic(k):
    """b = ceil(log2 K): 7/8/9 bits for the paper's K values."""
    cfg = quant.KMeansConfig(k=k)
    assert cfg.bits == {128: 7, 256: 8, 512: 9}[k]
    assert cfg.code_dtype == (jnp.uint8 if k <= 256 else jnp.uint16)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(32, 128), d=st.sampled_from([4, 8]),
       k=st.sampled_from([4, 16]))
def test_property_decode_error_bounded_by_worst_pair(n, d, k):
    """Reconstruction error <= max pairwise distance (codebook covers x)."""
    key = jax.random.PRNGKey(n * d * k)
    x = jax.random.normal(key, (n, d))
    cents, _ = quant.kmeans_fit(key, x, quant.KMeansConfig(k=k, iters=5))
    codes = quant.assign(x, cents)
    err = jnp.sum((x - quant.decode(codes, cents)) ** 2, -1)
    # nearest-centroid property: err <= distance to ANY centroid
    d_all = jnp.sum((x[:, None] - cents[None]) ** 2, -1)
    assert bool(jnp.all(err <= jnp.min(d_all, -1) + 1e-5))


def test_pq_roundtrip(rng):
    x = jax.random.normal(rng, (256, 32))
    cbs = quant.pq_fit(rng, x, quant.PQConfig(k=16, n_sub=4, iters=8))
    assert cbs.shape == (4, 16, 8)
    codes = quant.pq_quantize(x, cbs)
    assert codes.shape == (256, 4)
    dec = quant.pq_decode(codes, cbs)
    assert dec.shape == x.shape
    # PQ with more subspaces should reconstruct better than K=16 flat
    flat_c, _ = quant.kmeans_fit(rng, x, quant.KMeansConfig(k=16, iters=8))
    pq_err = float(jnp.mean(jnp.sum((x - dec) ** 2, -1)))
    flat_err = float(quant.quantization_error(x, flat_c))
    assert pq_err < flat_err
