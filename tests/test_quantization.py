"""K-Means quantization unit + property tests (core/quantization.py)."""
import jax
import jax.numpy as jnp
import numpy as np

from tests._hypothesis_compat import given, settings, st

from repro.core import quantization as quant


def test_kmeans_reduces_mse(rng):
    x = jax.random.normal(rng, (512, 16))
    cfg = quant.KMeansConfig(k=32, iters=15)
    cents, mses = quant.kmeans_fit(rng, x, cfg)
    assert cents.shape == (32, 16)
    assert float(mses[-1]) <= float(mses[0])
    # codebook should beat a random codebook
    rand_cents = jax.random.normal(jax.random.PRNGKey(9), (32, 16))
    assert (quant.quantization_error(x, cents)
            < quant.quantization_error(x, rand_cents))


def test_kmeans_recovers_planted_clusters(rng):
    centers = jax.random.normal(rng, (8, 8)) * 5
    idx = jax.random.randint(jax.random.PRNGKey(1), (1024,), 0, 8)
    x = centers[idx] + 0.05 * jax.random.normal(jax.random.PRNGKey(2),
                                                (1024, 8))
    cents, _ = quant.kmeans_fit(rng, x, quant.KMeansConfig(k=8, iters=25))
    err = quant.quantization_error(x, cents)
    assert float(err) < 0.1  # ~noise floor (8 dims * 0.05^2 = 0.02)


def test_assign_is_nearest(rng):
    x = jax.random.normal(rng, (64, 4))
    c = jax.random.normal(jax.random.PRNGKey(3), (7, 4))
    codes = quant.assign(x, c)
    d = jnp.sum((x[:, None] - c[None]) ** 2, -1)
    np.testing.assert_array_equal(np.asarray(codes), np.argmin(d, -1))


def test_quantize_decode_shapes_and_dtype(rng):
    x = jax.random.normal(rng, (10, 6, 16))
    c = jax.random.normal(jax.random.PRNGKey(3), (256, 16))
    codes = quant.quantize(x, c)
    assert codes.shape == (10, 6) and codes.dtype == jnp.uint8
    dec = quant.decode(codes, c)
    assert dec.shape == x.shape


@settings(max_examples=20, deadline=None)
@given(k=st.sampled_from([128, 256, 512]))
def test_paper_bits_arithmetic(k):
    """b = ceil(log2 K): 7/8/9 bits for the paper's K values."""
    cfg = quant.KMeansConfig(k=k)
    assert cfg.bits == {128: 7, 256: 8, 512: 9}[k]
    assert cfg.code_dtype == (jnp.uint8 if k <= 256 else jnp.uint16)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(32, 128), d=st.sampled_from([4, 8]),
       k=st.sampled_from([4, 16]))
def test_property_decode_error_bounded_by_worst_pair(n, d, k):
    """Reconstruction error <= max pairwise distance (codebook covers x)."""
    key = jax.random.PRNGKey(n * d * k)
    x = jax.random.normal(key, (n, d))
    cents, _ = quant.kmeans_fit(key, x, quant.KMeansConfig(k=k, iters=5))
    codes = quant.assign(x, cents)
    err = jnp.sum((x - quant.decode(codes, cents)) ** 2, -1)
    # nearest-centroid property: err <= distance to ANY centroid
    d_all = jnp.sum((x[:, None] - cents[None]) ** 2, -1)
    assert bool(jnp.all(err <= jnp.min(d_all, -1) + 1e-5))


def test_pairwise_sq_dists_clamped_non_negative():
    """Satellite: float cancellation yields negative squared distances on
    large-norm inputs; the clamp must keep every consumer (k-means++
    weights, inertia) on valid values."""
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 256)) * 1e3
    d = quant.pairwise_sq_dists(x, x)          # true diagonal is exactly 0
    assert float(jnp.min(d)) >= 0.0
    # the raw matmul form really does go negative on this input — the
    # clamp is load-bearing, not decorative
    x2 = jnp.sum(x * x, -1, keepdims=True)
    raw = x2 - 2.0 * (x @ x.T) + jnp.sum(x * x, -1)[None, :]
    assert float(jnp.min(raw)) < 0.0


def test_kmeans_pp_seeding_survives_duplicate_heavy_data():
    """All-duplicate rows drive every d2 to ~0; categorical weights must
    stay finite (no log of a negative / NaN sampling distribution)."""
    x = jnp.full((128, 32), 500.0)
    cents = quant._kmeans_pp_init(jax.random.PRNGKey(0), x, 8)
    assert bool(jnp.all(jnp.isfinite(cents)))


def test_seeding_corpus_smaller_than_seed_batch():
    """Regression (satellite): n < seed_batch seeds on all points without
    replacement — the v0 `replace=n < m` guard was dead code."""
    x = jax.random.normal(jax.random.PRNGKey(0), (48, 8))
    cfg = quant.KMeansConfig(k=8, iters=5, seed_batch=4096, n_restarts=2)
    cents, mses = quant.kmeans_fit(jax.random.PRNGKey(1), x, cfg)
    assert cents.shape == (8, 8)
    assert bool(jnp.all(jnp.isfinite(cents)))
    assert float(quant.quantization_error(x, cents)) <= float(mses[0]) + 1e-6


def test_empty_cluster_repair_deterministic():
    """A centroid that captures zero points must re-seed on the farthest
    point instead of staying frozen at its stale position."""
    a = jnp.zeros((8, 2)) + jnp.arange(8)[:, None] * 0.01
    b = jnp.array([10.0, 0.0]) + jnp.arange(8)[:, None] * 0.01
    x = jnp.concatenate([a, b])
    c0 = jnp.array([[0.0, 0.0], [10.0, 0.0], [100.0, 100.0]])
    new_c, _ = quant._lloyd_step(x, c0)
    # the dead centroid moved onto an actual data point...
    assert bool(jnp.any(jnp.all(jnp.isclose(x, new_c[2][None], atol=1e-6),
                                axis=1)))
    # ...specifically the farthest-from-assigned one, not (100, 100)
    assert float(jnp.max(jnp.abs(new_c[2]))) < 20.0


def test_repair_recovers_all_clusters():
    """Refining from seeds that double-cover one cluster and leave one
    centroid dead must still end up covering every planted cluster."""
    key = jax.random.PRNGKey(7)
    centers = jnp.array([[0.0, 0.0], [10.0, 0.0], [0.0, 10.0]])
    idx = jax.random.randint(key, (300,), 0, 3)
    x = centers[idx] + 0.05 * jax.random.normal(jax.random.PRNGKey(8),
                                                (300, 2))
    c0 = jnp.array([[0.0, 0.1], [0.1, 0.0], [500.0, 500.0]])
    best_c, _, _ = quant.kmeans_refine(x, c0, iters=10)
    err = float(quant.quantization_error(x, best_c))
    assert err < 0.1, err  # dead centroid frozen at (500,500) would be ~33


def test_restart_selection_picks_lowest_inertia():
    """kmeans_fit must return exactly the restart `_fit_single` ranks best
    — and on a stuck-prone planted dataset the restarts genuinely differ."""
    key = jax.random.PRNGKey(0)
    # overclustered (48 prototypes >> k=16) + few iters: different seeds
    # genuinely land at different local minima
    centers = jax.random.normal(key, (48, 4)) * 5
    idx = jax.random.randint(jax.random.PRNGKey(1), (256,), 0, 48)
    x = centers[idx] + 0.05 * jax.random.normal(jax.random.PRNGKey(2),
                                                (256, 4))
    cfg = quant.KMeansConfig(k=16, iters=2, n_restarts=4)
    fit_key = jax.random.PRNGKey(3)
    cents, _ = quant.kmeans_fit(fit_key, x, cfg)
    e_best = float(quant.quantization_error(x, cents))
    finals = [float(quant._fit_single(kk, x, cfg)[2])
              for kk in jax.random.split(fit_key, 4)]
    assert e_best <= min(finals) + 1e-5
    assert max(finals) > min(finals)  # selection has something to select


def test_refine_returns_best_iterate_not_last(rng, monkeypatch):
    """Satellite: the fit must return the lowest-inertia iterate, not the
    last one. Force a strictly worsening trajectory and check the first
    iterate wins."""
    x = jax.random.normal(rng, (64, 4))
    c_good, _ = quant.kmeans_fit(rng, x, quant.KMeansConfig(k=8, iters=10,
                                                            n_restarts=1))
    monkeypatch.setattr(
        quant, "_lloyd_step",
        lambda xx, cc: (cc * 2.0 + 1.0, quant._inertia(xx, cc)))
    best_c, inertias, best_i = quant.kmeans_refine(x, c_good, iters=4)
    np.testing.assert_allclose(np.asarray(best_c), np.asarray(c_good))
    assert float(best_i) == float(inertias[0])


def test_minibatch_mode_recovers_planted_clusters(rng):
    centers = jax.random.normal(rng, (8, 8)) * 5
    idx = jax.random.randint(jax.random.PRNGKey(1), (2048,), 0, 8)
    x = centers[idx] + 0.05 * jax.random.normal(jax.random.PRNGKey(2),
                                                (2048, 8))
    cfg = quant.KMeansConfig(k=8, iters=40, minibatch=256, n_restarts=2)
    cents, _ = quant.kmeans_fit(rng, x, cfg)
    err = float(quant.quantization_error(x, cents))
    assert err < 0.2, err  # near the 8 * 0.05^2 noise floor


def test_sharded_kmeans_parity_on_1dev_mesh():
    """Satellite: the sharded trainer on a 1-device mesh must reproduce
    the single-host codebook (same seeds, psum over one shard)."""
    from repro.core import distributed as dist
    mesh = jax.make_mesh((1,), ("data",))
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 8))
    cfg = quant.KMeansConfig(k=16, iters=8, n_restarts=2)
    c_sh, hist_sh = dist.sharded_kmeans_fit(mesh, jax.random.PRNGKey(3), x,
                                            cfg)
    c_ref, hist_ref = quant.kmeans_fit(jax.random.PRNGKey(3), x, cfg)
    np.testing.assert_allclose(np.asarray(c_sh), np.asarray(c_ref),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(hist_sh), np.asarray(hist_ref),
                               atol=1e-5)


def test_pq_roundtrip(rng):
    x = jax.random.normal(rng, (256, 32))
    cbs = quant.pq_fit(rng, x, quant.PQConfig(k=16, n_sub=4, iters=8))
    assert cbs.shape == (4, 16, 8)
    codes = quant.pq_quantize(x, cbs)
    assert codes.shape == (256, 4)
    dec = quant.pq_decode(codes, cbs)
    assert dec.shape == x.shape
    # PQ with more subspaces should reconstruct better than K=16 flat
    flat_c, _ = quant.kmeans_fit(rng, x, quant.KMeansConfig(k=16, iters=8))
    pq_err = float(jnp.mean(jnp.sum((x - dec) ** 2, -1)))
    flat_err = float(quant.quantization_error(x, flat_c))
    assert pq_err < flat_err
