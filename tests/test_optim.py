"""Optimizer substrate tests: AdamW, int8 moments, schedules, grad
compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import grad_compression as gc
from repro.optim import optimizer as opt


def _rosenbrock_ish(params):
    x, y = params["x"], params["y"]
    return jnp.sum((1 - x) ** 2) + 5 * jnp.sum((y - x ** 2) ** 2)


def _train(cfg, steps=300):
    params = {"x": jnp.full((4,), -1.0), "y": jnp.full((4,), 2.0)}
    state = opt.init(cfg, params)

    @jax.jit
    def step(p, s):
        g = jax.grad(_rosenbrock_ish)(p)
        return opt.update(cfg, g, s, p)

    for _ in range(steps):
        params, state, m = step(params, state)
    return float(_rosenbrock_ish(params)), m


def test_adamw_converges():
    loss, m = _train(opt.AdamWConfig(lr=3e-2, weight_decay=0.0,
                                     warmup_steps=10, total_steps=300))
    assert loss < 0.05


def test_int8_moments_converge_close_to_fp32():
    l32, _ = _train(opt.AdamWConfig(lr=3e-2, weight_decay=0.0,
                                    warmup_steps=10, total_steps=300))
    l8, _ = _train(opt.AdamWConfig(lr=3e-2, weight_decay=0.0,
                                   warmup_steps=10, total_steps=300,
                                   moment_dtype="int8"))
    assert l8 < max(10 * l32, 0.5), (l8, l32)


def test_int8_state_is_actually_int8():
    cfg = opt.AdamWConfig(moment_dtype="int8")
    params = {"w": jnp.ones((8, 16))}
    st = opt.init(cfg, params)
    assert st.m["w"].q.dtype == jnp.int8
    assert st.m["w"].scale.shape == (8, 1)
    # memory accounting: 1 B/entry + fp32 row scales vs 4 B/entry
    q_bytes = st.m["w"].q.size + st.m["w"].scale.size * 4
    assert q_bytes < params["w"].size * 4 / 3


def test_schedule_warmup_and_decay():
    cfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
    lrs = [float(opt.schedule(cfg, jnp.int32(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1.0) < 1e-6          # end of warmup
    assert lrs[-1] == pytest.approx(0.1, rel=1e-3)
    assert all(a >= b - 1e-9 for a, b in zip(lrs[1:], lrs[2:]))


def test_clip_bounds_update_norm():
    cfg = opt.AdamWConfig(lr=1.0, clip_norm=1e-3, weight_decay=0.0)
    params = {"w": jnp.zeros((4,))}
    st = opt.init(cfg, params)
    g = {"w": jnp.full((4,), 1e6)}
    new_p, st, m = opt.update(cfg, g, st, params)
    assert float(m["grad_norm"]) > 1e5
    assert bool(jnp.isfinite(new_p["w"]).all())


def test_nonfinite_guard_integration():
    from repro.train.loop import guard_nonfinite
    cfg = opt.AdamWConfig()
    params = {"w": jnp.ones((2,))}
    st = opt.init(cfg, params)

    def bad_step(p, o, b):
        return jax.tree.map(lambda x: x * jnp.nan, p), o, \
            {"loss": jnp.float32(jnp.nan)}

    guarded = jax.jit(guard_nonfinite(bad_step))
    p2, o2, m = guarded(params, st, {})
    np.testing.assert_array_equal(np.asarray(p2["w"]),
                                  np.asarray(params["w"]))
    assert int(m["skipped"]) == 1


# --- gradient compression ---------------------------------------------------

def test_int8_stochastic_rounding_unbiased(rng):
    g = jax.random.normal(rng, (2000,)) * 0.3
    keys = jax.random.split(rng, 64)
    deqs = jnp.stack([gc.dequantize_grad(gc.quantize_grad(k, g))
                      for k in keys])
    bias = jnp.abs(jnp.mean(deqs, 0) - g)
    scale = float(jnp.max(jnp.abs(g))) / 127
    assert float(bias.mean()) < scale * 0.3    # unbiased within MC noise


def test_topk_error_feedback_preserves_signal(rng):
    """With error feedback, repeated compression of a CONSTANT gradient
    eventually transmits everything (residual re-injection)."""
    g = {"w": jax.random.normal(rng, (64,))}
    state = gc.topk_init(g)
    sent_total = jnp.zeros((64,))
    for _ in range(20):
        kept, state, stats = gc.topk_compress(g, state, frac=0.1)
        sent_total = sent_total + kept["w"]
    # after 20 rounds, average transmitted ~= 20 * g (no signal lost)
    rel = float(jnp.linalg.norm(sent_total / 20 - g["w"])
                / jnp.linalg.norm(g["w"]))
    assert rel < 0.35, rel
    assert stats["ratio"] == pytest.approx(0.1, rel=0.1)


def test_topk_compress_layout(rng):
    g = {"w": jax.random.normal(rng, (10, 10))}
    kept, state, stats = gc.topk_compress(g, gc.topk_init(g), frac=0.05)
    nz = int(jnp.sum(kept["w"] != 0))
    assert nz == 5
    assert kept["w"].shape == (10, 10)
