"""PNA GNN + recsys family tests with the assigned smoke configs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.data import sampler, synthetic
from repro.dist.sharding import is_logical_spec
from repro.models import gnn, recsys
from repro.optim import optimizer as opt

RECSYS_ARCHS = [a for a, s in registry.ARCHS.items() if s.family == "recsys"]


def test_pna_smoke_learns(rng):
    cfg = registry.get("pna").smoke_config
    g = synthetic.make_graph(rng, 256, 1024, cfg.d_feat, cfg.n_classes)
    params = gnn.init(rng, cfg)
    assert (jax.tree.structure(params) ==
            jax.tree.structure(gnn.param_specs(cfg),
                               is_leaf=is_logical_spec))
    ocfg = opt.AdamWConfig(lr=5e-3, total_steps=60, warmup_steps=5)
    ostate = opt.init(ocfg, params)
    step = jax.jit(lambda p, o, b: gnn.train_step(p, o, b, cfg, ocfg))
    p, o, m = step(params, ostate, g)
    for _ in range(40):
        p, o, m = step(p, o, g)
    assert float(m["acc"]) > 0.7   # communities are learnable


def test_pna_molecule_graph_task(rng):
    base = registry.get("pna").smoke_config
    cfg = dataclasses.replace(base, d_feat=6, n_classes=2, task="graph")
    b = synthetic.make_molecule_batch(rng, 16, 10, 20, 6)
    params = gnn.init(rng, cfg)
    loss, parts = gnn.loss_fn(params, b, cfg)
    assert jnp.isfinite(loss)
    logits = gnn.serve_step(params, b, cfg)
    assert logits.shape == (16, 2)


def test_neighbor_sampler_shapes_and_validity(rng):
    g = synthetic.make_graph(rng, 500, 4000, 8, 4)
    csr = sampler.build_csr(500, np.asarray(g["edge_index"]),
                            np.asarray(g["feats"]), np.asarray(g["labels"]))
    rng_np = np.random.default_rng(0)
    seeds = rng_np.choice(500, 32, replace=False)
    sub = sampler.sample_subgraph(rng_np, csr, seeds, (5, 3))
    n_max = 32 + 32 * 5 + 160 * 3
    assert sub["feats"].shape == (n_max, 8)
    assert sub["edge_index"].shape == (2, 32 * 5 + 160 * 3)
    assert (sub["labels"] >= 0).sum() == 32          # only seeds labelled
    assert sub["edge_index"].max() < n_max
    # every edge endpoint has real features (belongs to sampled set)
    used = np.unique(sub["edge_index"])
    assert np.abs(sub["feats"][used]).sum() > 0


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke_train(arch):
    spec = registry.get(arch)
    cfg = spec.smoke_config
    key = jax.random.PRNGKey(0)
    params = recsys.init(key, cfg)
    assert (jax.tree.structure(params) ==
            jax.tree.structure(recsys.param_specs(cfg),
                               is_leaf=is_logical_spec))
    batch = synthetic.make_recsys_batch(key, 64, cfg.n_dense,
                                        cfg.table_rows, seq_len=cfg.seq_len,
                                        family=cfg.family)
    ocfg = opt.AdamWConfig(lr=3e-3, total_steps=60, warmup_steps=5)
    ostate = opt.init(ocfg, params)
    step = jax.jit(lambda p, o, b: recsys.train_step(p, o, b, cfg, ocfg))
    p, o, m = step(params, ostate, batch)
    l0 = float(m["loss"])
    for _ in range(30):
        p, o, m = step(p, o, batch)
    assert float(m["loss"]) < l0
    # serve + candidate scoring shapes
    probs = recsys.serve_step(p, batch, cfg)
    assert probs.shape == (64,) and bool(jnp.isfinite(probs).all())
    n_items = cfg.table_rows[-1 if cfg.family in ("dlrm", "dcn") else 0]
    cand = jax.random.randint(key, (128,), 0, n_items)
    one = {k: v[:1] for k, v in batch.items() if k != "label"}
    sc = recsys.score_candidates(p, one, cand, cfg)
    assert sc.shape == (128,) and bool(jnp.isfinite(sc).all())


def test_din_history_pruning_paper_transfer(rng):
    """din_prune_p: top-p% history pruning ~ full attention when the
    attention is concentrated (the paper's §III-C premise)."""
    spec = registry.get("din")
    cfg_full = spec.smoke_config
    cfg_pruned = dataclasses.replace(cfg_full, din_prune_p=50.0)
    params = recsys.init(rng, cfg_full)
    batch = synthetic.make_recsys_batch(rng, 32, 0, cfg_full.table_rows,
                                        seq_len=cfg_full.seq_len,
                                        family="din")
    full = recsys.forward(params, batch, cfg_full)
    pruned = recsys.forward(params, batch, cfg_pruned)
    assert pruned.shape == full.shape
    assert bool(jnp.isfinite(pruned).all())
    # ranking correlation between pruned/full scores stays high
    corr = np.corrcoef(np.asarray(full), np.asarray(pruned))[0, 1]
    assert corr > 0.6, corr


def test_quantized_tables_compress_and_approximate(rng):
    spec = registry.get("dlrm-mlperf")
    cfg = spec.smoke_config
    params = recsys.init(rng, cfg)
    qt = recsys.quantize_tables(rng, params["tables"], k=32, iters=10)
    ratio = recsys.tables_nbytes(params["tables"]) / recsys.qtables_nbytes(qt)
    assert ratio > 1.5  # smoke tables are codebook-dominated
    # production-shaped table: compression approaches 4*dim / 1
    big = [jax.random.normal(rng, (8192, 16))]
    qt_big = recsys.quantize_tables(rng, big, k=256, iters=3)
    big_ratio = recsys.tables_nbytes(big) / recsys.qtables_nbytes(qt_big)
    assert big_ratio > 20
    ids = jax.random.randint(rng, (16, len(cfg.table_rows)), 0,
                             min(cfg.table_rows))
    full = recsys.lookup(params["tables"], ids)
    approx = recsys.quantized_lookup(qt, ids)
    assert approx.shape == full.shape
    rel = float(jnp.linalg.norm(full - approx) / jnp.linalg.norm(full))
    assert rel < 0.9   # K=32 on random tables is lossy but correlated


def test_embedding_bag_modes(rng):
    table = jax.random.normal(rng, (40, 6))
    vals = jnp.array([3, 4, 5, 20, 21, 30])
    segs = jnp.array([0, 0, 0, 1, 1, 2])
    s = recsys.embedding_bag(table, vals, segs, 3, mode="sum")
    m = recsys.embedding_bag(table, vals, segs, 3, mode="mean")
    np.testing.assert_allclose(np.asarray(s[0]),
                               np.asarray(table[3:6].sum(0)), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(m[1]),
                               np.asarray(table[20:22].mean(0)), rtol=1e-6)
