"""Serving (continuous batching) + RAG integration tests."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pipeline as hpc
from repro.core import rag
from repro.data import synthetic
from repro.models import transformer as T
from repro.serving.server import RetrievalServer, ServeConfig


def test_server_batches_and_matches_direct(rng):
    spec = synthetic.CorpusSpec(n_docs=128, n_queries=16, n_patches=8,
                                n_q_patches=4, dim=16, n_topics=4)
    data = synthetic.make_retrieval_corpus(rng, spec)
    cfg = hpc.HPCConfig(k=16, p=100.0, mode="quantized", prune_side="none",
                        kmeans_iters=5)
    index = hpc.build_index(rng, data.doc_patches, data.doc_mask,
                            data.doc_salience, cfg)

    @jax.jit
    def search(q, qm, qs):
        return hpc.query(index, q, qm, qs, cfg, k=5)

    server = RetrievalServer(search, ServeConfig(max_batch=4, top_k=5,
                                                 max_wait_ms=5.0))
    # direct reference
    ref_s, ref_i = search(data.query_patches, data.query_mask,
                          data.query_salience)
    reqs = [server.submit(data.query_patches[i], data.query_mask[i],
                          data.query_salience[i]) for i in range(16)]
    for i, r in enumerate(reqs):
        assert r.event.wait(30)
        s, ids = r.result
        np.testing.assert_allclose(s, np.asarray(ref_s[i]), atol=1e-4)
        np.testing.assert_array_equal(ids, np.asarray(ref_i[i]))
    st = server.stats()
    assert st["n"] == 16
    assert st["mean_batch"] > 1.0     # coalescing actually happened
    server.close()


def test_rouge_l():
    assert rag.rouge_l([1, 2, 3], [1, 2, 3]) == 1.0
    assert rag.rouge_l([1, 2, 3], [4, 5, 6]) == 0.0
    f1 = rag.rouge_l([1, 2, 3, 4], [1, 3])
    assert 0 < f1 < 1
    assert rag.rouge_l([], [1]) == 0.0


def test_hallucination_rate():
    gen = [{1, 2}, {3}, {4, 5}]
    ctx = [{1, 2}, {9}, {4}]
    # 0/2 bad, 1/1 bad, 1/2 bad -> 2/5
    assert rag.hallucination_rate(gen, ctx) == pytest.approx(0.4)
    assert rag.hallucination_rate([set()], [set()]) == 0.0


def test_extract_facts():
    toks = np.array([[3, 4, 0, 1], [2, 7, 7, 99]])
    out = rag.extract_facts(toks, fact0=3, n_facts=10)
    assert out[0] == {0, 1}
    assert out[1] == {4}


def test_build_prompt_and_train_batch(rng):
    corpus, vocab = synthetic.make_fact_corpus(rng, n_docs=32,
                                               n_facts_vocab=20,
                                               facts_per_doc=3, dim=8,
                                               n_patches=6, n_queries=8,
                                               seq_len=16)
    rcfg = rag.RAGConfig(top_k_docs=2, facts_per_doc=3, max_answer=3)
    batch = rag.make_rag_train_batch(rng, corpus, vocab, rcfg, batch=4,
                                     seq_len=24, n_docs=32)
    assert batch["tokens"].shape == (4, 24)
    assert batch["targets"].shape == (4, 24)
    # only answer positions are supervised
    n_sup = int((batch["targets"] >= 0).sum())
    assert n_sup == 4 * 3
    # supervised targets are fact tokens
    sup = batch["targets"][batch["targets"] >= 0]
    assert bool((sup >= vocab["fact0"]).all())


def test_greedy_generate_matches_decode(rng):
    cfg = T.LMConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                     d_ff=64, vocab=29, q_chunk=8, loss_chunk=8)
    params = T.init(rng, cfg)
    prompt = jax.random.randint(rng, (2, 8), 0, 29)
    gen = rag.greedy_generate(params, prompt, cfg, max_new=3, prompt_len=8)
    assert gen.shape == (2, 3)
    # first generated token == argmax of prefill logits
    logits, _ = T.prefill(params, prompt, cfg, max_len=11)
    np.testing.assert_array_equal(np.asarray(gen[:, 0]),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_rag_pipeline_end_to_end_smoke(rng):
    """Full RAG loop with an untrained generator: metrics computable, and
    retrieval finds the gold doc (the planted corpus makes that easy)."""
    corpus, vocab = synthetic.make_fact_corpus(rng, n_docs=64,
                                               n_facts_vocab=400,
                                               facts_per_doc=3, dim=16,
                                               n_patches=6, n_queries=12,
                                               seq_len=16)
    # the corpus spans ~192 distinct fact prototypes: the codebook must be
    # large enough to separate them (K=128; the paper's K=256 regime)
    rcfg = rag.RAGConfig(
        retriever=hpc.HPCConfig(k=128, p=100.0, mode="quantized",
                                prune_side="none", kmeans_iters=15),
        top_k_docs=2, facts_per_doc=3, max_answer=3)
    index = hpc.build_index(rng, corpus.doc_patches, corpus.doc_mask,
                            corpus.doc_salience, rcfg.retriever)
    # check retrieval quality directly: gold doc in top-2
    _, ids = hpc.query(index, corpus.query_patches, corpus.query_mask,
                       corpus.query_salience, rcfg.retriever, k=2)
    hit = np.mean([int(corpus.gold_doc[i]) in set(np.asarray(ids[i]).tolist())
                   for i in range(12)])
    assert hit > 0.7, hit

    lm_cfg = T.LMConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                        d_ff=64, vocab=vocab["size"], q_chunk=8,
                        loss_chunk=8)
    gen_params = T.init(rng, lm_cfg)
    metrics = rag.rag_pipeline(index, gen_params, corpus, rcfg, lm_cfg,
                               n_facts_vocab=400)
    for k in ("rouge_l", "hallucination", "latency_ms"):
        assert k in metrics and np.isfinite(metrics[k])
