"""Serving (continuous batching) + RAG integration tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pipeline as hpc
from repro.core import rag
from repro.data import synthetic
from repro.models import transformer as T
from repro.serving.server import RetrievalServer, ServeConfig


def test_server_batches_and_matches_direct(rng):
    spec = synthetic.CorpusSpec(n_docs=128, n_queries=16, n_patches=8,
                                n_q_patches=4, dim=16, n_topics=4)
    data = synthetic.make_retrieval_corpus(rng, spec)
    cfg = hpc.HPCConfig(k=16, p=100.0, mode="quantized", prune_side="none",
                        kmeans_iters=5)
    index = hpc.build_index(rng, data.doc_patches, data.doc_mask,
                            data.doc_salience, cfg)

    @jax.jit
    def search(q, qm, qs):
        return hpc.query(index, q, qm, qs, cfg, k=5)

    server = RetrievalServer(search, ServeConfig(max_batch=4, top_k=5,
                                                 max_wait_ms=5.0))
    # direct reference
    ref_s, ref_i = search(data.query_patches, data.query_mask,
                          data.query_salience)
    reqs = [server.submit(data.query_patches[i], data.query_mask[i],
                          data.query_salience[i]) for i in range(16)]
    for i, r in enumerate(reqs):
        assert r.event.wait(30)
        s, ids = r.result
        np.testing.assert_allclose(s, np.asarray(ref_s[i]), atol=1e-4)
        np.testing.assert_array_equal(ids, np.asarray(ref_i[i]))
    st = server.stats()
    assert st["n"] == 16
    assert st["mean_batch"] > 1.0     # coalescing actually happened
    server.close()


def test_server_stats_empty_returns_zeros():
    server = RetrievalServer(lambda q, qm, qs: (q, q), ServeConfig())
    st = server.stats()
    # "timeouts" is the one always-on resilience counter (sync-facade
    # timeouts cancel their queued item on any server); the overload /
    # degradation keys only appear with ServeConfig(resilience=...)
    assert st == {"n": 0, "p50_ms": 0.0, "p99_ms": 0.0, "mean_batch": 0.0,
                  "qps": 0.0, "rungs": {}, "timeouts": 0}
    server.close()


def test_server_qps_is_wall_clock_not_latency_sum():
    """Concurrent requests share one batched search call: qps must come
    from the serving-window wall clock, not the sum of overlapping
    per-request latencies (which here is ~8x the wall clock)."""
    import time as _time

    def slow_search(q, qm, qs):
        _time.sleep(0.05)
        return np.zeros((q.shape[0], 5)), np.zeros((q.shape[0], 5), np.int64)

    server = RetrievalServer(slow_search,
                             ServeConfig(max_batch=8, max_wait_ms=20.0))
    t0 = _time.perf_counter()
    reqs = [server.submit(np.zeros((4, 8)), np.ones((4,), bool),
                          np.zeros((4,))) for _ in range(8)]
    for r in reqs:
        assert r.event.wait(10)
    wall = _time.perf_counter() - t0
    st = server.stats()
    assert st["n"] == 8
    # all 8 ran in ~1 batch: the latency *sum* is ~8 * 50ms >> wall span,
    # so the buggy formula would report < ~25 qps; wall-clock gives ~100+
    buggy_qps = st["n"] / (sum(server.latencies_ms) / 1e3)
    assert st["qps"] > 2 * buggy_qps
    assert st["qps"] <= st["n"] / 0.05 * 1.5    # sane upper bound
    assert wall < 5.0
    server.close()


def test_server_reset_stats_mid_flight_keeps_stats_sane():
    """reset_stats() while a batch is inside search_fn must not poison
    stats(): the window restarts at that batch's enqueue time."""
    import time as _time

    def slow_search(q, qm, qs):
        _time.sleep(0.1)
        return np.zeros((q.shape[0], 5)), np.zeros((q.shape[0], 5), np.int64)

    server = RetrievalServer(slow_search,
                             ServeConfig(max_batch=2, max_wait_ms=1.0))
    r = server.submit(np.zeros((4, 8)), np.ones((4,), bool), np.zeros((4,)))
    _time.sleep(0.03)                 # dispatcher is now inside search_fn
    server.reset_stats()
    assert r.event.wait(10)
    st = server.stats()               # must not raise
    assert st["n"] == 1 and st["qps"] > 0.0
    server.close()


def test_server_reset_stats():
    server = RetrievalServer(
        lambda q, qm, qs: (np.zeros((q.shape[0], 5)),
                           np.zeros((q.shape[0], 5), np.int64)),
        ServeConfig(max_batch=2, max_wait_ms=1.0))
    r = server.submit(np.zeros((4, 8)), np.ones((4,), bool), np.zeros((4,)))
    assert r.event.wait(10)
    assert server.stats()["n"] == 1
    server.reset_stats()
    assert server.stats()["n"] == 0
    server.close()


def test_rouge_l():
    assert rag.rouge_l([1, 2, 3], [1, 2, 3]) == 1.0
    assert rag.rouge_l([1, 2, 3], [4, 5, 6]) == 0.0
    f1 = rag.rouge_l([1, 2, 3, 4], [1, 3])
    assert 0 < f1 < 1
    assert rag.rouge_l([], [1]) == 0.0


def test_hallucination_rate():
    gen = [{1, 2}, {3}, {4, 5}]
    ctx = [{1, 2}, {9}, {4}]
    # 0/2 bad, 1/1 bad, 1/2 bad -> 2/5
    assert rag.hallucination_rate(gen, ctx) == pytest.approx(0.4)
    assert rag.hallucination_rate([set()], [set()]) == 0.0


def test_extract_facts():
    toks = np.array([[3, 4, 0, 1], [2, 7, 7, 99]])
    out = rag.extract_facts(toks, fact0=3, n_facts=10)
    assert out[0] == {0, 1}
    assert out[1] == {4}


def test_build_prompt_and_train_batch(rng):
    corpus, vocab = synthetic.make_fact_corpus(rng, n_docs=32,
                                               n_facts_vocab=20,
                                               facts_per_doc=3, dim=8,
                                               n_patches=6, n_queries=8,
                                               seq_len=16)
    rcfg = rag.RAGConfig(top_k_docs=2, facts_per_doc=3, max_answer=3)
    batch = rag.make_rag_train_batch(rng, corpus, vocab, rcfg, batch=4,
                                     seq_len=24, n_docs=32)
    assert batch["tokens"].shape == (4, 24)
    assert batch["targets"].shape == (4, 24)
    # only answer positions are supervised
    n_sup = int((batch["targets"] >= 0).sum())
    assert n_sup == 4 * 3
    # supervised targets are fact tokens
    sup = batch["targets"][batch["targets"] >= 0]
    assert bool((sup >= vocab["fact0"]).all())


def test_greedy_generate_matches_decode(rng):
    cfg = T.LMConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                     d_ff=64, vocab=29, q_chunk=8, loss_chunk=8)
    params = T.init(rng, cfg)
    prompt = jax.random.randint(rng, (2, 8), 0, 29)
    gen = rag.greedy_generate(params, prompt, cfg, max_new=3, prompt_len=8)
    assert gen.shape == (2, 3)
    # first generated token == argmax of prefill logits
    logits, _ = T.prefill(params, prompt, cfg, max_len=11)
    np.testing.assert_array_equal(np.asarray(gen[:, 0]),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_rag_pipeline_end_to_end_smoke(rng):
    """Full RAG loop with an untrained generator: metrics computable, and
    retrieval finds the gold doc (the planted corpus makes that easy)."""
    corpus, vocab = synthetic.make_fact_corpus(rng, n_docs=64,
                                               n_facts_vocab=400,
                                               facts_per_doc=3, dim=16,
                                               n_patches=6, n_queries=12,
                                               seq_len=16)
    # the corpus spans ~192 distinct fact prototypes: the codebook must be
    # large enough to separate them (K=128; the paper's K=256 regime)
    rcfg = rag.RAGConfig(
        retriever=hpc.HPCConfig(k=128, p=100.0, mode="quantized",
                                prune_side="none", kmeans_iters=15),
        top_k_docs=2, facts_per_doc=3, max_answer=3)
    index = hpc.build_index(rng, corpus.doc_patches, corpus.doc_mask,
                            corpus.doc_salience, rcfg.retriever)
    # check retrieval quality directly: gold doc in top-2
    _, ids = hpc.query(index, corpus.query_patches, corpus.query_mask,
                       corpus.query_salience, rcfg.retriever, k=2)
    hit = np.mean([int(corpus.gold_doc[i]) in set(np.asarray(ids[i]).tolist())
                   for i in range(12)])
    assert hit > 0.7, hit

    lm_cfg = T.LMConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                        d_ff=64, vocab=vocab["size"], q_chunk=8,
                        loss_chunk=8)
    gen_params = T.init(rng, lm_cfg)
    metrics = rag.rag_pipeline(index, gen_params, corpus, rcfg, lm_cfg,
                               n_facts_vocab=400)
    for k in ("rouge_l", "hallucination", "latency_ms"):
        assert k in metrics and np.isfinite(metrics[k])
