"""Attention-guided pruning tests (core/pruning.py, paper §III-C)."""
import jax
import jax.numpy as jnp
import numpy as np

from tests._hypothesis_compat import given, settings, st

from repro.core import pruning


def test_keep_count_paper_values():
    # p=40 keeps 40% -> 60% compute saved (the paper's headline number)
    assert pruning.keep_count(100, 40.0) == 40
    assert pruning.compute_saved_fraction(100, 40.0) == 0.6
    assert pruning.keep_count(100, 60.0) == 60
    assert pruning.keep_count(10, 1.0) == 1        # clamped to >= 1
    assert pruning.keep_count(10, 100.0) == 10


def test_prune_keeps_most_salient(rng):
    emb = jax.random.normal(rng, (4, 10, 8))
    sal = jnp.tile(jnp.arange(10.0)[None], (4, 1))
    mask = jnp.ones((4, 10), bool)
    pr = pruning.prune_topp(emb, sal, mask, p=30.0)
    assert pr.embeddings.shape == (4, 3, 8)
    np.testing.assert_array_equal(np.asarray(pr.indices),
                                  np.tile([9, 8, 7], (4, 1)))
    assert bool(pr.mask.all())


def test_prune_respects_mask(rng):
    emb = jax.random.normal(rng, (1, 6, 4))
    sal = jnp.array([[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]])
    mask = jnp.array([[False, False, True, True, True, True]])
    pr = pruning.prune_topp(emb, sal, mask, p=50.0)
    # top-3 among VALID = positions 2,3,4 (not the masked 0,1)
    np.testing.assert_array_equal(np.asarray(pr.indices[0]), [2, 3, 4])


def test_prune_pads_with_invalid_when_few_valid(rng):
    emb = jax.random.normal(rng, (1, 6, 4))
    sal = jnp.ones((1, 6))
    mask = jnp.zeros((1, 6), bool).at[0, 0].set(True)
    pr = pruning.prune_topp(emb, sal, mask, p=80.0)   # keep 5 > 1 valid
    assert int(pr.mask.sum()) == 1
    # invalid slots zeroed
    assert float(jnp.abs(pr.embeddings[0, 1:]).sum()) == 0.0


@settings(max_examples=25, deadline=None)
@given(m=st.integers(2, 24), p=st.floats(1.0, 100.0))
def test_property_pruned_salience_is_topk(m, p):
    key = jax.random.PRNGKey(m)
    sal = jax.random.uniform(key, (1, m))
    emb = jnp.ones((1, m, 2))
    mask = jnp.ones((1, m), bool)
    pr = pruning.prune_topp(emb, sal, mask, p=p)
    k = pruning.keep_count(m, p)
    expected = np.sort(np.asarray(sal[0]))[::-1][:k]
    np.testing.assert_allclose(np.sort(np.asarray(pr.salience[0]))[::-1],
                               expected, rtol=1e-6)


def test_prune_codes_matches_prune_embeddings(rng):
    codes = jax.random.randint(rng, (3, 12), 0, 255).astype(jnp.uint8)
    sal = jax.random.uniform(jax.random.PRNGKey(5), (3, 12))
    mask = jnp.ones((3, 12), bool)
    kept_codes, idx, msk, _ = pruning.prune_topp_codes(codes, sal, mask,
                                                       p=50.0)
    pr = pruning.prune_topp(codes[..., None].astype(jnp.float32), sal, mask,
                            p=50.0)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(pr.indices))
    np.testing.assert_array_equal(
        np.asarray(kept_codes),
        np.asarray(pr.embeddings[..., 0]).astype(np.uint8))


def test_salience_from_attention():
    attn = jnp.zeros((2, 3, 4, 4)).at[:, :, :, 1].set(1.0)  # all mass on key 1
    sal = pruning.salience_from_attention(attn)
    assert sal.shape == (2, 4)
    assert float(sal[0, 1]) == 1.0 and float(sal[0, 0]) == 0.0
