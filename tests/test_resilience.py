"""Fault-tolerant serving: deadlines, shedding, degradation, chaos.

Controller unit tests run unmarked; the fault-injection / watchdog /
crash-persistence suite is marked ``chaos`` (network-free, < 60 s) and
runs standalone in CI's analysis job via ``pytest -m chaos``.
"""
import asyncio
import os
import signal
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.serving.resilience import (AdmissionController, DeadlineExceeded,
                                      DegradationController,
                                      DispatcherFailed, FaultInjected,
                                      FaultInjector, Overloaded,
                                      ResilienceConfig, TokenBucket)
from repro.serving.server import (AsyncRetrievalServer, RetrievalServer,
                                  ServeConfig, Served)

chaos = pytest.mark.chaos

Q = (np.zeros((4, 16), np.float32), np.ones(4, bool),
     np.zeros(4, np.float32))


def _fake_search(q, qm, qs):
    b = q.shape[0]
    return (np.zeros((b, 5), np.float32),
            np.tile(np.arange(5, dtype=np.int64), (b, 1)))


def _fake_degraded(q, qm, qs):
    b = q.shape[0]
    return (np.full((b, 5), -1.0, np.float32),
            np.tile(np.arange(5, dtype=np.int64), (b, 1)))


def _poll(predicate, timeout=5.0, msg="condition"):
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < timeout:
        if predicate():
            return
        time.sleep(0.01)
    raise AssertionError(f"timed out waiting for {msg}")


# ---------------------------------------------------------------------------
# Controller units
# ---------------------------------------------------------------------------

def test_token_bucket_rate_and_burst():
    tb = TokenBucket(rate=10.0, burst=2.0)
    assert tb.try_take(now=0.0) and tb.try_take(now=0.0)
    assert not tb.try_take(now=0.0)           # burst exhausted
    assert tb.try_take(now=0.1)               # 0.1 s * 10/s = 1 token back
    assert not tb.try_take(now=0.1)
    unlimited = TokenBucket(rate=0.0, burst=1.0)
    assert all(unlimited.try_take(now=0.0) for _ in range(100))


def test_admission_queue_bound_and_batch_sheds_first():
    cfg = ResilienceConfig(max_queue=10, shed_batch_frac=0.5)
    adm = AdmissionController(cfg)
    assert adm.admit("interactive", depth=0) is None
    assert adm.admit("batch", depth=0) is None
    # batch sheds at half depth, interactive only at the hard bound
    assert adm.admit("batch", depth=5) is not None
    assert adm.admit("interactive", depth=5) is None
    assert "queue full" in adm.admit("interactive", depth=10)
    counts = adm.stats()
    assert counts == {"interactive": 1, "batch": 1}
    adm.reset()
    assert adm.stats() == {"interactive": 0, "batch": 0}
    with pytest.raises(ValueError, match="unknown SLO class"):
        adm.admit("bulk", depth=0)


def test_admission_token_bucket_per_class():
    cfg = ResilienceConfig(max_queue=100, interactive_rate=1.0,
                           interactive_burst=2.0)
    adm = AdmissionController(cfg)
    t = 100.0
    assert adm.admit("interactive", 0, now=t) is None
    assert adm.admit("interactive", 0, now=t) is None
    assert "token bucket" in adm.admit("interactive", 0, now=t)
    # batch class has its own (unlimited) bucket
    assert adm.admit("batch", 0, now=t) is None


def test_degradation_hysteresis():
    cfg = ResilienceConfig(degrade_high_frac=0.75, degrade_low_frac=0.25,
                           degrade_hold=3)
    dc = DegradationController(n_levels=3, cfg=cfg)
    assert dc.observe(0.1) == 0               # calm at level 0: stays
    assert dc.observe(0.8) == 1               # hot: step down immediately
    assert dc.observe(0.9) == 2
    assert dc.observe(0.9) == 2               # clamped at n_levels - 1
    assert dc.observe(0.5) == 2               # hysteresis band: hold
    assert dc.observe(0.1) == 2               # calm 1/3
    assert dc.observe(0.1) == 2               # calm 2/3
    assert dc.observe(0.5) == 2               # band resets the calm run
    assert dc.observe(0.1) == 2
    assert dc.observe(0.1) == 2
    assert dc.observe(0.1) == 1               # calm 3/3: step back up
    assert len(dc.transitions) == 3
    # p99 trigger is an independent OR condition
    cfg2 = ResilienceConfig(degrade_p99_ms=50.0)
    dc2 = DegradationController(n_levels=2, cfg=cfg2)
    assert dc2.observe(0.0, p99_ms=80.0) == 1


def test_fault_injector_arm_fire_clear():
    fi = FaultInjector()
    fi.fire("stage")                          # unarmed: no-op
    fi.arm("stage", times=2)
    with pytest.raises(FaultInjected):
        fi.fire("stage")
    with pytest.raises(FaultInjected):
        fi.fire("stage")
    fi.fire("stage")                          # exhausted
    assert fi.fired["stage"] == 2
    fi.arm("compute", latency_s=0.05)
    t0 = time.perf_counter()
    fi.fire("compute")                        # latency only, no exception
    assert time.perf_counter() - t0 >= 0.05
    fi.arm("fanout", exc=RuntimeError("boom"))
    fi.clear("fanout")
    fi.fire("fanout")                         # cleared: no-op


# ---------------------------------------------------------------------------
# Satellite regressions: sync timeout leak, close() join, qps span
# ---------------------------------------------------------------------------

def test_sync_timeout_cancels_queued_item():
    """Pre-fix: a timed-out sync query stayed queued and occupied a batch
    slot. Now it is cancelled on the loop and counted in stats."""
    gate = threading.Event()

    def stalled_search(q, qm, qs):
        gate.wait(10.0)
        return _fake_search(q, qm, qs)

    server = RetrievalServer(
        stalled_search, ServeConfig(max_batch=1, max_wait_ms=0.5,
                                    max_inflight=1))
    try:
        # A occupies the single compute slot; B times out while queued
        req_a = server.submit(*Q)
        with pytest.raises(TimeoutError, match="timed out"):
            server.query(*Q, timeout=0.3)
        gate.set()
        assert req_a.event.wait(5.0) and req_a.error is None
        # B's cancelled item must be pruned, not staged: only A (and the
        # post-fix probe) ever reach compute
        s, ids = server.query(*Q, timeout=5.0)
        assert s.shape == (5,)
        _poll(lambda: server.stats()["timeouts"] == 1, msg="timeout count")
        assert server.stats()["n"] == 2       # A + probe, never B
    finally:
        gate.set()
        server.close()


def test_close_raises_when_thread_fails_to_join():
    server = RetrievalServer(_fake_search, ServeConfig(max_batch=1))
    real_thread = server._thread

    class StuckThread:
        name = "serve-loop"
        daemon = True

        def join(self, timeout=None):
            pass

        def is_alive(self):
            return True

    server._thread = StuckThread()
    with pytest.raises(RuntimeError, match="failed to join"):
        server.close()
    # the real loop did stop; finish teardown manually
    real_thread.join(timeout=5.0)
    assert not real_thread.is_alive()
    server._loop.close()


def test_qps_span_from_timestamps_only():
    """qps must come from the monotonic first/last window. If the window
    is missing (reset_stats raced the last completion), report 0.0 —
    never the old sum-of-overlapping-latencies fallback, which inflated
    qps by orders of magnitude under concurrency."""
    server = RetrievalServer(_fake_search,
                             ServeConfig(max_batch=4, max_wait_ms=1.0))
    try:
        for _ in range(4):
            server.query(*Q, timeout=5.0)
        st = server.stats()
        assert st["n"] == 4 and st["qps"] > 0.0
        span = st["n"] / st["qps"]
        assert span <= 60.0                   # sane wall-clock window
        # simulate the race: latencies present, window cleared
        srv = server._async
        with srv._lock:
            srv._t_first_enqueue = None
            srv._t_last_done = None
        st = server.stats()
        assert st["n"] == 4
        assert st["qps"] == 0.0               # degraded fallback is gone
    finally:
        server.close()


def test_reset_stats_race_restores_window():
    """reset_stats while a batch is in flight: the fan-out backfills the
    window from the batch's own enqueue times, so qps stays derived from
    real timestamps."""
    gate = threading.Event()

    def slow_search(q, qm, qs):
        gate.wait(5.0)
        return _fake_search(q, qm, qs)

    server = RetrievalServer(slow_search,
                             ServeConfig(max_batch=1, max_wait_ms=0.2))
    try:
        req = server.submit(*Q)
        time.sleep(0.05)                      # batch now inside search_fn
        server.reset_stats()
        gate.set()
        assert req.event.wait(5.0) and req.error is None
        st = server.stats()
        assert st["n"] == 1
        assert 0.0 < st["qps"] < float("inf")
    finally:
        gate.set()
        server.close()


# ---------------------------------------------------------------------------
# Deadlines, shedding, degradation (async integration)
# ---------------------------------------------------------------------------

def test_deadline_expired_before_staging():
    async def go():
        srv = AsyncRetrievalServer(
            _fake_search,
            ServeConfig(max_batch=2, max_wait_ms=0.5,
                        resilience=ResilienceConfig()))
        # stall the dispatcher between dequeue and staging: the deadline
        # passes while the request is claimed, so it is dropped before
        # any compute happens
        srv.fault_injector.arm("dispatch", latency_s=0.08)
        with pytest.raises(DeadlineExceeded, match="before staging"):
            await srv.query(*Q, deadline_ms=20.0)
        st = srv.stats()
        assert st["deadline_expired"] == 1
        assert st["n"] == 0                   # never staged, never computed
        # deadline generous enough: served normally, tagged level 0
        out = await srv.query(*Q, deadline_ms=5000.0)
        assert isinstance(out, Served) and out.level == 0
        await srv.aclose()

    asyncio.run(go())


def test_deadline_expired_during_compute():
    async def go():
        srv = AsyncRetrievalServer(
            _fake_search,
            ServeConfig(max_batch=1, max_wait_ms=0.2,
                        resilience=ResilienceConfig()))
        srv.fault_injector.arm("compute", latency_s=0.08)
        with pytest.raises(DeadlineExceeded, match="during compute"):
            await srv.query(*Q, deadline_ms=20.0)
        assert srv.stats()["deadline_expired"] == 1
        await srv.aclose()

    asyncio.run(go())


def test_overload_sheds_with_explicit_rejection():
    gate = threading.Event()

    def stalled(q, qm, qs):
        gate.wait(10.0)
        return _fake_search(q, qm, qs)

    async def go():
        srv = AsyncRetrievalServer(
            stalled,
            ServeConfig(max_batch=1, max_wait_ms=0.2, max_inflight=1,
                        resilience=ResilienceConfig(max_queue=4,
                                                    shed_batch_frac=0.5)))
        tasks = [asyncio.ensure_future(srv.query(*Q)) for _ in range(12)]
        await asyncio.sleep(0.1)
        batch_rej = None
        try:
            await srv.query(*Q, slo="batch")  # queue deep: batch class shed
        except Overloaded as e:
            batch_rej = str(e)
        gate.set()
        outs = await asyncio.gather(*tasks, return_exceptions=True)
        st = srv.stats()
        await srv.aclose()
        return outs, st, batch_rej

    outs, st, batch_rej = asyncio.run(go())
    gate.set()
    shed = [o for o in outs if isinstance(o, Overloaded)]
    served = [o for o in outs if isinstance(o, Served)]
    assert len(shed) + len(served) == 12      # every request resolved
    assert len(shed) >= 1 and len(served) >= 1
    assert st["shed"] == len(shed) + 1        # + the explicit batch probe
    assert batch_rej is not None and "batch class shed" in batch_rej


def test_degradation_ladder_serves_and_recovers():
    async def go():
        res = ResilienceConfig(max_queue=64, degrade_high_frac=0.05,
                               degrade_low_frac=0.01, degrade_hold=2,
                               watchdog_interval_s=0.02)
        srv = AsyncRetrievalServer(
            _fake_search,
            ServeConfig(max_batch=2, max_wait_ms=0.2, max_inflight=1,
                        resilience=res),
            degraded_fns=(_fake_degraded,))
        srv.fault_injector.arm("compute", latency_s=0.01, times=1000)
        burst = await asyncio.gather(*[srv.query(*Q) for _ in range(40)],
                                     return_exceptions=True)
        st_hot = srv.stats()
        srv.fault_injector.clear()
        # trickle: calm observations step the ladder back to level 0
        for _ in range(30):
            out = await srv.query(*Q)
            if out.level == 0 and srv.stats()["degrade_level"] == 0:
                break
            await asyncio.sleep(0.02)
        st_calm = srv.stats()
        await srv.aclose()
        return burst, st_hot, st_calm

    burst, st_hot, st_calm = asyncio.run(go())
    served = [o for o in burst if isinstance(o, Served)]
    assert len(served) == 40                  # nothing hung, nothing lost
    # the burst pushed the controller past level 0 and level-1 responses
    # went out tagged (and came from the degraded function: scores -1)
    degraded = [o for o in served if o.level == 1]
    assert degraded and st_hot["level_served"].get(1, 0) == len(degraded)
    assert all(np.all(np.asarray(o[0]) == -1.0) for o in degraded)
    assert st_calm["degrade_level"] == 0      # recovered after the burst


# ---------------------------------------------------------------------------
# Chaos: fault injection at each site, watchdog, crash-safe persistence
# ---------------------------------------------------------------------------

@chaos
def test_chaos_stage_fault_isolated_sentry_unchanged():
    """An injected host-staging failure fails exactly its own batch; the
    dispatcher survives, later queries succeed, and the recompile
    sentry's signature set is untouched (satellite: staging isolation
    under FaultInjector)."""
    async def go():
        srv = AsyncRetrievalServer(
            _fake_search,
            ServeConfig(max_batch=2, max_wait_ms=0.5, guard_recompiles=True,
                        resilience=ResilienceConfig()))
        srv.warm_shapes(*Q)
        sigs_before = set(srv.recompile_sentry.signatures)
        srv.fault_injector.arm("stage")
        with pytest.raises(FaultInjected):
            await srv.query(*Q)
        assert srv.stats()["watchdog_restarts"] == 0  # dispatcher survived
        out = await srv.query(*Q)
        assert isinstance(out, Served)
        assert set(srv.recompile_sentry.signatures) == sigs_before
        await srv.aclose()

    asyncio.run(go())


@chaos
@pytest.mark.parametrize("site", ["compute", "fanout"])
def test_chaos_compute_and_fanout_faults_contained(site):
    async def go():
        srv = AsyncRetrievalServer(
            _fake_search,
            ServeConfig(max_batch=2, max_wait_ms=0.5,
                        resilience=ResilienceConfig()))
        srv.fault_injector.arm(site)
        with pytest.raises(FaultInjected):
            await srv.query(*Q)
        out = await srv.query(*Q)             # server fully functional
        assert isinstance(out, Served)
        assert srv.stats()["watchdog_restarts"] == 0
        await srv.aclose()

    asyncio.run(go())


@chaos
def test_chaos_dispatcher_death_watchdog_restarts():
    """A fault at the dispatch site kills the coalescing loop itself. The
    watchdog restarts it and fails the claimed request with a terminal
    DispatcherFailed instead of letting it hang."""
    async def go():
        srv = AsyncRetrievalServer(
            _fake_search,
            ServeConfig(max_batch=2, max_wait_ms=0.5,
                        resilience=ResilienceConfig(
                            watchdog_interval_s=0.02)))
        srv.fault_injector.arm("dispatch")
        with pytest.raises(DispatcherFailed, match="restarted by watchdog"):
            await srv.query(*Q)
        out = await srv.query(*Q)             # restarted loop serves again
        assert isinstance(out, Served)
        assert srv.stats()["watchdog_restarts"] == 1
        await srv.aclose()

    asyncio.run(go())


@chaos
def test_chaos_dispatcher_hang_watchdog_restarts():
    """A dispatcher stuck past stall_timeout_s with claimed work is
    cancelled and restarted; its claimed request gets DispatcherFailed."""
    gate = threading.Event()

    def stalled(q, qm, qs):
        gate.wait(10.0)
        return _fake_search(q, qm, qs)

    async def go():
        srv = AsyncRetrievalServer(
            stalled,
            ServeConfig(max_batch=1, max_wait_ms=0.2, max_inflight=1,
                        resilience=ResilienceConfig(
                            watchdog_interval_s=0.05,
                            stall_timeout_s=0.3)))
        # A occupies the only compute slot; B gets claimed and the
        # dispatcher blocks acquiring an in-flight slot -> heartbeat stale
        task_a = asyncio.ensure_future(srv.query(*Q))
        await asyncio.sleep(0.05)
        task_b = asyncio.ensure_future(srv.query(*Q))
        with pytest.raises(DispatcherFailed, match="hung"):
            await task_b
        gate.set()
        out_a = await task_a                  # in-flight batch still lands
        assert isinstance(out_a, Served)
        assert srv.stats()["watchdog_restarts"] >= 1
        out = await srv.query(*Q)
        assert isinstance(out, Served)
        await srv.aclose()

    try:
        asyncio.run(go())
    finally:
        gate.set()


@chaos
def test_chaos_guarded_degraded_serving_stays_on_ladder():
    """Degraded levels are part of the sentry's declared signature set:
    a full warm + overload burst compiles exactly ladder x levels and
    nothing else (no off-ladder recompiles while shedding/degrading)."""
    async def go():
        res = ResilienceConfig(max_queue=64, degrade_high_frac=0.05,
                               degrade_low_frac=0.01, degrade_hold=2)
        srv = AsyncRetrievalServer(
            _fake_search,
            ServeConfig(max_batch=4, max_wait_ms=0.2, max_inflight=1,
                        guard_recompiles=True, resilience=res),
            degraded_fns=(_fake_degraded,))
        srv.warm_shapes(*Q)                   # warms every level x rung
        srv.fault_injector.arm("compute", latency_s=0.01, times=1000)
        outs = await asyncio.gather(*[srv.query(*Q) for _ in range(30)],
                                    return_exceptions=True)
        await srv.aclose()
        return srv, outs

    srv, outs = asyncio.run(go())
    assert all(isinstance(o, Served) for o in outs)
    assert {o.level for o in outs} >= {1}     # degraded serving happened
    sigs = set(srv.recompile_sentry.signatures)
    assert {s[0] for s in sigs} == set(srv.ladder)
    assert {s[-1] for s in sigs} == {0, 1}
    # exact closed set: every (rung, level) pair, nothing else
    assert len(sigs) == len(srv.ladder) * 2
    srv.recompile_sentry.check_cache_consistent()


@chaos
def test_chaos_sigkill_mid_save_leaves_loadable_index(tmp_path):
    """SIGKILL a process mid-`IndexBackend.save`: the index path must
    hold the previous complete version (atomic rename) and load clean —
    never a torn file."""
    path = str(tmp_path / "idx.npz")
    code = f"""
import numpy as np, jax.numpy as jnp
from repro.core import index as index_mod
from repro.retrieval.base import RetrieverState, get_backend
rng = np.random.default_rng(0)
emb = rng.normal(size=(256, 8, 16)).astype(np.float32)
mask = np.ones((256, 8), bool)
ff = index_mod.build_float_flat(jnp.asarray(emb), jnp.asarray(mask))
state = RetrieverState(codebook=jnp.zeros((4, 16), jnp.float32),
                       backend_state=ff,
                       rerank_codes=jnp.zeros((256, 8), jnp.uint8),
                       rerank_mask=jnp.asarray(mask))
b = get_backend("float_flat")
i = 0
while True:
    b.save({path!r}, state)
    i += 1
    print("SAVED", i, flush=True)
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..",
                                      "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.Popen([sys.executable, "-c", code], env=env,
                            stdout=subprocess.PIPE, text=True)
    try:
        # wait for at least one committed save, then kill mid-loop
        line = proc.stdout.readline()
        assert line.startswith("SAVED"), line
        for _ in range(3):
            proc.stdout.readline()
        os.kill(proc.pid, signal.SIGKILL)
        proc.wait(timeout=30)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=30)
    from repro.retrieval.base import get_backend
    state = get_backend("float_flat").load(path)   # previous complete save
    assert state.rerank_codes.shape == (256, 8)


@chaos
def test_chaos_corrupt_index_fails_with_named_array(tmp_path):
    import jax.numpy as jnp
    from repro.core import index as index_mod
    from repro.retrieval.base import RetrieverState, get_backend
    emb = np.random.default_rng(0).normal(size=(32, 4, 8)).astype(
        np.float32)
    mask = np.ones((32, 4), bool)
    ff = index_mod.build_float_flat(jnp.asarray(emb), jnp.asarray(mask))
    state = RetrieverState(codebook=jnp.zeros((4, 8), jnp.float32),
                           backend_state=ff,
                           rerank_codes=jnp.zeros((32, 4), jnp.uint8),
                           rerank_mask=jnp.asarray(mask))
    backend = get_backend("float_flat")
    path = backend.save(str(tmp_path / "idx"), state)
    # flip bits in one leaf but keep the stored checksums: load must name
    # the corrupt array, never return silently-bad data
    with np.load(path) as z:
        payload = {k: z[k] for k in z.files}
    bad = payload["leaf_0001"].copy()
    bad.flat[0] += 1
    payload["leaf_0001"] = bad
    np.savez(path, **payload)
    with pytest.raises(ValueError, match="leaf_0001"):
        backend.load(path)
    # a v2-style file (no checksums key) still loads: nothing to verify
    del payload["checksums"]
    payload["leaf_0001"] = bad
    payload["format_version"] = np.asarray(2, np.int64)
    np.savez(path, **payload)
    backend.load(path)


@chaos
def test_chaos_corrupt_checkpoint_fails_with_named_leaf(tmp_path):
    from repro.ckpt import checkpoint as ckpt
    tree = {"w": np.arange(16, dtype=np.float32).reshape(4, 4),
            "b": np.ones((4,), np.float32)}
    path = ckpt.save(str(tmp_path), 1, tree)
    restored = ckpt.restore(path, tree)
    np.testing.assert_array_equal(np.asarray(restored["w"]), tree["w"])
    npz_path = os.path.join(path, "arrays.npz")
    with np.load(npz_path) as z:
        arrays = {k: z[k] for k in z.files}
    key = sorted(arrays)[0]
    arrays[key] = arrays[key] + 1             # corrupt one leaf on disk
    np.savez(npz_path, **arrays)
    with pytest.raises(ValueError, match="checksum mismatch on leaf"):
        ckpt.restore(path, tree)


@chaos
def test_chaos_sigkill_mid_checkpoint_previous_step_restores(tmp_path):
    from repro.ckpt import checkpoint as ckpt
    code = f"""
import numpy as np
from repro.ckpt import checkpoint as ckpt
tree = {{"w": np.zeros((256, 256), np.float32)}}
step = 0
while True:
    step += 1
    ckpt.save({str(tmp_path)!r}, step, tree)
    print("STEP", step, flush=True)
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..",
                                      "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.Popen([sys.executable, "-c", code], env=env,
                            stdout=subprocess.PIPE, text=True)
    try:
        assert proc.stdout.readline().startswith("STEP")
        proc.stdout.readline()
        os.kill(proc.pid, signal.SIGKILL)
        proc.wait(timeout=30)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=30)
    step = ckpt.latest_step(str(tmp_path))
    assert step is not None                   # some step fully committed
    tree = {"w": np.zeros((256, 256), np.float32)}
    restored = ckpt.restore(
        os.path.join(str(tmp_path), f"step_{step:08d}"), tree)
    assert np.asarray(restored["w"]).shape == (256, 256)


@chaos
def test_chaos_overload_drill_every_request_resolves():
    """Mini overload drill (the full curve runs in benchmarks/latency.py):
    a 4x-ish burst with deadlines sheds/serves/expires every request —
    zero hung — and the ladder recovers to level 0 afterwards."""
    async def go():
        res = ResilienceConfig(max_queue=16, shed_batch_frac=0.5,
                               degrade_high_frac=0.25,
                               degrade_low_frac=0.05, degrade_hold=2,
                               default_deadline_ms=2000.0,
                               watchdog_interval_s=0.02)
        srv = AsyncRetrievalServer(
            _fake_search,
            ServeConfig(max_batch=4, max_wait_ms=0.2, max_inflight=1,
                        resilience=res),
            degraded_fns=(_fake_degraded,))
        srv.fault_injector.arm("compute", latency_s=0.02, times=10_000)
        tasks = []
        for _ in range(120):
            tasks.append(asyncio.ensure_future(srv.query(*Q)))
            await asyncio.sleep(0.0005)       # ~4x the sustainable rate
        outs = await asyncio.gather(*tasks, return_exceptions=True)
        srv.fault_injector.clear()
        level = None
        for _ in range(50):
            out = await srv.query(*Q, deadline_ms=5000.0)
            level = srv.stats()["degrade_level"]
            if out.level == 0 and level == 0:
                break
            await asyncio.sleep(0.02)
        st = srv.stats()
        await srv.aclose()
        return outs, st, level

    outs, st, level = asyncio.run(go())
    served = [o for o in outs if isinstance(o, Served)]
    shed = [o for o in outs if isinstance(o, Overloaded)]
    expired = [o for o in outs if isinstance(o, DeadlineExceeded)]
    assert len(served) + len(shed) + len(expired) == 120  # zero hung
    assert served and shed                    # overload actually shed
    assert level == 0                         # recovered post-burst
    assert st["watchdog_restarts"] == 0
