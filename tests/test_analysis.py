"""Tests for the `repro.analysis` static-analysis subsystem.

Covers the three engines tools/jaxlint.py drives (docs/design.md §8):

  * AST lints — planted-violation fixtures in tests/fixtures/lint assert
    the JAX01-JAX04 rules fire at the exact (file, line, code), and the
    ruff-fallback rules (E9/F401/F541/F811) + noqa semantics are checked
    on inline sources.
  * Jaxpr budget manifests — the registry is sane, a clean manifest
    analyzes clean, and the acceptance case: deliberately unblocking the
    flat scan (the O(N*Mq*Md) ADC gather) is rejected.
  * Recompile sentry — signature counting, the allowed/expected gates,
    cache-consistency cross-check, and the serving integration
    (`ServeConfig.guard_recompiles`).
"""
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (BudgetManifest, RecompileGuardError,
                            RecompileSentry, analyze_manifest, check_source,
                            get_manifest, ladder_signatures, manifests,
                            run_paths)
from repro.analysis.astchecks import JAX_RULES
from repro.analysis.lintcore import RUFF_FALLBACK_RULES

FIXTURES = Path(__file__).parent / "fixtures" / "lint"


# --- AST lints: planted fixtures ------------------------------------------

def test_planted_fixtures_fire_at_exact_locations():
    findings = run_paths([FIXTURES], tuple(RUFF_FALLBACK_RULES) + JAX_RULES)
    got = {(Path(f.path).name, f.line, f.code) for f in findings}
    assert got == {
        ("jax01_key_reuse.py", 8, "JAX01"),
        ("jax02_host_sync.py", 7, "JAX02"),
        ("jax03_missing_static.py", 6, "JAX03"),
        ("jax04_bare_topk.py", 6, "JAX04"),
        ("jax05_async_sync.py", 8, "JAX05"),
        ("jax05_async_sync.py", 9, "JAX05"),
        ("jax05_async_sync.py", 10, "JAX05"),
    }, sorted(map(str, findings))


def test_noqa_suppressed_fixture_line_stays_silent():
    # jax04_bare_topk.py line 10 carries `# noqa: JAX04` — the suppressed
    # call must not appear even though line 6's identical call does
    findings = run_paths([FIXTURES / "jax04_bare_topk.py"], JAX_RULES)
    assert [f.line for f in findings] == [6]


# --- AST lints: fallback rules + noqa semantics ---------------------------

def test_f401_resolves_all_from_ast_not_text():
    exported = 'import os\n\n__all__ = ["os"]\n'
    assert check_source("m.py", exported, RUFF_FALLBACK_RULES) == []
    # merely *mentioning* __all__ in a string must not exempt the import
    textual = 'import os\n\nX = "see __all__ for exports"\n'
    findings = check_source("m.py", textual, RUFF_FALLBACK_RULES)
    assert [(f.line, f.code) for f in findings] == [(1, "F401")]


def test_noqa_is_code_specific():
    rules = RUFF_FALLBACK_RULES
    assert check_source("m.py", "import os  # noqa: F401\n", rules) == []
    assert check_source("m.py", "import os  # noqa\n", rules) == []
    # a noqa naming a *different* code does not suppress F401
    findings = check_source("m.py", "import os  # noqa: F811\n", rules)
    assert [(f.line, f.code) for f in findings] == [(1, "F401")]


def test_fallback_rules_e9_f541_f811():
    rules = RUFF_FALLBACK_RULES
    assert [f.code for f in check_source("m.py", "def broken(:\n", rules)] \
        == ["E9"]
    findings = check_source("m.py", 'x = f"static"\n', rules)
    assert [(f.line, f.code) for f in findings] == [(1, "F541")]
    dup = "def a():\n    pass\n\n\ndef a():\n    pass\n"
    findings = check_source("m.py", dup, rules)
    assert [(f.line, f.code) for f in findings] == [(5, "F811")]


# --- jaxpr budget manifests -----------------------------------------------

def test_manifest_registry_is_sorted_and_complete():
    names = [m.name for m in manifests()]
    assert names == sorted(names)
    assert {"search_flat", "search_float_flat", "search_hamming",
            "search_ivf", "search_hnsw", "retriever_rerank"} <= set(names)
    assert len(names) >= 10
    with pytest.raises(KeyError):
        get_manifest("no_such_entry_point")


def test_hamming_manifest_clean_with_int32_contract():
    m = get_manifest("scan_hamming")
    assert m.out_dtypes == (jnp.int32, jnp.int32)
    assert analyze_manifest(m) == []


def test_unblocked_scan_is_rejected():
    """Acceptance: swap the blocked scan for the naive one-shot ADC path
    and the analyzer must flag the O(N) blowup (the (B, Mq, N, Md) gather
    is ~2 KB/doc against a 16 B/doc allowance)."""
    from repro.core import late_interaction as li

    def trace(n):
        sds = jax.ShapeDtypeStruct
        qe = sds((8, 8, 16), jnp.float32)
        qm = sds((8, 8), jnp.bool_)
        codes = sds((n, 16), jnp.uint8)
        mask = sds((n, 16), jnp.bool_)
        cb = sds((256, 16), jnp.float32)

        def fn(qe, qm, codes, mask, cb):
            scores = li.quantized_maxsim(qe, qm, codes, mask, cb)
            return jax.lax.top_k(scores, 16)  # noqa: JAX04 - fixture trace
        return fn, (qe, qm, codes, mask, cb)

    m = BudgetManifest(name="unblocked_flat", trace=trace,
                       out_dtypes=None, n=1 << 14, n_alt=1 << 13)
    violations = analyze_manifest(m)
    assert violations, "the unblocked gather must not pass the budget"
    assert any(v.kind == "n_scaling" for v in violations)
    assert all(v.manifest == "unblocked_flat" for v in violations)


# --- recompile sentry ------------------------------------------------------

def test_sentry_counts_distinct_signatures():
    sentry = RecompileSentry(lambda x: x, name="t",
                             key_fn=lambda x: tuple(x.shape))
    a = jnp.zeros((2, 3))
    sentry(a)
    sentry(a)                      # repeat call mints nothing
    assert sentry.calls == 2 and len(sentry.signatures) == 1
    sentry(jnp.zeros((4, 3)))
    sentry.assert_signatures({(2, 3), (4, 3)})
    with pytest.raises(RecompileGuardError, match="mismatch"):
        sentry.assert_signatures({(2, 3)})


def test_sentry_allowed_gate_rejects_before_recording():
    sentry = RecompileSentry(lambda x: x, name="t",
                             key_fn=lambda x: tuple(x.shape),
                             allowed=lambda k: k[0] in (1, 2))
    sentry(jnp.zeros((2, 3)))
    with pytest.raises(RecompileGuardError, match="rejected"):
        sentry(jnp.zeros((5, 3)))
    # the rejected call never reached the jit cache: not recorded either
    assert set(sentry.signatures) == {(2, 3)}


def test_sentry_expected_and_max_signatures():
    sentry = RecompileSentry(lambda x: x, key_fn=lambda x: tuple(x.shape),
                             expected={(1,), (2,)})
    sentry(jnp.zeros((1,)))
    with pytest.raises(RecompileGuardError, match="unexpected signature"):
        sentry(jnp.zeros((3,)))

    capped = RecompileSentry(lambda x: x, key_fn=lambda x: tuple(x.shape),
                             max_signatures=2)
    capped(jnp.zeros((1,)))
    capped(jnp.zeros((2,)))
    with pytest.raises(RecompileGuardError, match="max_signatures"):
        capped(jnp.zeros((3,)))


def test_sentry_cache_consistency_catches_key_leak():
    @jax.jit
    def f(x):
        return x + 1

    # keyed on shape only: a dtype flip splits the jit cache underneath
    sentry = RecompileSentry(f, key_fn=lambda x: tuple(x.shape))
    sentry(jnp.ones((2,), jnp.float32))
    assert sentry.check_cache_consistent() == 1
    sentry(jnp.ones((2,), jnp.int32))
    with pytest.raises(RecompileGuardError, match="splitting the cache"):
        sentry.check_cache_consistent()


def test_ladder_signatures():
    assert ladder_signatures((1, 2, 4), 8) == {(1, 8), (2, 8), (4, 8)}
    assert ladder_signatures((1, 2), (8, 16)) == {
        (1, 8), (1, 16), (2, 8), (2, 16)}


def test_server_guard_recompiles_closed_rung_set():
    from repro.serving.server import RetrievalServer, ServeConfig

    @jax.jit
    def search_stub(q, qm, qs):
        b = q.shape[0]
        return jnp.zeros((b, 4), jnp.float32), jnp.zeros((b, 4), jnp.int32)

    cfg = ServeConfig(max_batch=4, top_k=4, guard_recompiles=True)
    server = RetrievalServer(search_stub, cfg)
    try:
        server.warm_shapes(np.zeros((8, 16), np.float32),
                           np.ones((8,), bool),
                           np.zeros((8,), np.float32))
        report = server.recompile_report()
        assert report["n_signatures"] == len(server.ladder)
        rung_bs = {sig[0] for sig in server.recompile_sentry.signatures}
        assert rung_bs == set(server.ladder)
        # an off-ladder batch raises instead of minting a new compile
        with pytest.raises(RecompileGuardError, match="rejected"):
            server.recompile_sentry(
                jnp.zeros((3, 8, 16), jnp.float32),
                jnp.ones((3, 8), bool),
                jnp.zeros((3, 8), jnp.float32))
        server.recompile_sentry.check_cache_consistent()
    finally:
        server.close()
