"""Optional-hypothesis shim: real hypothesis when installed, a tiny
deterministic fallback otherwise.

The CI sandbox has no network, so `hypothesis` may be missing; test
collection must not hard-fail. Property tests import `given`/`settings`/
`st` from here. With hypothesis installed they run unchanged; without it
each strategy degrades to a small fixed sample set (endpoints + interior
points) and `given` loops over them — a smoke sweep instead of a real
property search, but the same assertions execute.
"""
try:
    import hypothesis  # noqa: F401
    import hypothesis.strategies as st  # noqa: F401 - re-export
    from hypothesis import given, settings  # noqa: F401 - re-export
    HAVE_HYPOTHESIS = True
except ImportError:                                     # fallback shim
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, samples):
            self.samples = list(samples)

        def map(self, fn):
            return _Strategy([fn(s) for s in self.samples])

        def filter(self, fn):
            return _Strategy([s for s in self.samples if fn(s)])

    class _St:
        @staticmethod
        def integers(min_value=0, max_value=100):
            lo, hi = int(min_value), int(max_value)
            span = hi - lo
            vals = {lo, hi, lo + span // 2, lo + span // 3,
                    lo + (2 * span) // 3, lo + 1 if span else lo}
            return _Strategy(sorted(v for v in vals if lo <= v <= hi))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            lo, hi = float(min_value), float(max_value)
            mid = (lo + hi) / 2
            return _Strategy([lo, mid, (lo + mid) / 2, (mid + hi) / 2, hi])

        @staticmethod
        def sampled_from(seq):
            return _Strategy(list(seq))

        @staticmethod
        def booleans():
            return _Strategy([False, True])

    st = _St()

    def settings(*_a, **_kw):
        def deco(fn):
            return fn
        return deco

    def given(**strategies):
        names = list(strategies)

        def deco(fn):
            samples = [strategies[n].samples for n in names]
            n_cases = max(len(s) for s in samples)

            def wrapper():
                for i in range(n_cases):
                    case = {n: samples[j][i % len(samples[j])]
                            for j, n in enumerate(names)}
                    fn(**case)

            # keep the original name for pytest reporting, but NOT the
            # original signature (functools.wraps would make pytest treat
            # the strategy kwargs as fixtures)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco
