"""Tests for the jaxpr cost model (repro.analysis.cost_model).

Two layers:

  * Ground truth — closed-form FLOP/byte counts for the two scoring
    primitives the paper's efficiency argument rests on
    (`quantized_maxsim`, `binary_maxsim`) must match the jaxpr walk
    EXACTLY at several small shapes. Every term in the formulas is
    derived in-line from the traced primitive sequence, so a silent
    change to either the scoring code or the cost rules breaks these.
  * Gates — the acceptance case: deliberately unblocking the flat scan
    (the O(N*Mq*Md) ADC gather materialized at full corpus width) must
    be rejected both by the declared CostContract and by drift vs the
    committed COST_baseline.json, with the offending primitives named
    in the violation text.
"""
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.cost_model import (RESIDENT_BYTES, CostContract,
                                       RooflineSpec, check_against_baseline,
                                       classify_bound, closed_jaxpr_cost,
                                       cost_report, load_baseline,
                                       write_baseline)
from repro.analysis.manifests import BudgetManifest, get_manifest
from repro.core import late_interaction as li

sds = jax.ShapeDtypeStruct


# --- ground truth: quantized (ADC) scoring --------------------------------

def _qmaxsim_closed(B, Mq, D, K, N, Md):
    closed = jax.make_jaxpr(li.quantized_maxsim)(
        sds((B, Mq, D), jnp.float32), sds((B, Mq), jnp.bool_),
        sds((N, Md), jnp.uint8), sds((N, Md), jnp.bool_),
        sds((K, D), jnp.float32))
    return closed_jaxpr_cost(closed)


@pytest.mark.parametrize("B,Mq,D,K,N,Md", [
    (2, 3, 4, 5, 7, 2),       # all-distinct primes-ish: catches axis swaps
    (1, 4, 8, 16, 5, 3),
    (3, 2, 16, 32, 9, 4),
])
def test_quantized_maxsim_flops_match_closed_form(B, Mq, D, K, N, Md):
    cost = _qmaxsim_closed(B, Mq, D, K, N, Md)
    # traced primitive sequence (one term per FLOP-bearing eqn):
    #   dot_general  table = q @ cb.T           2*B*Mq*K*D
    #   lt/add/select_n  wraparound of int idx  3*N*Md
    #   select_n (mask) + reduce_max            2*B*N*Mq*Md
    #   mul (q_mask) + reduce_sum               2*B*N*Mq
    want = 2 * B * Mq * K * D + 3 * N * Md \
        + 2 * B * N * Mq * Md + 2 * B * N * Mq
    assert cost.flops == want
    # the ADC defining property: zero matmul FLOPs scale with N
    assert cost.prim_flops["dot_general"] == 2 * B * Mq * K * D


@pytest.mark.parametrize("B,Mq,D,K,N,Md", [
    (2, 3, 4, 5, 7, 2),
    (1, 4, 8, 16, 5, 3),
    (3, 2, 16, 32, 9, 4),
])
def test_quantized_maxsim_bytes_match_closed_form(B, Mq, D, K, N, Md):
    cost = _qmaxsim_closed(B, Mq, D, K, N, Md)
    # materializing intermediates:
    #   dot_general table (B, Mq, K) f32
    #   convert_element_type: codes->i32 (N, Md), the where fill scalar,
    #     and q_mask->f32 (B, 1, Mq)
    # the (B, Mq, N, Md) gather is NOT charged at small N (fuses into
    # its reduction below resident_bytes) — that is the design point the
    # unblocked-rejection test below exercises from the other side.
    inter = 4 * B * Mq * K + (4 * N * Md + 4 + 4 * B * Mq)
    inputs = 4 * B * Mq * D + B * Mq + N * Md + N * Md + 4 * K * D
    outputs = 4 * B * N
    assert cost.bytes == inter + inputs + outputs
    assert cost.prim_bytes["<inputs>"] == inputs
    assert cost.prim_bytes["<outputs>"] == outputs
    assert "gather" not in cost.prim_bytes


# --- ground truth: binary (hamming) scoring -------------------------------

def _binary_closed(B, Mq, N, Md):
    def fn(qc, qm, dc, dm):
        return li.binary_maxsim(qc, qm, dc, dm, 8)
    closed = jax.make_jaxpr(fn)(
        sds((B, Mq), jnp.int32), sds((B, Mq), jnp.bool_),
        sds((N, Md), jnp.int32), sds((N, Md), jnp.bool_))
    return closed_jaxpr_cost(closed)


@pytest.mark.parametrize("B,Mq,N,Md", [
    (2, 3, 7, 2),
    (1, 4, 5, 3),
    (3, 2, 9, 4),
])
def test_binary_maxsim_cost_matches_closed_form(B, Mq, N, Md):
    cost = _binary_closed(B, Mq, N, Md)
    # FLOPs: byte-masking `and` on each side (B*Mq + N*Md), then
    # xor + popcount + sub + mask-select + reduce_max over the full
    # (B, N, Mq, Md) sim tensor, then mul + reduce_sum over (B, N, Mq)
    want_flops = (B * Mq + N * Md) + 5 * B * N * Mq * Md + 2 * B * N * Mq
    assert cost.flops == want_flops
    assert cost.prim_flops["population_count"] == B * N * Mq * Md
    # bytes: converts (codes->u32 both sides, popcount->i32 at full sim
    # width, q_mask->i32) + inputs + the (B, N) i32 output
    inter = 4 * (2 * B * Mq + N * Md + B * N * Mq * Md)
    inputs = 5 * B * Mq + 5 * N * Md
    assert cost.bytes == inter + inputs + 4 * B * N


# --- roofline classification ----------------------------------------------

def test_classify_bound_straddles_ridge():
    spec = RooflineSpec("toy", peak_flops=100.0, hbm_bw=10.0)  # ridge 10
    assert spec.ridge == 10.0
    assert classify_bound(5.0, (spec,)) == {"toy": "memory"}
    assert classify_bound(50.0, (spec,)) == {"toy": "compute"}


def test_adc_flat_scan_is_memory_bound_on_tpu():
    """The paper's premise: on the accelerator the quantized scan sits
    far below the ridge intensity (it is a traffic problem, not a FLOP
    problem) — the committed baseline must agree."""
    base = load_baseline()
    assert base is not None, "COST_baseline.json must be committed"
    entry = base["entries"]["search_flat"]
    assert entry["bound"]["tpu_v5e"] == "memory"
    assert entry["intensity"] < base["rooflines"]["tpu_v5e"]["ridge"] / 10


# --- the acceptance gate: unblocked flat scan is rejected -----------------

def _unblocked_manifest(contract=None):
    """search_flat with the streaming scan swapped for the one-shot ADC
    path: the (B, Mq, N, Md) gather materializes at full corpus width."""
    def trace(n):
        qe = sds((8, 8, 16), jnp.float32)
        qm = sds((8, 8), jnp.bool_)
        codes = sds((n, 16), jnp.uint8)
        mask = sds((n, 16), jnp.bool_)
        cb = sds((256, 16), jnp.float32)

        def fn(qe, qm, codes, mask, cb):
            scores = li.quantized_maxsim(qe, qm, codes, mask, cb)
            return jax.lax.top_k(scores, 16)  # noqa: JAX04 - fixture trace
        return fn, (qe, qm, codes, mask, cb)

    return BudgetManifest(name="search_flat", trace=trace, out_dtypes=None,
                          n=1 << 15, n_alt=1 << 14, cost=contract)


def test_unblocked_search_flat_breaks_cost_contract():
    contract = get_manifest("search_flat").cost
    assert contract is not None and contract.max_bytes_per_doc is not None
    report = cost_report(_unblocked_manifest(contract))
    assert not report["ok"]
    byte_v = [v for v in report["violations"]
              if "bytes_per_doc" in v["detail"]]
    assert byte_v, report["violations"]
    # the violation names the offending primitive chain
    assert "gather" in byte_v[0]["detail"]
    # at n = 2**15 the (8, 8, n, 16) f32 sim tensor is 128 MiB > the
    # 64 MiB residency envelope: charged in full
    assert report["prim_bytes"]["gather"] >= 8 * 8 * (1 << 15) * 16 * 4


def test_unblocked_search_flat_drifts_from_committed_baseline():
    baseline = load_baseline()
    assert baseline is not None, "COST_baseline.json must be committed"
    report = cost_report(_unblocked_manifest())
    drift = check_against_baseline([report], baseline)
    drifted = {v.detail.split()[0] for v in drift if v.kind == "drift"}
    assert {"hbm_bytes", "bytes_per_doc"} <= drifted, drift
    named = [v for v in drift if "gather" in v.detail]
    assert named, "drift must name the gather as the offending primitive"


def test_registered_search_flat_matches_committed_baseline():
    """The committed artifact gates the real path: re-pricing the
    registered search_flat manifest today must sit inside tolerance."""
    baseline = load_baseline()
    assert baseline is not None
    report = cost_report(get_manifest("search_flat"))
    assert report["ok"], report["violations"]
    only = {"entries": {"search_flat": baseline["entries"]["search_flat"]}}
    assert check_against_baseline([report], only) == []


# --- baseline artifact I/O and drift mechanics ----------------------------

def test_baseline_roundtrip_and_missing_entries(tmp_path):
    report = cost_report(_unblocked_manifest())
    p = write_baseline([report], tmp_path / "COST_baseline.json")
    base = load_baseline(p)
    assert base["schema"] == 1
    assert base["resident_bytes"] == RESIDENT_BYTES
    # identical re-run: no drift
    assert check_against_baseline([report], base) == []
    # a manifest absent from the baseline is flagged, and a stale
    # baseline entry with no live manifest is flagged the other way
    other = dict(report, manifest="brand_new_path")
    viol = check_against_baseline([other], base)
    kinds = {(v.manifest, v.kind) for v in viol}
    assert ("brand_new_path", "baseline") in kinds
    assert ("search_flat", "baseline") in kinds


def test_drift_tolerance_band():
    report = cost_report(_unblocked_manifest())
    base = {"entries": {"search_flat": {
        k: report[k] for k in ("flops", "hbm_bytes", "flops_per_doc",
                               "bytes_per_doc", "prim_flops", "prim_bytes")
    }}}
    inflated = dict(report, flops=report["flops"] * 1.08)
    assert check_against_baseline([inflated], base) == []  # inside 10%
    inflated = dict(report, flops=report["flops"] * 1.12)
    viol = check_against_baseline([inflated], base)
    assert [v.kind for v in viol] == ["drift"]
    # improvements never fail
    improved = dict(report, flops=report["flops"] * 0.5,
                    hbm_bytes=report["hbm_bytes"] * 0.5)
    assert check_against_baseline([improved], base) == []


def test_contract_dataclass_is_optional_per_axis():
    m = _unblocked_manifest(CostContract(max_flops_per_doc=1e12))
    report = cost_report(m)
    assert report["ok"]  # byte axis undeclared -> not gated


def test_baseline_file_is_committed_at_repo_root():
    from repro.analysis.cost_model import BASELINE_PATH
    assert BASELINE_PATH.name == "COST_baseline.json"
    assert BASELINE_PATH.exists()
    assert (Path(__file__).resolve().parents[1] / "COST_baseline.json"
            == BASELINE_PATH)
