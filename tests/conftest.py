import os
import subprocess
import sys

import jax
import pytest

# Tests run on the single real CPU device; multi-device tests spawn
# subprocesses with XLA_FLAGS (never set the flag here — see dryrun.py).
jax.config.update("jax_enable_x64", False)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Run `code` in a fresh python with n_devices virtual CPU devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.abspath(SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)
