"""Network-free lint fallback for the `ruff check` rule classes CI gates.

  python tools/astlint.py [paths...]

The CI `lint` job runs ruff (pip-installed there); sandboxes without
network can't, so this gates the highest-signal subset on the stdlib AST:
syntax errors (E9), unused imports (F401), duplicate top-level
definitions (F811), and f-strings without placeholders (F541).

This is a thin shim over the shared framework in
`repro.analysis.lintcore` — the same Rule objects the `analysis` CI job
drives through tools/jaxlint.py, so the fallback and the framework
cannot drift. `# noqa` suppression follows ruff semantics: bare noqa
kills every code on the line, `# noqa: F401` only the named ones, and
F401 resolves re-exports from the parsed `__all__` list (not a textual
scan of the source). Exit code 1 if any finding.
"""

from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.analysis.lintcore import (  # noqa: E402
    DEFAULT_PATHS,
    RUFF_FALLBACK_RULES,
    iter_py_files,
    run_paths,
)


def main(argv) -> int:
    paths = list(argv) or list(DEFAULT_PATHS)
    findings = run_paths(paths, RUFF_FALLBACK_RULES)
    for f in findings:
        print(f)
    n_files = len(iter_py_files(paths))
    print(f"astlint: {n_files} files, {len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
