"""Network-free lint fallback for the `ruff check` rule classes CI gates.

  python tools/astlint.py [paths...]

The CI `lint` job runs ruff (pip-installed there); sandboxes without
network can't, so this implements the highest-signal subset on the
stdlib AST: syntax errors (E9), unused imports (F401), duplicate
top-level definitions (F811), and f-strings without placeholders (F541).
A `# noqa` comment on the flagged line suppresses it, same as ruff.
Exit code 1 if any finding.
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

DEFAULT_PATHS = ["src", "tests", "benchmarks", "examples", "tools"]


def _noqa_lines(source: str) -> set:
    return {i + 1 for i, ln in enumerate(source.splitlines())
            if "# noqa" in ln}


def _used_names(tree: ast.AST) -> set:
    used = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            # record the root of dotted access: np.zeros -> np
            n = node
            while isinstance(n, ast.Attribute):
                n = n.value
            if isinstance(n, ast.Name):
                used.add(n.id)
    return used


def check_file(path: Path) -> list:
    source = path.read_text()
    findings = []
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as e:
        return [(path, e.lineno or 0, "E9", f"syntax error: {e.msg}")]
    noqa = _noqa_lines(source)
    used = _used_names(tree)
    has_all = "__all__" in source
    # format specs (f"{x:8.3f}") parse as nested JoinedStr nodes with no
    # FormattedValue of their own — they are not F541
    spec_ids = {id(node.format_spec) for node in ast.walk(tree)
                if isinstance(node, ast.FormattedValue) and node.format_spec}

    for node in ast.walk(tree):
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            if node.lineno in noqa:
                continue
            if isinstance(node, ast.ImportFrom) and \
                    node.module == "__future__":
                continue
            for alias in node.names:
                name = (alias.asname or alias.name).split(".")[0]
                if alias.name == "*" or has_all:
                    continue
                if name not in used:
                    findings.append(
                        (path, node.lineno, "F401",
                         f"unused import: {alias.asname or alias.name}"))
        elif isinstance(node, ast.JoinedStr) and id(node) not in spec_ids:
            if node.lineno not in noqa and not any(
                    isinstance(v, ast.FormattedValue) for v in node.values):
                findings.append((path, node.lineno, "F541",
                                 "f-string without placeholders"))

    seen = {}
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            if node.name in seen and node.lineno not in noqa:
                findings.append(
                    (path, node.lineno, "F811",
                     f"redefinition of {node.name!r} "
                     f"(first at line {seen[node.name]})"))
            seen[node.name] = node.lineno
    return findings


def main(argv) -> int:
    roots = [Path(p) for p in (argv or DEFAULT_PATHS)]
    files = []
    for r in roots:
        files.extend(sorted(r.rglob("*.py")) if r.is_dir() else [r])
    findings = []
    for f in files:
        findings.extend(check_file(f))
    for path, line, code, msg in findings:
        print(f"{path}:{line}: {code} {msg}")
    print(f"astlint: {len(files)} files, {len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
