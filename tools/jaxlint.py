"""Static-analysis driver for the repro.analysis subsystem.

  python tools/jaxlint.py [--ast] [--jaxpr] [--recompile] [--cost]
                          [--pallas] [--github] [--json OUT.json]
                          [--write-cost-baseline] [paths...]

Engines (all run when no engine flag is given):

  --ast        AST lints: the ruff-fallback rules (E9/F401/F811/F541)
               plus the JAX-aware rules JAX01-JAX05 from
               repro.analysis.astchecks. Paths default to src,
               benchmarks and examples (tests plant deliberate
               violations as analyzer fixtures, so they are linted by
               tools/astlint.py's rule subset instead).
  --jaxpr      Memory-budget manifests: trace every registered entry
               point (all backend search_* paths, the facade rerank,
               the scan engine itself) at symbolic corpus size and
               enforce the per-entry budgets + dtype contracts from
               repro.analysis.manifests.
  --recompile  Serving-ladder compile contract: warm a jitted search
               stand-in over the default power-of-two ladder under a
               RecompileSentry and assert it compiles exactly the
               declared rung set, with a consistent jit cache.
  --cost       Jaxpr cost model: per-entry-point FLOPs, HBM traffic and
               arithmetic intensity vs the declarative roofline specs
               (repro.analysis.cost_model); gated two ways — declared
               CostContract envelopes and drift vs the committed
               COST_baseline.json (regenerate with
               --write-cost-baseline after an intentional change).
  --pallas     Pallas kernel verifier: every pl.pallas_call geometry in
               the kernel-site registry is checked statically for VMEM
               footprint, tiling divisibility, output-block coverage
               and output dtype contracts (PAL01-PAL04,
               repro.analysis.pallas_check).

--github additionally prints findings as GitHub Actions workflow
commands (::error file=...) so they render as inline PR annotations;
it switches on automatically when $GITHUB_ACTIONS is "true".

Network-free and CPU-only; --json writes the machine-readable findings
(the CI `analysis` job uploads it as an artifact). Exit code 1 on any
finding or violation.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

AST_DEFAULT_PATHS = ("src", "benchmarks", "examples")


def run_ast(paths) -> list:
    from repro.analysis.astchecks import JAX_RULES
    from repro.analysis.lintcore import RUFF_FALLBACK_RULES, run_paths

    return run_paths(paths, tuple(RUFF_FALLBACK_RULES) + tuple(JAX_RULES))


def run_jaxpr() -> list:
    from repro.analysis.jaxpr_budget import report
    from repro.analysis.manifests import manifests

    return [report(m) for m in manifests()]


def run_cost() -> tuple:
    """(reports, violations) for every manifest — contract + drift."""
    from repro.analysis.cost_model import (
        check_against_baseline,
        cost_report,
        load_baseline,
    )
    from repro.analysis.manifests import manifests

    reports = [cost_report(m) for m in manifests()]
    baseline = load_baseline()
    if baseline is None:
        from repro.analysis.cost_model import CostViolation
        drift = [CostViolation(
            "<all>", "baseline",
            "COST_baseline.json missing — generate it with "
            "`python tools/jaxlint.py --cost --write-cost-baseline`")]
    else:
        drift = check_against_baseline(reports, baseline)
    return reports, drift


def run_pallas() -> list:
    from repro.analysis.pallas_check import check_all

    return check_all()


def run_recompile() -> dict:
    """Warm the default serving ladder under a sentry; gate the rung set."""
    import jax
    import jax.numpy as jnp

    from repro.analysis.recompile import (
        RecompileGuardError,
        RecompileSentry,
        ladder_signatures,
    )
    from repro.serving.server import ServeConfig

    ladder = ServeConfig().resolved_ladder()
    mq = 8

    @jax.jit
    def search_stub(q, qm, qs):
        return jnp.sum(q, axis=(1, 2)), jnp.argsort(qm.sum(axis=1))

    def key_fn(q, qm, qs):
        return (int(q.shape[0]), int(q.shape[1]))

    sentry = RecompileSentry(search_stub, name="ladder", key_fn=key_fn)
    for b in ladder:
        for _ in range(2):  # repeat calls must not mint new signatures
            sentry(
                jnp.zeros((b, mq, 4), jnp.float32),
                jnp.ones((b, mq), bool),
                jnp.zeros((b, mq), jnp.float32),
            )
    try:
        sentry.assert_signatures(ladder_signatures(ladder, mq))
        sentry.check_cache_consistent()
        error = None
    except RecompileGuardError as e:
        error = str(e)
    return {
        "ladder": list(ladder),
        "report": sentry.report(),
        "ok": error is None,
        "error": error,
    }


def _annotate(findings, github: bool) -> None:
    """Print findings; in --github mode also as inline PR annotations."""
    for f in findings:
        print(f)
        if github:
            print(f.to_github())


def main(argv) -> int:
    ap = argparse.ArgumentParser(prog="jaxlint", description=__doc__)
    ap.add_argument("--ast", action="store_true")
    ap.add_argument("--jaxpr", action="store_true")
    ap.add_argument("--recompile", action="store_true")
    ap.add_argument("--cost", action="store_true")
    ap.add_argument("--pallas", action="store_true")
    ap.add_argument("--github", action="store_true",
                    help="emit GitHub annotations (auto in Actions)")
    ap.add_argument("--write-cost-baseline", action="store_true",
                    help="regenerate COST_baseline.json from this run")
    ap.add_argument("--json", metavar="OUT", default=None)
    ap.add_argument("paths", nargs="*", help="--ast paths")
    args = ap.parse_args(argv)
    run_all = not (args.ast or args.jaxpr or args.recompile or args.cost
                   or args.pallas)
    github = args.github or os.environ.get("GITHUB_ACTIONS") == "true"

    out: dict = {}
    failed = False

    if args.ast or run_all:
        findings = run_ast(args.paths or list(AST_DEFAULT_PATHS))
        _annotate(findings, github)
        print(f"jaxlint --ast: {len(findings)} finding(s)")
        out["ast"] = [f.to_json() for f in findings]
        failed |= bool(findings)

    if args.jaxpr or run_all:
        from repro.analysis.lintcore import Finding
        reports = run_jaxpr()
        bad = [r for r in reports if not r["ok"]]
        for r in bad:
            for v in r["violations"]:
                msg = f"[{v['manifest']}] {v['kind']}: {v['detail']}"
                print(msg)
                if github:
                    print(Finding("src/repro/analysis/manifests.py", 1,
                                  "JAXPR", msg).to_github())
        print(
            f"jaxlint --jaxpr: {len(reports)} manifest(s), "
            f"{len(bad)} violating"
        )
        out["jaxpr"] = reports
        failed |= bool(bad)

    if args.cost or run_all:
        from repro.analysis.cost_model import write_baseline
        reports, drift = run_cost()
        if args.write_cost_baseline:
            path = write_baseline(reports)
            print(f"jaxlint --cost: wrote {path}")
            drift = []  # the run IS the new baseline
        contract = [v for r in reports for v in r["violations"]]
        for v in contract:
            print(f"[{v['manifest']}] {v['kind']}: {v['detail']}")
        for d in drift:
            print(str(d))
        if github:
            from repro.analysis.lintcore import Finding
            for v in contract:
                print(Finding("src/repro/analysis/manifests.py", 1,
                              "COST", f"[{v['manifest']}] "
                              f"{v['detail']}").to_github())
            for d in drift:
                print(Finding("COST_baseline.json", 1, "COST",
                              str(d)).to_github())
        print(
            f"jaxlint --cost: {len(reports)} manifest(s), "
            f"{len(contract)} contract violation(s), "
            f"{len(drift)} drift violation(s)"
        )
        out["cost"] = {"reports": reports,
                       "drift": [d.to_json() for d in drift]}
        failed |= bool(contract) or bool(drift)

    if args.pallas or run_all:
        findings = run_pallas()
        _annotate(findings, github)
        from repro.analysis.pallas_check import kernel_sites
        print(
            f"jaxlint --pallas: {len(kernel_sites())} kernel site(s), "
            f"{len(findings)} finding(s)"
        )
        out["pallas"] = [f.to_json() for f in findings]
        failed |= bool(findings)

    if args.recompile or run_all:
        rec = run_recompile()
        if not rec["ok"]:
            print(f"jaxlint --recompile: {rec['error']}")
        print(
            f"jaxlint --recompile: ladder {rec['ladder']}, "
            f"{rec['report']['n_signatures']} signature(s), "
            f"ok={rec['ok']}"
        )
        out["recompile"] = rec
        failed |= not rec["ok"]

    out["ok"] = not failed
    if args.json:
        Path(args.json).write_text(json.dumps(out, indent=2, default=str))
        print(f"jaxlint: wrote {args.json}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
