"""Static-analysis driver for the repro.analysis subsystem.

  python tools/jaxlint.py [--ast] [--jaxpr] [--recompile]
                          [--json OUT.json] [paths...]

Engines (all run when no engine flag is given):

  --ast        AST lints: the ruff-fallback rules (E9/F401/F811/F541)
               plus the JAX-aware rules JAX01-JAX04 from
               repro.analysis.astchecks. Paths default to src,
               benchmarks and examples (tests plant deliberate
               violations as analyzer fixtures, so they are linted by
               tools/astlint.py's rule subset instead).
  --jaxpr      Memory-budget manifests: trace every registered entry
               point (all five backend search_* paths, the facade
               rerank, the scan engine itself) at symbolic corpus size
               and enforce the per-entry budgets + dtype contracts from
               repro.analysis.manifests.
  --recompile  Serving-ladder compile contract: warm a jitted search
               stand-in over the default power-of-two ladder under a
               RecompileSentry and assert it compiles exactly the
               declared rung set, with a consistent jit cache.

Network-free and CPU-only; --json writes the machine-readable findings
(the CI `analysis` job uploads it as an artifact). Exit code 1 on any
finding or violation.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

AST_DEFAULT_PATHS = ("src", "benchmarks", "examples")


def run_ast(paths) -> list:
    from repro.analysis.astchecks import JAX_RULES
    from repro.analysis.lintcore import RUFF_FALLBACK_RULES, run_paths

    return run_paths(paths, tuple(RUFF_FALLBACK_RULES) + tuple(JAX_RULES))


def run_jaxpr() -> list:
    from repro.analysis.jaxpr_budget import report
    from repro.analysis.manifests import manifests

    return [report(m) for m in manifests()]


def run_recompile() -> dict:
    """Warm the default serving ladder under a sentry; gate the rung set."""
    import jax
    import jax.numpy as jnp

    from repro.analysis.recompile import (
        RecompileGuardError,
        RecompileSentry,
        ladder_signatures,
    )
    from repro.serving.server import ServeConfig

    ladder = ServeConfig().resolved_ladder()
    mq = 8

    @jax.jit
    def search_stub(q, qm, qs):
        return jnp.sum(q, axis=(1, 2)), jnp.argsort(qm.sum(axis=1))

    def key_fn(q, qm, qs):
        return (int(q.shape[0]), int(q.shape[1]))

    sentry = RecompileSentry(search_stub, name="ladder", key_fn=key_fn)
    for b in ladder:
        for _ in range(2):  # repeat calls must not mint new signatures
            sentry(
                jnp.zeros((b, mq, 4), jnp.float32),
                jnp.ones((b, mq), bool),
                jnp.zeros((b, mq), jnp.float32),
            )
    try:
        sentry.assert_signatures(ladder_signatures(ladder, mq))
        sentry.check_cache_consistent()
        error = None
    except RecompileGuardError as e:
        error = str(e)
    return {
        "ladder": list(ladder),
        "report": sentry.report(),
        "ok": error is None,
        "error": error,
    }


def main(argv) -> int:
    ap = argparse.ArgumentParser(prog="jaxlint", description=__doc__)
    ap.add_argument("--ast", action="store_true")
    ap.add_argument("--jaxpr", action="store_true")
    ap.add_argument("--recompile", action="store_true")
    ap.add_argument("--json", metavar="OUT", default=None)
    ap.add_argument("paths", nargs="*", help="--ast paths")
    args = ap.parse_args(argv)
    run_all = not (args.ast or args.jaxpr or args.recompile)

    out: dict = {}
    failed = False

    if args.ast or run_all:
        findings = run_ast(args.paths or list(AST_DEFAULT_PATHS))
        for f in findings:
            print(f)
        print(f"jaxlint --ast: {len(findings)} finding(s)")
        out["ast"] = [f.to_json() for f in findings]
        failed |= bool(findings)

    if args.jaxpr or run_all:
        reports = run_jaxpr()
        bad = [r for r in reports if not r["ok"]]
        for r in bad:
            for v in r["violations"]:
                print(f"[{v['manifest']}] {v['kind']}: {v['detail']}")
        print(
            f"jaxlint --jaxpr: {len(reports)} manifest(s), "
            f"{len(bad)} violating"
        )
        out["jaxpr"] = reports
        failed |= bool(bad)

    if args.recompile or run_all:
        rec = run_recompile()
        if not rec["ok"]:
            print(f"jaxlint --recompile: {rec['error']}")
        print(
            f"jaxlint --recompile: ladder {rec['ladder']}, "
            f"{rec['report']['n_signatures']} signature(s), "
            f"ok={rec['ok']}"
        )
        out["recompile"] = rec
        failed |= not rec["ok"]

    out["ok"] = not failed
    if args.json:
        Path(args.json).write_text(json.dumps(out, indent=2, default=str))
        print(f"jaxlint: wrote {args.json}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
