"""Kernel-level microbenchmarks: the fused scan vs its unfused equivalents.

On CPU the Pallas interpret path is Python-slow, so the measured comparison
is ref (ADC table-gather) vs decode-then-matmul vs float scan — the HBM-
traffic argument (docs/design.md §2) is reported analytically per variant and
verified against the dry-run roofline terms for the colpali serve cell.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.core import late_interaction as li
from repro.core import quantization as quant


def flat_scan_metrics(n_docs: int = 4096, block_docs: int = 256,
                      verbose: bool = True) -> dict:
    """Wired-path timing of the streaming flat scan (core/scan.py).

    Times `index.search_flat` — the exact function every flat query
    serves through, blocked score+top-k fusion included — and reports
    per-query latency plus corpus sweep throughput. Gated by
    benchmarks/bench_gate.py (calib-normalised +-20%).
    """
    from repro.core import index as index_mod
    from repro.core.scan import ScanConfig

    B, Mq, D, Md, K = 8, 32, 128, 32, 256
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, Mq, D))
    cb = jax.random.normal(ks[1], (K, D))
    codes = jax.random.randint(ks[2], (n_docs, Md), 0, K).astype(jnp.uint8)
    qm = jnp.ones((B, Mq), bool)
    dm = jax.random.uniform(ks[3], (n_docs, Md)) > 0.1
    ix = index_mod.build_flat(codes, dm, cb)
    scan = ScanConfig(block_docs=block_docs, impl="auto")

    t = time_fn(lambda: index_mod.search_flat(ix, q, qm, k=10, scan=scan))
    ms_per_query = t * 1e3 / B
    docs_per_sec = n_docs * B / t
    if verbose:
        print(f"  flat streaming scan  N={n_docs} block={block_docs}  "
              f"{ms_per_query:.3f} ms/query  "
              f"{docs_per_sec/1e6:.2f}M docs/s")
    return {"flat_scan_ms_per_query": ms_per_query,
            "flat_scan_docs_per_sec": docs_per_sec,
            "flat_scan_n_docs": n_docs,
            "flat_scan_block_docs": block_docs}


def run(verbose: bool = True) -> List[dict]:
    key = jax.random.PRNGKey(0)
    B, Mq, D, N, Md, K = 8, 32, 128, 4096, 32, 256
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Mq, D))
    docs = jax.random.normal(ks[1], (N, Md, D))
    cb = jax.random.normal(ks[2], (K, D))
    codes = quant.quantize(docs, cb)
    qm = jnp.ones((B, Mq), bool)
    dm = jnp.ones((N, Md), bool)

    variants = {
        "float_scan": jax.jit(lambda: li.maxsim(q, qm, docs, dm)),
        "decode_then_scan": jax.jit(
            lambda: li.quantized_maxsim_decode(q, qm, codes, dm, cb)),
        "fused_adc_scan": jax.jit(
            lambda: li.quantized_maxsim(q, qm, codes, dm, cb)),
    }
    # analytic HBM bytes per scan (corpus side only)
    traffic = {
        "float_scan": N * Md * D * 4,
        "decode_then_scan": N * Md * D * 4 + N * Md,   # decoded corpus + codes
        "fused_adc_scan": N * Md,                      # codes only
    }
    rows = []
    for name, fn in variants.items():
        t = time_fn(fn)
        rows.append({"kernel": name, "ms": t * 1e3,
                     "corpus_bytes": traffic[name],
                     "traffic_ratio_vs_float": traffic["float_scan"]
                     / traffic[name]})
        if verbose:
            print(f"  {name:18s} {t*1e3:9.2f} ms   corpus-read "
                  f"{traffic[name]/1e6:8.2f} MB  "
                  f"({traffic['float_scan']/traffic[name]:5.0f}x less "
                  f"than float)")
    return rows


if __name__ == "__main__":
    run()
