"""Kernel-level microbenchmarks: the fused scan vs its unfused equivalents.

On CPU the Pallas interpret path is Python-slow, so the measured comparison
is ref (ADC table-gather) vs decode-then-matmul vs float scan — the HBM-
traffic argument (docs/design.md §2) is reported analytically per variant and
verified against the dry-run roofline terms for the colpali serve cell.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.core import late_interaction as li
from repro.core import quantization as quant


def flat_scan_metrics(n_docs: int = 4096, block_docs: int = 256,
                      verbose: bool = True) -> dict:
    """Wired-path timing of the streaming flat scan (core/scan.py).

    Times `index.search_flat` — the exact function every flat query
    serves through, blocked score+top-k fusion included — and reports
    per-query latency plus corpus sweep throughput. Gated by
    benchmarks/bench_gate.py (calib-normalised +-20%).
    """
    from repro.core import index as index_mod
    from repro.core.scan import ScanConfig

    B, Mq, D, Md, K = 8, 32, 128, 32, 256
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, Mq, D))
    cb = jax.random.normal(ks[1], (K, D))
    codes = jax.random.randint(ks[2], (n_docs, Md), 0, K).astype(jnp.uint8)
    qm = jnp.ones((B, Mq), bool)
    dm = jax.random.uniform(ks[3], (n_docs, Md)) > 0.1
    ix = index_mod.build_flat(codes, dm, cb)
    scan = ScanConfig(block_docs=block_docs, impl="auto")

    t = time_fn(lambda: index_mod.search_flat(ix, q, qm, k=10, scan=scan))
    ms_per_query = t * 1e3 / B
    docs_per_sec = n_docs * B / t
    if verbose:
        print(f"  flat streaming scan  N={n_docs} block={block_docs}  "
              f"{ms_per_query:.3f} ms/query  "
              f"{docs_per_sec/1e6:.2f}M docs/s")
    return {"flat_scan_ms_per_query": ms_per_query,
            "flat_scan_docs_per_sec": docs_per_sec,
            "flat_scan_n_docs": n_docs,
            "flat_scan_block_docs": block_docs}


def flat_scan_bytes_crosscheck(n_docs: int = 4096, block_docs: int = 256,
                               verbose: bool = True) -> dict:
    """Predicted vs measured HBM bytes/doc for the wired flat scan.

    Prices the exact `index.search_flat` computation with the static
    cost model (repro.analysis.cost_model) and cross-checks against
    XLA's own compiled cost analysis on this backend. The gate
    (bench_gate.py) pins the ratio inside [0.5, 2.0]: the analytic
    model that CI's `jaxlint --cost` drift gate trusts must stay within
    2x of what the compiler says the program actually moves.
    """
    from repro.analysis.cost_model import closed_jaxpr_cost
    from repro.core import index as index_mod
    from repro.core.scan import ScanConfig

    B, Mq, D, Md, K = 8, 32, 128, 32, 256
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, Mq, D))
    cb = jax.random.normal(ks[1], (K, D))
    codes = jax.random.randint(ks[2], (n_docs, Md), 0, K).astype(jnp.uint8)
    qm = jnp.ones((B, Mq), bool)
    dm = jax.random.uniform(ks[3], (n_docs, Md)) > 0.1
    ix = index_mod.build_flat(codes, dm, cb)
    scan = ScanConfig(block_docs=block_docs, impl="auto")

    # the corpus rides as an explicit argument so both the cost model
    # and XLA see it as an input (a closure would hide it in constvars)
    def fn(q, qm, ix):
        return index_mod.search_flat(ix, q, qm, k=10, scan=scan)

    pred = closed_jaxpr_cost(jax.make_jaxpr(fn)(q, qm, ix)).bytes
    analysis = jax.jit(fn).lower(q, qm, ix).compile().cost_analysis()
    if isinstance(analysis, (list, tuple)):   # older jax returns [dict]
        analysis = analysis[0]
    meas = float(analysis["bytes accessed"])
    pred_per_doc, meas_per_doc = pred / n_docs, meas / n_docs
    ratio = pred_per_doc / meas_per_doc if meas_per_doc else float("inf")
    if verbose:
        print(f"  flat scan bytes/doc  predicted {pred_per_doc:8.1f}  "
              f"measured {meas_per_doc:8.1f}  ratio {ratio:.2f}")
    return {"flat_scan_pred_bytes_per_doc": pred_per_doc,
            "flat_scan_meas_bytes_per_doc": meas_per_doc,
            "flat_scan_bytes_ratio": ratio}


def run(verbose: bool = True) -> List[dict]:
    key = jax.random.PRNGKey(0)
    B, Mq, D, N, Md, K = 8, 32, 128, 4096, 32, 256
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Mq, D))
    docs = jax.random.normal(ks[1], (N, Md, D))
    cb = jax.random.normal(ks[2], (K, D))
    codes = quant.quantize(docs, cb)
    qm = jnp.ones((B, Mq), bool)
    dm = jnp.ones((N, Md), bool)

    variants = {
        "float_scan": jax.jit(lambda: li.maxsim(q, qm, docs, dm)),
        "decode_then_scan": jax.jit(
            lambda: li.quantized_maxsim_decode(q, qm, codes, dm, cb)),
        "fused_adc_scan": jax.jit(
            lambda: li.quantized_maxsim(q, qm, codes, dm, cb)),
    }
    # analytic HBM bytes per scan (corpus side only)
    traffic = {
        "float_scan": N * Md * D * 4,
        "decode_then_scan": N * Md * D * 4 + N * Md,   # decoded corpus + codes
        "fused_adc_scan": N * Md,                      # codes only
    }
    rows = []
    for name, fn in variants.items():
        t = time_fn(fn)
        rows.append({"kernel": name, "ms": t * 1e3,
                     "corpus_bytes": traffic[name],
                     "traffic_ratio_vs_float": traffic["float_scan"]
                     / traffic[name]})
        if verbose:
            print(f"  {name:18s} {t*1e3:9.2f} ms   corpus-read "
                  f"{traffic[name]/1e6:8.2f} MB  "
                  f"({traffic['float_scan']/traffic[name]:5.0f}x less "
                  f"than float)")
    return rows


if __name__ == "__main__":
    run()
