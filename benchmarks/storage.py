"""Paper Table III: storage footprint, measured from real arrays.

Reports (per 100k docs x 50 patches, D=128 fp32 — the paper's accounting
unit) the payload bytes of: float, single 1-B code (the paper's *text*),
PQ-16 (the paper's *table* '32x' row), binary 9-bit, PQ-8x9-bit (the
table's '57x' row), plus the recsys embedding-table transfer.
"""
from __future__ import annotations

from typing import List

import jax

from repro.core import binary, quantization as quant
from repro.data import synthetic
from repro.retrieval import Corpus, HPCConfig, Retriever


PAPER_DOCS, PAPER_PATCHES, D = 100_000, 50, 128


def _scale(measured_bytes: int, measured_codes: int) -> float:
    """Scale a measured per-code payload to the paper's accounting unit."""
    per_code = measured_bytes / measured_codes
    return per_code * PAPER_DOCS * PAPER_PATCHES


def run(verbose: bool = True) -> List[dict]:
    k_data, k_build, k_pq = jax.random.split(jax.random.PRNGKey(0), 3)
    spec = synthetic.CorpusSpec(n_docs=512, n_queries=8)
    data = synthetic.make_retrieval_corpus(k_data, spec)
    n_codes = 512 * spec.n_patches
    float_ref = PAPER_DOCS * PAPER_PATCHES * D * 4

    rows = []

    def add(name, nbytes_scaled, note=""):
        ratio = float_ref / nbytes_scaled
        rows.append({"config": name, "gb": nbytes_scaled / 1e9,
                     "ratio": ratio, "note": note})
        if verbose:
            print(f"  {name:24s} {nbytes_scaled/1e9:8.4f} GB   "
                  f"{ratio:6.1f}x  {note}")

    # float32 baseline (measured bytes of the actual corpus arrays, scaled)
    add("ColPali-Full fp32", _scale(data.doc_patches.size * 4, n_codes))

    # single 1-byte K-Means code (the paper's text: '1-byte code index')
    retriever = Retriever(HPCConfig(k=256, backend="flat",
                                    prune_side="none", kmeans_iters=5))
    state = retriever.build(k_build,
                            Corpus(data.doc_patches, data.doc_mask,
                                   data.doc_salience))
    payload = retriever.storage_bytes(state)["payload"]
    add("K-Means K=256 (1 B/code)", _scale(payload, n_codes),
        "paper text's scheme; its '32x' table row is PQ-16 below")

    # PQ-16 x uint8 == the paper table's 0.08 GB / 32x row
    cbs = quant.pq_fit(k_pq, data.doc_patches.reshape(-1, D),
                       quant.PQConfig(k=256, n_sub=16, iters=4))
    pq_codes = quant.pq_quantize(data.doc_patches.reshape(-1, D), cbs)
    add("PQ-16xK256 (16 B/patch)", _scale(pq_codes.size, n_codes),
        "reproduces Table III '0.08 GB, 32x'")

    # binary: single 9-bit code (K=512)
    bits = binary.bits_for_k(512)
    add("Binary K=512 (9 bit)", _scale(binary.packed_nbytes(n_codes, bits),
                                       n_codes))

    # PQ-8 x 9-bit packed == the paper table's 0.045 GB / 57x row
    add("PQ-8xK512 9-bit packed",
        _scale(binary.packed_nbytes(n_codes * 8, 9), n_codes),
        "reproduces Table III '0.045 GB, 57x'")

    # recsys transfer: dlrm-mlperf embedding tables (full config arithmetic)
    from repro.configs import registry
    dl = registry.get("dlrm-mlperf").config
    full = sum(dl.table_rows) * dl.embed_dim * 4
    q = sum(dl.table_rows) * 1 + 26 * 256 * dl.embed_dim * 4
    rows.append({"config": "dlrm tables fp32", "gb": full / 1e9,
                 "ratio": 1.0, "note": "266M rows x 128"})
    rows.append({"config": "dlrm tables K=256 codes", "gb": q / 1e9,
                 "ratio": full / q, "note": "paper technique on recsys"})
    if verbose:
        print(f"  {'dlrm tables fp32':24s} {full/1e9:8.2f} GB      1.0x")
        print(f"  {'dlrm tables quantized':24s} {q/1e9:8.2f} GB   "
              f"{full/q:6.1f}x  paper technique on recsys")
    return rows


if __name__ == "__main__":
    run()
