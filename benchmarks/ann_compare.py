"""Candidate-routing comparison: `hnsw` graph walk vs `ivf` centroid
routing at EQUAL scanned-candidate budgets (paper §IV's HNSW serving
claim, measured head-to-head against the router it replaces).

Both backends route to a candidate set and score it through the same
fused `quantized_maxsim` scan, so the scanned-candidate budget is the
apples-to-apples knob:

    ivf  scans  n_probe * bucket_cap   padded bucket slots
    hnsw scans  ef_search              beam survivors

Recall@10 is measured against the `flat` backend (the budget-unlimited
exhaustive scan over the SAME codebook — every config below builds from
the same key, so the codebooks are bit-identical and only routing
differs) and is *tie-aware*: a returned document counts as a hit when
its score clears the oracle's k-th score. Near-duplicate documents
quantize to identical codes and tie exactly, so naive set-intersection
recall punishes a router for returning an equally-scored substitute —
and rewards whichever router happens to share the flat scan's doc-order
tie-breaking. DocPruner (arXiv:2509.23883) and the storage-efficiency
study (arXiv:2506.04997) both find candidate-generation quality
dominates end-to-end nDCG — this table is that quantity.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import numpy as np

from benchmarks.common import time_fn
from repro.core.graph import HNSWConfig
from repro.core.index import IVFConfig
from repro.data import synthetic
from repro.retrieval import Corpus, HPCConfig, Query, Retriever


def tie_aware_recall_at_k(scores: np.ndarray, ids: np.ndarray,
                          oracle_scores: np.ndarray, k: int,
                          rtol: float = 1e-5) -> float:
    """Fraction of the returned top-k whose score reaches the oracle's
    k-th score (all backends here share one scoring function, so scores
    are directly comparable). Sentinel (-1) rows never count."""
    out = []
    for qi in range(scores.shape[0]):
        thresh = np.sort(np.asarray(oracle_scores[qi]))[::-1][k - 1]
        tol = rtol * max(abs(float(thresh)), 1.0)
        s = np.asarray(scores[qi][:k])
        valid = np.asarray(ids[qi][:k]) >= 0
        out.append(float(np.sum((s >= thresh - tol) & valid)) / k)
    return float(np.mean(out))


def _search_ms(retriever: Retriever, state, queries: Query, k: int) -> float:
    fn = jax.jit(lambda a, b, c: retriever.search(
        state, Query(a, b, c), k=k))
    t = time_fn(fn, queries.embeddings, queries.mask, queries.salience)
    return t / queries.embeddings.shape[0] * 1e3


def run(seed: int = 0, verbose: bool = True,
        spec: Optional[synthetic.CorpusSpec] = None,
        n_list: int = 32, n_probe: int = 2, k: int = 10,
        measure_latency: bool = True) -> List[Dict]:
    """flat (oracle) vs ivf vs hnsw on one corpus, one shared codebook.

    The hnsw budget is pinned to the ivf budget: ef_search is set to
    exactly `n_probe * bucket_cap` after the IVF build reports its cap.
    """
    if spec is None:
        spec = synthetic.CorpusSpec(n_docs=1024, n_queries=64, n_patches=16,
                                    n_q_patches=4, dim=32, n_topics=16,
                                    dup_per_doc=3)
    key = jax.random.PRNGKey(seed)
    data = synthetic.make_retrieval_corpus(key, spec)
    corpus = Corpus(data.doc_patches, data.doc_mask, data.doc_salience)
    queries = Query(data.query_patches, data.query_mask, data.query_salience)
    build_key = jax.random.PRNGKey(seed + 1)

    def cfg_for(backend: str, **kw) -> HPCConfig:
        # the paper's operating point: doc-side pruning keeps the salient
        # patches, which also cleans the mean-vector routing representation
        return HPCConfig(k=64, p=60.0, backend=backend, prune_side="doc",
                         kmeans_iters=10, **kw)

    # oracle: exhaustive fused scan (budget = N)
    r_flat = Retriever(cfg_for("flat"))
    st_flat = r_flat.build(build_key, corpus)
    oracle_scores, _ = r_flat.search(st_flat, queries, k=k)
    oracle_scores = np.asarray(oracle_scores)

    r_ivf = Retriever(cfg_for(
        "ivf", ivf=IVFConfig(n_list=n_list, n_probe=n_probe, iters=8)))
    # same build_key as flat on purpose: identical codebook k-means init
    # keeps the backend comparison apples-to-apples
    st_ivf = r_ivf.build(build_key, corpus)  # noqa: JAX01
    cap = st_ivf.backend_state.index.bucket_codes.shape[1]
    budget = n_probe * cap

    r_hnsw = Retriever(cfg_for(
        "hnsw", hnsw=HNSWConfig(m=8, ef_construction=48, ef_search=budget)))
    # same build_key again, same controlled-comparison rationale
    st_hnsw = r_hnsw.build(build_key, corpus)  # noqa: JAX01

    rows = []
    for name, r, st, scanned in (
            ("flat", r_flat, st_flat, spec.n_docs),
            ("ivf", r_ivf, st_ivf, budget),
            ("hnsw", r_hnsw, st_hnsw, budget)):
        scores, ids = r.search(st, queries, k=k)
        row = {"backend": name, "scanned": scanned,
               "budget_frac": scanned / spec.n_docs,
               f"recall@{k}_vs_flat": tie_aware_recall_at_k(
                   np.asarray(scores), np.asarray(ids), oracle_scores, k)}
        if measure_latency:
            row["ms_per_query"] = _search_ms(r, st, queries, k)
        rows.append(row)
        if verbose:
            lat = (f"  {row['ms_per_query']:7.3f} ms/q"
                   if measure_latency else "")
            print(f"  {name:6s} scanned={scanned:5d} "
                  f"({row['budget_frac']:5.1%})  "
                  f"recall@{k}={row[f'recall@{k}_vs_flat']:.3f}{lat}")
    return rows


def smoke_metrics(seed: int = 0) -> Dict[str, float]:
    """Tiny-corpus hnsw-vs-ivf metrics for the CI bench gate.

    256 docs, n_list=16 -> cap 32, n_probe=2 -> budget 64 slots (25% of
    the corpus) for both routers. Gated: the hnsw recall floor, the
    hnsw-minus-ivf recall margin (>= 0: the graph must meet or beat the
    centroid router at the equal budget), and hnsw query latency.
    """
    spec = synthetic.CorpusSpec(n_docs=256, n_queries=32, n_patches=16,
                                n_q_patches=4, dim=32, n_topics=8,
                                dup_per_doc=3)
    rows = run(seed=seed, verbose=False, spec=spec, n_list=16, n_probe=2)
    by = {r["backend"]: r for r in rows}
    return {
        "hnsw_recall10": by["hnsw"]["recall@10_vs_flat"],
        "ivf_recall10": by["ivf"]["recall@10_vs_flat"],
        "hnsw_minus_ivf_recall10": (by["hnsw"]["recall@10_vs_flat"]
                                    - by["ivf"]["recall@10_vs_flat"]),
        "hnsw_ms_per_query": by["hnsw"]["ms_per_query"],
        "scanned_frac": by["hnsw"]["budget_frac"],
    }


if __name__ == "__main__":
    run()
