"""Paper Table IV: query latency / throughput under each configuration,
plus the §III-C compute-reduction sweep and the serving-layer benchmark.

Wall-clock is measured on CPU (the container's runtime); the *ordering*
and *relative* speedups are the reproduction target (Full > PQ-Only > HPC >
Binary ~ DistilCol). TPU-projected times come from the roofline terms in
benchmarks/roofline.py, not from CPU wall-clock.

`serving_run` drives the asyncio continuous-batching server under
open-loop Poisson arrivals (requests land at exponential gaps regardless
of completions — the honest way to measure tail latency) and reports
p50/p99/qps plus per-ladder-rung batch occupancy. `serving_compare` runs
the power-of-two padding ladder against the v1 single-compiled-shape
server at the same arrival rate: at occupancy < 50% the ladder should win
p50, because a lone straggler pads to 1-2 rows instead of max_batch.
"""
from __future__ import annotations

import asyncio
from typing import List, Optional, Tuple

import jax

from benchmarks.common import time_fn
from repro.core import late_interaction as li
from repro.core import pruning
from repro.data import synthetic
from repro.retrieval import Corpus, HPCConfig, Query, Retriever
from repro.serving.client import drive
from repro.serving.server import AsyncRetrievalServer, ServeConfig


def run(seed: int = 0, verbose: bool = True) -> List[dict]:
    k_data, k_build = jax.random.split(jax.random.PRNGKey(seed))
    spec = synthetic.CorpusSpec(n_docs=2048, n_queries=32)
    data = synthetic.make_retrieval_corpus(k_data, spec)
    q, qm, qs = (data.query_patches, data.query_mask, data.query_salience)

    configs = [
        ("ColPali-Full", HPCConfig(backend="float_flat", prune_side="none")),
        ("PQ-Only(K=256)", HPCConfig(k=256, backend="flat",
                                     prune_side="none")),
        ("HPC(K=256,p=60)", HPCConfig(k=256, p=60.0, backend="flat",
                                      prune_side="doc")),
        ("HPC(K=512,p=40)", HPCConfig(k=512, p=40.0, backend="flat",
                                      prune_side="doc")),
        ("HPC-Binary(K=512)", HPCConfig(k=512, p=60.0, backend="hamming",
                                        prune_side="doc")),
    ]

    rows = []
    t_full = None
    for name, cfg in configs:
        retriever = Retriever(cfg)
        state = retriever.build(k_build,
                                Corpus(data.doc_patches, data.doc_mask,
                                       data.doc_salience))
        fn = jax.jit(lambda a, b, c, _r=retriever, _s=state:
                     _r.search(_s, Query(a, b, c), k=10))
        t = time_fn(fn, q, qm, qs)
        per_query_ms = t / q.shape[0] * 1e3
        if name == "ColPali-Full":
            t_full = t
        rows.append({"config": name, "ms_per_query": per_query_ms,
                     "qps": q.shape[0] / t, "speedup_vs_full": t_full / t})
        if verbose:
            print(f"  {name:20s} {per_query_ms:8.3f} ms/q  "
                  f"{q.shape[0]/t:8.1f} QPS  {t_full/t:5.2f}x vs full")

    # DistilCol single-vector
    # JAX04-safe: k=10 <= n_docs=2048 (oracle over the whole tiny corpus)
    fn = jax.jit(lambda a, b: jax.lax.top_k(  # noqa: JAX04
        li.single_vector_score(a, b, data.doc_patches, data.doc_mask), 10))
    t = time_fn(fn, q, qm)
    rows.append({"config": "DistilCol", "ms_per_query": t / q.shape[0] * 1e3,
                 "qps": q.shape[0] / t, "speedup_vs_full": t_full / t})
    if verbose:
        print(f"  {'DistilCol':20s} {t/q.shape[0]*1e3:8.3f} ms/q  "
              f"{q.shape[0]/t:8.1f} QPS  {t_full/t:5.2f}x vs full")

    # §III-C sweep: late-interaction compute saved by pruning
    if verbose:
        print("  pruning compute sweep (paper claim: p=40 -> 60% saved):")
    for p in (80.0, 60.0, 40.0):
        saved = pruning.compute_saved_fraction(spec.n_patches, p)
        rows.append({"config": f"prune p={p:.0f}", "compute_saved": saved})
        if verbose:
            print(f"    p={p:4.0f}%: {saved*100:4.1f}% late-interaction "
                  f"compute removed")
    return rows


def _build_search_fn(seed: int, spec: synthetic.CorpusSpec, top_k: int):
    """Tiny flat-backend index + jitted search, shared by serving benches."""
    k_data, k_build = jax.random.split(jax.random.PRNGKey(seed))
    data = synthetic.make_retrieval_corpus(k_data, spec)
    cfg = HPCConfig(k=min(256, spec.n_docs), backend="flat",
                    prune_side="doc", p=60.0)
    retriever = Retriever(cfg)
    state = retriever.build(k_build,
                            Corpus(data.doc_patches, data.doc_mask,
                                   data.doc_salience))

    @jax.jit
    def search(q, qm, qs):
        return retriever.search(state, Query(q, qm, qs), k=top_k)

    return search, data


def serving_run(seed: int = 0, spec: Optional[synthetic.CorpusSpec] = None,
                rate_qps: float = 150.0, n_requests: int = 128,
                max_batch: int = 16, max_wait_ms: float = 2.0,
                ladder: Optional[Tuple[int, ...]] = None, top_k: int = 10,
                search_data=None, verbose: bool = True) -> dict:
    """One open-loop Poisson serving run; returns the stats row.

    `ladder=None` uses the power-of-two padding ladder; `ladder=(max_batch,)`
    reproduces the v1 single-compiled-shape server. Pass `search_data` (the
    `_build_search_fn` pair) to reuse one index across runs.
    """
    if search_data is None:
        if spec is None:
            spec = synthetic.CorpusSpec(n_docs=2048, n_queries=32)
        search_data = _build_search_fn(seed, spec, top_k)
    search, data = search_data
    server = AsyncRetrievalServer(
        search, ServeConfig(max_batch=max_batch, max_wait_ms=max_wait_ms,
                            top_k=top_k, ladder=ladder))
    server.warm_shapes(data.query_patches[0], data.query_mask[0],
                       data.query_salience[0])

    async def _go():
        await drive(server, data.query_patches, data.query_mask,
                    data.query_salience, n_requests=n_requests,
                    rate_qps=rate_qps, seed=seed + 1)
        await server.aclose()

    asyncio.run(_go())
    st = server.stats()
    row = {"server": "ladder" if len(server.ladder) > 1 else "single-shape",
           "ladder": server.ladder, "rate_qps": rate_qps,
           "occupancy": st["mean_batch"] / max_batch, **st}
    if verbose:
        rungs = " ".join(f"B={b}:{v['batches']}x@{v['occupancy']:.2f}"
                         for b, v in st["rungs"].items())
        print(f"  {row['server']:12s} rate={rate_qps:6.1f}/s  "
              f"p50 {st['p50_ms']:7.2f}ms  p99 {st['p99_ms']:7.2f}ms  "
              f"{st['qps']:6.1f} QPS  occ {row['occupancy']:.2f}  [{rungs}]")
    return row


def serving_compare(seed: int = 0, rate_qps: float = 150.0,
                    n_requests: int = 128, max_batch: int = 16,
                    verbose: bool = True) -> List[dict]:
    """Padding ladder vs v1 single compiled shape at the same arrival rate."""
    spec = synthetic.CorpusSpec(n_docs=2048, n_queries=32)
    search_data = _build_search_fn(seed, spec, top_k=10)
    if verbose:
        print("  open-loop Poisson serving (ladder vs single shape):")
    rows = [serving_run(seed, rate_qps=rate_qps, n_requests=n_requests,
                        max_batch=max_batch, ladder=ladder,
                        search_data=search_data, verbose=verbose)
            for ladder in (None, (max_batch,))]
    if verbose and rows[0]["occupancy"] < 0.5:
        win = rows[1]["p50_ms"] / max(rows[0]["p50_ms"], 1e-9)
        print(f"  ladder p50 win at occupancy<50%: {win:.2f}x")
    return rows


def _build_degraded_fn(seed: int, spec: synthetic.CorpusSpec, top_k: int):
    """A level-1 degraded search: same signature, half the corpus. Used
    by the overload curve so the degradation ladder has a real rung."""
    k_data, k_build = jax.random.split(jax.random.PRNGKey(seed))
    data = synthetic.make_retrieval_corpus(k_data, spec)
    half = spec.n_docs // 2
    cfg = HPCConfig(k=min(256, half), backend="flat", prune_side="doc",
                    p=60.0)
    retriever = Retriever(cfg)
    state = retriever.build(k_build,
                            Corpus(data.doc_patches[:half],
                                   data.doc_mask[:half],
                                   data.doc_salience[:half]))

    @jax.jit
    def search(q, qm, qs):
        return retriever.search(state, Query(q, qm, qs), k=top_k)

    return search


def overload_metrics(seed: int = 0, rate_mult: float = 4.0,
                     n_requests: int = 256, max_batch: int = 4,
                     service_floor_s: float = 0.01, max_queue: int = 16,
                     spec: Optional[synthetic.CorpusSpec] = None,
                     search_data=None, degraded_fns=(),
                     verbose: bool = True) -> dict:
    """Bounded-admission overload drill (bench gate + docs/serving.md).

    Per-batch service time is pinned with the server's fault injector
    (``latency_s=service_floor_s`` at the compute site), so the
    sustainable rate is known analytically (max_batch / service_floor)
    and the drill measures the *resilience machinery* — bounded queue,
    explicit shedding, admitted-tail latency — not corpus-size compute.
    Requests are submitted in small bursts pacing ``rate_mult``x the
    sustainable rate (burst pacing, because per-request sleeps at these
    gaps are below the event-loop timer resolution).

    Gate metrics: ``overload_p99_ms`` — the admitted p99, bounded by
    queue drain time (~ max_queue / sustainable; the point of bounded
    admission is that the tail cannot grow past the queue) — and
    ``shed_frac_at_4x`` with a pinned ceiling: under overload the server
    sheds most offered load but keeps serving; it never collapses to
    shedding everything.
    """
    from repro.serving.resilience import Overloaded, ResilienceConfig
    from repro.serving.server import Served

    if search_data is None:
        if spec is None:
            spec = synthetic.CorpusSpec(n_docs=256, n_queries=16,
                                        n_patches=8, n_q_patches=4, dim=16,
                                        n_topics=4)
        search_data = _build_search_fn(seed, spec, top_k=10)
    search, data = search_data
    sustainable = max_batch / service_floor_s
    offered = rate_mult * sustainable
    res = ResilienceConfig(max_queue=max_queue, degrade_high_frac=0.5,
                           degrade_low_frac=0.1, degrade_hold=2,
                           watchdog_interval_s=0.02)
    server = AsyncRetrievalServer(
        search, ServeConfig(max_batch=max_batch, max_wait_ms=1.0, top_k=10,
                            resilience=res),
        degraded_fns=degraded_fns)
    server.warm_shapes(data.query_patches[0], data.query_mask[0],
                       data.query_salience[0])
    server.fault_injector.arm("compute", latency_s=service_floor_s,
                              times=10 ** 9)
    q, qm, qs = data.query_patches, data.query_mask, data.query_salience
    nq = len(q)
    group = 8

    async def _go():
        tasks = []
        for i in range(n_requests):
            j = i % nq
            tasks.append(asyncio.ensure_future(
                server.query(q[j], qm[j], qs[j])))
            if (i + 1) % group == 0:
                await asyncio.sleep(group / offered)
        outs = await asyncio.gather(*tasks, return_exceptions=True)
        await server.aclose()
        return outs

    outs = asyncio.run(_go())
    served = sum(isinstance(o, Served) for o in outs)
    shed = sum(isinstance(o, Overloaded) for o in outs)
    other = [o for o in outs if not isinstance(o, (Served, Overloaded))]
    if other:
        # "every request resolves" is the drill's core invariant — an
        # unexpected outcome is a harness bug, not a metric
        raise RuntimeError(
            f"overload drill: {len(other)} request(s) resolved with "
            f"unexpected outcomes, e.g. {other[0]!r}")
    st = server.stats()
    levels = {int(k): int(v) for k, v in st["level_served"].items()}
    row = {"overload_p99_ms": st["p99_ms"],
           "shed_frac_at_4x": shed / n_requests,
           "overload_served": float(served),
           "overload_shed": float(shed),
           "overload_offered_qps": offered,
           "sustainable_qps": sustainable,
           "overload_level_served": levels}
    if verbose:
        print(f"  overload {rate_mult:.0f}x: offered {offered:.0f}/s  "
              f"served {served}  shed {shed} "
              f"({row['shed_frac_at_4x']:.0%})  admitted p99 "
              f"{st['p99_ms']:.1f} ms  (queue bound {max_queue}, "
              f"levels {levels})")
    return row


def overload_curve(seed: int = 0, mults=(1.0, 2.0, 3.0, 4.0),
                   verbose: bool = True) -> List[dict]:
    """Shed/degrade curve vs offered load (docs/serving.md): one drill
    per rate multiplier, with a real level-1 rung (half-corpus search)
    so the degradation ladder engages before admission sheds."""
    spec = synthetic.CorpusSpec(n_docs=256, n_queries=16, n_patches=8,
                                n_q_patches=4, dim=16, n_topics=4)
    search_data = _build_search_fn(seed, spec, top_k=10)
    degraded = _build_degraded_fn(seed, spec, top_k=10)
    rows = []
    for m in mults:
        row = overload_metrics(seed, rate_mult=m, search_data=search_data,
                               degraded_fns=(degraded,), verbose=False)
        row["rate_mult"] = m
        rows.append(row)
        if verbose:
            lv = row["overload_level_served"]
            deg = sum(v for k, v in lv.items() if k > 0)
            print(f"  {m:.0f}x sustainable: shed "
                  f"{row['shed_frac_at_4x']:.0%}  degraded-serve "
                  f"{deg / max(row['overload_served'], 1):.0%}  "
                  f"admitted p99 {row['overload_p99_ms']:.1f} ms")
    return rows


if __name__ == "__main__":
    run()
    serving_compare()
    overload_curve()
