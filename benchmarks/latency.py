"""Paper Table IV: query latency / throughput under each configuration,
plus the §III-C compute-reduction sweep.

Wall-clock is measured on CPU (the container's runtime); the *ordering*
and *relative* speedups are the reproduction target (Full > PQ-Only > HPC >
Binary ~ DistilCol). TPU-projected times come from the roofline terms in
benchmarks/roofline.py, not from CPU wall-clock.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.core import late_interaction as li
from repro.core import pruning
from repro.data import synthetic
from repro.retrieval import Corpus, HPCConfig, Query, Retriever


def run(seed: int = 0, verbose: bool = True) -> List[dict]:
    key = jax.random.PRNGKey(seed)
    spec = synthetic.CorpusSpec(n_docs=2048, n_queries=32)
    data = synthetic.make_retrieval_corpus(key, spec)
    q, qm, qs = (data.query_patches, data.query_mask, data.query_salience)

    configs = [
        ("ColPali-Full", HPCConfig(backend="float_flat", prune_side="none")),
        ("PQ-Only(K=256)", HPCConfig(k=256, backend="flat",
                                     prune_side="none")),
        ("HPC(K=256,p=60)", HPCConfig(k=256, p=60.0, backend="flat",
                                      prune_side="doc")),
        ("HPC(K=512,p=40)", HPCConfig(k=512, p=40.0, backend="flat",
                                      prune_side="doc")),
        ("HPC-Binary(K=512)", HPCConfig(k=512, p=60.0, backend="hamming",
                                        prune_side="doc")),
    ]

    rows = []
    t_full = None
    for name, cfg in configs:
        retriever = Retriever(cfg)
        state = retriever.build(key, Corpus(data.doc_patches, data.doc_mask,
                                            data.doc_salience))
        fn = jax.jit(lambda a, b, c, _r=retriever, _s=state:
                     _r.search(_s, Query(a, b, c), k=10))
        t = time_fn(fn, q, qm, qs)
        per_query_ms = t / q.shape[0] * 1e3
        if name == "ColPali-Full":
            t_full = t
        rows.append({"config": name, "ms_per_query": per_query_ms,
                     "qps": q.shape[0] / t, "speedup_vs_full": t_full / t})
        if verbose:
            print(f"  {name:20s} {per_query_ms:8.3f} ms/q  "
                  f"{q.shape[0]/t:8.1f} QPS  {t_full/t:5.2f}x vs full")

    # DistilCol single-vector
    fn = jax.jit(lambda a, b: jax.lax.top_k(
        li.single_vector_score(a, b, data.doc_patches, data.doc_mask), 10))
    t = time_fn(fn, q, qm)
    rows.append({"config": "DistilCol", "ms_per_query": t / q.shape[0] * 1e3,
                 "qps": q.shape[0] / t, "speedup_vs_full": t_full / t})
    if verbose:
        print(f"  {'DistilCol':20s} {t/q.shape[0]*1e3:8.3f} ms/q  "
              f"{q.shape[0]/t:8.1f} QPS  {t_full/t:5.2f}x vs full")

    # §III-C sweep: late-interaction compute saved by pruning
    if verbose:
        print("  pruning compute sweep (paper claim: p=40 -> 60% saved):")
    for p in (80.0, 60.0, 40.0):
        saved = pruning.compute_saved_fraction(spec.n_patches, p)
        rows.append({"config": f"prune p={p:.0f}", "compute_saved": saved})
        if verbose:
            print(f"    p={p:4.0f}%: {saved*100:4.1f}% late-interaction "
                  f"compute removed")
    return rows


if __name__ == "__main__":
    run()
