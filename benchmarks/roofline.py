"""Roofline report: renders EXPERIMENTS.md §Roofline tables from the
dry-run JSON (launch/dryrun.py --out).

  PYTHONPATH=src python -m benchmarks.roofline \
      --single benchmarks/results/dryrun_single.json \
      [--multi benchmarks/results/dryrun_multi.json] [--md out.md]

Terms (per device, TPU v5e constants from launch/mesh.py):
  compute    = HLO_FLOPs / 197 TFLOP/s
  memory     = HLO bytes-accessed / 819 GB/s
  collective = per-device collective link bytes / 50 GB/s
roofline_frac = (MODEL_FLOPS/chips / peak) / max(term) — how close the
*useful* model math runs to the hardware bound given the compiled program.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional


def load(path: str) -> List[dict]:
    with open(path) as f:
        return json.load(f)


def fmt_row(r: dict) -> str:
    ro = r["roofline"]
    mem = r["mem"]
    coll = r["collective_bytes_per_dev"]
    coll_total = sum(v for k, v in coll.items() if k != "count")
    return ("| {arch} | {shape} | {chips} | {c:.2e} | {m:.2e} | {x:.2e} | "
            "{dom} | {useful:.2f} | {frac:.3f} | {gib:.2f} | {fits} |"
            .format(arch=r["arch"], shape=r["shape"], chips=r["chips"],
                    c=ro["compute_s"], m=ro["memory_s"],
                    x=ro["collective_s"], dom=ro["dominant"],
                    useful=ro["useful_flops_ratio"],
                    frac=ro["roofline_frac"],
                    gib=mem["peak_bytes"] / 2 ** 30,
                    fits="yes" if mem["fits_16g"] else "NO"))


HEADER = ("| arch | shape | chips | compute (s) | memory (s) | "
          "collective (s) | bound | useful-flops | roofline-frac | "
          "GiB/dev | fits 16G |\n"
          "|---|---|---|---|---|---|---|---|---|---|---|")


def pick_hillclimb(rows: List[dict]) -> Dict[str, dict]:
    ok = [r for r in rows if r.get("status") == "ok"]
    worst = min(ok, key=lambda r: r["roofline"]["roofline_frac"] or 1e9)
    coll = max(ok, key=lambda r: r["roofline"]["collective_s"])
    paper = next((r for r in ok if r["arch"] == "colpali-hpc"
                  and r["shape"] == "serve_query"), None)
    return {"worst_roofline": worst, "most_collective_bound": coll,
            "paper_representative": paper}


def render(single: List[dict], multi: Optional[List[dict]] = None) -> str:
    out = ["### Roofline table — single pod (16x16 = 256 chips)", "",
           HEADER]
    for r in single:
        if r.get("status") == "ok":
            out.append(fmt_row(r))
        elif r.get("status") == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                       f"skipped | — | — | — | — |")
    if multi:
        out += ["", "### Multi-pod (2x16x16 = 512 chips)", "", HEADER]
        for r in multi:
            if r.get("status") == "ok":
                out.append(fmt_row(r))
    picks = pick_hillclimb(single)
    out += ["", "### Hillclimb picks", ""]
    for why, r in picks.items():
        if r is not None:
            out.append(f"- **{why}**: {r['arch']}/{r['shape']} "
                       f"(dominant={r['roofline']['dominant']}, "
                       f"frac={r['roofline']['roofline_frac']:.3f})")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--single", required=True)
    ap.add_argument("--multi", default=None)
    ap.add_argument("--md", default=None)
    args = ap.parse_args(argv)
    single = load(args.single)
    multi = load(args.multi) if args.multi else None
    text = render(single, multi)
    if args.md:
        with open(args.md, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.md}")
    else:
        print(text)


if __name__ == "__main__":
    main()
