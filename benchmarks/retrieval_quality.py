"""Paper Tables I & II: retrieval quality (nDCG@10 / Recall@10 / MAP) on the
ViDoRe-like and SEC-Filings-like corpora.

Rows: ColPali-Full, PQ-Only (K=256, no pruning), DistilCol (single-vector),
HPC-ColPali (K=256, p=60), HPC-ColPali (K=512, p=40), HPC binary (K=512).
Claim validated: HPC keeps nDCG@10 within ~2% of Full (paper §V-A).
"""
from __future__ import annotations

from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import retrieval_metrics
from repro.core import late_interaction as li
from repro.data import synthetic
from repro.retrieval import Corpus, HPCConfig, Query, Retriever


def _run_config(key, data, cfg: HPCConfig, k: int = 10) -> Dict[str, float]:
    retriever = Retriever(cfg)
    state = retriever.build(key, Corpus(data.doc_patches, data.doc_mask,
                                        data.doc_salience))
    _, ids = retriever.search(state, Query(data.query_patches,
                                           data.query_mask,
                                           data.query_salience), k=k)
    return retrieval_metrics(np.asarray(ids), np.asarray(data.relevance), k)


def _distilcol(data, k: int = 10) -> Dict[str, float]:
    scores = li.single_vector_score(data.query_patches, data.query_mask,
                                    data.doc_patches, data.doc_mask)
    # JAX04-safe: k=10 <= the benchmark corpus size
    _, ids = jax.lax.top_k(scores, k)  # noqa: JAX04
    return retrieval_metrics(np.asarray(ids), np.asarray(data.relevance), k)


CONFIGS = [
    ("ColPali-Full", HPCConfig(backend="float_flat", prune_side="none")),
    ("PQ-Only(K=256)", HPCConfig(k=256, backend="flat",
                                 prune_side="none")),
    ("HPC(K=256,p=60)", HPCConfig(k=256, p=60.0, backend="flat",
                                  prune_side="doc", rerank=32)),
    ("HPC(K=512,p=40)", HPCConfig(k=512, p=40.0, backend="flat",
                                  prune_side="doc", rerank=32)),
    ("HPC-Binary(K=512)", HPCConfig(k=512, p=60.0, backend="hamming",
                                    prune_side="doc")),
]


def run(seed: int = 0, verbose: bool = True, stress: bool = True,
        datasets=None) -> List[dict]:
    """Tables I/II + a beyond-paper codebook-capacity stress ablation
    (STRESS corpus plants 3072 prototypes >> K: quantization must degrade —
    quantifies the paper's implicit clusterability assumption).

    `datasets` overrides the (name, CorpusSpec) list — used by the CI
    smoke run with a tiny corpus.
    """
    rows = []
    if datasets is None:
        datasets = [("ViDoRe-like", synthetic.VIDORE),
                    ("SEC-like", synthetic.SEC_FILINGS)]
        if stress:
            datasets.append(("STRESS(3072proto)", synthetic.STRESS))
    for ds_name, spec in datasets:
        key = jax.random.PRNGKey(seed)
        data = synthetic.make_retrieval_corpus(key, spec)
        full_ndcg = None
        for name, cfg in CONFIGS:
            m = _run_config(jax.random.PRNGKey(seed + 1), data, cfg)
            if name == "ColPali-Full":
                full_ndcg = m["ndcg@10"]
            m["ndcg_drop_vs_full"] = (full_ndcg - m["ndcg@10"]
                                      if full_ndcg else 0.0)
            rows.append({"dataset": ds_name, "model": name, **m})
            if verbose:
                print(f"  {ds_name:12s} {name:20s} "
                      f"nDCG@10={m['ndcg@10']:.3f} "
                      f"R@10={m['recall@10']:.3f} MAP={m['map']:.3f}")
        m = _distilcol(data)
        m["ndcg_drop_vs_full"] = full_ndcg - m["ndcg@10"]
        rows.append({"dataset": ds_name, "model": "DistilCol", **m})
        if verbose:
            print(f"  {ds_name:12s} {'DistilCol':20s} "
                  f"nDCG@10={m['ndcg@10']:.3f} R@10={m['recall@10']:.3f} "
                  f"MAP={m['map']:.3f}")
    return rows


if __name__ == "__main__":
    run()
