"""Paper Tables I & II: retrieval quality (nDCG@10 / Recall@10 / MAP) on the
ViDoRe-like and SEC-Filings-like corpora.

Rows: ColPali-Full, PQ-Only (K=256, no pruning), DistilCol (single-vector),
HPC-ColPali (K=256, p=60), HPC-ColPali (K=512, p=40), HPC binary (K=512).
Claim validated: HPC keeps nDCG@10 within ~2% of Full (paper §V-A).
"""
from __future__ import annotations

from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import retrieval_metrics
from repro.core import late_interaction as li
from repro.data import synthetic
from repro.retrieval import (CascadeConfig, Corpus, HPCConfig, Query,
                             Retriever)


def _run_config(key, data, cfg: HPCConfig, k: int = 10) -> Dict[str, float]:
    retriever = Retriever(cfg)
    state = retriever.build(key, Corpus(data.doc_patches, data.doc_mask,
                                        data.doc_salience))
    _, ids = retriever.search(state, Query(data.query_patches,
                                           data.query_mask,
                                           data.query_salience), k=k)
    return retrieval_metrics(np.asarray(ids), np.asarray(data.relevance), k)


def _distilcol(data, k: int = 10) -> Dict[str, float]:
    scores = li.single_vector_score(data.query_patches, data.query_mask,
                                    data.doc_patches, data.doc_mask)
    # JAX04-safe: k=10 <= the benchmark corpus size
    _, ids = jax.lax.top_k(scores, k)  # noqa: JAX04
    return retrieval_metrics(np.asarray(ids), np.asarray(data.relevance), k)


CONFIGS = [
    ("ColPali-Full", HPCConfig(backend="float_flat", prune_side="none")),
    ("PQ-Only(K=256)", HPCConfig(k=256, backend="flat",
                                 prune_side="none")),
    ("HPC(K=256,p=60)", HPCConfig(k=256, p=60.0, backend="flat",
                                  prune_side="doc", rerank=32)),
    ("HPC(K=512,p=40)", HPCConfig(k=512, p=40.0, backend="flat",
                                  prune_side="doc", rerank=32)),
    ("HPC-Binary(K=512)", HPCConfig(k=512, p=60.0, backend="hamming",
                                    prune_side="doc")),
    # staged funnel: hamming over all N -> ADC top-p1 -> float top-p2.
    # budgets sized for the 2048-doc table corpora (12.5% / 3.1%); on the
    # tiny smoke corpus p1 >= N degenerates to a full binary scan, which
    # is the correct (and still cheap) small-corpus behaviour.
    ("HPC-Cascade(K=256)", HPCConfig(k=256, p=60.0, backend="cascade",
                                     prune_side="doc",
                                     cascade=CascadeConfig(p1=256, p2=64))),
]


def cascade_metrics(seed: int = 0, k: int = 10) -> Dict[str, float]:
    """Smoke-corpus cascade funnel metrics for the CI bench gate.

    Measures the staged cascade head-to-head against the `flat` oracle
    (exhaustive ADC scan over the SAME codebook — shared build key,
    like ann_compare), both scored against the planted ground-truth
    relevance. Comparing against the oracle's *ranking* would be wrong
    here: the cascade's float rerank intentionally corrects ADC
    quantization noise, so it disagrees with the ADC ordering exactly
    where it is MORE accurate (measured: the cascade beats the flat
    oracle's ground-truth recall at a 3% float budget). The gated
    acceptance is the ratio — cascade recall@10 >= 0.95x the flat
    scan's — plus the float-touched fraction ceiling (p2/N <= 5%, the
    paper's "expensive stage touches a few percent" regime) and query
    latency.
    """
    from benchmarks.ann_compare import _search_ms

    spec = synthetic.CorpusSpec(n_docs=512, n_queries=32, n_patches=16,
                                n_q_patches=4, dim=32, n_topics=8,
                                dup_per_doc=3)
    p1, p2 = 128, 16                      # 25% ADC, 3.1% float
    data = synthetic.make_retrieval_corpus(jax.random.PRNGKey(seed), spec)
    corpus = Corpus(data.doc_patches, data.doc_mask, data.doc_salience)
    queries = Query(data.query_patches, data.query_mask, data.query_salience)
    relevance = np.asarray(data.relevance)
    build_key = jax.random.PRNGKey(seed + 1)

    def cfg_for(backend: str, **kw) -> HPCConfig:
        return HPCConfig(k=64, p=60.0, backend=backend, prune_side="doc",
                         kmeans_iters=10, **kw)

    r_flat = Retriever(cfg_for("flat"))
    st_flat = r_flat.build(build_key, corpus)
    _, flat_ids = r_flat.search(st_flat, queries, k=k)
    flat_m = retrieval_metrics(np.asarray(flat_ids), relevance, k)

    r_casc = Retriever(cfg_for("cascade",
                               cascade=CascadeConfig(p1=p1, p2=p2)))
    # same build_key as flat on purpose: identical codebook k-means init
    # keeps the funnel-vs-oracle comparison apples-to-apples
    st_casc = r_casc.build(build_key, corpus)  # noqa: JAX01
    _, casc_ids = r_casc.search(st_casc, queries, k=k)
    casc_m = retrieval_metrics(np.asarray(casc_ids), relevance, k)

    bytes_per_doc = {
        f"cascade_bytes_per_doc_{key.removeprefix('stage_')}":
            val / spec.n_docs
        for key, val in r_casc.storage_bytes(st_casc).items()
        if key.startswith("stage_")}
    return {
        "cascade_recall10": casc_m[f"recall@{k}"],
        "flat_recall10": flat_m[f"recall@{k}"],
        "cascade_recall10_vs_flat": (casc_m[f"recall@{k}"]
                                     / max(flat_m[f"recall@{k}"], 1e-9)),
        "cascade_ndcg10": casc_m[f"ndcg@{k}"],
        "cascade_ms_per_query": _search_ms(r_casc, st_casc, queries, k),
        "cascade_float_frac": p2 / spec.n_docs,
        "cascade_p1_frac": p1 / spec.n_docs,
        **bytes_per_doc,
    }


def run(seed: int = 0, verbose: bool = True, stress: bool = True,
        datasets=None) -> List[dict]:
    """Tables I/II + a beyond-paper codebook-capacity stress ablation
    (STRESS corpus plants 3072 prototypes >> K: quantization must degrade —
    quantifies the paper's implicit clusterability assumption).

    `datasets` overrides the (name, CorpusSpec) list — used by the CI
    smoke run with a tiny corpus.
    """
    rows = []
    if datasets is None:
        datasets = [("ViDoRe-like", synthetic.VIDORE),
                    ("SEC-like", synthetic.SEC_FILINGS)]
        if stress:
            datasets.append(("STRESS(3072proto)", synthetic.STRESS))
    for ds_name, spec in datasets:
        key = jax.random.PRNGKey(seed)
        data = synthetic.make_retrieval_corpus(key, spec)
        full_ndcg = None
        for name, cfg in CONFIGS:
            m = _run_config(jax.random.PRNGKey(seed + 1), data, cfg)
            if name == "ColPali-Full":
                full_ndcg = m["ndcg@10"]
            m["ndcg_drop_vs_full"] = (full_ndcg - m["ndcg@10"]
                                      if full_ndcg else 0.0)
            rows.append({"dataset": ds_name, "model": name, **m})
            if verbose:
                print(f"  {ds_name:12s} {name:20s} "
                      f"nDCG@10={m['ndcg@10']:.3f} "
                      f"R@10={m['recall@10']:.3f} MAP={m['map']:.3f}")
        m = _distilcol(data)
        m["ndcg_drop_vs_full"] = full_ndcg - m["ndcg@10"]
        rows.append({"dataset": ds_name, "model": "DistilCol", **m})
        if verbose:
            print(f"  {ds_name:12s} {'DistilCol':20s} "
                  f"nDCG@10={m['ndcg@10']:.3f} R@10={m['recall@10']:.3f} "
                  f"MAP={m['map']:.3f}")
    return rows


if __name__ == "__main__":
    run()
