"""Benchmark harness: one module per paper table.

  PYTHONPATH=src python -m benchmarks.run [--fast]

Prints a ``name,us_per_call,derived`` CSV summary at the end (one row per
benchmark), after each table's detailed output.
"""
from __future__ import annotations

import argparse
import time

from benchmarks import (kernel_bench, latency, rag_bench, retrieval_quality,
                        storage)
from benchmarks.common import csv_row


def smoke() -> int:
    """CI smoke: retrieval quality + storage on a tiny corpus (~seconds)."""
    from repro.data import synthetic
    tiny = synthetic.CorpusSpec(n_docs=128, n_queries=8, n_patches=8,
                                n_q_patches=4, dim=16, n_topics=4)
    print("== smoke: retrieval quality (tiny corpus) ==")
    rows = retrieval_quality.run(stress=False, datasets=[("smoke", tiny)])
    assert rows, "smoke retrieval produced no rows"
    print("== smoke: storage footprint ==")
    storage.run(verbose=False)
    print("SMOKE_OK")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer RAG generator steps")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-config CI smoke run (quality + storage only)")
    args = ap.parse_args(argv)

    if args.smoke:
        return smoke()

    csv = []

    print("== Table I/II: retrieval quality (ViDoRe-like / SEC-like) ==")
    t0 = time.perf_counter()
    q_rows = retrieval_quality.run()
    dt = time.perf_counter() - t0
    hpc_row = [r for r in q_rows
               if r["model"] == "HPC(K=256,p=60)"][0]
    csv.append(csv_row("retrieval_quality", dt * 1e6,
                       f"ndcg_drop={hpc_row['ndcg_drop_vs_full']:.4f}"))

    print("== Table III: storage footprint ==")
    t0 = time.perf_counter()
    s_rows = storage.run()
    dt = time.perf_counter() - t0
    r32 = [r for r in s_rows if "PQ-16" in r["config"]][0]
    csv.append(csv_row("storage", dt * 1e6, f"pq16_ratio={r32['ratio']:.1f}x"))

    print("== Table IV: query latency / throughput ==")
    t0 = time.perf_counter()
    l_rows = latency.run()
    dt = time.perf_counter() - t0
    hpc_l = [r for r in l_rows if r["config"] == "HPC(K=256,p=60)"][0]
    csv.append(csv_row("latency", hpc_l["ms_per_query"] * 1e3,
                       f"speedup={hpc_l['speedup_vs_full']:.2f}x"))

    print("== Table V: RAG legal summarisation ==")
    t0 = time.perf_counter()
    r_rows = rag_bench.run(steps=120 if args.fast else 300)
    dt = time.perf_counter() - t0
    full = [r for r in r_rows if r["retriever"] == "ColPali-Full"][0]
    hpc_r = [r for r in r_rows if r["retriever"] == "HPC(K=256,p=60)"][0]
    csv.append(csv_row(
        "rag", dt * 1e6,
        f"halluc_full={full['hallucination']:.3f};"
        f"halluc_hpc={hpc_r['hallucination']:.3f};"
        f"lat_ratio={hpc_r['latency_ms']/max(full['latency_ms'],1e-9):.2f}"))

    print("== Kernel microbench: fused decode-and-score ==")
    k_rows = kernel_bench.run()
    fused = [r for r in k_rows if r["kernel"] == "fused_adc_scan"][0]
    csv.append(csv_row("kernel_fused_adc", fused["ms"] * 1e3,
                       f"traffic_saving={fused['traffic_ratio_vs_float']:.0f}x"))

    print("\nname,us_per_call,derived")
    for row in csv:
        print(row)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
