"""Benchmark harness: one module per paper table.

  PYTHONPATH=src python -m benchmarks.run [--fast]
  PYTHONPATH=src python -m benchmarks.run --smoke --json out.json

Prints a ``name,us_per_call,derived`` CSV summary at the end (one row per
benchmark), after each table's detailed output. `--json` writes the
machine-readable metrics the CI bench gate (benchmarks/bench_gate.py)
compares against the committed BENCH_baseline.json; the payload includes
a `calib_ms` machine-speed scalar so the gate can normalise wall-clock
metrics across runners.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks import (ann_compare, churn, kernel_bench, latency, rag_bench,
                        retrieval_quality, storage)
from benchmarks.common import calibrate_ms, csv_row


def _codebook_metrics() -> dict:
    """Codebook-quality smoke metrics: quantized-flat hit@10 (the seed-gap
    metric, gated as a hard floor — see bench_gate.py) and the trained
    codebook's inertia on the valid corpus patches.

    Uses 32 queries (not the 8-query serving spec) so the hit@10 quantum
    is 1/32 and the gate floor has a real noise margin below it."""
    import jax
    import jax.numpy as jnp

    from benchmarks.common import retrieval_metrics
    from repro.core import quantization as quant
    from repro.data import synthetic
    from repro.retrieval import Corpus, HPCConfig, Query, Retriever

    spec = synthetic.CorpusSpec(n_docs=128, n_queries=32, n_patches=8,
                                n_q_patches=4, dim=16, n_topics=4)
    data = synthetic.make_retrieval_corpus(jax.random.PRNGKey(0), spec)
    cfg = HPCConfig(k=32, p=60.0, backend="flat", prune_side="doc",
                    kmeans_iters=10, rerank=16)
    r = Retriever(cfg)
    state = r.build(jax.random.PRNGKey(1),
                    Corpus(data.doc_patches, data.doc_mask,
                           data.doc_salience))
    _, ids = r.search(state, Query(data.query_patches, data.query_mask,
                                   data.query_salience), k=10)
    m = retrieval_metrics(np.asarray(ids), np.asarray(data.relevance), 10)
    d = data.doc_patches.shape[-1]
    flat = np.asarray(data.doc_patches.reshape(-1, d))
    valid = np.asarray(data.doc_mask.reshape(-1)).astype(bool)
    inertia = float(quant.quantization_error(jnp.asarray(flat[valid]),
                                             state.codebook))
    return {"hit10_quantized_flat": m["hit@10"], "codebook_inertia": inertia}


def smoke(json_path=None) -> int:
    """CI smoke: retrieval quality + storage + serving on tiny configs."""
    from repro.data import synthetic
    tiny = synthetic.CorpusSpec(n_docs=128, n_queries=8, n_patches=8,
                                n_q_patches=4, dim=16, n_topics=4)
    print("== smoke: retrieval quality (tiny corpus) ==")
    rows = retrieval_quality.run(stress=False, datasets=[("smoke", tiny)])
    assert rows, "smoke retrieval produced no rows"
    print("== smoke: codebook quality (quantized-flat) ==")
    cb = _codebook_metrics()
    print(f"  hit@10={cb['hit10_quantized_flat']:.3f} "
          f"inertia={cb['codebook_inertia']:.4f}")
    print("== smoke: candidate routing (hnsw vs ivf, equal budget) ==")
    ann = ann_compare.smoke_metrics()
    print(f"  hnsw recall@10={ann['hnsw_recall10']:.3f} "
          f"ivf recall@10={ann['ivf_recall10']:.3f} "
          f"(margin {ann['hnsw_minus_ivf_recall10']:+.3f} at "
          f"{ann['scanned_frac']:.0%} scanned)  "
          f"hnsw {ann['hnsw_ms_per_query']:.3f} ms/q")
    print("== smoke: compression cascade (hamming -> ADC -> float) ==")
    casc = retrieval_quality.cascade_metrics()
    print(f"  recall@10={casc['cascade_recall10']:.3f} "
          f"(flat oracle {casc['flat_recall10']:.3f}, "
          f"ratio {casc['cascade_recall10_vs_flat']:.2f}x)  "
          f"float stage touches {casc['cascade_float_frac']:.1%}  "
          f"{casc['cascade_ms_per_query']:.3f} ms/q")
    print("== smoke: streaming flat scan (wired search path) ==")
    scan = kernel_bench.flat_scan_metrics()
    scan.update(kernel_bench.flat_scan_bytes_crosscheck())
    print("== smoke: live churn (LSM segments, add/delete interleaved) ==")
    churn_m = churn.churn_metrics()
    print(f"  recall@10={churn_m['churn_recall10']:.3f} "
          f"(rebuild {churn_m['rebuild_recall10']:.3f}, "
          f"ratio {churn_m['churn_recall10_vs_rebuild']:.3f})  "
          f"live={churn_m['live_docs']:.0f} "
          f"tombstone_frac={churn_m['tombstone_frac']:.2%} "
          f"over {churn_m['segments']:.0f} segments  "
          f"compact {churn_m['compact_ms']:.2f} ms")
    print("== smoke: storage footprint ==")
    storage.run(verbose=False)
    print("== smoke: serving latency (padding ladder, open-loop) ==")
    calib = calibrate_ms()
    serve_spec = synthetic.CorpusSpec(n_docs=256, n_queries=16, n_patches=8,
                                      n_q_patches=4, dim=16, n_topics=4)
    # 256 requests so the gated p99 is an order statistic over a real
    # sample, not the run's max; median of 3 runs (one shared index) so a
    # single scheduler stall on a noisy runner doesn't set the gate value.
    # The arrival rate adapts: probe runs back off until the server keeps
    # up (qps ~ rate), because a fixed rate overloads slow runners and
    # the gated p99 becomes backlog depth, not serving latency. A code
    # slowdown still shows: either latency rises at the settled rate, or
    # the backoff settles lower and qps drops against the baseline.
    search_data = latency._build_search_fn(0, serve_spec, top_k=10)
    rate = 200.0
    for _ in range(3):
        probe = latency.serving_run(rate_qps=rate, n_requests=96,
                                    max_batch=8, search_data=search_data,
                                    verbose=False)
        if probe["qps"] >= 0.8 * rate:
            break
        rate /= 2
    print(f"  settled open-loop rate {rate:.0f}/s")
    sruns = [latency.serving_run(rate_qps=rate, n_requests=256,
                                 max_batch=8, search_data=search_data)
             for _ in range(3)]
    med = {k: float(np.median([r[k] for r in sruns]))
           for k in ("p50_ms", "p99_ms", "qps", "mean_batch")}
    print("== smoke: overload drill (bounded admission, 4x sustained) ==")
    # service time is pinned via the fault injector, so the sustainable
    # rate is analytic and the drill gates the resilience machinery
    # (bounded queue -> bounded admitted p99; shedding never collapses)
    over = latency.overload_metrics(search_data=search_data)
    med["overload_p99_ms"] = over["overload_p99_ms"]
    med["shed_frac_at_4x"] = over["shed_frac_at_4x"]
    full = [r for r in rows if r["model"] == "ColPali-Full"][0]
    hpc = [r for r in rows if r["model"] == "HPC(K=256,p=60)"][0]
    metrics = {
        "schema": 1,
        "calib_ms": calib,
        "serving": med,
        "quality": {"ndcg_full": full["ndcg@10"], "ndcg_hpc": hpc["ndcg@10"],
                    **cb},
        "ann": ann,
        "scan": scan,
        "cascade": casc,
        "churn": churn_m,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
        print(f"wrote {json_path}")
    print("SMOKE_OK")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer RAG generator steps")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-config CI smoke run (quality + storage + "
                         "serving)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable metrics JSON (bench gate)")
    args = ap.parse_args(argv)

    if args.smoke:
        return smoke(json_path=args.json)

    csv = []

    print("== Table I/II: retrieval quality (ViDoRe-like / SEC-like) ==")
    t0 = time.perf_counter()
    q_rows = retrieval_quality.run()
    dt = time.perf_counter() - t0
    hpc_row = [r for r in q_rows
               if r["model"] == "HPC(K=256,p=60)"][0]
    csv.append(csv_row("retrieval_quality", dt * 1e6,
                       f"ndcg_drop={hpc_row['ndcg_drop_vs_full']:.4f}"))

    print("== Table III: storage footprint ==")
    t0 = time.perf_counter()
    s_rows = storage.run()
    dt = time.perf_counter() - t0
    r32 = [r for r in s_rows if "PQ-16" in r["config"]][0]
    csv.append(csv_row("storage", dt * 1e6, f"pq16_ratio={r32['ratio']:.1f}x"))

    print("== Candidate routing: hnsw graph vs ivf centroids ==")
    t0 = time.perf_counter()
    a_rows = ann_compare.run()
    dt = time.perf_counter() - t0
    a_hnsw = [r for r in a_rows if r["backend"] == "hnsw"][0]
    a_ivf = [r for r in a_rows if r["backend"] == "ivf"][0]
    csv.append(csv_row(
        "ann_compare", dt * 1e6,
        f"hnsw_recall={a_hnsw['recall@10_vs_flat']:.3f};"
        f"ivf_recall={a_ivf['recall@10_vs_flat']:.3f};"
        f"scanned={a_hnsw['budget_frac']:.2f}"))

    print("== Table IV: query latency / throughput ==")
    t0 = time.perf_counter()
    l_rows = latency.run()
    dt = time.perf_counter() - t0
    hpc_l = [r for r in l_rows if r["config"] == "HPC(K=256,p=60)"][0]
    csv.append(csv_row("latency", hpc_l["ms_per_query"] * 1e3,
                       f"speedup={hpc_l['speedup_vs_full']:.2f}x"))

    print("== Serving: padding ladder vs single compiled shape ==")
    t0 = time.perf_counter()
    srv_rows = latency.serving_compare()
    dt = time.perf_counter() - t0
    lad, single = srv_rows[0], srv_rows[1]
    csv.append(csv_row(
        "serving_ladder", lad["p50_ms"] * 1e3,
        f"p50_win={single['p50_ms']/max(lad['p50_ms'],1e-9):.2f}x;"
        f"occ={lad['occupancy']:.2f}"))

    print("== Table V: RAG legal summarisation ==")
    t0 = time.perf_counter()
    r_rows = rag_bench.run(steps=120 if args.fast else 300)
    dt = time.perf_counter() - t0
    full = [r for r in r_rows if r["retriever"] == "ColPali-Full"][0]
    hpc_r = [r for r in r_rows if r["retriever"] == "HPC(K=256,p=60)"][0]
    csv.append(csv_row(
        "rag", dt * 1e6,
        f"halluc_full={full['hallucination']:.3f};"
        f"halluc_hpc={hpc_r['hallucination']:.3f};"
        f"lat_ratio={hpc_r['latency_ms']/max(full['latency_ms'],1e-9):.2f}"))

    print("== Kernel microbench: fused decode-and-score ==")
    k_rows = kernel_bench.run()
    fused = [r for r in k_rows if r["kernel"] == "fused_adc_scan"][0]
    csv.append(csv_row("kernel_fused_adc", fused["ms"] * 1e3,
                       f"traffic_saving={fused['traffic_ratio_vs_float']:.0f}x"))

    print("\nname,us_per_call,derived")
    for row in csv:
        print(row)
    if args.json:
        # note: the gated baseline is produced by the --smoke path; this
        # payload carries the same keys (so bench_gate runs on it) but
        # measures the full-size corpora — don't mix the two baselines
        vd = [r for r in q_rows if r["dataset"] == "ViDoRe-like"]
        payload = {
            "schema": 1, "calib_ms": calibrate_ms(),
            "serving": {"p50_ms": lad["p50_ms"], "p99_ms": lad["p99_ms"],
                        "qps": lad["qps"], "mean_batch": lad["mean_batch"]},
            "quality": {
                "ndcg_full": [r for r in vd
                              if r["model"] == "ColPali-Full"][0]["ndcg@10"],
                "ndcg_hpc": [r for r in vd if r["model"] ==
                             "HPC(K=256,p=60)"][0]["ndcg@10"],
            },
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
