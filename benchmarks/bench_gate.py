"""CI benchmark-regression gate.

  python -m benchmarks.run --smoke --json out.json
  python -m benchmarks.bench_gate out.json --baseline BENCH_baseline.json

Compares the smoke run's serving metrics (p50/p99 latency, qps) and
quality metrics (nDCG) against the committed baseline JSON and exits
non-zero if any metric regressed beyond the tolerance (default +-20%).
Improvements never fail the gate.

Wall-clock metrics are normalised by each file's `calib_ms` machine-speed
scalar (a fixed jitted matmul, benchmarks/common.calibrate_ms) before
comparison, so a slower CI runner does not read as a code regression.
Normalisation is strictly forgiving: it only ever discounts a slower
machine, never inflates a faster one (fixed costs like the coalescing
wait window don't scale with compute speed, so symmetric scaling would
false-fail fast runners). Quality metrics are compared unnormalised.
"""
from __future__ import annotations

import argparse
import json
import sys


# (json path, direction, normalise, floor) per gated metric: "lower" =
# regression when the normalised value rises above baseline*(1+tol);
# "higher" = regression when it falls below baseline*(1-tol); "floor" =
# hard quality floor — the current value must be >= the PINNED constant
# below, with NO tolerance (quality gets no -20% forgiveness); "ceiling"
# = the current value must be <= the pinned constant, no tolerance (the
# budget-contract dual of "floor" — e.g. the cascade's float stage may
# never touch more than 5% of the corpus). Floors/ceilings are pinned
# here, not read from BENCH_baseline.json, so the routine
# baseline-refresh workflow (copying a smoke run's measured JSON) can
# never silently tighten it; the baseline field stays informational. 0.70
# mirrors the tier-1 quantized-flat floor, ~2.6 quanta (1/32 each) below
# the measured smoke value — codebook-training collapse lands far below.
GATED = [
    (("serving", "p50_ms"), "lower", True, None),
    (("serving", "p99_ms"), "lower", True, None),
    (("serving", "qps"), "higher", True, None),
    # fault-tolerant serving overload drill (latency.overload_metrics —
    # bounded admission driven at 4x a pinned sustainable rate). The
    # admitted p99 is bounded by queue drain time (max_queue /
    # sustainable) — band-gated so a broken queue bound (backlog-driven
    # tail) fails; the shed fraction has a pinned 0.90 ceiling: under
    # overload the server sheds most excess load but must keep serving —
    # shedding (nearly) everything is collapse, not load shedding.
    # Measured ~0.34 at the smoke config; admission bugs that reject all
    # traffic land at ~1.0, far past the ceiling.
    (("serving", "overload_p99_ms"), "lower", True, None),
    (("serving", "shed_frac_at_4x"), "ceiling", False, 0.90),
    (("quality", "ndcg_full"), "higher", False, None),
    (("quality", "ndcg_hpc"), "higher", False, None),
    (("quality", "hit10_quantized_flat"), "floor", False, 0.70),
    (("quality", "codebook_inertia"), "lower", False, None),
    # hnsw-vs-ivf routing (benchmarks/ann_compare.py, tie-aware recall at
    # a 25%-of-corpus scanned budget). The 0.90 floor sits ~27 smoke
    # quanta (1/320 each) below the measured 0.984; the 0.0 margin floor
    # IS the acceptance criterion — the graph router must never fall
    # behind the centroid router it replaced at the same budget.
    (("ann", "hnsw_recall10"), "floor", False, 0.90),
    (("ann", "hnsw_minus_ivf_recall10"), "floor", False, 0.0),
    (("ann", "hnsw_ms_per_query"), "lower", True, None),
    # streaming flat scan (benchmarks/kernel_bench.flat_scan_metrics —
    # the wired search_flat path through core/scan.py): per-query
    # latency and corpus sweep throughput, both calib-normalised. The
    # two derive from one timing (docs/sec = n_docs*1000/ms_per_query at
    # pinned n_docs) so they fail together — both are gated because both
    # are reported headline numbers; treat them as one signal.
    (("scan", "flat_scan_ms_per_query"), "lower", True, None),
    (("scan", "flat_scan_docs_per_sec"), "higher", True, None),
    # static-cost-model calibration (kernel_bench.flat_scan_bytes_
    # crosscheck): the analytic bytes/doc the `jaxlint --cost` gate
    # trusts, divided by XLA's compiled "bytes accessed" for the same
    # wired search_flat program. Pinned band [0.5, 2.0] — outside it the
    # model no longer describes the machine and COST_baseline.json
    # drift numbers stop meaning anything. Deterministic (no timing), so
    # the band is hard on both sides.
    (("scan", "flat_scan_bytes_ratio"), "floor", False, 0.5),
    (("scan", "flat_scan_bytes_ratio"), "ceiling", False, 2.0),
    # compression cascade (retrieval_quality.cascade_metrics — hamming
    # prefilter -> ADC top-p1 -> float rerank of top-p2). The acceptance
    # criterion is the RATIO: the funnel's ground-truth recall@10 must
    # reach 0.95x the exhaustive flat ADC oracle on the same codebook
    # (measured ~1.3x — the float rerank corrects quantization noise).
    # cascade_recall10 is additionally gated against the baseline value
    # (tolerance band) to catch absolute regressions the ratio hides
    # when flat moves too; the float-touched fraction is a pinned 5%
    # budget ceiling — the funnel's defining contract.
    (("cascade", "cascade_recall10"), "higher", False, None),
    (("cascade", "cascade_recall10_vs_flat"), "floor", False, 0.95),
    (("cascade", "cascade_ms_per_query"), "lower", True, None),
    (("cascade", "cascade_float_frac"), "ceiling", False, 0.05),
    # live churn (benchmarks/churn.py — LSM segment store under
    # interleaved add/delete). The 0.99 floor IS the tentpole acceptance
    # criterion: a grown-and-pruned index must answer within 1% of a
    # from-scratch rebuild of the same live corpus. recall itself is
    # additionally gated against the baseline band; compact_ms is the
    # steady-state segment fold, calib-normalised like other wall-clock
    # metrics.
    (("churn", "churn_recall10_vs_rebuild"), "floor", False, 0.99),
    (("churn", "churn_recall10"), "higher", False, None),
    (("churn", "compact_ms"), "lower", True, None),
]


def _get(d: dict, path):
    for k in path:
        if not isinstance(d, dict) or k not in d:
            return None
        d = d[k]
    return d


def compare(current: dict, baseline: dict, tolerance: float):
    """Returns (report_lines, n_failures)."""
    calib_cur = float(current.get("calib_ms") or 1.0)
    calib_base = float(baseline.get("calib_ms") or 1.0)
    speed = calib_base / calib_cur  # <1 -> this machine is slower
    lines = [f"calib_ms: baseline {calib_base:.4f}  current {calib_cur:.4f}"
             f"  (speed ratio {speed:.2f})"]
    failures = 0
    for path, direction, normalise, floor in GATED:
        name = ".".join(path)
        cur, base = _get(current, path), _get(baseline, path)
        if direction in ("floor", "ceiling"):
            base = floor              # pinned, never from the baseline file
        if base is None:
            lines.append(f"SKIP {name}: not in baseline")
            continue
        if cur is None:
            lines.append(f"FAIL {name}: missing from current run")
            failures += 1
            continue
        cur_n, base_n = float(cur), float(base)
        if normalise:
            # forgive a slower machine (speed < 1); never penalise a
            # faster one — fixed waits don't scale with compute speed
            forgive = min(speed, 1.0)
            if direction == "lower":      # latency: scale to baseline speed
                cur_n = cur_n * forgive
            else:                         # throughput
                cur_n = cur_n / forgive
        if direction == "lower":
            ok = cur_n <= base_n * (1.0 + tolerance)
            delta = (cur_n - base_n) / base_n if base_n else 0.0
            tol_s = f"tol {tolerance:.0%}"
        elif direction == "floor":
            ok = cur_n >= base_n
            delta = (base_n - cur_n) / base_n if base_n else 0.0
            tol_s = "pinned hard floor, no tolerance"
        elif direction == "ceiling":
            ok = cur_n <= base_n
            delta = (cur_n - base_n) / base_n if base_n else 0.0
            tol_s = "pinned hard ceiling, no tolerance"
        else:
            ok = cur_n >= base_n * (1.0 - tolerance)
            delta = (base_n - cur_n) / base_n if base_n else 0.0
            tol_s = f"tol {tolerance:.0%}"
        tag = "PASS" if ok else "FAIL"
        norm = " (normalised)" if normalise else ""
        lines.append(f"{tag} {name}: baseline {base_n:.4f}  current "
                     f"{cur_n:.4f}{norm}  regression {delta:+.1%} "
                     f"({tol_s})")
        failures += 0 if ok else 1
    return lines, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="metrics JSON from --smoke --json")
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--tolerance", type=float, default=0.20)
    args = ap.parse_args(argv)
    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    lines, failures = compare(current, baseline, args.tolerance)
    for ln in lines:
        print(ln)
    if failures:
        print(f"BENCH GATE: {failures} metric(s) regressed beyond "
              f"{args.tolerance:.0%}")
        return 1
    print("BENCH GATE: ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
