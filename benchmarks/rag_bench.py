"""Paper Table V: RAG legal-summarisation — ROUGE-L, hallucination rate,
end-to-end latency, per retriever configuration.

The generator is a small LM *trained here* (a few hundred steps) to answer
fact queries from retrieved context (data/synthetic.py::make_fact_corpus);
hallucination is exactly measurable on this corpus (docs/design.md §1).
Claim validated: better retrieval -> lower hallucination; quantized+pruned
retrieval preserves ROUGE-L while cutting latency; a weak (single-vector)
retriever raises hallucination sharply (the paper's DistilCol row).
"""
from __future__ import annotations

from typing import List

import jax
import numpy as np

from repro.core import late_interaction as li
from repro.core import rag
from repro.retrieval import Corpus, HPCConfig, Retriever
from repro.data import synthetic
from repro.models import transformer as T
from repro.optim import optimizer as opt

N_DOCS, N_FACTS, FPD = 96, 400, 3  # ~230 distinct fact protos < K=256
SEQ = 24


def train_generator(key, corpus, vocab, rcfg, steps: int = 300,
                    verbose: bool = True):
    lm_cfg = T.LMConfig(n_layers=3, d_model=96, n_heads=4, n_kv_heads=2,
                        d_ff=192, vocab=vocab["size"], q_chunk=8,
                        loss_chunk=SEQ, tie_embeddings=True)
    params = T.init(key, lm_cfg)
    ocfg = opt.AdamWConfig(lr=2e-3, total_steps=steps, warmup_steps=20,
                           weight_decay=0.01)
    state = opt.init(ocfg, params)
    step = jax.jit(lambda p, s, b: T.train_step(p, s, b, lm_cfg, ocfg))
    for i in range(steps):
        bkey = jax.random.fold_in(key, i)
        batch = rag.make_rag_train_batch(bkey, corpus, vocab, rcfg,
                                         batch=32, seq_len=SEQ,
                                         n_docs=N_DOCS)
        params, state, m = step(params, state, batch)
        if verbose and i % 100 == 0:
            print(f"    generator step {i}: loss {float(m['loss']):.3f}")
    if verbose:
        print(f"    generator final loss {float(m['loss']):.3f}")
    return params, lm_cfg


def run(seed: int = 0, steps: int = 300, verbose: bool = True) -> List[dict]:
    k_data, k_gen, k_build = jax.random.split(jax.random.PRNGKey(seed), 3)
    corpus, vocab = synthetic.make_fact_corpus(
        k_data, n_docs=N_DOCS, n_facts_vocab=N_FACTS, facts_per_doc=FPD,
        dim=64, n_patches=12, n_queries=64, seq_len=16)
    rcfg_base = rag.RAGConfig(top_k_docs=2, facts_per_doc=FPD,
                              fact0=vocab["fact0"], max_answer=FPD)
    gen_params, lm_cfg = train_generator(k_gen, corpus, vocab, rcfg_base,
                                         steps=steps, verbose=verbose)

    retrievers = [
        ("ColPali-Full", HPCConfig(backend="float_flat",
                                   prune_side="none")),
        ("HPC(K=256,p=60)", HPCConfig(k=256, p=60.0, backend="flat",
                                      prune_side="doc", rerank=8)),
        ("HPC-Binary(K=512)", HPCConfig(k=512, p=60.0, backend="hamming",
                                        prune_side="doc")),
    ]
    rows = []
    for name, cfg in retrievers:
        import dataclasses
        rcfg = dataclasses.replace(rcfg_base, retriever=cfg)
        state = Retriever(cfg).build(
            k_build, Corpus(corpus.doc_patches, corpus.doc_mask,
                            corpus.doc_salience))
        m = rag.rag_pipeline(state, gen_params, corpus, rcfg, lm_cfg,
                             n_facts_vocab=N_FACTS)
        rows.append({"retriever": name, **m})
        if verbose:
            print(f"  {name:20s} ROUGE-L={m['rouge_l']:.3f} "
                  f"halluc={m['hallucination']*100:5.1f}% "
                  f"acc={m['answer_acc']:.2f} "
                  f"latency={m['latency_ms']:.1f} ms/q")

    # DistilCol-style weak retriever: single-vector search feeding the
    # same generator (the paper's high-hallucination row)
    scores = li.single_vector_score(corpus.query_patches, corpus.query_mask,
                                    corpus.doc_patches, corpus.doc_mask)
    # JAX04-safe: top_k_docs=2 <= N_DOCS (weak-retriever oracle)
    _, weak_ids = jax.lax.top_k(scores, rcfg_base.top_k_docs)  # noqa: JAX04

    import time
    t0 = time.perf_counter()
    doc_toks = corpus.doc_tokens[weak_ids]
    keep = FPD + 1
    prompt_len = rcfg_base.top_k_docs * keep + corpus.query_tokens.shape[1]
    prompt = rag.build_prompt(doc_toks, corpus.query_tokens, rcfg_base,
                              prompt_len)
    gen = rag.greedy_generate(gen_params, prompt, lm_cfg, FPD, prompt_len)
    gen = np.asarray(jax.block_until_ready(gen))
    dt = (time.perf_counter() - t0) * 1e3 / gen.shape[0]
    ctx = [set(r.ravel().tolist())
           for r in np.asarray(corpus.doc_facts)[np.asarray(weak_ids)]]
    gsets = rag.extract_facts(gen, vocab["fact0"], N_FACTS)
    halluc = rag.hallucination_rate(gsets, ctx)
    rouges = [rag.rouge_l(sorted(g), sorted(set(r.tolist())))
              for g, r in zip(gsets, np.asarray(corpus.gold_facts))]
    rows.append({"retriever": "DistilCol", "rouge_l": float(np.mean(rouges)),
                 "hallucination": halluc, "latency_ms": dt})
    if verbose:
        print(f"  {'DistilCol':20s} ROUGE-L={np.mean(rouges):.3f} "
              f"halluc={halluc*100:5.1f}% latency={dt:.1f} ms/q")
    return rows


if __name__ == "__main__":
    run()
