"""Shared benchmark utilities: IR metrics + timing."""
from __future__ import annotations

import time
from typing import Callable, Dict

import jax
import numpy as np

# Relevance grade that counts as a "target" document (synthetic corpora
# grade 0..3). The single shared definition for hit@k — the tier-1
# acceptance test (tests/test_index_pipeline.py) and the CI bench-gate
# metric (hit10_quantized_flat) must measure the same quantity.
HIT_RELEVANCE = 2


def dcg_at_k(rels: np.ndarray, k: int) -> float:
    rels = np.asarray(rels)[:k]
    gains = (2.0 ** rels - 1.0)
    discounts = 1.0 / np.log2(np.arange(2, rels.size + 2))
    return float(np.sum(gains * discounts))


def ndcg_at_k(ranked_rels: np.ndarray, all_rels: np.ndarray, k: int) -> float:
    ideal = np.sort(np.asarray(all_rels))[::-1]
    idcg = dcg_at_k(ideal, k)
    return dcg_at_k(ranked_rels, k) / idcg if idcg > 0 else 0.0


def recall_at_k(ranked_rels: np.ndarray, all_rels: np.ndarray, k: int,
                rel_threshold: int = 2) -> float:
    n_rel = int(np.sum(np.asarray(all_rels) >= rel_threshold))
    if n_rel == 0:
        return 0.0
    got = int(np.sum(np.asarray(ranked_rels)[:k] >= rel_threshold))
    return got / n_rel


def average_precision(ranked_rels: np.ndarray, all_rels: np.ndarray,
                      rel_threshold: int = 2) -> float:
    rels = np.asarray(ranked_rels) >= rel_threshold
    n_rel = int(np.sum(np.asarray(all_rels) >= rel_threshold))
    if n_rel == 0:
        return 0.0
    hits, score = 0, 0.0
    for i, r in enumerate(rels):
        if r:
            hits += 1
            score += hits / (i + 1)
    return score / n_rel


def retrieval_metrics(ids: np.ndarray, relevance: np.ndarray, k: int = 10
                      ) -> Dict[str, float]:
    """ids (Q, >=k) ranked doc ids; relevance (Q, N) graded.

    Negative ids are the backend sentinel for "no document in this slot"
    (see IndexBackend.search) and are dropped, not scored — a -1 row must
    read as a miss, never as document N-1.
    """
    ndcgs, recalls, aps, hits = [], [], [], []
    for qi in range(ids.shape[0]):
        rel_row = np.asarray(relevance[qi])
        ids_q = np.asarray(ids[qi])
        ranked = rel_row[ids_q[ids_q >= 0]]
        ndcgs.append(ndcg_at_k(ranked, rel_row, k))
        recalls.append(recall_at_k(ranked, rel_row, k))
        aps.append(average_precision(ranked[:100], rel_row))
        hits.append(float((ranked[:k] >= HIT_RELEVANCE).any()))
    return {"ndcg@10": float(np.mean(ndcgs)),
            "recall@10": float(np.mean(recalls)),
            "map": float(np.mean(aps)),
            "hit@10": float(np.mean(hits))}


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-clock seconds of fn(*args) (blocking on outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def calibrate_ms() -> float:
    """Machine-speed scalar: median ms of a fixed jitted 256x256 matmul.

    The CI bench gate divides wall-clock metrics by this before comparing
    against the committed baseline, so a slower/faster runner doesn't read
    as a code regression/improvement.
    """
    import jax.numpy as jnp
    a = jnp.ones((256, 256), jnp.float32)
    f = jax.jit(lambda x: x @ x)
    return time_fn(f, a, warmup=3, iters=11) * 1e3
