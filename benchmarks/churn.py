"""Live-churn benchmark: interleaved add/delete/query without rebuild.

The LSM segment store's acceptance contract (docs/design.md §9): an index
grown via `add` and pruned via `delete` must answer with recall@10 within
1% of a from-scratch rebuild of the same live corpus, and `compact` must
fold the segments without losing live documents.

`churn_metrics` drives one backend through rounds of mutation —

    build(base) -> [add(delta); delete(sample); search] x rounds
                -> compact -> search

— and scores both the churned index and a fresh rebuild of the final
live corpus against exact float MaxSim ground truth:

  * ``churn_recall10``            — recall@10 of the churned index
  * ``rebuild_recall10``          — recall@10 of the from-scratch rebuild
  * ``churn_recall10_vs_rebuild`` — the gated ratio (floor 0.99 in
    benchmarks/bench_gate.py: within 1% of rebuild)
  * ``compact_recall10``          — recall@10 after compaction
  * ``compact_ms``                — wall-clock of the compact fold
    (calib-normalised in the gate)
  * ``live_docs`` / ``tombstone_frac`` / ``segments`` — the satellite
    accounting contract, read straight from `Retriever.build_stats`
    before compaction (deleted docs stop counting while their bytes
    are still resident).
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np


def _recall_vs_gt(ids: np.ndarray, gt, k: int = 10) -> float:
    hits, tot = 0, 0
    for row, want in zip(np.asarray(ids)[:, :k], gt):
        hits += len(set(int(x) for x in row if x >= 0)
                    & set(want[:k].tolist()))
        tot += k
    return hits / tot


def _gt_topk(q_emb, q_mask, d_emb, d_mask, ids, k: int = 10):
    """Exact float MaxSim top-k over the live corpus (the oracle)."""
    out = []
    for b in range(q_emb.shape[0]):
        sims = np.einsum("md,npd->mnp", q_emb[b], d_emb)
        sims = np.where(d_mask[None, :, :], sims, -np.inf)
        score = (sims.max(-1) * q_mask[b][:, None]).sum(0)
        out.append(ids[np.argsort(-score)[:k]])
    return out


def churn_metrics(backend: str = "flat", seed: int = 0) -> Dict[str, float]:
    import jax
    import jax.numpy as jnp

    from repro.data import synthetic
    from repro.retrieval import Corpus, HPCConfig, Query, Retriever

    spec = synthetic.CorpusSpec(n_docs=256, n_queries=32, n_patches=8,
                                n_q_patches=4, dim=32, n_topics=6,
                                patches_per_topic=8, noise=0.1)
    data = synthetic.make_retrieval_corpus(jax.random.PRNGKey(seed), spec)
    query = Query(data.query_patches, data.query_mask, data.query_salience)
    emb = np.asarray(data.doc_patches)
    msk = np.asarray(data.doc_mask)
    sal = np.asarray(data.doc_salience)

    def corpus(lo, hi):
        return Corpus(jnp.asarray(emb[lo:hi]), jnp.asarray(msk[lo:hi]),
                      jnp.asarray(sal[lo:hi]))

    cfg = HPCConfig(k=64, p=80.0, backend=backend, kmeans_iters=10,
                    kmeans_restarts=2, rerank=32)
    r = Retriever(cfg)
    key = jax.random.PRNGKey(1)

    n_base, n_total, rounds = 224, 256, 4
    state = r.build(key, corpus(0, n_base))
    rng = np.random.default_rng(seed)
    hi = n_base
    dead: set = set()
    per_round = (n_total - n_base) // rounds
    for _ in range(rounds):
        state = r.add(state, corpus(hi, hi + per_round))   # ids hi..hi+pr-1
        hi += per_round
        alive = np.array(sorted(set(range(hi)) - dead))
        kill = rng.choice(alive, size=min(6, alive.size // 4), replace=False)
        state = r.delete(state, kill)
        dead.update(int(x) for x in kill)
        r.search(state, query, k=10)        # keep the serve path hot

    live_ids = np.array(sorted(set(range(hi)) - dead))
    gt = _gt_topk(np.asarray(query.embeddings), np.asarray(query.mask),
                  emb[live_ids], msk[live_ids], live_ids)

    _, ids_churn = r.search(state, query, k=10)
    stats = r.build_stats(state)

    # same key as the churned build on purpose: the rebuild is the
    # comparison baseline, so codebook seeding must not differ
    rb_state = r.build(key, Corpus(jnp.asarray(emb[live_ids]),  # noqa: JAX01
                                   jnp.asarray(msk[live_ids]),
                                   jnp.asarray(sal[live_ids])))
    _, ids_rb = r.search(rb_state, query, k=10)
    ids_rb = np.asarray(ids_rb)
    ids_rb_global = np.where(ids_rb >= 0,
                             live_ids[np.maximum(ids_rb, 0)], -1)

    jax.block_until_ready(
        jax.tree_util.tree_leaves(r.compact(state)))   # warm the fold path
    t0 = time.perf_counter()
    state_c = r.compact(state)
    jax.block_until_ready(jax.tree_util.tree_leaves(state_c))
    compact_ms = (time.perf_counter() - t0) * 1e3
    _, ids_c = r.search(state_c, query, k=10)

    churn = _recall_vs_gt(ids_churn, gt)
    rebuild = _recall_vs_gt(ids_rb_global, gt)
    return {
        "churn_recall10": churn,
        "rebuild_recall10": rebuild,
        "churn_recall10_vs_rebuild": churn / max(rebuild, 1e-9),
        "compact_recall10": _recall_vs_gt(ids_c, gt),
        "compact_ms": compact_ms,
        "live_docs": stats["live_docs"],
        "tombstone_frac": stats["tombstone_frac"],
        "segments": stats["segments"],
    }
