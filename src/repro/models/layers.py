"""Shared model building blocks (pure JAX, init/apply style).

Every init function returns a nested dict of arrays; a parallel *_specs
function returns the same structure with logical-axis tuples for
dist/sharding.py. Tests assert the trees match for every arch config.

Conventions:
  * matmuls run in the activation dtype with fp32 accumulation
    (preferred_element_type), norms and softmax in fp32;
  * attention is GQA with RoPE; an optional chunked-local mode (Llama-4
    iRoPE-style: attend only within a fixed chunk window) makes the decode
    path sub-quadratic for the long_500k cell;
  * the query axis is processed in chunks via lax.scan (flash-style memory
    bound: scores never materialise beyond (B, H, q_chunk, K));
  * MoE uses sort-based grouped matmuls with a static capacity factor
    (dropping, Switch-style aux loss). Expert weights are stacked (E, ...)
    so EP is a sharding annotation, not a code path.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import NULL

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key: Array, in_dim: int, out_dim: int, dtype) -> Array:
    scale = 1.0 / jnp.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def embed_init(key: Array, vocab: int, dim: int, dtype) -> Array:
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: Array, w: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x (..., S, H, hd), positions (..., S) -> rotated x."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                     # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention (flash-style q-chunk scan; full or chunked-local mask)
# ---------------------------------------------------------------------------

def attn_init(key: Array, d_model: int, n_heads: int, n_kv: int,
              head_dim: int, qkv_bias: bool, dtype) -> Dict[str, Array]:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, n_kv * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, n_kv * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def attn_specs(qkv_bias: bool) -> Dict[str, tuple]:
    s = {
        "wq": ("embed", "qkv_out"),
        "wk": ("embed", "kv_out"),
        "wv": ("embed", "kv_out"),
        "wo": ("qkv_out", "embed"),
    }
    if qkv_bias:
        s["bq"] = ("qkv_out",)
        s["bk"] = ("kv_out",)
        s["bv"] = ("kv_out",)
    return s


def _qkv(p, x, n_heads, n_kv, head_dim):
    b, s, _ = x.shape
    dt = x.dtype
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return (q.reshape(b, s, n_heads, head_dim),
            k.reshape(b, s, n_kv, head_dim),
            v.reshape(b, s, n_kv, head_dim))


def _sdpa_chunk(q_blk, k, v, mask_blk):
    """q_blk (B, qc, Hkv, G, hd); k/v (B, T, Hkv, hd); mask (B?, qc, T).

    Returns (out (B, qc, Hkv, G, hd), attn_mass (B, T)) — attn_mass is the
    per-key attention mass (summed over heads/queries) for salience.
    """
    hd = q_blk.shape[-1]
    scores = jnp.einsum("bqkgh,btkh->bkgqt", q_blk, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    scores = jnp.where(mask_blk[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqt,btkh->bqkgh", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32).astype(v.dtype)
    mass = jnp.sum(probs, axis=(1, 2, 3))                # (B, T)
    return out, mass


def attention(p: Dict[str, Array], x: Array, positions: Array, *,
              n_heads: int, n_kv: int, head_dim: int, theta: float,
              chunk: int = 0, q_chunk: int = 512, shd=NULL,
              want_salience: bool = False,
              unroll: bool = False) -> Tuple[Array, Optional[Array]]:
    """Causal (optionally chunked-local) self-attention over x (B, S, D).

    chunk > 0 limits attention to the iRoPE-style window
    [floor(i/chunk)*chunk, i]. q_chunk bounds the materialised score block.
    """
    b, s, _ = x.shape
    g = n_heads // n_kv
    q, k, v = _qkv(p, x, n_heads, n_kv, head_dim)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    # §Perf iteration glm-1: no explicit q/k constraints — the fused
    # qkv_out/kv_out weight shardings already pin the projection outputs,
    # and forcing a head-sharded layout here made GSPMD replicate-and-
    # repartition k/v every layer ("involuntary full rematerialization"),
    # dominating the collective term (38.5 s on glm4-9b/train_4k).

    qc = min(q_chunk, s)
    while s % qc != 0:
        qc //= 2
    n_blocks = s // qc
    local = chunk > 0 and chunk < s
    if local:
        # q blocks must not straddle window boundaries
        assert chunk % qc == 0, (chunk, qc)
        # pad keys so the last window's slice stays in bounds
        pad = (-s) % chunk
        if pad:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qg = q.reshape(b, n_blocks, qc, n_kv, g, head_dim)

    def body(carry, blk):
        mass_acc = carry
        q_blk, blk_idx = blk
        s0 = blk_idx * qc
        i = s0 + jnp.arange(qc)[:, None]                 # (qc, 1) global q pos
        if local:
            w0 = (s0 // chunk) * chunk                   # window start (static)
            k_win = jax.lax.dynamic_slice_in_dim(k, w0, chunk, axis=1)
            v_win = jax.lax.dynamic_slice_in_dim(v, w0, chunk, axis=1)
            j = w0 + jnp.arange(chunk)[None, :]
            mask = (j <= i)
            out_blk, mass = _sdpa_chunk(q_blk, k_win, v_win,
                                        jnp.broadcast_to(mask, (b, qc, chunk)))
            mass_acc = jax.lax.dynamic_update_slice_in_dim(
                mass_acc, jax.lax.dynamic_slice_in_dim(
                    mass_acc, w0, chunk, axis=1) + mass, w0, axis=1)
        else:
            j = jnp.arange(s)[None, :]
            mask = (j <= i)
            out_blk, mass = _sdpa_chunk(q_blk, k, v,
                                        jnp.broadcast_to(mask, (b, qc, s)))
            mass_acc = mass_acc + mass
        return mass_acc, out_blk

    s_pad = k.shape[1]                                   # s, or padded to chunk
    mass0 = jnp.zeros((b, s_pad), jnp.float32)
    blk_ids = jnp.arange(n_blocks)
    qg_t = jnp.moveaxis(qg, 1, 0)                        # (n_blocks, b, qc, ...)
    # Inner remat: without it the q-chunk scan saves every chunk's (H, qc,
    # T) probability block for the backward pass — O(S^2) memory per layer.
    # Checkpointing the body keeps only (carry, ys) and recomputes probs in
    # bwd (flash-attention memory behaviour in pure jnp).
    mass, outs = jax.lax.scan(jax.checkpoint(body), mass0, (qg_t, blk_ids),
                              unroll=n_blocks if unroll else 1)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, n_heads * head_dim)
    out = out @ p["wo"].astype(out.dtype)
    sal = mass[:, :s] / s if want_salience else None
    return out, sal


def attention_decode(p: Dict[str, Array], x: Array, pos: Array,
                     k_cache: Array, v_cache: Array, *,
                     n_heads: int, n_kv: int, head_dim: int, theta: float,
                     chunk: int = 0, shd=NULL
                     ) -> Tuple[Array, Array, Array]:
    """Single-token decode. x (B, 1, D); caches (B, S, n_kv, hd); pos () i32.

    Returns (out (B, 1, D), new_k_cache, new_v_cache). For chunked-local
    layers only a static `chunk`-sized window of the cache is touched
    (sub-quadratic decode, docs/design.md §6).
    """
    b, _, _ = x.shape
    s_max = k_cache.shape[1]
    g = n_heads // n_kv
    q, k_new, v_new = _qkv(p, x, n_heads, n_kv, head_dim)
    posb = jnp.full((b, 1), pos)
    q = apply_rope(q, posb, theta)
    k_new = apply_rope(k_new, posb, theta)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, pos, axis=1)

    if chunk > 0 and chunk < s_max:
        # cache length must tile into windows (enforced by init_cache)
        assert s_max % chunk == 0, (s_max, chunk)
        w0 = (pos // chunk) * chunk
        k_att = jax.lax.dynamic_slice_in_dim(k_cache, w0, chunk, axis=1)
        v_att = jax.lax.dynamic_slice_in_dim(v_cache, w0, chunk, axis=1)
        j = w0 + jnp.arange(chunk)[None, :]
    else:
        k_att, v_att = k_cache, v_cache
        j = jnp.arange(s_max)[None, :]

    mask = jnp.broadcast_to(j <= pos, (b, 1, j.shape[1]))
    qg = q.reshape(b, 1, n_kv, g, head_dim)
    out, _ = _sdpa_chunk(qg, k_att, v_att, mask)
    out = out.reshape(b, 1, n_heads * head_dim) @ p["wo"].astype(x.dtype)
    return out, k_cache, v_cache


# ---------------------------------------------------------------------------
# FFN: dense SwiGLU
# ---------------------------------------------------------------------------

def ffn_init(key: Array, d_model: int, d_ff: int, dtype) -> Dict[str, Array]:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], d_model, d_ff, dtype),
        "w_up": dense_init(ks[1], d_model, d_ff, dtype),
        "w_down": dense_init(ks[2], d_ff, d_model, dtype),
    }


def ffn_specs() -> Dict[str, tuple]:
    return {
        "w_gate": ("embed", "mlp"),
        "w_up": ("embed", "mlp"),
        "w_down": ("mlp", "embed"),
    }


def ffn_apply(p: Dict[str, Array], x: Array) -> Array:
    dt = x.dtype
    h = jax.nn.silu(x @ p["w_gate"].astype(dt)) * (x @ p["w_up"].astype(dt))
    return h @ p["w_down"].astype(dt)


# ---------------------------------------------------------------------------
# MoE FFN: top-k routing, sort-based grouped matmul, capacity dropping
# ---------------------------------------------------------------------------

def moe_capacity(n_tokens: int, n_experts: int, top_k: int,
                 capacity_factor: float) -> int:
    import math
    c = math.ceil(n_tokens * top_k * capacity_factor / n_experts)
    return max(8, -(-c // 8) * 8)   # round up to a multiple of 8


def moe_init(key: Array, d_model: int, d_ff: int, n_experts: int,
             n_shared: int, dtype) -> Dict[str, Array]:
    ks = jax.random.split(key, 5)
    scale = 1.0 / jnp.sqrt(d_model)
    p = {
        "router": (jax.random.normal(ks[0], (d_model, n_experts))
                   * scale).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (n_experts, d_model, d_ff))
                   * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (n_experts, d_model, d_ff))
                 * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (n_experts, d_ff, d_model))
                   * (1.0 / jnp.sqrt(d_ff))).astype(dtype),
    }
    if n_shared:
        p["shared"] = ffn_init(ks[4], d_model, d_ff * n_shared, dtype)
    return p


def moe_specs(n_shared: int) -> Dict[str, Any]:
    s = {
        "router": ("embed", None),
        "w_gate": ("expert", "embed", "expert_mlp"),
        "w_up": ("expert", "embed", "expert_mlp"),
        "w_down": ("expert", "expert_mlp", "embed"),
    }
    if n_shared:
        s["shared"] = ffn_specs()
    return s


def moe_apply(p: Dict[str, Array], x: Array, *, top_k: int,
              capacity_factor: float = 1.25, shd=NULL,
              expert_chunks: int = 1) -> Tuple[Array, Array]:
    """x (T, D) -> (out (T, D), aux_loss ()).

    Grouped expert-parallel dispatch (EXPERIMENTS.md §Perf iteration moe-2):
    tokens are split into G groups matching the data sharding of the token
    dim, so routing / capacity grouping / gathers are *per-group batched
    ops* that GSPMD keeps local (no global gather that would replicate the
    (E, C, D) buffer). The two sharding constraints around the expert
    einsums flip the sharded dim group->expert and back, which the
    partitioner lowers to the canonical pair of all-to-alls of EP:

      (G@dp, E, Cg, D) --a2a--> (G, E@dp, Cg, D) -> expert FFN
                       <--a2a-- back, local combine per group.

    Capacity is per-group: Cg = ceil(T/G * k * cf / E) (Switch-style drops
    are now per data shard, as in real EP systems).
    """
    t, d = x.shape
    e = p["w_gate"].shape[0]
    g = shd.num_shards("tokens", t)
    tg = t // g
    c = moe_capacity(tg, e, top_k, capacity_factor)

    xg_tok = shd.constraint(x.reshape(g, tg, d), "tokens", None, None)
    logits = jnp.einsum("gtd,de->gte", xg_tok.astype(jnp.float32),
                        p["router"])                       # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    # JAX04-safe: router top_k <= n_experts by MoE config contract
    gate, idx = jax.lax.top_k(probs, top_k)  # noqa: JAX04 - (G, Tg, k)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    flat_e = idx.reshape(g, tg * top_k)
    flat_t = jnp.broadcast_to(
        jnp.arange(tg * top_k, dtype=jnp.int32) // top_k, (g, tg * top_k))
    order = jnp.argsort(flat_e, axis=-1, stable=True)
    se = jnp.take_along_axis(flat_e, order, axis=-1)       # (G, Tg*k)
    st = jnp.take_along_axis(flat_t, order, axis=-1)
    counts = jax.vmap(lambda fe: jax.ops.segment_sum(
        jnp.ones_like(fe, jnp.int32), fe, num_segments=e))(flat_e)
    group_start = jnp.cumsum(counts, axis=-1) - counts     # (G, E) exclusive
    pos = (jnp.arange(tg * top_k, dtype=jnp.int32)[None]
           - jnp.take_along_axis(group_start, se, axis=-1))
    keep = pos < c
    target = jnp.where(keep, se * c + pos, e * c)          # (G, Tg*k)

    def build_slots(tgt, st_):
        tfs = jnp.full((e * c + 1,), tg, jnp.int32)
        return tfs.at[tgt].set(st_, mode="drop")[:e * c]
    token_for_slot = jax.vmap(build_slots)(target, st)     # (G, E*C)

    x_pad = jnp.concatenate(
        [xg_tok, jnp.zeros((g, 1, d), x.dtype)], axis=1)   # (G, Tg+1, D)
    gate_sorted = jnp.take_along_axis(
        gate.reshape(g, tg * top_k), order, axis=-1).astype(x.dtype)

    # Expert-chunked dispatch (§Perf iteration moe-3): process eb = E/chunks
    # experts at a time so the dispatched activation buffer is
    # (G, eb, C, D) instead of (G, E, C, D) — bounds kimi-k2's per-layer
    # dispatch memory at the cost of `chunks` sequential block matmuls.
    assert e % expert_chunks == 0, (e, expert_chunks)
    eb = e // expert_chunks

    def one_block(carry, b):
        out_acc = carry
        slots_blk = jax.lax.dynamic_slice_in_dim(
            token_for_slot, b * eb * c, eb * c, axis=1)    # (G, eb*C)
        xg = jax.vmap(lambda xp, tfs: xp[tfs])(x_pad, slots_blk)
        xg = xg.reshape(g, eb, c, d)
        # all-to-all #1: group-sharded -> expert-sharded
        xg = shd.constraint(xg, None, "expert", None, None)
        wg = jax.lax.dynamic_slice_in_dim(p["w_gate"], b * eb, eb, 0)
        wu = jax.lax.dynamic_slice_in_dim(p["w_up"], b * eb, eb, 0)
        wd = jax.lax.dynamic_slice_in_dim(p["w_down"], b * eb, eb, 0)
        h = jnp.einsum("gecd,edf->gecf", xg, wg.astype(x.dtype),
                       preferred_element_type=jnp.float32).astype(x.dtype)
        u = jnp.einsum("gecd,edf->gecf", xg, wu.astype(x.dtype),
                       preferred_element_type=jnp.float32).astype(x.dtype)
        y = jnp.einsum("gecf,efd->gecd", jax.nn.silu(h) * u,
                       wd.astype(x.dtype),
                       preferred_element_type=jnp.float32).astype(x.dtype)
        # all-to-all #2: expert-sharded -> group-sharded
        y = shd.constraint(y, "tokens", None, None, None)
        y_flat = y.reshape(g, eb * c, d)

        # combine this block's slots back into tokens
        in_blk = (se >= b * eb) & (se < (b + 1) * eb)
        tgt_local = jnp.clip(target - b * eb * c, 0, eb * c - 1)
        slot_out = jax.vmap(lambda yf, tgt: yf[tgt])(y_flat, tgt_local)
        w_blk = jnp.where(in_blk & keep, gate_sorted, 0.0).astype(x.dtype)

        def combine(so, st_, gs):
            return jnp.zeros((tg, d), x.dtype).at[st_].add(gs[:, None] * so)
        out_acc = out_acc + jax.vmap(combine)(slot_out, st, w_blk)
        return out_acc, None

    out0 = jnp.zeros((g, tg, d), x.dtype)
    if expert_chunks == 1:
        out, _ = one_block(out0, 0)
    else:
        out, _ = jax.lax.scan(jax.checkpoint(one_block), out0,
                              jnp.arange(expert_chunks))
    out = shd.constraint(out, "tokens", None, None).reshape(t, d)

    if "shared" in p:
        out = out + ffn_apply(p["shared"], x)

    # Switch-style load-balance aux loss (global over all groups).
    me = jnp.mean(probs, axis=(0, 1))                      # (E,)
    dispatch_frac = jax.vmap(lambda k_, se_: jax.ops.segment_sum(
        jnp.where(k_, 1.0, 0.0), se_, num_segments=e))(keep, se)
    dispatch_frac = jnp.sum(dispatch_frac, axis=0) / (t * top_k)
    aux = e * jnp.sum(me * dispatch_frac)
    return out, aux
