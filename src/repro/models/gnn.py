"""PNA — Principal Neighbourhood Aggregation GNN (arXiv:2004.05718).

Message passing is built on jax.ops.segment_sum / segment_max / segment_min
over an edge-index (docs/design.md: JAX has no CSR SpMM — the scatter/segment
formulation IS the system here). A PNA layer:

    m_e   = MLP_pre([h_src, h_dst])                  per edge
    agg_a = segment_{mean,max,min,std}(m_e -> dst)   4 aggregators
    scaled= agg_a * {1, log(d+1)/delta, delta/log(d+1)}   3 scalers
    h'    = h + MLP_post([h, concat_{a,s} scaled])   residual update

Supports node classification (full-graph / sampled-subgraph) and batched
small-graph property prediction (mean readout per graph id).

Sharding: edges shard flat over all mesh axes ("edge" rule) and node
tensors over ("nodes") — cells pad both counts so they divide every mesh;
GSPMD reduces per-shard segment partials with one collective per
aggregator. Paper-technique applicability: K-Means feature quantization
optionally compresses the input node features (docs/design.md §5); attention
pruning does not apply (PNA is attention-free).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import NULL
from repro.models import layers as L
from repro.optim import optimizer as opt

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PNAConfig:
    name: str = "pna"
    n_layers: int = 4
    d_hidden: int = 75
    d_feat: int = 1433
    n_classes: int = 7
    delta: float = 2.5              # avg log-degree normaliser (PNA eq. 5)
    task: str = "node"              # "node" | "graph"
    param_dtype: str = "float32"

    @property
    def pdtype(self):
        return jnp.bfloat16 if self.param_dtype == "bfloat16" else jnp.float32

    def param_count(self) -> int:
        d = self.d_hidden
        per_layer = (2 * d) * d + (d + 12 * d) * d + d * d
        return self.d_feat * d + self.n_layers * per_layer + d * self.n_classes


def _mlp_init(key, dims, dtype):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": L.dense_init(ks[i], dims[i], dims[i + 1], dtype),
             "b": jnp.zeros((dims[i + 1],), dtype)}
            for i in range(len(dims) - 1)]


def _mlp_specs(dims):
    return [{"w": (None, None), "b": (None,)} for _ in range(len(dims) - 1)]


def _mlp_apply(layers, x, act=jax.nn.relu):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i < len(layers) - 1:
            x = act(x)
    return x


def init(key: Array, cfg: PNAConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, cfg.n_layers + 2)
    d = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        k1, k2 = jax.random.split(ks[i])
        layers.append({
            "pre": _mlp_init(k1, (2 * d, d), cfg.pdtype),
            "post": _mlp_init(k2, (13 * d, d), cfg.pdtype),  # h + 12 aggs
        })
    return {
        "encoder": _mlp_init(ks[-2], (cfg.d_feat, d), cfg.pdtype),
        "layers": layers,
        "head": _mlp_init(ks[-1], (d, cfg.n_classes), cfg.pdtype),
    }


def param_specs(cfg: PNAConfig) -> Dict[str, Any]:
    layers = [{"pre": _mlp_specs((0, 0)), "post": _mlp_specs((0, 0))}
              for _ in range(cfg.n_layers)]
    return {
        "encoder": _mlp_specs((0, 0)),
        "layers": layers,
        "head": _mlp_specs((0, 0)),
    }


def _pna_aggregate(msgs: Array, dst: Array, n_nodes: int, deg: Array,
                   delta: float) -> Array:
    """msgs (E, d), dst (E,) -> (N, 12*d) [4 aggregators x 3 scalers]."""
    ones = jnp.ones((msgs.shape[0],), msgs.dtype)
    cnt = jnp.maximum(jax.ops.segment_sum(ones, dst, num_segments=n_nodes), 1.0)
    s = jax.ops.segment_sum(msgs, dst, num_segments=n_nodes)
    mean = s / cnt[:, None]
    sq = jax.ops.segment_sum(msgs * msgs, dst, num_segments=n_nodes)
    std = jnp.sqrt(jnp.maximum(sq / cnt[:, None] - mean * mean, 0.0) + 1e-5)
    mx = jax.ops.segment_max(msgs, dst, num_segments=n_nodes)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    mn = jax.ops.segment_min(msgs, dst, num_segments=n_nodes)
    mn = jnp.where(jnp.isfinite(mn), mn, 0.0)
    agg = jnp.concatenate([mean, mx, mn, std], axis=-1)   # (N, 4d)

    logd = jnp.log(deg + 1.0)[:, None]
    amp = logd / delta
    att = delta / jnp.maximum(logd, 1e-5)
    return jnp.concatenate([agg, agg * amp, agg * att], axis=-1)  # (N, 12d)


def forward(params: Dict[str, Any], feats: Array, edge_index: Array,
            cfg: PNAConfig, shd=NULL, graph_ids: Optional[Array] = None,
            n_graphs: int = 0) -> Array:
    """feats (N, d_feat), edge_index (2, E) int32 -> logits.

    node task: (N, n_classes); graph task: (n_graphs, n_classes)
    (mean readout over graph_ids).
    """
    n = feats.shape[0]
    src, dst = edge_index[0], edge_index[1]
    h = _mlp_apply(params["encoder"], feats.astype(cfg.pdtype))
    h = shd.constraint(h, "nodes", None)
    deg = jax.ops.segment_sum(jnp.ones((src.shape[0],), h.dtype), dst,
                              num_segments=n)

    for lp in params["layers"]:
        # gathered edge tensors are edge-sharded (the big buffers at
        # ogb_products scale: 62M x 2d); node tensors node-sharded
        h_src = shd.constraint(jnp.take(h, src, axis=0), "edge", None)
        h_dst = shd.constraint(jnp.take(h, dst, axis=0), "edge", None)
        msgs = _mlp_apply(lp["pre"], jnp.concatenate([h_src, h_dst], -1))
        msgs = shd.constraint(msgs, "edge", None)
        agg = _pna_aggregate(msgs, dst, n, deg, cfg.delta)
        agg = shd.constraint(agg, "nodes", None)
        upd = _mlp_apply(lp["post"], jnp.concatenate([h, agg], -1))
        h = h + jax.nn.relu(upd)
        h = shd.constraint(h, "nodes", None)

    if cfg.task == "graph":
        assert graph_ids is not None and n_graphs > 0
        pooled = jax.ops.segment_sum(h, graph_ids, num_segments=n_graphs)
        cnt = jnp.maximum(jax.ops.segment_sum(
            jnp.ones((n,), h.dtype), graph_ids, num_segments=n_graphs), 1.0)
        h = pooled / cnt[:, None]
    return _mlp_apply(params["head"], h).astype(jnp.float32)


def loss_fn(params, batch: Dict[str, Array], cfg: PNAConfig, shd=NULL):
    """CE on labelled nodes (label -1 = unlabelled/padding) or graphs."""
    logits = forward(params, batch["feats"], batch["edge_index"], cfg, shd,
                     graph_ids=batch.get("graph_ids"),
                     n_graphs=int(batch["graph_labels"].shape[0])
                     if "graph_labels" in batch else 0)
    labels = (batch["graph_labels"] if "graph_labels" in batch
              else batch["labels"])
    valid = labels >= 0
    safe = jnp.maximum(labels, 0)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[:, None], axis=-1)[:, 0]
    ce = jnp.where(valid, logz - gold, 0.0)
    loss = jnp.sum(ce) / jnp.maximum(jnp.sum(valid), 1)
    acc = jnp.sum(jnp.where(valid, jnp.argmax(logits, -1) == labels, 0)) \
        / jnp.maximum(jnp.sum(valid), 1)
    return loss, {"acc": acc}


def train_step(params, opt_state, batch, cfg: PNAConfig,
               opt_cfg: opt.AdamWConfig, shd=NULL):
    (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, batch, cfg, shd)
    params, opt_state, om = opt.update(opt_cfg, grads, opt_state, params)
    return params, opt_state, {"loss": loss, **parts, **om}


def serve_step(params, batch, cfg: PNAConfig, shd=NULL):
    """Inference forward (full-batch scoring)."""
    return forward(params, batch["feats"], batch["edge_index"], cfg, shd,
                   graph_ids=batch.get("graph_ids"),
                   n_graphs=int(batch["graph_labels"].shape[0])
                   if "graph_labels" in batch else 0)
