"""RecSys models: DLRM (MLPerf), DCN-v2, DIN, DIEN.

Substrate notes (docs/design.md):
  * JAX has no nn.EmbeddingBag — `embedding_bag` here is jnp.take +
    jax.ops.segment_sum (sum/mean modes), the standard JAX formulation;
  * embedding tables are a list of (rows_i, dim) arrays, row-sharded over
    the "model" mesh axis ("table_rows" rule); dense MLPs replicate;
  * the paper transfer: `quantize_tables` compresses every table to 1-byte
    K-Means codes + a shared per-table codebook (HPC-ColPali §III-B applied
    to embedding storage — 32x/57x arithmetic identical), with
    decode-on-lookup. DIN's target-attention weights additionally drive the
    paper's top-p% *history pruning* (`din_prune_p`), a direct analogue of
    attention-guided patch pruning;
  * `score_candidates` is the retrieval_cand shape: one user against 10^6
    candidates as one batched einsum (no loop), candidates flat-sharded.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import pruning as core_pruning
from repro.core import quantization as quant
from repro.dist.sharding import NULL
from repro.models import layers as L
from repro.optim import optimizer as opt

Array = jax.Array


# ---------------------------------------------------------------------------
# Embedding substrate
# ---------------------------------------------------------------------------

def embedding_bag(table: Array, values: Array, segment_ids: Array,
                  num_segments: int, mode: str = "sum") -> Array:
    """EmbeddingBag: gather rows for `values` (flat multi-hot ids) and
    segment-reduce into `num_segments` bags. mode: sum | mean."""
    rows = jnp.take(table, values, axis=0)
    out = jax.ops.segment_sum(rows, segment_ids, num_segments=num_segments)
    if mode == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(values, out.dtype),
                                  segment_ids, num_segments=num_segments)
        out = out / jnp.maximum(cnt[:, None], 1.0)
    return out


def _tables_init(key, rows_list: Sequence[int], dim: int, dtype):
    ks = jax.random.split(key, len(rows_list))
    return [(jax.random.normal(ks[i], (r, dim)) / jnp.sqrt(dim)).astype(dtype)
            for i, r in enumerate(rows_list)]


def _tables_specs(rows_list):
    return [("table_rows", None) for _ in rows_list]


def lookup(tables: List[Array], ids: Array) -> Array:
    """Single-hot lookup: ids (B, n_fields) -> (B, n_fields, dim)."""
    cols = [jnp.take(t, ids[:, i], axis=0) for i, t in enumerate(tables)]
    return jnp.stack(cols, axis=1)


# --- paper transfer: K-Means-quantized tables ------------------------------

def quantize_tables(key: Array, tables: List[Array], k: int = 256,
                    iters: int = 10, restarts: int = 2) -> Dict[str, Any]:
    """Compress each table to (codes uint8, codebook (K, dim))."""
    out = {"codes": [], "codebooks": []}
    for i, t in enumerate(tables):
        kk = jax.random.fold_in(key, i)
        cb, _ = quant.kmeans_fit(
            kk, t, quant.KMeansConfig(k=min(k, t.shape[0]), iters=iters,
                                      n_restarts=restarts))
        out["codes"].append(quant.quantize(t, cb))
        out["codebooks"].append(cb)
    return out


def quantized_lookup(qtables: Dict[str, Any], ids: Array) -> Array:
    """Decode-on-lookup: 1 B/row HBM read + VMEM-resident codebook."""
    cols = []
    for i in range(len(qtables["codes"])):
        code = jnp.take(qtables["codes"][i], ids[:, i], axis=0)
        cols.append(jnp.take(qtables["codebooks"][i],
                             code.astype(jnp.int32), axis=0))
    return jnp.stack(cols, axis=1)


def tables_nbytes(tables: List[Array]) -> int:
    return sum(int(t.size) * t.dtype.itemsize for t in tables)


def qtables_nbytes(qt: Dict[str, Any]) -> int:
    return (sum(int(c.size) for c in qt["codes"])
            + sum(int(cb.size) * cb.dtype.itemsize for cb in qt["codebooks"]))


# ---------------------------------------------------------------------------
# MLP helpers (shared)
# ---------------------------------------------------------------------------

def _mlp_init(key, dims, dtype, final_act=False):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": L.dense_init(ks[i], dims[i], dims[i + 1], dtype),
             "b": jnp.zeros((dims[i + 1],), dtype)}
            for i in range(len(dims) - 1)]


def _mlp_specs(n_layers):
    return [{"w": (None, None), "b": (None,)} for _ in range(n_layers)]


def _mlp_apply(layers, x, final_act=False):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i < len(layers) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str = "dlrm"
    family: str = "dlrm"            # dlrm | dcn | din | dien
    n_dense: int = 13
    table_rows: Tuple[int, ...] = ()
    embed_dim: int = 128
    bot_mlp: Tuple[int, ...] = (512, 256, 128)
    top_mlp: Tuple[int, ...] = (1024, 1024, 512, 256, 1)
    n_cross_layers: int = 0          # dcn-v2
    # din/dien
    seq_len: int = 100
    attn_mlp: Tuple[int, ...] = (80, 40)
    gru_dim: int = 0                 # dien
    din_prune_p: float = 0.0         # paper transfer: history pruning (0=off)
    param_dtype: str = "float32"
    unroll: bool = False             # cost-analysis mode (launch/dryrun.py)

    @property
    def n_sparse(self) -> int:
        return len(self.table_rows)

    @property
    def pdtype(self):
        return jnp.bfloat16 if self.param_dtype == "bfloat16" else jnp.float32

    def param_count(self) -> int:
        emb = sum(self.table_rows) * self.embed_dim
        return emb  # MLPs are negligible at these scales


# ---------------------------------------------------------------------------
# DLRM
# ---------------------------------------------------------------------------

def dlrm_init(key: Array, cfg: RecsysConfig) -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.embed_dim
    n_vec = cfg.n_sparse + 1
    n_inter = n_vec * (n_vec - 1) // 2
    return {
        "tables": _tables_init(k1, cfg.table_rows, d, cfg.pdtype),
        "bot": _mlp_init(k2, (cfg.n_dense,) + cfg.bot_mlp, cfg.pdtype),
        "top": _mlp_init(k3, (n_inter + d,) + cfg.top_mlp, cfg.pdtype),
    }


def dlrm_specs(cfg: RecsysConfig) -> Dict[str, Any]:
    return {
        "tables": _tables_specs(cfg.table_rows),
        "bot": _mlp_specs(len(cfg.bot_mlp)),
        "top": _mlp_specs(len(cfg.top_mlp)),
    }


def _dot_interact(vecs: Array) -> Array:
    """vecs (B, F, d) -> upper-triangle pairwise dots (B, F(F-1)/2)."""
    b, f, d = vecs.shape
    g = jnp.einsum("bfd,bgd->bfg", vecs, vecs,
                   preferred_element_type=jnp.float32)
    iu, ju = jnp.triu_indices(f, k=1)
    return g[:, iu, ju]


def dlrm_forward(params, dense: Array, sparse_ids: Array,
                 cfg: RecsysConfig, shd=NULL) -> Array:
    x = _mlp_apply(params["bot"], dense.astype(cfg.pdtype), final_act=True)
    emb = lookup(params["tables"], sparse_ids)            # (B, 26, d)
    emb = shd.constraint(emb, "batch", None, None)
    vecs = jnp.concatenate([x[:, None, :], emb], axis=1)  # (B, 27, d)
    inter = _dot_interact(vecs).astype(cfg.pdtype)
    top_in = jnp.concatenate([x, inter], axis=-1)
    return _mlp_apply(params["top"], top_in)[:, 0].astype(jnp.float32)


# ---------------------------------------------------------------------------
# DCN-v2 (stacked: cross network then deep)
# ---------------------------------------------------------------------------

def dcn_init(key: Array, cfg: RecsysConfig) -> Dict[str, Any]:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d0 = cfg.n_sparse * cfg.embed_dim + cfg.n_dense
    cross = []
    for i in range(cfg.n_cross_layers):
        kk = jax.random.fold_in(k2, i)
        cross.append({"w": L.dense_init(kk, d0, d0, cfg.pdtype),
                      "b": jnp.zeros((d0,), cfg.pdtype)})
    return {
        "tables": _tables_init(k1, cfg.table_rows, cfg.embed_dim, cfg.pdtype),
        "cross": cross,
        "deep": _mlp_init(k3, (d0,) + cfg.top_mlp, cfg.pdtype),
        "out": _mlp_init(k4, (cfg.top_mlp[-1], 1), cfg.pdtype),
    }


def dcn_specs(cfg: RecsysConfig) -> Dict[str, Any]:
    return {
        "tables": _tables_specs(cfg.table_rows),
        "cross": [{"w": (None, None), "b": (None,)}
                  for _ in range(cfg.n_cross_layers)],
        "deep": _mlp_specs(len(cfg.top_mlp)),
        "out": _mlp_specs(1),
    }


def dcn_forward(params, dense: Array, sparse_ids: Array, cfg: RecsysConfig,
                shd=NULL) -> Array:
    emb = lookup(params["tables"], sparse_ids)            # (B, F, d)
    b = emb.shape[0]
    x0 = jnp.concatenate([emb.reshape(b, -1),
                          dense.astype(cfg.pdtype)], axis=-1)
    x0 = shd.constraint(x0, "batch", None)
    x = x0
    for cl in params["cross"]:
        x = x0 * (x @ cl["w"] + cl["b"]) + x              # DCN-v2 full-rank
    h = _mlp_apply(params["deep"], x, final_act=True)
    return _mlp_apply(params["out"], h)[:, 0].astype(jnp.float32)


# ---------------------------------------------------------------------------
# DIN (target attention over user history)
# ---------------------------------------------------------------------------

def din_init(key: Array, cfg: RecsysConfig) -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.embed_dim
    return {
        "tables": _tables_init(k1, cfg.table_rows, d, cfg.pdtype),  # [items]
        "attn": _mlp_init(k2, (4 * d,) + cfg.attn_mlp + (1,), cfg.pdtype),
        "mlp": _mlp_init(k3, (3 * d,) + cfg.top_mlp + (1,), cfg.pdtype),
    }


def din_specs(cfg: RecsysConfig) -> Dict[str, Any]:
    return {
        "tables": _tables_specs(cfg.table_rows),
        "attn": _mlp_specs(len(cfg.attn_mlp) + 1),
        "mlp": _mlp_specs(len(cfg.top_mlp) + 1),
    }


def din_attention(params, hist_e: Array, target_e: Array, hist_mask: Array,
                  cfg: RecsysConfig) -> Tuple[Array, Array]:
    """Target attention. hist_e (B, S, d), target_e (B, d) ->
    (user_vec (B, d), attn_weights (B, S))."""
    s = hist_e.shape[1]
    t = jnp.broadcast_to(target_e[:, None, :], hist_e.shape)
    feat = jnp.concatenate([hist_e, t, hist_e - t, hist_e * t], axis=-1)
    logits = _mlp_apply(params["attn"], feat)[..., 0]     # (B, S)
    logits = jnp.where(hist_mask, logits, -1e30)
    w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w = jnp.where(hist_mask, w, 0.0)

    if cfg.din_prune_p > 0:
        # Paper transfer: attention-guided pruning of the behaviour history
        # — keep only the top-p% most attended items (HPC-ColPali §III-C).
        pr = core_pruning.prune_topp(hist_e, w, hist_mask, p=cfg.din_prune_p)
        w_kept = jnp.take_along_axis(w, pr.indices, axis=-1) * pr.mask
        w_kept = w_kept / jnp.maximum(jnp.sum(w_kept, -1, keepdims=True), 1e-9)
        user = jnp.einsum("bs,bsd->bd", w_kept.astype(hist_e.dtype),
                          pr.embeddings)
    else:
        user = jnp.einsum("bs,bsd->bd", w.astype(hist_e.dtype), hist_e)
    return user, w


def din_forward(params, hist_ids: Array, hist_mask: Array, target_ids: Array,
                cfg: RecsysConfig, shd=NULL) -> Array:
    """hist_ids (B, S), target_ids (B,) -> logits (B,)."""
    table = params["tables"][0]
    hist_e = jnp.take(table, hist_ids, axis=0)            # (B, S, d)
    target_e = jnp.take(table, target_ids, axis=0)        # (B, d)
    hist_e = shd.constraint(hist_e, "batch", None, None)
    user, _ = din_attention(params, hist_e, target_e, hist_mask, cfg)
    feat = jnp.concatenate([user, target_e, user * target_e], axis=-1)
    return _mlp_apply(params["mlp"], feat)[:, 0].astype(jnp.float32)


# ---------------------------------------------------------------------------
# DIEN (GRU interest extraction + AUGRU interest evolution)
# ---------------------------------------------------------------------------

def _gru_init(key, d_in, d_h, dtype):
    k1, k2 = jax.random.split(key)
    return {"wx": L.dense_init(k1, d_in, 3 * d_h, dtype),
            "wh": L.dense_init(k2, d_h, 3 * d_h, dtype),
            "b": jnp.zeros((3 * d_h,), dtype)}


def _gru_cell(p, h, x, att: Optional[Array] = None):
    """GRU cell; att (B, 1) gates the update gate (AUGRU)."""
    gates = x @ p["wx"] + h @ p["wh"] + p["b"]
    r, z, _ = jnp.split(gates, 3, axis=-1)
    r, z = jax.nn.sigmoid(r), jax.nn.sigmoid(z)
    # n = tanh(Wn x + r * (Un h) + bn)
    dh = z.shape[-1]
    wx_n, wh_n, b_n = p["wx"][:, 2 * dh:], p["wh"][:, 2 * dh:], p["b"][2 * dh:]
    n = jnp.tanh(x @ wx_n + r * (h @ wh_n) + b_n)
    if att is not None:
        z = z * att                                      # AUGRU
    return (1 - z) * h + z * n


def dien_init(key: Array, cfg: RecsysConfig) -> Dict[str, Any]:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    d, g = cfg.embed_dim, cfg.gru_dim
    return {
        "tables": _tables_init(k1, cfg.table_rows, d, cfg.pdtype),
        "gru1": _gru_init(k2, d, g, cfg.pdtype),
        "augru": _gru_init(k3, g, g, cfg.pdtype),
        "attn": _mlp_init(k4, (g + d,) + cfg.attn_mlp + (1,), cfg.pdtype),
        "mlp": _mlp_init(k5, (g + 2 * d,) + cfg.top_mlp + (1,), cfg.pdtype),
    }


def dien_specs(cfg: RecsysConfig) -> Dict[str, Any]:
    gru = {"wx": (None, None), "wh": (None, None), "b": (None,)}
    return {
        "tables": _tables_specs(cfg.table_rows),
        "gru1": dict(gru), "augru": dict(gru),
        "attn": _mlp_specs(len(cfg.attn_mlp) + 1),
        "mlp": _mlp_specs(len(cfg.top_mlp) + 1),
    }


def dien_forward(params, hist_ids: Array, hist_mask: Array,
                 target_ids: Array, cfg: RecsysConfig, shd=NULL) -> Array:
    table = params["tables"][0]
    hist_e = jnp.take(table, hist_ids, axis=0)            # (B, S, d)
    target_e = jnp.take(table, target_ids, axis=0)        # (B, d)
    b, s, d = hist_e.shape
    g = cfg.gru_dim
    maskf = hist_mask.astype(hist_e.dtype)

    # Interest extraction GRU over the history.
    def step1(h, xs):
        x, m = xs
        h_new = _gru_cell(params["gru1"], h, x)
        h = jnp.where(m[:, None] > 0, h_new, h)
        return h, h
    h0 = jnp.zeros((b, g), hist_e.dtype)
    _, states = jax.lax.scan(step1, h0,
                             (hist_e.transpose(1, 0, 2), maskf.T),
                             unroll=s if cfg.unroll else 1)
    states = states.transpose(1, 0, 2)                    # (B, S, g)

    # Attention of target on interest states.
    t = jnp.broadcast_to(target_e[:, None, :], (b, s, d))
    alog = _mlp_apply(params["attn"],
                      jnp.concatenate([states, t], -1))[..., 0]
    alog = jnp.where(hist_mask, alog, -1e30)
    att = jax.nn.softmax(alog.astype(jnp.float32), -1).astype(hist_e.dtype)

    # AUGRU interest evolution.
    def step2(h, xs):
        x, a, m = xs
        h_new = _gru_cell(params["augru"], h, x, att=a[:, None])
        h = jnp.where(m[:, None] > 0, h_new, h)
        return h, None
    hT, _ = jax.lax.scan(step2, jnp.zeros((b, g), hist_e.dtype),
                         (states.transpose(1, 0, 2), att.T, maskf.T),
                         unroll=s if cfg.unroll else 1)

    hist_mean = jnp.mean(hist_e * maskf[..., None], axis=1)
    feat = jnp.concatenate([hT, target_e, hist_mean], axis=-1)
    return _mlp_apply(params["mlp"], feat)[:, 0].astype(jnp.float32)


# ---------------------------------------------------------------------------
# Unified API
# ---------------------------------------------------------------------------

def init(key: Array, cfg: RecsysConfig) -> Dict[str, Any]:
    return {"dlrm": dlrm_init, "dcn": dcn_init,
            "din": din_init, "dien": dien_init}[cfg.family](key, cfg)


def param_specs(cfg: RecsysConfig) -> Dict[str, Any]:
    return {"dlrm": dlrm_specs, "dcn": dcn_specs,
            "din": din_specs, "dien": dien_specs}[cfg.family](cfg)


def forward(params, batch: Dict[str, Array], cfg: RecsysConfig, shd=NULL
            ) -> Array:
    if cfg.family == "dlrm":
        return dlrm_forward(params, batch["dense"], batch["sparse_ids"],
                            cfg, shd)
    if cfg.family == "dcn":
        return dcn_forward(params, batch["dense"], batch["sparse_ids"],
                           cfg, shd)
    if cfg.family == "din":
        return din_forward(params, batch["hist_ids"], batch["hist_mask"],
                           batch["target_ids"], cfg, shd)
    return dien_forward(params, batch["hist_ids"], batch["hist_mask"],
                        batch["target_ids"], cfg, shd)


def loss_fn(params, batch, cfg: RecsysConfig, shd=NULL):
    logits = forward(params, batch, cfg, shd)
    y = batch["label"].astype(jnp.float32)
    # numerically stable BCE-with-logits
    loss = jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    acc = jnp.mean((logits > 0) == (y > 0.5))
    return loss, {"acc": acc}


def train_step(params, opt_state, batch, cfg: RecsysConfig,
               opt_cfg: opt.AdamWConfig, shd=NULL):
    (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, batch, cfg, shd)
    params, opt_state, om = opt.update(opt_cfg, grads, opt_state, params)
    return params, opt_state, {"loss": loss, **parts, **om}


def serve_step(params, batch, cfg: RecsysConfig, shd=NULL) -> Array:
    return jax.nn.sigmoid(forward(params, batch, cfg, shd))


def score_candidates(params, batch: Dict[str, Array], candidate_ids: Array,
                     cfg: RecsysConfig, shd=NULL) -> Array:
    """retrieval_cand shape: 1 user vs N candidates, one batched pass.

    Candidates are flat-sharded over the mesh ("candidate" rule); the user
    features broadcast. Implemented by tiling the user batch against the
    candidate axis and reusing `forward` (XLA fuses the broadcast).
    """
    n = candidate_ids.shape[0]
    if cfg.family in ("din", "dien"):
        hist_ids = jnp.broadcast_to(batch["hist_ids"], (n, cfg.seq_len))
        hist_mask = jnp.broadcast_to(batch["hist_mask"], (n, cfg.seq_len))
        cb = {"hist_ids": hist_ids, "hist_mask": hist_mask,
              "target_ids": candidate_ids}
    else:
        dense = jnp.broadcast_to(batch["dense"], (n, cfg.n_dense))
        sparse = jnp.broadcast_to(batch["sparse_ids"], (n, cfg.n_sparse))
        # candidate id replaces the last sparse field (item id slot)
        sparse = sparse.at[:, -1].set(candidate_ids)
        cb = {"dense": dense, "sparse_ids": sparse}
    scores = forward(params, cb, cfg, shd)
    return scores
