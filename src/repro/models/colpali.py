"""ColPali-style retrieval encoder: the paper's backbone (ColQwen2.5 class).

Architecture (docs/design.md §2, §5):
  * the *modality frontend is a stub* per the assignment — documents arrive
    as precomputed patch embeddings (B, M_patches, d_patch), exactly what a
    frozen vision tower would emit; `input_specs` hands over
    ShapeDtypeStructs for them;
  * a `patch_proj` maps patches into the LM's d_model; queries are token
    ids through the LM embedding table;
  * the LM backbone (any assigned LM config — qwen2-1.5b by default, the
    public ColQwen2.5 backbone family) contextualises the sequence;
  * `out_proj` maps hidden states to the D=128 retrieval space, L2-
    normalised (ColBERT convention);
  * the backbone's final-layer attention mass per position is returned as
    the *salience* signal that drives the paper's §III-C pruning.

Training: in-batch contrastive late interaction (ColPali's objective):
softmax over MaxSim(query_i, doc_j) with the matching doc on the diagonal.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import late_interaction as li
from repro.dist.sharding import NULL
from repro.models import layers as L
from repro.models import transformer as T
from repro.optim import optimizer as opt

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ColPaliConfig:
    name: str = "colpali"
    backbone: T.LMConfig = dataclasses.field(default_factory=T.LMConfig)
    d_patch: int = 768           # frozen vision-tower output dim (stub)
    proj_dim: int = 128          # retrieval embedding dim (paper: D=128)
    n_patches: int = 64          # patches per document page
    query_len: int = 32          # query token budget
    temperature: float = 0.02

    def param_count(self) -> int:
        return (self.backbone.param_count()
                + self.d_patch * self.backbone.d_model
                + self.backbone.d_model * self.proj_dim)


def init(key: Array, cfg: ColPaliConfig) -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "backbone": T.init(k1, cfg.backbone),
        "patch_proj": L.dense_init(k2, cfg.d_patch, cfg.backbone.d_model,
                                   cfg.backbone.pdtype),
        "out_proj": L.dense_init(k3, cfg.backbone.d_model, cfg.proj_dim,
                                 cfg.backbone.pdtype),
    }


def param_specs(cfg: ColPaliConfig) -> Dict[str, Any]:
    return {
        "backbone": T.param_specs(cfg.backbone),
        "patch_proj": (None, "embed"),
        "out_proj": ("embed", None),
    }


def _backbone_over_embeddings(params, x: Array, cfg: T.LMConfig, shd,
                              want_salience: bool):
    """Run the LM blocks over already-embedded inputs (B, S, D)."""
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    chunked = cfg.layer_is_chunked()
    n_l = cfg.n_layers

    def body(carry, xs):
        x = carry
        bp, is_chunked, is_last = xs
        fn = lambda bp_, x_: T._block_apply(bp_, x_, positions, is_chunked,
                                            cfg, shd, want_salience)
        x, aux, sal = jax.checkpoint(fn)(bp, x)
        if sal is None:
            sal = jnp.zeros((b, s), jnp.float32)
        sal = jnp.where(is_last, sal, 0.0)
        return x, sal

    is_last = jnp.arange(n_l) == n_l - 1
    x, sals = jax.lax.scan(body, x, (params["blocks"], chunked, is_last),
                           unroll=n_l if cfg.unroll else 1)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    sal = jnp.sum(sals, axis=0)
    return x, sal


def encode_doc(params, patches: Array, patch_mask: Array,
               cfg: ColPaliConfig, shd=NULL) -> Tuple[Array, Array]:
    """patches (B, M, d_patch) -> (embeddings (B, M, proj_dim), salience).

    Embeddings are L2-normalised; padded patches zeroed.
    """
    x = (patches.astype(cfg.backbone.adtype)
         @ params["patch_proj"].astype(cfg.backbone.adtype))
    x = shd.constraint(x, "batch", None, None)
    h, sal = _backbone_over_embeddings(params["backbone"], x, cfg.backbone,
                                       shd, True)
    e = h @ params["out_proj"].astype(h.dtype)
    e = e / jnp.maximum(jnp.linalg.norm(e.astype(jnp.float32), axis=-1,
                                        keepdims=True), 1e-6).astype(e.dtype)
    e = e * patch_mask[..., None].astype(e.dtype)
    sal = sal * patch_mask.astype(sal.dtype)
    return e.astype(jnp.float32), sal


def encode_query(params, tokens: Array, token_mask: Array,
                 cfg: ColPaliConfig, shd=NULL) -> Tuple[Array, Array]:
    """tokens (B, Lq) int32 -> (embeddings (B, Lq, proj_dim), salience)."""
    x = jnp.take(params["backbone"]["embed"], tokens, axis=0)
    x = x.astype(cfg.backbone.adtype)
    h, sal = _backbone_over_embeddings(params["backbone"], x, cfg.backbone,
                                       shd, True)
    e = h @ params["out_proj"].astype(h.dtype)
    e = e / jnp.maximum(jnp.linalg.norm(e.astype(jnp.float32), axis=-1,
                                        keepdims=True), 1e-6).astype(e.dtype)
    e = e * token_mask[..., None].astype(e.dtype)
    sal = sal * token_mask.astype(sal.dtype)
    return e.astype(jnp.float32), sal


def contrastive_loss(params, batch: Dict[str, Array], cfg: ColPaliConfig,
                     shd=NULL) -> Tuple[Array, Dict[str, Array]]:
    """In-batch late-interaction contrastive loss (ColPali training).

    batch: query_tokens (B, Lq), query_mask, doc_patches (B, M, d_patch),
    doc_mask. Positive pairs on the diagonal.
    """
    q, _ = encode_query(params, batch["query_tokens"], batch["query_mask"],
                        cfg, shd)
    d, _ = encode_doc(params, batch["doc_patches"], batch["doc_mask"],
                      cfg, shd)
    scores = li.maxsim(q, batch["query_mask"], d, batch["doc_mask"])
    scores = scores / cfg.temperature
    b = scores.shape[0]
    labels = jnp.arange(b)
    logz = jax.scipy.special.logsumexp(scores, axis=-1)
    gold = scores[jnp.arange(b), labels]
    loss = jnp.mean(logz - gold)
    acc = jnp.mean(jnp.argmax(scores, -1) == labels)
    return loss, {"acc": acc}


def train_step(params, opt_state, batch, cfg: ColPaliConfig,
               opt_cfg: opt.AdamWConfig, shd=NULL):
    (loss, parts), grads = jax.value_and_grad(contrastive_loss, has_aux=True)(
        params, batch, cfg, shd)
    params, opt_state, om = opt.update(opt_cfg, grads, opt_state, params)
    return params, opt_state, {"loss": loss, **parts, **om}
