"""Decoder-only LM (dense + MoE, GQA, RoPE, chunked-local attention).

Covers the five assigned LM architectures (glm4-9b, qwen2-1.5b,
llama3.2-3b, llama4-scout-17b-a16e, kimi-k2-1t-a32b) and serves as the
ColPali encoder backbone (models/colpali.py).

Implementation notes (docs/design.md §4, §6):
  * layers are stacked on a leading dim and iterated with lax.scan +
    jax.checkpoint — one traced block, O(1) compile in depth, remat saves
    only the (sequence-parallel-sharded) residual carry;
  * the residual stream is sharding-constrained to
    ("batch", "seq_sp", None) between blocks (Megatron-SP style); the
    divisibility fallback turns this off automatically for decode (S=1);
  * cross-entropy runs in sequence chunks (lax.map) so the (tokens, vocab)
    logits never fully materialise;
  * prefill returns stacked KV caches; decode_step updates them in place
    (donated) at a traced position — chunked-local layers touch only a
    static window of the cache.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import NULL
from repro.models import layers as L
from repro.optim import optimizer as opt

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str = "lm"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 2
    d_ff: int = 512
    vocab: int = 1024
    head_dim: int = 0                 # 0 -> d_model // n_heads
    qkv_bias: bool = False            # qwen2-style QKV bias
    tie_embeddings: bool = True
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    # MoE (n_experts == 0 -> dense)
    n_experts: int = 0
    moe_top_k: int = 1
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    moe_expert_chunks: int = 1        # sequential expert blocks (memory)
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    # attention structure
    attn_chunk: int = 0               # >0: chunked-local (iRoPE) layers
    global_every: int = 4             # every Nth layer stays full attention
    q_chunk: int = 512                # flash-style query block
    loss_chunk: int = 2048            # CE sequence chunk
    # dtypes
    param_dtype: str = "float32"
    activation_dtype: str = "float32"
    # cost-analysis mode: fully unroll scans so HLO flop counts are exact
    # (XLA cost analysis visits while bodies once) — launch/dryrun.py
    unroll: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def pdtype(self):
        return jnp.bfloat16 if self.param_dtype == "bfloat16" else jnp.float32

    @property
    def adtype(self):
        return (jnp.bfloat16 if self.activation_dtype == "bfloat16"
                else jnp.float32)

    def layer_is_chunked(self) -> Array:
        """(L,) bool — which layers use chunked-local attention."""
        i = jnp.arange(self.n_layers)
        if self.attn_chunk <= 0:
            return jnp.zeros((self.n_layers,), bool)
        return (i % self.global_every) != (self.global_every - 1)

    def param_count(self) -> int:
        d, hd = self.d_model, self.hd
        attn = self.n_layers * (d * (self.n_heads + 2 * self.n_kv_heads) * hd
                                + self.n_heads * hd * d)
        if self.is_moe:
            ff = self.n_layers * (
                self.n_experts * 3 * d * self.moe_d_ff + d * self.n_experts
                + (3 * d * self.moe_d_ff * self.n_shared_experts))
        else:
            ff = self.n_layers * 3 * d * self.d_ff
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return attn + ff + emb + self.n_layers * 2 * d + d

    def active_param_count(self) -> int:
        """Per-token active params (MoE: top_k + shared experts only)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        all_experts = self.n_layers * self.n_experts * 3 * d * self.moe_d_ff
        active = self.n_layers * self.moe_top_k * 3 * d * self.moe_d_ff
        return full - all_experts + active


# ---------------------------------------------------------------------------
# init / specs
# ---------------------------------------------------------------------------

def _layer_init(key: Array, cfg: LMConfig) -> Dict[str, Any]:
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": jnp.ones((cfg.d_model,), cfg.pdtype),
        "ln2": jnp.ones((cfg.d_model,), cfg.pdtype),
        "attn": L.attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                            cfg.hd, cfg.qkv_bias, cfg.pdtype),
    }
    if cfg.is_moe:
        p["moe"] = L.moe_init(k2, cfg.d_model, cfg.moe_d_ff, cfg.n_experts,
                              cfg.n_shared_experts, cfg.pdtype)
    else:
        # exclusive if/else: k2 feeds either the MoE or the FFN, never both
        p["ffn"] = L.ffn_init(k2, cfg.d_model, cfg.d_ff, cfg.pdtype)  # noqa: JAX01
    return p


def init(key: Array, cfg: LMConfig) -> Dict[str, Any]:
    k_emb, k_out, k_layers = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    blocks = jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys)
    p = {
        "embed": L.embed_init(k_emb, cfg.vocab, cfg.d_model, cfg.pdtype),
        "blocks": blocks,
        "ln_f": jnp.ones((cfg.d_model,), cfg.pdtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = L.dense_init(k_out, cfg.d_model, cfg.vocab, cfg.pdtype)
    return p


def _stack(spec_tree):
    """Prepend the stacked-layer dim (None) to every spec tuple."""
    return jax.tree.map(lambda s: (None,) + tuple(s), spec_tree,
                        is_leaf=lambda s: isinstance(s, tuple))


def param_specs(cfg: LMConfig) -> Dict[str, Any]:
    block = {
        "ln1": ("embed",),
        "ln2": ("embed",),
        "attn": L.attn_specs(cfg.qkv_bias),
    }
    if cfg.is_moe:
        block["moe"] = L.moe_specs(cfg.n_shared_experts)
    else:
        block["ffn"] = L.ffn_specs()
    s = {
        "embed": ("vocab", "embed"),
        "blocks": _stack(block),
        "ln_f": ("embed",),
    }
    if not cfg.tie_embeddings:
        s["unembed"] = ("embed", "vocab")
    return s


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _block_apply(bp: Dict[str, Any], x: Array, positions: Array,
                 is_chunked: Array, cfg: LMConfig, shd,
                 want_salience: bool) -> Tuple[Array, Array, Optional[Array]]:
    """One transformer block. Returns (x, aux_loss, salience)."""
    h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)

    def run_attn(chunk):
        return L.attention(bp["attn"], h, positions,
                           n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                           head_dim=cfg.hd, theta=cfg.rope_theta,
                           chunk=chunk, q_chunk=cfg.q_chunk, shd=shd,
                           want_salience=want_salience, unroll=cfg.unroll)

    s = x.shape[1]
    if cfg.attn_chunk > 0 and cfg.attn_chunk < s:
        attn_out, sal = jax.lax.cond(
            is_chunked,
            lambda: run_attn(cfg.attn_chunk),
            lambda: run_attn(0))
    else:
        attn_out, sal = run_attn(0)
    x = x + attn_out
    x = shd.constraint(x, "batch", "seq_sp", None)

    h = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        b, sq, d = h.shape
        ff, aux = L.moe_apply(bp["moe"], h.reshape(b * sq, d),
                              top_k=cfg.moe_top_k,
                              capacity_factor=cfg.capacity_factor, shd=shd,
                              expert_chunks=cfg.moe_expert_chunks)
        ff = ff.reshape(b, sq, d)
    else:
        ff, aux = L.ffn_apply(bp["ffn"], h), jnp.float32(0.0)
    x = x + ff
    x = shd.constraint(x, "batch", "seq_sp", None)
    return x, aux, sal


def forward(params: Dict[str, Any], tokens: Array, cfg: LMConfig,
            shd=NULL, *, want_salience: bool = False
            ) -> Tuple[Array, Array, Optional[Array]]:
    """tokens (B, S) -> (hidden (B, S, D), aux_loss (), salience (B, S)|None).

    Salience (attention mass received per position, final layer) feeds the
    paper's pruning — models/colpali.py.
    """
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.adtype)
    x = shd.constraint(x, "batch", "seq_sp", None)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    chunked = cfg.layer_is_chunked()
    n_l = cfg.n_layers

    def body(carry, xs):
        x = carry
        bp, is_chunked, is_last = xs
        want = want_salience  # only the last layer's salience is kept
        fn = lambda bp_, x_: _block_apply(bp_, x_, positions, is_chunked,
                                          cfg, shd, want)
        x, aux, sal = jax.checkpoint(fn)(bp, x)
        if sal is None:
            sal = jnp.zeros((b, s), jnp.float32)
        sal = jnp.where(is_last, sal, 0.0)
        return x, (aux, sal)

    is_last = jnp.arange(n_l) == n_l - 1
    x, (auxes, sals) = jax.lax.scan(body, x, (params["blocks"], chunked,
                                              is_last),
                                    unroll=n_l if cfg.unroll else 1)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    aux = jnp.sum(auxes)
    sal = jnp.sum(sals, axis=0) if want_salience else None
    return x, aux, sal


def logits_fn(params: Dict[str, Any], h: Array, cfg: LMConfig) -> Array:
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return jnp.einsum("bsd,dv->bsv", h, w.astype(h.dtype),
                      preferred_element_type=jnp.float32)


def loss_fn(params: Dict[str, Any], tokens: Array, targets: Array,
            cfg: LMConfig, shd=NULL) -> Tuple[Array, Dict[str, Array]]:
    """Next-token CE, chunked over the sequence (docs/design.md §6).

    Positions with target < 0 are masked out (prompt positions in RAG
    fine-tuning, padding).
    """
    h, aux, _ = forward(params, tokens, cfg, shd)
    # exit sequence parallelism before the loss: the chunk scan slices the
    # seq dim, and a model-sharded seq dim would otherwise make GSPMD
    # replicate the (B, ck, V) logits (§Perf iteration loss-1: 86 GiB/dev
    # of replicated fp32 logits on kimi-k2 -> 0.17 GiB sharded)
    h = shd.constraint(h, "batch", None, None)
    b, s, d = h.shape
    ck = min(cfg.loss_chunk, s)
    while s % ck != 0:
        ck //= 2
    n_chunks = s // ck
    hc = h.reshape(b, n_chunks, ck, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(b, n_chunks, ck).transpose(1, 0, 2)

    def chunk_loss(args):
        hcb, tcb = args
        valid = tcb >= 0
        safe = jnp.maximum(tcb, 0)
        logits = logits_fn(params, hcb, cfg)              # (B, ck, V) f32
        logits = shd.constraint(logits, "batch", None, "vocab")
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        ce = jnp.where(valid, logz - gold, 0.0)
        return jnp.sum(ce), jnp.sum(valid)

    # checkpoint: without it the scan saves logits-sized residuals per
    # chunk for bwd, un-doing the whole point of chunking the CE loss.
    losses, counts = jax.lax.scan(
        lambda _, args: (None, jax.checkpoint(chunk_loss)(args)), None,
        (hc, tc), unroll=n_chunks if cfg.unroll else 1)[1]
    n_valid = jnp.maximum(jnp.sum(counts), 1)
    ce = jnp.sum(losses) / n_valid
    total = ce + cfg.aux_loss_weight * aux
    return total, {"ce": ce, "aux": aux}


def train_step(params, opt_state, batch: Dict[str, Array], cfg: LMConfig,
               opt_cfg: opt.AdamWConfig, shd=NULL):
    """(params, opt_state, {tokens, targets}) -> (params, opt_state, metrics)."""
    (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, batch["tokens"], batch["targets"], cfg, shd)
    params, opt_state, om = opt.update(opt_cfg, grads, opt_state, params)
    metrics = {"loss": loss, **parts, **om}
    return params, opt_state, metrics


# ---------------------------------------------------------------------------
# serving: prefill + decode with stacked KV caches
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: Array   # (L, B, S_max, n_kv, hd)
    v: Array


def cache_specs() -> "KVCache":
    return KVCache((None, "batch", "kv_seq", "kv_heads", None),
                   (None, "batch", "kv_seq", "kv_heads", None))


def init_cache(cfg: LMConfig, batch: int, max_len: int) -> KVCache:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return KVCache(jnp.zeros(shape, cfg.adtype), jnp.zeros(shape, cfg.adtype))


def prefill(params, tokens: Array, cfg: LMConfig, max_len: int, shd=NULL
            ) -> Tuple[Array, KVCache]:
    """Run the prompt, return (last-position logits (B, V), filled caches).

    The cache K/V are the *post-RoPE* keys/values, recomputed layerwise —
    we re-run the block projections on the final hidden stream; to keep one
    code path we recompute k/v per layer from the stored residual inputs.
    """
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.adtype)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    chunked = cfg.layer_is_chunked()

    def body(x, xs):
        bp, is_chunked = xs
        h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
        q, k, v = L._qkv(bp["attn"], h, cfg.n_heads, cfg.n_kv_heads, cfg.hd)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        # attention itself (recomputes qkv internally; fine for prefill)
        x, _, _ = _block_apply(bp, x, positions, is_chunked, cfg, shd, False)
        pad = max_len - s
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x, (kc.astype(cfg.adtype), vc.astype(cfg.adtype))

    x, (kc, vc) = jax.lax.scan(body, x, (params["blocks"], chunked),
                               unroll=cfg.n_layers if cfg.unroll else 1)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = logits_fn(params, x[:, -1:, :], cfg)[:, 0]
    return logits, KVCache(kc, vc)


def decode_step(params, token: Array, cache: KVCache, pos: Array,
                cfg: LMConfig, shd=NULL) -> Tuple[Array, KVCache]:
    """One decode step. token (B,) int32; pos () int32 (aligned batch).

    Returns (logits (B, V), updated cache). Cache buffers are donated by
    the serving loop (launch/serve.py) so the update is in-place on device.
    """
    b = token.shape[0]
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(cfg.adtype)
    chunked = cfg.layer_is_chunked()

    def body(x, xs):
        bp, kc, vc, is_chunked = xs
        h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)

        def run(chunk):
            return L.attention_decode(
                bp["attn"], h, pos, kc, vc, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv_heads, head_dim=cfg.hd, theta=cfg.rope_theta,
                chunk=chunk, shd=shd)

        if cfg.attn_chunk > 0 and cfg.attn_chunk < kc.shape[1]:
            attn_out, kc, vc = jax.lax.cond(
                is_chunked, lambda: run(cfg.attn_chunk), lambda: run(0))
        else:
            attn_out, kc, vc = run(0)
        x = x + attn_out
        h2 = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            ff, _ = L.moe_apply(bp["moe"], h2.reshape(b, -1),
                                top_k=cfg.moe_top_k,
                                capacity_factor=2.0, shd=shd)
            ff = ff.reshape(b, 1, -1)
        else:
            ff = L.ffn_apply(bp["ffn"], h2)
        x = x + ff
        return x, (kc, vc)

    x, (kc, vc) = jax.lax.scan(body, x, (params["blocks"], cache.k, cache.v,
                                         chunked),
                               unroll=cfg.n_layers if cfg.unroll else 1)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = logits_fn(params, x, cfg)[:, 0]
    return logits, KVCache(kc, vc)
