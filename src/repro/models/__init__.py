"""Model definitions: LM transformers (dense/MoE/GQA/chunked-local
attention), the ColPali retrieval encoder, PNA GNN, and recsys models."""
