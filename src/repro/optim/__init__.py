"""Optimizer substrate: AdamW (+ int8 moments), schedules, grad compression."""

from repro.optim import optimizer  # noqa: F401
