"""Pure-JAX AdamW with optional int8-quantized moments + LR schedules.

No optax in this environment; this module provides the full optimizer
substrate: warmup-cosine schedule, global-norm clipping, decoupled weight
decay, and (for the 1T-param kimi-k2 cell) *int8 blockwise-quantized Adam
moments* — 1 byte per moment entry with a per-row fp32 scale, dequantized/
requantized inside the (jit-fused) update. This is the memory trick that
brings kimi-k2 training from 16 B/param (fp32 Adam) to ~4.1 B/param
(bf16 params + int8 m + int8 v) — docs/design.md §6. It is also thematically the
paper's quantization idea applied to optimizer state (beyond-paper).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Literal, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    moment_dtype: Literal["fp32", "int8"] = "fp32"
    param_dtype: Literal["fp32", "bf16"] = "fp32"


def schedule(cfg: AdamWConfig, step: Array) -> Array:
    """Linear warmup -> cosine decay to min_lr_frac * lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


# --- int8 blockwise moment codec ------------------------------------------

class QMoment(NamedTuple):
    q: Array       # int8, same shape as the moment
    scale: Array   # fp32, shape = moment.shape[:-1] + (1,)


def _quantize_moment(x: Array) -> QMoment:
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return QMoment(q, scale.astype(jnp.float32))


def _dequantize_moment(qm: QMoment) -> Array:
    return qm.q.astype(jnp.float32) * qm.scale


class AdamWState(NamedTuple):
    step: Array
    m: PyTree      # fp32 arrays or QMoment leaves
    v: PyTree


def init(cfg: AdamWConfig, params: PyTree) -> AdamWState:
    def zero_like(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _quantize_moment(z) if cfg.moment_dtype == "int8" else z
    zeros = jax.tree.map(zero_like, params)
    m = zeros
    v = jax.tree.map(zero_like, params)
    return AdamWState(jnp.zeros((), jnp.int32), m, v)


def global_norm(tree: PyTree) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(cfg: AdamWConfig, grads: PyTree, state: AdamWState,
           params: PyTree) -> Tuple[PyTree, AdamWState, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    lr = schedule(cfg, step)

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    is_qm = lambda x: isinstance(x, QMoment)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_f = _dequantize_moment(m) if is_qm(m) else m
        v_f = _dequantize_moment(v) if is_qm(v) else v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        # v stays >= 0; quantization preserves sign trivially.
        mh = m_f / (1 - cfg.b1 ** step.astype(jnp.float32))
        vh = v_f / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        if is_qm(m):
            return new_p, _quantize_moment(m_f), _quantize_moment(v_f)
        return new_p, m_f, v_f

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m, is_leaf=is_qm)
    flat_v = jax.tree.leaves(state.v, is_leaf=is_qm)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, AdamWState(step, new_m, new_v), metrics


def state_specs(param_specs: PyTree, cfg: AdamWConfig) -> "AdamWState":
    """Logical-axis specs for the optimizer state, mirroring param specs.

    int8 moments: the quantized tensor shards like the param; the per-row
    scale drops the last dim's sharding (shape[-1] == 1).
    """
    def moment_spec(spec):
        spec = tuple(spec)
        if cfg.moment_dtype == "int8":
            return QMoment(spec, spec[:-1] + (None,))
        return spec
    from repro.dist.sharding import is_logical_spec
    m = jax.tree.map(moment_spec, param_specs, is_leaf=is_logical_spec)
    return AdamWState((), m, m)
