"""Gradient compression for bandwidth-bound data parallelism.

Two composable schemes (docs/design.md §4 distributed-optimization tricks):

  * int8 stochastic-rounding quantization — 4x less all-reduce traffic;
    stochastic rounding keeps the estimator unbiased so convergence is
    preserved in expectation (validated in tests on a quadratic problem);
  * top-k sparsification with error feedback (Deep Gradient Compression
    style) — only the k largest-|g| entries per tensor are exchanged; the
    residual accumulates locally and is re-injected next step, which is the
    property that makes 100-1000x sparsification trainable.

Both operate on the *gradient pytree before the optimizer*, so they compose
with AdamW and the int8-moment option independently.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


# --- int8 stochastic-rounding codec ----------------------------------------

class QGrad(NamedTuple):
    q: jax.Array      # int8
    scale: jax.Array  # f32 per-tensor scale


def quantize_grad(key: jax.Array, g: jax.Array) -> QGrad:
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    x = g / scale
    lo = jnp.floor(x)
    p_up = x - lo
    up = jax.random.uniform(key, g.shape) < p_up
    q = jnp.clip(lo + up.astype(x.dtype), -127, 127).astype(jnp.int8)
    return QGrad(q, scale.astype(jnp.float32))


def dequantize_grad(qg: QGrad) -> jax.Array:
    return qg.q.astype(jnp.float32) * qg.scale


def compress_tree_int8(key: jax.Array, grads: PyTree) -> PyTree:
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    out = [quantize_grad(k, g) for k, g in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, out)


def decompress_tree_int8(qtree: PyTree) -> PyTree:
    return jax.tree.map(dequantize_grad, qtree,
                        is_leaf=lambda x: isinstance(x, QGrad))


def compressed_bytes_int8(grads: PyTree) -> int:
    return sum(x.size + 4 for x in jax.tree.leaves(grads))


# --- top-k + error feedback -------------------------------------------------

class TopKState(NamedTuple):
    residual: PyTree   # error-feedback accumulator (same structure as grads)


def topk_init(grads_template: PyTree) -> TopKState:
    return TopKState(jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32),
                                  grads_template))


def topk_compress(grads: PyTree, state: TopKState, frac: float
                  ) -> Tuple[PyTree, TopKState, dict]:
    """Keep the top-`frac` entries (by |g|) of (grad + residual) per tensor.

    Returns (sparse-but-dense-layout grads, new state, stats). The returned
    grads are dense tensors with zeros at dropped positions — the layout a
    sparse all-reduce would reconstruct on the other side; traffic
    accounting uses `nnz`.
    """
    def one(g, r):
        acc = g.astype(jnp.float32) + r
        k = max(1, int(acc.size * frac))
        flat = acc.reshape(-1)
        # JAX04-safe: k = max(1, size * frac) <= size for frac <= 1
        _, idx = jax.lax.top_k(jnp.abs(flat), k)  # noqa: JAX04
        kept = jnp.zeros_like(flat).at[idx].set(flat[idx])
        return kept.reshape(g.shape), acc - kept.reshape(g.shape)

    outs = jax.tree.map(one, grads, state.residual)
    kept = jax.tree.map(lambda o: o[0], outs,
                        is_leaf=lambda x: isinstance(x, tuple))
    resid = jax.tree.map(lambda o: o[1], outs,
                         is_leaf=lambda x: isinstance(x, tuple))
    nnz = sum(max(1, int(g.size * frac)) for g in jax.tree.leaves(grads))
    total = sum(g.size for g in jax.tree.leaves(grads))
    return kept, TopKState(resid), {"nnz": nnz, "total": total,
                                    "ratio": nnz / total}
