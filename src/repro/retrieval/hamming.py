"""`hamming` backend: binary codes + VPU popcount MaxSim (paper §III-D).

Queries are quantized to centroid indices with the SAME code dtype the
corpus was built with (`code_dtype(k)` — v0 inconsistently used uint16
for queries vs uint8 corpora). The bit width is static aux data.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import binary as binary_mod
from repro.core import index as index_mod
from repro.core import quantization as quant
from repro.retrieval.base import (Corpus, IndexBackend, Query,
                                  RetrieverState, code_dtype, encode_corpus,
                                  register_backend)
from repro.retrieval.config import HPCConfig

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class HammingState:
    """HammingIndex + the static bit width (aux data, not a leaf)."""

    index: index_mod.HammingIndex
    bits: int

    def tree_flatten(self):
        return (self.index,), self.bits

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)


@register_backend("hamming")
class HammingBackend(IndexBackend):

    def build(self, key: Array, corpus: Corpus, cfg: HPCConfig,
              mesh=None) -> RetrieverState:
        _, codebook, codes_full, codes, mask = encode_corpus(
            key, corpus, cfg, mesh=mesh)
        ham = index_mod.build_hamming(codes, mask, cfg.bits)
        return RetrieverState(
            codebook=codebook,
            backend_state=HammingState(ham, cfg.bits),
            rerank_codes=codes_full,
            rerank_mask=corpus.mask)

    def _q_codes(self, state: RetrieverState, query: Query) -> Array:
        return quant.quantize(query.embeddings, state.codebook,
                              code_dtype=code_dtype(
                                  1 << state.backend_state.bits))

    def search(self, state: RetrieverState, query: Query, *, k: int,
               scan=None) -> Tuple[Array, Array]:
        s = state.backend_state
        q_codes = self._q_codes(state, query)
        seg = self._segmented(state)
        if seg is not None:
            return index_mod.search_hamming_segmented(
                seg, q_codes, query.mask, bits=s.bits, k=k, scan=scan)
        return index_mod.search_hamming(s.index, q_codes, query.mask,
                                        bits=s.bits, k=k, scan=scan)

    def search_candidates(self, state: RetrieverState, query: Query,
                          candidate_ids, *, k: int,
                          scan=None) -> Tuple[Array, Array]:
        if candidate_ids is None:
            return self.search(state, query, k=k, scan=scan)
        s = state.backend_state
        q_codes = self._q_codes(state, query)
        seg = self._segmented(state)
        if seg is not None:
            return index_mod.search_hamming_segmented_candidates(
                seg, q_codes, query.mask, candidate_ids,
                bits=s.bits, k=k, scan=scan)
        return index_mod.search_hamming_candidates(
            s.index, q_codes, query.mask, candidate_ids,
            bits=s.bits, k=k, scan=scan)

    # -- mutation hooks ------------------------------------------------------

    def _delta_segment(self, state, seg, enc, delta, cfg, doc_ids):
        _, codes, mask = enc
        return index_mod.make_hamming_segment(
            codes, mask, state.backend_state.bits, doc_ids)

    def _compact_payload(self, state, seg, cfg):
        (codes, mask), ids = index_mod.gather_live_rows(
            seg, ("codes", "mask"))
        return index_mod.make_hamming_segment(
            codes, mask, state.backend_state.bits, ids)

    def _seg_payload_bytes(self, payload, n_live: int) -> int:
        bits = int(payload.bits)
        return binary_mod.packed_nbytes(n_live * payload.codes.shape[-1],
                                        bits)

    def storage_bytes(self, state: RetrieverState) -> Dict[str, int]:
        s = state.backend_state
        seg = self._segmented(state)
        if seg is not None:
            return self._segmented_storage(state, seg)
        n_codes = int(s.index.codes.size)
        cb = state.codebook
        return {"payload": binary_mod.packed_nbytes(n_codes, s.bits),
                "codebook": cb.size * cb.dtype.itemsize}

    def abstract_state(self, *, n: int, md: int = 16, d: int = 16,
                       k: int = 256, **knobs) -> RetrieverState:
        bits = knobs.get("bits", binary_mod.bits_for_k(k))
        sds, cdt = jax.ShapeDtypeStruct, code_dtype(1 << bits)

        def seg_payload(cap):
            return index_mod.HammingIndex(
                codes=sds((cap, md), cdt),
                mask=sds((cap, md), jnp.bool_),
                doc_ids=sds((cap,), jnp.int32),
                bits=sds((), jnp.int32))

        segments = knobs.get("segments")
        if segments is not None:
            id_cap = knobs.get("id_cap",
                               index_mod.segment_capacity(sum(segments)))
            bs = index_mod.SegmentedState(
                tuple(seg_payload(c) for c in segments),
                tuple(sds((c,), jnp.bool_) for c in segments),
                sds((id_cap,), jnp.int32))
            n = id_cap
        else:
            bs = seg_payload(n)
        return RetrieverState(
            codebook=sds((k, d), jnp.float32),
            backend_state=HammingState(bs, bits),
            rerank_codes=sds((n, md), cdt),
            rerank_mask=sds((n, md), jnp.bool_))

    def _state_aux(self, state: RetrieverState):
        return state.backend_state.bits

    def state_template(self, aux, n_segments: int = 0) -> RetrieverState:
        if n_segments:
            bs = index_mod.SegmentedState(
                tuple(index_mod.HammingIndex(0, 0, 0, 0)
                      for _ in range(n_segments)),
                (0,) * n_segments, 0)
        else:
            bs = index_mod.HammingIndex(0, 0, 0, 0)
        return RetrieverState(0, HammingState(bs, aux), 0, 0)
