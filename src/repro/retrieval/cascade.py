"""`cascade` backend: the staged compression funnel (ROADMAP item).

Composes three existing backends as *search stages* over one shared
encode (codebook + quantized corpus, done once via `encode_corpus`):

    stage 1  hamming     popcount prefilter over all N docs  -> top p1
    stage 2  flat (ADC)  quantized rescore of the p1 pool    -> top p2
    stage 3  float_flat  exact late-interaction rerank       -> top k

Stages 2-3 run through `search_candidates` — the per-query (B, P)
layout of the streaming scan engine — so the expensive stages cost
O(B * p) rather than O(N); the float stage touches only p2/N of the
corpus (the paper's "expensive stage touches ~1% of documents" regime).
The `-1` sentinel contract holds at every boundary: a stage that
surfaces fewer than its budget of valid candidates (including
k > p2 > p1 > N misconfigurations) hands -1 rows downstream, where they
are never scored and stay -1 in the final output.

Budgets (p1, p2) come from `HPCConfig.cascade` at build time and ride
in the state as static aux — the same pattern as IVF's `n_probe` — so
`search(state, query, k=...)` stays self-contained and jit-stable.
Member states nest inside `CascadeState`; persistence, sharding, stats,
and the jaxpr budget analyzer all compose from the member backends.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binary as binary_mod
from repro.core import index as index_mod
from repro.core import pruning
from repro.retrieval.base import (Corpus, IndexBackend, Query,
                                  RetrieverState, code_dtype, encode_corpus,
                                  get_backend, register_backend)
from repro.retrieval.config import HPCConfig
from repro.retrieval.hamming import HammingState

Array = jax.Array

# Stage composition is fixed (registry names, coarse -> exact). Making
# this data — not config — keeps the persisted aux a plain int tuple
# (no backend names on disk) and the treedef reconstructible without
# pickle. Future stages (DocPruner adaptive budgets, Sculpting merge)
# slot in here once they exist as backends with `search_candidates`.
STAGES = ("hamming", "flat", "float_flat")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CascadeState:
    """Nested member states + the static (p1, p2) stage budgets."""

    members: Tuple  # (HammingState, FlatIndex, FloatFlatIndex)
    p1: int
    p2: int

    def tree_flatten(self):
        return (self.members,), (self.p1, self.p2)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)


@register_backend("cascade")
class CascadeBackend(IndexBackend):
    # the final stage scores raw embeddings — exact late-interaction
    # scores, so the facade skips its quantized rerank (like float_flat)
    exact_scores = True

    def build(self, key: Array, corpus: Corpus, cfg: HPCConfig,
              mesh=None) -> RetrieverState:
        """One shared encode, three member structures over it.

        The Hamming and ADC stages index the SAME pruned quantized
        corpus (binary codes are the centroid indices read at b bits),
        so the funnel adds only the float-stage embeddings on top of
        what `flat` alone would store.
        """
        _, codebook, codes_full, codes, mask = encode_corpus(
            key, corpus, cfg, mesh=mesh)
        ham = HammingState(index_mod.build_hamming(codes, mask, cfg.bits),
                           cfg.bits)
        flat = index_mod.build_flat(codes, mask, codebook)
        emb, fmask = corpus.embeddings, corpus.mask
        if cfg.prune_side in ("doc", "both"):
            pr = pruning.prune_topp(emb, corpus.salience, fmask, p=cfg.p)
            emb, fmask = pr.embeddings, pr.mask
        ff = index_mod.build_float_flat(emb, fmask)
        return RetrieverState(
            codebook=codebook,
            backend_state=CascadeState((ham, flat, ff),
                                       cfg.cascade.p1, cfg.cascade.p2),
            rerank_codes=codes_full,
            rerank_mask=corpus.mask)

    # -- search -------------------------------------------------------------

    def _views(self, state: RetrieverState):
        """(backend, member-view RetrieverState) per stage.

        A view is the outer state with `backend_state` swapped for one
        member — member backends see exactly the state shape they built,
        sharing the outer codebook/rerank leaves.
        """
        return [(get_backend(name), state._replace(backend_state=member))
                for name, member in zip(STAGES, state.backend_state.members)]

    def search(self, state: RetrieverState, query: Query, *, k: int,
               scan=None) -> Tuple[Array, Array]:
        """Run the funnel: full-corpus prefilter, then narrowing stages.

        Stage outputs are *global doc ids*; members are built over the
        same unsharded corpus (doc_ids = arange), so ids double as
        positions for the next stage's `search_candidates` gather.
        """
        s = state.backend_state
        (ham_b, ham_v), (flat_b, flat_v), (ff_b, ff_v) = self._views(state)
        _, ids1 = ham_b.search(ham_v, query, k=s.p1, scan=scan)
        _, ids2 = flat_b.search_candidates(flat_v, query, ids1, k=s.p2,
                                           scan=scan)
        return ff_b.search_candidates(ff_v, query, ids2, k=k, scan=scan)

    # -- graceful degradation (serving overload ladder) ---------------------

    def with_budgets(self, state: RetrieverState, p1: int,
                     p2: int) -> RetrieverState:
        """Same member arrays, different static (p1, p2) stage budgets.

        Budgets are static pytree aux, so the replaced state keys a
        distinct jit signature while sharing every device buffer — the
        degradation ladder's rungs are O(1) to derive and pre-compile.
        """
        s = state.backend_state
        return state._replace(
            backend_state=CascadeState(s.members, int(p1), int(p2)))

    def degrade_rungs(self, state: RetrieverState, *, k: int,
                      max_levels: int = 3) -> Tuple:
        """Budget rungs below the configured (p1, p2), coarsest last.

        Each rung halves both budgets (floored at p1 >= 2k, p2 >= k so a
        degraded response still ranks a full top-k); the final ``None``
        rung is the hamming-only floor (`search_prefilter`). The returned
        tuple is a *closed* set: serving pre-compiles exactly these
        signatures and the recompile sentry holds them, so stepping down
        under overload never mints an off-ladder compile.
        """
        s = state.backend_state
        rungs: list = []
        p1, p2 = int(s.p1), int(s.p2)
        while len(rungs) < max(0, max_levels - 1):
            nxt = (max(p1 // 2, 2 * k), max(p2 // 2, k))
            if nxt == (p1, p2):
                break
            p1, p2 = nxt
            rungs.append(nxt)
        rungs.append(None)
        return tuple(rungs)

    def search_prefilter(self, state: RetrieverState, query: Query, *,
                         k: int, scan=None) -> Tuple[Array, Array]:
        """Degradation floor: answer from stage 1 alone (float32 scores)."""
        ham_b, ham_v = self._views(state)[0]
        sh = ham_v.backend_state
        q_codes = ham_b._q_codes(ham_v, query)
        seg = ham_b._segmented(ham_v)
        target = seg if seg is not None else sh.index
        return index_mod.search_hamming_floor(
            target, q_codes, query.mask, bits=sh.bits, k=k, scan=scan)

    def search_degraded(self, state: RetrieverState, query: Query, *,
                        k: int, rung, scan=None) -> Tuple[Array, Array]:
        """Serve one degradation rung: a (p1, p2) pair from
        `degrade_rungs`, or None for the hamming-only floor."""
        if rung is None:
            return self.search_prefilter(state, query, k=k, scan=scan)
        return self.search(self.with_budgets(state, *rung), query, k=k,
                           scan=scan)

    # -- mutation (member-wise composition) ---------------------------------

    def _segmented(self, state: RetrieverState):
        # CascadeState is not an `index`-field wrapper; the flat member's
        # SegmentedState stands in for segment accounting (all members
        # mutate in lockstep, so their segment/tombstone structure agrees)
        flat_member = state.backend_state.members[1]
        if isinstance(flat_member, index_mod.SegmentedState):
            return flat_member
        return None

    def _recompose(self, state: RetrieverState, member_states,
                   rerank_from: int) -> RetrieverState:
        """Reassemble the outer state from mutated member views.

        Rerank leaves come from the member at `rerank_from` (the flat
        stage — float_flat writes placeholder rows that must not clobber
        the shared full-code rerank corpus)."""
        s = state.backend_state
        donor = member_states[rerank_from]
        return state._replace(
            backend_state=CascadeState(
                tuple(ms.backend_state for ms in member_states), s.p1, s.p2),
            rerank_codes=donor.rerank_codes,
            rerank_mask=donor.rerank_mask)

    def to_segmented(self, state: RetrieverState, *,
                     id_cap=None) -> RetrieverState:
        if self._segmented(state) is not None:
            return state
        if id_cap is None:
            ids = np.asarray(state.backend_state.members[1].doc_ids)
            id_cap = index_mod.segment_capacity(int(ids.max(initial=-1)) + 1)
        outs = [backend.to_segmented(view, id_cap=id_cap)
                for backend, view in self._views(state)]
        return self._recompose(state, outs, rerank_from=1)

    def add(self, state: RetrieverState, delta: Corpus, cfg: HPCConfig, *,
            doc_ids=None) -> RetrieverState:
        n_new = int(delta.embeddings.shape[0])
        if n_new == 0:
            return state
        state = self.to_segmented(state)
        if doc_ids is None:
            # resolve fresh ids once so every member assigns identically
            seg = self._segmented(state)
            max_id = -1
            for payload in seg.segments:
                ids = np.asarray(index_mod.seg_doc_ids(payload)).reshape(-1)
                max_id = max(max_id, int(ids.max(initial=-1)))
            doc_ids = np.arange(max_id + 1, max_id + 1 + n_new,
                                dtype=np.int64)
        outs = [backend.add(view, delta, cfg, doc_ids=doc_ids)
                for backend, view in self._views(state)]
        return self._recompose(state, outs, rerank_from=1)

    def delete(self, state: RetrieverState, doc_ids) -> RetrieverState:
        state = self.to_segmented(state)
        outs = [backend.delete(view, doc_ids)
                for backend, view in self._views(state)]
        return self._recompose(state, outs, rerank_from=1)

    def compact(self, state: RetrieverState,
                cfg: HPCConfig) -> RetrieverState:
        state = self.to_segmented(state)
        outs = [backend.compact(view, cfg)
                for backend, view in self._views(state)]
        return self._recompose(state, outs, rerank_from=1)

    # -- accounting ---------------------------------------------------------

    def storage_bytes(self, state: RetrieverState) -> Dict[str, int]:
        """Per-stage payloads (stage_* keys) + their sum as `payload`."""
        out: Dict[str, int] = {}
        total = 0
        for name, (backend, view) in zip(STAGES, self._views(state)):
            b = backend.storage_bytes(view)
            out[f"stage_{name}"] = b["payload"]
            total += b["payload"]
            if "codebook" in b:          # shared across stages: count once
                out.setdefault("codebook", b["codebook"])
        out["payload"] = total
        return out

    def build_stats(self, state: RetrieverState) -> Dict[str, float]:
        s = state.backend_state
        stats = {"p1": float(s.p1), "p2": float(s.p2)}
        seg = self._segmented(state)
        if seg is not None:
            stats.update(self._segment_stats(seg))
        for name, (backend, view) in zip(STAGES, self._views(state)):
            for key, val in backend.build_stats(view).items():
                stats[f"{name}_{key}"] = val
        return stats

    def abstract_state(self, *, n: int, md: int = 16, d: int = 16,
                       k: int = 256, **knobs) -> RetrieverState:
        """Compose the members' abstract states (shape-only, no alloc)."""
        bits = knobs.get("bits", binary_mod.bits_for_k(k))
        p1 = knobs.get("p1", 1024)
        p2 = knobs.get("p2", 64)
        segments = knobs.get("segments")
        id_cap = None
        if segments is not None:
            id_cap = knobs.get("id_cap",
                               index_mod.segment_capacity(sum(segments)))
        members = []
        for name in STAGES:
            stage_knobs = {"bits": bits} if name == "hamming" else {}
            if segments is not None:
                stage_knobs.update(segments=segments, id_cap=id_cap)
            ab = get_backend(name).abstract_state(n=n, md=md, d=d, k=k,
                                                  **stage_knobs)
            members.append(ab.backend_state)
        if id_cap is not None:
            n = id_cap
        sds, cdt = jax.ShapeDtypeStruct, code_dtype(k)
        return RetrieverState(
            codebook=sds((k, d), jnp.float32),
            backend_state=CascadeState(tuple(members), p1, p2),
            rerank_codes=sds((n, md), cdt),
            rerank_mask=sds((n, md), jnp.bool_))

    # -- sharding -----------------------------------------------------------

    def shard_specs(self, state: RetrieverState):
        """Compose member spec trees (each member backend's own policy)."""
        s = state.backend_state
        member_specs = tuple(
            backend.shard_specs(view).backend_state
            for backend, view in self._views(state))
        return RetrieverState(
            codebook=(None, None),
            backend_state=CascadeState(member_specs, s.p1, s.p2),
            rerank_codes=("corpus", None),
            rerank_mask=("corpus", None))

    # -- persistence --------------------------------------------------------

    def _state_aux(self, state: RetrieverState):
        s = state.backend_state
        return (s.p1, s.p2, s.members[0].bits)

    def state_template(self, aux, n_segments: int = 0) -> RetrieverState:
        p1, p2, bits = aux
        member_aux = {"hamming": bits, "flat": None, "float_flat": None}
        members = tuple(
            get_backend(name).state_template(
                member_aux[name], n_segments=n_segments).backend_state
            for name in STAGES)
        return RetrieverState(0, CascadeState(members, p1, p2), 0, 0)
