"""`cascade` backend: the staged compression funnel (ROADMAP item).

Composes three existing backends as *search stages* over one shared
encode (codebook + quantized corpus, done once via `encode_corpus`):

    stage 1  hamming     popcount prefilter over all N docs  -> top p1
    stage 2  flat (ADC)  quantized rescore of the p1 pool    -> top p2
    stage 3  float_flat  exact late-interaction rerank       -> top k

Stages 2-3 run through `search_candidates` — the per-query (B, P)
layout of the streaming scan engine — so the expensive stages cost
O(B * p) rather than O(N); the float stage touches only p2/N of the
corpus (the paper's "expensive stage touches ~1% of documents" regime).
The `-1` sentinel contract holds at every boundary: a stage that
surfaces fewer than its budget of valid candidates (including
k > p2 > p1 > N misconfigurations) hands -1 rows downstream, where they
are never scored and stay -1 in the final output.

Budgets (p1, p2) come from `HPCConfig.cascade` at build time and ride
in the state as static aux — the same pattern as IVF's `n_probe` — so
`search(state, query, k=...)` stays self-contained and jit-stable.
Member states nest inside `CascadeState`; persistence, sharding, stats,
and the jaxpr budget analyzer all compose from the member backends.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import binary as binary_mod
from repro.core import index as index_mod
from repro.core import pruning
from repro.retrieval.base import (Corpus, IndexBackend, Query,
                                  RetrieverState, code_dtype, encode_corpus,
                                  get_backend, register_backend)
from repro.retrieval.config import HPCConfig
from repro.retrieval.hamming import HammingState

Array = jax.Array

# Stage composition is fixed (registry names, coarse -> exact). Making
# this data — not config — keeps the persisted aux a plain int tuple
# (no backend names on disk) and the treedef reconstructible without
# pickle. Future stages (DocPruner adaptive budgets, Sculpting merge)
# slot in here once they exist as backends with `search_candidates`.
STAGES = ("hamming", "flat", "float_flat")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CascadeState:
    """Nested member states + the static (p1, p2) stage budgets."""

    members: Tuple  # (HammingState, FlatIndex, FloatFlatIndex)
    p1: int
    p2: int

    def tree_flatten(self):
        return (self.members,), (self.p1, self.p2)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)


@register_backend("cascade")
class CascadeBackend(IndexBackend):
    # the final stage scores raw embeddings — exact late-interaction
    # scores, so the facade skips its quantized rerank (like float_flat)
    exact_scores = True

    def build(self, key: Array, corpus: Corpus, cfg: HPCConfig,
              mesh=None) -> RetrieverState:
        """One shared encode, three member structures over it.

        The Hamming and ADC stages index the SAME pruned quantized
        corpus (binary codes are the centroid indices read at b bits),
        so the funnel adds only the float-stage embeddings on top of
        what `flat` alone would store.
        """
        _, codebook, codes_full, codes, mask = encode_corpus(
            key, corpus, cfg, mesh=mesh)
        ham = HammingState(index_mod.build_hamming(codes, mask, cfg.bits),
                           cfg.bits)
        flat = index_mod.build_flat(codes, mask, codebook)
        emb, fmask = corpus.embeddings, corpus.mask
        if cfg.prune_side in ("doc", "both"):
            pr = pruning.prune_topp(emb, corpus.salience, fmask, p=cfg.p)
            emb, fmask = pr.embeddings, pr.mask
        ff = index_mod.build_float_flat(emb, fmask)
        return RetrieverState(
            codebook=codebook,
            backend_state=CascadeState((ham, flat, ff),
                                       cfg.cascade.p1, cfg.cascade.p2),
            rerank_codes=codes_full,
            rerank_mask=corpus.mask)

    # -- search -------------------------------------------------------------

    def _views(self, state: RetrieverState):
        """(backend, member-view RetrieverState) per stage.

        A view is the outer state with `backend_state` swapped for one
        member — member backends see exactly the state shape they built,
        sharing the outer codebook/rerank leaves.
        """
        return [(get_backend(name), state._replace(backend_state=member))
                for name, member in zip(STAGES, state.backend_state.members)]

    def search(self, state: RetrieverState, query: Query, *, k: int,
               scan=None) -> Tuple[Array, Array]:
        """Run the funnel: full-corpus prefilter, then narrowing stages.

        Stage outputs are *global doc ids*; members are built over the
        same unsharded corpus (doc_ids = arange), so ids double as
        positions for the next stage's `search_candidates` gather.
        """
        s = state.backend_state
        (ham_b, ham_v), (flat_b, flat_v), (ff_b, ff_v) = self._views(state)
        _, ids1 = ham_b.search(ham_v, query, k=s.p1, scan=scan)
        _, ids2 = flat_b.search_candidates(flat_v, query, ids1, k=s.p2,
                                           scan=scan)
        return ff_b.search_candidates(ff_v, query, ids2, k=k, scan=scan)

    # -- accounting ---------------------------------------------------------

    def storage_bytes(self, state: RetrieverState) -> Dict[str, int]:
        """Per-stage payloads (stage_* keys) + their sum as `payload`."""
        out: Dict[str, int] = {}
        total = 0
        for name, (backend, view) in zip(STAGES, self._views(state)):
            b = backend.storage_bytes(view)
            out[f"stage_{name}"] = b["payload"]
            total += b["payload"]
            if "codebook" in b:          # shared across stages: count once
                out.setdefault("codebook", b["codebook"])
        out["payload"] = total
        return out

    def build_stats(self, state: RetrieverState) -> Dict[str, float]:
        s = state.backend_state
        stats = {"p1": float(s.p1), "p2": float(s.p2)}
        for name, (backend, view) in zip(STAGES, self._views(state)):
            for key, val in backend.build_stats(view).items():
                stats[f"{name}_{key}"] = val
        return stats

    def abstract_state(self, *, n: int, md: int = 16, d: int = 16,
                       k: int = 256, **knobs) -> RetrieverState:
        """Compose the members' abstract states (shape-only, no alloc)."""
        bits = knobs.get("bits", binary_mod.bits_for_k(k))
        p1 = knobs.get("p1", 1024)
        p2 = knobs.get("p2", 64)
        members = []
        for name in STAGES:
            stage_knobs = {"bits": bits} if name == "hamming" else {}
            ab = get_backend(name).abstract_state(n=n, md=md, d=d, k=k,
                                                  **stage_knobs)
            members.append(ab.backend_state)
        sds, cdt = jax.ShapeDtypeStruct, code_dtype(k)
        return RetrieverState(
            codebook=sds((k, d), jnp.float32),
            backend_state=CascadeState(tuple(members), p1, p2),
            rerank_codes=sds((n, md), cdt),
            rerank_mask=sds((n, md), jnp.bool_))

    # -- sharding -----------------------------------------------------------

    def shard_specs(self, state: RetrieverState):
        """Compose member spec trees (each member backend's own policy)."""
        s = state.backend_state
        member_specs = tuple(
            backend.shard_specs(view).backend_state
            for backend, view in self._views(state))
        return RetrieverState(
            codebook=(None, None),
            backend_state=CascadeState(member_specs, s.p1, s.p2),
            rerank_codes=("corpus", None),
            rerank_mask=("corpus", None))

    # -- persistence --------------------------------------------------------

    def _state_aux(self, state: RetrieverState):
        s = state.backend_state
        return (s.p1, s.p2, s.members[0].bits)

    def state_template(self, aux) -> RetrieverState:
        p1, p2, bits = aux
        members = (
            HammingState(index_mod.HammingIndex(0, 0, 0, 0), bits),
            index_mod.FlatIndex(0, 0, 0, 0),
            index_mod.FloatFlatIndex(0, 0, 0),
        )
        return RetrieverState(0, CascadeState(members, p1, p2), 0, 0)
