"""`hnsw` backend: layered small-world graph routing (paper §IV).

The graph (core/graph.py) replaces routing only: it walks the mean
decoded-patch vectors to `ef_search` candidate documents, which are then
scored through the same fused `quantized_maxsim` scan as `ivf` — so the
two backends compare head-to-head at equal scanned-candidate budgets
(`ef_search` vs `n_probe * bucket_cap`). `ef_search` is a *static*
search knob carried as pytree aux data, like IVF's `n_probe`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import graph as graph_mod
from repro.core import index as index_mod
from repro.retrieval.base import (Corpus, IndexBackend, Query,
                                  RetrieverState, encode_corpus,
                                  register_backend)
from repro.retrieval.config import HPCConfig

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class HNSWState:
    """HNSWIndex + the static ef_search knob (aux data, not a leaf)."""

    index: graph_mod.HNSWIndex
    ef_search: int

    def tree_flatten(self):
        return (self.index,), self.ef_search

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)


@register_backend("hnsw")
class HNSWBackend(IndexBackend):

    def build(self, key: Array, corpus: Corpus, cfg: HPCConfig,
              mesh=None) -> RetrieverState:
        k_graph, codebook, codes_full, codes, mask = encode_corpus(
            key, corpus, cfg, mesh=mesh)
        hn = graph_mod.build_hnsw(k_graph, codes, mask, codebook, cfg.hnsw)
        return RetrieverState(
            codebook=codebook,
            backend_state=HNSWState(hn, cfg.hnsw.ef_search),
            rerank_codes=codes_full,
            rerank_mask=corpus.mask)

    def search(self, state: RetrieverState, query: Query, *, k: int,
               scan=None) -> Tuple[Array, Array]:
        s = state.backend_state
        seg = self._segmented(state)
        if seg is not None:
            return graph_mod.search_hnsw_live(
                seg.segments[0], seg.live[0], query.embeddings, query.mask,
                ef_search=s.ef_search, k=k, scan=scan)
        return graph_mod.search_hnsw(s.index, query.embeddings, query.mask,
                                     ef_search=s.ef_search, k=k, scan=scan)

    def search_candidates(self, state: RetrieverState, query: Query,
                          candidate_ids, *, k: int,
                          scan=None) -> Tuple[Array, Array]:
        # hnsw declines the stage contract: the graph walk is the candidate
        # generator, not a scorer over externally supplied pools.
        if candidate_ids is None:
            return self.search(state, query, k=k, scan=scan)
        raise NotImplementedError(
            "backend 'hnsw' generates candidates via its graph walk and "
            "does not support candidate-restricted search; use "
            "flat/float_flat/hamming as cascade stages")

    # -- mutation hooks ------------------------------------------------------
    # hnsw keeps ONE growable graph segment: appends insert into the graph
    # (Malkov Alg. 1 over the mean decoded-patch vectors) rather than
    # stacking immutable segments a walk could not cross.

    def _append_segment(self, state: RetrieverState, seg, enc, delta,
                        cfg: HPCConfig, doc_ids: Array):
        _, codes, mask = enc
        ix, live = graph_mod.hnsw_insert(
            seg.segments[0], seg.live[0], codes, mask, doc_ids, cfg.hnsw)
        return index_mod.SegmentedState((ix,), (live,), seg.pos_of_id)

    def _compact_payload(self, state: RetrieverState, seg,
                         cfg: HPCConfig):
        return graph_mod.hnsw_compact(seg.segments[0], seg.live[0],
                                      cfg.hnsw)

    def _seg_payload_bytes(self, payload, n_live: int) -> int:
        codes = payload.codes
        return n_live * codes.shape[-1] * codes.dtype.itemsize

    def storage_bytes(self, state: RetrieverState) -> Dict[str, int]:
        seg = self._segmented(state)
        if seg is not None:
            out = self._segmented_storage(state, seg)
            ix = seg.segments[0]
            # graph bytes are capacity-resident (tombstones stay routable
            # until compact), so they report on the padded cap
            out["graph"] = (ix.neighbors.size * ix.neighbors.dtype.itemsize
                            + ix.doc_vecs.size * ix.doc_vecs.dtype.itemsize)
            return out
        ix = state.backend_state.index
        cb = state.codebook
        graph_bytes = (ix.neighbors.size * ix.neighbors.dtype.itemsize
                       + ix.doc_vecs.size * ix.doc_vecs.dtype.itemsize)
        return {"payload": ix.codes.size * ix.codes.dtype.itemsize,
                "graph": graph_bytes,
                "codebook": cb.size * cb.dtype.itemsize}

    def build_stats(self, state: RetrieverState) -> Dict[str, float]:
        seg = self._segmented(state)
        if seg is not None:
            out = self._segment_stats(seg)
            ix = seg.segments[0]
            filled = ix.doc_ids >= 0
            degree = jnp.sum(ix.neighbors[0] >= 0, axis=-1)
            out["mean_degree_l0"] = float(
                jnp.sum(jnp.where(filled, degree, 0))
                / jnp.maximum(jnp.sum(filled), 1))
            out["levels"] = float(ix.neighbors.shape[0])
            out["entry_level"] = float(ix.node_level[ix.entry])
            return out
        ix = state.backend_state.index
        degree = jnp.sum(ix.neighbors[0] >= 0, axis=-1)
        return {"mean_degree_l0": float(jnp.mean(degree)),
                "levels": int(ix.neighbors.shape[0]),
                "entry_level": int(ix.node_level[ix.entry])}

    def abstract_state(self, *, n: int, md: int = 16, d: int = 16,
                       k: int = 256, **knobs) -> RetrieverState:
        from repro.retrieval.base import code_dtype
        cfg = graph_mod.HNSWConfig()
        levels = knobs.get("levels", cfg.levels)
        m = knobs.get("m", cfg.m)
        ef_search = knobs.get("ef_search", cfg.ef_search)
        sds, cdt = jax.ShapeDtypeStruct, code_dtype(k)

        def graph_sds(cap):
            return graph_mod.HNSWIndex(
                doc_vecs=sds((cap, d), jnp.float32),
                neighbors=sds((levels, cap, 2 * m), jnp.int32),
                entry=sds((), jnp.int32),
                node_level=sds((cap,), jnp.int32),
                codes=sds((cap, md), cdt),
                mask=sds((cap, md), jnp.bool_),
                doc_ids=sds((cap,), jnp.int32),
                codebook=sds((k, d), jnp.float32))

        segments = knobs.get("segments")
        if segments is not None:
            # hnsw keeps one growable segment; only segments[0] is used
            cap = segments[0]
            id_cap = knobs.get("id_cap", index_mod.segment_capacity(cap))
            bs = index_mod.SegmentedState(
                (graph_sds(cap),), (sds((cap,), jnp.bool_),),
                sds((id_cap,), jnp.int32))
            n = id_cap
        else:
            bs = graph_sds(n)
        return RetrieverState(
            codebook=sds((k, d), jnp.float32),
            backend_state=HNSWState(bs, ef_search),
            rerank_codes=sds((n, md), cdt),
            rerank_mask=sds((n, md), jnp.bool_))

    def _state_aux(self, state: RetrieverState):
        return state.backend_state.ef_search

    def state_template(self, aux, n_segments: int = 0) -> RetrieverState:
        if n_segments:
            bs = index_mod.SegmentedState(
                tuple(graph_mod.HNSWIndex(0, 0, 0, 0, 0, 0, 0, 0)
                      for _ in range(n_segments)),
                (0,) * n_segments, 0)
        else:
            bs = graph_mod.HNSWIndex(0, 0, 0, 0, 0, 0, 0, 0)
        return RetrieverState(0, HNSWState(bs, aux), 0, 0)

    def shard_specs(self, state: RetrieverState):
        # The graph walk needs global adjacency + routing vectors, so the
        # graph itself replicates; the scan payload (codes) and the rerank
        # corpus shard over the corpus axis like every other backend.
        def graph_leaf_specs():
            return graph_mod.HNSWIndex(
                doc_vecs=(None, None),
                neighbors=(None, None, None),
                entry=(),
                node_level=(None,),
                codes=("corpus", None),
                mask=("corpus", None),
                doc_ids=("corpus",),
                codebook=(None, None))

        seg = self._segmented(state)
        if seg is not None:
            # live bits replicate: the walk consults them on every shard
            bs = index_mod.SegmentedState(
                tuple(graph_leaf_specs() for _ in seg.segments),
                tuple((None,) for _ in seg.live),
                (None,))
        else:
            bs = graph_leaf_specs()
        return RetrieverState(
            codebook=(None, None),
            backend_state=HNSWState(bs, state.backend_state.ef_search),
            rerank_codes=("corpus", None),
            rerank_mask=("corpus", None))
