"""`hnsw` backend: layered small-world graph routing (paper §IV).

The graph (core/graph.py) replaces routing only: it walks the mean
decoded-patch vectors to `ef_search` candidate documents, which are then
scored through the same fused `quantized_maxsim` scan as `ivf` — so the
two backends compare head-to-head at equal scanned-candidate budgets
(`ef_search` vs `n_probe * bucket_cap`). `ef_search` is a *static*
search knob carried as pytree aux data, like IVF's `n_probe`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import graph as graph_mod
from repro.retrieval.base import (Corpus, IndexBackend, Query,
                                  RetrieverState, encode_corpus,
                                  register_backend)
from repro.retrieval.config import HPCConfig

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class HNSWState:
    """HNSWIndex + the static ef_search knob (aux data, not a leaf)."""

    index: graph_mod.HNSWIndex
    ef_search: int

    def tree_flatten(self):
        return (self.index,), self.ef_search

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)


@register_backend("hnsw")
class HNSWBackend(IndexBackend):

    def build(self, key: Array, corpus: Corpus, cfg: HPCConfig,
              mesh=None) -> RetrieverState:
        k_graph, codebook, codes_full, codes, mask = encode_corpus(
            key, corpus, cfg, mesh=mesh)
        hn = graph_mod.build_hnsw(k_graph, codes, mask, codebook, cfg.hnsw)
        return RetrieverState(
            codebook=codebook,
            backend_state=HNSWState(hn, cfg.hnsw.ef_search),
            rerank_codes=codes_full,
            rerank_mask=corpus.mask)

    def search(self, state: RetrieverState, query: Query, *, k: int,
               scan=None) -> Tuple[Array, Array]:
        s = state.backend_state
        return graph_mod.search_hnsw(s.index, query.embeddings, query.mask,
                                     ef_search=s.ef_search, k=k, scan=scan)

    def search_candidates(self, state: RetrieverState, query: Query,
                          candidate_ids, *, k: int,
                          scan=None) -> Tuple[Array, Array]:
        # hnsw declines the stage contract: the graph walk is the candidate
        # generator, not a scorer over externally supplied pools.
        if candidate_ids is None:
            return self.search(state, query, k=k, scan=scan)
        raise NotImplementedError(
            "backend 'hnsw' generates candidates via its graph walk and "
            "does not support candidate-restricted search; use "
            "flat/float_flat/hamming as cascade stages")

    def storage_bytes(self, state: RetrieverState) -> Dict[str, int]:
        ix = state.backend_state.index
        cb = state.codebook
        graph_bytes = (ix.neighbors.size * ix.neighbors.dtype.itemsize
                       + ix.doc_vecs.size * ix.doc_vecs.dtype.itemsize)
        return {"payload": ix.codes.size * ix.codes.dtype.itemsize,
                "graph": graph_bytes,
                "codebook": cb.size * cb.dtype.itemsize}

    def build_stats(self, state: RetrieverState) -> Dict[str, float]:
        ix = state.backend_state.index
        degree = jnp.sum(ix.neighbors[0] >= 0, axis=-1)
        return {"mean_degree_l0": float(jnp.mean(degree)),
                "levels": int(ix.neighbors.shape[0]),
                "entry_level": int(ix.node_level[ix.entry])}

    def abstract_state(self, *, n: int, md: int = 16, d: int = 16,
                       k: int = 256, **knobs) -> RetrieverState:
        from repro.retrieval.base import code_dtype
        cfg = graph_mod.HNSWConfig()
        levels = knobs.get("levels", cfg.levels)
        m = knobs.get("m", cfg.m)
        ef_search = knobs.get("ef_search", cfg.ef_search)
        sds, cdt = jax.ShapeDtypeStruct, code_dtype(k)
        ix = graph_mod.HNSWIndex(
            doc_vecs=sds((n, d), jnp.float32),
            neighbors=sds((levels, n, 2 * m), jnp.int32),
            entry=sds((), jnp.int32),
            node_level=sds((n,), jnp.int32),
            codes=sds((n, md), cdt),
            mask=sds((n, md), jnp.bool_),
            doc_ids=sds((n,), jnp.int32),
            codebook=sds((k, d), jnp.float32))
        return RetrieverState(
            codebook=sds((k, d), jnp.float32),
            backend_state=HNSWState(ix, ef_search),
            rerank_codes=sds((n, md), cdt),
            rerank_mask=sds((n, md), jnp.bool_))

    def _state_aux(self, state: RetrieverState):
        return state.backend_state.ef_search

    def state_template(self, aux) -> RetrieverState:
        return RetrieverState(
            0, HNSWState(graph_mod.HNSWIndex(0, 0, 0, 0, 0, 0, 0, 0), aux),
            0, 0)

    def shard_specs(self, state: RetrieverState):
        # The graph walk needs global adjacency + routing vectors, so the
        # graph itself replicates; the scan payload (codes) and the rerank
        # corpus shard over the corpus axis like every other backend.
        hnsw_specs = graph_mod.HNSWIndex(
            doc_vecs=(None, None),
            neighbors=(None, None, None),
            entry=(),
            node_level=(None,),
            codes=("corpus", None),
            mask=("corpus", None),
            doc_ids=("corpus",),
            codebook=(None, None))
        return RetrieverState(
            codebook=(None, None),
            backend_state=HNSWState(hnsw_specs,
                                    state.backend_state.ef_search),
            rerank_codes=("corpus", None),
            rerank_mask=("corpus", None))
