"""Retriever API v1: the `IndexBackend` contract + string-keyed registry.

A backend owns ONE primary search structure (exhaustive flat scan, IVF
routing, Hamming scan, ...) behind four methods over pytree state:

    build(key, corpus, cfg)             -> RetrieverState
    search(state, query, *, k, scan)    -> (scores (B, k), doc_ids (B, k))
    storage_bytes(state)                -> {"payload": ..., ...}
    save(path, state) / load(path)      -> RetrieverState

plus `shard_specs(state)` (logical-axis specs so the corpus dimension
shards over the mesh — see repro/dist/sharding.py) and the optional
*search-stage* entry point

    search_candidates(state, query, candidate_ids, *, k, scan)

which scores only a (B, P) per-query id pool — the composable-stage
contract the `cascade` backend chains (Hamming prefilter -> ADC scan ->
float rerank). Everything shared between backends — codebook training,
corpus quantization, doc/query-side pruning, candidate rerank — lives in
the `Retriever` facade (retriever.py) or in the helpers below, so a new
backend is one file:

    @register_backend("my_index")
    class MyBackend(IndexBackend):
        def build(self, key, corpus, cfg): ...
        def search(self, state, query, *, k, scan=None): ...
        def storage_bytes(self, state): ...

See docs/api.md for the full contract.
"""
from __future__ import annotations

import dataclasses
import inspect
import os
import warnings
import zlib
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import index as index_mod
from repro.core import pruning
from repro.core import quantization as quant
from repro.retrieval.config import HPCConfig

Array = jax.Array

# On-disk npz manifest version (IndexBackend.save/load). History:
#   1 — monolithic states, no version key (PR 4; absence of the key
#       identifies a v1 file, which still loads)
#   2 — adds `format_version` + the `segments` count for segmented
#       LSM states (this version reads v1 files unchanged)
#   3 — crash-safe writes (tmp file + fsync + atomic rename) and a
#       per-leaf crc32 `checksums` array; load verifies every leaf and
#       names the corrupt array instead of returning silently-bad data
#       (this version reads v1/v2 files unchanged — they carry no
#       checksums to verify)
FORMAT_VERSION = 3


def leaf_crc32(arr) -> int:
    """crc32 of an array's raw bytes (shape/dtype ride in the npz header)."""
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def fsync_dir(dirname: str) -> None:
    """fsync a directory so a just-renamed file survives power loss.

    Best-effort: some filesystems refuse O_RDONLY fsync on directories;
    the rename itself is still atomic there."""
    try:
        fd = os.open(dirname or ".", os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def code_dtype(k: int):
    """Dtype of centroid-index codes for a K-entry codebook.

    The single source of truth for code width: build AND query sides must
    agree (v0 quantized queries to uint16 while building uint8 corpora).
    """
    return jnp.uint8 if k <= 256 else jnp.uint16


# ---------------------------------------------------------------------------
# Data carriers (all pytrees)
# ---------------------------------------------------------------------------

class Corpus(NamedTuple):
    """Doc-side inputs: (N, Md, D) embeddings, (N, Md) mask/salience."""
    embeddings: Array
    mask: Array
    salience: Array


class Query(NamedTuple):
    """Query-side inputs: (B, Mq, D) embeddings, (B, Mq) mask/salience."""
    embeddings: Array
    mask: Array
    salience: Array


class RetrieverState(NamedTuple):
    """Built index state (a pytree — shardable/checkpointable).

    `backend_state` is the single tagged backend structure (the tag is its
    Python type — FlatIndex, IVFState, HammingState, FloatFlatIndex), which
    replaces v0's four-way Optional union. `rerank_codes`/`rerank_mask`
    hold the unpruned quantized corpus for the facade's rerank stage.
    """

    codebook: Array
    backend_state: Any
    rerank_codes: Array
    rerank_mask: Array

    # v0 `HPCIndex` compatibility accessors -------------------------------
    #
    # DEPRECATED since PR 7 (scheduled for removal in v2.0): read
    # `state.backend_state` and dispatch on its type instead. These
    # properties predate the tagged-union state and only resolve the four
    # v0 structures (a `cascade` state returns None from all of them).
    # The frozen-v0 parity tests keep exercising them until removal.

    @property
    def flat(self) -> Optional[index_mod.FlatIndex]:
        """Deprecated v0 accessor — use `backend_state` (removal: v2.0)."""
        s = self.backend_state
        return s if isinstance(s, index_mod.FlatIndex) else None

    @property
    def float_flat(self) -> Optional[index_mod.FloatFlatIndex]:
        """Deprecated v0 accessor — use `backend_state` (removal: v2.0)."""
        s = self.backend_state
        return s if isinstance(s, index_mod.FloatFlatIndex) else None

    @property
    def ivf(self) -> Optional[index_mod.IVFIndex]:
        """Deprecated v0 accessor — use `backend_state` (removal: v2.0)."""
        from repro.retrieval.ivf import IVFState
        s = self.backend_state
        return s.index if isinstance(s, IVFState) else None

    @property
    def hamming(self) -> Optional[index_mod.HammingIndex]:
        """Deprecated v0 accessor — use `backend_state` (removal: v2.0)."""
        from repro.retrieval.hamming import HammingState
        s = self.backend_state
        return s.index if isinstance(s, HammingState) else None


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, "IndexBackend"] = {}


def _accepts_scan(fn) -> bool:
    """Does this `search` implementation take the `scan=` keyword?"""
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):   # builtins/C callables: assume modern
        return True
    return "scan" in params or any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values())


def register_backend(name: str):
    """Class decorator: `@register_backend("flat")` installs a singleton.

    `search` implementations must accept the full v1 signature
    `(state, query, *, k, scan=None)`. Legacy out-of-tree backends
    whose `search` predates the `scan=` keyword still register, but get
    one `DeprecationWarning` here and a shim that strips `scan` before
    calling them (scheduled for removal in v2.0 — accept `scan=` to
    opt into the streaming-scan knobs).
    """
    def deco(cls):
        cls.name = name
        if not _accepts_scan(cls.search):
            warnings.warn(
                f"index backend {name!r}: search() does not accept the "
                "scan= keyword; registering a compatibility shim that "
                "drops it. Add `scan=None` to the signature — the shim "
                "will be removed in v2.0.",
                DeprecationWarning, stacklevel=3)
            legacy_search = cls.search

            def search(self, state, query, *, k, scan=None):
                del scan  # legacy backend cannot use the scan knobs
                return legacy_search(self, state, query, k=k)

            search.__doc__ = legacy_search.__doc__
            cls.search = search
        _REGISTRY[name] = cls()
        return cls
    return deco


def _ensure_builtin_backends():
    """Install the built-in backends (idempotent, import-cycle safe).

    Registration normally happens when `repro.retrieval` initialises; this
    lazy hook covers callers that imported only a submodule (e.g. the
    `repro.core.pipeline` compat shim during `repro.core` package init).
    """
    from repro.retrieval import (cascade, flat, float_flat,  # noqa: F401
                                 hamming, hnsw, ivf)


def get_backend(name: str) -> "IndexBackend":
    if name not in _REGISTRY:
        _ensure_builtin_backends()
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown index backend {name!r}; available: "
            f"{sorted(_REGISTRY)}")
    return _REGISTRY[name]


def available_backends() -> Tuple[str, ...]:
    _ensure_builtin_backends()
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# Shared build stages (identical numerics to the v0 pipeline)
# ---------------------------------------------------------------------------

def kmeans_config(cfg: HPCConfig) -> quant.KMeansConfig:
    """The codebook-training config implied by an HPCConfig."""
    return quant.KMeansConfig(
        k=cfg.k, iters=cfg.kmeans_iters, seed_batch=cfg.kmeans_seed_batch,
        n_restarts=cfg.kmeans_restarts, minibatch=cfg.kmeans_minibatch)


def fit_codebook(key: Array, corpus: Corpus, cfg: HPCConfig,
                 mesh=None) -> Array:
    """Train the K-Means codebook on valid patches only.

    Invalid rows are replaced by resampled valid rows so Lloyd sees real
    data (zero vectors would otherwise form their own cluster). With a
    `mesh`, training runs through the sharded k-means
    (core/distributed.py): points sharded over the corpus axes, per-cluster
    stats psum-reduced — same seeds and algorithm as the single-host path.
    """
    d = corpus.embeddings.shape[-1]
    flat = corpus.embeddings.reshape(-1, d)
    flat_mask = corpus.mask.reshape(-1)
    valid_idx = jnp.argsort(~flat_mask, stable=True)  # valid rows first
    n_valid = jnp.sum(flat_mask)
    gather_idx = jnp.where(
        jnp.arange(flat.shape[0]) < n_valid,
        valid_idx,
        valid_idx[jnp.mod(jnp.arange(flat.shape[0]),
                          jnp.maximum(n_valid, 1))])
    train_x = flat[gather_idx]
    if mesh is not None:
        from repro.core import distributed as dist
        codebook, _ = dist.sharded_kmeans_fit(mesh, key, train_x,
                                              kmeans_config(cfg))
    else:
        codebook, _ = quant.kmeans_fit(key, train_x, kmeans_config(cfg))
    return codebook


def encode_corpus(key: Array, corpus: Corpus, cfg: HPCConfig, mesh=None
                  ) -> Tuple[Array, Array, Array, Array, Array]:
    """Shared offline stages for all code-based backends.

    Splits the key exactly like v0 `build_index` (codebook key first, the
    remainder free for the backend's own structure, e.g. IVF routing),
    trains the codebook, quantizes the full corpus (the rerank structure),
    and applies doc-side pruning for the primary structure. With a `mesh`,
    codebook training and corpus quantization run sharded over the mesh's
    corpus axes (assignment through the Pallas kernel on TPU devices).

    Returns (struct_key, codebook, codes_full, codes, mask).
    """
    k_cb, k_struct = jax.random.split(key)
    codebook = fit_codebook(k_cb, corpus, cfg, mesh=mesh)
    if mesh is not None:
        from repro.core import distributed as dist
        codes_full = dist.sharded_quantize(mesh, corpus.embeddings, codebook,
                                           code_dtype(cfg.k))       # (N, Md)
    else:
        codes_full = quant.quantize(corpus.embeddings, codebook,
                                    code_dtype=code_dtype(cfg.k))   # (N, Md)
    if cfg.prune_side in ("doc", "both"):
        codes, _, mask, _ = pruning.prune_topp_codes(
            codes_full, corpus.salience, corpus.mask, p=cfg.p)
    else:
        codes, mask = codes_full, corpus.mask
    return k_struct, codebook, codes_full, codes, mask


def encode_delta(codebook: Array, delta: Corpus, cfg: HPCConfig
                 ) -> Tuple[Array, Array, Array]:
    """Encode a corpus delta against an EXISTING codebook (no refit).

    The online counterpart of `encode_corpus`: quantizes the delta's
    patches with the codebook the index was built with and applies the
    same doc-side pruning policy, so an appended segment is scored on
    exactly the representation a from-scratch build would give those
    docs. Returns (codes_full, codes, mask) — full codes feed the rerank
    rows, pruned codes/mask feed the primary structure.
    """
    k = codebook.shape[0]
    codes_full = quant.quantize(delta.embeddings, codebook,
                                code_dtype=code_dtype(k))
    if cfg.prune_side in ("doc", "both"):
        codes, _, mask, _ = pruning.prune_topp_codes(
            codes_full, delta.salience, delta.mask, p=cfg.p)
    else:
        codes, mask = codes_full, delta.mask
    return codes_full, codes, mask


# ---------------------------------------------------------------------------
# Backend base class
# ---------------------------------------------------------------------------

class IndexBackend:
    """Contract every index backend implements (see module docstring)."""

    name: str = "?"
    # True -> the backend's scores are exact late-interaction scores over
    # raw embeddings; the facade skips the quantized rerank stage.
    exact_scores: bool = False

    # -- required -----------------------------------------------------------

    def build(self, key: Array, corpus: Corpus, cfg: HPCConfig,
              mesh=None) -> RetrieverState:
        """Offline indexing. `mesh` (optional) runs the shared encode
        stages (codebook fit + corpus quantization) sharded over it."""
        raise NotImplementedError

    def search(self, state: RetrieverState, query: Query, *, k: int,
               scan=None) -> Tuple[Array, Array]:
        """Candidate search -> (scores (B, k), doc_ids (B, k)).

        `scan` (a `repro.core.scan.ScanConfig`, or None for defaults)
        selects the streaming-scan block size and block-scorer impl; all
        built-in backends route their scoring through the blocked
        score+top-k engine in core/scan.py, so no search path ever
        materialises an O(N * Mq) intermediate.

        Sentinel contract: a backend whose structure can surface fewer
        than k valid documents (ivf with sparse probed buckets, hnsw with
        a beam smaller than k reachable nodes, any backend asked for
        k > N) MUST fill the tail rows with doc_id -1 and NEG_INF-or-
        below scores (for hamming's int32 scores: the int32 minimum).
        Consumers — the facade rerank, benchmarks, hit/recall
        accounting — must ignore `id < 0` rows rather than treating them
        as real documents.
        """
        raise NotImplementedError

    def search_candidates(self, state: RetrieverState, query: Query,
                          candidate_ids: Array, *, k: int,
                          scan=None) -> Tuple[Array, Array]:
        """Score only a (B, P) per-query candidate pool -> (B, k) top-k.

        The composable search-stage entry point: `candidate_ids[b]` lists
        the corpus positions query `b` may match (typically a coarser
        stage's output ids); -1 marks empty pool slots and is never
        scored. Output rows follow the same sentinel contract as
        `search` — with fewer than k valid candidates (including k > P)
        the tail rows carry doc_id -1 and sentinel scores. Cost must be
        O(B * P), never O(N): implementations route through the scan
        engine's per-query-candidates layout, no full-corpus gather.

        `search(state, query, k=k)` is semantically this method with
        `candidate_ids=None` (the whole corpus as the pool). Backends
        whose structure already does its own candidate routing (ivf,
        hnsw) may decline by raising NotImplementedError — stage
        composition then excludes them.
        """
        if candidate_ids is None:
            return self.search(state, query, k=k, scan=scan)
        raise NotImplementedError(
            f"backend {self.name!r} does not support candidate-restricted "
            "search (search_candidates); use flat/float_flat/hamming as "
            "cascade stages")

    # -- mutation (segmented LSM store — docs/design.md §9) ------------------
    #
    # A built state starts monolithic (bit-identical to the pre-mutation
    # format); the first `add`/`delete` normalizes it into a
    # `SegmentedState` — segment 0 wraps the existing structure zero-copy.
    # `add` appends one immutable pow2-capacity segment encoded with the
    # EXISTING codebook; `delete` flips live bits (tombstones honored by
    # every search path via the valid-mask contract); `compact` gathers
    # the live docs back into a single segment. Rerank rows are indexed
    # by GLOBAL doc id throughout, so the facade's rerank never changes.

    def _segmented(self, state: RetrieverState
                   ) -> Optional[index_mod.SegmentedState]:
        """The state's SegmentedState, or None while still monolithic."""
        s = state.backend_state
        if isinstance(s, index_mod.SegmentedState):
            return s
        if self._is_wrapper(s) and isinstance(s.index,
                                              index_mod.SegmentedState):
            return s.index
        return None

    @staticmethod
    def _is_wrapper(s) -> bool:
        """Aux-carrying wrapper state (IVFState/HammingState/HNSWState)?

        NamedTuple payloads (FlatIndex, ...) also `hasattr(s, "index")` —
        the tuple method — so require a dataclass with an `index` field.
        """
        return (dataclasses.is_dataclass(s)
                and not isinstance(s, index_mod.SegmentedState)
                and any(f.name == "index" for f in dataclasses.fields(s)))

    def _set_segmented(self, state: RetrieverState,
                       seg: index_mod.SegmentedState) -> RetrieverState:
        s = state.backend_state
        if self._is_wrapper(s):
            return state._replace(
                backend_state=dataclasses.replace(s, index=seg))
        return state._replace(backend_state=seg)

    def _wrap_segment(self, state: RetrieverState
                      ) -> Tuple[Any, Array]:
        """(payload, live) wrapping the monolithic structure zero-copy."""
        s = state.backend_state
        payload = s.index if self._is_wrapper(s) else s
        return payload, index_mod.seg_doc_ids(payload) >= 0

    def _grow_rerank(self, state: RetrieverState, id_cap: int
                     ) -> RetrieverState:
        if state.rerank_codes.shape[0] >= id_cap:
            return state
        return state._replace(
            rerank_codes=index_mod.pad_dim0(state.rerank_codes, id_cap, 0),
            rerank_mask=index_mod.pad_dim0(state.rerank_mask, id_cap, False))

    def to_segmented(self, state: RetrieverState, *,
                     id_cap: Optional[int] = None) -> RetrieverState:
        """Normalize a monolithic state into single-segment form (no-op if
        already segmented). Search results are bit-identical either way —
        segment 0 IS the original structure."""
        if self._segmented(state) is not None:
            return state
        payload, live = self._wrap_segment(state)
        if id_cap is None:
            ids = np.asarray(index_mod.seg_doc_ids(payload)).reshape(-1)
            id_cap = index_mod.segment_capacity(int(ids.max(initial=-1)) + 1)
        seg = index_mod.SegmentedState(
            (payload,), (live,),
            index_mod.rebuild_pos_of_id((payload,), (live,), id_cap))
        return self._grow_rerank(self._set_segmented(state, seg), id_cap)

    # per-backend append hooks -------------------------------------------

    def _encode_delta(self, state: RetrieverState, delta: Corpus,
                      cfg: HPCConfig) -> Tuple[Array, Array, Array]:
        """(full_repr, payload_repr, payload_mask) for a delta."""
        return encode_delta(state.codebook, delta, cfg)

    def _delta_segment(self, state: RetrieverState,
                       seg: index_mod.SegmentedState, enc, delta: Corpus,
                       cfg: HPCConfig, doc_ids: Array) -> Tuple[Any, Array]:
        """(payload, live) for an append segment — backend-specific."""
        raise NotImplementedError(
            f"backend {self.name!r} does not support add()")

    def _append_segment(self, state: RetrieverState,
                        seg: index_mod.SegmentedState, enc, delta: Corpus,
                        cfg: HPCConfig, doc_ids: Array
                        ) -> index_mod.SegmentedState:
        """Default: one more immutable segment. `hnsw` overrides to grow
        its single graph segment in place (incremental insert)."""
        payload, live = self._delta_segment(state, seg, enc, delta, cfg,
                                            doc_ids)
        return index_mod.SegmentedState(
            seg.segments + (payload,), seg.live + (live,), seg.pos_of_id)

    def _rerank_delta_rows(self, enc, delta: Corpus) -> Tuple[Array, Array]:
        """Rows written into the id-indexed rerank corpus for a delta."""
        return enc[0], delta.mask

    # public mutation API -------------------------------------------------

    def add(self, state: RetrieverState, delta: Corpus, cfg: HPCConfig, *,
            doc_ids=None) -> RetrieverState:
        """Append (or upsert) documents without rebuilding. Returns the
        new state; `state` is unchanged (segments are immutable).

        doc_ids None assigns fresh ids past the largest ever used.
        Explicit ids may reuse existing ones: a live prior occurrence is
        tombstoned (upsert — the newest segment wins), a dead one stays
        dead. Duplicate ids within one delta are rejected. The delta must
        have the same patch count (Md) and embedding dim as the corpus
        the index was built on.
        """
        n_new = int(delta.embeddings.shape[0])
        if n_new == 0:
            return state
        state = self.to_segmented(state)
        seg = self._segmented(state)

        # resolve ids (host side)
        max_assigned = -1
        for payload in seg.segments:
            s_ids = np.asarray(index_mod.seg_doc_ids(payload))
            if s_ids.size:
                max_assigned = max(max_assigned, int(s_ids.max()))
        if doc_ids is None:
            ids_np = np.arange(max_assigned + 1, max_assigned + 1 + n_new,
                               dtype=np.int64)
        else:
            ids_np = np.asarray(jax.device_get(doc_ids),
                                np.int64).reshape(-1)
            if ids_np.shape[0] != n_new:
                raise ValueError(
                    f"doc_ids has {ids_np.shape[0]} entries for a "
                    f"{n_new}-doc delta")
            if (ids_np < 0).any():
                raise ValueError("doc_ids must be non-negative")
            if np.unique(ids_np).size != n_new:
                raise ValueError(
                    "duplicate doc_ids within one add() delta; split the "
                    "delta so each id appears once (newest-wins upserts "
                    "need a segment boundary between occurrences)")
        ids_j = jnp.asarray(ids_np, jnp.int32)

        # prior live occurrences of reused ids -> flattened positions now
        # (positions of existing rows are stable under append)
        pos_np = np.asarray(seg.pos_of_id)
        in_cap = ids_np < pos_np.shape[0]
        old_pos = np.where(in_cap, pos_np[np.minimum(
            ids_np, pos_np.shape[0] - 1)], -1)
        kill_pos = old_pos[old_pos >= 0]

        enc = self._encode_delta(state, delta, cfg)
        seg2 = self._append_segment(state, seg, enc, delta, cfg, ids_j)

        if kill_pos.size:  # upsert: tombstone the prior occurrence
            new_live, off = [], 0
            for payload, lv in zip(seg2.segments, seg2.live):
                size = int(np.prod(np.shape(
                    index_mod.seg_doc_ids(payload))))
                sel = kill_pos[(kill_pos >= off) & (kill_pos < off + size)]
                if sel.size:
                    lv_np = np.asarray(lv).reshape(-1).copy()
                    lv_np[sel - off] = False
                    new_live.append(jnp.asarray(
                        lv_np.reshape(np.shape(lv))))
                else:
                    new_live.append(lv)
                off += size
            seg2 = index_mod.SegmentedState(seg2.segments, tuple(new_live),
                                            seg2.pos_of_id)

        id_cap = index_mod.segment_capacity(
            max(pos_np.shape[0], int(ids_np.max()) + 1))
        seg2 = index_mod.SegmentedState(
            seg2.segments, seg2.live,
            index_mod.rebuild_pos_of_id(seg2.segments, seg2.live, id_cap))
        state = self._grow_rerank(self._set_segmented(state, seg2), id_cap)
        rc_rows, rm_rows = self._rerank_delta_rows(enc, delta)
        return state._replace(
            rerank_codes=state.rerank_codes.at[ids_j].set(
                rc_rows.astype(state.rerank_codes.dtype)),
            rerank_mask=state.rerank_mask.at[ids_j].set(
                rm_rows.astype(state.rerank_mask.dtype)))

    def delete(self, state: RetrieverState, doc_ids) -> RetrieverState:
        """Tombstone documents by global id. O(total slots) host work, no
        device recompute: searches mask the docs out via the valid-mask
        contract (scores exactly NEG_INF, ids -1). Unknown or already-
        dead ids are a no-op."""
        state = self.to_segmented(state)
        seg = self._segmented(state)
        kill = np.unique(np.asarray(jax.device_get(doc_ids),
                                    np.int64).reshape(-1))
        kill = kill[kill >= 0]
        new_live, changed = [], False
        for payload, lv in zip(seg.segments, seg.live):
            s_ids = np.asarray(index_mod.seg_doc_ids(payload))
            lv_np = np.asarray(lv)
            hit = np.isin(s_ids, kill) & lv_np
            if hit.any():
                changed = True
                new_live.append(jnp.asarray(lv_np & ~hit))
            else:
                new_live.append(lv)
        if not changed:
            return state
        seg2 = index_mod.SegmentedState(
            seg.segments, tuple(new_live),
            index_mod.rebuild_pos_of_id(seg.segments, tuple(new_live),
                                        seg.pos_of_id.shape[0]))
        return self._set_segmented(state, seg2)

    def _compact_payload(self, state: RetrieverState,
                         seg: index_mod.SegmentedState, cfg: HPCConfig
                         ) -> Tuple[Any, Array]:
        """(payload, live) holding exactly the live docs — per backend."""
        raise NotImplementedError(
            f"backend {self.name!r} does not support compact()")

    def compact(self, state: RetrieverState, cfg: HPCConfig
                ) -> RetrieverState:
        """Physically drop tombstones: gather the live docs into a single
        fresh segment (ivf re-buckets through its existing centroids,
        hnsw re-inserts live nodes with their stored level draws). Doc
        ids and the id-indexed rerank rows are preserved, so search
        results over the live corpus are unchanged at full budgets."""
        state = self.to_segmented(state)
        seg = self._segmented(state)
        payload, live = self._compact_payload(state, seg, cfg)
        seg2 = index_mod.SegmentedState(
            (payload,), (live,),
            index_mod.rebuild_pos_of_id((payload,), (live,),
                                        seg.pos_of_id.shape[0]))
        return self._set_segmented(state, seg2)

    def storage_bytes(self, state: RetrieverState) -> Dict[str, int]:
        raise NotImplementedError

    # -- segmented accounting helpers ---------------------------------------

    def _seg_payload_bytes(self, payload, n_live: int) -> int:
        """Payload bytes attributable to `n_live` live docs of a segment."""
        raise NotImplementedError

    def _segmented_storage(self, state: RetrieverState,
                           seg: index_mod.SegmentedState) -> Dict[str, int]:
        """Live-docs-only payload accounting + per-segment breakdown.

        Tombstoned docs stop counting toward `payload` the moment they
        are deleted (satellite contract) — physical bytes are only freed
        at compact, but the storage *metric* tracks the live corpus.
        """
        out: Dict[str, int] = {}
        total = 0
        for i, (payload, lv) in enumerate(zip(seg.segments, seg.live)):
            ids = np.asarray(index_mod.seg_doc_ids(payload)).reshape(-1)
            n_live = int(np.sum(np.asarray(lv).reshape(-1) & (ids >= 0)))
            b = self._seg_payload_bytes(payload, n_live)
            out[f"segment_{i}_payload"] = b
            total += b
        out["payload"] = total
        cb = state.codebook
        out["codebook"] = cb.size * cb.dtype.itemsize
        return out

    def _segment_stats(self, seg: index_mod.SegmentedState
                       ) -> Dict[str, float]:
        live, tomb = seg.counts()
        return {"segments": float(seg.n_segments),
                "live_docs": float(live),
                "tombstoned_docs": float(tomb),
                "tombstone_frac": tomb / max(live + tomb, 1)}

    # -- diagnostics --------------------------------------------------------

    def build_stats(self, state: RetrieverState) -> Dict[str, float]:
        """Structure-quality stats of a built index (may sync to host).

        Backends override to expose what their build dropped or skewed
        (e.g. `ivf` reports its bucket-overflow drop rate). Default: {}
        for monolithic states; segmented states report the segment
        lifecycle counters (segments / live_docs / tombstoned_docs /
        tombstone_frac) — overriders should merge `_segment_stats` in.
        """
        seg = self._segmented(state)
        return self._segment_stats(seg) if seg is not None else {}

    def abstract_state(self, *, n: int, md: int = 16, d: int = 16,
                       k: int = 256, **knobs) -> RetrieverState:
        """Shape-only `RetrieverState` at corpus size `n` (no allocation).

        The static-analysis registration hook (docs/design.md §8): every
        leaf is a `jax.ShapeDtypeStruct`, so `repro.analysis` can trace
        `search` against a 2^20-document corpus and walk the jaxpr
        without ever building an index. `knobs` carries backend-specific
        structure parameters (ivf: n_list/n_probe, hnsw: levels/m/
        ef_search, hamming: bits) — the same statics a real build would
        bake in, so the traced program matches production.
        """
        raise NotImplementedError(
            f"backend {self.name!r} must define abstract_state to register "
            "with the jaxpr budget analyzer (repro.analysis.manifests)")

    # -- sharding -----------------------------------------------------------

    def shard_specs(self, state: RetrieverState):
        """Logical-axis spec tree matching `state` (same treedef).

        Default: shard dim 0 of every backend-state array over the
        "corpus" logical axis (documents/buckets over the mesh), keep the
        codebook replicated, shard the rerank corpus over "corpus" too.
        Backends with non-corpus leading dims override this. Segmented
        states shard per segment (each segment's dim 0 spreads over the
        mesh independently); the id->position map replicates — every
        shard resolves global ids locally.
        """
        def leaf_spec(leaf):
            nd = jnp.ndim(leaf)
            return ("corpus",) + (None,) * (nd - 1) if nd else ()
        backend_specs = jax.tree.map(leaf_spec, state.backend_state)
        if self._segmented(state) is not None:
            def fix(sp):
                return dataclasses.replace(sp, pos_of_id=(None,))
            backend_specs = (
                dataclasses.replace(backend_specs, index=fix(
                    backend_specs.index))
                if self._is_wrapper(backend_specs) else fix(backend_specs))
        return RetrieverState(
            codebook=(None, None),
            backend_state=backend_specs,
            rerank_codes=("corpus", None),
            rerank_mask=("corpus", None))

    # -- persistence --------------------------------------------------------
    #
    # One flat .npz: ordered array leaves + the backend name + an optional
    # static-aux scalar or int tuple (IVF n_probe, Hamming bits, cascade
    # (p1, p2, bits)). The treedef is NEVER serialized — it is
    # reconstructed from `state_template`, so loading an untrusted index
    # file deserializes arrays only (no pickle, no code).

    def _state_aux(self, state: RetrieverState):
        """Static aux carried by the backend state (None if stateless)."""
        return None

    def state_template(self, aux, n_segments: int = 0) -> RetrieverState:
        """Dummy-leaf state with this backend's exact pytree structure.

        `n_segments` 0 is the monolithic layout; > 0 is a SegmentedState
        with that many segments. Backends with custom state must override
        this (or save/load)."""
        raise NotImplementedError(
            f"backend {self.name!r} must define state_template (or override "
            "save/load) for persistence")

    def _n_segments(self, state: RetrieverState) -> int:
        seg = self._segmented(state)
        return seg.n_segments if seg is not None else 0

    def _template(self, aux, n_segments: int) -> RetrieverState:
        """state_template with a graceful path for legacy overrides that
        predate the n_segments parameter (monolithic-only backends)."""
        try:
            return self.state_template(aux, n_segments=n_segments)
        except TypeError:
            if n_segments:
                raise
            return self.state_template(aux)

    def save(self, path: str, state: RetrieverState) -> str:
        aux = self._state_aux(state)
        n_seg = self._n_segments(state)
        leaves, treedef = jax.tree_util.tree_flatten(state)
        template_def = jax.tree_util.tree_structure(
            self._template(aux, n_seg))
        if treedef != template_def:
            raise NotImplementedError(
                f"backend {self.name!r}: state structure {treedef} does not "
                f"match state_template {template_def}; override save/load")
        payload = {f"leaf_{i:04d}": np.asarray(leaf)
                   for i, leaf in enumerate(leaves)}
        payload["backend"] = np.array(self.name)
        payload["format_version"] = np.asarray(FORMAT_VERSION, np.int64)
        if n_seg:
            payload["segments"] = np.asarray(n_seg, np.int64)
        if aux is not None:
            payload["aux"] = np.asarray(aux, np.int64)
        # v3: per-leaf crc32, ordered like the leaf keys — load verifies
        # and names the corrupt array instead of returning bad data
        payload["checksums"] = np.asarray(
            [leaf_crc32(payload[f"leaf_{i:04d}"])
             for i in range(len(leaves))], np.uint32)
        if not path.endswith(".npz"):
            path = path + ".npz"
        # crash-safe: a SIGKILL mid-write leaves either the previous
        # complete file or a stray .tmp — never a torn index at `path`
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            np.savez(f, **payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        fsync_dir(os.path.dirname(path))
        return path

    def load(self, path: str) -> RetrieverState:
        if not path.endswith(".npz"):
            path = path + ".npz"
        with np.load(path, allow_pickle=False) as z:
            if "backend" not in z.files:
                raise ValueError(
                    f"{path!r} is not a retriever index file (no 'backend' "
                    "key); it may predate the v1 retriever format — rebuild "
                    "the index with this version")
            saved = str(z["backend"])
            if saved != self.name:
                raise ValueError(
                    f"index was saved by backend {saved!r}, not {self.name!r}")
            # absence of the key marks a format-v1 file (still readable);
            # files from the future fail with a clear message instead of
            # an opaque structure mismatch
            version = (int(z["format_version"])
                       if "format_version" in z.files else 1)
            if version > FORMAT_VERSION:
                raise ValueError(
                    f"index file {path!r} has format version {version}; "
                    f"this build reads versions <= {FORMAT_VERSION} — "
                    "upgrade to load it, or re-save with this version")
            n_seg = int(z["segments"]) if "segments" in z.files else 0
            if "aux" in z.files:
                a = z["aux"]
                aux = int(a) if a.ndim == 0 else tuple(int(x) for x in a)
            else:
                aux = None
            names = sorted(n for n in z.files if n.startswith("leaf_"))
            host_leaves = [z[n] for n in names]
            if "checksums" in z.files:
                crcs = np.asarray(z["checksums"], np.uint32)
                if crcs.size != len(names):
                    raise ValueError(
                        f"index file {path!r} carries {crcs.size} checksums "
                        f"for {len(names)} arrays — truncated manifest")
                for name, arr, want in zip(names, host_leaves, crcs):
                    got = leaf_crc32(arr)
                    if got != int(want):
                        raise ValueError(
                            f"index file {path!r}: checksum mismatch on "
                            f"array {name!r} (crc32 {got:#010x} != stored "
                            f"{int(want):#010x}) — the file is corrupt; "
                            "restore from a previous complete save")
            leaves = [jnp.asarray(a) for a in host_leaves]
        treedef = jax.tree_util.tree_structure(self._template(aux, n_seg))
        if treedef.num_leaves != len(leaves):
            raise ValueError(
                f"index file has {len(leaves)} arrays, backend {self.name!r} "
                f"expects {treedef.num_leaves}")
        return jax.tree_util.tree_unflatten(treedef, leaves)
