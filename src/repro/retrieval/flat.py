"""`flat` backend: exhaustive fused ADC MaxSim scan over quantized codes.

The paper's main configuration (quantized + flat): one MXU-friendly pass
over the (pruned) code corpus per query batch.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax

import jax.numpy as jnp

from repro.core import index as index_mod
from repro.retrieval.base import (Corpus, IndexBackend, Query,
                                  RetrieverState, code_dtype, encode_corpus,
                                  register_backend)
from repro.retrieval.config import HPCConfig

Array = jax.Array


@register_backend("flat")
class FlatBackend(IndexBackend):

    def build(self, key: Array, corpus: Corpus, cfg: HPCConfig,
              mesh=None) -> RetrieverState:
        _, codebook, codes_full, codes, mask = encode_corpus(
            key, corpus, cfg, mesh=mesh)
        return RetrieverState(
            codebook=codebook,
            backend_state=index_mod.build_flat(codes, mask, codebook),
            rerank_codes=codes_full,
            rerank_mask=corpus.mask)

    def search(self, state: RetrieverState, query: Query, *, k: int,
               scan=None) -> Tuple[Array, Array]:
        seg = self._segmented(state)
        if seg is not None:
            return index_mod.search_flat_segmented(
                seg, query.embeddings, query.mask, k=k, scan=scan)
        return index_mod.search_flat(
            state.backend_state, query.embeddings, query.mask, k=k,
            scan=scan)

    def search_candidates(self, state: RetrieverState, query: Query,
                          candidate_ids, *, k: int,
                          scan=None) -> Tuple[Array, Array]:
        if candidate_ids is None:
            return self.search(state, query, k=k, scan=scan)
        seg = self._segmented(state)
        if seg is not None:
            return index_mod.search_flat_segmented_candidates(
                seg, query.embeddings, query.mask, candidate_ids, k=k,
                scan=scan)
        return index_mod.search_flat_candidates(
            state.backend_state, query.embeddings, query.mask,
            candidate_ids, k=k, scan=scan)

    # -- mutation hooks ------------------------------------------------------

    def _delta_segment(self, state, seg, enc, delta, cfg, doc_ids):
        _, codes, mask = enc
        return index_mod.make_flat_segment(codes, mask, state.codebook,
                                           doc_ids)

    def _compact_payload(self, state, seg, cfg):
        (codes, mask), ids = index_mod.gather_live_rows(
            seg, ("codes", "mask"))
        return index_mod.make_flat_segment(codes, mask, state.codebook, ids)

    def _seg_payload_bytes(self, payload, n_live: int) -> int:
        codes = payload.codes
        return n_live * codes.shape[-1] * codes.dtype.itemsize

    def storage_bytes(self, state: RetrieverState) -> Dict[str, int]:
        seg = self._segmented(state)
        if seg is not None:
            return self._segmented_storage(state, seg)
        codes = state.backend_state.codes
        cb = state.codebook
        return {"payload": codes.size * codes.dtype.itemsize,
                "codebook": cb.size * cb.dtype.itemsize}

    def abstract_state(self, *, n: int, md: int = 16, d: int = 16,
                       k: int = 256, **knobs) -> RetrieverState:
        sds, cdt = jax.ShapeDtypeStruct, code_dtype(k)

        def seg_payload(cap):
            return index_mod.FlatIndex(
                codes=sds((cap, md), cdt),
                mask=sds((cap, md), jnp.bool_),
                codebook=sds((k, d), jnp.float32),
                doc_ids=sds((cap,), jnp.int32))

        segments = knobs.get("segments")
        if segments is not None:
            # segmented layout: tuple of per-segment capacities
            id_cap = knobs.get("id_cap",
                               index_mod.segment_capacity(sum(segments)))
            bs = index_mod.SegmentedState(
                tuple(seg_payload(c) for c in segments),
                tuple(sds((c,), jnp.bool_) for c in segments),
                sds((id_cap,), jnp.int32))
            n = id_cap
        else:
            bs = seg_payload(n)
        return RetrieverState(
            codebook=sds((k, d), jnp.float32),
            backend_state=bs,
            rerank_codes=sds((n, md), cdt),
            rerank_mask=sds((n, md), jnp.bool_))

    def state_template(self, aux, n_segments: int = 0) -> RetrieverState:
        if n_segments:
            bs = index_mod.SegmentedState(
                tuple(index_mod.FlatIndex(0, 0, 0, 0)
                      for _ in range(n_segments)),
                (0,) * n_segments, 0)
        else:
            bs = index_mod.FlatIndex(0, 0, 0, 0)
        return RetrieverState(0, bs, 0, 0)

    def shard_specs(self, state: RetrieverState):
        specs = super().shard_specs(state)
        # the FlatIndex carries its own codebook copy — replicate it
        seg = self._segmented(state)
        if seg is not None:
            bs = specs.backend_state
            return specs._replace(backend_state=dataclasses.replace(
                bs, segments=tuple(p._replace(codebook=(None, None))
                                   for p in bs.segments)))
        return specs._replace(
            backend_state=specs.backend_state._replace(codebook=(None, None)))
