"""`flat` backend: exhaustive fused ADC MaxSim scan over quantized codes.

The paper's main configuration (quantized + flat): one MXU-friendly pass
over the (pruned) code corpus per query batch.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax

import jax.numpy as jnp

from repro.core import index as index_mod
from repro.retrieval.base import (Corpus, IndexBackend, Query,
                                  RetrieverState, code_dtype, encode_corpus,
                                  register_backend)
from repro.retrieval.config import HPCConfig

Array = jax.Array


@register_backend("flat")
class FlatBackend(IndexBackend):

    def build(self, key: Array, corpus: Corpus, cfg: HPCConfig,
              mesh=None) -> RetrieverState:
        _, codebook, codes_full, codes, mask = encode_corpus(
            key, corpus, cfg, mesh=mesh)
        return RetrieverState(
            codebook=codebook,
            backend_state=index_mod.build_flat(codes, mask, codebook),
            rerank_codes=codes_full,
            rerank_mask=corpus.mask)

    def search(self, state: RetrieverState, query: Query, *, k: int,
               scan=None) -> Tuple[Array, Array]:
        return index_mod.search_flat(
            state.backend_state, query.embeddings, query.mask, k=k,
            scan=scan)

    def search_candidates(self, state: RetrieverState, query: Query,
                          candidate_ids, *, k: int,
                          scan=None) -> Tuple[Array, Array]:
        if candidate_ids is None:
            return self.search(state, query, k=k, scan=scan)
        return index_mod.search_flat_candidates(
            state.backend_state, query.embeddings, query.mask,
            candidate_ids, k=k, scan=scan)

    def storage_bytes(self, state: RetrieverState) -> Dict[str, int]:
        codes = state.backend_state.codes
        cb = state.codebook
        return {"payload": codes.size * codes.dtype.itemsize,
                "codebook": cb.size * cb.dtype.itemsize}

    def abstract_state(self, *, n: int, md: int = 16, d: int = 16,
                       k: int = 256, **knobs) -> RetrieverState:
        sds, cdt = jax.ShapeDtypeStruct, code_dtype(k)
        ix = index_mod.FlatIndex(
            codes=sds((n, md), cdt),
            mask=sds((n, md), jnp.bool_),
            codebook=sds((k, d), jnp.float32),
            doc_ids=sds((n,), jnp.int32))
        return RetrieverState(
            codebook=sds((k, d), jnp.float32),
            backend_state=ix,
            rerank_codes=sds((n, md), cdt),
            rerank_mask=sds((n, md), jnp.bool_))

    def state_template(self, aux) -> RetrieverState:
        return RetrieverState(0, index_mod.FlatIndex(0, 0, 0, 0), 0, 0)

    def shard_specs(self, state: RetrieverState):
        specs = super().shard_specs(state)
        # the FlatIndex carries its own codebook copy — replicate it
        return specs._replace(
            backend_state=specs.backend_state._replace(codebook=(None, None)))
