"""Retriever API v1: pluggable index backends behind a string registry.

    from repro.retrieval import Retriever, Corpus, Query, HPCConfig

    r = Retriever(HPCConfig(k=256, p=60.0, backend="flat", rerank=32))
    state = r.build(key, Corpus(doc_emb, doc_mask, doc_salience))
    scores, ids = r.search(state, Query(q_emb, q_mask, q_salience), k=10)

Built-in backends (one module each — the template for new ones):
  float_flat — uncompressed exhaustive MaxSim (ColPali-Full baseline)
  flat       — exhaustive fused ADC scan over quantized codes
  ivf        — centroid routing over padded-dense buckets
  hnsw       — layered small-world graph routing (beam search)
  hamming    — binary codes + popcount scan
  cascade    — staged funnel: hamming -> ADC -> float rerank, budgets
               from HPCConfig.cascade (p1, p2)

See docs/api.md for the `IndexBackend` contract and the
search-stage (`search_candidates`) contract the cascade composes.
"""

from repro.retrieval.base import (  # noqa: F401
    Corpus,
    IndexBackend,
    Query,
    RetrieverState,
    available_backends,
    code_dtype,
    get_backend,
    register_backend,
)
from repro.retrieval.config import CascadeConfig, HPCConfig  # noqa: F401
from repro.retrieval.retriever import Retriever  # noqa: F401

# importing the backend modules installs them in the registry
from repro.retrieval import (cascade, flat, float_flat,  # noqa: E402,F401
                             hamming, hnsw, ivf)
