"""`Retriever`: the facade over config-selected index backends.

Owns every stage that is backend-independent — query-side dynamic pruning
(paper §III-C), candidate over-fetch, and the rerank over the unpruned
quantized corpus (§III-E2 step 5) — and delegates the primary structure to
the backend resolved from `cfg.backend` via the registry. All state flows
through `RetrieverState` pytrees, so build/search jit, shard (see
`shard`), checkpoint and donate cleanly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import pruning
from repro.core import scan as scan_mod
from repro.dist.sharding import Sharder, is_logical_spec
from repro.retrieval.base import (Corpus, IndexBackend, Query,
                                  RetrieverState, get_backend)
from repro.retrieval.config import HPCConfig

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Retriever:
    """HPC-ColPali retrieval over a pluggable index backend."""

    cfg: HPCConfig

    @property
    def backend(self) -> IndexBackend:
        return get_backend(self.cfg.backend)

    # -- offline ------------------------------------------------------------

    def build(self, key: Array, corpus: Corpus, *,
              mesh: Optional[Mesh] = None) -> RetrieverState:
        """Offline indexing (paper §III-E1).

        With `mesh`, the shared encode stages run sharded: codebook
        training through the distributed k-means (points over the mesh's
        corpus axes, per-cluster stats psum-reduced) and corpus
        quantization shard-mapped over documents, with nearest-centroid
        assignment routed through the Pallas kernel on TPU devices. On a
        1-device mesh the result matches the single-host build within
        float tolerance; without `mesh` the build is bit-stable (a pure
        function of key/corpus/config).
        """
        if mesh is None:
            # keep the pre-mesh call shape so out-of-tree backends written
            # against build(key, corpus, cfg) still work for local builds
            return self.backend.build(key, corpus, self.cfg)
        return self.backend.build(key, corpus, self.cfg, mesh=mesh)

    # -- online -------------------------------------------------------------

    def search(self, state: RetrieverState, query: Query, *, k: int
               ) -> Tuple[Array, Array]:
        """Online query (paper §III-E2 steps 2-5).

        Returns (scores (B, k), doc_ids (B, k)).
        """
        cfg, backend = self.cfg, self.backend

        # Step 2 — query-side dynamic pruning.
        q_emb, q_mask = query.embeddings, query.mask
        if cfg.prune_side in ("query", "both"):
            pr = pruning.prune_topp(q_emb, query.salience, q_mask, p=cfg.p)
            q_emb, q_mask = pr.embeddings, pr.mask
        pruned = Query(q_emb, q_mask, query.salience)

        # Steps 3-4 — backend candidate search (over-fetch for rerank).
        # All backends take the full v1 signature with `scan=` — legacy
        # out-of-tree backends get a kwargs-stripping shim at registration
        # (base.register_backend), so no signature sniffing here.
        n_cand = k if cfg.rerank == 0 else max(k, cfg.rerank)
        scores, ids = backend.search(state, pruned, k=n_cand, scan=cfg.scan)

        # Step 5 — rerank candidates with unpruned quantized MaxSim.
        if cfg.rerank and not backend.exact_scores:
            return self._rerank(state, pruned, scores, ids, k=k)
        return scores[:, :k], ids[:, :k]

    def degrade_rungs(self, state: RetrieverState, *, k: int) -> Tuple:
        """Overload degradation rungs for serving (docs/design.md §11).

        Empty for backends without a quality-for-latency ladder; the
        cascade returns its budget halvings ending at the hamming-only
        floor (None)."""
        backend = self.backend
        if not hasattr(backend, "degrade_rungs"):
            return ()
        return backend.degrade_rungs(state, k=k)

    def search_degraded(self, state: RetrieverState, query: Query, *,
                        k: int, rung) -> Tuple[Array, Array]:
        """Degraded online query: same query-side pruning, cheaper funnel.

        `rung` comes from `degrade_rungs`. Degraded stages return their
        own (exact-enough) scores — no quantized rerank on top: the whole
        point of stepping down is shedding compute.
        """
        cfg, backend = self.cfg, self.backend
        q_emb, q_mask = query.embeddings, query.mask
        if cfg.prune_side in ("query", "both"):
            pr = pruning.prune_topp(q_emb, query.salience, q_mask, p=cfg.p)
            q_emb, q_mask = pr.embeddings, pr.mask
        pruned = Query(q_emb, q_mask, query.salience)
        scores, ids = backend.search_degraded(state, pruned, k=k, rung=rung,
                                              scan=cfg.scan)
        return scores[:, :k], ids[:, :k]

    def _rerank(self, state: RetrieverState, query: Query, scores: Array,
                ids: Array, *, k: int) -> Tuple[Array, Array]:
        safe = jnp.maximum(ids, 0)
        cand_codes = state.rerank_codes[safe]                 # (B, r, Md)
        cand_mask = state.rerank_mask[safe]
        return scan_mod.quantized_maxsim_topk(
            query.embeddings, query.mask, cand_codes, cand_mask,
            state.codebook, k=k, doc_ids=ids, valid=ids >= 0,
            scan=self.cfg.scan)

    # -- mutation (LSM segments) ---------------------------------------------

    def add(self, state: RetrieverState, delta: Corpus, *,
            doc_ids=None) -> RetrieverState:
        """Append (or upsert) documents without rebuilding (segment append).

        The first mutation normalizes a monolithic build into segmented
        form (bit-identical search either way). With explicit `doc_ids`,
        ids already live in the index are upserted — the prior occurrence
        is tombstoned and the newest segment wins.
        """
        return self.backend.add(state, delta, self.cfg, doc_ids=doc_ids)

    def delete(self, state: RetrieverState, doc_ids) -> RetrieverState:
        """Tombstone documents by global id: they vanish from search
        results (scores NEG_INF, ids -1) without touching the payload."""
        return self.backend.delete(state, doc_ids)

    def compact(self, state: RetrieverState) -> RetrieverState:
        """Fold all segments into one and physically drop tombstones.

        Search over the live corpus is unchanged; storage and scan cost
        shrink to the live document set.
        """
        return self.backend.compact(state, self.cfg)

    # -- accounting ---------------------------------------------------------

    def storage_bytes(self, state: RetrieverState) -> Dict[str, int]:
        """Measured storage footprint of the built index (paper Table III).

        Counts the patch representation payload (the paper's metric);
        masks/ids are reported separately.
        """
        return self.backend.storage_bytes(state)

    def build_stats(self, state: RetrieverState) -> Dict[str, float]:
        """Structure-quality stats of a built index (backend-defined).

        `ivf` reports its bucket-overflow drop rate (enforced against
        `IVFConfig.max_drop_rate` at build time), `hnsw` its realised
        level-0 degree and entry level; flat scans have nothing to report.
        """
        return self.backend.build_stats(state)

    # -- persistence --------------------------------------------------------

    def save(self, path: str, state: RetrieverState) -> str:
        return self.backend.save(path, state)

    def load(self, path: str) -> RetrieverState:
        return self.backend.load(path)

    # -- distribution -------------------------------------------------------

    def shard(self, state: RetrieverState, mesh: Mesh,
              sharder: Optional[Sharder] = None) -> RetrieverState:
        """Place `state` on `mesh`, corpus dimension sharded over the mesh.

        Backends declare logical-axis specs (`shard_specs`); the "corpus"
        axis resolves over ("pod", "data", "model") with the usual
        divisibility fallback (repro/dist/sharding.py), so the same index
        shards on any mesh that divides the document count and replicates
        gracefully otherwise.
        """
        shd = sharder if sharder is not None else Sharder(mesh)
        specs = self.backend.shard_specs(state)
        return jax.tree.map(
            lambda spec, leaf: jax.device_put(
                leaf, shd.named(tuple(spec), jnp.shape(leaf))),
            specs, state, is_leaf=is_logical_spec)
