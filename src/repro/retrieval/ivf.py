"""`ivf` backend: centroid routing over padded-dense buckets.

The TPU analogue of FAISS IVF/HNSW (core/index.py): documents bucket by
the routing cluster of their mean decoded patch; a query scores the
routing centroids with one matmul and fused-scans only `n_probe` buckets.
`n_probe` is a *static* search knob, carried as pytree aux data so
`search(state, query, k=...)` stays self-contained and jit-stable.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import index as index_mod
from repro.retrieval.base import (Corpus, IndexBackend, Query,
                                  RetrieverState, code_dtype, encode_corpus,
                                  register_backend)
from repro.retrieval.config import HPCConfig

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class IVFState:
    """IVFIndex + the static n_probe search knob (aux data, not a leaf)."""

    index: index_mod.IVFIndex
    n_probe: int

    def tree_flatten(self):
        return (self.index,), self.n_probe

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)


@register_backend("ivf")
class IVFBackend(IndexBackend):

    def build(self, key: Array, corpus: Corpus, cfg: HPCConfig,
              mesh=None) -> RetrieverState:
        k_ivf, codebook, codes_full, codes, mask = encode_corpus(
            key, corpus, cfg, mesh=mesh)
        ivf = index_mod.build_ivf(k_ivf, codes, mask, codebook, cfg.ivf)
        # Enforce the bucket-overflow contract: docs beyond bucket_cap are
        # silently absent from the primary structure, which reads as a
        # recall loss, not an error — so the build fails loudly instead.
        n_docs = corpus.embeddings.shape[0]
        drop = index_mod.ivf_drop_rate(ivf, n_docs)
        if drop > cfg.ivf.max_drop_rate:
            raise ValueError(
                f"IVF bucket overflow dropped {drop:.2%} of {n_docs} docs "
                f"(> max_drop_rate={cfg.ivf.max_drop_rate:.2%}); raise "
                "bucket_cap/n_list or rebalance the routing clustering")
        if drop > 0:
            warnings.warn(
                f"IVF bucket overflow dropped {drop:.2%} of {n_docs} docs "
                f"(within max_drop_rate={cfg.ivf.max_drop_rate:.2%})",
                stacklevel=2)
        return RetrieverState(
            codebook=codebook,
            backend_state=IVFState(ivf, cfg.ivf.n_probe),
            rerank_codes=codes_full,
            rerank_mask=corpus.mask)

    def search(self, state: RetrieverState, query: Query, *, k: int,
               scan=None) -> Tuple[Array, Array]:
        s = state.backend_state
        return index_mod.search_ivf(s.index, query.embeddings, query.mask,
                                    n_probe=s.n_probe, k=k, scan=scan)

    def search_candidates(self, state: RetrieverState, query: Query,
                          candidate_ids, *, k: int,
                          scan=None) -> Tuple[Array, Array]:
        # ivf declines the stage contract: its bucketed layout has no
        # position->doc addressing, and routing already narrows candidates.
        if candidate_ids is None:
            return self.search(state, query, k=k, scan=scan)
        raise NotImplementedError(
            "backend 'ivf' routes its own candidates (n_probe buckets) and "
            "does not support candidate-restricted search; use "
            "flat/float_flat/hamming as cascade stages")

    def storage_bytes(self, state: RetrieverState) -> Dict[str, int]:
        codes = state.backend_state.index.bucket_codes
        cb = state.codebook
        return {"payload": codes.size * codes.dtype.itemsize,
                "codebook": cb.size * cb.dtype.itemsize}

    def build_stats(self, state: RetrieverState) -> Dict[str, float]:
        ix = state.backend_state.index
        n_docs = state.rerank_codes.shape[0]
        return {"ivf_drop_rate": index_mod.ivf_drop_rate(ix, n_docs),
                "n_list": int(ix.bucket_valid.shape[0]),
                "bucket_cap": int(ix.bucket_valid.shape[1])}

    def abstract_state(self, *, n: int, md: int = 16, d: int = 16,
                       k: int = 256, **knobs) -> RetrieverState:
        n_list = knobs.get("n_list", index_mod.IVFConfig.n_list)
        n_probe = knobs.get("n_probe", index_mod.IVFConfig.n_probe)
        # same padded-dense capacity rule as build_ivf (2x mean load)
        cap = knobs.get("bucket_cap", int(max(8, 2 * -(-n // n_list))))
        sds, cdt = jax.ShapeDtypeStruct, code_dtype(k)
        ix = index_mod.IVFIndex(
            routing_centroids=sds((n_list, d), jnp.float32),
            bucket_codes=sds((n_list, cap, md), cdt),
            bucket_mask=sds((n_list, cap, md), jnp.bool_),
            bucket_valid=sds((n_list, cap), jnp.bool_),
            bucket_doc_ids=sds((n_list, cap), jnp.int32),
            codebook=sds((k, d), jnp.float32))
        return RetrieverState(
            codebook=sds((k, d), jnp.float32),
            backend_state=IVFState(ix, n_probe),
            rerank_codes=sds((n, md), cdt),
            rerank_mask=sds((n, md), jnp.bool_))

    def _state_aux(self, state: RetrieverState):
        return state.backend_state.n_probe

    def state_template(self, aux) -> RetrieverState:
        return RetrieverState(
            0, IVFState(index_mod.IVFIndex(0, 0, 0, 0, 0, 0), aux), 0, 0)

    def shard_specs(self, state: RetrieverState):
        ivf = state.backend_state.index
        # buckets (dim 0 = n_list) spread over the corpus axes; routing
        # centroids + codebook replicated (every query scores all of them)
        ivf_specs = index_mod.IVFIndex(
            routing_centroids=(None, None),
            bucket_codes=("corpus", None, None),
            bucket_mask=("corpus", None, None),
            bucket_valid=("corpus", None),
            bucket_doc_ids=("corpus", None),
            codebook=(None, None))
        return RetrieverState(
            codebook=(None, None),
            backend_state=IVFState(ivf_specs, state.backend_state.n_probe),
            rerank_codes=("corpus", None),
            rerank_mask=("corpus", None))
