"""`ivf` backend: centroid routing over padded-dense buckets.

The TPU analogue of FAISS IVF/HNSW (core/index.py): documents bucket by
the routing cluster of their mean decoded patch; a query scores the
routing centroids with one matmul and fused-scans only `n_probe` buckets.
`n_probe` is a *static* search knob, carried as pytree aux data so
`search(state, query, k=...)` stays self-contained and jit-stable.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import index as index_mod
from repro.retrieval.base import (Corpus, IndexBackend, Query,
                                  RetrieverState, code_dtype, encode_corpus,
                                  register_backend)
from repro.retrieval.config import HPCConfig

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class IVFState:
    """IVFIndex + the static n_probe search knob (aux data, not a leaf)."""

    index: index_mod.IVFIndex
    n_probe: int

    def tree_flatten(self):
        return (self.index,), self.n_probe

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)


@register_backend("ivf")
class IVFBackend(IndexBackend):

    def build(self, key: Array, corpus: Corpus, cfg: HPCConfig,
              mesh=None) -> RetrieverState:
        k_ivf, codebook, codes_full, codes, mask = encode_corpus(
            key, corpus, cfg, mesh=mesh)
        ivf = index_mod.build_ivf(k_ivf, codes, mask, codebook, cfg.ivf)
        # Enforce the bucket-overflow contract: docs beyond bucket_cap are
        # silently absent from the primary structure, which reads as a
        # recall loss, not an error — so the build fails loudly instead.
        n_docs = corpus.embeddings.shape[0]
        drop = index_mod.ivf_drop_rate(ivf, n_docs)
        if drop > cfg.ivf.max_drop_rate:
            raise ValueError(
                f"IVF bucket overflow dropped {drop:.2%} of {n_docs} docs "
                f"(> max_drop_rate={cfg.ivf.max_drop_rate:.2%}); raise "
                "bucket_cap/n_list or rebalance the routing clustering")
        if drop > 0:
            warnings.warn(
                f"IVF bucket overflow dropped {drop:.2%} of {n_docs} docs "
                f"(within max_drop_rate={cfg.ivf.max_drop_rate:.2%})",
                stacklevel=2)
        return RetrieverState(
            codebook=codebook,
            backend_state=IVFState(ivf, cfg.ivf.n_probe),
            rerank_codes=codes_full,
            rerank_mask=corpus.mask)

    def search(self, state: RetrieverState, query: Query, *, k: int,
               scan=None) -> Tuple[Array, Array]:
        s = state.backend_state
        seg = self._segmented(state)
        if seg is not None:
            return index_mod.search_ivf_segmented(
                seg, query.embeddings, query.mask,
                n_probe=s.n_probe, k=k, scan=scan)
        return index_mod.search_ivf(s.index, query.embeddings, query.mask,
                                    n_probe=s.n_probe, k=k, scan=scan)

    def search_candidates(self, state: RetrieverState, query: Query,
                          candidate_ids, *, k: int,
                          scan=None) -> Tuple[Array, Array]:
        # ivf declines the stage contract: its bucketed layout has no
        # position->doc addressing, and routing already narrows candidates.
        if candidate_ids is None:
            return self.search(state, query, k=k, scan=scan)
        raise NotImplementedError(
            "backend 'ivf' routes its own candidates (n_probe buckets) and "
            "does not support candidate-restricted search; use "
            "flat/float_flat/hamming as cascade stages")

    # -- mutation hooks ------------------------------------------------------

    def _delta_segment(self, state, seg, enc, delta, cfg, doc_ids):
        _, codes, mask = enc
        return index_mod.make_ivf_segment(
            codes, mask, state.codebook,
            seg.segments[0].routing_centroids, doc_ids)

    def _compact_payload(self, state, seg, cfg):
        # gather flattens the (n_list, cap) slot layout; re-bucket through
        # the shared routing centroids so compaction rebalances loads
        (codes, mask), ids = index_mod.gather_live_rows(
            seg, ("bucket_codes", "bucket_mask"))
        return index_mod.make_ivf_segment(
            codes, mask, state.codebook,
            seg.segments[0].routing_centroids, ids)

    def _seg_payload_bytes(self, payload, n_live: int) -> int:
        codes = payload.bucket_codes
        return n_live * codes.shape[-1] * codes.dtype.itemsize

    def storage_bytes(self, state: RetrieverState) -> Dict[str, int]:
        seg = self._segmented(state)
        if seg is not None:
            return self._segmented_storage(state, seg)
        codes = state.backend_state.index.bucket_codes
        cb = state.codebook
        return {"payload": codes.size * codes.dtype.itemsize,
                "codebook": cb.size * cb.dtype.itemsize}

    def build_stats(self, state: RetrieverState) -> Dict[str, float]:
        seg = self._segmented(state)
        if seg is not None:
            # drop-rate is a build-time contract; segments admit every doc
            # by construction (per-segment cap = realised max bucket load)
            out = self._segment_stats(seg)
            first = seg.segments[0]
            out["n_list"] = int(first.bucket_valid.shape[0])
            out["bucket_cap"] = int(first.bucket_valid.shape[1])
            return out
        ix = state.backend_state.index
        n_docs = state.rerank_codes.shape[0]
        return {"ivf_drop_rate": index_mod.ivf_drop_rate(ix, n_docs),
                "n_list": int(ix.bucket_valid.shape[0]),
                "bucket_cap": int(ix.bucket_valid.shape[1])}

    def abstract_state(self, *, n: int, md: int = 16, d: int = 16,
                       k: int = 256, **knobs) -> RetrieverState:
        n_list = knobs.get("n_list", index_mod.IVFConfig.n_list)
        n_probe = knobs.get("n_probe", index_mod.IVFConfig.n_probe)
        # same padded-dense capacity rule as build_ivf (2x mean load)
        cap = knobs.get("bucket_cap", int(max(8, 2 * -(-n // n_list))))
        sds, cdt = jax.ShapeDtypeStruct, code_dtype(k)

        def seg_payload(bucket_cap):
            return index_mod.IVFIndex(
                routing_centroids=sds((n_list, d), jnp.float32),
                bucket_codes=sds((n_list, bucket_cap, md), cdt),
                bucket_mask=sds((n_list, bucket_cap, md), jnp.bool_),
                bucket_valid=sds((n_list, bucket_cap), jnp.bool_),
                bucket_doc_ids=sds((n_list, bucket_cap), jnp.int32),
                codebook=sds((k, d), jnp.float32))

        segments = knobs.get("segments")
        if segments is not None:
            # segmented layout: tuple of per-segment *bucket* capacities
            id_cap = knobs.get("id_cap", index_mod.segment_capacity(
                n_list * sum(segments)))
            bs = index_mod.SegmentedState(
                tuple(seg_payload(c) for c in segments),
                tuple(sds((n_list, c), jnp.bool_) for c in segments),
                sds((id_cap,), jnp.int32))
            n = id_cap
        else:
            bs = seg_payload(cap)
        return RetrieverState(
            codebook=sds((k, d), jnp.float32),
            backend_state=IVFState(bs, n_probe),
            rerank_codes=sds((n, md), cdt),
            rerank_mask=sds((n, md), jnp.bool_))

    def _state_aux(self, state: RetrieverState):
        return state.backend_state.n_probe

    def state_template(self, aux, n_segments: int = 0) -> RetrieverState:
        if n_segments:
            bs = index_mod.SegmentedState(
                tuple(index_mod.IVFIndex(0, 0, 0, 0, 0, 0)
                      for _ in range(n_segments)),
                (0,) * n_segments, 0)
        else:
            bs = index_mod.IVFIndex(0, 0, 0, 0, 0, 0)
        return RetrieverState(0, IVFState(bs, aux), 0, 0)

    def shard_specs(self, state: RetrieverState):
        # buckets (dim 0 = n_list) spread over the corpus axes; routing
        # centroids + codebook replicated (every query scores all of them)
        def ivf_leaf_specs():
            return index_mod.IVFIndex(
                routing_centroids=(None, None),
                bucket_codes=("corpus", None, None),
                bucket_mask=("corpus", None, None),
                bucket_valid=("corpus", None),
                bucket_doc_ids=("corpus", None),
                codebook=(None, None))

        seg = self._segmented(state)
        if seg is not None:
            bs = index_mod.SegmentedState(
                tuple(ivf_leaf_specs() for _ in seg.segments),
                tuple(("corpus", None) for _ in seg.live),
                (None,))
        else:
            bs = ivf_leaf_specs()
        return RetrieverState(
            codebook=(None, None),
            backend_state=IVFState(bs, state.backend_state.n_probe),
            rerank_codes=("corpus", None),
            rerank_mask=("corpus", None))
