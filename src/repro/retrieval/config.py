"""HPC-ColPali configuration (paper §III) + backend selection.

`HPCConfig.backend` names the index backend ("float_flat", "flat", "ivf",
"hamming", "cascade") resolved through the `repro.retrieval` registry.
The v0 knobs `mode`/`index` are still accepted as a deprecated alias pair
and are kept populated on the config (derived from `backend`) so old
readers keep working; new code should pass `backend=` only. The alias
pair is scheduled for removal in v2.0 (docs/api.md "Deprecations").
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Literal, Optional

from repro.core import binary as binary_mod
from repro.core.graph import HNSWConfig
from repro.core.index import IVFConfig
from repro.core.scan import ScanConfig

# (mode, index) -> backend name; the old union dispatch, now a table.
_MODE_INDEX_TO_BACKEND = {
    ("float", "flat"): "float_flat",
    ("float", "ivf"): "float_flat",      # v0 ignored `index` for float
    ("quantized", "flat"): "flat",
    ("quantized", "ivf"): "ivf",
    ("binary", "flat"): "hamming",       # v0 ignored `index` for binary
    ("binary", "ivf"): "hamming",
}
# backend name -> canonical (mode, index) for old readers. `hnsw` maps to
# ("quantized", "ivf") — the nearest v0 spelling (a quantized routing
# index); the deprecated mode/index pair can never *produce* hnsw.
# `cascade` ends in a float rerank, so its nearest v0 spelling is the
# float scan; like hnsw it can never be produced *from* mode/index.
_BACKEND_TO_MODE_INDEX = {
    "float_flat": ("float", "flat"),
    "flat": ("quantized", "flat"),
    "ivf": ("quantized", "ivf"),
    "hnsw": ("quantized", "ivf"),
    "hamming": ("binary", "flat"),
    "cascade": ("float", "flat"),
}

# The mode/index deprecation fires once per process, not once per
# construction — sweeps that build hundreds of configs (benchmarks,
# autotuning) should not drown real warnings. Tests reset this flag.
_mode_index_warned = False


def _warn_mode_index(backend: str) -> None:
    global _mode_index_warned
    if _mode_index_warned:
        return
    _mode_index_warned = True
    # stacklevel: this helper -> __post_init__ -> dataclass __init__ ->
    # the caller's HPCConfig(...) line.
    warnings.warn(
        "HPCConfig(mode=..., index=...) is deprecated and will be removed "
        f"in v2.0; pass backend={backend!r} instead (this warning is "
        "emitted once per process)",
        DeprecationWarning, stacklevel=4)


@dataclasses.dataclass(frozen=True)
class CascadeConfig:
    """Per-stage candidate budgets of the compression cascade.

    The staged funnel (retrieval/cascade.py) narrows the corpus in three
    fidelity steps: Hamming prefilter over all N docs -> ADC quantized
    rescore of the top `p1` -> float late-interaction rerank of the top
    `p2` -> final top-k. Budgets are baked into the built state as
    static aux (like IVF `n_probe`), so searches jit per (p1, p2, k).

    p1: candidates surviving the Hamming stage (scored by ADC).
    p2: candidates surviving the ADC stage (scored in float) — the
        "fraction of corpus touched by the expensive stage" knob; the
        paper's target regime is p2/N of a few percent.
    """

    p1: int = 1024
    p2: int = 64


@dataclasses.dataclass(frozen=True)
class HPCConfig:
    """Tunable knobs of HPC-ColPali (paper §III).

    Exactly one primary search structure is selected by `backend`; `mode`
    and `index` are the deprecated v0 spelling (kept as derived aliases,
    removal scheduled for v2.0).
    """

    k: int = 256                     # codebook size (128/256/512)
    p: float = 60.0                  # top-p% patches kept
    prune_side: Literal["doc", "query", "both", "none"] = "doc"
    mode: Optional[Literal["float", "quantized", "binary"]] = None
    index: Optional[Literal["flat", "ivf"]] = None
    ivf: IVFConfig = dataclasses.field(default_factory=IVFConfig)
    hnsw: HNSWConfig = dataclasses.field(default_factory=HNSWConfig)
    cascade: CascadeConfig = dataclasses.field(default_factory=CascadeConfig)
    kmeans_iters: int = 25
    kmeans_restarts: int = 8         # independent codebook fits, best-of-N
                                     # by inertia (must match the
                                     # KMeansConfig default for v0 parity)
    kmeans_seed_batch: int = 4096    # k-means++ seeding subsample;
                                     # 0 = seed on the full corpus
    kmeans_minibatch: int = 0        # 0 = full-batch Lloyd; else per-step
                                     # sample size for corpus-scale N
    rerank: int = 0                  # rerank top-r candidates with unpruned
                                     # quantized maxsim (0 = off)
    scan_block_docs: int = 256       # docs per streaming-scan block (peak
                                     # scan memory ~ B*Mq*block*Md floats)
    scan_impl: str = "auto"          # block scorer: auto|pallas|jnp|interpret
                                     # (core/scan.py dispatcher)
    backend: Optional[str] = None    # registry key; wins over mode/index

    def __post_init__(self):
        if self.backend is None:
            mode = self.mode if self.mode is not None else "quantized"
            index = self.index if self.index is not None else "flat"
            if self.mode is not None or self.index is not None:
                _warn_mode_index(_MODE_INDEX_TO_BACKEND[(mode, index)])
            object.__setattr__(
                self, "backend", _MODE_INDEX_TO_BACKEND[(mode, index)])
        elif self.backend not in _BACKEND_TO_MODE_INDEX:
            # unknown names are allowed for out-of-tree backends, but then
            # the mode/index aliases cannot be derived — leave as given.
            if self.mode is None or self.index is None:
                object.__setattr__(self, "mode", self.mode or "quantized")
                object.__setattr__(self, "index", self.index or "flat")
            return
        mode, index = _BACKEND_TO_MODE_INDEX[self.backend]
        object.__setattr__(self, "mode", mode)
        object.__setattr__(self, "index", index)

    @property
    def bits(self) -> int:
        return binary_mod.bits_for_k(self.k)

    @property
    def scan(self) -> ScanConfig:
        """Static streaming-scan config implied by this HPCConfig."""
        return ScanConfig(block_docs=self.scan_block_docs,
                          impl=self.scan_impl)
