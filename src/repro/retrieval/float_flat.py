"""`float_flat` backend: uncompressed exhaustive MaxSim (ColPali-Full).

The paper's fp32 baseline — no codebook, no rerank (its scores are already
exact late-interaction scores).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import index as index_mod
from repro.core import pruning
from repro.retrieval.base import (Corpus, IndexBackend, Query,
                                  RetrieverState, register_backend)
from repro.retrieval.config import HPCConfig

Array = jax.Array


@register_backend("float_flat")
class FloatFlatBackend(IndexBackend):
    exact_scores = True

    def build(self, key: Array, corpus: Corpus, cfg: HPCConfig,
              mesh=None) -> RetrieverState:
        # no codebook to train — `mesh` is accepted for contract parity
        # (the float corpus shards post-build via Retriever.shard)
        n, _, d = corpus.embeddings.shape
        emb, mask = corpus.embeddings, corpus.mask
        if cfg.prune_side in ("doc", "both"):
            pr = pruning.prune_topp(emb, corpus.salience, mask, p=cfg.p)
            emb, mask = pr.embeddings, pr.mask
        return RetrieverState(
            codebook=jnp.zeros((1, d), corpus.embeddings.dtype),
            backend_state=index_mod.build_float_flat(emb, mask),
            rerank_codes=jnp.zeros((n, 1), jnp.uint8),
            rerank_mask=jnp.zeros((n, 1), bool))

    def search(self, state: RetrieverState, query: Query, *, k: int,
               scan=None) -> Tuple[Array, Array]:
        seg = self._segmented(state)
        if seg is not None:
            return index_mod.search_float_flat_segmented(
                seg, query.embeddings, query.mask, k=k, scan=scan)
        return index_mod.search_float_flat(
            state.backend_state, query.embeddings, query.mask, k=k,
            scan=scan)

    def search_candidates(self, state: RetrieverState, query: Query,
                          candidate_ids, *, k: int,
                          scan=None) -> Tuple[Array, Array]:
        if candidate_ids is None:
            return self.search(state, query, k=k, scan=scan)
        seg = self._segmented(state)
        if seg is not None:
            return index_mod.search_float_flat_segmented_candidates(
                seg, query.embeddings, query.mask, candidate_ids, k=k,
                scan=scan)
        return index_mod.search_float_flat_candidates(
            state.backend_state, query.embeddings, query.mask,
            candidate_ids, k=k, scan=scan)

    # -- mutation hooks ------------------------------------------------------

    def _encode_delta(self, state, delta, cfg):
        # no codebook: the payload is the (doc-pruned) float embeddings
        emb, mask = delta.embeddings, delta.mask
        if cfg.prune_side in ("doc", "both"):
            pr = pruning.prune_topp(emb, delta.salience, mask, p=cfg.p)
            emb, mask = pr.embeddings, pr.mask
        return emb, emb, mask

    def _delta_segment(self, state, seg, enc, delta, cfg, doc_ids):
        _, emb, mask = enc
        return index_mod.make_float_flat_segment(emb, mask, doc_ids)

    def _rerank_delta_rows(self, enc, delta):
        # exact_scores backend: the facade never reranks — keep the dummy
        # placeholder rows the build writes
        n = delta.embeddings.shape[0]
        return jnp.zeros((n, 1), jnp.uint8), jnp.zeros((n, 1), bool)

    def _compact_payload(self, state, seg, cfg):
        (emb, mask), ids = index_mod.gather_live_rows(
            seg, ("embeddings", "mask"))
        return index_mod.make_float_flat_segment(emb, mask, ids)

    def _seg_payload_bytes(self, payload, n_live: int) -> int:
        e = payload.embeddings
        return n_live * e.shape[-2] * e.shape[-1] * e.dtype.itemsize

    def storage_bytes(self, state: RetrieverState) -> Dict[str, int]:
        seg = self._segmented(state)
        if seg is not None:
            out = self._segmented_storage(state, seg)
            out.pop("codebook", None)    # dummy (1, d) placeholder
            return out
        e = state.backend_state.embeddings
        return {"payload": e.size * e.dtype.itemsize}

    def abstract_state(self, *, n: int, md: int = 16, d: int = 16,
                       k: int = 256, **knobs) -> RetrieverState:
        sds = jax.ShapeDtypeStruct

        def seg_payload(cap):
            return index_mod.FloatFlatIndex(
                embeddings=sds((cap, md, d), jnp.float32),
                mask=sds((cap, md), jnp.bool_),
                doc_ids=sds((cap,), jnp.int32))

        segments = knobs.get("segments")
        if segments is not None:
            id_cap = knobs.get("id_cap",
                               index_mod.segment_capacity(sum(segments)))
            bs = index_mod.SegmentedState(
                tuple(seg_payload(c) for c in segments),
                tuple(sds((c,), jnp.bool_) for c in segments),
                sds((id_cap,), jnp.int32))
            n = id_cap
        else:
            bs = seg_payload(n)
        return RetrieverState(
            codebook=sds((1, d), jnp.float32),
            backend_state=bs,
            rerank_codes=sds((n, 1), jnp.uint8),
            rerank_mask=sds((n, 1), jnp.bool_))

    def state_template(self, aux, n_segments: int = 0) -> RetrieverState:
        if n_segments:
            bs = index_mod.SegmentedState(
                tuple(index_mod.FloatFlatIndex(0, 0, 0)
                      for _ in range(n_segments)),
                (0,) * n_segments, 0)
        else:
            bs = index_mod.FloatFlatIndex(0, 0, 0)
        return RetrieverState(0, bs, 0, 0)
