"""`float_flat` backend: uncompressed exhaustive MaxSim (ColPali-Full).

The paper's fp32 baseline — no codebook, no rerank (its scores are already
exact late-interaction scores).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import index as index_mod
from repro.core import pruning
from repro.retrieval.base import (Corpus, IndexBackend, Query,
                                  RetrieverState, register_backend)
from repro.retrieval.config import HPCConfig

Array = jax.Array


@register_backend("float_flat")
class FloatFlatBackend(IndexBackend):
    exact_scores = True

    def build(self, key: Array, corpus: Corpus, cfg: HPCConfig,
              mesh=None) -> RetrieverState:
        # no codebook to train — `mesh` is accepted for contract parity
        # (the float corpus shards post-build via Retriever.shard)
        n, _, d = corpus.embeddings.shape
        emb, mask = corpus.embeddings, corpus.mask
        if cfg.prune_side in ("doc", "both"):
            pr = pruning.prune_topp(emb, corpus.salience, mask, p=cfg.p)
            emb, mask = pr.embeddings, pr.mask
        return RetrieverState(
            codebook=jnp.zeros((1, d), corpus.embeddings.dtype),
            backend_state=index_mod.build_float_flat(emb, mask),
            rerank_codes=jnp.zeros((n, 1), jnp.uint8),
            rerank_mask=jnp.zeros((n, 1), bool))

    def search(self, state: RetrieverState, query: Query, *, k: int,
               scan=None) -> Tuple[Array, Array]:
        return index_mod.search_float_flat(
            state.backend_state, query.embeddings, query.mask, k=k,
            scan=scan)

    def search_candidates(self, state: RetrieverState, query: Query,
                          candidate_ids, *, k: int,
                          scan=None) -> Tuple[Array, Array]:
        if candidate_ids is None:
            return self.search(state, query, k=k, scan=scan)
        return index_mod.search_float_flat_candidates(
            state.backend_state, query.embeddings, query.mask,
            candidate_ids, k=k, scan=scan)

    def storage_bytes(self, state: RetrieverState) -> Dict[str, int]:
        e = state.backend_state.embeddings
        return {"payload": e.size * e.dtype.itemsize}

    def abstract_state(self, *, n: int, md: int = 16, d: int = 16,
                       k: int = 256, **knobs) -> RetrieverState:
        sds = jax.ShapeDtypeStruct
        ix = index_mod.FloatFlatIndex(
            embeddings=sds((n, md, d), jnp.float32),
            mask=sds((n, md), jnp.bool_),
            doc_ids=sds((n,), jnp.int32))
        return RetrieverState(
            codebook=sds((1, d), jnp.float32),
            backend_state=ix,
            rerank_codes=sds((n, 1), jnp.uint8),
            rerank_mask=sds((n, 1), jnp.bool_))

    def state_template(self, aux) -> RetrieverState:
        return RetrieverState(0, index_mod.FloatFlatIndex(0, 0, 0), 0, 0)
