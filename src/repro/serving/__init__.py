"""Serving substrate: continuous-batching retrieval server."""

from repro.serving import server  # noqa: F401
