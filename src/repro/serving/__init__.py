"""Serving substrate: asyncio continuous-batching retrieval server."""

from repro.serving.client import drive  # noqa: F401
from repro.serving.live import LiveIndexSession  # noqa: F401
from repro.serving.resilience import (DeadlineExceeded,  # noqa: F401
                                      DegradationController,
                                      DispatcherFailed, FaultInjected,
                                      FaultInjector, Overloaded,
                                      ResilienceConfig)
from repro.serving.server import (AsyncRetrievalServer,  # noqa: F401
                                  RetrievalServer, ServeConfig, ServerClosed,
                                  Served, padding_ladder)
