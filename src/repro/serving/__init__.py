"""Serving substrate: asyncio continuous-batching retrieval server."""

from repro.serving.client import drive  # noqa: F401
from repro.serving.live import LiveIndexSession  # noqa: F401
from repro.serving.server import (AsyncRetrievalServer,  # noqa: F401
                                  RetrievalServer, ServeConfig, ServerClosed,
                                  padding_ladder)
