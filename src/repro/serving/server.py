"""Async continuous-batching retrieval serving (v2).

`AsyncRetrievalServer` is asyncio-native: clients ``await server.query(...)``;
a coalescing loop drains the request queue under ``max_wait_ms`` and pads each
batch up a **power-of-two ladder** of compiled shapes (B in {1, 2, 4, ...,
max_batch}) instead of always padding to ``max_batch`` — a batch of 3 pads to
4, not 32, so a lone straggler pays single-digit-row compute. Shapes are
warmed lazily (jax.jit's shape-keyed cache compiles each (B, Mq) on first
use); ``warm_shapes`` pre-compiles the whole ladder up front.

Host staging overlaps device compute by double-buffering: the dispatcher
stages batch n+1's numpy->device transfer on the event loop while batch n's
jitted search runs in a bounded executor; ``jax.block_until_ready`` happens
only at fan-out, off the event loop, so percentiles include device time but
the loop never blocks on it.

Fault tolerance (v3, opt-in via ``ServeConfig.resilience``) threads the
`repro.serving.resilience` controllers through the loop: per-request
deadlines (expired items are dropped before staging and cancelled at
fan-out), bounded admission with explicit `Overloaded` rejection and
per-SLO-class token buckets, a degradation ladder that serves overload
bursts from pre-compiled cheaper search functions (`degraded_fns` — the
cascade's smaller (p1, p2) rungs down to hamming-only) and steps back up
under hysteresis, a watchdog that restarts a dead/hung dispatcher and
fails its claimed requests with `DispatcherFailed`, and a `FaultInjector`
with named sites (dispatch/stage/compute/fanout) driving the chaos suite.
Every successful response is a `Served` tuple tagged with the degradation
level that produced it. The degraded functions are part of the recompile
sentry's declared signature set — shedding and degrading never mint an
off-ladder compile.

`RetrievalServer` is the thin sync facade (thread-backed event loop) kept so
v1 call sites — ``submit`` returning a waitable request, blocking ``query`` —
keep working unchanged. ``close`` drains: in-flight batches complete and
deliver real results; requests still queued get a terminal `ServerClosed`
error instead of hanging until their client-side timeout. A facade
``query`` that times out *cancels* its queued item (and counts it in
``stats()["timeouts"]``) so abandoned requests stop occupying batch slots.

Latency percentiles (p50/p99) are tracked per request, matching the paper's
Table IV metric definitions; ``stats()`` additionally reports per-ladder-rung
batch occupancy so under-filled compiled shapes are visible.
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import logging
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.resilience import (AdmissionController,
                                      DeadlineExceeded,
                                      DegradationController,
                                      DispatcherFailed, FaultInjector,
                                      Overloaded, ResilienceConfig)

logger = logging.getLogger(__name__)


class ServerClosed(RuntimeError):
    """Terminal error set on requests the server will never serve."""


class Served(tuple):
    """A ``(scores, ids)`` result tagged with the degradation level that
    served it (0 = full quality). Unpacks as a plain 2-tuple, so existing
    ``scores, ids = await server.query(...)`` call sites are unchanged."""

    def __new__(cls, pair, level: int = 0):
        self = tuple.__new__(cls, pair)
        self.level = int(level)
        return self


def padding_ladder(max_batch: int) -> Tuple[int, ...]:
    """Powers of two up to ``max_batch`` (always ending at ``max_batch``)."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    rungs: List[int] = []
    b = 1
    while b < max_batch:
        rungs.append(b)
        b *= 2
    rungs.append(max_batch)
    return tuple(rungs)


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_wait_ms: float = 2.0
    top_k: int = 10
    # Compiled batch shapes. None -> power-of-two ladder up to max_batch;
    # a single-element tuple like (max_batch,) reproduces the v1 behaviour
    # of padding every batch to one full compiled shape.
    ladder: Optional[Tuple[int, ...]] = None
    # Double-buffer depth: how many staged batches may be in flight on the
    # device at once. 2 = stage n+1 while n computes (the default); 1
    # disables the overlap.
    max_inflight: int = 2
    # Wrap search_fn in a repro.analysis RecompileSentry: every call's
    # (B, Mq, dtypes, level) signature is recorded, batches whose B is not
    # a ladder rung raise RecompileGuardError instead of silently minting a
    # new compiled shape, and `recompile_report()` exposes the signature
    # set for the exact-rung-set assertion in tests/soaks.
    guard_recompiles: bool = False
    # Fault-tolerant serving (docs/design.md §11): deadlines, bounded
    # admission + load shedding, degradation ladder, watchdog. None keeps
    # the pre-v3 behaviour (unbounded queue, no deadlines, no watchdog).
    resilience: Optional[ResilienceConfig] = None

    def resolved_ladder(self) -> Tuple[int, ...]:
        if self.ladder is None:
            return padding_ladder(self.max_batch)
        rungs = tuple(sorted(set(int(b) for b in self.ladder)))
        if not rungs or rungs[0] < 1:
            raise ValueError(f"invalid ladder {self.ladder}")
        if rungs[-1] != self.max_batch:
            raise ValueError(
                f"ladder {rungs} must end at max_batch={self.max_batch}"
            )
        return rungs


class _Item:
    """One queued query inside the asyncio server."""

    __slots__ = ("q_emb", "q_mask", "q_sal", "future", "t_enqueue",
                 "deadline", "slo")

    def __init__(self, q_emb, q_mask, q_sal, future, t_enqueue,
                 deadline=None, slo="interactive"):
        self.q_emb, self.q_mask, self.q_sal = q_emb, q_mask, q_sal
        self.future = future
        self.t_enqueue = t_enqueue
        # absolute time.perf_counter() deadline, or None
        self.deadline = deadline
        self.slo = slo


_STOP = object()


class AsyncRetrievalServer:
    """search_fn(q_emb (B,Mq,D), q_mask, q_sal) -> (scores (B,k), ids).

    Bind to one event loop: the first ``query`` (or an explicit ``start``)
    captures the running loop; all queries must come from that loop.

    ``degraded_fns`` is an ordered sequence of cheaper search functions
    (same signature/output shapes as ``search_fn``); level L > 0 of the
    degradation ladder serves from ``degraded_fns[L - 1]``. They must be
    pre-compiled shapes of the same ladder (see `LiveIndexSession` /
    `cascade.degrade_rungs`) so stepping down never compiles.
    """

    def __init__(self, search_fn: Callable, cfg: ServeConfig,
                 degraded_fns: Sequence[Callable] = ()):
        self.search_fns: List[Callable] = [search_fn, *degraded_fns]
        self.cfg = cfg
        self.ladder = cfg.resolved_ladder()
        self.recompile_sentry = None
        if cfg.guard_recompiles:
            from repro.analysis.recompile import RecompileSentry
            rungs = set(self.ladder)

            def _serve(q, qm, qs, level=0):
                return self.search_fns[level](q, qm, qs)

            def _cache_size():
                return sum(fn._cache_size()
                           for fn in self.search_fns
                           if hasattr(fn, "_cache_size"))

            _serve._cache_size = _cache_size

            def serve_signature(q, qm, qs, level=0):
                # B stays at position 0: tests and reports key rungs off
                # sig[0]; the degradation level rides at the end
                return (int(q.shape[0]), int(q.shape[1]), str(q.dtype),
                        str(qm.dtype), str(qs.dtype), int(level))

            n_levels = len(self.search_fns)
            self.recompile_sentry = RecompileSentry(
                _serve, name="serve.search_fn", key_fn=serve_signature,
                allowed=lambda key: key[0] in rungs and key[-1] < n_levels)
        self._queue: Optional[asyncio.Queue] = None
        self._dispatcher: Optional[asyncio.Task] = None
        self._watchdog_task: Optional[asyncio.Task] = None
        self._inflight: Optional[asyncio.Semaphore] = None
        self._fanout_tasks: set = set()
        self._pool = ThreadPoolExecutor(
            max_workers=max(1, cfg.max_inflight),
            thread_name_prefix="serve-compute",
        )
        self._closing = False
        self._closed = False
        # (B, Mq) shapes that have gone through the jit cache at least once
        self._warmed: set = set()
        # -- resilience (all None/no-op when cfg.resilience is None) --
        res = cfg.resilience
        self.fault_injector = FaultInjector()
        self._admission = AdmissionController(res) if res else None
        self._degrade = (DegradationController(len(self.search_fns), res)
                         if res else None)
        # items dequeued by the dispatcher but not yet handed to fan-out;
        # the watchdog fails these with DispatcherFailed on restart.
        # Loop-confined (only the event loop touches it) — no lock.
        self._claimed: Dict[_Item, float] = {}
        self._beat = 0.0  # dispatcher heartbeat (loop.time())
        # -- stats (threading lock: read from facade threads, written from
        # fan-out tasks; the wall-clock span invariant is the same as v1:
        # qps = requests / (first enqueue -> last completion), never the sum
        # of overlapping per-request latencies) --
        self._lock = threading.Lock()
        self.latencies_ms: List[float] = []
        self.batch_sizes: List[int] = []
        self._rung_counts: Dict[int, int] = {}
        self._rung_occupied: Dict[int, int] = {}
        self._level_served: Dict[int, int] = {}
        self._recent_lat: collections.deque = collections.deque(maxlen=256)
        self._n_timeouts = 0
        self._n_deadline_expired = 0
        self._n_watchdog_restarts = 0
        self._t_first_enqueue: Optional[float] = None
        self._t_last_done: Optional[float] = None

    # -- lifecycle ----------------------------------------------------------

    async def start(self) -> None:
        """Idempotent: bind to the running loop and start the dispatcher."""
        if self._closed:
            raise ServerClosed("server already closed")
        if self._queue is None:
            loop = asyncio.get_running_loop()
            self._queue = asyncio.Queue()
            self._inflight = asyncio.Semaphore(max(1, self.cfg.max_inflight))
            self._beat = loop.time()
            self._dispatcher = loop.create_task(self._dispatch())
            if self.cfg.resilience is not None:
                self._watchdog_task = loop.create_task(self._watchdog())

    async def aclose(self) -> None:
        """Stop serving. In-flight batches complete and deliver results;
        still-queued requests get a terminal `ServerClosed` error."""
        if self._closed:
            return
        self._closing = True
        if self._watchdog_task is not None:
            self._watchdog_task.cancel()
            await asyncio.gather(self._watchdog_task, return_exceptions=True)
            self._watchdog_task = None
        if self._queue is not None:
            await self._queue.put(_STOP)
            # never let a dispatcher crash skip the drain below
            await asyncio.gather(self._dispatcher, return_exceptions=True)
            while True:
                try:
                    item = self._queue.get_nowait()
                except asyncio.QueueEmpty:
                    break
                if item is not _STOP and not item.future.done():
                    item.future.set_exception(
                        ServerClosed("server closed before request ran")
                    )
        if self._fanout_tasks:
            await asyncio.gather(
                *list(self._fanout_tasks), return_exceptions=True
            )
        # a dispatcher that died mid-claim leaves orphans; never strand them
        self._fail_claimed(ServerClosed("server closed before request ran"))
        self._pool.shutdown(wait=True)
        self._closed = True

    # -- client API ---------------------------------------------------------

    async def _enqueue(self, q_emb, q_mask, q_sal, *, _t_enqueue=None,
                       deadline_ms=None, slo="interactive") -> _Item:
        """Admission + enqueue; returns the queued `_Item` so callers (the
        sync facade) can cancel its future on their own timeout."""
        if self._closing or self._closed:
            raise ServerClosed("server is closed")
        await self.start()
        if self._admission is not None:
            reason = self._admission.admit(slo, self._queue.qsize())
            if reason is not None:
                raise Overloaded(reason)
        res = self.cfg.resilience
        t_enq = time.perf_counter() if _t_enqueue is None else _t_enqueue
        if deadline_ms is None and res is not None \
                and res.default_deadline_ms > 0:
            deadline_ms = res.default_deadline_ms
        deadline = None if deadline_ms is None else t_enq + deadline_ms / 1e3
        fut = asyncio.get_running_loop().create_future()
        item = _Item(
            # client inputs are host arrays by contract — no device sync
            np.asarray(q_emb), np.asarray(q_mask), np.asarray(q_sal), fut,  # noqa: JAX05
            t_enq, deadline, slo,
        )
        with self._lock:
            if self._t_first_enqueue is None:
                self._t_first_enqueue = t_enq
        await self._queue.put(item)
        return item

    async def query(self, q_emb, q_mask, q_sal, *, _t_enqueue=None,
                    deadline_ms=None, slo="interactive"):
        """Awaitable single-query search; returns (scores (k,), ids (k,)).

        Raises `Overloaded` when admission sheds the request,
        `DeadlineExceeded` when ``deadline_ms`` (or the configured
        default) passes before results are ready. The result is a
        `Served` tuple carrying ``.level``.
        """
        item = await self._enqueue(
            q_emb, q_mask, q_sal, _t_enqueue=_t_enqueue,
            deadline_ms=deadline_ms, slo=slo,
        )
        try:
            return await item.future
        except asyncio.CancelledError:
            # caller abandoned the wait: kill the queued item too so it
            # stops occupying a batch slot
            if not item.future.done():
                item.future.cancel()
            raise

    def rung_for(self, n: int) -> int:
        """Smallest ladder rung that fits a batch of n requests."""
        for b in self.ladder:
            if b >= n:
                return b
        return self.ladder[-1]

    def warm_shapes(self, q_emb, q_mask, q_sal, rungs=None,
                    levels=None) -> None:
        """Pre-compile ladder rungs for one query geometry (blocking).

        Takes a single example query (Mq, D); tiles it to each rung and runs
        the jitted search once so serving never pays a compile stall. All
        degradation levels are warmed by default — stepping down the
        quality ladder under overload must never stall on a compile.
        """
        q = np.asarray(q_emb)
        qm = np.asarray(q_mask)
        qs = np.asarray(q_sal)
        if levels is None:
            levels = range(len(self.search_fns))
        for b in rungs if rungs is not None else self.ladder:
            qb = jnp.asarray(np.broadcast_to(q, (b,) + q.shape))
            qmb = jnp.asarray(np.broadcast_to(qm, (b,) + qm.shape))
            qsb = jnp.asarray(np.broadcast_to(qs, (b,) + qs.shape))
            for level in levels:
                out = self._call_search(level, qb, qmb, qsb)
                jax.block_until_ready(out)
            self._warmed.add((b, q.shape[0]))

    @property
    def compiled_shapes(self) -> set:
        """(B, Mq) pairs that have hit the jit compile cache."""
        return set(self._warmed)

    @property
    def search_fn(self) -> Callable:
        """The level-0 (full quality) search function."""
        return self.search_fns[0]

    @search_fn.setter
    def search_fn(self, fn: Callable) -> None:
        self.search_fns[0] = fn

    def _call_search(self, level: int, q, qm, qs):
        if self.recompile_sentry is not None:
            return self.recompile_sentry(q, qm, qs, level)
        return self.search_fns[level](q, qm, qs)

    def swap_search_fn(self, search_fn: Callable,
                       degraded_fns: Optional[Sequence[Callable]] = None,
                       ) -> None:
        """Atomically swap the underlying search function (live index
        mutation). The recompile sentry — and its signature history — stays
        in place: the serving ladder's compiled rung set is a property of
        the *server*, and a swapped-in function must keep honouring it.
        Batches already staged finish on whichever function they read.

        When the server carries degradation levels, pass matching
        ``degraded_fns`` built from the same new state — the level count
        is fixed at construction (it sizes the degradation controller).
        """
        if degraded_fns is not None:
            if len(degraded_fns) + 1 != len(self.search_fns):
                raise ValueError(
                    f"got {len(degraded_fns)} degraded fns for a server "
                    f"with {len(self.search_fns) - 1} degraded levels"
                )
            self.search_fns[1:] = list(degraded_fns)
        self.search_fns[0] = search_fn

    # -- dispatcher ---------------------------------------------------------

    def _resolve_exc(self, item: _Item, exc: BaseException) -> None:
        self._claimed.pop(item, None)
        if not item.future.done():
            item.future.set_exception(exc)

    def _fail_claimed(self, exc: BaseException) -> None:
        for it in list(self._claimed):
            self._resolve_exc(it, exc)

    def _drop_stale(self, item: _Item) -> bool:
        """Drop cancelled/expired items before they occupy a batch slot."""
        if item.future.done():
            # client cancelled (sync facade timeout / abandoned await)
            self._claimed.pop(item, None)
            return True
        if item.deadline is not None \
                and time.perf_counter() >= item.deadline:
            with self._lock:
                self._n_deadline_expired += 1
            self._resolve_exc(item, DeadlineExceeded(
                "deadline passed while queued — dropped before staging"))
            return True
        return False

    def _observe_level(self) -> int:
        """One degradation-controller observation per coalesced batch."""
        if self._degrade is None:
            return 0
        res = self.cfg.resilience
        depth_frac = self._queue.qsize() / max(1, res.max_queue)
        with self._lock:
            recent = list(self._recent_lat)
        p99 = float(np.percentile(np.asarray(recent), 99)) if recent else 0.0
        return self._degrade.observe(depth_frac, p99)

    async def _dispatch(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            self._beat = loop.time()
            item = await self._queue.get()
            self._beat = loop.time()
            if item is _STOP:
                return
            self._claimed[item] = time.perf_counter()
            self.fault_injector.fire("dispatch")
            if self._closing:
                self._resolve_exc(item, ServerClosed(
                    "server closed before request ran"))
                continue
            if self._drop_stale(item):
                continue
            batch = [item]
            stop_after = False
            deadline = loop.time() + self.cfg.max_wait_ms / 1e3
            while len(batch) < self.cfg.max_batch:
                rem = deadline - loop.time()
                if rem <= 0:
                    break
                try:
                    nxt = await asyncio.wait_for(self._queue.get(), rem)
                except asyncio.TimeoutError:
                    break
                if nxt is _STOP:
                    stop_after = True
                    break
                self._claimed[nxt] = time.perf_counter()
                if not self._drop_stale(nxt):
                    batch.append(nxt)
            # deadlines/cancellations may have landed while coalescing
            batch = [r for r in batch if not self._drop_stale(r)]
            if not batch:
                if stop_after:
                    return
                continue
            level = self._observe_level()
            # bound in-flight batches (double buffer): once a slot frees we
            # stage the next batch here while the previous one still computes
            await self._inflight.acquire()
            # the wait for a slot can be long under load: re-check for
            # cancellations/deadlines that landed during it
            batch = [r for r in batch if not self._drop_stale(r)]
            if not batch:
                self._inflight.release()
                if stop_after:
                    return
                continue
            try:
                staged = self._stage(batch, level)
            except Exception as e:  # noqa: BLE001 - e.g. mixed-shape batch
                # fail this batch but keep the dispatcher alive: a staging
                # error (say, two coalesced queries with different Mq) must
                # not strand every later request on a dead queue
                self._inflight.release()
                for r in batch:
                    self._resolve_exc(r, e)
                if stop_after:
                    return
                continue
            for r in batch:
                # handed to fan-out, which owns resolution from here; the
                # watchdog only covers the dequeue->stage window
                self._claimed.pop(r, None)
            task = loop.create_task(self._fanout(batch, level, *staged))
            self._fanout_tasks.add(task)
            task.add_done_callback(self._fanout_tasks.discard)
            if stop_after:
                return

    async def _watchdog(self) -> None:
        """Detect a dead or hung dispatcher, restart it, and fail the
        requests it had claimed with `DispatcherFailed` instead of letting
        them hang. Runs only when `ServeConfig.resilience` is set."""
        res = self.cfg.resilience
        loop = asyncio.get_running_loop()
        while not (self._closing or self._closed):
            await asyncio.sleep(res.watchdog_interval_s)
            if self._closing or self._closed:
                return
            d = self._dispatcher
            if d is None:
                continue
            if d.done():
                err = None if d.cancelled() else d.exception()
                logger.error("serve dispatcher died (%r); restarting", err)
                self._restart_dispatcher(loop, DispatcherFailed(
                    f"dispatcher died ({err!r}) while this request was "
                    "claimed; restarted by watchdog"))
                continue
            pending = bool(self._claimed) or self._queue.qsize() > 0
            if pending and (loop.time() - self._beat) > res.stall_timeout_s:
                logger.error(
                    "serve dispatcher hung (heartbeat %.1fs stale, "
                    "%d claimed, depth %d); restarting",
                    loop.time() - self._beat, len(self._claimed),
                    self._queue.qsize())
                d.cancel()
                await asyncio.gather(d, return_exceptions=True)
                self._restart_dispatcher(loop, DispatcherFailed(
                    "dispatcher hung past stall_timeout_s while this "
                    "request was claimed; restarted by watchdog"))

    def _restart_dispatcher(self, loop, exc: DispatcherFailed) -> None:
        self._fail_claimed(exc)
        with self._lock:
            self._n_watchdog_restarts += 1
        self._beat = loop.time()
        self._dispatcher = loop.create_task(self._dispatch())

    def _stage(self, batch: List[_Item], level: int = 0):
        """Host staging: pad to the ladder rung and start the host->device
        transfer. Runs on the event loop, overlapped with the previous
        batch's device compute."""
        self.fault_injector.fire("stage")
        rung = self.rung_for(len(batch))
        first = batch[0]
        q = np.zeros((rung,) + first.q_emb.shape, first.q_emb.dtype)
        qm = np.zeros((rung,) + first.q_mask.shape, bool)
        qs = np.zeros((rung,) + first.q_sal.shape, first.q_sal.dtype)
        for i, r in enumerate(batch):
            q[i], qm[i], qs[i] = r.q_emb, r.q_mask, r.q_sal
        self._warmed.add((rung, first.q_emb.shape[0]))
        return rung, jnp.asarray(q), jnp.asarray(qm), jnp.asarray(qs)

    async def _fanout(self, batch: List[_Item], level: int, rung: int,
                      q, qm, qs) -> None:
        loop = asyncio.get_running_loop()

        def _compute():
            self.fault_injector.fire("compute")
            out = self._call_search(level, q, qm, qs)
            jax.block_until_ready(out)  # only blocking point, off the loop
            # device->host transfer stays on the executor thread too: done
            # on the event loop it head-of-line blocked every coalesced
            # request behind one D2H copy (JAX05)
            return np.asarray(out[0]), np.asarray(out[1])

        try:
            scores, ids = await loop.run_in_executor(self._pool, _compute)
            self.fault_injector.fire("fanout")
        except Exception as e:  # noqa: BLE001 - forwarded to every waiter
            for r in batch:
                self._resolve_exc(r, e)
            self._inflight.release()
            return
        now = time.perf_counter()
        with self._lock:
            self._t_last_done = now
            self.batch_sizes.append(len(batch))
            self._rung_counts[rung] = self._rung_counts.get(rung, 0) + 1
            self._rung_occupied[rung] = (
                self._rung_occupied.get(rung, 0) + len(batch)
            )
            if self._t_first_enqueue is None:
                # reset_stats() ran while this batch was in flight: restart
                # the window at this batch's earliest enqueue so the
                # span/latency invariant holds
                self._t_first_enqueue = min(r.t_enqueue for r in batch)
            for r in batch:
                lat_ms = (now - r.t_enqueue) * 1e3
                self.latencies_ms.append(lat_ms)
                self._recent_lat.append(lat_ms)
        for i, r in enumerate(batch):
            if r.deadline is not None and now >= r.deadline:
                # result arrived, but nobody is waiting for it anymore
                with self._lock:
                    self._n_deadline_expired += 1
                self._resolve_exc(r, DeadlineExceeded(
                    "deadline passed during compute"))
                continue
            if not r.future.done():
                r.future.set_result(Served((scores[i], ids[i]), level))
                with self._lock:
                    self._level_served[level] = (
                        self._level_served.get(level, 0) + 1
                    )
        self._inflight.release()

    # -- stats --------------------------------------------------------------

    def _resilience_stats(self) -> Dict[str, Any]:
        """Caller holds self._lock. The timeout counter is unconditional
        (sync-facade timeouts cancel their queued item on any server); the
        overload/degradation counters only exist on a guarded server."""
        out: Dict[str, Any] = {"timeouts": self._n_timeouts}
        if self.cfg.resilience is None:
            return out
        shed = (self._admission.stats() if self._admission is not None
                else {"interactive": 0, "batch": 0})
        out.update({
            "deadline_expired": self._n_deadline_expired,
            "shed": sum(shed.values()),
            "shed_interactive": shed["interactive"],
            "shed_batch": shed["batch"],
            "degrade_level": (self._degrade.level
                              if self._degrade is not None else 0),
            "level_served": dict(self._level_served),
            "watchdog_restarts": self._n_watchdog_restarts,
        })
        return out

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            lat = np.array(self.latencies_ms)
            batch_sizes = list(self.batch_sizes)
            rungs = {
                b: {
                    "batches": self._rung_counts[b],
                    "occupancy": self._rung_occupied[b]
                    / (self._rung_counts[b] * b),
                }
                for b in sorted(self._rung_counts)
            }
            t0, t1 = self._t_first_enqueue, self._t_last_done
            res = self._resilience_stats()
        if lat.size == 0:
            # no traffic yet: report zeros, never fabricated percentiles
            return {"n": 0, "p50_ms": 0.0, "p99_ms": 0.0, "mean_batch": 0.0,
                    "qps": 0.0, "rungs": {}, **res}
        # span comes from monotonic first/last timestamps ONLY; the fan-out
        # backfill keeps (lat nonempty => t0/t1 set) true even when
        # reset_stats races a completing batch, so a missing timestamp
        # means no completed window — report qps 0, never a value derived
        # from summed overlapping latencies
        if t0 is None or t1 is None:
            qps = 0.0
        else:
            qps = lat.size / max(t1 - t0, 1e-9)
        return {
            "n": int(lat.size),
            "p50_ms": float(np.percentile(lat, 50)),
            "p99_ms": float(np.percentile(lat, 99)),
            "mean_batch": float(np.mean(batch_sizes)) if batch_sizes else 0.0,
            "qps": qps,
            "rungs": rungs,
            **res,
        }

    def recompile_report(self) -> Optional[Dict[str, Any]]:
        """The recompile sentry's signature report (None when the guard
        is off — see ServeConfig.guard_recompiles)."""
        if self.recompile_sentry is None:
            return None
        return self.recompile_sentry.report()

    def reset_stats(self) -> None:
        """Drop recorded latencies and the serving window (e.g. after a
        warmup/compile request, which would otherwise skew qps). Resilience
        counters reset too, except watchdog_restarts (lifetime health)."""
        with self._lock:
            self.latencies_ms = []
            self.batch_sizes = []
            self._rung_counts = {}
            self._rung_occupied = {}
            self._level_served = {}
            self._recent_lat.clear()
            self._n_timeouts = 0
            self._n_deadline_expired = 0
            self._t_first_enqueue = None
            self._t_last_done = None
        if self._admission is not None:
            self._admission.reset()


class _Request:
    """v1 request handle: wait on ``event``, read ``result`` / ``error``."""

    __slots__ = ("q_emb", "q_mask", "q_sal", "event", "result", "error",
                 "t_enqueue", "deadline_ms", "slo", "item", "abandoned")

    def __init__(self, q_emb, q_mask, q_sal, deadline_ms=None,
                 slo="interactive"):
        self.q_emb, self.q_mask, self.q_sal = q_emb, q_mask, q_sal
        self.event = threading.Event()
        self.result = None
        self.error: Optional[BaseException] = None
        self.t_enqueue = time.perf_counter()
        self.deadline_ms = deadline_ms
        self.slo = slo
        self.item: Optional[_Item] = None   # set once enqueued (loop thread)
        self.abandoned = False              # set by the facade's timeout


class RetrievalServer:
    """Sync facade over `AsyncRetrievalServer` (thread-backed event loop).

    Keeps the v1 surface — ``submit`` -> waitable request, blocking
    ``query`` — so existing call sites work unchanged while the serving
    core is asyncio."""

    def __init__(self, search_fn: Callable, cfg: ServeConfig,
                 degraded_fns: Sequence[Callable] = ()):
        self.search_fn = search_fn
        self.cfg = cfg
        self._async = AsyncRetrievalServer(search_fn, cfg, degraded_fns)
        self._loop = asyncio.new_event_loop()
        self._thread = threading.Thread(
            target=self._loop.run_forever, name="serve-loop", daemon=True
        )
        self._thread.start()
        self._run(self._async.start()).result(timeout=10.0)
        self._closed = False
        # serialises submit-vs-close: a submit never schedules onto a loop
        # that close() has already begun stopping
        self._lifecycle = threading.Lock()

    def _run(self, coro):
        return asyncio.run_coroutine_threadsafe(coro, self._loop)

    # -- v1 surface ---------------------------------------------------------

    def submit(self, q_emb, q_mask, q_sal, *, deadline_ms=None,
               slo="interactive") -> _Request:
        req = _Request(np.asarray(q_emb), np.asarray(q_mask),
                       np.asarray(q_sal), deadline_ms, slo)

        async def _go():
            try:
                item = await self._async._enqueue(
                    req.q_emb, req.q_mask, req.q_sal,
                    _t_enqueue=req.t_enqueue,
                    deadline_ms=req.deadline_ms, slo=req.slo,
                )
                req.item = item
                if req.abandoned and not item.future.done():
                    item.future.cancel()
                req.result = await item.future
            except BaseException as e:  # noqa: BLE001 - handed to waiter
                req.error = e
            finally:
                req.event.set()

        with self._lifecycle:
            if self._closed:
                req.error = ServerClosed("server is closed")
                req.event.set()
                return req
            try:
                self._run(_go())
            except RuntimeError as e:   # loop torn down concurrently
                req.error = ServerClosed(f"server is closed ({e})")
                req.event.set()
        return req

    def cancel(self, req: _Request) -> None:
        """Cancel a submitted request from any thread: its queued item is
        killed on the loop (freeing the batch slot) and the abandonment is
        counted in ``stats()["timeouts"]``."""
        def _cancel():
            req.abandoned = True
            if req.item is not None and not req.item.future.done():
                req.item.future.cancel()
            with self._async._lock:
                self._async._n_timeouts += 1

        try:
            self._loop.call_soon_threadsafe(_cancel)
        except RuntimeError:
            pass  # loop already closed: nothing left to cancel

    def query(self, q_emb, q_mask, q_sal, timeout: float = 30.0, *,
              deadline_ms=None, slo="interactive"):
        req = self.submit(q_emb, q_mask, q_sal, deadline_ms=deadline_ms,
                          slo=slo)
        if not req.event.wait(timeout):
            # cancel the queued item — pre-fix it stayed enqueued and
            # occupied a batch slot long after this client gave up
            self.cancel(req)
            raise TimeoutError("retrieval request timed out")
        if req.error is not None:
            raise req.error
        return req.result

    def warm_shapes(self, q_emb, q_mask, q_sal, rungs=None,
                    levels=None) -> None:
        self._async.warm_shapes(q_emb, q_mask, q_sal, rungs, levels)

    def swap_search_fn(self, search_fn: Callable,
                       degraded_fns: Optional[Sequence[Callable]] = None,
                       ) -> None:
        self._async.swap_search_fn(search_fn, degraded_fns)

    @property
    def ladder(self) -> Tuple[int, ...]:
        return self._async.ladder

    @property
    def latencies_ms(self) -> List[float]:
        return self._async.latencies_ms

    @property
    def batch_sizes(self) -> List[int]:
        return self._async.batch_sizes

    def stats(self) -> Dict[str, Any]:
        return self._async.stats()

    @property
    def recompile_sentry(self):
        return self._async.recompile_sentry

    @property
    def fault_injector(self) -> FaultInjector:
        return self._async.fault_injector

    def recompile_report(self) -> Optional[Dict[str, Any]]:
        return self._async.recompile_report()

    def reset_stats(self) -> None:
        self._async.reset_stats()

    def close(self):
        """Drain and stop: in-flight batches deliver results, queued
        requests get a terminal `ServerClosed` error (no 30 s timeouts).
        Raises RuntimeError if the serving loop thread fails to join —
        a silent leak of a live thread is never reported as success."""
        with self._lifecycle:
            if self._closed:
                return
            self._closed = True
        try:
            self._run(self._async.aclose()).result(timeout=30.0)
        finally:
            self._loop.call_soon_threadsafe(self._loop.stop)
            self._thread.join(timeout=5.0)
            if self._thread.is_alive():
                state = (f"thread={self._thread.name!r} alive=True "
                         f"daemon={self._thread.daemon} "
                         f"loop_running={self._loop.is_running()}")
                logger.error("serving loop failed to join within 5 s (%s)",
                             state)
                raise RuntimeError(
                    f"serving loop thread failed to join within 5 s ({state})"
                )
            self._loop.close()
