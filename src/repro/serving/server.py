"""Async continuous-batching retrieval serving (v2).

`AsyncRetrievalServer` is asyncio-native: clients ``await server.query(...)``;
a coalescing loop drains the request queue under ``max_wait_ms`` and pads each
batch up a **power-of-two ladder** of compiled shapes (B in {1, 2, 4, ...,
max_batch}) instead of always padding to ``max_batch`` — a batch of 3 pads to
4, not 32, so a lone straggler pays single-digit-row compute. Shapes are
warmed lazily (jax.jit's shape-keyed cache compiles each (B, Mq) on first
use); ``warm_shapes`` pre-compiles the whole ladder up front.

Host staging overlaps device compute by double-buffering: the dispatcher
stages batch n+1's numpy->device transfer on the event loop while batch n's
jitted search runs in a bounded executor; ``jax.block_until_ready`` happens
only at fan-out, off the event loop, so percentiles include device time but
the loop never blocks on it.

`RetrievalServer` is the thin sync facade (thread-backed event loop) kept so
v1 call sites — ``submit`` returning a waitable request, blocking ``query`` —
keep working unchanged. ``close`` drains: in-flight batches complete and
deliver real results; requests still queued get a terminal `ServerClosed`
error instead of hanging until their client-side timeout.

Latency percentiles (p50/p99) are tracked per request, matching the paper's
Table IV metric definitions; ``stats()`` additionally reports per-ladder-rung
batch occupancy so under-filled compiled shapes are visible.
"""
from __future__ import annotations

import asyncio
import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class ServerClosed(RuntimeError):
    """Terminal error set on requests the server will never serve."""


def padding_ladder(max_batch: int) -> Tuple[int, ...]:
    """Powers of two up to ``max_batch`` (always ending at ``max_batch``)."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    rungs: List[int] = []
    b = 1
    while b < max_batch:
        rungs.append(b)
        b *= 2
    rungs.append(max_batch)
    return tuple(rungs)


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_wait_ms: float = 2.0
    top_k: int = 10
    # Compiled batch shapes. None -> power-of-two ladder up to max_batch;
    # a single-element tuple like (max_batch,) reproduces the v1 behaviour
    # of padding every batch to one full compiled shape.
    ladder: Optional[Tuple[int, ...]] = None
    # Double-buffer depth: how many staged batches may be in flight on the
    # device at once. 2 = stage n+1 while n computes (the default); 1
    # disables the overlap.
    max_inflight: int = 2
    # Wrap search_fn in a repro.analysis RecompileSentry: every call's
    # (B, Mq, dtypes) signature is recorded, batches whose B is not a
    # ladder rung raise RecompileGuardError instead of silently minting a
    # new compiled shape, and `recompile_report()` exposes the signature
    # set for the exact-rung-set assertion in tests/soaks.
    guard_recompiles: bool = False

    def resolved_ladder(self) -> Tuple[int, ...]:
        if self.ladder is None:
            return padding_ladder(self.max_batch)
        rungs = tuple(sorted(set(int(b) for b in self.ladder)))
        if not rungs or rungs[0] < 1:
            raise ValueError(f"invalid ladder {self.ladder}")
        if rungs[-1] != self.max_batch:
            raise ValueError(
                f"ladder {rungs} must end at max_batch={self.max_batch}"
            )
        return rungs


class _Item:
    """One queued query inside the asyncio server."""

    __slots__ = ("q_emb", "q_mask", "q_sal", "future", "t_enqueue")

    def __init__(self, q_emb, q_mask, q_sal, future, t_enqueue):
        self.q_emb, self.q_mask, self.q_sal = q_emb, q_mask, q_sal
        self.future = future
        self.t_enqueue = t_enqueue


_STOP = object()


class AsyncRetrievalServer:
    """search_fn(q_emb (B,Mq,D), q_mask, q_sal) -> (scores (B,k), ids).

    Bind to one event loop: the first ``query`` (or an explicit ``start``)
    captures the running loop; all queries must come from that loop.
    """

    def __init__(self, search_fn: Callable, cfg: ServeConfig):
        self.search_fn = search_fn
        self.cfg = cfg
        self.ladder = cfg.resolved_ladder()
        self.recompile_sentry = None
        if cfg.guard_recompiles:
            from repro.analysis.recompile import RecompileSentry
            rungs = set(self.ladder)

            def serve_signature(q, qm, qs):
                return (int(q.shape[0]), int(q.shape[1]), str(q.dtype),
                        str(qm.dtype), str(qs.dtype))

            self.recompile_sentry = RecompileSentry(
                search_fn, name="serve.search_fn", key_fn=serve_signature,
                allowed=lambda key: key[0] in rungs)
            self.search_fn = self.recompile_sentry
        self._queue: Optional[asyncio.Queue] = None
        self._dispatcher: Optional[asyncio.Task] = None
        self._inflight: Optional[asyncio.Semaphore] = None
        self._fanout_tasks: set = set()
        self._pool = ThreadPoolExecutor(
            max_workers=max(1, cfg.max_inflight),
            thread_name_prefix="serve-compute",
        )
        self._closing = False
        self._closed = False
        # (B, Mq) shapes that have gone through the jit cache at least once
        self._warmed: set = set()
        # -- stats (threading lock: read from facade threads, written from
        # fan-out tasks; the wall-clock span invariant is the same as v1:
        # qps = requests / (first enqueue -> last completion), never the sum
        # of overlapping per-request latencies) --
        self._lock = threading.Lock()
        self.latencies_ms: List[float] = []
        self.batch_sizes: List[int] = []
        self._rung_counts: Dict[int, int] = {}
        self._rung_occupied: Dict[int, int] = {}
        self._t_first_enqueue: Optional[float] = None
        self._t_last_done: Optional[float] = None

    # -- lifecycle ----------------------------------------------------------

    async def start(self) -> None:
        """Idempotent: bind to the running loop and start the dispatcher."""
        if self._closed:
            raise ServerClosed("server already closed")
        if self._queue is None:
            self._queue = asyncio.Queue()
            self._inflight = asyncio.Semaphore(max(1, self.cfg.max_inflight))
            self._dispatcher = asyncio.get_running_loop().create_task(
                self._dispatch()
            )

    async def aclose(self) -> None:
        """Stop serving. In-flight batches complete and deliver results;
        still-queued requests get a terminal `ServerClosed` error."""
        if self._closed:
            return
        self._closing = True
        if self._queue is not None:
            await self._queue.put(_STOP)
            # never let a dispatcher crash skip the drain below
            await asyncio.gather(self._dispatcher, return_exceptions=True)
            while True:
                try:
                    item = self._queue.get_nowait()
                except asyncio.QueueEmpty:
                    break
                if item is not _STOP and not item.future.done():
                    item.future.set_exception(
                        ServerClosed("server closed before request ran")
                    )
        if self._fanout_tasks:
            await asyncio.gather(
                *list(self._fanout_tasks), return_exceptions=True
            )
        self._pool.shutdown(wait=True)
        self._closed = True

    # -- client API ---------------------------------------------------------

    async def query(self, q_emb, q_mask, q_sal, *, _t_enqueue=None):
        """Awaitable single-query search; returns (scores (k,), ids (k,))."""
        if self._closing or self._closed:
            raise ServerClosed("server is closed")
        await self.start()
        t_enq = time.perf_counter() if _t_enqueue is None else _t_enqueue
        fut = asyncio.get_running_loop().create_future()
        item = _Item(
            # client inputs are host arrays by contract — no device sync
            np.asarray(q_emb), np.asarray(q_mask), np.asarray(q_sal), fut,  # noqa: JAX05
            t_enq,
        )
        with self._lock:
            if self._t_first_enqueue is None:
                self._t_first_enqueue = t_enq
        await self._queue.put(item)
        return await fut

    def rung_for(self, n: int) -> int:
        """Smallest ladder rung that fits a batch of n requests."""
        for b in self.ladder:
            if b >= n:
                return b
        return self.ladder[-1]

    def warm_shapes(self, q_emb, q_mask, q_sal, rungs=None) -> None:
        """Pre-compile ladder rungs for one query geometry (blocking).

        Takes a single example query (Mq, D); tiles it to each rung and runs
        the jitted search once so serving never pays a compile stall.
        """
        q = np.asarray(q_emb)
        qm = np.asarray(q_mask)
        qs = np.asarray(q_sal)
        for b in rungs if rungs is not None else self.ladder:
            out = self.search_fn(
                jnp.asarray(np.broadcast_to(q, (b,) + q.shape)),
                jnp.asarray(np.broadcast_to(qm, (b,) + qm.shape)),
                jnp.asarray(np.broadcast_to(qs, (b,) + qs.shape)),
            )
            jax.block_until_ready(out)
            self._warmed.add((b, q.shape[0]))

    @property
    def compiled_shapes(self) -> set:
        """(B, Mq) pairs that have hit the jit compile cache."""
        return set(self._warmed)

    def swap_search_fn(self, search_fn: Callable) -> None:
        """Atomically swap the underlying search function (live index
        mutation). The recompile sentry — and its signature history — stays
        in place: the serving ladder's compiled rung set is a property of
        the *server*, and a swapped-in function must keep honouring it.
        Batches already staged finish on whichever function they read."""
        if self.recompile_sentry is not None:
            self.recompile_sentry.fn = search_fn
        else:
            self.search_fn = search_fn

    # -- dispatcher ---------------------------------------------------------

    async def _dispatch(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            item = await self._queue.get()
            if item is _STOP:
                return
            if self._closing:
                if not item.future.done():
                    item.future.set_exception(
                        ServerClosed("server closed before request ran")
                    )
                continue
            batch = [item]
            stop_after = False
            deadline = loop.time() + self.cfg.max_wait_ms / 1e3
            while len(batch) < self.cfg.max_batch:
                rem = deadline - loop.time()
                if rem <= 0:
                    break
                try:
                    nxt = await asyncio.wait_for(self._queue.get(), rem)
                except asyncio.TimeoutError:
                    break
                if nxt is _STOP:
                    stop_after = True
                    break
                batch.append(nxt)
            # bound in-flight batches (double buffer): once a slot frees we
            # stage the next batch here while the previous one still computes
            await self._inflight.acquire()
            try:
                staged = self._stage(batch)
            except Exception as e:  # noqa: BLE001 - e.g. mixed-shape batch
                # fail this batch but keep the dispatcher alive: a staging
                # error (say, two coalesced queries with different Mq) must
                # not strand every later request on a dead queue
                self._inflight.release()
                for r in batch:
                    if not r.future.done():
                        r.future.set_exception(e)
                if stop_after:
                    return
                continue
            task = loop.create_task(self._fanout(batch, *staged))
            self._fanout_tasks.add(task)
            task.add_done_callback(self._fanout_tasks.discard)
            if stop_after:
                return

    def _stage(self, batch: List[_Item]):
        """Host staging: pad to the ladder rung and start the host->device
        transfer. Runs on the event loop, overlapped with the previous
        batch's device compute."""
        rung = self.rung_for(len(batch))
        first = batch[0]
        q = np.zeros((rung,) + first.q_emb.shape, first.q_emb.dtype)
        qm = np.zeros((rung,) + first.q_mask.shape, bool)
        qs = np.zeros((rung,) + first.q_sal.shape, first.q_sal.dtype)
        for i, r in enumerate(batch):
            q[i], qm[i], qs[i] = r.q_emb, r.q_mask, r.q_sal
        self._warmed.add((rung, first.q_emb.shape[0]))
        return rung, jnp.asarray(q), jnp.asarray(qm), jnp.asarray(qs)

    async def _fanout(self, batch: List[_Item], rung: int, q, qm, qs) -> None:
        loop = asyncio.get_running_loop()

        def _compute():
            out = self.search_fn(q, qm, qs)
            jax.block_until_ready(out)  # only blocking point, off the loop
            # device->host transfer stays on the executor thread too: done
            # on the event loop it head-of-line blocked every coalesced
            # request behind one D2H copy (JAX05)
            return np.asarray(out[0]), np.asarray(out[1])

        try:
            scores, ids = await loop.run_in_executor(self._pool, _compute)
        except Exception as e:  # noqa: BLE001 - forwarded to every waiter
            for r in batch:
                if not r.future.done():
                    r.future.set_exception(e)
            self._inflight.release()
            return
        now = time.perf_counter()
        with self._lock:
            self._t_last_done = now
            self.batch_sizes.append(len(batch))
            self._rung_counts[rung] = self._rung_counts.get(rung, 0) + 1
            self._rung_occupied[rung] = (
                self._rung_occupied.get(rung, 0) + len(batch)
            )
            if self._t_first_enqueue is None:
                # reset_stats() ran while this batch was in flight: restart
                # the window at this batch's earliest enqueue so the
                # span/latency invariant holds
                self._t_first_enqueue = min(r.t_enqueue for r in batch)
            for r in batch:
                self.latencies_ms.append((now - r.t_enqueue) * 1e3)
        for i, r in enumerate(batch):
            if not r.future.done():
                r.future.set_result((scores[i], ids[i]))
        self._inflight.release()

    # -- stats --------------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            lat = np.array(self.latencies_ms)
            batch_sizes = list(self.batch_sizes)
            rungs = {
                b: {
                    "batches": self._rung_counts[b],
                    "occupancy": self._rung_occupied[b]
                    / (self._rung_counts[b] * b),
                }
                for b in sorted(self._rung_counts)
            }
            t0, t1 = self._t_first_enqueue, self._t_last_done
        if lat.size == 0:
            # no traffic yet: report zeros, never fabricated percentiles
            return {"n": 0, "p50_ms": 0.0, "p99_ms": 0.0, "mean_batch": 0.0,
                    "qps": 0.0, "rungs": {}}
        if t0 is None or t1 is None:
            span_s = max(float(np.sum(lat)) / 1e3, 1e-9)  # degraded
        else:
            span_s = max(t1 - t0, 1e-9)
        return {
            "n": int(lat.size),
            "p50_ms": float(np.percentile(lat, 50)),
            "p99_ms": float(np.percentile(lat, 99)),
            "mean_batch": float(np.mean(batch_sizes)) if batch_sizes else 0.0,
            "qps": lat.size / span_s,
            "rungs": rungs,
        }

    def recompile_report(self) -> Optional[Dict[str, Any]]:
        """The recompile sentry's signature report (None when the guard
        is off — see ServeConfig.guard_recompiles)."""
        if self.recompile_sentry is None:
            return None
        return self.recompile_sentry.report()

    def reset_stats(self) -> None:
        """Drop recorded latencies and the serving window (e.g. after a
        warmup/compile request, which would otherwise skew qps)."""
        with self._lock:
            self.latencies_ms = []
            self.batch_sizes = []
            self._rung_counts = {}
            self._rung_occupied = {}
            self._t_first_enqueue = None
            self._t_last_done = None


class _Request:
    """v1 request handle: wait on ``event``, read ``result`` / ``error``."""

    __slots__ = ("q_emb", "q_mask", "q_sal", "event", "result", "error",
                 "t_enqueue")

    def __init__(self, q_emb, q_mask, q_sal):
        self.q_emb, self.q_mask, self.q_sal = q_emb, q_mask, q_sal
        self.event = threading.Event()
        self.result = None
        self.error: Optional[BaseException] = None
        self.t_enqueue = time.perf_counter()


class RetrievalServer:
    """Sync facade over `AsyncRetrievalServer` (thread-backed event loop).

    Keeps the v1 surface — ``submit`` -> waitable request, blocking
    ``query`` — so existing call sites work unchanged while the serving
    core is asyncio."""

    def __init__(self, search_fn: Callable, cfg: ServeConfig):
        self.search_fn = search_fn
        self.cfg = cfg
        self._async = AsyncRetrievalServer(search_fn, cfg)
        self._loop = asyncio.new_event_loop()
        self._thread = threading.Thread(
            target=self._loop.run_forever, name="serve-loop", daemon=True
        )
        self._thread.start()
        self._run(self._async.start()).result(timeout=10.0)
        self._closed = False
        # serialises submit-vs-close: a submit never schedules onto a loop
        # that close() has already begun stopping
        self._lifecycle = threading.Lock()

    def _run(self, coro):
        return asyncio.run_coroutine_threadsafe(coro, self._loop)

    # -- v1 surface ---------------------------------------------------------

    def submit(self, q_emb, q_mask, q_sal) -> _Request:
        req = _Request(np.asarray(q_emb), np.asarray(q_mask),
                       np.asarray(q_sal))

        async def _go():
            try:
                req.result = await self._async.query(
                    req.q_emb, req.q_mask, req.q_sal,
                    _t_enqueue=req.t_enqueue,
                )
            except BaseException as e:  # noqa: BLE001 - handed to waiter
                req.error = e
            finally:
                req.event.set()

        with self._lifecycle:
            if self._closed:
                req.error = ServerClosed("server is closed")
                req.event.set()
                return req
            try:
                self._run(_go())
            except RuntimeError as e:   # loop torn down concurrently
                req.error = ServerClosed(f"server is closed ({e})")
                req.event.set()
        return req

    def query(self, q_emb, q_mask, q_sal, timeout: float = 30.0):
        req = self.submit(q_emb, q_mask, q_sal)
        if not req.event.wait(timeout):
            raise TimeoutError("retrieval request timed out")
        if req.error is not None:
            raise req.error
        return req.result

    def warm_shapes(self, q_emb, q_mask, q_sal, rungs=None) -> None:
        self._async.warm_shapes(q_emb, q_mask, q_sal, rungs)

    def swap_search_fn(self, search_fn: Callable) -> None:
        self._async.swap_search_fn(search_fn)

    @property
    def ladder(self) -> Tuple[int, ...]:
        return self._async.ladder

    @property
    def latencies_ms(self) -> List[float]:
        return self._async.latencies_ms

    @property
    def batch_sizes(self) -> List[int]:
        return self._async.batch_sizes

    def stats(self) -> Dict[str, Any]:
        return self._async.stats()

    @property
    def recompile_sentry(self):
        return self._async.recompile_sentry

    def recompile_report(self) -> Optional[Dict[str, Any]]:
        return self._async.recompile_report()

    def reset_stats(self) -> None:
        self._async.reset_stats()

    def close(self):
        """Drain and stop: in-flight batches deliver results, queued
        requests get a terminal `ServerClosed` error (no 30 s timeouts)."""
        with self._lifecycle:
            if self._closed:
                return
            self._closed = True
        try:
            self._run(self._async.aclose()).result(timeout=30.0)
        finally:
            self._loop.call_soon_threadsafe(self._loop.stop)
            self._thread.join(timeout=5.0)
            self._loop.close()
