"""Batched retrieval serving with continuous micro-batching.

RetrievalServer fronts the (possibly mesh-sharded) HPC-ColPali index:
requests land on a queue; a dispatcher thread coalesces up to
`max_batch` requests (or `max_wait_ms`, whichever first — classic
continuous batching), pads the query tensors to the compiled batch shape,
runs the jitted query pipeline once, and fans results back out per-request.
Latency percentiles (p50/p99) are tracked per request, matching the
paper's Table IV metric definitions.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_wait_ms: float = 2.0
    top_k: int = 10


class _Request:
    __slots__ = ("q_emb", "q_mask", "q_sal", "event", "result", "t_enqueue")

    def __init__(self, q_emb, q_mask, q_sal):
        self.q_emb, self.q_mask, self.q_sal = q_emb, q_mask, q_sal
        self.event = threading.Event()
        self.result = None
        self.t_enqueue = time.perf_counter()


class RetrievalServer:
    """search_fn(q_emb (B,Mq,D), q_mask, q_sal) -> (scores (B,k), ids)."""

    def __init__(self, search_fn: Callable, cfg: ServeConfig):
        self.search_fn = search_fn
        self.cfg = cfg
        self._q: "queue.Queue[_Request]" = queue.Queue()
        self._stop = threading.Event()
        self.latencies_ms: List[float] = []
        self.batch_sizes: List[int] = []
        # wall-clock span of the serving window: first enqueue -> last
        # completion. qps must be requests / span, NOT requests / sum of
        # per-request latencies (overlapping requests would make the sum
        # exceed the wall clock and wildly underestimate throughput).
        self._lock = threading.Lock()
        self._t_first_enqueue: Optional[float] = None
        self._t_last_done: Optional[float] = None
        self._thread = threading.Thread(target=self._dispatch, daemon=True)
        self._thread.start()

    def submit(self, q_emb, q_mask, q_sal) -> _Request:
        req = _Request(np.asarray(q_emb), np.asarray(q_mask),
                       np.asarray(q_sal))
        with self._lock:
            if self._t_first_enqueue is None:
                self._t_first_enqueue = req.t_enqueue
        self._q.put(req)
        return req

    def query(self, q_emb, q_mask, q_sal, timeout: float = 30.0):
        req = self.submit(q_emb, q_mask, q_sal)
        if not req.event.wait(timeout):
            raise TimeoutError("retrieval request timed out")
        return req.result

    def _dispatch(self):
        while not self._stop.is_set():
            try:
                first = self._q.get(timeout=0.1)
            except queue.Empty:
                continue
            batch = [first]
            deadline = time.perf_counter() + self.cfg.max_wait_ms / 1e3
            while len(batch) < self.cfg.max_batch:
                rem = deadline - time.perf_counter()
                if rem <= 0:
                    break
                try:
                    batch.append(self._q.get(timeout=rem))
                except queue.Empty:
                    break
            self._run(batch)

    def _run(self, batch: List[_Request]):
        b = self.cfg.max_batch
        q = np.stack([r.q_emb for r in batch])
        qm = np.stack([r.q_mask for r in batch])
        qs = np.stack([r.q_sal for r in batch])
        if len(batch) < b:                       # pad to the compiled shape
            pad = b - len(batch)
            q = np.concatenate([q, np.zeros((pad,) + q.shape[1:], q.dtype)])
            qm = np.concatenate([qm, np.zeros((pad,) + qm.shape[1:], bool)])
            qs = np.concatenate([qs, np.zeros((pad,) + qs.shape[1:],
                                              qs.dtype)])
        scores, ids = self.search_fn(jnp.asarray(q), jnp.asarray(qm),
                                     jnp.asarray(qs))
        scores, ids = np.asarray(scores), np.asarray(ids)
        now = time.perf_counter()
        self.batch_sizes.append(len(batch))
        with self._lock:
            self._t_last_done = now
            if self._t_first_enqueue is None:
                # reset_stats() ran while this batch was in flight: restart
                # the window at this batch's earliest enqueue so the
                # span/latency invariant holds
                self._t_first_enqueue = min(r.t_enqueue for r in batch)
        for i, r in enumerate(batch):
            r.result = (scores[i], ids[i])
            self.latencies_ms.append((now - r.t_enqueue) * 1e3)
            r.event.set()

    def stats(self) -> Dict[str, float]:
        if not self.latencies_ms:
            # no traffic yet: report zeros, never fabricated percentiles
            return {"n": 0, "p50_ms": 0.0, "p99_ms": 0.0, "mean_batch": 0.0,
                    "qps": 0.0}
        lat = np.array(self.latencies_ms)
        with self._lock:
            if self._t_last_done is None or self._t_first_enqueue is None:
                span_s = max(float(np.sum(lat)) / 1e3, 1e-9)  # degraded
            else:
                span_s = max(self._t_last_done - self._t_first_enqueue, 1e-9)
        return {
            "n": len(self.latencies_ms),
            "p50_ms": float(np.percentile(lat, 50)),
            "p99_ms": float(np.percentile(lat, 99)),
            "mean_batch": float(np.mean(self.batch_sizes))
            if self.batch_sizes else 0.0,
            "qps": len(self.latencies_ms) / span_s,
        }

    def reset_stats(self):
        """Drop recorded latencies and the serving window (e.g. after a
        warmup/compile request, which would otherwise skew qps)."""
        with self._lock:
            self.latencies_ms = []
            self.batch_sizes = []
            self._t_first_enqueue = None
            self._t_last_done = None

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
