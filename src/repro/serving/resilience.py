"""Fault-tolerant serving substrate: the policy objects behind
`AsyncRetrievalServer`'s overload and failure behaviour.

Under overload a serving stack without admission control silently builds
backlog until client timeouts fire — every request is eventually "served"
into a void. This module makes the failure modes explicit and *cheap*:

  * **Deadlines** — every request may carry one; expired requests are
    dropped before staging (never burn device compute) and cancelled at
    fan-out (never deliver a result the client stopped waiting for).
    `DeadlineExceeded` is the terminal error.
  * **Bounded admission + load shedding** — a bounded queue with explicit
    `Overloaded` rejection and per-SLO-class token buckets
    (`interactive` / `batch`). Shedding is cost-aware: the `batch` class
    sheds first (at `shed_batch_frac` of the queue bound), `interactive`
    only at the hard bound.
  * **Graceful degradation** — `DegradationController` watches queue
    depth (and optionally p99) and steps the active *degradation level*
    up/down under hysteresis. Levels index a pre-compiled ladder of
    search functions (full cascade budgets -> halved budgets -> ...
    -> hamming-only prefilter), so stepping down trades quality for
    latency without minting a single off-ladder compile.
  * **Fault injection** — `FaultInjector` arms exceptions / latency
    spikes at named sites inside the serving loop (stage / compute /
    fanout / dispatch); the chaos suite (tests/test_resilience.py)
    drives it to prove each failure stays contained.
  * **Watchdog** — the server's watchdog task (see server.py) detects a
    dead or hung coalescing loop, restarts it, and fails the requests
    the dead loop had claimed with `DispatcherFailed` instead of
    letting them hang.

All controllers here are plain host-side Python: no JAX, no device work,
O(1) per decision. See docs/design.md §11 for the full policy writeup.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Tuple

__all__ = [
    "AdmissionController",
    "DeadlineExceeded",
    "DegradationController",
    "DispatcherFailed",
    "FaultInjector",
    "FaultInjected",
    "Overloaded",
    "ResilienceConfig",
    "SLO_CLASSES",
    "TokenBucket",
]

SLO_CLASSES = ("interactive", "batch")


class Overloaded(RuntimeError):
    """Request rejected at admission: queue bound or SLO-class budget.

    The explicit alternative to silent backlog — a client that sees
    `Overloaded` can back off / retry elsewhere instead of waiting out a
    timeout behind an unbounded queue.
    """


class DeadlineExceeded(TimeoutError):
    """Request deadline passed before (or while) it was served."""


class DispatcherFailed(RuntimeError):
    """Terminal error for requests claimed by a dead/hung dispatcher.

    Set by the watchdog when it restarts the coalescing loop: requests
    the dead loop had already dequeued cannot be recovered (their batch
    state died with it), so their waiters are released with this error
    instead of hanging forever.
    """


class FaultInjected(RuntimeError):
    """Default exception raised by an armed `FaultInjector` site."""


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Knobs of the fault-tolerant serving layer (docs/design.md §11).

    Attach via ``ServeConfig(resilience=ResilienceConfig(...))``; None
    keeps the pre-resilience behaviour (unbounded queue, no deadlines,
    no degradation, no watchdog) for existing call sites.
    """

    # -- bounded admission + shedding --------------------------------------
    # Hard queue bound; a request arriving with `max_queue` already
    # waiting is rejected with `Overloaded` regardless of class.
    max_queue: int = 128
    # Queue-depth fraction beyond which the `batch` class sheds
    # (cost-aware: batch work is deferrable, interactive is not).
    shed_batch_frac: float = 0.5
    # Per-class token buckets (requests/s + burst); rate 0 = unlimited.
    interactive_rate: float = 0.0
    interactive_burst: float = 32.0
    batch_rate: float = 0.0
    batch_burst: float = 32.0

    # -- deadlines ----------------------------------------------------------
    # Applied to requests that carry no explicit deadline; 0 = none.
    default_deadline_ms: float = 0.0

    # -- degradation ladder -------------------------------------------------
    # Step one level DOWN the quality ladder when queue depth crosses
    # `degrade_high_frac` of max_queue (or p99 crosses degrade_p99_ms,
    # when set); step back UP one level only after `degrade_hold`
    # consecutive calm observations below `degrade_low_frac` — the
    # hysteresis band between the two fractions holds the level.
    degrade_high_frac: float = 0.75
    degrade_low_frac: float = 0.25
    degrade_p99_ms: float = 0.0
    degrade_hold: int = 4

    # -- watchdog -----------------------------------------------------------
    watchdog_interval_s: float = 0.05
    # A claimed-but-unresolved request older than this is failed with
    # `DispatcherFailed`; a dispatcher whose heartbeat is older than this
    # while work is pending is cancelled and restarted.
    stall_timeout_s: float = 30.0

    def __post_init__(self):
        if self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {self.max_queue}")
        if not 0.0 <= self.degrade_low_frac <= self.degrade_high_frac:
            raise ValueError(
                "need 0 <= degrade_low_frac <= degrade_high_frac, got "
                f"({self.degrade_low_frac}, {self.degrade_high_frac})")


class TokenBucket:
    """Classic token bucket: `rate` tokens/s, capacity `burst`.

    `rate <= 0` means unlimited (every take succeeds). Host-clock based
    (time.perf_counter), O(1) per take, no background refill task.
    """

    def __init__(self, rate: float, burst: float):
        self.rate = float(rate)
        self.burst = max(float(burst), 1.0)
        self.tokens = self.burst
        self._t_last: Optional[float] = None

    def try_take(self, now: Optional[float] = None) -> bool:
        if self.rate <= 0:
            return True
        if now is None:
            now = time.perf_counter()
        if self._t_last is not None:
            self.tokens = min(self.burst,
                              self.tokens + (now - self._t_last) * self.rate)
        self._t_last = now
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False


class AdmissionController:
    """Bounded queue + per-SLO-class token buckets + cost-aware shedding.

    `admit(slo, depth)` returns None to admit, or a short reason string
    when the request must be shed (the server raises `Overloaded` with
    it). Rejections are counted per class in `shed_counts`.
    """

    def __init__(self, cfg: ResilienceConfig):
        self.cfg = cfg
        self.buckets = {
            "interactive": TokenBucket(cfg.interactive_rate,
                                       cfg.interactive_burst),
            "batch": TokenBucket(cfg.batch_rate, cfg.batch_burst),
        }
        self.shed_counts: Dict[str, int] = {c: 0 for c in SLO_CLASSES}
        self._lock = threading.Lock()

    def admit(self, slo: str, depth: int,
              now: Optional[float] = None) -> Optional[str]:
        if slo not in SLO_CLASSES:
            raise ValueError(
                f"unknown SLO class {slo!r}; expected one of {SLO_CLASSES}")
        cfg = self.cfg
        with self._lock:
            if depth >= cfg.max_queue:
                self.shed_counts[slo] += 1
                return (f"queue full ({depth}/{cfg.max_queue})")
            if (slo == "batch"
                    and depth >= cfg.shed_batch_frac * cfg.max_queue):
                self.shed_counts[slo] += 1
                return (f"batch class shed at depth {depth} "
                        f">= {cfg.shed_batch_frac:.0%} of {cfg.max_queue}")
            if not self.buckets[slo].try_take(now):
                self.shed_counts[slo] += 1
                return f"{slo} token bucket empty"
        return None

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return dict(self.shed_counts)

    def reset(self) -> None:
        """Zero the shed counters (token-bucket fill is left alone)."""
        with self._lock:
            self.shed_counts = {c: 0 for c in SLO_CLASSES}


class DegradationController:
    """Queue-depth/p99-driven quality-for-latency ladder with hysteresis.

    `observe(depth_frac, p99_ms)` is called once per dispatcher
    iteration and returns the level every batch of that iteration is
    served at. Level 0 is full quality; higher levels select cheaper
    pre-compiled search functions (smaller cascade budgets, ultimately
    the hamming-only prefilter). Stepping down is immediate (overload is
    now); stepping back up requires `hold` consecutive calm
    observations, so a bursty arrival process does not flap the level.
    """

    def __init__(self, n_levels: int, cfg: Optional[ResilienceConfig] = None):
        if n_levels < 1:
            raise ValueError(f"n_levels must be >= 1, got {n_levels}")
        self.n_levels = n_levels
        self.cfg = cfg if cfg is not None else ResilienceConfig()
        self._level = 0
        self._calm = 0
        # (t_monotonic, from_level, to_level) — bounded history
        self.transitions: List[Tuple[float, int, int]] = []
        self._lock = threading.Lock()

    @property
    def level(self) -> int:
        return self._level

    def _move(self, to: int) -> None:
        if to != self._level:
            self.transitions.append((time.perf_counter(), self._level, to))
            del self.transitions[:-256]
            self._level = to

    def observe(self, depth_frac: float, p99_ms: float = 0.0) -> int:
        cfg = self.cfg
        hot = depth_frac >= cfg.degrade_high_frac or (
            cfg.degrade_p99_ms > 0 and p99_ms >= cfg.degrade_p99_ms)
        calm = depth_frac <= cfg.degrade_low_frac and not hot
        with self._lock:
            if hot:
                self._calm = 0
                self._move(min(self._level + 1, self.n_levels - 1))
            elif calm and self._level > 0:
                self._calm += 1
                if self._calm >= cfg.degrade_hold:
                    self._calm = 0
                    self._move(self._level - 1)
            elif not calm:
                self._calm = 0          # hysteresis band: hold the level
            return self._level

    def stats(self) -> Dict[str, float]:
        with self._lock:
            return {"level": float(self._level),
                    "n_levels": float(self.n_levels),
                    "transitions": float(len(self.transitions))}


class FaultInjector:
    """Named-site fault injection for the chaos suite.

    The serving loop calls `fire(site)` at its instrumented sites
    ("stage", "compute", "fanout", "dispatch"); an unarmed site is a
    no-op costing one dict lookup. `arm` installs an exception and/or a
    latency spike that fires on the next `times` calls. Thread-safe:
    sites fire from both the event loop and executor threads.
    """

    def __init__(self):
        self._armed: Dict[str, Dict] = {}
        self.fired: Dict[str, int] = {}
        self._lock = threading.Lock()

    def arm(self, site: str, *, exc: Optional[BaseException] = None,
            latency_s: float = 0.0, times: int = 1) -> None:
        """Arm `site` to raise `exc` (default `FaultInjected`) and/or
        sleep `latency_s` on its next `times` firings."""
        if exc is None and latency_s <= 0.0:
            exc = FaultInjected(f"injected fault at site {site!r}")
        with self._lock:
            self._armed[site] = {"exc": exc, "latency_s": float(latency_s),
                                 "times": int(times)}

    def clear(self, site: Optional[str] = None) -> None:
        with self._lock:
            if site is None:
                self._armed.clear()
            else:
                self._armed.pop(site, None)

    def fire(self, site: str) -> None:
        with self._lock:
            spec = self._armed.get(site)
            if spec is None:
                return
            spec["times"] -= 1
            if spec["times"] <= 0:
                del self._armed[site]
            self.fired[site] = self.fired.get(site, 0) + 1
            exc, latency = spec["exc"], spec["latency_s"]
        if latency > 0.0:
            # deliberately a blocking sleep: the injector simulates a
            # stalled device/host exactly where the real stall would be
            time.sleep(latency)
        if exc is not None:
            raise exc
