"""Client-side load driver for the async retrieval server.

Shared by the serving CLI (repro.launch.serve) and the latency benchmark
(benchmarks/latency.py) so both measure the same arrival process.
"""
from __future__ import annotations

import asyncio
from typing import Optional

import numpy as np


async def drive(server, q_embs, q_masks, q_sals,
                n_requests: Optional[int] = None, rate_qps: float = 0.0,
                seed: int = 0, deadline_ms: Optional[float] = None,
                slo: str = "interactive", return_exceptions: bool = False):
    """Submit queries through ``server.query``; returns results in
    submission order.

    Request *i* uses query index ``i % len(q_embs)``. ``rate_qps <= 0``
    is a closed loop (everything submitted at once); ``> 0`` is an
    open-loop Poisson arrival process at that rate — arrivals land at
    exponential gaps regardless of completions, the honest way to
    measure tail latency.

    ``deadline_ms``/``slo`` propagate per request to a resilient server.
    With ``return_exceptions=True`` per-request outcomes come back in
    place (`Served` tuple, `Overloaded`, `DeadlineExceeded`, ...) so an
    overload drill can assert that *every* request resolved; admission
    rejections are raised at submit time and still land in the slot.
    """
    rng = np.random.default_rng(seed)
    n = len(q_embs) if n_requests is None else n_requests
    nq = len(q_embs)
    kw = {}
    if deadline_ms is not None:
        kw["deadline_ms"] = deadline_ms
    if slo != "interactive":
        kw["slo"] = slo
    tasks = []
    for i in range(n):
        j = i % nq
        tasks.append(asyncio.ensure_future(
            server.query(q_embs[j], q_masks[j], q_sals[j], **kw)))
        if rate_qps > 0:
            await asyncio.sleep(rng.exponential(1.0 / rate_qps))
    return await asyncio.gather(*tasks,
                                return_exceptions=return_exceptions)
