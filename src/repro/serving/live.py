"""Live-index serving: mutations interleaved with queries, no recompiles.

`LiveIndexSession` couples a `Retriever` over a *segmented* state (see
core/index.py `SegmentedState`) with the async serving ladder so the
corpus can grow (`add`), shrink (`delete`) and fold (`compact`) while
queries keep flowing — without minting new compiled search shapes per
mutation.

The recompile story has two layers:

  * **Serving ladder** — the server's search function is a fixed wrapper
    that reads the session's current state *at execution time*; the
    sentry (``ServeConfig.guard_recompiles``) keys on (B, Mq, dtypes)
    and its compiled rung set is untouched by mutations. Swapping state
    never swaps the function the sentry wraps.
  * **State shapes** — the session jits ONE search function with the
    state as an *argument*, so jax.jit's cache keys on the state's shape
    signature. Deletes and upserts flip tombstone bits in place (zero
    new shapes). Adds append segments whose capacity is bucketed to
    powers of two (``segment_capacity``), so the distinct-signature
    registry grows O(log N) with corpus size, not O(#mutations);
    ``compact`` folds everything back to the single-segment signature.
    ``state_signatures`` exposes the realised registry so soaks can
    assert it stays bounded.

Mutations are atomic swaps: the new state is built off-thread from the
current one, then published with a single reference assignment. Batches
already staged finish against whichever state they read — a query never
sees a half-applied mutation.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Optional, Tuple

import jax

from repro.retrieval.base import Corpus, Query, RetrieverState
from repro.retrieval.retriever import Retriever
from repro.serving.server import RetrievalServer, ServeConfig

__all__ = ["LiveIndexSession"]


class LiveIndexSession:
    """Serve queries over an index that mutates between batches."""

    def __init__(self, retriever: Retriever, state: RetrieverState,
                 cfg: ServeConfig, *, top_k: Optional[int] = None):
        self.retriever = retriever
        self.top_k = cfg.top_k if top_k is None else top_k
        # normalize up front so the first add doesn't change the treedef
        # from monolithic to segmented mid-flight
        self._state = retriever.backend.to_segmented(state)
        self._mutate_lock = threading.Lock()
        self._signatures: Dict[Tuple, int] = {}
        self._record_signature()

        def _search(st, q, qm, qs):
            return retriever.search(st, Query(q, qm, qs), k=self.top_k)

        self._jsearch = jax.jit(_search)

        def search_fn(q, qm, qs):
            # read once: the batch runs entirely against this state
            return self._jsearch(self._state, q, qm, qs)

        # degradation ladder (overload response, docs/design.md §11): one
        # jitted function per rung below the configured budgets. The rung
        # is baked into each closure; the STATE stays an argument, so
        # mutations swap through the same O(log N) shape registry and the
        # degraded levels never recompile per publish.
        self.degrade_rungs: Tuple = ()
        if cfg.resilience is not None:
            self.degrade_rungs = retriever.degrade_rungs(self._state,
                                                         k=self.top_k)

        def _make_degraded(rung):
            def _dsearch(st, q, qm, qs):
                return retriever.search_degraded(
                    st, Query(q, qm, qs), k=self.top_k, rung=rung)

            jfn = jax.jit(_dsearch)

            def degraded_fn(q, qm, qs):
                return jfn(self._state, q, qm, qs)

            return degraded_fn

        degraded_fns = tuple(_make_degraded(r) for r in self.degrade_rungs)
        self.server = RetrievalServer(search_fn, cfg, degraded_fns)

    # -- state registry ------------------------------------------------------

    def _signature(self, state: RetrieverState) -> Tuple:
        seg = self.retriever.backend._segmented(state)
        caps = tuple(
            tuple(jax.numpy.shape(lv)) for lv in seg.live) if seg else ()
        return (caps, state.rerank_codes.shape[0])

    def _record_signature(self) -> None:
        key = self._signature(self._state)
        self._signatures[key] = self._signatures.get(key, 0) + 1

    @property
    def state(self) -> RetrieverState:
        return self._state

    def state_signatures(self) -> Dict[Tuple, int]:
        """Distinct state shape signatures published so far (each is one
        potential jit cache entry per ladder rung)."""
        return dict(self._signatures)

    def segment_shapes(self) -> Tuple:
        return self._signature(self._state)[0]

    # -- mutations -----------------------------------------------------------

    def _publish(self, new_state: RetrieverState) -> None:
        self._state = new_state       # atomic reference swap
        self._record_signature()

    def add(self, delta: Corpus, *, doc_ids=None) -> None:
        with self._mutate_lock:
            self._publish(self.retriever.add(self._state, delta,
                                             doc_ids=doc_ids))

    def delete(self, doc_ids) -> None:
        with self._mutate_lock:
            self._publish(self.retriever.delete(self._state, doc_ids))

    def compact(self) -> None:
        with self._mutate_lock:
            self._publish(self.retriever.compact(self._state))

    # -- serving passthrough -------------------------------------------------

    def query(self, q_emb, q_mask, q_sal, timeout: float = 30.0, *,
              deadline_ms=None, slo="interactive"):
        return self.server.query(q_emb, q_mask, q_sal, timeout=timeout,
                                 deadline_ms=deadline_ms, slo=slo)

    def submit(self, q_emb, q_mask, q_sal, *, deadline_ms=None,
               slo="interactive"):
        return self.server.submit(q_emb, q_mask, q_sal,
                                  deadline_ms=deadline_ms, slo=slo)

    def warm_shapes(self, q_emb, q_mask, q_sal, rungs=None,
                    levels=None) -> None:
        self.server.warm_shapes(q_emb, q_mask, q_sal, rungs, levels)

    def stats(self) -> Dict[str, Any]:
        return self.server.stats()

    def recompile_report(self) -> Optional[Dict[str, Any]]:
        return self.server.recompile_report()

    def build_stats(self) -> Dict[str, float]:
        return self.retriever.build_stats(self._state)

    def close(self) -> None:
        self.server.close()
