"""Distribution layer: logical-axis sharding rules + collective helpers.

`sharding.py` maps *logical* axis names ("batch", "mlp", "corpus", ...)
onto physical mesh axes ("pod", "data", "model") with divisibility and
conflict fallbacks, so model code never hardcodes a mesh topology.
`collectives.py` holds hand-rolled collective schedules (ring all-gather
matmul) used where XLA's default SPMD partitioning is not the schedule we
want.
"""

from repro.dist import collectives, sharding  # noqa: F401
