"""Hand-scheduled collectives.

`ring_allgather_matmul` overlaps an all-gather of the weight shards with
the partial matmuls that consume them (the classic ring schedule: at step
i every device multiplies against the weight block it currently holds and
simultaneously passes it to its left neighbour). On TPU the jnp body is
replaced by the Pallas ring-DMA kernel (see /opt guides "Ring
Collectives"); this shard_map + ppermute formulation is the portable
reference schedule that XLA lowers to collective-permute, and is what the
multi-device CPU tests exercise.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def ring_allgather_matmul(mesh: Mesh, axis_name: str):
    """Build f(x, w) = x @ w with a ring-pipelined weight all-gather.

    x (M, K) is sharded over rows, w (K, N) over columns of `axis_name`;
    each of the `n` steps computes one (M/n, N/n) output block while the
    w block moves one hop around the ring, so no device ever materialises
    the full weight. Falls back to a plain matmul when M or N don't tile
    over the axis.
    """
    n = mesh.shape[axis_name]

    def f(x: jax.Array, w: jax.Array) -> jax.Array:
        m, _ = x.shape
        _, p = w.shape
        if n == 1 or m % n != 0 or p % n != 0:
            return x @ w
        blk_p = p // n
        out_dtype = jnp.result_type(x.dtype, w.dtype)
        # after i hops, device d holds w block (d + i) % n
        shift_left = [((j + 1) % n, j) for j in range(n)]

        @partial(shard_map, mesh=mesh,
                 in_specs=(P(axis_name, None), P(None, axis_name)),
                 out_specs=P(axis_name, None))
        def run(x_blk, w_blk):
            my = jax.lax.axis_index(axis_name)

            def step(i, carry):
                out, w_cur = carry
                col = (my + i) % n
                part = (x_blk @ w_cur).astype(out_dtype)
                out = jax.lax.dynamic_update_slice(out, part, (0, col * blk_p))
                w_cur = jax.lax.ppermute(w_cur, axis_name, shift_left)
                return out, w_cur

            out0 = jnp.zeros((x_blk.shape[0], p), out_dtype)
            out, _ = jax.lax.fori_loop(0, n, step, (out0, w_blk))
            return out

        return run(x, w)

    return f
