"""Logical-axis sharding: resolve logical dim names to mesh PartitionSpecs.

Model code annotates every tensor dim with a *logical* name ("batch",
"mlp", "corpus", ...). `DEFAULT_RULES` maps each logical name to an
ordered tuple of *physical* mesh axes it may shard over. `Sharder.resolve`
turns a logical spec + concrete shape into a `PartitionSpec` with three
fallbacks, applied per dim in order:

  1. missing axes — rule axes not present in the mesh are skipped silently
     (the same model code runs on a 1-pod ("data","model") mesh and a
     multi-pod ("pod","data","model") mesh);
  2. conflicts — a mesh axis already claimed by an earlier dim of the same
     tensor is dropped (a tensor cannot use one mesh axis twice);
  3. divisibility — axes are dropped from the *right* of the rule until the
     dim size divides the product of the remaining axis sizes (never
     produce an uneven shard; replicate instead).

The resolver is pure shape arithmetic: it needs axis *sizes* only, so it
works under `jax.eval_shape` and on fake meshes in tests.

`NULL` is the no-mesh singleton: `shd=NULL` turns every constraint into a
no-op so the same model code runs unsharded (single device, unit tests).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical dim name -> ordered mesh axes it may shard over. Order matters:
# divisibility drops from the right, so put the "most essential" axis first.
# Only axes that exist in the production meshes may appear here
# (tests/test_sharding.py pins the set to {"pod", "data", "model"}).
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    # data-parallel-ish dims
    "batch": ("pod", "data"),
    "nodes": ("pod", "data"),
    "edge": ("pod", "data"),
    "tokens": ("pod", "data"),
    # fan-out dims that may take the whole mesh
    "candidate": ("pod", "data", "model"),
    "corpus": ("pod", "data", "model"),
    # tensor-parallel dims
    "mlp": ("model",),
    "vocab": ("model",),
    "qkv_out": ("model",),
    "kv_out": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "seq_sp": ("model",),
    "expert": ("model",),
    "table_rows": ("model",),
    # contracting / replicated dims
    "embed": (),
    "expert_mlp": (),
    "kv_seq": (),
}


def is_logical_spec(x) -> bool:
    """True for a plain tuple of logical dim names (str) / None.

    NamedTuple pytree nodes (whose fields are themselves specs) and tuples
    holding non-str entries are *not* logical specs — this is the `is_leaf`
    predicate used when tree-mapping spec trees against parameter trees.
    """
    return (type(x) is tuple
            and all(e is None or isinstance(e, str) for e in x))


class Sharder:
    """Resolves logical specs against one concrete mesh."""

    def __init__(self, mesh: Mesh, rules: Optional[Dict[str, Tuple[str, ...]]]
                 = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES if rules is None else rules)
        self._sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    # -- core resolution ----------------------------------------------------

    def _axes_for(self, name: Optional[str], dim: int, used: set
                  ) -> Tuple[Tuple[str, ...], int]:
        """Mesh axes a single dim shards over, after all three fallbacks.

        Returns (kept_axes, n_present) where n_present is the number of
        rule axes that exist in this mesh (it decides the spec entry form:
        a bare string for single-axis rules, a tuple for multi-axis ones).
        """
        if name is None:
            return (), 0
        rule = self.rules.get(name, ())
        present = tuple(a for a in rule if a in self._sizes)
        kept = [a for a in present if a not in used]
        # drop from the right until the dim divides the shard product
        while kept:
            prod = 1
            for a in kept:
                prod *= self._sizes[a]
            if dim % prod == 0:
                break
            kept.pop()
        return tuple(kept), len(present)

    def resolve(self, spec: Tuple[Optional[str], ...],
                shape: Tuple[int, ...]) -> P:
        """Logical spec + shape -> PartitionSpec on this mesh."""
        assert len(spec) == len(shape), (spec, shape)
        used: set = set()
        entries = []
        for name, dim in zip(spec, shape):
            kept, n_present = self._axes_for(name, dim, used)
            used.update(kept)
            if not kept:
                entries.append(None)
            elif n_present == 1:
                entries.append(kept[0])
            else:
                entries.append(kept)
        return P(*entries)

    # -- conveniences -------------------------------------------------------

    def named(self, spec: Tuple[Optional[str], ...],
              shape: Tuple[int, ...]) -> NamedSharding:
        return NamedSharding(self.mesh, self.resolve(spec, shape))

    def constraint(self, x: jax.Array, *spec: Optional[str]) -> jax.Array:
        """with_sharding_constraint under the resolved spec (jit-side)."""
        return jax.lax.with_sharding_constraint(
            x, self.named(tuple(spec), x.shape))

    def num_shards(self, name: str, dim: int) -> int:
        """How many ways a dim of this size/logical name actually shards."""
        kept, _ = self._axes_for(name, dim, set())
        prod = 1
        for a in kept:
            prod *= self._sizes[a]
        return prod


class _NullSharder:
    """Mesh-less stand-in: every operation is the identity / replicated.

    The default `shd=NULL` argument of model code — lets the exact same
    forward functions run unsharded in unit tests and on one device.
    """

    mesh = None
    rules: Dict[str, Tuple[str, ...]] = {}

    def resolve(self, spec, shape) -> P:
        return P(*([None] * len(spec)))

    def named(self, spec, shape):
        raise ValueError("NULL sharder has no mesh — use a real Sharder")

    def constraint(self, x, *spec):
        return x

    def num_shards(self, name, dim) -> int:
        return 1


NULL = _NullSharder()
