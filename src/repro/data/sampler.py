"""GNN neighbour sampler (GraphSAGE-style fanout sampling, host-side numpy).

Required by the pna `minibatch_lg` cell (Reddit-scale graph, batch_nodes=1024,
fanout 15-10): builds a CSR adjacency once, then per batch samples a 2-hop
subgraph with *static* output shapes (padded) so the jitted train step never
recompiles. Runs on the host thread of the data pipeline; numpy only.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray      # (N+1,)
    indices: np.ndarray     # (E,)
    feats: np.ndarray       # (N, d)
    labels: np.ndarray      # (N,)

    @property
    def n_nodes(self) -> int:
        return self.indptr.shape[0] - 1


def build_csr(n_nodes: int, edge_index: np.ndarray, feats: np.ndarray,
              labels: np.ndarray) -> CSRGraph:
    src, dst = edge_index
    order = np.argsort(src, kind="stable")
    src_s, dst_s = src[order], dst[order]
    counts = np.bincount(src_s, minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSRGraph(indptr, dst_s.astype(np.int32), feats, labels)


def sample_subgraph(rng: np.random.Generator, g: CSRGraph, seeds: np.ndarray,
                    fanouts: Tuple[int, ...]) -> Dict[str, np.ndarray]:
    """Fanout-sample a subgraph rooted at `seeds`.

    Returns statically-shaped arrays:
      feats      (n_max, d)    — local node features (padded w/ zeros)
      edge_index (2, e_max)    — local ids; padding edges point to node 0
                                 with src == dst == n_valid-slot (masked by
                                 label -1 so they only add zero messages)
      labels     (n_max,)      — -1 for non-seed / padding nodes
    with n_max = sum over hops of prod(fanouts[:h]) * len(seeds) and
    e_max = the matching edge budget. Deduplication keeps the first
    occurrence (standard GraphSAGE behaviour).
    """
    layer_nodes = [seeds.astype(np.int64)]
    edges_src, edges_dst = [], []
    frontier = seeds.astype(np.int64)
    for f in fanouts:
        deg = g.indptr[frontier + 1] - g.indptr[frontier]
        # sample f neighbours with replacement (degree 0 -> self loop)
        offs = (rng.random((frontier.shape[0], f))
                * np.maximum(deg, 1)[:, None]).astype(np.int64)
        nbrs = g.indices[np.minimum(g.indptr[frontier][:, None] + offs,
                                    len(g.indices) - 1)]
        nbrs = np.where(deg[:, None] > 0, nbrs, frontier[:, None])
        edges_src.append(nbrs.ravel())                    # neighbour -> node
        edges_dst.append(np.repeat(frontier, f))
        frontier = nbrs.ravel()
        layer_nodes.append(frontier)

    all_nodes = np.concatenate(layer_nodes)
    uniq, local = np.unique(all_nodes, return_inverse=True)
    n_pos = 0
    # map global -> local
    lookup = {int(n): i for i, n in enumerate(uniq)}
    src = np.concatenate(edges_src)
    dst = np.concatenate(edges_dst)
    src_l = np.fromiter((lookup[int(x)] for x in src), np.int32, len(src))
    dst_l = np.fromiter((lookup[int(x)] for x in dst), np.int32, len(dst))

    # static budgets
    n_max = sum(len(x) for x in layer_nodes)
    e_max = len(src)
    feats = np.zeros((n_max, g.feats.shape[1]), g.feats.dtype)
    feats[:len(uniq)] = g.feats[uniq]
    labels = np.full((n_max,), -1, np.int32)
    seed_local = np.fromiter((lookup[int(s)] for s in seeds), np.int32,
                             len(seeds))
    labels[seed_local] = g.labels[seeds]
    edge_index = np.stack([src_l, dst_l]).astype(np.int32)
    return {"feats": feats, "edge_index": edge_index, "labels": labels}
