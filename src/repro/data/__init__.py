"""Data substrate: synthetic corpora, GNN neighbour sampler, host pipeline."""

from repro.data import pipeline, sampler, synthetic  # noqa: F401
