"""Synthetic data generators with *planted structure*.

No datasets ship in this container (docs/design.md §1), so every benchmark runs
on controlled synthetic data where the quantities the paper measures are
well-defined:

  * `make_retrieval_corpus` — patch corpora with topic structure and graded
    relevance (3/2/1/0) so nDCG@10 / Recall@10 / MAP differences between
    ColPali-Full, PQ-Only, HPC and DistilCol are meaningful (stands in for
    ViDoRe / SEC-Filings; two presets differ in topic count, patch count
    and noise to mimic the two datasets' difficulty gap);
  * `make_fact_corpus` — RAG corpus where each document carries an explicit
    fact set; hallucination (generated fact not present in retrieved
    context) is *exactly* measurable;
  * `make_lm_batch` — order-2 Markov token streams (learnable: loss drops
    well below ln(V));
  * `make_graph` / `make_molecule_batch` — Cora-like graphs + batched small
    graphs with community-correlated labels;
  * `make_recsys_batch` — CTR batches with a planted logistic teacher.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Multi-vector retrieval corpus (paper Tables I/II)
# ---------------------------------------------------------------------------

class RetrievalData(NamedTuple):
    doc_patches: Array     # (N, Md, D) float32
    doc_mask: Array        # (N, Md) bool
    doc_salience: Array    # (N, Md) float32 — synthetic attention salience
    doc_topic: Array       # (N,) int32
    query_patches: Array   # (Q, Mq, D)
    query_mask: Array      # (Q, Mq) bool
    query_salience: Array  # (Q, Mq)
    relevance: Array       # (Q, N) int32 graded 0..3


@dataclasses.dataclass(frozen=True)
class CorpusSpec:
    n_docs: int = 2048
    n_queries: int = 128
    n_patches: int = 32        # Md (paper: ~50/doc)
    n_q_patches: int = 8       # Mq
    dim: int = 128             # D (paper: 128)
    n_topics: int = 32
    patches_per_topic: int = 64
    noise: float = 0.25        # patch noise (higher -> harder corpus)
    salient_frac: float = 0.5  # fraction of patches that carry signal
    dup_per_doc: int = 3       # graded-relevant near-duplicates per query


# Presets standing in for the paper's two datasets: ViDoRe (academic pages,
# more visual variety -> more topics/prototypes, noisier) vs SEC-Filings
# (templated financial docs -> fewer topics, cleaner). Prototype counts are
# kept in the K-Means-coverable regime (topics x patches_per_topic ~ K..2K)
# because the paper's premise is that real ColPali patch embeddings are
# highly clusterable (<2% nDCG loss at K=256); STRESS below deliberately
# exceeds codebook capacity — the failure-mode ablation the paper lacks
# (EXPERIMENTS.md §Quality).
VIDORE = CorpusSpec(n_docs=2048, n_queries=128, n_topics=24,
                    patches_per_topic=10, noise=0.20, salient_frac=0.4)
SEC_FILINGS = CorpusSpec(n_docs=2048, n_queries=128, n_topics=16,
                         patches_per_topic=10, noise=0.15, salient_frac=0.4)
STRESS = CorpusSpec(n_docs=2048, n_queries=128, n_topics=48,
                    patches_per_topic=64, noise=0.30)


def make_retrieval_corpus(key: Array, spec: CorpusSpec) -> RetrievalData:
    """Build a corpus with planted graded relevance.

    Structure: each topic owns a bank of patch prototypes. A document
    samples patches from its topic bank (salient patches) mixed with
    background patches (non-salient). A query is built from a target doc's
    *salient* patches + noise. Relevance: target doc = 3, its near-duplicates
    (same prototype subset) = 2, same-topic docs = 1, rest 0.
    """
    ks = iter(jax.random.split(key, 12))
    t_centers = jax.random.normal(next(ks), (spec.n_topics, spec.dim))
    banks = (t_centers[:, None, :]
             + 0.7 * jax.random.normal(
                 next(ks), (spec.n_topics, spec.patches_per_topic, spec.dim)))

    n, md, d = spec.n_docs, spec.n_patches, spec.dim
    # group documents: target groups of (1 + dup_per_doc) near-duplicates
    group = jnp.arange(n) // (1 + spec.dup_per_doc)
    topic = group % spec.n_topics

    # per-group prototype subset (salient patches share prototypes in-group)
    n_sal = max(1, int(md * spec.salient_frac))
    proto_idx = jax.random.randint(
        next(ks), (n // (1 + spec.dup_per_doc) + 1, n_sal),
        0, spec.patches_per_topic)
    doc_proto = proto_idx[group]                               # (N, n_sal)
    sal_patches = banks[topic[:, None], doc_proto]             # (N, n_sal, D)
    bg_topic = jax.random.randint(next(ks), (n, md - n_sal), 0, spec.n_topics)
    bg_proto = jax.random.randint(next(ks), (n, md - n_sal), 0,
                                  spec.patches_per_topic)
    bg_patches = banks[bg_topic, bg_proto]                     # (N, md-n_sal, D)
    patches = jnp.concatenate([sal_patches, bg_patches], axis=1)
    patches = patches + spec.noise * jax.random.normal(next(ks), patches.shape)
    # L2 normalise (ColPali embeddings are normalised)
    patches = patches / jnp.linalg.norm(patches, axis=-1, keepdims=True)

    # synthetic attention salience: salient patches high, background low
    sal = jnp.concatenate([
        0.8 + 0.2 * jax.random.uniform(next(ks), (n, n_sal)),
        0.2 * jax.random.uniform(next(ks), (n, md - n_sal))], axis=1)
    mask = jnp.ones((n, md), bool)

    # queries from target docs (the first doc of each group)
    q_target = (jnp.arange(spec.n_queries)
                * (1 + spec.dup_per_doc)) % n                  # (Q,)
    mq = spec.n_q_patches
    pick = jax.random.randint(next(ks), (spec.n_queries, mq), 0, n_sal)
    q_patches = patches[q_target[:, None], pick]               # (Q, mq, D)
    q_patches = q_patches + spec.noise * jax.random.normal(
        next(ks), q_patches.shape)
    q_patches = q_patches / jnp.linalg.norm(q_patches, axis=-1, keepdims=True)
    q_sal = 0.5 + 0.5 * jax.random.uniform(next(ks), (spec.n_queries, mq))
    q_mask = jnp.ones((spec.n_queries, mq), bool)

    # graded relevance
    same_group = group[None, :] == group[q_target][:, None]    # (Q, N)
    same_topic = topic[None, :] == topic[q_target][:, None]
    is_target = jnp.arange(n)[None, :] == q_target[:, None]
    rel = (is_target.astype(jnp.int32) * 3
           + (same_group & ~is_target).astype(jnp.int32) * 2
           + (same_topic & ~same_group).astype(jnp.int32) * 1)
    return RetrievalData(patches.astype(jnp.float32), mask,
                         sal.astype(jnp.float32), topic.astype(jnp.int32),
                         q_patches.astype(jnp.float32), q_mask,
                         q_sal.astype(jnp.float32), rel)


# ---------------------------------------------------------------------------
# RAG fact corpus (paper Table V)
# ---------------------------------------------------------------------------

class FactCorpus(NamedTuple):
    doc_patches: Array     # (N, Md, D)
    doc_mask: Array
    doc_salience: Array
    doc_facts: Array       # (N, F) int32 fact ids carried by each doc
    doc_tokens: Array      # (N, Ld) int32 generator-side rendering
    query_tokens: Array    # (Q, Lq) int32
    query_patches: Array   # (Q, Mq, D) retriever-side rendering
    query_mask: Array
    query_salience: Array
    gold_doc: Array        # (Q,) the doc answering each query
    gold_facts: Array      # (Q, F) reference facts (= gold doc's facts)


def make_fact_corpus(key: Array, n_docs: int = 256, n_facts_vocab: int = 200,
                     facts_per_doc: int = 4, dim: int = 64,
                     n_patches: int = 16, n_queries: int = 64,
                     seq_len: int = 32) -> Tuple[FactCorpus, Dict[str, int]]:
    """Legal-summarisation stand-in where hallucination is measurable.

    Token layout: [0] PAD, [1] SEP, [2] QUERY-marker,
    [3 .. 3+n_facts_vocab) fact tokens. A doc's tokens are its fact tokens;
    a query asks (via the QUERY marker + one probe fact token) for the doc
    containing that fact; the reference summary is the gold doc's fact set.
    """
    vocab = {"pad": 0, "sep": 1, "query": 2, "fact0": 3,
             "size": 3 + n_facts_vocab}
    ks = iter(jax.random.split(key, 10))

    # each fact id has a patch-space prototype: retrieval is fact matching
    fact_proto = jax.random.normal(next(ks), (n_facts_vocab, dim))
    doc_facts = jax.random.randint(next(ks), (n_docs, facts_per_doc),
                                   0, n_facts_vocab)
    # patches: facts repeated + noise
    reps = n_patches // facts_per_doc
    pat_f = jnp.repeat(doc_facts, reps, axis=1)[:, :n_patches]
    patches = fact_proto[pat_f] + 0.15 * jax.random.normal(
        next(ks), (n_docs, n_patches, dim))
    patches = patches / jnp.linalg.norm(patches, axis=-1, keepdims=True)
    sal = jnp.ones((n_docs, n_patches), jnp.float32)
    mask = jnp.ones((n_docs, n_patches), bool)

    # generator-side doc tokens: fact tokens separated by SEP, padded
    dt = jnp.full((n_docs, seq_len), vocab["pad"], jnp.int32)
    dt = dt.at[:, :facts_per_doc].set(doc_facts + vocab["fact0"])
    dt = dt.at[:, facts_per_doc].set(vocab["sep"])

    # queries: probe one fact of a gold doc
    gold_doc = jax.random.randint(next(ks), (n_queries,), 0, n_docs)
    probe_slot = jax.random.randint(next(ks), (n_queries,), 0, facts_per_doc)
    probe_fact = doc_facts[gold_doc, probe_slot]               # (Q,)
    qt = jnp.full((n_queries, 4), vocab["pad"], jnp.int32)
    qt = qt.at[:, 0].set(vocab["query"])
    qt = qt.at[:, 1].set(probe_fact + vocab["fact0"])
    qt = qt.at[:, 2].set(vocab["sep"])

    mq = 4
    q_patches = jnp.stack([fact_proto[probe_fact]] * mq, axis=1)
    q_patches = q_patches + 0.15 * jax.random.normal(next(ks), q_patches.shape)
    q_patches = q_patches / jnp.linalg.norm(q_patches, axis=-1, keepdims=True)

    fc = FactCorpus(
        patches.astype(jnp.float32), mask, sal, doc_facts.astype(jnp.int32),
        dt, qt, q_patches.astype(jnp.float32),
        jnp.ones((n_queries, mq), bool), jnp.ones((n_queries, mq)),
        gold_doc.astype(jnp.int32),
        doc_facts[gold_doc].astype(jnp.int32))
    return fc, vocab


# ---------------------------------------------------------------------------
# LM token streams (order-2 Markov chain — learnable)
# ---------------------------------------------------------------------------

def make_lm_batch(key: Array, vocab: int, batch: int, seq: int,
                  n_states: int = 64) -> Dict[str, Array]:
    k1, k2, k3 = jax.random.split(key, 3)
    # sparse-ish transition structure over a reduced state space
    trans = jax.random.dirichlet(k1, jnp.ones((4,)) * 0.5,
                                 (n_states, n_states))          # top-4 moves
    nxt = jax.random.randint(k2, (n_states, n_states, 4), 0, n_states)

    def gen(key):
        def step(carry, k):
            s1, s2 = carry
            p = trans[s1, s2]
            choice = jax.random.categorical(k, jnp.log(p + 1e-9))
            s3 = nxt[s1, s2, choice]
            return (s2, s3), s3
        ks = jax.random.split(key, seq + 1)
        init = (jnp.int32(0), jnp.int32(1))
        _, toks = jax.lax.scan(step, init, ks)
        return toks

    toks = jax.vmap(gen)(jax.random.split(k3, batch)) % vocab
    return {"tokens": toks[:, :seq].astype(jnp.int32),
            "targets": toks[:, 1:seq + 1].astype(jnp.int32)}


# ---------------------------------------------------------------------------
# Graphs
# ---------------------------------------------------------------------------

def make_graph(key: Array, n_nodes: int, n_edges: int, d_feat: int,
               n_classes: int, n_comm: int = 8) -> Dict[str, Array]:
    """Community-structured graph: labels correlate with communities and
    features correlate with labels (so PNA can learn)."""
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(key, 7)
    comm = jax.random.randint(k1, (n_nodes,), 0, n_comm)
    # intra-community edges (80%) + random (20%)
    n_intra = int(n_edges * 0.8)
    src_i = jax.random.randint(k2, (n_intra,), 0, n_nodes)
    # destination within same community: resample via sorting trick
    perm = jnp.argsort(comm)
    pos_of = jnp.argsort(perm)
    # neighbour in sorted order (same community w.h.p.)
    off = jax.random.randint(k3, (n_intra,), 1, 5)
    dst_i = perm[jnp.clip(pos_of[src_i] + off, 0, n_nodes - 1)]
    src_r = jax.random.randint(k4, (n_edges - n_intra,), 0, n_nodes)
    dst_r = jax.random.randint(k5, (n_edges - n_intra,), 0, n_nodes)
    src = jnp.concatenate([src_i, src_r])
    dst = jnp.concatenate([dst_i, dst_r])
    labels = comm % n_classes
    centers = jax.random.normal(k6, (n_classes, d_feat))
    feats = centers[labels] + 0.8 * jax.random.normal(k7, (n_nodes, d_feat))
    return {"feats": feats.astype(jnp.float32),
            "edge_index": jnp.stack([src, dst]).astype(jnp.int32),
            "labels": labels.astype(jnp.int32)}


def make_molecule_batch(key: Array, n_graphs: int, nodes_per: int,
                        edges_per: int, d_feat: int) -> Dict[str, Array]:
    k1, k2, k3 = jax.random.split(key, 3)
    n = n_graphs * nodes_per
    feats = jax.random.normal(k1, (n, d_feat))
    # ring + random chords per graph, offset per graph id
    base = jnp.arange(nodes_per)
    ring_src = jnp.tile(base, n_graphs)
    ring_dst = jnp.tile((base + 1) % nodes_per, n_graphs)
    off = jnp.repeat(jnp.arange(n_graphs) * nodes_per, nodes_per)
    extra = edges_per - nodes_per
    es = jax.random.randint(k2, (n_graphs, extra), 0, nodes_per)
    ed = jax.random.randint(k3, (n_graphs, extra), 0, nodes_per)
    goff = jnp.arange(n_graphs)[:, None] * nodes_per
    src = jnp.concatenate([ring_src + off, (es + goff).ravel()])
    dst = jnp.concatenate([ring_dst + off, (ed + goff).ravel()])
    graph_ids = jnp.repeat(jnp.arange(n_graphs), nodes_per)
    # label: does mean feature exceed 0 in dim 0 (learnable)
    pooled = jax.ops.segment_sum(feats[:, 0], graph_ids, num_segments=n_graphs)
    labels = (pooled > 0).astype(jnp.int32)
    return {"feats": feats.astype(jnp.float32),
            "edge_index": jnp.stack([src, dst]).astype(jnp.int32),
            "graph_ids": graph_ids.astype(jnp.int32),
            "graph_labels": labels}


# ---------------------------------------------------------------------------
# RecSys batches (planted logistic teacher)
# ---------------------------------------------------------------------------

def make_recsys_batch(key: Array, batch: int, n_dense: int,
                      table_rows, seq_len: int = 0,
                      family: str = "dlrm") -> Dict[str, Array]:
    ks = iter(jax.random.split(key, 8))
    if family in ("din", "dien"):
        n_items = table_rows[0]
        hist = jax.random.randint(next(ks), (batch, seq_len), 0, n_items)
        hl = jax.random.randint(next(ks), (batch,), seq_len // 2, seq_len + 1)
        mask = jnp.arange(seq_len)[None, :] < hl[:, None]
        target = jax.random.randint(next(ks), (batch,), 0, n_items)
        # planted signal: click if target shares low bits with history mode
        sig = (jnp.sum((hist % 7) * mask, axis=1) % 7) == (target % 7)
        noise = jax.random.bernoulli(next(ks), 0.1, (batch,))
        label = jnp.logical_xor(sig, noise).astype(jnp.float32)
        return {"hist_ids": hist.astype(jnp.int32), "hist_mask": mask,
                "target_ids": target.astype(jnp.int32), "label": label}
    dense = jax.random.normal(next(ks), (batch, n_dense))
    sparse = jnp.stack([jax.random.randint(next(ks), (batch,), 0, r)
                        for r in table_rows], axis=1)
    w = jax.random.normal(next(ks), (n_dense,))
    logit = dense @ w + 0.5 * jnp.sum((sparse % 5) - 2, axis=1) / len(table_rows)
    label = (jax.nn.sigmoid(logit)
             > jax.random.uniform(next(ks), (batch,))).astype(jnp.float32)
    return {"dense": dense.astype(jnp.float32),
            "sparse_ids": sparse.astype(jnp.int32), "label": label}
