"""Sharded host data pipeline with prefetch + straggler mitigation.

A background thread pulls batches from a (host, numpy/jnp) iterator into a
bounded queue and places them onto the mesh with the batch-axis sharding.
Straggler mitigation at the data layer (docs/design.md §4): if the producer
misses the `timeout_s` budget (slow storage shard / preprocessing straggler)
the consumer *re-serves the previous batch* and logs the event instead of
stalling the whole step — at 1000+ nodes a single slow input shard must not
idle the pod. Repeat-batch accounting is exposed in `stats`.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Dict, Iterator, Optional

import jax


class PrefetchPipeline:
    def __init__(self, batch_iter: Iterator[Any], *,
                 put_fn: Optional[Callable[[Any], Any]] = None,
                 depth: int = 2, timeout_s: float = 30.0):
        self._iter = batch_iter
        self._put = put_fn or (lambda x: x)
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._err: Optional[BaseException] = None
        self.stats = {"served": 0, "repeats": 0, "produced": 0}
        self._last = None
        self.timeout_s = timeout_s
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        try:
            for batch in self._iter:
                if self._stop.is_set():
                    return
                self._q.put(self._put(batch))
                self.stats["produced"] += 1
        except BaseException as e:  # surfaced on next __next__
            self._err = e
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        if self._err is not None:
            raise self._err
        try:
            batch = self._q.get(timeout=self.timeout_s)
        except queue.Empty:
            # Straggler: producer missed the deadline. Re-serve last batch.
            if self._last is None:
                batch = self._q.get()     # first batch: must wait
            else:
                self.stats["repeats"] += 1
                self.stats["served"] += 1
                return self._last
        if batch is None:
            if self._err is not None:
                raise self._err
            raise StopIteration
        self._last = batch
        self.stats["served"] += 1
        return batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


def device_put_batch(batch: Dict[str, Any], shardings: Dict[str, Any]):
    """Place a host batch onto the mesh with per-key shardings."""
    return {k: jax.device_put(v, shardings.get(k)) for k, v in batch.items()}
