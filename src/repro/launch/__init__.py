"""Launch entry points: mesh, dryrun, train, serve."""
