"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — "pod" is an
additional pure-DP axis across the inter-pod DCN/ICI links.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax init,
while tests/benchmarks see the real single CPU device.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape=(1, 1), axes=("data", "model")) -> Mesh:
    """Small mesh over whatever host devices exist (tests)."""
    return jax.make_mesh(shape, axes)


# TPU v5e hardware constants (roofline §Roofline)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (per-chip effective)
HBM_BYTES = 16 * 2 ** 30        # 16 GiB per chip
