"""Retrieval serving CLI: build an HPC-ColPali index over a synthetic
corpus and serve batched queries through the continuous-batching server.

  PYTHONPATH=src python -m repro.launch.serve --n-docs 4096 --queries 256 \
      --backend flat --k 256 --p 60

`--backend` names a registry backend (float_flat / flat / ivf / hamming);
the deprecated `--mode`/`--index` pair is still accepted.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core.index import IVFConfig
from repro.data import synthetic
from repro.retrieval import (Corpus, HPCConfig, Query, Retriever,
                             available_backends)
from repro.serving.server import RetrievalServer, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=4096)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--backend", default=None,
                    choices=list(available_backends()),
                    help="index backend (wins over --mode/--index)")
    ap.add_argument("--mode", default=None,
                    choices=["float", "quantized", "binary"],
                    help="deprecated: use --backend")
    ap.add_argument("--index", default=None, choices=["flat", "ivf"],
                    help="deprecated: use --backend")
    ap.add_argument("--k", type=int, default=256)
    ap.add_argument("--p", type=float, default=60.0)
    ap.add_argument("--top-k", type=int, default=10)
    ap.add_argument("--max-batch", type=int, default=8)
    args = ap.parse_args(argv)

    key = jax.random.PRNGKey(0)
    spec = synthetic.CorpusSpec(n_docs=args.n_docs, n_queries=args.queries)
    data = synthetic.make_retrieval_corpus(key, spec)

    backend = args.backend
    if backend is None and args.mode is None and args.index is None:
        backend = "flat"
    cfg = HPCConfig(k=args.k, p=args.p, backend=backend, mode=args.mode,
                    index=args.index, prune_side="doc", rerank=32,
                    ivf=IVFConfig(n_list=64, n_probe=8))
    retriever = Retriever(cfg)

    t0 = time.perf_counter()
    state = retriever.build(key, Corpus(data.doc_patches, data.doc_mask,
                                        data.doc_salience))
    jax.block_until_ready(state.codebook)
    print(f"index[{cfg.backend}] built in {time.perf_counter()-t0:.2f}s | "
          f"storage {retriever.storage_bytes(state)}")

    @jax.jit
    def search(q, qm, qs):
        return retriever.search(state, Query(q, qm, qs), k=args.top_k)

    server = RetrievalServer(search, ServeConfig(max_batch=args.max_batch,
                                                 top_k=args.top_k))
    # warmup compile (excluded from the serving-window stats)
    server.query(data.query_patches[0], data.query_mask[0],
                 data.query_salience[0])
    server.reset_stats()

    hits = 0
    t0 = time.perf_counter()
    results = []
    for i in range(args.queries):
        results.append(server.submit(data.query_patches[i],
                                     data.query_mask[i],
                                     data.query_salience[i]))
    for i, r in enumerate(results):
        r.event.wait(30)
        scores, ids = r.result
        rel = np.asarray(data.relevance[i])
        hits += int((rel[ids] > 0).any())
    wall = time.perf_counter() - t0
    st = server.stats()
    print(f"served {args.queries} queries in {wall:.2f}s "
          f"({st['qps']:.1f} QPS) | hit@{args.top_k} "
          f"{hits/args.queries:.3f} | p50 {st['p50_ms']:.1f}ms "
          f"p99 {st['p99_ms']:.1f}ms | mean batch {st['mean_batch']:.1f}")
    server.close()


if __name__ == "__main__":
    main()
