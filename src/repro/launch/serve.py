"""Retrieval serving CLI: build an HPC-ColPali index over a synthetic
corpus and serve batched queries through the asyncio continuous-batching
server (power-of-two padding ladder).

  PYTHONPATH=src python -m repro.launch.serve --n-docs 4096 --queries 256 \
      --backend flat --k 256 --p 60

`--backend` names a registry backend (float_flat / flat / ivf / hnsw /
hamming);
the deprecated `--mode`/`--index` pair is still accepted. `--rate-qps`
switches from closed-loop (submit everything at once) to an open-loop
Poisson arrival process; `--single-shape` disables the padding ladder
(v1 behaviour: every batch pads to --max-batch).
"""
from __future__ import annotations

import argparse
import asyncio
import time

import jax
import numpy as np

from repro.core.index import IVFConfig
from repro.data import synthetic
from repro.retrieval import (Corpus, HPCConfig, Query, Retriever,
                             available_backends)
from repro.serving.client import drive
from repro.serving.server import AsyncRetrievalServer, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=4096)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--backend", default=None,
                    choices=list(available_backends()),
                    help="index backend (wins over --mode/--index)")
    ap.add_argument("--mode", default=None,
                    choices=["float", "quantized", "binary"],
                    help="deprecated: use --backend")
    ap.add_argument("--index", default=None, choices=["flat", "ivf"],
                    help="deprecated: use --backend")
    ap.add_argument("--k", type=int, default=256)
    ap.add_argument("--p", type=float, default=60.0)
    ap.add_argument("--top-k", type=int, default=10)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--rate-qps", type=float, default=0.0,
                    help="open-loop Poisson arrival rate (0 = closed loop)")
    ap.add_argument("--single-shape", action="store_true",
                    help="v1 behaviour: pad every batch to --max-batch")
    args = ap.parse_args(argv)

    k_data, k_build = jax.random.split(jax.random.PRNGKey(0))
    spec = synthetic.CorpusSpec(n_docs=args.n_docs, n_queries=args.queries)
    data = synthetic.make_retrieval_corpus(k_data, spec)

    backend = args.backend
    if backend is None and args.mode is None and args.index is None:
        backend = "flat"
    cfg = HPCConfig(k=args.k, p=args.p, backend=backend, mode=args.mode,
                    index=args.index, prune_side="doc", rerank=32,
                    ivf=IVFConfig(n_list=64, n_probe=8))
    retriever = Retriever(cfg)

    t0 = time.perf_counter()
    state = retriever.build(k_build, Corpus(data.doc_patches, data.doc_mask,
                                            data.doc_salience))
    jax.block_until_ready(state.codebook)
    print(f"index[{cfg.backend}] built in {time.perf_counter()-t0:.2f}s | "
          f"storage {retriever.storage_bytes(state)}")

    @jax.jit
    def search(q, qm, qs):
        return retriever.search(state, Query(q, qm, qs), k=args.top_k)

    ladder = (args.max_batch,) if args.single_shape else None
    server = AsyncRetrievalServer(
        search, ServeConfig(max_batch=args.max_batch, top_k=args.top_k,
                            ladder=ladder))
    # pre-compile every ladder rung (excluded from the serving-window stats)
    t0 = time.perf_counter()
    server.warm_shapes(data.query_patches[0], data.query_mask[0],
                       data.query_salience[0])
    print(f"ladder {server.ladder} warmed in {time.perf_counter()-t0:.2f}s")

    async def _serve():
        t0 = time.perf_counter()
        results = await drive(server, data.query_patches, data.query_mask,
                              data.query_salience, n_requests=args.queries,
                              rate_qps=args.rate_qps, seed=1)
        wall = time.perf_counter() - t0
        await server.aclose()
        return results, wall

    results, wall = asyncio.run(_serve())
    hits = 0
    for i, (scores, ids) in enumerate(results):
        rel = np.asarray(data.relevance[i])
        hits += int((rel[ids] > 0).any())
    st = server.stats()
    rungs = " ".join(f"B={b}:{v['batches']}x@{v['occupancy']:.2f}"
                     for b, v in st["rungs"].items())
    print(f"served {args.queries} queries in {wall:.2f}s "
          f"({st['qps']:.1f} QPS) | hit@{args.top_k} "
          f"{hits/args.queries:.3f} | p50 {st['p50_ms']:.1f}ms "
          f"p99 {st['p99_ms']:.1f}ms | mean batch {st['mean_batch']:.1f}")
    print(f"ladder occupancy: {rungs}")


if __name__ == "__main__":
    main()
