"""Retrieval serving CLI: build an HPC-ColPali index over a synthetic
corpus and serve batched queries through the continuous-batching server.

  PYTHONPATH=src python -m repro.launch.serve --n-docs 4096 --queries 256 \
      --mode quantized --k 256 --p 60
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pipeline as hpc
from repro.core.index import IVFConfig
from repro.data import synthetic
from repro.serving.server import RetrievalServer, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=4096)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--mode", default="quantized",
                    choices=["float", "quantized", "binary"])
    ap.add_argument("--index", default="flat", choices=["flat", "ivf"])
    ap.add_argument("--k", type=int, default=256)
    ap.add_argument("--p", type=float, default=60.0)
    ap.add_argument("--top-k", type=int, default=10)
    ap.add_argument("--max-batch", type=int, default=8)
    args = ap.parse_args(argv)

    key = jax.random.PRNGKey(0)
    spec = synthetic.CorpusSpec(n_docs=args.n_docs, n_queries=args.queries)
    data = synthetic.make_retrieval_corpus(key, spec)

    cfg = hpc.HPCConfig(k=args.k, p=args.p, mode=args.mode, index=args.index,
                        prune_side="doc", rerank=32,
                        ivf=IVFConfig(n_list=64, n_probe=8))
    t0 = time.perf_counter()
    index = hpc.build_index(key, data.doc_patches, data.doc_mask,
                            data.doc_salience, cfg)
    jax.block_until_ready(index.codebook)
    print(f"index built in {time.perf_counter()-t0:.2f}s | "
          f"storage {hpc.storage_bytes(index, cfg)}")

    mq = data.query_patches.shape[1]

    @jax.jit
    def search(q, qm, qs):
        return hpc.query(index, q, qm, qs, cfg, k=args.top_k)

    server = RetrievalServer(search, ServeConfig(max_batch=args.max_batch,
                                                 top_k=args.top_k))
    # warmup compile
    server.query(data.query_patches[0], data.query_mask[0],
                 data.query_salience[0])

    hits = 0
    t0 = time.perf_counter()
    results = []
    for i in range(args.queries):
        results.append(server.submit(data.query_patches[i],
                                     data.query_mask[i],
                                     data.query_salience[i]))
    for i, r in enumerate(results):
        r.event.wait(30)
        scores, ids = r.result
        rel = np.asarray(data.relevance[i])
        hits += int((rel[ids] > 0).any())
    wall = time.perf_counter() - t0
    st = server.stats()
    print(f"served {args.queries} queries in {wall:.2f}s "
          f"({args.queries/wall:.1f} QPS) | hit@{args.top_k} "
          f"{hits/args.queries:.3f} | p50 {st['p50_ms']:.1f}ms "
          f"p99 {st['p99_ms']:.1f}ms | mean batch {st['mean_batch']:.1f}")
    server.close()


if __name__ == "__main__":
    main()
