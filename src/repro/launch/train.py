"""Training CLI.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 50 --batch 8 --seq 128

Runs the family's real train_step through the fault-tolerant loop
(checkpoint/restart, NaN guard, straggler watchdog) on whatever devices
exist. Production meshes are exercised via launch/dryrun.py; this driver is
for end-to-end runnable training (examples/ use it with ~100M configs).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.data import synthetic
from repro.data.pipeline import PrefetchPipeline
from repro.models import colpali as colpali_mod
from repro.models import gnn as gnn_mod
from repro.models import recsys as recsys_mod
from repro.models import transformer as T
from repro.optim import optimizer as opt
from repro.train import loop as train_loop


def batch_stream(make_batch, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    while True:
        key, sub = jax.random.split(key)
        yield make_batch(sub)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args(argv)

    spec = registry.get(args.arch)
    cfg = spec.smoke_config if args.smoke else spec.config
    k_init, k_data = jax.random.split(jax.random.PRNGKey(0))
    ocfg = opt.AdamWConfig(lr=args.lr, total_steps=args.steps,
                           warmup_steps=max(1, args.steps // 10))

    if spec.family == "lm":
        params = T.init(k_init, cfg)
        opt_state = opt.init(ocfg, params)
        step = jax.jit(lambda p, o, b: T.train_step(p, o, b, cfg, ocfg))
        mk = lambda k: synthetic.make_lm_batch(k, cfg.vocab, args.batch,
                                               args.seq)
    elif spec.family == "gnn":
        cfg2 = cfg
        # exclusive elif branch: k_init consumed once per run
        params = gnn_mod.init(k_init, cfg2)  # noqa: JAX01
        opt_state = opt.init(ocfg, params)
        step = jax.jit(lambda p, o, b: gnn_mod.train_step(p, o, b, cfg2,
                                                          ocfg))
        g = synthetic.make_graph(k_data, 512, 2048, cfg2.d_feat,
                                 cfg2.n_classes)
        mk = lambda k: g
    elif spec.family == "recsys":
        # exclusive elif branch: k_init consumed once per run
        params = recsys_mod.init(k_init, cfg)  # noqa: JAX01
        opt_state = opt.init(ocfg, params)
        step = jax.jit(lambda p, o, b: recsys_mod.train_step(p, o, b, cfg,
                                                             ocfg))
        mk = lambda k: synthetic.make_recsys_batch(
            k, args.batch, cfg.n_dense, cfg.table_rows,
            seq_len=cfg.seq_len, family=cfg.family)
    else:  # colpali
        enc = cfg.encoder
        # exclusive elif branch: k_init consumed once per run
        params = colpali_mod.init(k_init, enc)  # noqa: JAX01
        opt_state = opt.init(ocfg, params)
        step = jax.jit(lambda p, o, b: colpali_mod.train_step(p, o, b, enc,
                                                              ocfg))
        def mk(k):
            ks = jax.random.split(k, 2)
            return {
                "query_tokens": jax.random.randint(
                    ks[0], (args.batch, enc.query_len), 0,
                    enc.backbone.vocab),
                "query_mask": jnp.ones((args.batch, enc.query_len), bool),
                "doc_patches": jax.random.normal(
                    ks[1], (args.batch, enc.n_patches, enc.d_patch)),
                "doc_mask": jnp.ones((args.batch, enc.n_patches), bool),
            }

    pipe = PrefetchPipeline(batch_stream(mk), depth=2)
    loop_cfg = train_loop.LoopConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir, log_every=max(1, args.steps // 10))
    out = train_loop.run(step, params, opt_state, pipe, loop_cfg)
    pipe.close()
    print(f"final loss {out['history'][-1]['loss']:.4f} | "
          f"stats {out['stats']} | pipeline {pipe.stats}")
    return out


if __name__ == "__main__":
    main()
