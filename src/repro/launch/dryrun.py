import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). Everything below is ordinary code.

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract the roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out benchmarks/results/dryrun.json

Per cell it records: compiled memory_analysis (bytes/device), HLO flops &
bytes accessed from cost_analysis (per device), per-collective byte counts
parsed from the post-SPMD HLO (operand sizes, per device), MODEL_FLOPS
metadata, and lower/compile wall times. Failures (sharding mismatch,
unsupported collective) are bugs — the run exits non-zero listing them.
"""
import argparse
import json
import re
import time
import traceback
from typing import Any, Dict

import jax

jax.config.update("jax_compilation_cache_dir", "/tmp/repro_jax_cache")
jax.config.update("jax_persistent_cache_min_compile_time_secs", 10)

from repro.configs import registry
from repro.launch import cells as cells_mod
from repro.launch.mesh import (HBM_BW, HBM_BYTES, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|"
                       r"pred|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")

# Line shape: `%name = <type-or-tuple> <collective>(%operands...), ...`
_COLL_RE = re.compile(r"=\s+(.*?)\s+(" + "|".join(COLLECTIVES) + r")\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device link traffic from post-SPMD HLO collectives.

    Post-optimization HLO operands carry no inline types, so we read each
    collective's *output* type (tuple types included) and apply the ring
    cost model: all-reduce moves ~2x its size per device (reduce-scatter +
    all-gather phases); all-gather / reduce-scatter / all-to-all /
    collective-permute move ~1x.
    """
    out: Dict[str, int] = {k: 0 for k in COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        nbytes = sum(_shape_bytes(d, dims)
                     for d, dims in _SHAPE_RE.findall(type_str))
        factor = 2 if kind == "all-reduce" else 1
        out[kind] += factor * nbytes
        out["count"] += 1
    return out


import dataclasses as _dc


def _spec_with_layers(spec, n_layers: int, smoke: bool):
    """Variant of an ArchSpec with unrolled scans and n_layers layers —
    used by the cost pass (XLA cost analysis visits while bodies once, so
    flops/bytes/collectives are extracted from small fully-unrolled
    lowerings and extrapolated linearly in depth). The variant is installed
    as BOTH config and smoke_config so build_cell picks it either way."""
    base = spec.smoke_config if smoke else spec.config
    if spec.family == "lm":
        # cap unrolled attention blocks (q_chunk >= 512) or the unrolled
        # cost lowering explodes at 32k-seq cells; flop counts are
        # q_chunk-invariant
        cfg = _dc.replace(base, n_layers=n_layers, unroll=True,
                          q_chunk=max(base.q_chunk, 512))
    elif spec.family == "colpali":
        bb = _dc.replace(base.encoder.backbone, n_layers=n_layers,
                         unroll=True,
                         q_chunk=max(base.encoder.backbone.q_chunk, 512))
        cfg = _dc.replace(base, encoder=_dc.replace(base.encoder,
                                                    backbone=bb))
    elif spec.family == "recsys":
        cfg = _dc.replace(base, unroll=True)
    else:
        cfg = base
    return _dc.replace(spec, config=cfg, smoke_config=cfg)


def _lower_compile(spec, cell, mesh, smoke):
    built = cells_mod.build_cell(spec, cell, mesh, smoke=smoke)
    if built.in_shardings is None:
        jitted = built.fn              # already jitted (shard_map search)
    else:
        jitted = jax.jit(built.fn, in_shardings=built.in_shardings,
                         out_shardings=built.out_shardings,
                         donate_argnums=built.donate_argnums)
    lowered = jitted.lower(*built.args)
    return built, lowered.compile()


def _raw_metrics(compiled):
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll}


def exact_cost_metrics(spec, cell, mesh, smoke: bool) -> Dict[str, Any]:
    """Loop-exact flops/bytes/collective counts.

    LM/ColPali: lower fully-unrolled variants at two depths (L1, L2) and
    extrapolate linearly to the real depth (layers are identical blocks, so
    every per-device count is affine in depth). DIEN: one unrolled
    lowering (seq scan). Others have no scans — production numbers exact.
    """
    fam = spec.family
    if fam in ("lm", "colpali"):
        base_cfg = spec.smoke_config if smoke else spec.config
        bb = base_cfg if fam == "lm" else base_cfg.encoder.backbone
        l_full = bb.n_layers
        step = bb.global_every if bb.attn_chunk > 0 else 1
        l1, l2 = min(step, l_full), min(2 * step, l_full)
        if l1 == l2:                       # shallow smoke config
            _, c = _lower_compile(_spec_with_layers(spec, l1, smoke), cell,
                                  mesh, smoke)
            m = _raw_metrics(c)
            m["source"] = f"unrolled L={l1}"
            return m
        _, c1 = _lower_compile(_spec_with_layers(spec, l1, smoke), cell,
                               mesh, smoke)
        _, c2 = _lower_compile(_spec_with_layers(spec, l2, smoke), cell,
                               mesh, smoke)
        m1, m2 = _raw_metrics(c1), _raw_metrics(c2)

        def extr(a, b):
            return a + (b - a) * (l_full - l1) / (l2 - l1)

        coll = {k: int(extr(m1["coll"][k], m2["coll"][k]))
                for k in m1["coll"]}
        return {"flops": extr(m1["flops"], m2["flops"]),
                "bytes": extr(m1["bytes"], m2["bytes"]),
                "coll": coll,
                "source": f"extrapolated from unrolled L={l1},{l2}"}
    if fam == "recsys" and spec.config.family == "dien":
        _, c = _lower_compile(_spec_with_layers(spec, 0, smoke), cell, mesh,
                              smoke)
        m = _raw_metrics(c)
        m["source"] = "unrolled seq scan"
        return m
    return {"source": "production"}     # no loops: production is exact


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             smoke: bool = False, cost_exact: bool = True) -> Dict[str, Any]:
    spec = registry.get(arch_id)
    cell = next(c for c in spec.shapes if c.name == shape_name)
    if cell.skip:
        return {"arch": arch_id, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": cell.skip}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size

    with mesh:
        built = cells_mod.build_cell(spec, cell, mesh, smoke=smoke)
        t0 = time.perf_counter()
        if built.in_shardings is None:
            jitted = built.fn          # already jitted (shard_map search)
        else:
            jitted = jax.jit(built.fn,
                             in_shardings=built.in_shardings,
                             out_shardings=built.out_shardings,
                             donate_argnums=built.donate_argnums)
        lowered = jitted.lower(*built.args)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    cost_source = "production"
    if cost_exact:
        with mesh:
            em = exact_cost_metrics(spec, cell, mesh, smoke)
        if em["source"] != "production":
            flops, bytes_acc, coll = em["flops"], em["bytes"], em["coll"]
            cost_source = em["source"]
    coll_total = sum(v for k, v in coll.items() if k != "count")

    compute_t = flops / PEAK_FLOPS_BF16
    memory_t = bytes_acc / HBM_BW
    coll_t = coll_total / ICI_BW
    model_flops = built.meta.get("model_flops", 0.0)

    per_dev_bytes = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                     + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    rec = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "chips": n_chips, "status": "ok",
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "cost_source": cost_source,
        "hlo_flops_per_dev": flops,
        "hlo_bytes_per_dev": bytes_acc,
        "collective_bytes_per_dev": coll,
        "mem": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes": per_dev_bytes,
            "fits_16g": bool(per_dev_bytes <= HBM_BYTES),
        },
        "roofline": {
            "compute_s": compute_t,
            "memory_s": memory_t,
            "collective_s": coll_t,
            "dominant": max(
                [("compute", compute_t), ("memory", memory_t),
                 ("collective", coll_t)], key=lambda kv: kv[1])[0],
            "model_flops_total": model_flops,
            "model_flops_per_dev": model_flops / n_chips,
            "useful_flops_ratio": (model_flops / n_chips / flops)
            if flops else 0.0,
            "roofline_frac": ((model_flops / n_chips / PEAK_FLOPS_BF16)
                              / max(compute_t, memory_t, coll_t))
            if max(compute_t, memory_t, coll_t) > 0 else 0.0,
        },
        "meta": {k: v for k, v in built.meta.items()},
    }
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="use reduced configs (CI sanity)")
    ap.add_argument("--no-cost-exact", action="store_true",
                    help="skip the unrolled cost pass (multi-pod sweeps: "
                         "the roofline table is single-pod only)")
    ap.add_argument("--out", default=None)
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)

    if args.list:
        for arch_id, cell in registry.all_cells(include_skipped=True):
            flag = f"  [SKIP: {cell.skip}]" if cell.skip else ""
            print(f"{arch_id:28s} {cell.name:16s} {cell.kind:10s}{flag}")
        return 0

    todo = []
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])
    if args.all:
        for arch_id, cell in registry.all_cells():
            for m in meshes:
                todo.append((arch_id, cell.name, m == "multi"))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for m in meshes:
            todo.append((args.arch, args.shape, m == "multi"))

    results, failures = [], []
    for arch_id, shape, multi in todo:
        tag = f"{arch_id}/{shape}/{'multi' if multi else 'single'}"
        print(f"=== {tag} ===", flush=True)
        try:
            rec = run_cell(arch_id, shape, multi, smoke=args.smoke,
                           cost_exact=not args.no_cost_exact)
            results.append(rec)
            if rec["status"] == "ok":
                r = rec["roofline"]
                print(f"  ok: compile {rec['compile_s']}s | "
                      f"mem/dev {rec['mem']['peak_bytes']/2**30:.2f} GiB "
                      f"(fits16G={rec['mem']['fits_16g']}) | "
                      f"compute {r['compute_s']:.2e}s "
                      f"memory {r['memory_s']:.2e}s "
                      f"collective {r['collective_s']:.2e}s "
                      f"-> {r['dominant']}-bound | "
                      f"roofline_frac {r['roofline_frac']:.3f}", flush=True)
            else:
                print(f"  skipped: {rec['reason']}", flush=True)
        except Exception as e:  # noqa: BLE001 — report and continue
            failures.append((tag, repr(e)))
            traceback.print_exc()
            results.append({"arch": arch_id, "shape": shape,
                            "mesh": "multi" if multi else "single",
                            "status": "error", "error": repr(e)})

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")

    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(f"  {tag}: {err}")
        return 1
    print(f"\nall {len(results)} cells ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
