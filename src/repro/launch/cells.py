"""Cell builder: (arch x shape x mesh) -> lowering-ready step function.

For each family this module constructs:
  * the jittable step function for the cell kind (train / prefill / decode /
    serve / candidates / encode / search),
  * ShapeDtypeStruct stand-ins for every input (params and optimizer state
    included — nothing is allocated; the shannon/kernels input_specs
    pattern),
  * in/out NamedShardings resolved from the logical-axis specs,
  * MODEL_FLOPS metadata for §Roofline (6·N·D train / 2·N_active·D fwd
    conventions; analytic formulas for GNN/recsys documented inline).

launch/dryrun.py calls `build_cell` then `.lower().compile()`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ArchSpec, ShapeCell
from repro.core import distributed as dist_core
from repro.dist.sharding import Sharder, is_logical_spec
from repro.models import colpali as colpali_mod
from repro.models import gnn as gnn_mod
from repro.models import recsys as recsys_mod
from repro.models import transformer as T
from repro.optim import optimizer as opt

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass
class BuiltCell:
    arch_id: str
    cell: ShapeCell
    fn: Callable                 # positional args
    args: Tuple[Any, ...]        # ShapeDtypeStructs (pytrees thereof)
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    meta: Dict[str, Any]


def _shard_tree(sharder: Sharder, spec_tree, sds_tree):
    return jax.tree.map(
        lambda spec, s: sharder.named(tuple(spec), s.shape),
        spec_tree, sds_tree, is_leaf=is_logical_spec)


def _eval_sds(fn, *args):
    return jax.eval_shape(fn, *args)


def _opt_cfg_for(arch_id: str) -> opt.AdamWConfig:
    if arch_id.startswith("kimi"):
        # 1T params: bf16 params + int8 moments (docs/design.md §6)
        return opt.AdamWConfig(moment_dtype="int8")
    return opt.AdamWConfig()


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

def _lm_model_flops(cfg: T.LMConfig, cell: ShapeCell) -> float:
    n_active = cfg.active_param_count()
    d = cell.dims
    if cell.kind == "train":
        tokens = d["global_batch"] * d["seq_len"]
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = d["global_batch"] * d["seq_len"]
        return 2.0 * n_active * tokens
    # decode: one token per sequence per step
    return 2.0 * n_active * d["global_batch"]


def build_lm_cell(spec: ArchSpec, cell: ShapeCell, mesh: Mesh,
                  smoke: bool = False) -> BuiltCell:
    cfg = spec.smoke_config if smoke else spec.config
    sharder = Sharder(mesh)
    dims = cell.dims
    gb, seq = dims["global_batch"], dims["seq_len"]

    params_sds = _eval_sds(lambda: T.init(jax.random.PRNGKey(0), cfg))
    pspecs = T.param_specs(cfg)
    p_sh = _shard_tree(sharder, pspecs, params_sds)
    batch_sh = sharder.named(("batch", None), (gb, seq))
    meta = {"model_flops": _lm_model_flops(cfg, cell),
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count()}

    if cell.kind == "train":
        ocfg = _opt_cfg_for(spec.arch_id)
        opt_sds = _eval_sds(partial(opt.init, ocfg), params_sds)
        ospecs = opt.state_specs(pspecs, ocfg)
        o_sh = _shard_tree(sharder, ospecs, opt_sds)
        fn = lambda p, o, b: T.train_step(p, o, b, cfg, ocfg, shd=sharder)
        batch = {"tokens": SDS((gb, seq), jnp.int32),
                 "targets": SDS((gb, seq), jnp.int32)}
        b_sh = {"tokens": batch_sh, "targets": batch_sh}
        return BuiltCell(spec.arch_id, cell, fn,
                         (params_sds, opt_sds, batch),
                         (p_sh, o_sh, b_sh), (p_sh, o_sh, None), (0, 1),
                         meta)

    if cell.kind == "prefill":
        fn = lambda p, tok: T.prefill(p, tok, cfg, max_len=seq, shd=sharder)
        tok = SDS((gb, seq), jnp.int32)
        cache_sds = _eval_sds(fn, params_sds, tok)[1]
        c_sh = _shard_tree(sharder, T.cache_specs(), cache_sds)
        return BuiltCell(spec.arch_id, cell, fn, (params_sds, tok),
                         (p_sh, batch_sh), (None, c_sh), (), meta)

    # decode: one token against a seq-length cache
    fn = lambda p, tok, cache, pos: T.decode_step(p, tok, cache, pos, cfg,
                                                  shd=sharder)
    tok = SDS((gb,), jnp.int32)
    cache = T.KVCache(
        SDS((cfg.n_layers, gb, seq, cfg.n_kv_heads, cfg.hd), cfg.adtype),
        SDS((cfg.n_layers, gb, seq, cfg.n_kv_heads, cfg.hd), cfg.adtype))
    c_sh = _shard_tree(sharder, T.cache_specs(), cache)
    tok_sh = sharder.named(("batch",), (gb,))
    pos = SDS((), jnp.int32)
    return BuiltCell(spec.arch_id, cell, fn, (params_sds, tok, cache, pos),
                     (p_sh, tok_sh, c_sh, None), (None, c_sh), (2,), meta)


# ---------------------------------------------------------------------------
# GNN family (PNA)
# ---------------------------------------------------------------------------

def _gnn_model_flops(cfg: gnn_mod.PNAConfig, dims: Dict[str, int]) -> float:
    """Analytic PNA step flops: encoder N*2*f*d; per layer: pre-MLP
    E*2*(2d*d), post-MLP N*2*(13d*d); head N*2*d*c. x3 for fwd+bwd."""
    n, e, d = dims["n_nodes"], dims["n_edges"], cfg.d_hidden
    f, c = dims["d_feat"], dims["n_classes"]
    fwd = (2 * n * f * d
           + cfg.n_layers * (2 * e * 2 * d * d + 2 * n * 13 * d * d)
           + 2 * n * d * c)
    return 3.0 * fwd


def build_gnn_cell(spec: ArchSpec, cell: ShapeCell, mesh: Mesh,
                   smoke: bool = False) -> BuiltCell:
    base = spec.smoke_config if smoke else spec.config
    dims = cell.dims
    cfg = dataclasses.replace(
        base, d_feat=dims["d_feat"], n_classes=dims["n_classes"],
        task="graph" if "n_graphs" in dims else "node")
    sharder = Sharder(mesh)
    n, e = dims["n_nodes"], dims["n_edges"]

    params_sds = _eval_sds(lambda: gnn_mod.init(jax.random.PRNGKey(0), cfg))
    pspecs = gnn_mod.param_specs(cfg)
    p_sh = _shard_tree(sharder, pspecs, params_sds)

    batch = {"feats": SDS((n, dims["d_feat"]), jnp.float32),
             "edge_index": SDS((2, e), jnp.int32),
             "labels": SDS((n,), jnp.int32)}
    b_sh = {"feats": sharder.named(("nodes", None), (n, dims["d_feat"])),
            "edge_index": sharder.named((None, "edge"), (2, e)),
            "labels": sharder.named(("nodes",), (n,))}
    if "n_graphs" in dims:
        batch["graph_ids"] = SDS((n,), jnp.int32)
        batch["graph_labels"] = SDS((dims["n_graphs"],), jnp.int32)
        b_sh["graph_ids"] = sharder.named(("nodes",), (n,))
        b_sh["graph_labels"] = sharder.named((None,), (dims["n_graphs"],))
        del batch["labels"], b_sh["labels"]

    meta = {"model_flops": _gnn_model_flops(cfg, dims),
            "params": cfg.param_count()}

    ocfg = opt.AdamWConfig()
    opt_sds = _eval_sds(partial(opt.init, ocfg), params_sds)
    o_sh = _shard_tree(sharder, opt.state_specs(pspecs, ocfg), opt_sds)
    fn = lambda p, o, b: gnn_mod.train_step(p, o, b, cfg, ocfg, shd=sharder)
    return BuiltCell(spec.arch_id, cell, fn, (params_sds, opt_sds, batch),
                     (p_sh, o_sh, b_sh), (p_sh, o_sh, None), (0, 1), meta)


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------

def _recsys_dense_params(params_sds) -> int:
    """Parameters outside the embedding tables (MLPs, cross, GRUs)."""
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_sds)[0]:
        if "tables" not in jax.tree_util.keystr(path):
            total += int(jnp.prod(jnp.array(leaf.shape)))
    return total


def _recsys_batch(cfg: recsys_mod.RecsysConfig, b: int):
    if cfg.family in ("din", "dien"):
        return {"hist_ids": SDS((b, cfg.seq_len), jnp.int32),
                "hist_mask": SDS((b, cfg.seq_len), jnp.bool_),
                "target_ids": SDS((b,), jnp.int32),
                "label": SDS((b,), jnp.float32)}
    return {"dense": SDS((b, cfg.n_dense), jnp.float32),
            "sparse_ids": SDS((b, cfg.n_sparse), jnp.int32),
            "label": SDS((b,), jnp.float32)}


def _recsys_batch_shardings(sharder: Sharder, batch):
    return {k: sharder.named(("batch",) + (None,) * (len(v.shape) - 1),
                             v.shape) for k, v in batch.items()}


def build_recsys_cell(spec: ArchSpec, cell: ShapeCell, mesh: Mesh,
                      smoke: bool = False) -> BuiltCell:
    cfg = spec.smoke_config if smoke else spec.config
    sharder = Sharder(mesh)
    dims = cell.dims

    params_sds = _eval_sds(
        lambda: recsys_mod.init(jax.random.PRNGKey(0), cfg))
    pspecs = recsys_mod.param_specs(cfg)
    p_sh = _shard_tree(sharder, pspecs, params_sds)
    dense_p = _recsys_dense_params(params_sds)
    emb_p = sum(cfg.table_rows) * cfg.embed_dim

    if cell.kind == "candidates":
        nc = dims["n_candidates"]
        if cfg.family in ("din", "dien"):
            one = {"hist_ids": SDS((1, cfg.seq_len), jnp.int32),
                   "hist_mask": SDS((1, cfg.seq_len), jnp.bool_)}
        else:
            one = {"dense": SDS((1, cfg.n_dense), jnp.float32),
                   "sparse_ids": SDS((1, cfg.n_sparse), jnp.int32)}
        cand = SDS((nc,), jnp.int32)
        fn = lambda p, b, c: recsys_mod.score_candidates(p, b, c, cfg,
                                                         shd=sharder)
        one_sh = {k: sharder.named((None,) * len(v.shape), v.shape)
                  for k, v in one.items()}
        cand_sh = sharder.named(("candidate",), (nc,))
        # hist per candidate: attention MLP over seq_len; dense: top MLP
        meta = {"model_flops": 2.0 * dense_p * nc
                * (cfg.seq_len if cfg.family in ("din", "dien") else 1),
                "params": dense_p + emb_p}
        return BuiltCell(spec.arch_id, cell, fn, (params_sds, one, cand),
                         (p_sh, one_sh, cand_sh),
                         sharder.named(("candidate",), (nc,)), (), meta)

    b = dims["batch"]
    batch = _recsys_batch(cfg, b)
    b_sh = _recsys_batch_shardings(sharder, batch)
    seq_mult = cfg.seq_len if cfg.family in ("din", "dien") else 1

    if cell.kind == "serve":
        fn = lambda p, bb: recsys_mod.serve_step(p, bb, cfg, shd=sharder)
        meta = {"model_flops": 2.0 * dense_p * b * seq_mult,
                "params": dense_p + emb_p}
        return BuiltCell(spec.arch_id, cell, fn, (params_sds, batch),
                         (p_sh, b_sh), sharder.named(("batch",), (b,)),
                         (), meta)

    ocfg = opt.AdamWConfig()
    opt_sds = _eval_sds(partial(opt.init, ocfg), params_sds)
    o_sh = _shard_tree(sharder, opt.state_specs(pspecs, ocfg), opt_sds)
    fn = lambda p, o, bb: recsys_mod.train_step(p, o, bb, cfg, ocfg,
                                                shd=sharder)
    meta = {"model_flops": 6.0 * dense_p * b * seq_mult,
            "params": dense_p + emb_p}
    return BuiltCell(spec.arch_id, cell, fn, (params_sds, opt_sds, batch),
                     (p_sh, o_sh, b_sh), (p_sh, o_sh, None), (0, 1), meta)


# ---------------------------------------------------------------------------
# ColPali family (the paper's system)
# ---------------------------------------------------------------------------

def build_colpali_cell(spec: ArchSpec, cell: ShapeCell, mesh: Mesh,
                       smoke: bool = False) -> BuiltCell:
    arch = spec.smoke_config if smoke else spec.config
    enc = arch.encoder
    sharder = Sharder(mesh)
    dims = cell.dims

    params_sds = _eval_sds(
        lambda: colpali_mod.init(jax.random.PRNGKey(0), enc))
    pspecs = colpali_mod.param_specs(enc)
    p_sh = _shard_tree(sharder, pspecs, params_sds)
    n_active = enc.param_count()

    if cell.kind == "train":
        gb = dims["global_batch"]
        batch = {"query_tokens": SDS((gb, enc.query_len), jnp.int32),
                 "query_mask": SDS((gb, enc.query_len), jnp.bool_),
                 "doc_patches": SDS((gb, enc.n_patches, enc.d_patch),
                                    jnp.float32),
                 "doc_mask": SDS((gb, enc.n_patches), jnp.bool_)}
        b_sh = {k: sharder.named(("batch",) + (None,) * (len(v.shape) - 1),
                                 v.shape) for k, v in batch.items()}
        ocfg = opt.AdamWConfig()
        opt_sds = _eval_sds(partial(opt.init, ocfg), params_sds)
        o_sh = _shard_tree(sharder, opt.state_specs(pspecs, ocfg), opt_sds)
        fn = lambda p, o, bb: colpali_mod.train_step(p, o, bb, arch.encoder,
                                                     ocfg, shd=sharder)
        tokens = gb * (enc.query_len + enc.n_patches)
        meta = {"model_flops": 6.0 * n_active * tokens, "params": n_active}
        return BuiltCell(spec.arch_id, cell, fn,
                         (params_sds, opt_sds, batch),
                         (p_sh, o_sh, b_sh), (p_sh, o_sh, None), (0, 1),
                         meta)

    if cell.kind == "encode":
        gb = dims["global_batch"]
        fn = lambda p, pat, m: colpali_mod.encode_doc(p, pat, m, arch.encoder,
                                                      shd=sharder)
        pat = SDS((gb, enc.n_patches, enc.d_patch), jnp.float32)
        msk = SDS((gb, enc.n_patches), jnp.bool_)
        pat_sh = sharder.named(("batch", None, None), pat.shape)
        msk_sh = sharder.named(("batch", None), msk.shape)
        meta = {"model_flops": 2.0 * n_active * gb * enc.n_patches,
                "params": n_active}
        return BuiltCell(spec.arch_id, cell, fn, (params_sds, pat, msk),
                         (p_sh, pat_sh, msk_sh), None, (), meta)

    # search: sharded ADC MaxSim scan over the quantized corpus
    corpus_axes = tuple(mesh.axis_names)     # flat over all axes
    q_n, n_docs = dims["queries"], dims["corpus"]
    md, mq = arch.kept_patches, enc.query_len
    search = dist_core.sharded_search_fn(mesh, corpus_axes, k=arch.top_k)
    q = SDS((q_n, mq, enc.proj_dim), jnp.float32)
    qm = SDS((q_n, mq), jnp.float32)
    codes = SDS((n_docs, md), jnp.int32)
    dm = SDS((n_docs, md), jnp.float32)
    ids = SDS((n_docs,), jnp.int32)
    cb = SDS((arch.hpc.k, enc.proj_dim), jnp.float32)
    # ADC scan reads 4 B/code (int32 lanes); table build is the only matmul
    meta = {"model_flops": 2.0 * q_n * mq * arch.hpc.k * enc.proj_dim
            + 1.0 * q_n * mq * n_docs * md,   # compares (add/max ops)
            "params": arch.hpc.k * enc.proj_dim}
    return BuiltCell(spec.arch_id, cell, search,
                     (q, qm, codes, dm, ids, cb),
                     None, None, (), meta)


FAMILY_BUILDERS = {
    "lm": build_lm_cell,
    "gnn": build_gnn_cell,
    "recsys": build_recsys_cell,
    "colpali": build_colpali_cell,
}


def build_cell(spec: ArchSpec, cell: ShapeCell, mesh: Mesh,
               smoke: bool = False) -> BuiltCell:
    return FAMILY_BUILDERS[spec.family](spec, cell, mesh, smoke=smoke)
