"""Atomic / async / mesh-elastic checkpointing."""

from repro.ckpt import checkpoint  # noqa: F401
