"""Atomic, async, mesh-elastic checkpointing (no orbax in this env).

Format: one directory per step containing
  arrays.npz   — flattened pytree leaves keyed by their tree path
  meta.json    — step, leaf manifest (path, shape, dtype, per-leaf crc32),
                 framework version
  COMMIT       — written last; a checkpoint without it is ignored (torn
                 writes from preempted hosts can never be restored)

Atomicity: write into `<dir>.tmp`, fsync every file *and* the enclosing
directories, then os.replace -> the rename is the commit point on POSIX.
Integrity: meta.json records a crc32 per leaf; `restore` verifies every
array against it and fails naming the corrupt leaf — a bit flip between
save and restore can never load silently. Async: `save_async` snapshots the pytree to host
memory synchronously (cheap) and writes on a background thread so the train
loop overlaps I/O with compute; `wait()` joins before the next save.

Elasticity (docs/design.md §4): leaves are stored *unsharded* (host-gathered);
`restore` takes a template pytree (for structure/dtype) plus optional
NamedShardings and device_puts each leaf — so a checkpoint written on a
256-chip mesh restores onto 512 chips (or 1 CPU) unchanged. Multi-host
note: at real scale each host would write only its addressable shards
(process_index-suffixed files); the single-process container exercises the
full-gather path, and the format keeps per-leaf granularity so the sharded
writer is a drop-in.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from typing import Any, Dict, Optional

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = np.asarray(leaf)
    return out


def _crc32(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _write_fsync(path: str, write_fn) -> None:
    """Write via `write_fn(f)`, flush and fsync before close — a COMMIT
    must never hit the disk ahead of the data it commits."""
    with open(path, "wb") as f:
        write_fn(f)
        f.flush()
        os.fsync(f.fileno())


def _fsync_dir(dirname: str) -> None:
    try:
        fd = os.open(dirname or ".", os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def save(directory: str, step: int, tree: PyTree) -> str:
    """Synchronous atomic save. Returns the committed path."""
    path = os.path.join(directory, f"step_{step:08d}")
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    arrays = _flatten(tree)
    _write_fsync(os.path.join(tmp, "arrays.npz"),
                 lambda f: np.savez(f, **arrays))
    meta = {
        "step": step,
        "time": time.time(),
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                       "crc32": _crc32(v)}
                   for k, v in arrays.items()},
    }
    _write_fsync(os.path.join(tmp, "meta.json"),
                 lambda f: f.write(json.dumps(meta).encode()))
    _write_fsync(os.path.join(tmp, "COMMIT"), lambda f: f.write(b"ok"))
    _fsync_dir(tmp)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)
    _fsync_dir(directory)
    return path


def restore(path: str, template: PyTree,
            shardings: Optional[PyTree] = None) -> PyTree:
    """Load a checkpoint into the structure of `template`.

    shardings: optional matching pytree of jax.sharding.Sharding — leaves
    are device_put with them (elastic re-shard on a different mesh).
    """
    if not os.path.exists(os.path.join(path, "COMMIT")):
        raise FileNotFoundError(f"uncommitted/corrupt checkpoint: {path}")
    with np.load(os.path.join(path, "arrays.npz")) as npz:
        arrays = {k: npz[k] for k in npz.files}
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    # verify BEFORE any leaf is device_put: a corrupt checkpoint fails
    # with the leaf's tree path, it never half-loads
    for key, arr in arrays.items():
        want = meta.get("leaves", {}).get(key, {}).get("crc32")
        if want is None:
            continue  # pre-crc32 checkpoint: nothing to verify against
        got = _crc32(arr)
        if got != int(want):
            raise ValueError(
                f"checkpoint {path!r}: checksum mismatch on leaf {key!r} "
                f"(crc32 {got:#010x} != stored {int(want):#010x}) — "
                "corrupt; restore an earlier committed step")
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(flat))
    leaves = []
    for (pathk, leaf), shd in zip(flat, shard_leaves):
        key = jax.tree_util.keystr(pathk)
        arr = arrays[key]
        expect = tuple(leaf.shape)
        if tuple(arr.shape) != expect:
            raise ValueError(f"shape mismatch at {key}: "
                             f"ckpt {arr.shape} vs template {expect}")
        arr = arr.astype(leaf.dtype)
        leaves.append(jax.device_put(arr, shd) if shd is not None
                      else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "COMMIT")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


class CheckpointManager:
    """Keeps the last `keep` checkpoints; async writes; auto-resume."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, step: int, tree: PyTree):
        self.wait()
        # Snapshot to host memory synchronously (device buffers may be
        # donated/overwritten by the next step).
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save(self.directory, step, host_tree)
                self._gc()
            except BaseException as e:
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save(self, step: int, tree: PyTree):
        self.wait()
        save(self.directory, step, tree)
        self._gc()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")
            and os.path.exists(os.path.join(self.directory, n, "COMMIT")))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, template: PyTree,
                       shardings: Optional[PyTree] = None
                       ) -> Optional[tuple]:
        step = latest_step(self.directory)
        if step is None:
            return None
        path = os.path.join(self.directory, f"step_{step:08d}")
        return step, restore(path, template, shardings)
