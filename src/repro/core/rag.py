"""RAG integration (paper §V-C / Table V): HPC-ColPali as the retriever for
a summarisation LM, with *exactly measurable* hallucination.

The synthetic legal corpus (data/synthetic.py::make_fact_corpus) gives every
document an explicit fact set. The pipeline:

  query -> HPC-ColPali retrieval (top-k docs) -> prompt
  [doc_1 facts .. doc_k facts, SEP, QUERY, probe, SEP] -> greedy decode
  of `facts_per_doc` answer tokens -> extracted fact ids.

Metrics (paper Table V definitions):
  hallucination rate — fraction of generated fact tokens NOT contained in
    the retrieved context (the model asserted something its sources don't
    support);
  ROUGE-L — LCS-based F1 between generated fact sequence and the gold
    summary (the gold document's fact set);
  end-to-end latency — retrieval + generation wall-clock.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pipeline as hpc
from repro.models import transformer as T
from repro.retrieval.base import Query as RQuery
from repro.retrieval.retriever import Retriever

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class RAGConfig:
    retriever: hpc.HPCConfig = dataclasses.field(default_factory=hpc.HPCConfig)
    top_k_docs: int = 2
    facts_per_doc: int = 4
    fact0: int = 3               # first fact-token id (vocab layout)
    sep: int = 1
    max_answer: int = 4


def build_prompt(doc_tokens: Array, query_tokens: Array, cfg: RAGConfig,
                 prompt_len: int) -> Array:
    """Retrieved docs' tokens + query -> fixed-length prompt (B, prompt_len).

    doc_tokens: (B, k, Ld) the retrieved docs' token renderings.
    """
    b, k, ld = doc_tokens.shape
    # keep only the fact prefix of each doc (facts_per_doc + SEP)
    keep = cfg.facts_per_doc + 1
    ctx = doc_tokens[:, :, :keep].reshape(b, k * keep)
    q = query_tokens
    prompt = jnp.concatenate([ctx, q], axis=1)
    pad = prompt_len - prompt.shape[1]
    assert pad >= 0, (prompt.shape, prompt_len)
    return jnp.pad(prompt, ((0, 0), (0, pad)))


def greedy_generate(params, prompt: Array, cfg_lm: T.LMConfig,
                    max_new: int, prompt_len: int) -> Array:
    """Greedy decode max_new tokens after the prompt. Returns (B, max_new)."""
    b = prompt.shape[0]
    max_len = prompt_len + max_new
    logits, cache = T.prefill(params, prompt, cfg_lm, max_len=max_len)
    outs = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(max_new):
        outs.append(tok)
        if i == max_new - 1:
            break
        logits, cache = T.decode_step(params, tok, cache,
                                      jnp.int32(prompt_len + i), cfg_lm)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    return jnp.stack(outs, axis=1)


def extract_facts(tokens: np.ndarray, fact0: int, n_facts: int) -> List[set]:
    """Token rows -> sets of fact ids (non-fact tokens ignored)."""
    out = []
    for row in tokens:
        out.append({int(t) - fact0 for t in row
                    if fact0 <= int(t) < fact0 + n_facts})
    return out


def hallucination_rate(generated: Sequence[set],
                       context_facts: Sequence[set]) -> float:
    """Fraction of generated facts unsupported by the retrieved context."""
    total, bad = 0, 0
    for gen, ctx in zip(generated, context_facts):
        for f in gen:
            total += 1
            bad += f not in ctx
    return bad / max(total, 1)


def rouge_l(gen: Sequence[int], ref: Sequence[int]) -> float:
    """ROUGE-L F1 on token sequences."""
    g, r = list(gen), list(ref)
    if not g or not r:
        return 0.0
    dp = np.zeros((len(g) + 1, len(r) + 1), np.int32)
    for i in range(1, len(g) + 1):
        for j in range(1, len(r) + 1):
            dp[i, j] = (dp[i - 1, j - 1] + 1 if g[i - 1] == r[j - 1]
                        else max(dp[i - 1, j], dp[i, j - 1]))
    lcs = dp[-1, -1]
    prec, rec = lcs / len(g), lcs / len(r)
    return 0.0 if lcs == 0 else 2 * prec * rec / (prec + rec)


def rag_pipeline(index: "hpc.HPCIndex", gen_params, corpus, rag_cfg: RAGConfig,
                 lm_cfg: T.LMConfig, n_facts_vocab: int,
                 queries_slice: slice = slice(None)) -> Dict[str, float]:
    """Run retrieval + generation over the fact corpus; return Table V row."""
    q_emb = corpus.query_patches[queries_slice]
    q_mask = corpus.query_mask[queries_slice]
    q_sal = corpus.query_salience[queries_slice]
    q_tok = corpus.query_tokens[queries_slice]
    gold_facts = np.asarray(corpus.gold_facts[queries_slice])

    t0 = time.perf_counter()
    retriever = Retriever(rag_cfg.retriever)
    _, ids = retriever.search(index, RQuery(q_emb, q_mask, q_sal),
                              k=rag_cfg.top_k_docs)
    ids = jnp.maximum(ids, 0)
    t_retrieve = time.perf_counter() - t0

    doc_toks = corpus.doc_tokens[ids]                     # (B, k, Ld)
    keep = rag_cfg.facts_per_doc + 1
    prompt_len = rag_cfg.top_k_docs * keep + q_tok.shape[1]
    prompt = build_prompt(doc_toks, q_tok, rag_cfg, prompt_len)

    t1 = time.perf_counter()
    gen = greedy_generate(gen_params, prompt, lm_cfg, rag_cfg.max_answer,
                          prompt_len)
    gen = np.asarray(jax.block_until_ready(gen))
    t_generate = time.perf_counter() - t1

    ctx_facts_arr = np.asarray(corpus.doc_facts)[np.asarray(ids)]  # (B,k,F)
    ctx_sets = [set(row.ravel().tolist()) for row in ctx_facts_arr]
    gen_sets = extract_facts(gen, rag_cfg.fact0, n_facts_vocab)
    halluc = hallucination_rate(gen_sets, ctx_sets)

    rouges = [rouge_l(sorted(g), sorted(set(ref.tolist())))
              for g, ref in zip(gen_sets, gold_facts)]
    # answer accuracy: all gold facts generated
    correct = np.mean([set(ref.tolist()) <= g
                       for g, ref in zip(gen_sets, gold_facts)])
    b = gen.shape[0]
    return {
        "rouge_l": float(np.mean(rouges)),
        "hallucination": float(halluc),
        "answer_acc": float(correct),
        "latency_ms": (t_retrieve + t_generate) * 1e3 / b,
        "retrieve_ms": t_retrieve * 1e3 / b,
        "generate_ms": t_generate * 1e3 / b,
    }


def make_rag_train_batch(key: Array, corpus, vocab: Dict[str, int],
                         rag_cfg: RAGConfig, batch: int, seq_len: int,
                         n_docs: int) -> Dict[str, Array]:
    """Supervised RAG fine-tuning batch: prompt (gold doc + distractors in
    context) -> answer = gold doc's facts. Loss masked to answer positions."""
    k1, k2, k3 = jax.random.split(key, 3)
    gold = jax.random.randint(k1, (batch,), 0, n_docs)
    distract = jax.random.randint(k2, (batch, rag_cfg.top_k_docs - 1),
                                  0, n_docs)
    # randomise gold position within the context
    docs = jnp.concatenate([gold[:, None], distract], axis=1)
    perm = jax.vmap(lambda k: jax.random.permutation(k, rag_cfg.top_k_docs))(
        jax.random.split(k3, batch))
    docs = jnp.take_along_axis(docs, perm, axis=1)
    doc_toks = corpus.doc_tokens[docs]

    probe_slot = jax.random.randint(k3, (batch,), 0, rag_cfg.facts_per_doc)
    probe = corpus.doc_facts[gold, probe_slot] + vocab["fact0"]
    q_tok = jnp.zeros((batch, 4), jnp.int32)
    q_tok = q_tok.at[:, 0].set(vocab["query"])
    q_tok = q_tok.at[:, 1].set(probe)
    q_tok = q_tok.at[:, 2].set(vocab["sep"])

    keep = rag_cfg.facts_per_doc + 1
    prompt_len = rag_cfg.top_k_docs * keep + 4
    prompt = build_prompt(doc_toks, q_tok, rag_cfg, prompt_len)
    answer = corpus.doc_facts[gold] + vocab["fact0"]       # (B, F)
    full = jnp.concatenate([prompt, answer], axis=1)
    pad = seq_len + 1 - full.shape[1]
    assert pad >= 0
    full = jnp.pad(full, ((0, 0), (0, pad)))
    tokens = full[:, :-1]
    targets = full[:, 1:]
    # mask: only answer positions contribute
    pos = jnp.arange(seq_len)[None, :]
    is_answer = (pos >= prompt_len - 1) & (pos < prompt_len - 1
                                           + rag_cfg.facts_per_doc)
    targets = jnp.where(is_answer, targets, -1)
    return {"tokens": tokens, "targets": targets}
