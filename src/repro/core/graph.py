"""Layered small-world graph (HNSW) over document routing vectors.

The paper serves queries through FAISS HNSW (§IV); the `ivf` centroid
router only approximates that. This module is the real thing, adapted to
the repo's static-shape discipline:

  * the graph lives over the per-document *mean decoded-patch* vectors
    (`index.doc_mean_vectors`) — the same routing representation IVF
    buckets by, so the two backends are comparable at equal budgets;
  * adjacency is a padded fixed-degree array `(levels, N, 2m)` of int32
    neighbor ids (-1 = empty slot): one dense pytree leaf, no ragged
    host-side lists, so the state jits/shards/checkpoints like every
    other index;
  * search is greedy descent through the upper levels (a `while_loop`
    whose carried best distance strictly decreases, so it terminates)
    followed by a bounded best-first beam over level 0 with a *static*
    `ef_search` frontier and the visited set kept as an (N,) bool
    bitmask — the whole query path is one jitted function;
  * construction (insert points one at a time, connect to the ef_c-best
    neighbors, prune back-links to degree) is inherently sequential and
    runs in numpy on the host; it is a pure function of (key, vectors,
    config), so builds are deterministic.

The graph only *routes*: the `ef_search` surviving candidates are scored
through the same streaming fused-ADC engine the other backends use
(core/scan.py, see `search_hnsw`).
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scan as scan_mod
from repro.core.index import doc_mean_vectors, mean_pool, segment_capacity

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class HNSWConfig:
    m: int = 8                 # max out-degree on levels >= 1 (level 0: 2m)
    ef_construction: int = 48  # beam width while inserting
    ef_search: int = 64        # query beam width = scanned-candidate budget
    levels: int = 4            # static number of graph levels


class HNSWIndex(NamedTuple):
    doc_vecs: Array    # (N, D) float32 mean decoded-patch vectors
    neighbors: Array   # (levels, N, 2m) int32 adjacency, -1 padded
    entry: Array       # () int32 — entry node (highest-level node)
    node_level: Array  # (N,) int32 — max level each node appears on
    codes: Array       # (N, Md) uint8/16 quantized patches (scan payload)
    mask: Array        # (N, Md) bool
    doc_ids: Array     # (N,) int32 global ids
    codebook: Array    # (K, D)


# ---------------------------------------------------------------------------
# Build (host-side numpy: insertion is sequential by nature)
# ---------------------------------------------------------------------------

def _sq_dists(x: np.ndarray, q: np.ndarray) -> np.ndarray:
    diff = x - q
    return np.einsum("...d,...d->...", diff, diff)


def _greedy_np(x: np.ndarray, nbrs: np.ndarray, cur: int, q: np.ndarray
               ) -> int:
    """Greedy descent on one level: move to the best neighbor until stuck."""
    d = float(_sq_dists(x[cur], q))
    while True:
        nb = nbrs[cur]
        nb = nb[nb >= 0]
        if nb.size == 0:
            return cur
        nd = _sq_dists(x[nb], q)
        j = int(np.argmin(nd))
        if nd[j] >= d:
            return cur
        cur, d = int(nb[j]), float(nd[j])


def _search_layer_np(x: np.ndarray, nbrs: np.ndarray, entry: int,
                     q: np.ndarray, ef: int) -> list:
    """Best-first search on one level -> up to ef ids, nearest first."""
    d0 = float(_sq_dists(x[entry], q))
    visited = {entry}
    cand = [(d0, entry)]                 # min-heap of frontier
    result = [(-d0, entry)]              # max-heap of the ef best so far
    while cand:
        d, c = heapq.heappop(cand)
        if d > -result[0][0] and len(result) >= ef:
            break
        nb = nbrs[c]
        nb = [int(v) for v in nb[nb >= 0] if int(v) not in visited]
        if not nb:
            continue
        visited.update(nb)
        nd = _sq_dists(x[np.asarray(nb)], q)
        for dn, v in zip(nd, nb):
            dn = float(dn)
            if len(result) < ef or dn < -result[0][0]:
                heapq.heappush(cand, (dn, v))
                heapq.heappush(result, (-dn, v))
                if len(result) > ef:
                    heapq.heappop(result)
    return [v for _, v in sorted((-dd, v) for dd, v in result)]


def _select_diverse(x: np.ndarray, q: np.ndarray, cand: list, cap: int
                    ) -> list:
    """Heuristic neighbor selection (Malkov & Yashunin, Alg. 4).

    `cand` is nearest-first to q. A candidate is kept only if it is
    closer to q than to every already-kept neighbor — this preserves
    edges that bridge clusters, which nearest-only selection prunes away
    (fragmenting the graph; near-duplicate documents make this acute).
    Remaining slots are backfilled with the skipped nearest candidates
    (hnswlib's keepPrunedConnections) so the degree budget isn't wasted.
    """
    if not cand:
        return []
    d_q = _sq_dists(x[np.asarray(cand)], q)                   # (len(cand),)
    sel: list = []
    skipped: list = []
    for c, dc in zip(cand, d_q):
        if len(sel) == cap:
            break
        if not sel or np.all(_sq_dists(x[np.asarray(sel)], x[c]) >= dc):
            sel.append(int(c))
        else:
            skipped.append(int(c))
    sel.extend(skipped[:cap - len(sel)])
    return sel


def _connect(nbrs: np.ndarray, x: np.ndarray, i: int, found: list, cap: int
             ) -> None:
    """Set i's neighbor row and the pruned bidirectional back-links.

    `cap` is the per-level degree bound (2m on level 0, m above); rows
    are left-packed, so the fill level is the count of non-negative ids.
    Both directions select neighbors with the diversity heuristic.
    """
    sel = _select_diverse(x, x[i], found, cap)
    nbrs[i, :len(sel)] = sel
    for j in sel:
        row = nbrs[j]
        filled = np.flatnonzero(row >= 0)
        if filled.size < cap:
            row[filled.size] = i
        else:
            cand = np.append(row[filled], i)
            d = _sq_dists(x[cand], x[j])
            order = [int(c) for c in cand[np.argsort(d, kind="stable")]]
            keep = _select_diverse(x, x[j], order, cap)
            row[:len(keep)] = keep
            row[len(keep):] = -1


def _insert_np(x: np.ndarray, nbrs: np.ndarray, lvl: np.ndarray,
               entry: int, top: int, order, ef_construction: int, m: int
               ) -> Tuple[int, int]:
    """Insert nodes `order` into the adjacency in place (Malkov Alg. 1).

    The core sequential insert shared by `build_hnsw` (bulk, entry=-1),
    `hnsw_insert` (incremental append into a populated graph) and
    `hnsw_compact` (re-insert of live survivors). entry < 0 means the
    graph is empty: the first inserted node becomes the entry point.
    Returns the possibly-updated (entry, top).
    """
    width = 2 * m
    for i in order:
        i = int(i)
        li_ = int(lvl[i])
        if entry < 0:
            entry, top = i, li_
            continue
        cur = entry
        for lev in range(top, li_, -1):
            cur = _greedy_np(x, nbrs[lev], cur, x[i])
        for lev in range(min(li_, top), -1, -1):
            found = _search_layer_np(x, nbrs[lev], cur, x[i],
                                     ef_construction)
            _connect(nbrs[lev], x, i, found, width if lev == 0 else m)
            cur = found[0]
        if li_ > top:
            entry, top = i, li_
    return entry, top


def _draw_levels(key: Array, n: int, config: HNSWConfig) -> np.ndarray:
    """Exponentially-decaying level draws, capped at the static count."""
    u = np.asarray(jax.random.uniform(key, (n,), minval=1e-12, maxval=1.0))
    ml = 1.0 / math.log(max(config.m, 2))
    return np.minimum((-np.log(u) * ml).astype(np.int64), config.levels - 1)


def build_hnsw(key: Array, codes: Array, mask: Array, codebook: Array,
               config: HNSWConfig, doc_ids: Optional[Array] = None
               ) -> HNSWIndex:
    """Insert documents one at a time into the layered graph.

    Deterministic: level draws come from `key`, and insertion order is
    document order. Degree cap is 2m on level 0 and m above (the standard
    HNSW split); both are stored in the one (levels, N, 2m) array.
    """
    n, _ = codes.shape
    if doc_ids is None:
        doc_ids = jnp.arange(n, dtype=jnp.int32)
    doc_vecs = doc_mean_vectors(codes, mask, codebook)
    x = np.asarray(doc_vecs, np.float32)

    lvl = _draw_levels(key, n, config)
    nbrs = np.full((config.levels, n, 2 * config.m), -1, np.int64)
    entry, top = _insert_np(x, nbrs, lvl, -1, -1, range(n),
                            config.ef_construction, config.m)

    return HNSWIndex(
        doc_vecs=doc_vecs.astype(jnp.float32),
        neighbors=jnp.asarray(nbrs, jnp.int32),
        entry=jnp.int32(entry),
        node_level=jnp.asarray(lvl, jnp.int32),
        codes=codes, mask=mask,
        doc_ids=doc_ids, codebook=codebook)


# ---------------------------------------------------------------------------
# Search (jit-stable: static ef frontier, bitmask visited set)
# ---------------------------------------------------------------------------

def _greedy_level(doc_vecs: Array, nbrs: Array, q: Array, cur: Array,
                  d_cur: Array) -> Tuple[Array, Array]:
    """One level of greedy descent. The carried distance strictly
    decreases each iteration, so the while_loop terminates."""

    def cond(c):
        return c[2]

    def body(c):
        cur, d, _ = c
        nb = nbrs[cur]                                        # (width,)
        nb_s = jnp.where(nb >= 0, nb, 0)
        nd = jnp.sum((doc_vecs[nb_s] - q) ** 2, axis=-1)
        nd = jnp.where(nb >= 0, nd, jnp.inf)
        j = jnp.argmin(nd)
        better = nd[j] < d
        return (jnp.where(better, nb_s[j], cur),
                jnp.where(better, nd[j], d), better)

    cur, d_cur, _ = jax.lax.while_loop(
        cond, body, (cur, d_cur, jnp.bool_(True)))
    return cur, d_cur


def _beam_level0(doc_vecs: Array, nbrs0: Array, q: Array, entry: Array,
                 d_entry: Array, ef: int) -> Tuple[Array, Array]:
    """Bounded best-first beam on the base layer.

    Fixed ef expansion steps over a static-(ef,) frontier; the visited
    set is an (N,) bool bitmask, so the whole loop is one lax.scan of
    static shapes. Returns (dists (ef,), ids (ef,)) nearest-first, ids
    -1 where fewer than ef nodes were reachable.
    """
    n = doc_vecs.shape[0]
    width = nbrs0.shape[1]
    ids0 = jnp.full((ef,), -1, jnp.int32).at[0].set(entry)
    ds0 = jnp.full((ef,), jnp.inf, jnp.float32).at[0].set(d_entry)
    exp0 = jnp.zeros((ef,), bool)
    visited0 = jnp.zeros((n,), bool).at[entry].set(True)

    def step(state, _):
        ids, ds, exp, visited = state
        open_d = jnp.where(exp | (ids < 0), jnp.inf, ds)
        b = jnp.argmin(open_d)
        has_open = jnp.isfinite(open_d[b])
        exp = exp.at[b].set(exp[b] | has_open)
        node = jnp.where(has_open, ids[b], 0)
        nb = nbrs0[node]                                      # (width,)
        nb_s = jnp.where(nb >= 0, nb, 0)
        fresh = (nb >= 0) & has_open & ~visited[nb_s]
        nd = jnp.sum((doc_vecs[nb_s] - q) ** 2, axis=-1)
        nd = jnp.where(fresh, nd, jnp.inf)
        visited = visited.at[nb_s].set(visited[nb_s] | fresh)
        all_ids = jnp.concatenate([ids, jnp.where(fresh, nb_s, -1)])
        all_ds = jnp.concatenate([ds, nd])
        all_exp = jnp.concatenate([exp, jnp.zeros((width,), bool)])
        # JAX04-safe: all_ds has ef + width entries, always >= ef
        _, order = jax.lax.top_k(-all_ds, ef)  # noqa: JAX04
        return (all_ids[order], all_ds[order], all_exp[order], visited), None

    (ids, ds, _, _), _ = jax.lax.scan(step, (ids0, ds0, exp0, visited0),
                                      None, length=ef)
    return ds, ids


def hnsw_candidates(index: HNSWIndex, q_vec: Array, *, ef_search: int
                    ) -> Tuple[Array, Array]:
    """Graph routing for one query vector (D,) -> (dists, ids) (ef,)."""
    n_levels = index.neighbors.shape[0]
    cur = index.entry
    d = jnp.sum((index.doc_vecs[cur] - q_vec) ** 2, axis=-1)
    for lev in range(n_levels - 1, 0, -1):
        cur, d = _greedy_level(index.doc_vecs, index.neighbors[lev], q_vec,
                               cur, d)
    return _beam_level0(index.doc_vecs, index.neighbors[0], q_vec, cur, d,
                        ef_search)


@partial(jax.jit, static_argnames=("ef_search", "k", "scan"))
def search_hnsw(index: HNSWIndex, q: Array, q_mask: Array, *, ef_search: int,
                k: int, scan=None) -> Tuple[Array, Array]:
    """Graph-route to ef_search candidates, stream-scan them, top-k.

    The beam survivors score through the streaming engine's per-query
    layout (core/scan.py) — the same fused ADC path as every other
    backend. Returns (scores (B, k), doc_ids (B, k)). Sentinel contract:
    rows beyond the reachable candidates carry doc_id -1 with
    NEG_INF-or-below scores (see IndexBackend.search); k > ef_search
    pads rather than failing, matching search_ivf when k exceeds the
    probed pool.
    """
    q_vec = mean_pool(q, q_mask)                              # (B, D)
    _, cand = jax.vmap(
        lambda v: hnsw_candidates(index, v, ef_search=ef_search))(q_vec)
    valid = cand >= 0                                         # (B, ef)
    safe = jnp.where(valid, cand, 0)
    cand_codes = index.codes[safe]                            # (B, ef, Md)
    cand_mask = index.mask[safe] & valid[..., None]
    ids = jnp.where(valid, index.doc_ids[safe], -1)
    return scan_mod.quantized_maxsim_topk(
        q, q_mask, cand_codes, cand_mask, index.codebook, k=k,
        doc_ids=ids, valid=valid, scan=scan)


# ---------------------------------------------------------------------------
# Incremental mutation (segmented LSM store — docs/design.md §9)
# ---------------------------------------------------------------------------
#
# Unlike the flat-family backends, HNSW keeps ONE capacity-padded segment:
# appends insert into the existing adjacency (Malkov Alg. 1, the same
# host-side routine the bulk build runs), growing the arrays to the next
# pow2 capacity bucket only when full. Tombstoned nodes stay in the graph
# as routable waypoints — removing their edges would fragment the
# small-world structure — and are filtered at scoring time via the live
# mask (`search_hnsw_live`). `hnsw_compact` physically drops them by
# re-inserting the live survivors (with their STORED level draws) into a
# fresh graph.

_INSERT_KEY = 0x5eed  # deterministic level-draw stream for appends


def _filled_count(doc_ids: np.ndarray) -> int:
    """Occupied row count — rows are filled front-to-back, padding is -1."""
    return int(np.sum(doc_ids >= 0))


def _grow_dim(arr: Array, axis: int, cap: int, fill) -> Array:
    """Pad `axis` of arr to `cap` with `fill`."""
    n = arr.shape[axis]
    if n == cap:
        return arr
    shape = list(arr.shape)
    shape[axis] = cap - n
    pad = jnp.full(tuple(shape), fill, arr.dtype)
    return jnp.concatenate([arr, pad], axis=axis)


def hnsw_insert(index: HNSWIndex, live: Array, codes: Array, mask: Array,
                doc_ids: Array, config: HNSWConfig,
                levels: Optional[np.ndarray] = None
                ) -> Tuple[HNSWIndex, Array]:
    """Append new documents into an existing graph (no rebuild).

    Host-side sequential insert, like the build. Level draws are a
    deterministic function of the graph's fill count (fold_in of a fixed
    key), so the same mutation history always yields the same graph.
    Arrays grow to the next pow2 capacity bucket (`segment_capacity`)
    only when the current padding is exhausted, so repeated small appends
    reuse the same jit signature. Padding rows have no in-edges and are
    never an entry point, so the search beam cannot reach them.

    Returns the new (index, live); new rows are live, old live bits are
    carried (tombstones stay routable but filtered).
    """
    n_new = int(codes.shape[0])
    ids_np = np.asarray(index.doc_ids)
    filled = _filled_count(ids_np)
    cap_now = int(ids_np.shape[0])
    cap = max(cap_now, segment_capacity(filled + n_new))

    if levels is None:
        key = jax.random.fold_in(jax.random.PRNGKey(_INSERT_KEY), filled)
        levels = _draw_levels(key, n_new, config)
    new_vecs = doc_mean_vectors(codes, mask, index.codebook)

    # host copies, grown to cap
    x = np.zeros((cap, index.doc_vecs.shape[1]), np.float32)
    x[:cap_now] = np.asarray(index.doc_vecs, np.float32)
    x[filled:filled + n_new] = np.asarray(new_vecs, np.float32)
    nbrs = np.full((config.levels, cap, 2 * config.m), -1, np.int64)
    nbrs[:, :cap_now] = np.asarray(index.neighbors)
    lvl = np.full((cap,), -1, np.int64)
    lvl[:cap_now] = np.asarray(index.node_level)
    lvl[filled:filled + n_new] = levels

    entry = int(index.entry) if filled > 0 else -1
    top = int(lvl[entry]) if filled > 0 else -1
    entry, top = _insert_np(x, nbrs, lvl, entry, top,
                            range(filled, filled + n_new),
                            config.ef_construction, config.m)

    slot = jnp.arange(cap)
    new_rows = (slot >= filled) & (slot < filled + n_new)
    out = HNSWIndex(
        doc_vecs=jnp.asarray(x),
        neighbors=jnp.asarray(nbrs, jnp.int32),
        entry=jnp.int32(entry),
        node_level=jnp.asarray(lvl, jnp.int32),
        codes=_grow_dim(index.codes, 0, cap, 0).at[filled:filled + n_new]
              .set(codes.astype(index.codes.dtype)),
        mask=_grow_dim(index.mask, 0, cap, False)
             .at[filled:filled + n_new].set(mask),
        doc_ids=_grow_dim(index.doc_ids, 0, cap, -1)
                .at[filled:filled + n_new].set(doc_ids.astype(jnp.int32)),
        codebook=index.codebook)
    live_out = jnp.where(new_rows, True,
                         _grow_dim(live.astype(bool), 0, cap, False))
    return out, live_out


def hnsw_compact(index: HNSWIndex, live: Array, config: HNSWConfig
                 ) -> Tuple[HNSWIndex, Array]:
    """Drop tombstones: re-insert the live survivors into a fresh graph.

    Survivors keep their STORED level draws and their original relative
    order, so compaction is deterministic (no new randomness) and the
    graph quality matches a bulk build over the live corpus.
    """
    ids_np = np.asarray(index.doc_ids)
    lv = np.asarray(live).astype(bool).reshape(-1)
    keep = np.flatnonzero(lv & (ids_np >= 0))
    n_live = int(keep.size)
    cap = segment_capacity(n_live)

    x = np.zeros((cap, index.doc_vecs.shape[1]), np.float32)
    x[:n_live] = np.asarray(index.doc_vecs, np.float32)[keep]
    lvl = np.full((cap,), -1, np.int64)
    lvl[:n_live] = np.asarray(index.node_level)[keep]
    nbrs = np.full((config.levels, cap, 2 * config.m), -1, np.int64)
    entry, _ = _insert_np(x, nbrs, lvl, -1, -1, range(n_live),
                          config.ef_construction, config.m)

    keep_j = jnp.asarray(keep, jnp.int32)
    out = HNSWIndex(
        doc_vecs=jnp.asarray(x),
        neighbors=jnp.asarray(nbrs, jnp.int32),
        entry=jnp.int32(max(entry, 0)),
        node_level=jnp.asarray(lvl, jnp.int32),
        codes=_grow_dim(index.codes[keep_j], 0, cap, 0),
        mask=_grow_dim(index.mask[keep_j], 0, cap, False),
        doc_ids=_grow_dim(index.doc_ids[keep_j], 0, cap, -1),
        codebook=index.codebook)
    return out, jnp.arange(cap) < n_live


@partial(jax.jit, static_argnames=("ef_search", "k", "scan"))
def search_hnsw_live(index: HNSWIndex, live: Array, q: Array, q_mask: Array,
                     *, ef_search: int, k: int, scan=None
                     ) -> Tuple[Array, Array]:
    """`search_hnsw` with a tombstone mask: dead nodes still route the
    beam (their edges are intact) but are excluded from scoring via the
    valid-mask contract — exactly NEG_INF scores, -1 ids."""
    q_vec = mean_pool(q, q_mask)                              # (B, D)
    _, cand = jax.vmap(
        lambda v: hnsw_candidates(index, v, ef_search=ef_search))(q_vec)
    safe = jnp.where(cand >= 0, cand, 0)
    valid = (cand >= 0) & live[safe]                          # (B, ef)
    cand_codes = index.codes[safe]                            # (B, ef, Md)
    cand_mask = index.mask[safe] & valid[..., None]
    ids = jnp.where(valid, index.doc_ids[safe], -1)
    return scan_mod.quantized_maxsim_topk(
        q, q_mask, cand_codes, cand_mask, index.codebook, k=k,
        doc_ids=ids, valid=valid, scan=scan)
