"""Retrieval indexes over (possibly quantized / binary) patch corpora.

TPU adaptation of the paper's FAISS HNSW / Flat-L2 / bit-packed structures
(docs/design.md §2):

  * FlatIndex    — exhaustive fused scan (codes or floats). The TPU analogue
                   of Flat-L2: one MXU-friendly pass over the corpus shard.
  * IVFIndex     — centroid routing replaces HNSW's graph walk: documents are
                   bucketed by the cluster of their mean patch embedding;
                   a query scores the n_list routing centroids with one
                   matmul and scans only the n_probe nearest buckets.
                   Buckets are stored padded-dense so the scan is a static-
                   shape gather + fused MaxSim (no host-side candidate
                   lists), which jits and shards.
  * HammingIndex — bit-packed binary codes + VPU popcount scan.

All index states are NamedTuple pytrees: they jit, shard (corpus axis over
the mesh — core/distributed.py), checkpoint, and donate cleanly.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantization as quant
from repro.core import scan as scan_mod

Array = jax.Array


# ---------------------------------------------------------------------------
# Shared routing-vector helpers
# ---------------------------------------------------------------------------

def mean_pool(emb: Array, mask: Array) -> Array:
    """Masked mean over the patch axis: (..., M, D), (..., M) -> (..., D)."""
    m = mask[..., None].astype(emb.dtype)
    return jnp.sum(emb * m, axis=-2) / jnp.maximum(jnp.sum(m, axis=-2), 1.0)


def doc_mean_vectors(codes: Array, mask: Array, codebook: Array) -> Array:
    """Document routing vectors: mean of decoded (reconstructed) patches.

    The representation both routing structures (IVF buckets, HNSW graph)
    are built over — (N, Md) codes -> (N, D) float vectors.
    """
    return mean_pool(quant.decode(codes, codebook), mask)


# ---------------------------------------------------------------------------
# Flat index (quantized corpus by default)
# ---------------------------------------------------------------------------

class FlatIndex(NamedTuple):
    codes: Array       # (N, Md) uint8/16 centroid indices
    mask: Array        # (N, Md) bool
    codebook: Array    # (K, D) float32
    doc_ids: Array     # (N,) int32 — global ids (for sharded shards)


def build_flat(codes: Array, mask: Array, codebook: Array,
               doc_ids: Optional[Array] = None) -> FlatIndex:
    n = codes.shape[0]
    if doc_ids is None:
        doc_ids = jnp.arange(n, dtype=jnp.int32)
    return FlatIndex(codes, mask, codebook, doc_ids)


@partial(jax.jit, static_argnames=("k", "scan"))
def search_flat(index: FlatIndex, q: Array, q_mask: Array, *, k: int,
                scan: Optional[scan_mod.ScanConfig] = None
                ) -> Tuple[Array, Array]:
    """Exhaustive ADC MaxSim scan -> (scores (B,k), doc_ids (B,k)).

    Streams the corpus through core/scan.py in `scan.block_docs`-sized
    blocks with top-k folded into the sweep — no (B, N) score matrix.
    When k > N the tail rows carry the -1/sentinel contract (see
    IndexBackend.search) instead of crashing lax.top_k.
    """
    return scan_mod.quantized_maxsim_topk(
        q, q_mask, index.codes, index.mask, index.codebook, k=k,
        doc_ids=index.doc_ids, scan=scan)


def _gather_candidates(candidate_ids: Array, doc_ids: Array,
                       *leaves: Array) -> Tuple[Array, Array, Tuple[Array, ...]]:
    """Gather per-query candidate rows from a shared corpus layout.

    candidate_ids (B, P) are *positions* into the index's doc axis; -1
    marks empty pool slots (the sentinel contract). Returns
    (global_ids (B, P), valid (B, P), gathered leaves each (B, P, ...)).
    Per-query gather cost is O(B * P * row), never O(N).
    """
    valid = candidate_ids >= 0
    safe = jnp.maximum(candidate_ids, 0)
    ids = jnp.where(valid, doc_ids[safe], -1).astype(jnp.int32)
    return ids, valid, tuple(leaf[safe] for leaf in leaves)


@partial(jax.jit, static_argnames=("k", "scan"))
def search_flat_candidates(index: FlatIndex, q: Array, q_mask: Array,
                           candidate_ids: Array, *, k: int,
                           scan: Optional[scan_mod.ScanConfig] = None
                           ) -> Tuple[Array, Array]:
    """ADC MaxSim over a (B, P) candidate pool — the cascade's mid stage.

    Scores only the listed positions via the streaming engine's
    per-query layout; rows with candidate_id -1 (and k > P padding)
    carry the -1/sentinel contract in the output.
    """
    ids, valid, (codes, mask) = _gather_candidates(
        candidate_ids, index.doc_ids, index.codes, index.mask)
    return scan_mod.quantized_maxsim_topk(
        q, q_mask, codes, mask, index.codebook, k=k,
        doc_ids=ids, valid=valid, scan=scan)


class FloatFlatIndex(NamedTuple):
    """Uncompressed baseline (ColPali-Full)."""
    embeddings: Array  # (N, Md, D)
    mask: Array
    doc_ids: Array


def build_float_flat(embeddings: Array, mask: Array,
                     doc_ids: Optional[Array] = None) -> FloatFlatIndex:
    n = embeddings.shape[0]
    if doc_ids is None:
        doc_ids = jnp.arange(n, dtype=jnp.int32)
    return FloatFlatIndex(embeddings, mask, doc_ids)


@partial(jax.jit, static_argnames=("k", "scan"))
def search_float_flat(index: FloatFlatIndex, q: Array, q_mask: Array, *,
                      k: int, scan: Optional[scan_mod.ScanConfig] = None
                      ) -> Tuple[Array, Array]:
    """Exhaustive float MaxSim scan, streamed (see search_flat)."""
    return scan_mod.maxsim_topk(
        q, q_mask, index.embeddings, index.mask, k=k,
        doc_ids=index.doc_ids, scan=scan)


@partial(jax.jit, static_argnames=("k", "scan"))
def search_float_flat_candidates(index: FloatFlatIndex, q: Array,
                                 q_mask: Array, candidate_ids: Array, *,
                                 k: int,
                                 scan: Optional[scan_mod.ScanConfig] = None
                                 ) -> Tuple[Array, Array]:
    """Float MaxSim over a (B, P) candidate pool — the cascade's rerank."""
    ids, valid, (emb, mask) = _gather_candidates(
        candidate_ids, index.doc_ids, index.embeddings, index.mask)
    return scan_mod.maxsim_topk(
        q, q_mask, emb, mask, k=k, doc_ids=ids, valid=valid, scan=scan)


# ---------------------------------------------------------------------------
# IVF index — centroid routing (HNSW replacement)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IVFConfig:
    n_list: int = 64       # routing clusters
    n_probe: int = 8       # clusters scanned per query
    bucket_cap: int = 0    # max docs per bucket (0 = computed from data)
    iters: int = 15        # routing k-means iterations
    restarts: int = 2      # routing k-means restarts (routing tolerates
                           # coarser clustering than the codebook, so this
                           # stays below KMeansConfig's best-of-8 default)
    max_drop_rate: float = 0.01  # build fails above this bucket-overflow
                                 # drop fraction (IVFBackend.build checks)


class IVFIndex(NamedTuple):
    routing_centroids: Array   # (n_list, D)
    bucket_codes: Array        # (n_list, cap, Md) uint8/16
    bucket_mask: Array         # (n_list, cap, Md) bool — patch validity
    bucket_valid: Array        # (n_list, cap) bool — slot occupied
    bucket_doc_ids: Array      # (n_list, cap) int32
    codebook: Array            # (K, D)


def build_ivf(key: Array, codes: Array, mask: Array, codebook: Array,
              config: IVFConfig, doc_ids: Optional[Array] = None) -> IVFIndex:
    """Bucket documents by the routing cluster of their mean decoded patch.

    Padded-dense bucket layout: (n_list, cap, ...). cap defaults to
    2x the mean load (overflowing docs spilling to their 2nd-nearest
    bucket's free slots would complicate things; instead docs beyond cap
    are dropped from that bucket and counted). `ivf_drop_rate` measures
    the dropped fraction; `IVFBackend.build` enforces it against
    `config.max_drop_rate` (build_ivf itself stays a pure structure
    builder).
    """
    n, md = codes.shape
    if doc_ids is None:
        doc_ids = jnp.arange(n, dtype=jnp.int32)
    doc_vec = doc_mean_vectors(codes, mask, codebook)         # (N, D)
    cents, _ = quant.kmeans_fit(
        key, doc_vec, quant.KMeansConfig(k=config.n_list, iters=config.iters,
                                         n_restarts=config.restarts))
    assign_ = quant.assign(doc_vec, cents)                    # (N,)

    cap = config.bucket_cap
    if cap == 0:
        cap = int(max(8, 2 * -(-n // config.n_list)))         # 2x mean load
    bucket_codes, bucket_mask, bucket_valid, bucket_ids = _bucket_scatter(
        codes, mask, doc_ids, assign_, config.n_list, cap)
    return IVFIndex(cents, bucket_codes, bucket_mask, bucket_valid,
                    bucket_ids, codebook)


def _bucket_scatter(codes: Array, mask: Array, doc_ids: Array,
                    assign_: Array, n_list: int, cap: int
                    ) -> Tuple[Array, Array, Array, Array]:
    """Dense scatter into padded (n_list, cap, ...) buckets (pure jnp).

    Shared between `build_ivf` and `make_ivf_segment` (the append path,
    which re-uses existing routing centroids). Docs whose within-bucket
    rank exceeds `cap` scatter to the out-of-bounds slot `cap` and are
    discarded by mode="drop" — routing them to a real slot would clobber
    the doc legitimately stored there.
    """
    n, md = codes.shape
    order = jnp.argsort(assign_, stable=True)
    sorted_cluster = assign_[order]
    # rank within cluster
    same = (sorted_cluster[:, None] == jnp.arange(n_list)[None, :])
    rank_in_cluster = jnp.cumsum(same, axis=0)[jnp.arange(n), sorted_cluster] - 1
    slot = jnp.where(rank_in_cluster < cap, rank_in_cluster, cap)

    bucket_codes = jnp.zeros((n_list, cap, md), codes.dtype)
    bucket_mask = jnp.zeros((n_list, cap, md), bool)
    bucket_valid = jnp.zeros((n_list, cap), bool)
    bucket_ids = jnp.full((n_list, cap), -1, jnp.int32)

    sc, sl = sorted_cluster, slot
    src = order
    bucket_codes = bucket_codes.at[sc, sl].set(codes[src], mode="drop")
    bucket_mask = bucket_mask.at[sc, sl].set(mask[src], mode="drop")
    bucket_valid = bucket_valid.at[sc, sl].set(True, mode="drop")
    bucket_ids = bucket_ids.at[sc, sl].set(doc_ids[src].astype(jnp.int32),
                                           mode="drop")
    return bucket_codes, bucket_mask, bucket_valid, bucket_ids


def ivf_drop_rate(index: IVFIndex, n_docs: int) -> float:
    """Fraction of docs dropped by bucket overflow (should be ~0)."""
    stored = int(jnp.sum(index.bucket_valid))
    return 1.0 - stored / max(n_docs, 1)


@partial(jax.jit, static_argnames=("n_probe", "k", "scan"))
def search_ivf(index: IVFIndex, q: Array, q_mask: Array, *, n_probe: int,
               k: int, scan: Optional[scan_mod.ScanConfig] = None
               ) -> Tuple[Array, Array]:
    """Route to n_probe buckets, stream-scan them, global top-k.

    Returns (scores (B, k), doc_ids (B, k)). The probed pool (B,
    n_probe*cap candidates per query) scores through the streaming
    engine's per-query layout, so the (B, Mq, pool, Md) similarity
    intermediate never materialises. Sentinel contract: when the probed
    buckets hold fewer than k valid documents, the tail rows carry
    doc_id -1 with NEG_INF-or-below scores — callers must ignore
    `id < 0` rows (see IndexBackend.search).
    """
    b = q.shape[0]
    q_vec = mean_pool(q, q_mask)                              # (B, D)
    # Route by *negative squared L2* to the routing centroids — the same
    # metric `quant.assign` bucketed documents with at build time. v0
    # routed by max inner product, which disagrees with L2-nearest for
    # unnormalized vectors, so queries probed the wrong buckets. ||q||^2
    # is constant per query, so 2<q,c> - ||c||^2 preserves the ordering.
    route = (2.0 * (q_vec @ index.routing_centroids.T)
             - jnp.sum(index.routing_centroids ** 2, axis=-1)[None, :])
    # clamp the static probe count: n_probe > n_list would crash top_k
    # (JAX04) — probing every bucket is the correct degenerate behaviour
    n_probe = min(n_probe, index.routing_centroids.shape[0])
    _, probe = jax.lax.top_k(route, n_probe)  # noqa: JAX04 - clamped above

    cand_codes = index.bucket_codes[probe]      # (B, n_probe, cap, Md)
    cand_mask = index.bucket_mask[probe]
    cand_valid = index.bucket_valid[probe]      # (B, n_probe, cap)
    cand_ids = index.bucket_doc_ids[probe]

    cap, md = cand_codes.shape[2], cand_codes.shape[3]
    cand_codes = cand_codes.reshape(b, n_probe * cap, md)
    cand_mask = cand_mask.reshape(b, n_probe * cap, md)
    cand_valid = cand_valid.reshape(b, n_probe * cap)
    cand_ids = cand_ids.reshape(b, n_probe * cap)
    return scan_mod.quantized_maxsim_topk(
        q, q_mask, cand_codes, cand_mask, index.codebook, k=k,
        doc_ids=cand_ids, valid=cand_valid, scan=scan)


# ---------------------------------------------------------------------------
# Hamming (binary) index
# ---------------------------------------------------------------------------

class HammingIndex(NamedTuple):
    codes: Array      # (N, Md) uint16 — b-bit codes (packed form on disk)
    mask: Array       # (N, Md) bool
    doc_ids: Array    # (N,)
    bits: Array       # () int32 — static-ish scalar carried in the pytree


def build_hamming(codes: Array, mask: Array, bits: int,
                  doc_ids: Optional[Array] = None) -> HammingIndex:
    n = codes.shape[0]
    if doc_ids is None:
        doc_ids = jnp.arange(n, dtype=jnp.int32)
    return HammingIndex(codes.astype(jnp.uint16), mask, doc_ids,
                        jnp.int32(bits))


@partial(jax.jit, static_argnames=("k", "bits", "scan"))
def search_hamming(index: HammingIndex, q_codes: Array, q_mask: Array, *,
                   bits: int, k: int,
                   scan: Optional[scan_mod.ScanConfig] = None
                   ) -> Tuple[Array, Array]:
    """Popcount MaxSim scan, streamed (see search_flat)."""
    return scan_mod.hamming_maxsim_topk(
        q_codes, q_mask, index.codes, index.mask, bits=bits, k=k,
        doc_ids=index.doc_ids, scan=scan)


@partial(jax.jit, static_argnames=("k", "bits", "scan"))
def search_hamming_candidates(index: HammingIndex, q_codes: Array,
                              q_mask: Array, candidate_ids: Array, *,
                              bits: int, k: int,
                              scan: Optional[scan_mod.ScanConfig] = None
                              ) -> Tuple[Array, Array]:
    """Popcount MaxSim over a (B, P) candidate pool (per-query layout)."""
    ids, valid, (codes, mask) = _gather_candidates(
        candidate_ids, index.doc_ids, index.codes, index.mask)
    return scan_mod.hamming_maxsim_topk(
        q_codes, q_mask, codes, mask, bits=bits, k=k,
        doc_ids=ids, valid=valid, scan=scan)


# ---------------------------------------------------------------------------
# Segmented LSM corpus store (live add/delete/update — docs/design.md §9)
# ---------------------------------------------------------------------------
#
# A mutable index is an ordered list of immutable *segments* plus a
# tombstone set. Segment 0 is the original build (wrapped as-is, zero
# copy); every `add` appends one pow2-capacity-padded segment built with
# the EXISTING codebook/centroids (no refit); `delete` flips live bits
# (the structure is untouched — tombstoned docs score exactly NEG_INF via
# the scan engine's valid-mask contract); `compact` gathers the live docs
# into a fresh single segment. Search sweeps the segment list threading
# the scan engine's (B, k) merge buffer across segments (`carry=`), which
# is bit-identical to one sweep over the concatenated corpus.

SEG_MIN_CAP = 8  # smallest append-segment capacity (pow2 shape bucketing)


def next_pow2(n: int) -> int:
    """Smallest power of two >= max(n, 1)."""
    return 1 << max(int(n) - 1, 0).bit_length() if n > 1 else 1


def segment_capacity(n: int) -> int:
    """Capacity bucket for an n-doc segment: next pow2, floor SEG_MIN_CAP.

    Pow2 bucketing bounds the set of distinct segment shapes (hence jit
    signatures) at O(log N) across any mutation history, and lets the
    serving layer pre-pad the registry so interleaved add/delete/query
    never mints a recompile (serving/live.py).
    """
    return max(SEG_MIN_CAP, next_pow2(int(n)))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SegmentedState:
    """Ordered immutable segments + per-slot live bits + id->position map.

    segments: tuple of per-backend payloads (FlatIndex / FloatFlatIndex /
        HammingIndex / IVFIndex / graph.HNSWIndex), each carrying its own
        doc_ids; padding slots hold doc_id -1.
    live: one bool array per segment, shaped like that segment's doc-id
        array ((cap,) flat-likes, (n_list, cap) ivf). False = padding OR
        tombstoned; a slot with doc_id >= 0 and live False is a tombstone.
    pos_of_id: (id_cap,) int32 — the flattened slot position (row-major
        across the segment list) of each doc id's unique LIVE occurrence,
        -1 if the id is dead or unassigned. Invariant: every id has at
        most one live slot (upserts tombstone the older occurrence), so
        this map is total over live docs — it is how the per-query
        candidate stages (cascade) resolve global ids to rows.
    """

    segments: Tuple[Any, ...]
    live: Tuple[Array, ...]
    pos_of_id: Array

    def tree_flatten(self):
        return ((self.segments, self.live, self.pos_of_id), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    # -- static geometry (python ints — never traced) ----------------------

    @property
    def n_segments(self) -> int:
        return len(self.segments)

    def slot_counts(self) -> Tuple[int, ...]:
        """Flattened slot count per segment (ivf: n_list * cap)."""
        return tuple(int(np.prod(seg_doc_ids(p).shape))
                     for p in self.segments)

    def offsets(self) -> Tuple[int, ...]:
        """Flattened start position of each segment."""
        out, off = [], 0
        for c in self.slot_counts():
            out.append(off)
            off += c
        return tuple(out)

    # -- host-side occupancy (sync) ----------------------------------------

    def counts(self) -> Tuple[int, int]:
        """(live_docs, tombstoned_docs) — host sync."""
        live = tomb = 0
        for payload, lv in zip(self.segments, self.live):
            ids = np.asarray(seg_doc_ids(payload)).reshape(-1)
            lvf = np.asarray(lv).reshape(-1)
            filled = ids >= 0
            live += int(np.sum(filled & lvf))
            tomb += int(np.sum(filled & ~lvf))
        return live, tomb


def seg_doc_ids(payload) -> Array:
    """The doc-id array of one segment payload (layout-specific name)."""
    if isinstance(payload, IVFIndex):
        return payload.bucket_doc_ids
    return payload.doc_ids


def rebuild_pos_of_id(segments: Tuple, live: Tuple, id_cap: int) -> Array:
    """Recompute the id->flattened-position map from the segment list.

    Host-side O(total slots); correct because each id has at most one
    live slot (the SegmentedState invariant).
    """
    pos = np.full((int(id_cap),), -1, np.int32)
    off = 0
    for payload, lv in zip(segments, live):
        ids = np.asarray(seg_doc_ids(payload)).reshape(-1).astype(np.int64)
        lvf = np.asarray(lv).reshape(-1).astype(bool)
        occ = np.flatnonzero(lvf & (ids >= 0))
        pos[ids[occ]] = (off + occ).astype(np.int32)
        off += ids.size
    return jnp.asarray(pos)


# -- segment construction ---------------------------------------------------

def pad_dim0(arr: Array, cap: int, fill=0) -> Array:
    """Pad dim 0 to `cap` rows with `fill` (no-op when already there)."""
    n = arr.shape[0]
    if n == cap:
        return arr
    pad = jnp.full((cap - n,) + arr.shape[1:], fill, arr.dtype)
    return jnp.concatenate([arr, pad], axis=0)


def make_flat_segment(codes: Array, mask: Array, codebook: Array,
                      doc_ids: Array, cap: Optional[int] = None
                      ) -> Tuple[FlatIndex, Array]:
    """(FlatIndex, live) for an n-doc append, padded to a pow2 capacity."""
    n = codes.shape[0]
    cap = segment_capacity(n) if cap is None else cap
    ix = FlatIndex(pad_dim0(codes, cap), pad_dim0(mask, cap, False),
                   codebook,
                   pad_dim0(doc_ids.astype(jnp.int32), cap, -1))
    return ix, jnp.arange(cap) < n


def make_float_flat_segment(embeddings: Array, mask: Array, doc_ids: Array,
                            cap: Optional[int] = None
                            ) -> Tuple[FloatFlatIndex, Array]:
    n = embeddings.shape[0]
    cap = segment_capacity(n) if cap is None else cap
    ix = FloatFlatIndex(pad_dim0(embeddings, cap),
                        pad_dim0(mask, cap, False),
                        pad_dim0(doc_ids.astype(jnp.int32), cap, -1))
    return ix, jnp.arange(cap) < n


def make_hamming_segment(codes: Array, mask: Array, bits: int,
                         doc_ids: Array, cap: Optional[int] = None
                         ) -> Tuple[HammingIndex, Array]:
    n = codes.shape[0]
    cap = segment_capacity(n) if cap is None else cap
    ix = HammingIndex(pad_dim0(codes.astype(jnp.uint16), cap),
                      pad_dim0(mask, cap, False),
                      pad_dim0(doc_ids.astype(jnp.int32), cap, -1),
                      jnp.int32(bits))
    return ix, jnp.arange(cap) < n


def make_ivf_segment(codes: Array, mask: Array, codebook: Array,
                     centroids: Array, doc_ids: Array,
                     cap: Optional[int] = None) -> Tuple[IVFIndex, Array]:
    """Bucket an append delta through EXISTING routing centroids.

    No re-clustering: the new docs assign to the centroids the base
    segment was built with, so a query's routing decision covers every
    segment with one centroid matmul (`search_ivf_segmented`). The
    default bucket cap is the realised max bucket load (host-computed),
    so an append never drops docs; pass a fixed `cap` for shape-stable
    serving appends.
    """
    doc_vec = doc_mean_vectors(codes, mask, codebook)
    assign_ = quant.assign(doc_vec, centroids)
    n_list = centroids.shape[0]
    if cap is None:
        counts = np.bincount(np.asarray(assign_), minlength=n_list)
        cap = segment_capacity(int(counts.max()) if counts.size else 1)
    bc, bm, bv, bi = _bucket_scatter(codes, mask,
                                     doc_ids.astype(jnp.int32),
                                     assign_, n_list, int(cap))
    return IVFIndex(centroids, bc, bm, bv, bi, codebook), bv


# -- segmented search (full sweep: merge buffer carried across segments) ----

def _empty_topk(b: int, k: int, score_dtype) -> Tuple[Array, Array]:
    return (jnp.full((b, k), scan_mod.score_sentinel(score_dtype),
                     score_dtype),
            jnp.full((b, k), -1, jnp.int32))


@partial(jax.jit, static_argnames=("k", "scan"))
def search_flat_segmented(seg: SegmentedState, q: Array, q_mask: Array, *,
                          k: int, scan: Optional[scan_mod.ScanConfig] = None
                          ) -> Tuple[Array, Array]:
    """ADC MaxSim over a segment list: one sweep per segment, one carried
    (B, k) merge buffer. Tombstoned/padding slots (live False) score
    exactly NEG_INF with id -1 (the valid-mask contract), so deletes are
    honored without touching the stored codes."""
    carry = None
    for payload, live in zip(seg.segments, seg.live):
        carry = scan_mod.quantized_maxsim_topk(
            q, q_mask, payload.codes, payload.mask, payload.codebook, k=k,
            doc_ids=payload.doc_ids, valid=live, scan=scan, carry=carry)
    return carry if carry is not None else _empty_topk(q.shape[0], k,
                                                       jnp.float32)


@partial(jax.jit, static_argnames=("k", "scan"))
def search_float_flat_segmented(seg: SegmentedState, q: Array,
                                q_mask: Array, *, k: int,
                                scan: Optional[scan_mod.ScanConfig] = None
                                ) -> Tuple[Array, Array]:
    carry = None
    for payload, live in zip(seg.segments, seg.live):
        carry = scan_mod.maxsim_topk(
            q, q_mask, payload.embeddings, payload.mask, k=k,
            doc_ids=payload.doc_ids, valid=live, scan=scan, carry=carry)
    return carry if carry is not None else _empty_topk(q.shape[0], k,
                                                       jnp.float32)


@partial(jax.jit, static_argnames=("bits", "k", "scan"))
def search_hamming_segmented(seg: SegmentedState, q_codes: Array,
                             q_mask: Array, *, bits: int, k: int,
                             scan: Optional[scan_mod.ScanConfig] = None
                             ) -> Tuple[Array, Array]:
    carry = None
    for payload, live in zip(seg.segments, seg.live):
        carry = scan_mod.hamming_maxsim_topk(
            q_codes, q_mask, payload.codes, payload.mask, bits=bits, k=k,
            doc_ids=payload.doc_ids, valid=live, scan=scan, carry=carry)
    return carry if carry is not None else _empty_topk(q_codes.shape[0], k,
                                                       jnp.int32)


@partial(jax.jit, static_argnames=("n_probe", "k", "scan"))
def search_ivf_segmented(seg: SegmentedState, q: Array, q_mask: Array, *,
                         n_probe: int, k: int,
                         scan: Optional[scan_mod.ScanConfig] = None
                         ) -> Tuple[Array, Array]:
    """Route ONCE over the shared centroids, probe each segment's buckets.

    Every segment shares the base segment's routing centroids (append
    buckets through them — `make_ivf_segment`), so one centroid matmul
    picks the probe set for the whole list; per-segment probed pools then
    fold into one carried merge buffer.
    """
    b = q.shape[0]
    cents = seg.segments[0].routing_centroids
    q_vec = mean_pool(q, q_mask)
    route = (2.0 * (q_vec @ cents.T)
             - jnp.sum(cents ** 2, axis=-1)[None, :])
    n_probe = min(n_probe, cents.shape[0])
    _, probe = jax.lax.top_k(route, n_probe)  # noqa: JAX04 - clamped above

    carry = None
    for payload, live in zip(seg.segments, seg.live):
        cand_codes = payload.bucket_codes[probe]  # (B, n_probe, cap, Md)
        cand_mask = payload.bucket_mask[probe]
        cand_valid = live[probe]                  # (B, n_probe, cap)
        cand_ids = payload.bucket_doc_ids[probe]
        cap, md = cand_codes.shape[2], cand_codes.shape[3]
        carry = scan_mod.quantized_maxsim_topk(
            q, q_mask,
            cand_codes.reshape(b, n_probe * cap, md),
            cand_mask.reshape(b, n_probe * cap, md),
            payload.codebook, k=k,
            doc_ids=cand_ids.reshape(b, n_probe * cap),
            valid=cand_valid.reshape(b, n_probe * cap),
            scan=scan, carry=carry)
    return carry if carry is not None else _empty_topk(b, k, jnp.float32)


# -- segmented candidate gather (the cascade's stage boundary) --------------

def _gather_segmented(seg: SegmentedState, candidate_ids: Array,
                      leaf_names: Tuple[str, ...]
                      ) -> Tuple[Array, Array, Tuple[Array, ...]]:
    """Resolve (B, P) global doc ids to rows across the segment list.

    Unlike the monolithic `_gather_candidates` (positions == ids), the
    segmented form routes through `pos_of_id`: dead/unknown ids resolve
    to -1 and are never scored. Cost stays O(B * P * row) per segment —
    one clamped gather + select per segment, never O(N).
    """
    id_cap = seg.pos_of_id.shape[0]
    in_range = (candidate_ids >= 0) & (candidate_ids < id_cap)
    safe_ids = jnp.clip(candidate_ids, 0, id_cap - 1)
    pos = jnp.where(in_range, seg.pos_of_id[safe_ids], -1)    # (B, P)
    valid = pos >= 0
    outs = None
    offset = 0
    for payload in seg.segments:
        size = int(np.prod(seg_doc_ids(payload).shape))
        local = pos - offset
        in_seg = valid & (local >= 0) & (local < size)
        idx = jnp.clip(local, 0, size - 1)
        gathered = []
        for nm in leaf_names:
            leaf = getattr(payload, nm)
            g = leaf[idx]                                     # (B, P, ...)
            sel = in_seg.reshape(in_seg.shape + (1,) * (g.ndim - 2))
            gathered.append(jnp.where(sel, g, jnp.zeros_like(g)))
        outs = gathered if outs is None else [
            o | g if o.dtype == jnp.bool_ else o + g
            for o, g in zip(outs, gathered)]
        offset += size
    ids = jnp.where(valid, candidate_ids, -1).astype(jnp.int32)
    return ids, valid, tuple(outs)


@partial(jax.jit, static_argnames=("k", "scan"))
def search_flat_segmented_candidates(
        seg: SegmentedState, q: Array, q_mask: Array, candidate_ids: Array,
        *, k: int, scan: Optional[scan_mod.ScanConfig] = None
        ) -> Tuple[Array, Array]:
    """ADC MaxSim over a (B, P) global-id pool resolved via pos_of_id."""
    ids, valid, (codes, mask) = _gather_segmented(
        seg, candidate_ids, ("codes", "mask"))
    return scan_mod.quantized_maxsim_topk(
        q, q_mask, codes, mask, seg.segments[0].codebook, k=k,
        doc_ids=ids, valid=valid, scan=scan)


@partial(jax.jit, static_argnames=("k", "scan"))
def search_float_flat_segmented_candidates(
        seg: SegmentedState, q: Array, q_mask: Array, candidate_ids: Array,
        *, k: int, scan: Optional[scan_mod.ScanConfig] = None
        ) -> Tuple[Array, Array]:
    ids, valid, (emb, mask) = _gather_segmented(
        seg, candidate_ids, ("embeddings", "mask"))
    return scan_mod.maxsim_topk(
        q, q_mask, emb, mask, k=k, doc_ids=ids, valid=valid, scan=scan)


@partial(jax.jit, static_argnames=("bits", "k", "scan"))
def search_hamming_segmented_candidates(
        seg: SegmentedState, q_codes: Array, q_mask: Array,
        candidate_ids: Array, *, bits: int, k: int,
        scan: Optional[scan_mod.ScanConfig] = None) -> Tuple[Array, Array]:
    ids, valid, (codes, mask) = _gather_segmented(
        seg, candidate_ids, ("codes", "mask"))
    return scan_mod.hamming_maxsim_topk(
        q_codes, q_mask, codes, mask, bits=bits, k=k,
        doc_ids=ids, valid=valid, scan=scan)


def search_hamming_floor(index_or_seg, q_codes: Array, q_mask: Array, *,
                         bits: int, k: int,
                         scan: Optional[scan_mod.ScanConfig] = None
                         ) -> Tuple[Array, Array]:
    """Degraded-serving floor: hamming-only scan with float32 scores.

    The overload degradation ladder's last rung (docs/design.md §11)
    answers straight from the popcount prefilter — no ADC rescore, no
    float rerank. Popcount scores are int32; they are cast to float32
    here so every ladder level hands the serving fan-out dtype-identical
    results (a level flip must never change the response signature).
    Accepts either a `HammingIndex` or a `SegmentedState` of hamming
    segments, matching the cascade's stage-1 state either way.
    """
    if isinstance(index_or_seg, SegmentedState):
        scores, ids = search_hamming_segmented(
            index_or_seg, q_codes, q_mask, bits=bits, k=k, scan=scan)
    else:
        scores, ids = search_hamming(index_or_seg, q_codes, q_mask,
                                     bits=bits, k=k, scan=scan)
    return scores.astype(jnp.float32), ids


def gather_live_rows(seg: SegmentedState, leaf_names: Tuple[str, ...]
                     ) -> Tuple[Tuple[Array, ...], Array]:
    """Host-side gather of every live doc's rows in flattened slot order.

    The compaction primitive: returns (leaves..., doc_ids) with exactly
    the live docs, in the deterministic row-major order of the segment
    list (ivf buckets flatten (n_list, cap) first). Padding and
    tombstones are dropped.
    """
    outs = [[] for _ in leaf_names]
    ids_out = []
    for payload, lv in zip(seg.segments, seg.live):
        ids = np.asarray(seg_doc_ids(payload)).reshape(-1)
        lvf = np.asarray(lv).reshape(-1).astype(bool)
        keep = np.flatnonzero(lvf & (ids >= 0))
        slots = int(ids.size)
        slot_ndim = len(np.shape(seg_doc_ids(payload)))
        for o, nm in zip(outs, leaf_names):
            leaf = np.asarray(getattr(payload, nm))
            o.append(leaf.reshape((slots,) + leaf.shape[slot_ndim:])[keep])
        ids_out.append(ids[keep])
    leaves = tuple(jnp.asarray(np.concatenate(o, axis=0)) for o in outs)
    return leaves, jnp.asarray(np.concatenate(ids_out).astype(np.int32))
