"""Retrieval indexes over (possibly quantized / binary) patch corpora.

TPU adaptation of the paper's FAISS HNSW / Flat-L2 / bit-packed structures
(docs/design.md §2):

  * FlatIndex    — exhaustive fused scan (codes or floats). The TPU analogue
                   of Flat-L2: one MXU-friendly pass over the corpus shard.
  * IVFIndex     — centroid routing replaces HNSW's graph walk: documents are
                   bucketed by the cluster of their mean patch embedding;
                   a query scores the n_list routing centroids with one
                   matmul and scans only the n_probe nearest buckets.
                   Buckets are stored padded-dense so the scan is a static-
                   shape gather + fused MaxSim (no host-side candidate
                   lists), which jits and shards.
  * HammingIndex — bit-packed binary codes + VPU popcount scan.

All index states are NamedTuple pytrees: they jit, shard (corpus axis over
the mesh — core/distributed.py), checkpoint, and donate cleanly.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import quantization as quant
from repro.core import scan as scan_mod

Array = jax.Array


# ---------------------------------------------------------------------------
# Shared routing-vector helpers
# ---------------------------------------------------------------------------

def mean_pool(emb: Array, mask: Array) -> Array:
    """Masked mean over the patch axis: (..., M, D), (..., M) -> (..., D)."""
    m = mask[..., None].astype(emb.dtype)
    return jnp.sum(emb * m, axis=-2) / jnp.maximum(jnp.sum(m, axis=-2), 1.0)


def doc_mean_vectors(codes: Array, mask: Array, codebook: Array) -> Array:
    """Document routing vectors: mean of decoded (reconstructed) patches.

    The representation both routing structures (IVF buckets, HNSW graph)
    are built over — (N, Md) codes -> (N, D) float vectors.
    """
    return mean_pool(quant.decode(codes, codebook), mask)


# ---------------------------------------------------------------------------
# Flat index (quantized corpus by default)
# ---------------------------------------------------------------------------

class FlatIndex(NamedTuple):
    codes: Array       # (N, Md) uint8/16 centroid indices
    mask: Array        # (N, Md) bool
    codebook: Array    # (K, D) float32
    doc_ids: Array     # (N,) int32 — global ids (for sharded shards)


def build_flat(codes: Array, mask: Array, codebook: Array,
               doc_ids: Optional[Array] = None) -> FlatIndex:
    n = codes.shape[0]
    if doc_ids is None:
        doc_ids = jnp.arange(n, dtype=jnp.int32)
    return FlatIndex(codes, mask, codebook, doc_ids)


@partial(jax.jit, static_argnames=("k", "scan"))
def search_flat(index: FlatIndex, q: Array, q_mask: Array, *, k: int,
                scan: Optional[scan_mod.ScanConfig] = None
                ) -> Tuple[Array, Array]:
    """Exhaustive ADC MaxSim scan -> (scores (B,k), doc_ids (B,k)).

    Streams the corpus through core/scan.py in `scan.block_docs`-sized
    blocks with top-k folded into the sweep — no (B, N) score matrix.
    When k > N the tail rows carry the -1/sentinel contract (see
    IndexBackend.search) instead of crashing lax.top_k.
    """
    return scan_mod.quantized_maxsim_topk(
        q, q_mask, index.codes, index.mask, index.codebook, k=k,
        doc_ids=index.doc_ids, scan=scan)


def _gather_candidates(candidate_ids: Array, doc_ids: Array,
                       *leaves: Array) -> Tuple[Array, Array, Tuple[Array, ...]]:
    """Gather per-query candidate rows from a shared corpus layout.

    candidate_ids (B, P) are *positions* into the index's doc axis; -1
    marks empty pool slots (the sentinel contract). Returns
    (global_ids (B, P), valid (B, P), gathered leaves each (B, P, ...)).
    Per-query gather cost is O(B * P * row), never O(N).
    """
    valid = candidate_ids >= 0
    safe = jnp.maximum(candidate_ids, 0)
    ids = jnp.where(valid, doc_ids[safe], -1).astype(jnp.int32)
    return ids, valid, tuple(leaf[safe] for leaf in leaves)


@partial(jax.jit, static_argnames=("k", "scan"))
def search_flat_candidates(index: FlatIndex, q: Array, q_mask: Array,
                           candidate_ids: Array, *, k: int,
                           scan: Optional[scan_mod.ScanConfig] = None
                           ) -> Tuple[Array, Array]:
    """ADC MaxSim over a (B, P) candidate pool — the cascade's mid stage.

    Scores only the listed positions via the streaming engine's
    per-query layout; rows with candidate_id -1 (and k > P padding)
    carry the -1/sentinel contract in the output.
    """
    ids, valid, (codes, mask) = _gather_candidates(
        candidate_ids, index.doc_ids, index.codes, index.mask)
    return scan_mod.quantized_maxsim_topk(
        q, q_mask, codes, mask, index.codebook, k=k,
        doc_ids=ids, valid=valid, scan=scan)


class FloatFlatIndex(NamedTuple):
    """Uncompressed baseline (ColPali-Full)."""
    embeddings: Array  # (N, Md, D)
    mask: Array
    doc_ids: Array


def build_float_flat(embeddings: Array, mask: Array,
                     doc_ids: Optional[Array] = None) -> FloatFlatIndex:
    n = embeddings.shape[0]
    if doc_ids is None:
        doc_ids = jnp.arange(n, dtype=jnp.int32)
    return FloatFlatIndex(embeddings, mask, doc_ids)


@partial(jax.jit, static_argnames=("k", "scan"))
def search_float_flat(index: FloatFlatIndex, q: Array, q_mask: Array, *,
                      k: int, scan: Optional[scan_mod.ScanConfig] = None
                      ) -> Tuple[Array, Array]:
    """Exhaustive float MaxSim scan, streamed (see search_flat)."""
    return scan_mod.maxsim_topk(
        q, q_mask, index.embeddings, index.mask, k=k,
        doc_ids=index.doc_ids, scan=scan)


@partial(jax.jit, static_argnames=("k", "scan"))
def search_float_flat_candidates(index: FloatFlatIndex, q: Array,
                                 q_mask: Array, candidate_ids: Array, *,
                                 k: int,
                                 scan: Optional[scan_mod.ScanConfig] = None
                                 ) -> Tuple[Array, Array]:
    """Float MaxSim over a (B, P) candidate pool — the cascade's rerank."""
    ids, valid, (emb, mask) = _gather_candidates(
        candidate_ids, index.doc_ids, index.embeddings, index.mask)
    return scan_mod.maxsim_topk(
        q, q_mask, emb, mask, k=k, doc_ids=ids, valid=valid, scan=scan)


# ---------------------------------------------------------------------------
# IVF index — centroid routing (HNSW replacement)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IVFConfig:
    n_list: int = 64       # routing clusters
    n_probe: int = 8       # clusters scanned per query
    bucket_cap: int = 0    # max docs per bucket (0 = computed from data)
    iters: int = 15        # routing k-means iterations
    restarts: int = 2      # routing k-means restarts (routing tolerates
                           # coarser clustering than the codebook, so this
                           # stays below KMeansConfig's best-of-8 default)
    max_drop_rate: float = 0.01  # build fails above this bucket-overflow
                                 # drop fraction (IVFBackend.build checks)


class IVFIndex(NamedTuple):
    routing_centroids: Array   # (n_list, D)
    bucket_codes: Array        # (n_list, cap, Md) uint8/16
    bucket_mask: Array         # (n_list, cap, Md) bool — patch validity
    bucket_valid: Array        # (n_list, cap) bool — slot occupied
    bucket_doc_ids: Array      # (n_list, cap) int32
    codebook: Array            # (K, D)


def build_ivf(key: Array, codes: Array, mask: Array, codebook: Array,
              config: IVFConfig, doc_ids: Optional[Array] = None) -> IVFIndex:
    """Bucket documents by the routing cluster of their mean decoded patch.

    Padded-dense bucket layout: (n_list, cap, ...). cap defaults to
    2x the mean load (overflowing docs spilling to their 2nd-nearest
    bucket's free slots would complicate things; instead docs beyond cap
    are dropped from that bucket and counted). `ivf_drop_rate` measures
    the dropped fraction; `IVFBackend.build` enforces it against
    `config.max_drop_rate` (build_ivf itself stays a pure structure
    builder).
    """
    n, md = codes.shape
    if doc_ids is None:
        doc_ids = jnp.arange(n, dtype=jnp.int32)
    doc_vec = doc_mean_vectors(codes, mask, codebook)         # (N, D)
    cents, _ = quant.kmeans_fit(
        key, doc_vec, quant.KMeansConfig(k=config.n_list, iters=config.iters,
                                         n_restarts=config.restarts))
    assign_ = quant.assign(doc_vec, cents)                    # (N,)

    cap = config.bucket_cap
    if cap == 0:
        cap = int(max(8, 2 * -(-n // config.n_list)))         # 2x mean load
    # Dense scatter into padded buckets (host-side friendly, but pure jnp).
    order = jnp.argsort(assign_, stable=True)
    sorted_cluster = assign_[order]
    # rank within cluster
    same = (sorted_cluster[:, None] == jnp.arange(config.n_list)[None, :])
    rank_in_cluster = jnp.cumsum(same, axis=0)[jnp.arange(n), sorted_cluster] - 1
    # overflowing docs (rank >= cap) scatter to the out-of-bounds slot
    # `cap` and are discarded by mode="drop" — routing them to a real slot
    # would clobber the doc legitimately stored there
    slot = jnp.where(rank_in_cluster < cap, rank_in_cluster, cap)

    bucket_codes = jnp.zeros((config.n_list, cap, md), codes.dtype)
    bucket_mask = jnp.zeros((config.n_list, cap, md), bool)
    bucket_valid = jnp.zeros((config.n_list, cap), bool)
    bucket_ids = jnp.full((config.n_list, cap), -1, jnp.int32)

    sc, sl = sorted_cluster, slot
    src = order
    bucket_codes = bucket_codes.at[sc, sl].set(codes[src], mode="drop")
    bucket_mask = bucket_mask.at[sc, sl].set(mask[src], mode="drop")
    bucket_valid = bucket_valid.at[sc, sl].set(True, mode="drop")
    bucket_ids = bucket_ids.at[sc, sl].set(doc_ids[src], mode="drop")

    return IVFIndex(cents, bucket_codes, bucket_mask, bucket_valid,
                    bucket_ids, codebook)


def ivf_drop_rate(index: IVFIndex, n_docs: int) -> float:
    """Fraction of docs dropped by bucket overflow (should be ~0)."""
    stored = int(jnp.sum(index.bucket_valid))
    return 1.0 - stored / max(n_docs, 1)


@partial(jax.jit, static_argnames=("n_probe", "k", "scan"))
def search_ivf(index: IVFIndex, q: Array, q_mask: Array, *, n_probe: int,
               k: int, scan: Optional[scan_mod.ScanConfig] = None
               ) -> Tuple[Array, Array]:
    """Route to n_probe buckets, stream-scan them, global top-k.

    Returns (scores (B, k), doc_ids (B, k)). The probed pool (B,
    n_probe*cap candidates per query) scores through the streaming
    engine's per-query layout, so the (B, Mq, pool, Md) similarity
    intermediate never materialises. Sentinel contract: when the probed
    buckets hold fewer than k valid documents, the tail rows carry
    doc_id -1 with NEG_INF-or-below scores — callers must ignore
    `id < 0` rows (see IndexBackend.search).
    """
    b = q.shape[0]
    q_vec = mean_pool(q, q_mask)                              # (B, D)
    # Route by *negative squared L2* to the routing centroids — the same
    # metric `quant.assign` bucketed documents with at build time. v0
    # routed by max inner product, which disagrees with L2-nearest for
    # unnormalized vectors, so queries probed the wrong buckets. ||q||^2
    # is constant per query, so 2<q,c> - ||c||^2 preserves the ordering.
    route = (2.0 * (q_vec @ index.routing_centroids.T)
             - jnp.sum(index.routing_centroids ** 2, axis=-1)[None, :])
    # clamp the static probe count: n_probe > n_list would crash top_k
    # (JAX04) — probing every bucket is the correct degenerate behaviour
    n_probe = min(n_probe, index.routing_centroids.shape[0])
    _, probe = jax.lax.top_k(route, n_probe)  # noqa: JAX04 - clamped above

    cand_codes = index.bucket_codes[probe]      # (B, n_probe, cap, Md)
    cand_mask = index.bucket_mask[probe]
    cand_valid = index.bucket_valid[probe]      # (B, n_probe, cap)
    cand_ids = index.bucket_doc_ids[probe]

    cap, md = cand_codes.shape[2], cand_codes.shape[3]
    cand_codes = cand_codes.reshape(b, n_probe * cap, md)
    cand_mask = cand_mask.reshape(b, n_probe * cap, md)
    cand_valid = cand_valid.reshape(b, n_probe * cap)
    cand_ids = cand_ids.reshape(b, n_probe * cap)
    return scan_mod.quantized_maxsim_topk(
        q, q_mask, cand_codes, cand_mask, index.codebook, k=k,
        doc_ids=cand_ids, valid=cand_valid, scan=scan)


# ---------------------------------------------------------------------------
# Hamming (binary) index
# ---------------------------------------------------------------------------

class HammingIndex(NamedTuple):
    codes: Array      # (N, Md) uint16 — b-bit codes (packed form on disk)
    mask: Array       # (N, Md) bool
    doc_ids: Array    # (N,)
    bits: Array       # () int32 — static-ish scalar carried in the pytree


def build_hamming(codes: Array, mask: Array, bits: int,
                  doc_ids: Optional[Array] = None) -> HammingIndex:
    n = codes.shape[0]
    if doc_ids is None:
        doc_ids = jnp.arange(n, dtype=jnp.int32)
    return HammingIndex(codes.astype(jnp.uint16), mask, doc_ids,
                        jnp.int32(bits))


@partial(jax.jit, static_argnames=("k", "bits", "scan"))
def search_hamming(index: HammingIndex, q_codes: Array, q_mask: Array, *,
                   bits: int, k: int,
                   scan: Optional[scan_mod.ScanConfig] = None
                   ) -> Tuple[Array, Array]:
    """Popcount MaxSim scan, streamed (see search_flat)."""
    return scan_mod.hamming_maxsim_topk(
        q_codes, q_mask, index.codes, index.mask, bits=bits, k=k,
        doc_ids=index.doc_ids, scan=scan)


@partial(jax.jit, static_argnames=("k", "bits", "scan"))
def search_hamming_candidates(index: HammingIndex, q_codes: Array,
                              q_mask: Array, candidate_ids: Array, *,
                              bits: int, k: int,
                              scan: Optional[scan_mod.ScanConfig] = None
                              ) -> Tuple[Array, Array]:
    """Popcount MaxSim over a (B, P) candidate pool (per-query layout)."""
    ids, valid, (codes, mask) = _gather_candidates(
        candidate_ids, index.doc_ids, index.codes, index.mask)
    return scan_mod.hamming_maxsim_topk(
        q_codes, q_mask, codes, mask, bits=bits, k=k,
        doc_ids=ids, valid=valid, scan=scan)
