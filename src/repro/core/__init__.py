"""HPC-ColPali core: quantization, pruning, binary encoding, late
interaction, indexes, end-to-end pipeline, and mesh-sharded retrieval."""

# NOTE: `pipeline` is deliberately NOT imported here — it is the v0 compat
# shim over `repro.retrieval`, whose backends import these core modules;
# eager-importing it from the package init would create an import cycle.
# Use `from repro.core import pipeline` (a plain submodule import) as before.
from repro.core import (  # noqa: F401
    binary,
    distributed,
    index,
    late_interaction,
    pruning,
    quantization,
    scan,
)
