"""HPC-ColPali core: quantization, pruning, binary encoding, late
interaction, indexes, end-to-end pipeline, and mesh-sharded retrieval."""

from repro.core import (  # noqa: F401
    binary,
    distributed,
    index,
    late_interaction,
    pipeline,
    pruning,
    quantization,
)
