"""Late-interaction (MaxSim) scoring — float, quantized (ADC), and binary.

score(q, d) = sum_i  max_j  <q_i, d_j>        (ColBERT / ColPali)

Variants implemented here are the canonical jnp forms; the tiled Pallas
kernels in kernels/{maxsim,quantized_maxsim,hamming}.py are drop-in
replacements for the inner scan and are validated against these.

Quantized scoring uses the ADC (asymmetric distance computation) trick:
queries stay float, documents are 1-byte codes. We precompute the
query-token x centroid table  T = Q @ C^T  (Mq x K dots, once per query),
after which scoring a document patch is a pure table gather — zero matmul
FLOPs per document. This is the TPU-native realisation of the paper's
"decode each code back to its centroid then search" (§III-E1): instead of
materialising a decoded float corpus in HBM (undoing the 32x storage win),
the decode is folded into a VMEM table lookup. See docs/design.md §2.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core import binary as binary_mod

Array = jax.Array
NEG_INF = -1e30


def _masked_max(sim: Array, d_mask: Array) -> Array:
    """Max over the last (doc-patch) axis, ignoring invalid patches.

    sim: (..., Mq, Md), d_mask broadcastable (..., 1, Md) -> (..., Mq).
    """
    sim = jnp.where(d_mask, sim, NEG_INF)
    return jnp.max(sim, axis=-1)


def maxsim(q: Array, q_mask: Array, d: Array, d_mask: Array) -> Array:
    """Float late interaction.

    Args:
      q:      (B, Mq, D) query patch embeddings.
      q_mask: (B, Mq) bool.
      d:      (N, Md, D) document patch embeddings.
      d_mask: (N, Md) bool.
    Returns:
      scores (B, N) float32.
    """
    sim = jnp.einsum("bqd,nkd->bnqk", q, d,
                     preferred_element_type=jnp.float32)
    per_q = _masked_max(sim, d_mask[None, :, None, :])        # (B, N, Mq)
    per_q = per_q * q_mask[:, None, :].astype(per_q.dtype)
    return jnp.sum(per_q, axis=-1)


def adc_table(q: Array, codebook: Array) -> Array:
    """Query-token x centroid similarity table T (B, Mq, K)."""
    return jnp.einsum("bqd,kd->bqk", q, codebook,
                      preferred_element_type=jnp.float32)


def quantized_maxsim(q: Array, q_mask: Array, d_codes: Array, d_mask: Array,
                     codebook: Array) -> Array:
    """ADC late interaction over a quantized corpus.

    Args:
      q:        (B, Mq, D) float queries.
      d_codes:  (N, Md) uint8/uint16 centroid indices.
      codebook: (K, D).
    Returns:
      scores (B, N) float32 — identical (up to fp assoc.) to
      maxsim(q, decode(d_codes)).
    """
    table = adc_table(q, codebook)                            # (B, Mq, K)
    codes = d_codes.astype(jnp.int32)                         # (N, Md)
    # Gather: sim[b, n, i, j] = table[b, i, codes[n, j]]
    sim = table[:, :, codes]                                  # (B, Mq, N, Md)
    sim = jnp.moveaxis(sim, 2, 1)                             # (B, N, Mq, Md)
    per_q = _masked_max(sim, d_mask[None, :, None, :])
    per_q = per_q * q_mask[:, None, :].astype(per_q.dtype)
    return jnp.sum(per_q, axis=-1)


def quantized_maxsim_decode(q: Array, q_mask: Array, d_codes: Array,
                            d_mask: Array, codebook: Array) -> Array:
    """Decode-then-score variant (the paper's literal §III-E1 path).

    Equivalent to quantized_maxsim; kept as an equivalence oracle and for
    measuring the HBM-traffic delta in benchmarks/roofline.py.
    """
    d = jnp.take(codebook, d_codes.astype(jnp.int32), axis=0)
    return maxsim(q, q_mask, d, d_mask)


def binary_maxsim(q_codes: Array, q_mask: Array, d_codes: Array,
                  d_mask: Array, bits: int) -> Array:
    """Hamming-similarity late interaction (binary mode, §III-D).

    sim(i, j) = bits - hamming(q_i, d_j); scores are int32 sums.
    """
    sim = binary_mod.hamming_sim_matrix(
        q_codes[:, None, :], d_codes[None, :, :], bits)       # (B, N, Mq, Md)
    sim = jnp.where(d_mask[None, :, None, :], sim, jnp.int32(-(2 ** 20)))
    per_q = jnp.max(sim, axis=-1)                             # (B, N, Mq)
    per_q = per_q * q_mask[:, None, :].astype(per_q.dtype)
    return jnp.sum(per_q, axis=-1).astype(jnp.int32)


def single_vector_score(q: Array, q_mask: Array, d: Array, d_mask: Array) -> Array:
    """DistilCol-style single-vector baseline: mean-pool both sides, dot.

    (B, Mq, D) x (N, Md, D) -> (B, N). Used as the paper's DistilCol stand-in.
    """
    qm = q_mask[..., None].astype(q.dtype)
    dm = d_mask[..., None].astype(d.dtype)
    q_pool = jnp.sum(q * qm, axis=1) / jnp.maximum(jnp.sum(qm, axis=1), 1.0)
    d_pool = jnp.sum(d * dm, axis=1) / jnp.maximum(jnp.sum(dm, axis=1), 1.0)
    q_pool = q_pool / jnp.maximum(jnp.linalg.norm(q_pool, axis=-1, keepdims=True), 1e-9)
    d_pool = d_pool / jnp.maximum(jnp.linalg.norm(d_pool, axis=-1, keepdims=True), 1e-9)
    return q_pool @ d_pool.T


def late_interaction_flops(mq: int, md: int, d: int, n_docs: int) -> int:
    """FLOPs of one query's float late interaction over n_docs documents."""
    return 2 * mq * md * d * n_docs


def adc_flops(mq: int, md: int, d: int, k: int, n_docs: int) -> int:
    """FLOPs of ADC scoring: one table build + per-doc gathers (0 matmul)."""
    return 2 * mq * k * d  # table; gather/max/sum are O(mq*md*n_docs) adds
