"""Optional binary encoding + Hamming similarity (HPC-ColPali §III-D).

Each centroid index q_i is its own b-bit binary string (b = ceil(log2 K)),
so Hamming distance between two codes is simply

    popcount(code_a XOR code_b)        (restricted to the low b bits)

— no learned hashing involved, exactly as in the paper. TPU adaptation
(docs/design.md §2): x86 POPCNT becomes ``jax.lax.population_count`` on the VPU;
the scan kernel lives in kernels/hamming.py. For storage accounting we
bit-pack code streams to ceil(N*b/8) bytes (the paper's 57x number for
K=512/b=9); compute unpacks to int32 lanes, which is free relative to the
HBM read of the packed words.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def bits_for_k(k: int) -> int:
    """b = ceil(log2 K)."""
    return max(1, int(math.ceil(math.log2(k))))


def hamming_distance(a: Array, b: Array, bits: int) -> Array:
    """Elementwise Hamming distance between integer codes (broadcasting).

    Only the low `bits` bits are meaningful; inputs are masked to them.
    """
    mask = jnp.uint32((1 << bits) - 1)
    ax = a.astype(jnp.uint32) & mask
    bx = b.astype(jnp.uint32) & mask
    return jax.lax.population_count(ax ^ bx).astype(jnp.int32)


def hamming_sim_matrix(q_codes: Array, d_codes: Array, bits: int) -> Array:
    """Similarity matrix b - hamming for q (..., Mq) x d (..., Md).

    Returns (..., Mq, Md) int32 similarity (higher = closer).
    """
    h = hamming_distance(q_codes[..., :, None], d_codes[..., None, :], bits)
    return bits - h


# ---------------------------------------------------------------------------
# Bit packing (storage layer). Streams of b-bit codes -> uint8 buffer.
# numpy-side (host, offline indexing); round-trip tested.
# ---------------------------------------------------------------------------

def pack_codes(codes: np.ndarray, bits: int) -> np.ndarray:
    """Pack integer codes (N,) into a uint8 buffer of ceil(N*bits/8) bytes."""
    codes = np.asarray(codes, dtype=np.uint32).ravel()
    n = codes.shape[0]
    total_bits = n * bits
    out = np.zeros((total_bits + 7) // 8, dtype=np.uint8)
    bitpos = np.arange(n, dtype=np.int64) * bits
    for b in range(bits):
        pos = bitpos + b
        byte_idx = pos >> 3
        bit_in_byte = (pos & 7).astype(np.uint8)
        bit_vals = ((codes >> b) & 1).astype(np.uint8)
        np.bitwise_or.at(out, byte_idx, bit_vals << bit_in_byte)
    return out


def unpack_codes(packed: np.ndarray, bits: int, n: int) -> np.ndarray:
    """Inverse of pack_codes -> uint32 codes (n,)."""
    packed = np.asarray(packed, dtype=np.uint8)
    out = np.zeros(n, dtype=np.uint32)
    bitpos = np.arange(n, dtype=np.int64) * bits
    for b in range(bits):
        pos = bitpos + b
        byte_idx = pos >> 3
        bit_in_byte = (pos & 7).astype(np.uint8)
        bit = (packed[byte_idx] >> bit_in_byte) & 1
        out |= bit.astype(np.uint32) << b
    return out


def packed_nbytes(n_codes: int, bits: int) -> int:
    """Storage bytes for n_codes b-bit codes (paper Table III arithmetic)."""
    return (n_codes * bits + 7) // 8


# ---------------------------------------------------------------------------
# Word-packed layout for the Pallas scan kernel: 32/bits codes per uint32 is
# awkward for b=9; instead we pack each code into a fixed 16-bit lane and put
# two codes per uint32 word (b <= 16 always holds for K <= 65536). XOR +
# popcount on the word then sums the two lanes' Hamming distances, which the
# kernel exploits to halve HBM traffic vs uint32-per-code.
# ---------------------------------------------------------------------------

def pack_u16_pairs(codes: Array) -> Array:
    """codes (..., M) -> packed uint32 (..., M/2): two 16-bit lanes per word.

    M must be even (pad with zeros + mask upstream).
    """
    assert codes.shape[-1] % 2 == 0, "pad code count to even before packing"
    c = codes.astype(jnp.uint32)
    lo = c[..., 0::2]
    hi = c[..., 1::2]
    return lo | (hi << 16)


def unpack_u16_pairs(packed: Array) -> Array:
    lo = packed & jnp.uint32(0xFFFF)
    hi = packed >> 16
    return jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)
