"""Streaming ADC scan engine: blocked score + top-k fusion.

Every backend's scoring hot path runs through this module. The naive jnp
forms in core/late_interaction.py materialise a (B, Mq, N, Md) similarity
tensor (the `table[:, :, codes]` gather) — ~131 GB at B=8, Mq=32, Md=128,
N=1M — which caps the corpus at whatever fits in device memory *per query
batch*. This engine instead sweeps the corpus in fixed-size doc blocks
under one `lax.scan`:

  * each block is scored by an `impl` dispatcher — the Pallas
    `quantized_maxsim_pallas` kernel on TPU (`auto`), the blocked jnp
    gather elsewhere (`jnp`), or the kernel's interpreter (`interpret`,
    tests only);
  * top-k is folded into the sweep: a running (B, k) merge buffer is
    top-k'd against each block's (B, block) scores, so neither the
    (B, Mq, N, Md) similarity intermediate NOR the (B, N) score matrix
    ever exists. Peak scan memory is O(B * Mq * block_docs * Md); corpus
    capacity is bounded by the codes alone, O(N * Md) bytes.

Numerical contract: per-document scores are bit-identical to the
unblocked oracles (blocking the doc axis does not touch any per-doc
reduction), and the merge preserves `lax.top_k`'s lowest-index
tie-breaking — blocks are visited in doc order and the carried buffer
sits before the new block in each merge, so equal scores resolve to the
lowest doc index exactly as one global top_k would. The two layouts:

  * shared corpus  — codes (N, Md), every query scores every doc
    (flat / float_flat / hamming);
  * per-query candidates — codes (B, P, Md), each query scores its own
    pool (ivf probed buckets, hnsw beam survivors, facade rerank).

Sentinel contract (IndexBackend.search): result rows beyond the valid
pool carry doc_id -1; their score is the merge buffer's init value
(-inf for float scores), strictly below any real document's score — so a
degenerate all-patches-masked document (score ~ Mq * NEG_INF, finite)
still outranks the sentinel and is returned when k allows, matching the
unblocked oracle. Documents with `valid=False` (empty bucket slots,
unreachable beam rows) score exactly NEG_INF with id -1, the v0
convention. See docs/design.md §2.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import late_interaction as li
from repro.kernels import hamming as hamming_k
from repro.kernels import maxsim as maxsim_k
from repro.kernels import quantized_maxsim as qmaxsim_k
from repro.kernels import vmem

Array = jax.Array
NEG_INF = li.NEG_INF


@dataclasses.dataclass(frozen=True)
class ScanConfig:
    """Static knobs of the streaming scan (hashable — jit-static).

    block_docs: documents scored per sweep step. Peak scan memory is
        O(B * Mq * block_docs * Md) — the default keeps an 8x32-query
        batch over Md=128 patches around 128 MB of block similarities.
    impl: "auto" (Pallas kernel on TPU, blocked jnp elsewhere),
        "pallas", "jnp", or "interpret" (Pallas interpreter, tests).
    """

    block_docs: int = 256
    impl: str = "auto"


DEFAULT = ScanConfig()


def resolve_impl(impl: str) -> str:
    """Resolve the dispatcher key to a concrete block scorer.

    The single auto->pallas-on-TPU policy for the repo: kernels/ops.py
    delegates here too. "ref" (ops.py's name for the compiled-XLA
    oracle) is accepted as an alias of "jnp".
    """
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl == "ref":
        return "jnp"
    if impl not in ("pallas", "jnp", "interpret"):
        raise ValueError(
            f"unknown scan impl {impl!r}; expected auto|pallas|jnp|"
            "interpret (or ref, an alias of jnp)")
    return impl


def score_sentinel(dtype) -> Array:
    """Merge-buffer init value: below every representable real score."""
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.array(jnp.iinfo(dtype).min, dtype)
    return jnp.array(-jnp.inf, dtype)


def _kernel_tile(t: int, default: int, fits=None) -> int:
    """Inner Pallas doc tile for a t-doc block (VMEM-sized, divides t).

    ``fits(tile) -> bool`` is the kernel's VMEM predicate (its
    ``*_vmem_bytes`` footprint vs ``kernels.vmem.VMEM_BUDGET_BYTES``).
    The tile halves — staying a divisor of ``t`` — until it fits, so a
    wide geometry (e.g. the ADC kernel's one-hot tile at K=512, Md=128)
    gets a smaller doc tile instead of a Mosaic VMEM failure; if no
    halving fits, the kernel's own ``ValueError`` surfaces the computed
    footprint.
    """
    tile = default if (t > default and t % default == 0) else t
    if fits is not None:
        while tile > 1 and tile % 2 == 0 and not fits(tile):
            tile //= 2
    return tile


# ---------------------------------------------------------------------------
# The streaming sweep
# ---------------------------------------------------------------------------

def _streaming_topk(score_block, payload: tuple, doc_ids: Array,
                    valid: Array, *, b: int, n: int, k: int, block_docs: int,
                    per_query: bool, score_dtype,
                    carry: Optional[Tuple[Array, Array]] = None
                    ) -> Tuple[Array, Array]:
    """lax.scan over doc blocks with a running (B, k) top-k merge buffer.

    score_block(*payload_block) -> (B, T) scores for one block; payload
    leaves have the doc axis at dim 1 (per_query) or dim 0 (shared).

    `carry`, if given, seeds the merge buffer with a previous sweep's
    (scores (B, k), ids (B, k)) — the cross-segment continuation used by
    the segmented searches (core/index.py): sweeping segment s+1 with
    segment s's buffer as carry is bit-identical to one sweep over the
    concatenated corpus, because the carried buffer sits first in every
    merge (ties resolve to the earlier segment, i.e. the lower global
    position, exactly as one global lax.top_k would).
    """
    sent = score_sentinel(score_dtype)
    if carry is not None:
        init = (carry[0].astype(score_dtype), carry[1].astype(jnp.int32))
    else:
        init = (jnp.full((b, k), sent, score_dtype),
                jnp.full((b, k), -1, jnp.int32))
    if n == 0:
        return init
    block = max(1, min(block_docs, n))
    axis = 1 if per_query else 0
    doc_ids = doc_ids.astype(jnp.int32)
    invalid_score = jnp.array(NEG_INF, score_dtype) if \
        jnp.issubdtype(jnp.dtype(score_dtype), jnp.floating) else sent

    def merge(carry, start, t):
        """Score docs [start, start+t) and fold into the (B, k) buffer."""
        top_s, top_i = carry
        blk = tuple(jax.lax.dynamic_slice_in_dim(a, start, t, axis)
                    for a in payload)
        ids = jax.lax.dynamic_slice_in_dim(doc_ids, start, t,
                                           doc_ids.ndim - 1)
        v = jax.lax.dynamic_slice_in_dim(valid, start, t, valid.ndim - 1)
        s = score_block(*blk)                                 # (B, T)
        if v.ndim == 1:
            v = jnp.broadcast_to(v[None], s.shape)
        if ids.ndim == 1:
            ids = jnp.broadcast_to(ids[None], s.shape)
        # Caller-invalid slots (empty buckets, unreachable beam rows)
        # score exactly NEG_INF — the v0 convention. (Unfilled buffer
        # rows keep the init sentinel, strictly below every real doc.)
        s = jnp.where(v, s, invalid_score)
        ids = jnp.where(v, ids, -1)
        # Carried buffer first: equal scores resolve to the earlier
        # (lower-id) document, matching one global lax.top_k.
        cat_s = jnp.concatenate([top_s, s], axis=1)
        cat_i = jnp.concatenate([top_i, ids], axis=1)
        new_s, sel = jax.lax.top_k(cat_s, k)
        return new_s, jnp.take_along_axis(cat_i, sel, axis=1)

    # Full blocks sweep under lax.scan; a ragged N % block tail is scored
    # once at its natural (static) size — no padded corpus copy, no
    # in-range masking.
    n_full, tail = divmod(n, block)
    carry = init
    if n_full:
        carry, _ = jax.lax.scan(
            lambda c, j: (merge(c, j * block, block), None),
            carry, jnp.arange(n_full))
    if tail:
        carry = merge(carry, n_full * block, tail)
    return carry


def _prep(n: int, doc_ids: Optional[Array], valid: Optional[Array],
          per_query: bool, b: int) -> Tuple[Array, Array]:
    if doc_ids is None:
        doc_ids = jnp.arange(n, dtype=jnp.int32)
    if valid is None:
        valid = jnp.ones((b, n) if per_query and doc_ids.ndim == 2
                         else (n,), bool)
    return doc_ids, valid


# ---------------------------------------------------------------------------
# ADC (quantized) scan — the paper's hot path
# ---------------------------------------------------------------------------

def _adc_reduce(sim, d_mask_btm, q_mask):
    """Shared ADC tail: masked per-patch max, query-weighted sum.

    sim (B, Mq, T, Md) gathered table values; d_mask_btm broadcastable
    to (B, T, 1, Md) — li.quantized_maxsim minus the table build/gather.
    """
    sim = jnp.moveaxis(sim, 2, 1)                         # (B, T, Mq, Md)
    sim = jnp.where(d_mask_btm, sim, NEG_INF)
    per_q = jnp.max(sim, axis=-1)
    per_q = per_q * q_mask[:, None, :].astype(per_q.dtype)
    return jnp.sum(per_q, axis=-1)


def quantized_maxsim_topk(q: Array, q_mask: Array, codes: Array,
                          d_mask: Array, codebook: Array, *, k: int,
                          doc_ids: Optional[Array] = None,
                          valid: Optional[Array] = None,
                          scan: Optional[ScanConfig] = None,
                          carry: Optional[Tuple[Array, Array]] = None
                          ) -> Tuple[Array, Array]:
    """Streaming fused ADC MaxSim top-k.

    q (B, Mq, D), q_mask (B, Mq) bool, codebook (K, D);
    codes/d_mask (N, Md) shared or (B, P, Md) per-query candidates.
    Optional doc_ids ((N,) or (B, P)) map scan positions to global ids;
    optional valid ((N,) or (B, P)) marks real pool slots; optional
    carry seeds the merge buffer with a previous sweep's (B, k) result
    (the cross-segment continuation — see _streaming_topk).
    -> (scores (B, k) f32, doc_ids (B, k) i32) per IndexBackend.search.
    """
    scan = scan if scan is not None else DEFAULT
    mode = resolve_impl(scan.impl)
    per_query = codes.ndim == 3
    b = q.shape[0]
    n = codes.shape[1] if per_query else codes.shape[0]
    table = li.adc_table(q, codebook)                     # (B, Mq, K)
    doc_ids, valid = _prep(n, doc_ids, valid, per_query, b)

    if mode == "jnp":
        if per_query:
            def score_block(c, m):
                sim = jax.vmap(lambda tab, cc: tab[:, cc])(
                    table, c.astype(jnp.int32))           # (B, Mq, T, Md)
                return _adc_reduce(sim, m[:, :, None, :], q_mask)
        else:
            def score_block(c, m):
                sim = table[:, :, c.astype(jnp.int32)]    # (B, Mq, T, Md)
                return _adc_reduce(sim, m[None, :, None, :], q_mask)
    else:
        interpret = mode == "interpret"
        qm_f = q_mask.astype(jnp.float32)
        mq_n, k_n = table.shape[1], table.shape[2]
        md_n = codes.shape[-1]

        def qfits(tile):
            return vmem.fits(qmaxsim_k.qmaxsim_vmem_bytes(
                tile, mq_n, k_n, md_n))

        if per_query:
            def score_block(c, m):
                def one(tab, qm1, cc, mm):
                    tile = _kernel_tile(cc.shape[0], 32, fits=qfits)
                    return qmaxsim_k.quantized_maxsim_pallas(
                        tab[None], qm1[None], cc.astype(jnp.int32),
                        mm.astype(jnp.float32), block_docs=tile,
                        interpret=interpret)[0]
                return jax.vmap(one)(table, qm_f, c, m)
        else:
            def score_block(c, m):
                tile = _kernel_tile(c.shape[0], 32, fits=qfits)
                return qmaxsim_k.quantized_maxsim_pallas(
                    table, qm_f, c.astype(jnp.int32), m.astype(jnp.float32),
                    block_docs=tile, interpret=interpret)

    return _streaming_topk(score_block, (codes, d_mask), doc_ids, valid,
                           b=b, n=n, k=k, block_docs=scan.block_docs,
                           per_query=per_query, score_dtype=jnp.float32,
                           carry=carry)


# ---------------------------------------------------------------------------
# Float scan (uncompressed baseline)
# ---------------------------------------------------------------------------

def maxsim_topk(q: Array, q_mask: Array, docs: Array, d_mask: Array, *,
                k: int, doc_ids: Optional[Array] = None,
                valid: Optional[Array] = None,
                scan: Optional[ScanConfig] = None,
                carry: Optional[Tuple[Array, Array]] = None
                ) -> Tuple[Array, Array]:
    """Streaming float MaxSim top-k.

    docs/d_mask are either a shared (N, Md, D) corpus or (B, P, Md, D)
    per-query candidate pools (the cascade's float rerank stage) — same
    two layouts as `quantized_maxsim_topk`.
    """
    scan = scan if scan is not None else DEFAULT
    mode = resolve_impl(scan.impl)
    per_query = docs.ndim == 4
    b = q.shape[0]
    n = docs.shape[1] if per_query else docs.shape[0]
    doc_ids, valid = _prep(n, doc_ids, valid, per_query, b)

    if mode == "jnp":
        if per_query:
            def score_block(d, m):
                return jax.vmap(
                    lambda q1, qm1, d1, m1: li.maxsim(q1[None], qm1[None],
                                                      d1, m1)[0]
                )(q, q_mask, d, m)
        else:
            def score_block(d, m):
                return li.maxsim(q, q_mask, d, m)
    else:
        interpret = mode == "interpret"
        qm_f = q_mask.astype(jnp.float32)

        mq_n, md_n, d_n = q.shape[1], docs.shape[-2], docs.shape[-1]

        def mfits(tile):
            return vmem.fits(maxsim_k.maxsim_vmem_bytes(
                tile, mq_n, md_n, d_n))

        if per_query:
            def score_block(d, m):
                def one(q1, qm1, d1, m1):
                    tile = _kernel_tile(d1.shape[0], 16, fits=mfits)
                    return maxsim_k.maxsim_pallas(
                        q1[None], qm1[None], d1, m1.astype(jnp.float32),
                        block_docs=tile, interpret=interpret)[0]
                return jax.vmap(one)(q, qm_f, d, m)
        else:
            def score_block(d, m):
                tile = _kernel_tile(d.shape[0], 16, fits=mfits)
                return maxsim_k.maxsim_pallas(q, qm_f, d,
                                              m.astype(jnp.float32),
                                              block_docs=tile,
                                              interpret=interpret)

    return _streaming_topk(score_block, (docs, d_mask), doc_ids, valid,
                           b=b, n=n, k=k, block_docs=scan.block_docs,
                           per_query=per_query, score_dtype=jnp.float32,
                           carry=carry)


# ---------------------------------------------------------------------------
# Hamming (binary) scan
# ---------------------------------------------------------------------------

def hamming_maxsim_topk(q_codes: Array, q_mask: Array, d_codes: Array,
                        d_mask: Array, *, bits: int, k: int,
                        doc_ids: Optional[Array] = None,
                        valid: Optional[Array] = None,
                        scan: Optional[ScanConfig] = None,
                        carry: Optional[Tuple[Array, Array]] = None
                        ) -> Tuple[Array, Array]:
    """Streaming binary MaxSim top-k.

    d_codes/d_mask are either a shared (N, Md) code corpus or (B, P, Md)
    per-query candidate pools — the same two layouts as
    `quantized_maxsim_topk`. Scores are int32 on every impl (v0's
    li.binary_maxsim dtype; the sentinel is the int32 minimum). The
    Pallas kernel accumulates in f32 (its documented contract); its
    block scores are clamped to the int32 range and cast — real scores
    (|s| <= bits * Mq) are exact, only the degenerate
    all-patches-masked sums (~ -Mq * 2^20) can lose ULPs.
    """
    scan = scan if scan is not None else DEFAULT
    mode = resolve_impl(scan.impl)
    per_query = d_codes.ndim == 3
    b = q_codes.shape[0]
    n = d_codes.shape[1] if per_query else d_codes.shape[0]
    doc_ids, valid = _prep(n, doc_ids, valid, per_query, b)
    ii = jnp.iinfo(jnp.int32)

    if mode == "jnp":
        if per_query:
            def score_block(d, m):
                return jax.vmap(
                    lambda q1, qm1, d1, m1: li.binary_maxsim(
                        q1[None], qm1[None], d1, m1, bits)[0]
                )(q_codes, q_mask, d, m)
        else:
            def score_block(d, m):
                return li.binary_maxsim(q_codes, q_mask, d, m, bits)
    else:
        interpret = mode == "interpret"
        qm_f = q_mask.astype(jnp.float32)

        mq_n, md_n = q_codes.shape[1], d_codes.shape[-1]

        def hfits(tile):
            return vmem.fits(hamming_k.hamming_vmem_bytes(
                tile, mq_n, md_n))

        if per_query:
            def score_block(d, m):
                def one(q1, qm1, d1, m1):
                    tile = _kernel_tile(d1.shape[0], 64, fits=hfits)
                    return hamming_k.hamming_maxsim_pallas(
                        q1[None], qm1[None], d1.astype(jnp.int32),
                        m1.astype(jnp.float32), bits=bits,
                        block_docs=tile, interpret=interpret)[0]
                out = jax.vmap(one)(q_codes, qm_f, d, m)
                return jnp.maximum(out, float(ii.min)).astype(jnp.int32)
        else:
            def score_block(d, m):
                tile = _kernel_tile(d.shape[0], 64, fits=hfits)
                out = hamming_k.hamming_maxsim_pallas(
                    q_codes, qm_f, d.astype(jnp.int32), m.astype(jnp.float32),
                    bits=bits, block_docs=tile, interpret=interpret)
                # only the lower bound can be exceeded (NEG_INF-masked
                # sums); -2^31 is f32-exact, real scores are far below 2^31
                return jnp.maximum(out, float(ii.min)).astype(jnp.int32)

    return _streaming_topk(score_block, (d_codes, d_mask), doc_ids, valid,
                           b=b, n=n, k=k, block_docs=scan.block_docs,
                           per_query=per_query, score_dtype=jnp.int32,
                           carry=carry)
