"""HPC-ColPali end-to-end pipeline (paper §III-E) — v0 compatibility shim.

The pipeline now lives behind the Retriever API (`repro.retrieval`):
`HPCConfig` selects an index backend by name, the `Retriever` facade
composes prune -> backend search -> rerank, and backend state is a single
tagged pytree instead of v0's four-way Optional union. This module keeps
the v0 entry points (`build_index` / `query` / `storage_bytes`,
`HPCIndex`) as thin wrappers so existing callers and tests keep working;
new code should use `repro.retrieval.Retriever` directly.
"""
from __future__ import annotations

from typing import Tuple

import jax

# submodule imports (not the package) so `repro.core` and
# `repro.retrieval` can initialise in either order
from repro.retrieval.base import (  # noqa: F401
    Corpus, Query, RetrieverState, code_dtype)
from repro.retrieval.config import HPCConfig  # noqa: F401
from repro.retrieval.retriever import Retriever

Array = jax.Array

# v0 name for the built index state (same pytree, tagged backend state).
HPCIndex = RetrieverState


def build_index(key: Array, doc_emb: Array, doc_mask: Array,
                doc_salience: Array, config: HPCConfig) -> HPCIndex:
    """Offline indexing (paper §III-E1). v0 wrapper over Retriever.build.

    Args:
      doc_emb:      (N, Md, D) float patch embeddings.
      doc_mask:     (N, Md) bool.
      doc_salience: (N, Md) attention-derived salience.
    """
    return Retriever(config).build(key, Corpus(doc_emb, doc_mask,
                                               doc_salience))


def query(index: HPCIndex, q_emb: Array, q_mask: Array, q_salience: Array,
          config: HPCConfig, *, k: int) -> Tuple[Array, Array]:
    """Online query (paper §III-E2). v0 wrapper over Retriever.search.

    Returns (scores (B, k), doc_ids (B, k)).
    """
    return Retriever(config).search(index, Query(q_emb, q_mask, q_salience),
                                    k=k)


def storage_bytes(index: HPCIndex, config: HPCConfig) -> dict:
    """Measured storage footprint of the built index (paper Table III)."""
    return Retriever(config).storage_bytes(index)
