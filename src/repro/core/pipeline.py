"""HPC-ColPali end-to-end pipeline (paper §III-E).

Offline:  patch embeddings + salience -> (doc-side prune) -> K-Means codebook
          -> quantize -> index (flat / IVF / hamming).
Online:   query embeddings + salience -> (query-side prune) -> [quantize if
          binary] -> coarse search -> rerank with full (unpruned) quantized
          representations -> top-k.

The pipeline object is a thin orchestration layer: every stage is a pure
function from core/{quantization,pruning,binary,late_interaction,index}.py,
so each is independently testable, jit-able and shardable.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import binary as binary_mod
from repro.core import index as index_mod
from repro.core import late_interaction as li
from repro.core import pruning
from repro.core import quantization as quant

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class HPCConfig:
    """Tunable knobs of HPC-ColPali (paper §III)."""

    k: int = 256                     # codebook size (128/256/512)
    p: float = 60.0                  # top-p% patches kept
    prune_side: Literal["doc", "query", "both", "none"] = "doc"
    mode: Literal["float", "quantized", "binary"] = "quantized"
    index: Literal["flat", "ivf"] = "flat"
    ivf: index_mod.IVFConfig = dataclasses.field(
        default_factory=index_mod.IVFConfig)
    kmeans_iters: int = 25
    rerank: int = 0                  # rerank top-r candidates with unpruned
                                     # quantized maxsim (0 = off)

    @property
    def bits(self) -> int:
        return binary_mod.bits_for_k(self.k)


class HPCIndex(NamedTuple):
    """Built index state (a pytree — shardable/checkpointable)."""

    codebook: Array
    # primary search structure (exactly one is non-None)
    flat: Optional[index_mod.FlatIndex]
    ivf: Optional[index_mod.IVFIndex]
    hamming: Optional[index_mod.HammingIndex]
    float_flat: Optional[index_mod.FloatFlatIndex]
    # unpruned quantized corpus for the rerank stage
    rerank_codes: Array
    rerank_mask: Array


def build_index(key: Array, doc_emb: Array, doc_mask: Array,
                doc_salience: Array, config: HPCConfig) -> HPCIndex:
    """Offline indexing (paper §III-E1).

    Args:
      doc_emb:      (N, Md, D) float patch embeddings.
      doc_mask:     (N, Md) bool.
      doc_salience: (N, Md) attention-derived salience.
    """
    n, md, d = doc_emb.shape
    k_cb, k_ivf = jax.random.split(key)

    if config.mode == "float":
        # ColPali-Full baseline: no codebook; store raw floats.
        emb, mask = doc_emb, doc_mask
        if config.prune_side in ("doc", "both"):
            pr = pruning.prune_topp(doc_emb, doc_salience, doc_mask, p=config.p)
            emb, mask = pr.embeddings, pr.mask
        codebook = jnp.zeros((1, d), doc_emb.dtype)
        return HPCIndex(codebook, None, None, None,
                        index_mod.build_float_flat(emb, mask),
                        rerank_codes=jnp.zeros((n, 1), jnp.uint8),
                        rerank_mask=jnp.zeros((n, 1), bool))

    # Train the codebook on valid patches only (masked-out rows excluded by
    # weighting: invalid rows are mapped to zero vectors which form their own
    # cluster otherwise — instead we drop them via salience-weighted sample).
    flat = doc_emb.reshape(-1, d)
    flat_mask = doc_mask.reshape(-1)
    # Replace invalid rows with resampled valid rows so Lloyd sees real data.
    valid_idx = jnp.argsort(~flat_mask, stable=True)  # valid rows first
    n_valid = jnp.sum(flat_mask)
    gather_idx = jnp.where(
        jnp.arange(flat.shape[0]) < n_valid,
        valid_idx,
        valid_idx[jnp.mod(jnp.arange(flat.shape[0]), jnp.maximum(n_valid, 1))])
    train_x = flat[gather_idx]
    codebook, _ = quant.kmeans_fit(
        k_cb, train_x, quant.KMeansConfig(k=config.k, iters=config.kmeans_iters))

    # Quantize the full corpus (unpruned) — rerank structure.
    codes_full = quant.quantize(doc_emb, codebook,
                                code_dtype=jnp.uint8 if config.k <= 256
                                else jnp.uint16)              # (N, Md)

    # Doc-side pruning for the primary structure.
    if config.prune_side in ("doc", "both"):
        codes, _, mask, _ = pruning.prune_topp_codes(
            codes_full, doc_salience, doc_mask, p=config.p)
    else:
        codes, mask = codes_full, doc_mask

    flat_idx = ivf_idx = ham_idx = None
    if config.mode == "binary":
        ham_idx = index_mod.build_hamming(codes, mask, config.bits)
    elif config.index == "ivf":
        ivf_idx = index_mod.build_ivf(k_ivf, codes, mask, codebook, config.ivf)
    else:
        flat_idx = index_mod.build_flat(codes, mask, codebook)

    return HPCIndex(codebook, flat_idx, ivf_idx, ham_idx, None,
                    rerank_codes=codes_full, rerank_mask=doc_mask)


def query(index: HPCIndex, q_emb: Array, q_mask: Array, q_salience: Array,
          config: HPCConfig, *, k: int) -> Tuple[Array, Array]:
    """Online query (paper §III-E2 steps 2-5).

    Returns (scores (B, k), doc_ids (B, k)).
    """
    # Step 2 — query-side dynamic pruning.
    if config.prune_side in ("query", "both"):
        pr = pruning.prune_topp(q_emb, q_salience, q_mask, p=config.p)
        q_emb, q_mask = pr.embeddings, pr.mask

    # Steps 3-4 — quantize/encode + similarity search.
    n_cand = k if config.rerank == 0 else max(k, config.rerank)
    if config.mode == "float":
        scores, ids = index_mod.search_float_flat(
            index.float_flat, q_emb, q_mask, k=n_cand)
    elif config.mode == "binary":
        q_codes = quant.quantize(q_emb, index.codebook, code_dtype=jnp.uint16)
        scores, ids = index_mod.search_hamming(
            index.hamming, q_codes, q_mask, bits=config.bits, k=n_cand)
    elif config.index == "ivf":
        scores, ids = index_mod.search_ivf(
            index.ivf, q_emb, q_mask, n_probe=config.ivf.n_probe, k=n_cand)
    else:
        scores, ids = index_mod.search_flat(index.flat, q_emb, q_mask, k=n_cand)

    # Step 5 — rerank candidates with unpruned quantized late interaction.
    if config.rerank and config.mode != "float":
        cand_codes = index.rerank_codes[ids]                  # (B, r, Md)
        cand_mask = index.rerank_mask[ids]
        def rerank_one(qi, qmi, codes, msk):
            return li.quantized_maxsim(qi[None], qmi[None], codes, msk,
                                       index.codebook)[0]
        re_scores = jax.vmap(rerank_one)(q_emb, q_mask, cand_codes, cand_mask)
        re_scores = jnp.where(ids >= 0, re_scores, li.NEG_INF)
        top_s, top_i = jax.lax.top_k(re_scores, k)
        return top_s, jnp.take_along_axis(ids, top_i, axis=1)
    return scores[:, :k], ids[:, :k]


def storage_bytes(index: HPCIndex, config: HPCConfig) -> dict:
    """Measured storage footprint of the built index (paper Table III).

    Counts the patch representation payload (the paper's metric); masks/ids
    are reported separately.
    """
    out = {}
    if config.mode == "float":
        e = index.float_flat.embeddings
        out["payload"] = e.size * e.dtype.itemsize
    elif config.mode == "binary":
        n_codes = int(index.hamming.codes.size)
        out["payload"] = binary_mod.packed_nbytes(n_codes, config.bits)
        out["codebook"] = index.codebook.size * index.codebook.dtype.itemsize
    else:
        src = index.flat if index.flat is not None else None
        if src is not None:
            codes = src.codes
        elif index.ivf is not None:
            codes = index.ivf.bucket_codes
        else:
            codes = index.rerank_codes
        out["payload"] = codes.size * codes.dtype.itemsize
        out["codebook"] = index.codebook.size * index.codebook.dtype.itemsize
    return out
