"""Attention-guided dynamic pruning (HPC-ColPali §III-C).

Given per-patch salience scores (derived from the VLM encoder's attention
maps — see models/colpali.py::attention_salience), keep only the top-p% most
salient patches. All shapes are static: for M patches and ratio p the kept
count is ceil(M * p / 100), computed in Python so the pruned tensors jit
cleanly and shard over the mesh.

The paper prunes document patches by attention score (§III-C) and the query
patches at query time (§III-E step 2); we support both sides plus `both`
(docs/design.md §2, assumption notes).
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array

NEG_INF = -1e30


class Pruned(NamedTuple):
    """Result of top-p pruning on a bag of patch embeddings."""

    embeddings: Array   # (..., M_keep, D)
    indices: Array      # (..., M_keep) int32 — positions kept, salience-desc
    mask: Array         # (..., M_keep) bool — False for padded/invalid kept slots
    salience: Array     # (..., M_keep) — salience of kept patches


def keep_count(m: int, p: float) -> int:
    """ceil(M * p / 100), clamped to [1, M]. Static (Python) arithmetic."""
    return max(1, min(m, int(math.ceil(m * p / 100.0))))


@partial(jax.jit, static_argnames=("p",))
def prune_topp(embeddings: Array, salience: Array, mask: Array,
               *, p: float) -> Pruned:
    """Keep the top-p% most salient patches.

    Args:
      embeddings: (..., M, D) patch embeddings.
      salience:   (..., M) non-negative salience (attention mass per patch).
      mask:       (..., M) bool validity mask (False = padding).
      p:          percentage of patches to keep, e.g. 60.0.

    Invalid patches get -inf salience so they are only selected when fewer
    than M_keep valid patches exist; the returned mask stays False for them,
    so downstream MaxSim ignores them exactly as before pruning.
    """
    m = embeddings.shape[-2]
    m_keep = keep_count(m, p)
    masked_sal = jnp.where(mask, salience, NEG_INF)
    # JAX04-safe: keep_count guarantees m_keep <= M (patch axis length)
    top_sal, top_idx = jax.lax.top_k(masked_sal, m_keep)  # noqa: JAX04
    kept_mask = top_sal > NEG_INF / 2
    kept_emb = jnp.take_along_axis(embeddings, top_idx[..., None], axis=-2)
    kept_emb = kept_emb * kept_mask[..., None].astype(kept_emb.dtype)
    return Pruned(kept_emb, top_idx.astype(jnp.int32), kept_mask, top_sal)


@partial(jax.jit, static_argnames=("p",))
def prune_topp_codes(codes: Array, salience: Array, mask: Array,
                     *, p: float):
    """Same as prune_topp but over integer code arrays (..., M) instead of
    float embeddings — used when pruning an already-quantized corpus."""
    m = codes.shape[-1]
    m_keep = keep_count(m, p)
    masked_sal = jnp.where(mask, salience, NEG_INF)
    # JAX04-safe: keep_count guarantees m_keep <= M (patch axis length)
    top_sal, top_idx = jax.lax.top_k(masked_sal, m_keep)  # noqa: JAX04
    kept_mask = top_sal > NEG_INF / 2
    kept_codes = jnp.take_along_axis(codes, top_idx, axis=-1)
    return kept_codes, top_idx.astype(jnp.int32), kept_mask, top_sal


def compute_saved_fraction(m: int, p: float) -> float:
    """Fraction of late-interaction compute removed by pruning one side.

    Late interaction is O(Mq * Md); pruning docs to p% cuts the doc factor to
    ceil(M*p/100)/M. Used by benchmarks/latency.py to verify the paper's
    'up to 60% compute reduction' claim (p=40 -> 60% saved).
    """
    return 1.0 - keep_count(m, p) / m


def salience_from_attention(attn: Array, query_len_mask: Array | None = None) -> Array:
    """Aggregate a (..., H, T, T) attention tensor into per-position salience.

    Salience of position j = mean over heads and query positions of the
    attention mass received by j — the signal class DynamicViT-style pruning
    uses and the one the paper attributes to the VLM encoder (§III-C).
    """
    # attn: (..., H, Tq, Tk) -> (..., Tk)
    sal = jnp.mean(attn, axis=(-3, -2))
    if query_len_mask is not None:
        sal = sal * query_len_mask.astype(sal.dtype)
    return sal
