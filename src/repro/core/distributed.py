"""Mesh-sharded retrieval: the paper's system at production scale.

The quantized corpus (codes + masks + ids) is sharded over mesh axes
(each device owns N/n_dev documents); queries are replicated. Each device
runs the fused ADC MaxSim scan over its shard, takes a *local* top-k, and
the global answer is the top-k of the all-gathered (score, id) pairs —
k <= 128, so the merge traffic is k * 8 bytes vs the multi-GB scan, i.e.
negligible (quantified in EXPERIMENTS.md §Roofline for the colpali cells).

Also contains the sharded K-Means v2 trainer: points sharded over devices,
replicated codebook, per-cluster sums reduced with psum, empty-cluster
repair via a local-top-k/all-gather/global-top-k farthest-point merge, and
multi-restart select-best — the same algorithm as the single-host
`quantization.kmeans_fit` (seeding reuses its `seed_centroids`, so on a
1-device mesh the two paths agree within float tolerance). This is the
streaming-codebook building block the paper lists as future work (§VII),
wired into `Retriever.build(..., mesh=...)` via `sharded_kmeans_fit` /
`sharded_quantize`.
"""
from __future__ import annotations

import warnings
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import late_interaction as li
from repro.core import quantization as quant
from repro.dist.sharding import Sharder

Array = jax.Array


def corpus_data_axes(mesh: Mesh, n: int) -> Tuple[str, ...]:
    """Mesh axes an N-point dimension shards over on this mesh.

    Resolved through the logical-axis Sharder's "corpus" rule
    (dist/sharding.py DEFAULT_RULES — one source of truth with
    `Retriever.shard`, so build-time and search-time sharding can't
    drift): missing axes are skipped and axes drop from the right until n
    divides the shard product. Returns () when nothing divides (caller
    falls back to the single-host path).
    """
    entry = Sharder(mesh).resolve(("corpus",), (n,))[0]
    if entry is None:
        return ()
    return (entry,) if isinstance(entry, str) else tuple(entry)


def sharded_search_fn(mesh: Mesh, corpus_axes: Tuple[str, ...], *, k: int,
                      block_docs: int = 128):
    """Build a shard_map'd corpus-sharded ADC search function.

    Args:
      mesh: the device mesh.
      corpus_axes: mesh axes the document dimension is sharded over
        (e.g. ("data", "model") for 256-way on a single pod).
      k: global top-k.
      block_docs: local scan block — bounds the transient (B, Mq, blk, Md)
        similarity buffer exactly like the Pallas kernel's doc tile
        (§Perf iteration colpali-1: 79.6 GiB/dev -> fits; on TPU this jnp
        block loop is replaced by kernels/quantized_maxsim.py).
      k: global top-k.

    Returns a function
      (q (B, Mq, D), q_mask (B, Mq),
       codes (N, Md), mask (N, Md), doc_ids (N,), codebook (K, D))
      -> (scores (B, k), ids (B, k))
    with codes/mask/doc_ids sharded over corpus_axes on dim 0 and everything
    else replicated.
    """
    corpus_spec = P(corpus_axes)
    n_shards = 1
    for a in corpus_axes:
        n_shards *= mesh.shape[a]

    def local_search(q, q_mask, codes, mask, doc_ids, codebook):
        # Local fused scan over this device's shard, in doc blocks so the
        # (B, Mq, blk, Md) sim tile stays VMEM-sized (kernel semantics).
        n_local, md = codes.shape
        q_mask = q_mask.astype(jnp.float32)
        blk = min(block_docs, n_local)
        while n_local % blk != 0:
            blk //= 2
        table = li.adc_table(q, codebook)                      # (B, Mq, K)

        def score_block(c_blk):
            codes_b, mask_b = c_blk
            sim = jnp.take(table, codes_b.astype(jnp.int32).reshape(-1),
                           axis=2)
            sim = sim.reshape(*table.shape[:2], blk, md)       # (B,Mq,blk,Md)
            sim = jnp.where(mask_b[None, None] > 0, sim, li.NEG_INF)
            per_q = jnp.max(sim, axis=-1)                      # (B, Mq, blk)
            per_q = per_q * q_mask[:, :, None]
            return jnp.sum(per_q, axis=1)                      # (B, blk)

        blocks = (codes.reshape(-1, blk, md),
                  mask.reshape(-1, blk, md).astype(jnp.float32))
        scores = jax.lax.map(score_block, blocks)              # (nb, B, blk)
        scores = jnp.moveaxis(scores, 0, 1).reshape(q.shape[0], n_local)
        local_k = min(k, codes.shape[0])
        # JAX04-safe: local_k = min(k, shard size) just above
        top_s, top_i = jax.lax.top_k(scores, local_k)  # noqa: JAX04
        top_ids = doc_ids[top_i]
        # Global merge: gather every shard's candidates, re-top-k.
        all_s = top_s
        all_i = top_ids
        for ax in corpus_axes:
            all_s = jax.lax.all_gather(all_s, ax, axis=1, tiled=True)
            all_i = jax.lax.all_gather(all_i, ax, axis=1, tiled=True)
        # JAX04-safe: callers cap k at the global corpus size, and the
        # gathered axis holds local_k * n_shards >= min(k, N) entries
        g_s, g_pos = jax.lax.top_k(all_s, k)  # noqa: JAX04
        g_i = jnp.take_along_axis(all_i, g_pos, axis=1)
        return g_s, g_i

    return jax.jit(shard_map(
        local_search, mesh=mesh,
        in_specs=(P(), P(), corpus_spec, corpus_spec, corpus_spec, P()),
        out_specs=(P(), P()),
        check_rep=False))


def sharded_kmeans_refine_fn(mesh: Mesh, data_axes: Tuple[str, ...], *,
                             k: int, iters: int, n_total: int,
                             block_rows: int = 65536):
    """Distributed Lloyd v2: x sharded over data_axes, codebook replicated.

    Each step: local assignment (matmul, streamed in `block_rows` row
    blocks so the per-device transient is (block_rows, K), never
    (N_local, K)) -> local segment sums -> psum over the data axes ->
    replicated centroid update -> empty-cluster repair (each device's
    top-k farthest points are all-gathered and re-top-k'd, so dead
    centroids re-seed on the *global* farthest points — same rule as
    quantization._repair_dead_centroids). Tracks the lowest-inertia
    iterate exactly like quantization.kmeans_refine. Row blocking is
    bitwise-transparent: every row's argmin/min is independent of the
    chunking.

    Returns f(x, centroids0) -> (best_centroids, inertias (iters,),
    best_inertia) with x sharded over data_axes and everything else
    replicated.
    """
    x_spec = P(data_axes)
    n_f = float(n_total)

    def psum_all(v):
        for ax in data_axes:
            v = jax.lax.psum(v, ax)
        return v

    def e_step(x, centroids):
        n = x.shape[0]
        if n <= block_rows:
            d2 = quant.pairwise_sq_dists(x, centroids)
            return jnp.argmin(d2, axis=-1), jnp.min(d2, axis=-1)
        nb = -(-n // block_rows)
        xp = jnp.pad(x, ((0, nb * block_rows - n), (0, 0)))

        def block(xb):
            d2 = quant.pairwise_sq_dists(xb, centroids)
            return jnp.argmin(d2, axis=-1), jnp.min(d2, axis=-1)

        codes, min_d2 = jax.lax.map(block, xp.reshape(nb, block_rows, -1))
        return codes.reshape(-1)[:n], min_d2.reshape(-1)[:n]

    def repair(x, centroids, cnts, min_d2):
        kk = min(k, x.shape[0])
        # JAX04-safe: kk = min(k, shard size) just above
        far_d, far_i = jax.lax.top_k(min_d2, kk)  # noqa: JAX04
        far_x = x[far_i]                                   # (kk, D)
        for ax in data_axes:
            far_d = jax.lax.all_gather(far_d, ax, axis=0, tiled=True)
            far_x = jax.lax.all_gather(far_x, ax, axis=0, tiled=True)
        # JAX04-safe: k clamped to the gathered axis length inline
        g_d, g_pos = jax.lax.top_k(far_d, min(k, far_d.shape[0]))  # noqa: JAX04
        cand = far_x[g_pos]                                # global farthest
        dead = cnts <= 0
        rank = jnp.clip(jnp.cumsum(dead.astype(jnp.int32)) - 1, 0,
                        cand.shape[0] - 1)
        return jnp.where(dead[:, None], cand[rank], centroids)

    def fit(x, centroids0):
        def step(carry, _):
            c, best_c, best_i = carry
            codes, min_d2 = e_step(x, c)
            inertia = psum_all(jnp.sum(min_d2)) / n_f
            sums = psum_all(jax.ops.segment_sum(x, codes, num_segments=k))
            cnts = psum_all(jax.ops.segment_sum(
                jnp.ones((x.shape[0],), x.dtype), codes, num_segments=k))
            new_c = jnp.where(cnts[:, None] > 0,
                              sums / jnp.maximum(cnts[:, None], 1.0), c)
            new_c = repair(x, new_c, cnts, min_d2)
            better = inertia < best_i
            best_c = jnp.where(better, c, best_c)
            best_i = jnp.where(better, inertia, best_i)
            return (new_c, best_c, best_i), inertia

        init = (centroids0, centroids0, jnp.asarray(jnp.inf, x.dtype))
        (c_last, best_c, best_i), inertias = jax.lax.scan(
            step, init, None, length=iters)
        _, min_d2 = e_step(x, c_last)
        last_i = psum_all(jnp.sum(min_d2)) / n_f
        better = last_i < best_i
        best_c = jnp.where(better, c_last, best_c)
        best_i = jnp.where(better, last_i, best_i)
        return best_c, inertias, best_i

    return jax.jit(shard_map(
        fit, mesh=mesh, in_specs=(x_spec, P()), out_specs=(P(), P(), P()),
        check_rep=False))


def sharded_kmeans_fit(mesh: Mesh, key: Array, x: Array,
                       config: quant.KMeansConfig,
                       data_axes: Optional[Tuple[str, ...]] = None
                       ) -> Tuple[Array, Array]:
    """Mesh-sharded `quantization.kmeans_fit`: same seeds, same algorithm.

    Per restart: k-means++ seeding on the (replicated, O(seed_batch))
    subsample using the exact keys the single-host path derives, then the
    shard_map'd Lloyd v2 over x sharded on `data_axes`; the restart with
    the lowest final inertia wins. Falls back to the single-host fit —
    with a warning, since that re-introduces the full-device-memory build
    the mesh was meant to avoid — when the mesh has none of the
    ("pod", "data", "model") corpus axes or N doesn't divide the shard
    product.

    Stochastic mini-batch mode is single-host-only; here `config.minibatch`
    instead bounds the E-step's per-device transient to
    (minibatch, K) row blocks (streamed full-batch — bitwise identical to
    the unblocked E-step), so corpus-scale N never materialises an
    (N_local, K) distance matrix.

    Returns (centroids (K, D), per-iteration inertia (iters,)) like
    `kmeans_fit`; on a 1-device mesh the result matches the single-host
    path within float tolerance (psum reassociates the per-cluster sums).
    """
    x = x.astype(config.dtype)
    n = x.shape[0]
    if data_axes is None:
        data_axes = corpus_data_axes(mesh, n)
    if not data_axes:
        warnings.warn(
            f"sharded_kmeans_fit: no 'corpus'-rule mesh axis divides "
            f"N={n} on mesh "
            f"{dict(zip(mesh.axis_names, mesh.devices.shape))}; falling "
            "back to the single-host fit (full single-device memory)",
            stacklevel=2)
        return quant.kmeans_fit(key, x, config)
    refine = sharded_kmeans_refine_fn(
        mesh, data_axes, k=config.k, iters=config.iters, n_total=n,
        block_rows=config.minibatch if config.minibatch > 0 else 65536)
    x_sh = jax.device_put(x, NamedSharding(mesh, P(data_axes)))
    restarts = max(1, config.n_restarts)
    keys = jax.random.split(key, restarts)
    best = None
    for r in range(restarts):
        k_seed, k_init, _ = jax.random.split(keys[r], 3)
        c0 = quant.seed_centroids(k_seed, k_init, x, config)
        c, hist, inertia = refine(x_sh, c0)
        if best is None or float(inertia) < best[0]:
            best = (float(inertia), c, hist)
    return best[1], best[2]


def sharded_quantize(mesh: Mesh, x: Array, codebook: Array, code_dtype,
                     data_axes: Optional[Tuple[str, ...]] = None) -> Array:
    """Quantize (N, ..., D) across the mesh: N sharded, codebook replicated.

    Assignment inside the shard runs through `quantization.quantize`, which
    routes to the Pallas kernel (kernels/kmeans_assign.py) on TPU and the
    reference jnp path elsewhere. Falls back to single-host quantization
    when no corpus axis divides N.
    """
    n = x.shape[0]
    if data_axes is None:
        data_axes = corpus_data_axes(mesh, n)
    if not data_axes:
        warnings.warn(
            f"sharded_quantize: no corpus mesh axis divides N={n}; "
            "falling back to single-host quantization", stacklevel=2)
        return quant.quantize(x, codebook, code_dtype=code_dtype)
    in_spec = P(*((data_axes,) + (None,) * (x.ndim - 1)))
    out_spec = P(*((data_axes,) + (None,) * (x.ndim - 2)))

    def f(x_local, cb):
        # "auto": Pallas assignment on TPU devices, canonical jnp elsewhere
        return quant.quantize(x_local, cb, code_dtype=code_dtype,
                              impl="auto")

    fn = jax.jit(shard_map(f, mesh=mesh, in_specs=(in_spec, P()),
                           out_specs=out_spec, check_rep=False))
    x_sh = jax.device_put(x, NamedSharding(mesh, in_spec))
    return fn(x_sh, codebook)


def corpus_shardings(mesh: Mesh, corpus_axes: Tuple[str, ...]):
    """NamedShardings for (codes, mask, doc_ids, codebook, queries...)."""
    c = NamedSharding(mesh, P(corpus_axes))
    r = NamedSharding(mesh, P())
    return dict(codes=c, mask=c, doc_ids=c, codebook=r, replicated=r)
