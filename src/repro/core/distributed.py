"""Mesh-sharded retrieval: the paper's system at production scale.

The quantized corpus (codes + masks + ids) is sharded over mesh axes
(each device owns N/n_dev documents); queries are replicated. Each device
runs the fused ADC MaxSim scan over its shard, takes a *local* top-k, and
the global answer is the top-k of the all-gathered (score, id) pairs —
k <= 128, so the merge traffic is k * 8 bytes vs the multi-GB scan, i.e.
negligible (quantified in EXPERIMENTS.md §Roofline for the colpali cells).

Also contains the sharded K-Means trainer: points sharded over devices,
replicated codebook, per-cluster sums reduced with psum — the streaming-
codebook building block the paper lists as future work (§VII).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import late_interaction as li
from repro.core import quantization as quant

Array = jax.Array


def sharded_search_fn(mesh: Mesh, corpus_axes: Tuple[str, ...], *, k: int,
                      block_docs: int = 128):
    """Build a shard_map'd corpus-sharded ADC search function.

    Args:
      mesh: the device mesh.
      corpus_axes: mesh axes the document dimension is sharded over
        (e.g. ("data", "model") for 256-way on a single pod).
      k: global top-k.
      block_docs: local scan block — bounds the transient (B, Mq, blk, Md)
        similarity buffer exactly like the Pallas kernel's doc tile
        (§Perf iteration colpali-1: 79.6 GiB/dev -> fits; on TPU this jnp
        block loop is replaced by kernels/quantized_maxsim.py).
      k: global top-k.

    Returns a function
      (q (B, Mq, D), q_mask (B, Mq),
       codes (N, Md), mask (N, Md), doc_ids (N,), codebook (K, D))
      -> (scores (B, k), ids (B, k))
    with codes/mask/doc_ids sharded over corpus_axes on dim 0 and everything
    else replicated.
    """
    corpus_spec = P(corpus_axes)
    n_shards = 1
    for a in corpus_axes:
        n_shards *= mesh.shape[a]

    def local_search(q, q_mask, codes, mask, doc_ids, codebook):
        # Local fused scan over this device's shard, in doc blocks so the
        # (B, Mq, blk, Md) sim tile stays VMEM-sized (kernel semantics).
        n_local, md = codes.shape
        q_mask = q_mask.astype(jnp.float32)
        blk = min(block_docs, n_local)
        while n_local % blk != 0:
            blk //= 2
        table = li.adc_table(q, codebook)                      # (B, Mq, K)

        def score_block(c_blk):
            codes_b, mask_b = c_blk
            sim = jnp.take(table, codes_b.astype(jnp.int32).reshape(-1),
                           axis=2)
            sim = sim.reshape(*table.shape[:2], blk, md)       # (B,Mq,blk,Md)
            sim = jnp.where(mask_b[None, None] > 0, sim, li.NEG_INF)
            per_q = jnp.max(sim, axis=-1)                      # (B, Mq, blk)
            per_q = per_q * q_mask[:, :, None]
            return jnp.sum(per_q, axis=1)                      # (B, blk)

        blocks = (codes.reshape(-1, blk, md),
                  mask.reshape(-1, blk, md).astype(jnp.float32))
        scores = jax.lax.map(score_block, blocks)              # (nb, B, blk)
        scores = jnp.moveaxis(scores, 0, 1).reshape(q.shape[0], n_local)
        local_k = min(k, codes.shape[0])
        top_s, top_i = jax.lax.top_k(scores, local_k)          # (B, local_k)
        top_ids = doc_ids[top_i]
        # Global merge: gather every shard's candidates, re-top-k.
        all_s = top_s
        all_i = top_ids
        for ax in corpus_axes:
            all_s = jax.lax.all_gather(all_s, ax, axis=1, tiled=True)
            all_i = jax.lax.all_gather(all_i, ax, axis=1, tiled=True)
        g_s, g_pos = jax.lax.top_k(all_s, k)
        g_i = jnp.take_along_axis(all_i, g_pos, axis=1)
        return g_s, g_i

    return jax.jit(shard_map(
        local_search, mesh=mesh,
        in_specs=(P(), P(), corpus_spec, corpus_spec, corpus_spec, P()),
        out_specs=(P(), P()),
        check_rep=False))


def sharded_kmeans_fn(mesh: Mesh, data_axes: Tuple[str, ...], *,
                      k: int, iters: int):
    """Distributed Lloyd: x sharded over data_axes, codebook replicated.

    Each step: local assignment (matmul) -> local segment sums -> psum over
    the data axes -> replicated centroid update. Returns f(x, centroids0).
    """
    x_spec = P(data_axes)

    def fit(x, centroids0):
        def step(centroids, _):
            codes = quant.assign(x, centroids)
            sums = jax.ops.segment_sum(x, codes, num_segments=k)
            cnts = jax.ops.segment_sum(jnp.ones((x.shape[0],), x.dtype),
                                       codes, num_segments=k)
            for ax in data_axes:
                sums = jax.lax.psum(sums, ax)
                cnts = jax.lax.psum(cnts, ax)
            new_c = jnp.where(cnts[:, None] > 0,
                              sums / jnp.maximum(cnts[:, None], 1.0),
                              centroids)
            return new_c, None
        centroids, _ = jax.lax.scan(step, centroids0, None, length=iters)
        return centroids

    return jax.jit(shard_map(
        fit, mesh=mesh, in_specs=(x_spec, P()), out_specs=P(),
        check_rep=False))


def corpus_shardings(mesh: Mesh, corpus_axes: Tuple[str, ...]):
    """NamedShardings for (codes, mask, doc_ids, codebook, queries...)."""
    c = NamedSharding(mesh, P(corpus_axes))
    r = NamedSharding(mesh, P())
    return dict(codes=c, mask=c, doc_ids=c, codebook=r, replicated=r)
