"""K-Means quantization of patch embeddings (HPC-ColPali §III-B).

Replaces D-dim float32 patch embeddings with 1-byte centroid indices
(K <= 256) or 2-byte indices (K <= 65536), giving up to 32x storage
compression for D=128/float32.

TPU adaptation (DESIGN.md §2): FAISS's CPU Lloyd iteration is replaced by a
fully batched, jit-compiled Lloyd step where

  * assignment is one MXU matmul:  argmin_k ||x||^2 - 2 x C^T + ||c_k||^2
  * the centroid update is a ``segment_sum`` scatter,

plus k-means++ seeding via distance-weighted categorical sampling. Everything
is functional and mesh-shardable: points shard over the data axes, the
codebook is replicated, and per-cluster sums reduce with ``psum`` when run
under ``shard_map`` (see core/distributed.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class KMeansConfig:
    """Configuration for codebook training."""

    k: int = 256            # number of centroids (paper: 128 / 256 / 512)
    iters: int = 25         # Lloyd iterations
    seed_batch: int = 4096  # subsample size used for k-means++ seeding
    dtype: jnp.dtype = jnp.float32

    @property
    def bits(self) -> int:
        """b = ceil(log2 K) — bits per code in binary mode (paper §III-D)."""
        return max(1, int(jnp.ceil(jnp.log2(self.k))))

    @property
    def code_dtype(self) -> jnp.dtype:
        return jnp.uint8 if self.k <= 256 else jnp.uint16


def pairwise_sq_dists(x: Array, c: Array) -> Array:
    """||x_i - c_k||^2 for x (N, D), c (K, D) -> (N, K). One MXU matmul."""
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)          # (N, 1)
    c2 = jnp.sum(c * c, axis=-1)                         # (K,)
    xc = x @ c.T                                         # (N, K) — MXU
    return x2 - 2.0 * xc + c2[None, :]


def assign(x: Array, centroids: Array) -> Array:
    """Nearest-centroid assignment -> integer codes (N,).

    The Pallas-accelerated version lives in kernels/kmeans_assign.py; this is
    the canonical jnp form used for training the codebook and as oracle.
    """
    return jnp.argmin(pairwise_sq_dists(x, centroids), axis=-1)


def decode(codes: Array, centroids: Array) -> Array:
    """codes (…,) -> reconstructed embeddings (…, D) by centroid gather."""
    return jnp.take(centroids, codes.astype(jnp.int32), axis=0)


def _kmeans_pp_init(key: Array, x: Array, k: int) -> Array:
    """k-means++ seeding on a (N, D) sample, fully inside lax.scan/fori."""
    n = x.shape[0]
    k0, key = jax.random.split(key)
    first = jax.random.randint(k0, (), 0, n)
    centroids0 = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(x[first])
    d2_0 = jnp.sum((x - x[first]) ** 2, axis=-1)

    def body(i, carry):
        centroids, d2, key = carry
        key, sub = jax.random.split(key)
        # Sample next seed proportionally to squared distance (k-means++).
        logits = jnp.log(jnp.maximum(d2, 1e-30))
        idx = jax.random.categorical(sub, logits)
        c_new = x[idx]
        centroids = centroids.at[i].set(c_new)
        d2 = jnp.minimum(d2, jnp.sum((x - c_new) ** 2, axis=-1))
        return centroids, d2, key

    centroids, _, _ = jax.lax.fori_loop(1, k, body, (centroids0, d2_0, key))
    return centroids


def _lloyd_step(x: Array, centroids: Array) -> Tuple[Array, Array]:
    """One Lloyd iteration. Returns (new_centroids, mean_sq_error)."""
    k = centroids.shape[0]
    codes = assign(x, centroids)
    # Scatter-reduce: per-cluster sums and counts.
    sums = jax.ops.segment_sum(x, codes, num_segments=k)
    counts = jax.ops.segment_sum(jnp.ones((x.shape[0],), x.dtype), codes,
                                 num_segments=k)
    new_centroids = jnp.where(counts[:, None] > 0,
                              sums / jnp.maximum(counts[:, None], 1.0),
                              centroids)
    recon = decode(codes, new_centroids)
    mse = jnp.mean(jnp.sum((x - recon) ** 2, axis=-1))
    return new_centroids, mse


@partial(jax.jit, static_argnames=("config",))
def kmeans_fit(key: Array, x: Array, config: KMeansConfig) -> Tuple[Array, Array]:
    """Train a K-Means codebook on patch embeddings x (N, D).

    Returns (centroids (K, D), per-iteration mse (iters,)).
    """
    x = x.astype(config.dtype)
    n = x.shape[0]
    k_seed, k_init = jax.random.split(key)
    # Seed on a subsample to keep k-means++ O(seed_batch * K).
    m = min(config.seed_batch, n)
    sel = jax.random.choice(k_seed, n, (m,), replace=n < m)
    centroids = _kmeans_pp_init(k_init, x[sel], config.k)

    def body(centroids, _):
        new_c, mse = _lloyd_step(x, centroids)
        return new_c, mse

    centroids, mses = jax.lax.scan(body, centroids, None, length=config.iters)
    return centroids, mses


def quantize(x: Array, centroids: Array, code_dtype=jnp.uint8) -> Array:
    """Quantize embeddings (…, M, D) -> codes (…, M) of code_dtype.

    Works for arbitrary leading batch dims (vmapped assignment).
    """
    flat = x.reshape(-1, x.shape[-1])
    codes = assign(flat, centroids).astype(code_dtype)
    return codes.reshape(x.shape[:-1])


def quantization_error(x: Array, centroids: Array) -> Array:
    """Mean squared reconstruction error of the codebook on x (N, D)."""
    codes = assign(x, centroids)
    return jnp.mean(jnp.sum((x - decode(codes, centroids)) ** 2, axis=-1))


# ---------------------------------------------------------------------------
# Product-quantization extension (paper §VII "Future work"): split D into
# n_sub sub-spaces with an independent codebook each. Kept API-compatible
# with the single-codebook path; used by benchmarks/storage.py ablations.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PQConfig:
    k: int = 256
    n_sub: int = 4
    iters: int = 15
    seed_batch: int = 4096


@partial(jax.jit, static_argnames=("config",))
def pq_fit(key: Array, x: Array, config: PQConfig) -> Array:
    """Train per-subspace codebooks -> (n_sub, K, D/n_sub)."""
    n, d = x.shape
    assert d % config.n_sub == 0, "D must divide n_sub"
    ds = d // config.n_sub
    sub = x.reshape(n, config.n_sub, ds).transpose(1, 0, 2)  # (n_sub, N, ds)
    keys = jax.random.split(key, config.n_sub)
    kcfg = KMeansConfig(k=config.k, iters=config.iters,
                        seed_batch=config.seed_batch)
    fit = lambda kk, xx: kmeans_fit(kk, xx, kcfg)[0]
    return jax.vmap(fit)(keys, sub)


def pq_quantize(x: Array, codebooks: Array) -> Array:
    """x (…, D) -> codes (…, n_sub) uint8/16."""
    n_sub, k, ds = codebooks.shape
    flat = x.reshape(-1, n_sub, ds).transpose(1, 0, 2)       # (n_sub, N, ds)
    codes = jax.vmap(assign)(flat, codebooks)                # (n_sub, N)
    dt = jnp.uint8 if k <= 256 else jnp.uint16
    return codes.T.reshape(*x.shape[:-1], n_sub).astype(dt)


def pq_decode(codes: Array, codebooks: Array) -> Array:
    """codes (…, n_sub) -> x̂ (…, n_sub*ds)."""
    n_sub, _, ds = codebooks.shape
    flat = codes.reshape(-1, n_sub).astype(jnp.int32)        # (N, n_sub)
    parts = jax.vmap(lambda cb, c: cb[c], in_axes=(0, 1))(codebooks, flat)
    return parts.transpose(1, 0, 2).reshape(*codes.shape[:-1], n_sub * ds)
