"""K-Means quantization of patch embeddings (HPC-ColPali §III-B).

Replaces D-dim float32 patch embeddings with 1-byte centroid indices
(K <= 256) or 2-byte indices (K <= 65536), giving up to 32x storage
compression for D=128/float32.

TPU adaptation (docs/design.md §2): FAISS's CPU Lloyd iteration is replaced by a
fully batched, jit-compiled Lloyd step where

  * assignment is one MXU matmul:  argmin_k ||x||^2 - 2 x C^T + ||c_k||^2
  * the centroid update is a ``segment_sum`` scatter,

plus k-means++ seeding via distance-weighted categorical sampling.

Codebook training v2 adds the quality machinery that closes the seed
retrieval gap (ISSUE 3):

  * multi-restart fitting — ``n_restarts`` independent seeds refined under
    ``lax.map`` (sequential, memory-bounded), the lowest-inertia restart
    wins;
  * empty-cluster repair — every Lloyd step re-seeds zero-count centroids
    on the points farthest from their assigned centroid, instead of
    leaving dead centroids frozen at their stale position;
  * best-iterate tracking — Lloyd with repair is not monotone, so the fit
    returns the *lowest-inertia* iterate seen, never just the last one;
  * full-data k-means++ seeding (``seed_batch=0``) when the subsample
    would be the quality bottleneck, and a mini-batch Lloyd mode
    (``minibatch=b``) for corpora too large for full-batch E-steps.

Everything is functional and mesh-shardable: points shard over the data
axes, the codebook is replicated, and per-cluster sums reduce with
``psum`` when run under ``shard_map`` (see core/distributed.py, which
reuses ``pairwise_sq_dists``/``_repair_dead_centroids`` so the sharded
and single-host paths agree within float tolerance). With the default
config the single-host fit is bit-stable: a pure function of
``(key, x, config)`` with no device-dependent branches.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class KMeansConfig:
    """Configuration for codebook training."""

    k: int = 256            # number of centroids (paper: 128 / 256 / 512)
    iters: int = 25         # Lloyd iterations
    seed_batch: int = 4096  # k-means++ seeding subsample; 0 = seed on all
                            # of x (quality over O(seed_batch * K) cost)
    n_restarts: int = 8     # independent fits; lowest final inertia wins
    minibatch: int = 0      # 0 = full-batch Lloyd; else per-step sample
                            # size (Sculley-style streaming update)
    dtype: jnp.dtype = jnp.float32

    @property
    def bits(self) -> int:
        """b = ceil(log2 K) — bits per code in binary mode (paper §III-D)."""
        return max(1, int(jnp.ceil(jnp.log2(self.k))))

    @property
    def code_dtype(self) -> jnp.dtype:
        return jnp.uint8 if self.k <= 256 else jnp.uint16


def pairwise_sq_dists(x: Array, c: Array) -> Array:
    """||x_i - c_k||^2 for x (N, D), c (K, D) -> (N, K). One MXU matmul.

    Clamped at zero: the matmul form cancels catastrophically when x_i is
    (nearly) a centroid, and small *negative* squared distances poison
    every downstream consumer that treats the output as a distance — the
    k-means++ categorical weights (log of a negative) and inertia /
    ``quantization_error`` sums. Argmin is unaffected by the clamp.
    """
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)          # (N, 1)
    c2 = jnp.sum(c * c, axis=-1)                         # (K,)
    xc = x @ c.T                                         # (N, K) — MXU
    return jnp.maximum(x2 - 2.0 * xc + c2[None, :], 0.0)


def assign(x: Array, centroids: Array) -> Array:
    """Nearest-centroid assignment -> integer codes (N,).

    The Pallas-accelerated version lives in kernels/kmeans_assign.py; this is
    the canonical jnp form used for training the codebook and as oracle.
    """
    return jnp.argmin(pairwise_sq_dists(x, centroids), axis=-1)


def decode(codes: Array, centroids: Array) -> Array:
    """codes (…,) -> reconstructed embeddings (…, D) by centroid gather."""
    return jnp.take(centroids, codes.astype(jnp.int32), axis=0)


def _kmeans_pp_init(key: Array, x: Array, k: int) -> Array:
    """k-means++ seeding on a (N, D) sample, fully inside lax.scan/fori."""
    n = x.shape[0]
    k0, key = jax.random.split(key)
    first = jax.random.randint(k0, (), 0, n)
    centroids0 = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(x[first])
    d2_0 = pairwise_sq_dists(x, x[first][None])[:, 0]

    def body(i, carry):
        centroids, d2, key = carry
        key, sub = jax.random.split(key)
        # Sample next seed proportionally to squared distance (k-means++).
        logits = jnp.log(jnp.maximum(d2, 1e-30))
        idx = jax.random.categorical(sub, logits)
        c_new = x[idx]
        centroids = centroids.at[i].set(c_new)
        d2 = jnp.minimum(d2, pairwise_sq_dists(x, c_new[None])[:, 0])
        return centroids, d2, key

    centroids, _, _ = jax.lax.fori_loop(1, k, body, (centroids0, d2_0, key))
    return centroids


def _repair_dead_centroids(x: Array, centroids: Array, counts: Array,
                           min_d2: Array) -> Array:
    """Re-seed zero-count centroids on the farthest points.

    The r-th dead centroid (in index order) moves to the point with the
    r-th largest distance-to-assigned-centroid, so repaired centroids land
    where the codebook underfits instead of staying frozen. Shapes:
    x (N, D), centroids (K, D), counts (K,), min_d2 (N,).
    """
    k = centroids.shape[0]
    kk = min(k, x.shape[0])
    # JAX04-safe: kk = min(k, N) just above
    _, far_idx = jax.lax.top_k(min_d2, kk)  # noqa: JAX04 - farthest points
    dead = counts <= 0
    rank = jnp.clip(jnp.cumsum(dead.astype(jnp.int32)) - 1, 0, kk - 1)
    repl = x[far_idx[rank]]
    return jnp.where(dead[:, None], repl, centroids)


def _lloyd_step(x: Array, centroids: Array) -> Tuple[Array, Array]:
    """One Lloyd iteration with empty-cluster repair.

    Returns (new_centroids, inertia) where inertia is the mean squared
    distance of x to the *input* centroids (the quantity Lloyd descends).
    """
    k = centroids.shape[0]
    d2 = pairwise_sq_dists(x, centroids)
    codes = jnp.argmin(d2, axis=-1)
    min_d2 = jnp.min(d2, axis=-1)
    inertia = jnp.mean(min_d2)
    # Scatter-reduce: per-cluster sums and counts.
    sums = jax.ops.segment_sum(x, codes, num_segments=k)
    counts = jax.ops.segment_sum(jnp.ones((x.shape[0],), x.dtype), codes,
                                 num_segments=k)
    new_centroids = jnp.where(counts[:, None] > 0,
                              sums / jnp.maximum(counts[:, None], 1.0),
                              centroids)
    new_centroids = _repair_dead_centroids(x, new_centroids, counts, min_d2)
    return new_centroids, inertia


def _inertia(x: Array, centroids: Array) -> Array:
    """Mean squared distance of x to its nearest centroid."""
    return jnp.mean(jnp.min(pairwise_sq_dists(x, centroids), axis=-1))


def kmeans_refine(x: Array, centroids0: Array, iters: int
                  ) -> Tuple[Array, Array, Array]:
    """Run `iters` Lloyd steps from `centroids0`, tracking the best iterate.

    Lloyd with empty-cluster repair is not monotone in inertia, so the
    returned codebook is the lowest-inertia iterate seen (including the
    final one), not whatever the last step produced.

    Returns (best_centroids, per-iteration inertia (iters,), best_inertia).
    """
    init = (centroids0, centroids0, jnp.asarray(jnp.inf, x.dtype))

    def body(carry, _):
        c, best_c, best_i = carry
        new_c, inertia = _lloyd_step(x, c)
        better = inertia < best_i
        best_c = jnp.where(better, c, best_c)
        best_i = jnp.where(better, inertia, best_i)
        return (new_c, best_c, best_i), inertia

    (c_last, best_c, best_i), inertias = jax.lax.scan(
        body, init, None, length=iters)
    last_i = _inertia(x, c_last)
    better = last_i < best_i
    best_c = jnp.where(better, c_last, best_c)
    best_i = jnp.where(better, last_i, best_i)
    return best_c, inertias, best_i


def _minibatch_refine(key: Array, x: Array, centroids0: Array, iters: int,
                      batch: int) -> Tuple[Array, Array]:
    """Mini-batch Lloyd (Sculley): per-step sample, cumulative-count step.

    Each centroid moves toward its batch mean with learning rate
    n_batch / n_cumulative, so early batches move centroids fast and the
    trajectory converges as counts accumulate. Centroids that have never
    received a point are re-seeded on the batch's farthest points.
    """
    n = x.shape[0]
    k = centroids0.shape[0]
    keys = jax.random.split(key, iters)

    def body(carry, kt):
        c, cum = carry
        # with replacement (standard Sculley): O(batch) per step, where
        # replace=False sampling would cost O(n) work/memory every step
        idx = jax.random.randint(kt, (batch,), 0, n)
        xb = x[idx]
        d2 = pairwise_sq_dists(xb, c)
        codes = jnp.argmin(d2, axis=-1)
        min_d2 = jnp.min(d2, axis=-1)
        sums = jax.ops.segment_sum(xb, codes, num_segments=k)
        cnts = jax.ops.segment_sum(jnp.ones((batch,), x.dtype), codes,
                                   num_segments=k)
        cum_new = cum + cnts
        target = sums / jnp.maximum(cnts[:, None], 1.0)
        eta = (cnts / jnp.maximum(cum_new, 1.0))[:, None]
        new_c = jnp.where(cnts[:, None] > 0, c + eta * (target - c), c)
        new_c = _repair_dead_centroids(xb, new_c, cum_new, min_d2)
        return (new_c, cum_new), jnp.mean(min_d2)

    (c, _), inertias = jax.lax.scan(
        body, (centroids0, jnp.zeros((k,), x.dtype)), keys)
    return c, inertias


def seed_centroids(k_seed: Array, k_init: Array, x: Array,
                   config: KMeansConfig) -> Array:
    """k-means++ seeds for one restart (shared by the sharded trainer).

    Seeds on a `seed_batch` subsample (or all of x when `seed_batch=0` or
    x is smaller), sampled explicitly WITHOUT replacement — sampling with
    replacement would seed duplicate points (v0's `replace=n < m` guard
    was dead code: m = min(seed_batch, n) makes it always False).
    """
    n = x.shape[0]
    m = config.seed_batch if config.seed_batch > 0 else n
    m = min(m, n)
    if m < n:
        sel = jax.random.choice(k_seed, n, (m,), replace=False)
        seed_x = x[sel]
    else:
        seed_x = x
    return _kmeans_pp_init(k_init, seed_x, config.k)


def _fit_single(key: Array, x: Array, config: KMeansConfig,
                eval_idx: Array = None) -> Tuple[Array, Array, Array]:
    """One seeded fit -> (centroids, per-iter inertia, final inertia)."""
    n = x.shape[0]
    k_seed, k_init, k_mb = jax.random.split(key, 3)
    centroids0 = seed_centroids(k_seed, k_init, x, config)
    if config.minibatch and config.minibatch < n:
        c, inertias = _minibatch_refine(k_mb, x, centroids0, config.iters,
                                        config.minibatch)
        # Restart selection needs a final-inertia estimate, but the full
        # (N, K) E-step is exactly what mini-batch mode exists to avoid:
        # estimate on one eval batch instead. kmeans_fit passes the SAME
        # eval_idx to every restart so selection compares like with like
        # (per-restart eval batches would add selection noise).
        if eval_idx is None:                       # standalone call
            k_eval = jax.random.fold_in(k_mb, config.iters)
            eval_idx = jax.random.randint(k_eval, (config.minibatch,), 0, n)
        return c, inertias, _inertia(x[eval_idx], c)
    best_c, inertias, best_i = kmeans_refine(x, centroids0, config.iters)
    return best_c, inertias, best_i


@partial(jax.jit, static_argnames=("config",))
def kmeans_fit(key: Array, x: Array, config: KMeansConfig) -> Tuple[Array, Array]:
    """Train a K-Means codebook on patch embeddings x (N, D).

    Runs `config.n_restarts` independent seeded fits (sequentially under
    `lax.map`, so peak memory stays one restart's worth) and returns the
    restart with the lowest final inertia.

    Returns (centroids (K, D), per-iteration inertia (iters,)).
    """
    x = x.astype(config.dtype)
    n = x.shape[0]
    restarts = max(1, config.n_restarts)
    if config.minibatch and config.minibatch < n:
        # one eval batch shared by every restart (see _fit_single); the
        # key split happens only in mini-batch mode so the full-batch
        # path keeps its bit-stable key derivation
        key, k_eval = jax.random.split(key)
        eval_idx = jax.random.randint(k_eval, (config.minibatch,), 0, n)
    else:
        eval_idx = None
    keys = jax.random.split(key, restarts)
    cents, inertias, final = jax.lax.map(
        lambda kk: _fit_single(kk, x, config, eval_idx), keys)
    best = jnp.argmin(final)
    return cents[best], inertias[best]


def quantize(x: Array, centroids: Array, code_dtype=jnp.uint8, *,
             impl: str = "jnp") -> Array:
    """Quantize embeddings (…, M, D) -> codes (…, M) of code_dtype.

    Works for arbitrary leading batch dims (vmapped assignment). `impl`
    routes the assignment: the default "jnp" is the canonical form —
    bit-stable and device-independent, so mesh-less builds reproduce
    everywhere; "auto" uses the Pallas kernel on TPU and the canonical
    form elsewhere (what the sharded build path passes); anything else is
    forwarded to `repro.kernels.ops.kmeans_assign`
    ("pallas"/"interpret"/"ref").
    """
    flat = x.reshape(-1, x.shape[-1])
    if impl == "auto" and jax.default_backend() != "tpu":
        impl = "jnp"
    if impl == "jnp":
        codes = assign(flat, centroids)
    else:
        from repro.kernels import ops as kernel_ops  # lazy: avoid cycle
        codes = kernel_ops.kmeans_assign(flat, centroids, impl=impl)
    return codes.astype(code_dtype).reshape(x.shape[:-1])


def quantization_error(x: Array, centroids: Array) -> Array:
    """Mean squared reconstruction error of the codebook on x (N, D).

    Exactly the k-means inertia: the (clamped, hence non-negative) squared
    distance to the nearest centroid.
    """
    return _inertia(x, centroids)


# ---------------------------------------------------------------------------
# Product-quantization extension (paper §VII "Future work"): split D into
# n_sub sub-spaces with an independent codebook each. Kept API-compatible
# with the single-codebook path; used by benchmarks/storage.py ablations.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PQConfig:
    k: int = 256
    n_sub: int = 4
    iters: int = 15
    seed_batch: int = 4096
    n_restarts: int = 8
    minibatch: int = 0


@partial(jax.jit, static_argnames=("config",))
def pq_fit(key: Array, x: Array, config: PQConfig) -> Array:
    """Train per-subspace codebooks -> (n_sub, K, D/n_sub)."""
    n, d = x.shape
    assert d % config.n_sub == 0, "D must divide n_sub"
    ds = d // config.n_sub
    sub = x.reshape(n, config.n_sub, ds).transpose(1, 0, 2)  # (n_sub, N, ds)
    keys = jax.random.split(key, config.n_sub)
    kcfg = KMeansConfig(k=config.k, iters=config.iters,
                        seed_batch=config.seed_batch,
                        n_restarts=config.n_restarts,
                        minibatch=config.minibatch)
    fit = lambda kk, xx: kmeans_fit(kk, xx, kcfg)[0]
    return jax.vmap(fit)(keys, sub)


def pq_quantize(x: Array, codebooks: Array) -> Array:
    """x (…, D) -> codes (…, n_sub) uint8/16."""
    n_sub, k, ds = codebooks.shape
    flat = x.reshape(-1, n_sub, ds).transpose(1, 0, 2)       # (n_sub, N, ds)
    codes = jax.vmap(assign)(flat, codebooks)                # (n_sub, N)
    dt = jnp.uint8 if k <= 256 else jnp.uint16
    return codes.T.reshape(*x.shape[:-1], n_sub).astype(dt)


def pq_decode(codes: Array, codebooks: Array) -> Array:
    """codes (…, n_sub) -> x̂ (…, n_sub*ds)."""
    n_sub, _, ds = codebooks.shape
    flat = codes.reshape(-1, n_sub).astype(jnp.int32)        # (N, n_sub)
    parts = jax.vmap(lambda cb, c: cb[c], in_axes=(0, 1))(codebooks, flat)
    return parts.transpose(1, 0, 2).reshape(*codes.shape[:-1], n_sub * ds)
