"""Training substrate: fault-tolerant loop, GPipe PP, elastic re-shard."""

from repro.train import elastic, loop  # noqa: F401
