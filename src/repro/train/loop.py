"""Fault-tolerant training loop + GPipe pipeline parallelism.

Fault tolerance (docs/design.md §4):
  * checkpoint/restart — CheckpointManager (atomic+async), auto-resume from
    the latest committed step;
  * NaN/inf guard — the *jitted* step rejects non-finite updates
    functionally (params/opt_state roll back to their pre-step values and a
    skip counter increments), so a single bad batch or flaky-core bitflip
    never corrupts the run;
  * straggler mitigation — data-layer (PrefetchPipeline timeout reserve),
    plus a per-step wall-clock watchdog that logs steps exceeding
    `straggler_factor` x the trailing-median step time (at real scale this
    signal feeds the scheduler to evict the slow host);
  * preemption simulation is tested in tests/test_train_loop.py by killing
    the loop mid-run and resuming.

Pipeline parallelism: `make_pipelined_fn` implements GPipe microbatch
rotation with shard_map + ppermute over a "pipe" mesh axis — used for
depth-sharding beyond the (data, model) production mesh; validated against
the sequential reference in tests on a host-device mesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.ckpt.checkpoint import CheckpointManager

PyTree = Any


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_ckpts: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0


def guard_nonfinite(step_fn: Callable) -> Callable:
    """Wrap (params, opt_state, batch) -> (params, opt_state, metrics) with
    a functional non-finite rollback. Adds metrics["skipped"]."""

    def guarded(params, opt_state, batch):
        new_p, new_o, metrics = step_fn(params, opt_state, batch)
        ok = jnp.isfinite(metrics["loss"])
        sel = lambda a, b: jax.tree.map(
            lambda x, y: jnp.where(ok, x, y), a, b)
        params = sel(new_p, params)
        opt_state = sel(new_o, opt_state)
        metrics = dict(metrics)
        metrics["skipped"] = jnp.where(ok, 0, 1).astype(jnp.int32)
        return params, opt_state, metrics

    return guarded


def run(step_fn: Callable, params: PyTree, opt_state: PyTree,
        batches: Iterator[Dict[str, Any]], cfg: LoopConfig,
        start_step: int = 0, manager: Optional[CheckpointManager] = None,
        log_fn: Callable[[str], None] = print) -> Dict[str, Any]:
    """Run the guarded training loop. step_fn must already be jitted.

    Returns {params, opt_state, step, history, stats}.
    """
    if manager is None:
        manager = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep_ckpts)

    # auto-resume
    restored = manager.restore_latest((params, opt_state))
    if restored is not None:
        start_step, (params, opt_state) = restored
        log_fn(f"[loop] resumed from step {start_step}")

    history = []
    step_times = []
    n_skipped = 0
    stats = {"stragglers": 0, "skipped": 0}
    step = start_step
    guarded = guard_nonfinite(step_fn)

    for step in range(start_step, cfg.total_steps):
        batch = next(batches)
        t0 = time.perf_counter()
        params, opt_state, metrics = guarded(params, opt_state, batch)
        loss = float(metrics["loss"])      # sync point
        dt = time.perf_counter() - t0
        step_times.append(dt)
        n_skipped += int(metrics["skipped"])
        if len(step_times) > 10:
            med = float(np.median(step_times[-50:]))
            if dt > cfg.straggler_factor * med:
                stats["stragglers"] += 1
                log_fn(f"[loop] straggler step {step}: {dt:.3f}s "
                       f"(median {med:.3f}s)")
        history.append({"step": step, "loss": loss,
                        **{k: float(v) for k, v in metrics.items()
                           if k not in ("loss",)}})
        if cfg.log_every and step % cfg.log_every == 0:
            log_fn(f"[loop] step {step} loss {loss:.4f} ({dt*1e3:.1f} ms)")
        if cfg.ckpt_every and (step + 1) % cfg.ckpt_every == 0:
            manager.save_async(step + 1, (params, opt_state))

    manager.wait()
    manager.save(cfg.total_steps, (params, opt_state))
    stats["skipped"] = n_skipped
    return {"params": params, "opt_state": opt_state, "step": step + 1,
            "history": history, "stats": stats}


# ---------------------------------------------------------------------------
# GPipe pipeline parallelism (shard_map + ppermute microbatch rotation)
# ---------------------------------------------------------------------------

def make_pipelined_fn(mesh: Mesh, stage_fn: Callable, n_microbatches: int,
                      axis: str = "pipe") -> Callable:
    """Build f(stage_params, x) running `stage_fn` depth-sharded over `axis`.

    stage_params: pytree with leading dim = n_stages (sharded over axis).
    x: (n_microbatches * mb, ...) activations entering stage 0.
    Schedule: standard GPipe fill/flush — T = n_micro + n_stages - 1 ticks;
    at each tick every stage processes the microbatch it holds (if valid)
    then ppermutes its output to the next stage. Bubble fraction
    (n_stages-1)/T as usual.
    """
    n_stages = mesh.shape[axis]

    def pipelined(stage_params, x):
        def local(stage_params, x):
            # stage_params leaves have leading dim 1 (this stage's slice)
            sp = jax.tree.map(lambda a: a[0], stage_params)
            stage = jax.lax.axis_index(axis)
            mb = x.shape[0] // n_microbatches
            mbs = x.reshape(n_microbatches, mb, *x.shape[1:])
            out = jnp.zeros_like(mbs)
            # current activation buffer + validity tag (mb index, -1 invalid)
            buf = jnp.zeros((mb, *x.shape[1:]), x.dtype)
            tag = jnp.int32(-1)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

            def tick(carry, t):
                buf, tag, out = carry
                # stage 0 injects microbatch t (if any remain)
                inject = jnp.logical_and(stage == 0, t < n_microbatches)
                safe_t = jnp.minimum(t, n_microbatches - 1)
                buf = jnp.where(inject, mbs[safe_t], buf)
                tag = jnp.where(inject, safe_t, tag)
                # all stages process their buffer (compute is unconditional;
                # invalid buffers produce garbage that is never committed)
                y = stage_fn(sp, buf)
                # last stage commits finished microbatches
                commit = jnp.logical_and(stage == n_stages - 1, tag >= 0)
                safe_tag = jnp.maximum(tag, 0)
                out = jnp.where(
                    commit,
                    jax.lax.dynamic_update_index_in_dim(out, y, safe_tag, 0),
                    out)
                # rotate activations to the next stage
                buf = jax.lax.ppermute(y, axis, perm)
                tag = jax.lax.ppermute(tag, axis, perm)
                # stage 0 receives from the last stage: clear its tag
                tag = jnp.where(stage == 0, -1, tag)
                return (buf, tag, out), None

            (buf, tag, out), _ = jax.lax.scan(
                tick, (buf, tag, out), jnp.arange(n_stages + n_microbatches - 1))
            # only the last stage holds real outputs; broadcast via psum
            out = jnp.where(stage == n_stages - 1, out, 0)
            out = jax.lax.psum(out, axis)
            return out.reshape(x.shape)

        spec_params = jax.tree.map(lambda _: P(axis), stage_params)
        return shard_map(
            local, mesh=mesh,
            in_specs=(spec_params, P()), out_specs=P(),
            check_rep=False)(stage_params, x)

    return pipelined
