"""Elastic scaling: resume a run on a different device count / mesh.

Checkpoints store unsharded leaves (ckpt/checkpoint.py), so elasticity is:
build the new mesh, re-resolve every logical spec against it (the
divisibility fallback absorbs axis-size changes), and restore with the new
NamedShardings. `reshard_plan` reports which tensors change their layout —
at production scale this is the prefetch plan for the resharding transfer.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.ckpt import checkpoint as ck
from repro.dist.sharding import Sharder, is_logical_spec

PyTree = Any


def resolve_shardings(sharder: Sharder, spec_tree: PyTree,
                      template: PyTree) -> PyTree:
    """Logical specs + template shapes -> NamedShardings on sharder.mesh."""
    return jax.tree.map(
        lambda spec, leaf: sharder.named(tuple(spec), leaf.shape),
        spec_tree, template, is_leaf=is_logical_spec)


def restore_elastic(directory: str, template: PyTree, spec_tree: PyTree,
                    mesh: Mesh, rules: Optional[Dict] = None
                    ) -> Optional[Tuple[int, PyTree]]:
    """Restore the latest checkpoint resharded onto `mesh`."""
    sharder = Sharder(mesh, rules) if rules else Sharder(mesh)
    shardings = resolve_shardings(sharder, spec_tree, template)
    mgr = ck.CheckpointManager(directory)
    return mgr.restore_latest(template, shardings)


def reshard_plan(old_sharder: Sharder, new_sharder: Sharder,
                 spec_tree: PyTree, template: PyTree) -> Dict[str, tuple]:
    """Which leaves change PartitionSpec between two meshes."""
    flat, _ = jax.tree_util.tree_flatten_with_path(
        template, is_leaf=lambda x: hasattr(x, "shape"))
    specs = jax.tree_util.tree_leaves(spec_tree, is_leaf=is_logical_spec)
    changes = {}
    for (path, leaf), spec in zip(flat, specs):
        old = old_sharder.resolve(tuple(spec), leaf.shape)
        new = new_sharder.resolve(tuple(spec), leaf.shape)
        if old != new:
            changes[jax.tree_util.keystr(path)] = (old, new)
    return changes
