"""Pallas TPU kernels for the HPC-ColPali hot paths.

Kernels (TPU target; validated with interpret=True on CPU against ref.py):
  maxsim.py            — tiled float MaxSim corpus scan
  quantized_maxsim.py  — fused decode-and-score ADC scan (1 B/patch HBM)
  hamming.py           — binary-mode XOR+popcount scan
  kmeans_assign.py     — nearest-centroid assignment (K-Means E-step)

Use the jit'd wrappers in ops.py; they pad, cast, and dispatch per platform.
"""

from repro.kernels import ops, ref  # noqa: F401
