"""Pallas TPU kernel: tiled float MaxSim late-interaction scan.

score(b, n) = sum_i q_mask[b,i] * max_j (d_mask[n,j] ? <q[b,i], d[n,j]> : -inf)

Tiling (docs/design.md §7): the query block for batch row b — (Mq, D) — stays
resident in VMEM across the whole corpus sweep; documents stream through in
blocks of `block_docs` docs ((block_docs*Md, D) flattened so the Q @ D^T is a
single MXU matmul per tile). Per-tile VMEM:

    Mq*D*4  +  block_docs*Md*D*4  +  Mq*block_docs*Md*4 (sims)  +  out

e.g. Mq=32, Md=64, D=128, block_docs=16 -> 16 KB + 512 KB + 128 KB ≈ 0.7 MB,
comfortably inside the ~16 MB v5e VMEM with double buffering. MXU alignment:
choose Mq, block_docs*Md multiples of 128 where possible (ops.py pads).

Grid: (B, N // block_docs); the doc axis is the fastest-varying so the Q
block is reused N/block_docs times per HBM read (grid iteration order on
TPU is minor-to-major: last grid dim innermost).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import vmem

NEG_INF = -1e30


def maxsim_vmem_bytes(block_docs: int, mq: int, md: int, d: int) -> int:
    """Per-grid-step VMEM footprint of ``_maxsim_kernel`` in bytes:
    double-buffered blocks + the (Mq, block_docs*Md) similarity
    temporaries (raw + masked) and per-query reductions."""
    blocks = 4 * (mq * d + mq + block_docs * md * d + block_docs * md
                  + block_docs)
    sims = 4 * (2 * mq * block_docs * md + 2 * mq * block_docs)
    return vmem.DOUBLE_BUFFER * blocks + sims


def _maxsim_kernel(q_ref, qm_ref, d_ref, dm_ref, out_ref):
    # q_ref:  (1, Mq, D)        VMEM
    # qm_ref: (1, Mq)           VMEM
    # d_ref:  (block_docs, Md, D)
    # dm_ref: (block_docs, Md)
    # out_ref: (1, block_docs)
    q = q_ref[0].astype(jnp.float32)                      # (Mq, D)
    d = d_ref[...].astype(jnp.float32)                    # (T, Md, D)
    t, md, dd = d.shape
    d_flat = d.reshape(t * md, dd)
    # One MXU matmul per tile.
    sim = jax.lax.dot_general(q, d_flat,
                              (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    sim = sim.reshape(q.shape[0], t, md)                  # (Mq, T, Md)
    dm = dm_ref[...]                                      # (T, Md) f32 0/1
    sim = jnp.where(dm[None] > 0, sim, NEG_INF)
    per_q = jnp.max(sim, axis=-1)                         # (Mq, T)
    qm = qm_ref[0]                                        # (Mq,)
    out_ref[0, :] = jnp.sum(per_q * qm[:, None], axis=0)


@functools.partial(jax.jit, static_argnames=("block_docs", "interpret"))
def maxsim_pallas(q, q_mask, docs, d_mask, *, block_docs: int = 16,
                  interpret: bool = False):
    """q (B, Mq, D) f32, q_mask (B, Mq) f32, docs (N, Md, D) f32,
    d_mask (N, Md) f32 -> scores (B, N) f32.  N % block_docs == 0."""
    b, mq, dd = q.shape
    n, md, _ = docs.shape
    vmem.check_divisible(n, block_docs, kernel="maxsim_pallas")
    vmem.check_vmem(
        maxsim_vmem_bytes(block_docs, mq, md, dd),
        kernel="maxsim_pallas",
        detail=f"block_docs={block_docs}, Mq={mq}, Md={md}, D={dd}; the "
               f"doc block is ({block_docs * md}, {dd}) f32")
    grid = (b, n // block_docs)
    return pl.pallas_call(
        _maxsim_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, mq, dd), lambda i, j: (i, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, mq), lambda i, j: (i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((block_docs, md, dd), lambda i, j: (j, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((block_docs, md), lambda i, j: (j, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, block_docs), lambda i, j: (i, j),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(q.astype(jnp.float32), q_mask.astype(jnp.float32),
      docs.astype(jnp.float32), d_mask.astype(jnp.float32))
