"""Pallas TPU kernel: tiled nearest-centroid assignment (K-Means E-step).

dist(x, c) = ||x||^2 - 2 x.C^T + ||c||^2 ; argmin over K.

The codebook (K, D) <= 512x128x4 = 256 KB stays VMEM-resident across the
whole sweep; points stream in blocks of `block_n` rows, one MXU matmul per
tile. ||c||^2 is folded in-kernel (recomputed per tile — K*D mults,
negligible vs the matmul, avoids a second input stream).

Grid: (N // block_n,).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import vmem


def kmeans_assign_vmem_bytes(block_n: int, k: int, d: int) -> int:
    """Per-grid-step VMEM footprint of ``_assign_kernel`` in bytes:
    double-buffered blocks (points, codebook, codes out) + the
    (block_n, K) distance temporaries and the centroid-norm fold."""
    blocks = 4 * (block_n * d + k * d + block_n)
    temps = 4 * (k * d + k + 2 * block_n * k) + 4 * block_n
    return vmem.DOUBLE_BUFFER * blocks + temps


def _assign_kernel(x_ref, c_ref, out_ref):
    # x_ref: (block_n, D); c_ref: (K, D); out_ref: (block_n,)
    x = x_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    c2 = jnp.sum(c * c, axis=-1)                          # (K,)
    xc = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    # ||x||^2 is constant per row — argmin unaffected; skip it.
    d = c2[None, :] - 2.0 * xc                            # (block_n, K)
    out_ref[...] = jnp.argmin(d, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign_pallas(x, centroids, *, block_n: int = 256,
                         interpret: bool = False):
    """x (N, D), centroids (K, D) -> codes (N,) int32.  N % block_n == 0."""
    n, d = x.shape
    k, _ = centroids.shape
    vmem.check_divisible(n, block_n, kernel="kmeans_assign_pallas")
    vmem.check_vmem(
        kmeans_assign_vmem_bytes(block_n, k, d),
        kernel="kmeans_assign_pallas",
        detail=f"block_n={block_n}, K={k}, D={d}; the distance tile is "
               f"({block_n}, {k}) f32")
    grid = (n // block_n,)
    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((k, d), lambda i: (0, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(x.astype(jnp.float32), centroids.astype(jnp.float32))
