"""Pallas TPU kernel: fused decode-and-score ADC MaxSim — the paper's hot
path, TPU-adapted (docs/design.md §2).

A float corpus scan reads 4*D = 512 B/patch from HBM; this kernel reads the
1-byte code instead and resolves it against the query-centroid table
T = Q @ C^T (built once per query batch, (Mq, K) f32 <= 64 KB) held in VMEM.
HBM traffic drops ~32x at unchanged MaxSim semantics — converting the
paper's storage win into the bandwidth win that a memory-bound scan needs.

The in-kernel "gather" is realised as a one-hot matmul

    sim = one_hot(codes, K) @ T^T        # (T*Md, K) @ (K, Mq)

which runs on the MXU with perfectly regular access instead of a serialised
VPU gather — the standard TPU idiom for small-table lookups. The one-hot
tile (block_docs*Md, K) dominates the per-grid-step VMEM footprint;
`qmaxsim_vmem_bytes` prices it and the entry point *checks* it against the
16 MiB budget (a `ValueError`, not a latent Mosaic failure — e.g. K=512 at
Md=128 no longer fits the default block_docs=32 and must drop to 16, which
`core/scan._kernel_tile` now does automatically).

Grid: (B, N // block_docs), doc axis innermost so the per-batch table block
is reused across the corpus sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import vmem

NEG_INF = -1e30


def qmaxsim_vmem_bytes(block_docs: int, mq: int, k: int, md: int) -> int:
    """Per-grid-step VMEM footprint of ``_qmaxsim_kernel`` in bytes.

    Double-buffered blocks (table, q_mask, codes, d_mask, out) plus the
    kernel temporaries: the one-hot expansion (iota i32 + eq bool +
    one-hot f32 over (block_docs*Md, K) — the dominant term) and the
    similarity/reduction buffers.
    """
    blocks = 4 * (mq * k + mq + 2 * block_docs * md + block_docs)
    onehot = block_docs * md * k * (4 + 1 + 4)
    sims = 4 * (2 * block_docs * md * mq + 2 * block_docs * mq)
    return vmem.DOUBLE_BUFFER * blocks + onehot + sims


def _qmaxsim_kernel(tab_ref, qm_ref, codes_ref, dm_ref, out_ref):
    # tab_ref:  (1, Mq, K)  query-centroid table, VMEM-resident
    # qm_ref:   (1, Mq)
    # codes_ref:(block_docs, Md) int32
    # dm_ref:   (block_docs, Md) f32
    # out_ref:  (1, block_docs)
    tab = tab_ref[0]                                      # (Mq, K) f32
    mq, k = tab.shape
    codes = codes_ref[...]                                # (T, Md) i32
    t, md = codes.shape
    flat = codes.reshape(t * md)
    # One-hot gather on the MXU: (T*Md, K) @ (K, Mq) -> (T*Md, Mq)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (t * md, k), 1)
    onehot = (iota_k == flat[:, None]).astype(jnp.float32)
    sim = jax.lax.dot_general(onehot, tab,
                              (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    sim = sim.reshape(t, md, mq)                          # (T, Md, Mq)
    dm = dm_ref[...]                                      # (T, Md)
    sim = jnp.where(dm[..., None] > 0, sim, NEG_INF)
    per_q = jnp.max(sim, axis=1)                          # (T, Mq)
    qm = qm_ref[0]
    out_ref[0, :] = jnp.sum(per_q * qm[None, :], axis=1)


@functools.partial(jax.jit, static_argnames=("block_docs", "interpret"))
def quantized_maxsim_pallas(table, q_mask, codes, d_mask, *,
                            block_docs: int = 32, interpret: bool = False):
    """table (B, Mq, K) f32, q_mask (B, Mq) f32, codes (N, Md) int,
    d_mask (N, Md) f32 -> scores (B, N) f32.  N % block_docs == 0."""
    b, mq, k = table.shape
    n, md = codes.shape
    vmem.check_divisible(n, block_docs, kernel="quantized_maxsim_pallas")
    vmem.check_vmem(
        qmaxsim_vmem_bytes(block_docs, mq, k, md),
        kernel="quantized_maxsim_pallas",
        detail=f"block_docs={block_docs}, Mq={mq}, K={k}, Md={md}; the "
               f"one-hot tile is ({block_docs * md}, {k}) f32")
    grid = (b, n // block_docs)
    return pl.pallas_call(
        _qmaxsim_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, mq, k), lambda i, j: (i, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, mq), lambda i, j: (i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((block_docs, md), lambda i, j: (j, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((block_docs, md), lambda i, j: (j, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, block_docs), lambda i, j: (i, j),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(table.astype(jnp.float32), q_mask.astype(jnp.float32),
      codes.astype(jnp.int32), d_mask.astype(jnp.float32))
