"""Pallas TPU kernel: binary-mode Hamming MaxSim scan (paper §III-D).

sim(i, j) = bits - popcount(q_code_i XOR d_code_j), MaxSim-reduced exactly
like the float kernel. x86 POPCNT becomes `lax.population_count` on the VPU
(8x128 int32 lanes); there is no MXU work here — the scan is bandwidth-bound
on the 1-2 B/patch code stream, which is the point of the binary mode.

Codes arrive as int32 lanes (ops.py casts from the uint16 storage form; the
bit-packed on-disk layout is unpacked once at load, see core/binary.py).

Grid: (B, N // block_docs), doc axis innermost; the query code vector
(Mq int32) is VMEM-resident across the sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import vmem

NEG_INF = -1e30


def hamming_vmem_bytes(block_docs: int, mq: int, md: int) -> int:
    """Per-grid-step VMEM footprint of ``_hamming_kernel`` in bytes:
    double-buffered blocks + the (Mq, block_docs, Md) xor/popcount/
    masked-sim temporaries and per-query reductions. The SMEM bits
    scalar is excluded (not VMEM)."""
    blocks = 4 * (2 * mq + 2 * block_docs * md + block_docs)
    temps = 4 * (4 * mq * block_docs * md + 2 * mq * block_docs)
    return vmem.DOUBLE_BUFFER * blocks + temps


def _hamming_kernel(bits_ref, q_ref, qm_ref, d_ref, dm_ref, out_ref):
    # bits_ref: (1, 1) i32 in SMEM  — b = ceil(log2 K)
    # q_ref:  (1, Mq) i32; qm_ref: (1, Mq) f32
    # d_ref:  (block_docs, Md) i32; dm_ref: (block_docs, Md) f32
    # out_ref: (1, block_docs) f32
    bits = bits_ref[0, 0]
    q = q_ref[0]                                          # (Mq,)
    d = d_ref[...]                                        # (T, Md)
    x = jax.lax.population_count(
        jnp.bitwise_xor(q[:, None, None], d[None, :, :])) # (Mq, T, Md)
    sim = (bits - x).astype(jnp.float32)
    dm = dm_ref[...]
    sim = jnp.where(dm[None] > 0, sim, NEG_INF)
    per_q = jnp.max(sim, axis=-1)                         # (Mq, T)
    qm = qm_ref[0]
    out_ref[0, :] = jnp.sum(per_q * qm[:, None], axis=0)


@functools.partial(jax.jit, static_argnames=("bits", "block_docs", "interpret"))
def hamming_maxsim_pallas(q_codes, q_mask, d_codes, d_mask, *, bits: int,
                          block_docs: int = 64, interpret: bool = False):
    """q_codes (B, Mq) int, d_codes (N, Md) int, masks f32 ->
    scores (B, N) f32.  N % block_docs == 0."""
    b, mq = q_codes.shape
    n, md = d_codes.shape
    vmem.check_divisible(n, block_docs, kernel="hamming_maxsim_pallas")
    vmem.check_vmem(
        hamming_vmem_bytes(block_docs, mq, md),
        kernel="hamming_maxsim_pallas",
        detail=f"block_docs={block_docs}, Mq={mq}, Md={md}; the xor/"
               f"popcount temporaries are ({mq}, {block_docs}, {md}) i32")
    mask_b = (1 << bits) - 1
    qc = (q_codes.astype(jnp.int32) & mask_b)
    dc = (d_codes.astype(jnp.int32) & mask_b)
    bits_arr = jnp.full((1, 1), bits, jnp.int32)
    grid = (b, n // block_docs)
    return pl.pallas_call(
        _hamming_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, mq), lambda i, j: (i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, mq), lambda i, j: (i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((block_docs, md), lambda i, j: (j, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((block_docs, md), lambda i, j: (j, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, block_docs), lambda i, j: (i, j),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(bits_arr, qc, q_mask.astype(jnp.float32), dc,
      d_mask.astype(jnp.float32))
