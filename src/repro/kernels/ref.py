"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are asserted against (interpret=True
on CPU, real lowering on TPU). They intentionally mirror the kernels'
numerical contracts: fp32 accumulation, mask conventions (float 0/1 masks),
and -inf handling for empty doc-patch slots.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array
NEG_INF = -1e30


def maxsim(q: Array, q_mask: Array, docs: Array, d_mask: Array) -> Array:
    """Float MaxSim late interaction.

    q (B, Mq, D) fp; q_mask (B, Mq) f32 0/1; docs (N, Md, D); d_mask (N, Md).
    -> scores (B, N) f32.
    """
    sim = jnp.einsum("bqd,nkd->bnqk", q.astype(jnp.float32),
                     docs.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    sim = jnp.where(d_mask[None, :, None, :] > 0, sim, NEG_INF)
    per_q = jnp.max(sim, axis=-1)                     # (B, N, Mq)
    per_q = per_q * q_mask[:, None, :]
    return jnp.sum(per_q, axis=-1)


def quantized_maxsim(table: Array, q_mask: Array, codes: Array,
                     d_mask: Array) -> Array:
    """ADC MaxSim from a precomputed query-centroid table.

    table (B, Mq, K) f32; codes (N, Md) int; d_mask (N, Md) f32 0/1.
    -> scores (B, N) f32.
    """
    c = codes.astype(jnp.int32)
    sim = table[:, :, c]                              # (B, Mq, N, Md)
    sim = jnp.moveaxis(sim, 2, 1)                     # (B, N, Mq, Md)
    sim = jnp.where(d_mask[None, :, None, :] > 0, sim, NEG_INF)
    per_q = jnp.max(sim, axis=-1)
    per_q = per_q * q_mask[:, None, :]
    return jnp.sum(per_q, axis=-1)


def hamming_maxsim(q_codes: Array, q_mask: Array, d_codes: Array,
                   d_mask: Array, bits: int) -> Array:
    """Binary-mode MaxSim: sim = bits - popcount(xor).

    q_codes (B, Mq) int; d_codes (N, Md) int; masks f32 0/1.
    -> scores (B, N) f32 (float for kernel-accum parity).
    """
    mask_b = jnp.uint32((1 << bits) - 1)
    qx = q_codes.astype(jnp.uint32) & mask_b
    dx = d_codes.astype(jnp.uint32) & mask_b
    h = jax.lax.population_count(qx[:, :, None, None] ^ dx[None, None, :, :])
    sim = (bits - h).astype(jnp.float32)              # (B, Mq, N, Md)
    sim = jnp.moveaxis(sim, 2, 1)                     # (B, N, Mq, Md)
    sim = jnp.where(d_mask[None, :, None, :] > 0, sim, NEG_INF)
    per_q = jnp.max(sim, axis=-1)
    per_q = per_q * q_mask[:, None, :]
    return jnp.sum(per_q, axis=-1)


def kmeans_assign(x: Array, centroids: Array) -> Array:
    """Nearest centroid (squared L2). x (N, D), centroids (K, D) -> (N,) i32."""
    x = x.astype(jnp.float32)
    c = centroids.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    c2 = jnp.sum(c * c, axis=-1)
    d = x2 - 2.0 * (x @ c.T) + c2[None, :]
    return jnp.argmin(d, axis=-1).astype(jnp.int32)
