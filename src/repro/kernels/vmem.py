"""Shared VMEM-budget accounting for the Pallas kernels.

Every kernel in this package streams fixed-size blocks through VMEM
(~16 MiB per TPU v5e core, see /docs/design.md §10). Each kernel module
exposes a ``*_vmem_bytes`` function computing its per-grid-step
footprint from the same accounting the static verifier
(``repro.analysis.pallas_check``) applies to the traced jaxpr:

    footprint = DOUBLE_BUFFER * (sum of VMEM in/out block bytes)
              + sum of in-kernel non-view temporaries

(block operands are double-buffered by the Pallas pipeline; SMEM
scalars are excluded). The entry points validate this *eagerly* at
trace time — a tile that cannot fit raises ``ValueError`` carrying the
computed footprint instead of failing opaquely inside Mosaic — and the
scan engine's tile picker (``core/scan._kernel_tile``) consults the
same functions to shrink tiles until they fit.
"""
from __future__ import annotations

MiB = 2 ** 20

# TPU v5e per-core VMEM. Other generations are close enough (v4: 16 MiB,
# v5p: 16 MiB) that one conservative budget serves as the contract.
VMEM_BUDGET_BYTES = 16 * MiB

# Pallas pipelines block operands: while grid step i computes, step
# i+1's blocks are prefetched — every block buffer exists twice.
DOUBLE_BUFFER = 2

__all__ = ["DOUBLE_BUFFER", "MiB", "VMEM_BUDGET_BYTES",
           "check_divisible", "check_vmem", "fits"]


def fits(footprint_bytes: int, budget: int = VMEM_BUDGET_BYTES) -> bool:
    return footprint_bytes <= budget


def check_divisible(n: int, block_docs: int, *, kernel: str,
                    axis: str = "N") -> None:
    """The grid contract: the streamed axis must tile exactly."""
    if block_docs <= 0:
        raise ValueError(
            f"{kernel}: block_docs must be positive, got {block_docs}")
    if n % block_docs:
        raise ValueError(
            f"{kernel}: {axis}={n} is not divisible by "
            f"block_docs={block_docs} — the grid would drop the last "
            f"{n % block_docs} row(s); pad the operand (kernels/ops.py "
            f"does) or pick a divisor tile")


def check_vmem(footprint_bytes: int, *, kernel: str, detail: str,
               budget: int = VMEM_BUDGET_BYTES) -> None:
    """Raise if a kernel's per-grid-step footprint exceeds the budget."""
    if not fits(footprint_bytes, budget):
        raise ValueError(
            f"{kernel}: per-grid-step VMEM footprint "
            f"{footprint_bytes / MiB:.2f} MiB ({detail}) exceeds the "
            f"{budget / MiB:.0f} MiB budget — shrink block_docs (the "
            f"scan engine's _kernel_tile does this automatically) or "
            f"the table width")
