"""Public jit'd wrappers for the Pallas kernels.

Responsibilities:
  * platform dispatch — real Pallas lowering on TPU, ``interpret=True``
    execution when requested (tests), pure-jnp oracle otherwise (CPU prod
    path: interpret mode is Python-slow, the oracle is compiled XLA);
  * padding — corpora are padded to the doc-block multiple with masked-out
    rows (scores for pad rows are dropped before returning);
  * dtype hygiene — bool masks -> f32 0/1, codes -> int32 lanes.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import hamming as hamming_k
from repro.kernels import kmeans_assign as kmeans_k
from repro.kernels import maxsim as maxsim_k
from repro.kernels import quantized_maxsim as qmaxsim_k
from repro.kernels import ref

Array = jax.Array
Impl = Literal["auto", "pallas", "interpret", "ref"]


def _resolve(impl: Impl) -> str:
    """Platform dispatch, shared with the streaming scan engine.

    core/scan.py owns the single auto->pallas-on-TPU policy; this module
    spells the compiled-XLA oracle "ref" where the engine says "jnp"
    (the resolver accepts both). Lazy import: repro.core.scan imports
    kernel modules from this package, so binding it at module top would
    race package init.
    """
    from repro.core.scan import resolve_impl
    mode = resolve_impl(impl)
    return "ref" if mode == "jnp" else mode


def _pad_docs(arrs, n, block):
    """Pad dim 0 of each array to the next multiple of `block`."""
    n_pad = (-n) % block
    if n_pad == 0:
        return arrs, n
    out = []
    for a in arrs:
        pad_width = [(0, n_pad)] + [(0, 0)] * (a.ndim - 1)
        out.append(jnp.pad(a, pad_width))
    return out, n + n_pad


@functools.partial(jax.jit, static_argnames=("impl", "block_docs"))
def maxsim(q: Array, q_mask: Array, docs: Array, d_mask: Array, *,
           impl: Impl = "auto", block_docs: int = 16) -> Array:
    """Float MaxSim scores (B, N)."""
    mode = _resolve(impl)
    qm = q_mask.astype(jnp.float32)
    dm = d_mask.astype(jnp.float32)
    if mode == "ref":
        return ref.maxsim(q, qm, docs, dm)
    n = docs.shape[0]
    (docs_p, dm_p), n_p = _pad_docs((docs, dm), n, block_docs)
    out = maxsim_k.maxsim_pallas(q, qm, docs_p, dm_p,
                                 block_docs=block_docs,
                                 interpret=(mode == "interpret"))
    return out[:, :n]


@functools.partial(jax.jit, static_argnames=("impl", "block_docs"))
def quantized_maxsim(q: Array, q_mask: Array, codes: Array, d_mask: Array,
                     codebook: Array, *, impl: Impl = "auto",
                     block_docs: int = 32) -> Array:
    """Fused ADC MaxSim scores (B, N) over a quantized corpus."""
    mode = _resolve(impl)
    qm = q_mask.astype(jnp.float32)
    dm = d_mask.astype(jnp.float32)
    table = jnp.einsum("bqd,kd->bqk", q.astype(jnp.float32),
                       codebook.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    if mode == "ref":
        return ref.quantized_maxsim(table, qm, codes, dm)
    n = codes.shape[0]
    (codes_p, dm_p), n_p = _pad_docs((codes.astype(jnp.int32), dm), n,
                                     block_docs)
    out = qmaxsim_k.quantized_maxsim_pallas(
        table, qm, codes_p, dm_p, block_docs=block_docs,
        interpret=(mode == "interpret"))
    return out[:, :n]


@functools.partial(jax.jit, static_argnames=("bits", "impl", "block_docs"))
def hamming_maxsim(q_codes: Array, q_mask: Array, d_codes: Array,
                   d_mask: Array, *, bits: int, impl: Impl = "auto",
                   block_docs: int = 64) -> Array:
    """Binary-mode MaxSim scores (B, N)."""
    mode = _resolve(impl)
    qm = q_mask.astype(jnp.float32)
    dm = d_mask.astype(jnp.float32)
    if mode == "ref":
        return ref.hamming_maxsim(q_codes, qm, d_codes, dm, bits)
    n = d_codes.shape[0]
    (codes_p, dm_p), n_p = _pad_docs((d_codes.astype(jnp.int32), dm), n,
                                     block_docs)
    out = hamming_k.hamming_maxsim_pallas(
        q_codes, qm, codes_p, dm_p, bits=bits, block_docs=block_docs,
        interpret=(mode == "interpret"))
    return out[:, :n]


@functools.partial(jax.jit, static_argnames=("impl", "block_n"))
def kmeans_assign(x: Array, centroids: Array, *, impl: Impl = "auto",
                  block_n: int = 256) -> Array:
    """Nearest-centroid codes (N,) int32."""
    mode = _resolve(impl)
    if mode == "ref":
        return ref.kmeans_assign(x, centroids)
    n = x.shape[0]
    (x_p,), n_p = _pad_docs((x,), n, block_n)
    out = kmeans_k.kmeans_assign_pallas(
        x_p, centroids, block_n=block_n, interpret=(mode == "interpret"))
    return out[:n]
