"""repro — HPC-ColPali: hierarchical patch compression for multi-vector
document retrieval, as a multi-pod JAX/TPU framework.

Subpackages:
  core/     the paper's contribution (quantization, pruning, binary,
            late interaction, indexes, pipeline, sharded retrieval, RAG)
  models/   LM transformers (dense/MoE/GQA), ColPali encoder, PNA GNN, recsys
  kernels/  Pallas TPU kernels (maxsim, quantized_maxsim, hamming, kmeans)
  data/     synthetic corpora, samplers, sharded host pipeline
  optim/    AdamW (+ int8 moments), schedules, gradient compression
  dist/     logical-axis sharding rules, collective helpers
  ckpt/     atomic/async/elastic checkpointing
  train/    fault-tolerant training loop, pipeline parallelism
  serving/  batched retrieval serving
  configs/  assigned architectures + the paper's own config
  launch/   mesh, dryrun, train, serve entry points
"""

__version__ = "1.0.0"
