"""Jaxpr cost model: per-path FLOP / HBM-traffic roofline contracts.

The paper's efficiency claim is a *bandwidth* claim: 32x smaller codes
turn the memory-bound ADC scan into 32x less HBM traffic per document.
PR 6 made the memory *envelope* a statically checked contract; this
module does the same for compute and traffic so a regression in
arithmetic intensity is caught at trace time, before any benchmark runs
(the way PLAID and the ADC literature reason about per-query byte/FLOP
budgets analytically).

For each ``BudgetManifest`` the analyzer traces the entry point at the
manifest's two corpus sizes (symbolic ``ShapeDtypeStruct`` — zero
allocation) and walks the closed jaxpr recursively:

  * **FLOPs** per primitive: ``dot_general`` from its dimension numbers
    (2*M*N*K per batch element), elementwise/select/compare ops at one
    FLOP per output element, reductions at one per *input* element,
    ``top_k``/``sort`` at n*ceil(log2 n). Structural primitives
    (reshape, broadcast, gather, slices, converts) cost zero FLOPs.
  * **HBM bytes moved**: top-level inputs (read once) + outputs +
    *materializing* intermediates. The model is fusion-aware: an
    elementwise/reduction intermediate small enough to stay resident in
    on-chip memory (``resident_bytes``, default the budget analyzer's
    64 MiB block envelope) is assumed fused into its consumer and moves
    nothing; primitives that inherently produce a new buffer
    (``dynamic_slice`` out of an HBM operand — the streamed corpus
    block, ``convert_element_type``, ``concatenate``, ``dot_general``,
    ``top_k``, ``sort``, scatters) always count; ANY intermediate larger
    than ``resident_bytes`` counts regardless of primitive — that is
    exactly how the unblocked ``(B, Mq, N, Md)`` ADC gather shows up.
  * **Control flow**: ``pjit``-style calls recurse at cost x1; ``scan``
    bodies recurse x ``length`` (the streaming sweep's corpus traffic
    scales through the trip count); ``cond`` takes the max over
    branches; ``while`` bodies count once (a static lower bound — the
    report carries ``while_loops`` so entry points with data-dependent
    trip counts, e.g. the hnsw descent, are visibly lower-bounded).

Two-size tracing splits every metric into a static part and a per-doc
marginal (``flops_per_doc``, ``bytes_per_doc``) exactly like the memory
budgets. Arithmetic intensity = FLOPs / bytes is classified against the
declarative per-platform ``RooflineSpec`` table (compute-bound above the
ridge FLOP/byte, memory-bound below), and the whole report is gated two
ways by ``tools/jaxlint.py --cost``:

  * **absolute contracts** — a manifest may declare a ``CostContract``
    (max FLOPs/doc, max traffic bytes/doc): the design envelope, not
    what the code happens to cost today;
  * **drift vs baseline** — the committed ``COST_baseline.json``
    artifact pins every entry point's numbers; an increase beyond
    tolerance fails CI with the offending primitives named (per-prim
    FLOP/byte deltas), no benchmark run required.
"""
from __future__ import annotations

import dataclasses
import json
import math
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.analysis.jaxpr_budget import VIEW_PRIMS

__all__ = [
    "Cost",
    "CostContract",
    "CostViolation",
    "RooflineSpec",
    "ROOFLINES",
    "RESIDENT_BYTES",
    "check_against_baseline",
    "classify_bound",
    "cost_report",
    "jaxpr_cost",
    "load_baseline",
    "write_baseline",
]

MiB = 2 ** 20

# Fusion-awareness threshold: intermediates at or below this stay
# resident (cache/VMEM at block scale) and move no HBM bytes; anything
# larger spills. Deliberately the same 64 MiB envelope jaxpr_budget
# enforces for the blocked working set — the two models agree on what
# "fits on chip" means.
RESIDENT_BYTES = 64 * MiB

# Primitives whose output never moves bytes on its own: relayouts and
# lazily-generated values XLA folds into consumers at any size.
_FREE_PRIMS = VIEW_PRIMS | {"broadcast_in_dim", "iota", "copy"}

# Primitives that inherently write a new buffer regardless of size:
# slices streamed out of HBM operands, dtype converts, concatenations,
# MXU outputs, sorts. (`gather` is deliberately NOT here: a block-sized
# table lookup fuses into its reduction; the *unblocked* gather is
# caught by the resident_bytes threshold instead.)
_MATERIALIZING = {
    "dynamic_slice", "dynamic_update_slice", "concatenate",
    "convert_element_type", "dot_general", "top_k", "sort",
    "scatter", "scatter-add", "scatter_add", "pad",
}

# One FLOP per *output* element.
_ELEMENTWISE = {
    "add", "sub", "mul", "div", "rem", "pow", "integer_pow", "max", "min",
    "neg", "abs", "sign", "floor", "ceil", "round", "exp", "log", "log1p",
    "expm1", "tanh", "logistic", "sqrt", "rsqrt", "cbrt", "erf", "erfc",
    "sin", "cos", "tan", "atan2", "and", "or", "xor", "not", "shift_left",
    "shift_right_logical", "shift_right_arithmetic", "population_count",
    "clz", "nextafter", "select_n", "clamp", "eq", "ne", "lt", "le", "gt",
    "ge", "is_finite", "square",
}

# One FLOP per *input* element (the reduction tree).
_REDUCERS = {
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "reduce_and",
    "reduce_or", "reduce_xor", "argmax", "argmin", "reduce_precision",
    "cumsum", "cummax", "cummin", "cumprod", "cumlogsumexp",
}

# eqn params that carry sub-jaxprs to recurse into at cost x1
_CALL_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr")


@dataclasses.dataclass(frozen=True)
class RooflineSpec:
    """One platform's roofline: peak FLOP/s and HBM bandwidth.

    ``ridge`` is the arithmetic intensity (FLOP/byte) at which the
    platform transitions from memory- to compute-bound.
    """

    name: str
    peak_flops: float       # FLOP/s
    hbm_bw: float           # bytes/s

    @property
    def ridge(self) -> float:
        return self.peak_flops / self.hbm_bw


def _default_rooflines() -> Tuple[RooflineSpec, ...]:
    # TPU numbers come from the one source of truth (launch/mesh.py —
    # the same constants the dry-run roofline report uses).
    from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16
    return (
        RooflineSpec("tpu_v5e", PEAK_FLOPS_BF16, HBM_BW),
        # a CI-class x86 core: ~100 GFLOP/s f32, ~40 GB/s DRAM
        RooflineSpec("cpu_ci", 100e9, 40e9),
    )


ROOFLINES: Tuple[RooflineSpec, ...] = _default_rooflines()


@dataclasses.dataclass(frozen=True)
class CostContract:
    """Absolute per-path design envelope (declared on a manifest).

    Numbers come from the entry point's *design*, not from what it
    happens to cost today — the drift gate vs COST_baseline.json handles
    "today"; this handles "ever".
    """

    max_flops_per_doc: Optional[float] = None
    max_bytes_per_doc: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class CostViolation:
    """One cost-contract / baseline-drift violation."""

    manifest: str
    kind: str        # "contract" | "drift" | "baseline"
    detail: str

    def __str__(self) -> str:
        return f"[{self.manifest}] {self.kind}: {self.detail}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class Cost:
    """Accumulated FLOPs / HBM bytes with a per-primitive breakdown."""

    __slots__ = ("flops", "bytes", "prim_flops", "prim_bytes",
                 "while_loops")

    def __init__(self):
        self.flops = 0
        self.bytes = 0
        self.prim_flops: Dict[str, int] = {}
        self.prim_bytes: Dict[str, int] = {}
        self.while_loops = 0

    def add_flops(self, prim: str, n: int) -> None:
        if n:
            self.flops += n
            self.prim_flops[prim] = self.prim_flops.get(prim, 0) + n

    def add_bytes(self, prim: str, n: int) -> None:
        if n:
            self.bytes += n
            self.prim_bytes[prim] = self.prim_bytes.get(prim, 0) + n

    def merge(self, other: "Cost", times: int = 1) -> None:
        self.flops += other.flops * times
        self.bytes += other.bytes * times
        self.while_loops += other.while_loops
        for k, v in other.prim_flops.items():
            self.prim_flops[k] = self.prim_flops.get(k, 0) + v * times
        for k, v in other.prim_bytes.items():
            self.prim_bytes[k] = self.prim_bytes.get(k, 0) + v * times


def _aval_elems(aval) -> Optional[int]:
    shape = getattr(aval, "shape", None)
    if shape is None or getattr(aval, "dtype", None) is None:
        return None
    return int(np.prod(shape, dtype=np.int64)) if len(shape) else 1


def _aval_bytes(aval) -> Optional[int]:
    n = _aval_elems(aval)
    if n is None:
        return None
    return n * np.dtype(aval.dtype).itemsize


def _dot_general_flops(eqn) -> int:
    """2 * batch * M * N * K from the dimension numbers."""
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    batch = int(np.prod([lhs.shape[i] for i in lb], dtype=np.int64)) \
        if lb else 1
    contract = int(np.prod([lhs.shape[i] for i in lc], dtype=np.int64)) \
        if lc else 1
    m = int(np.prod([d for i, d in enumerate(lhs.shape)
                     if i not in set(lc) | set(lb)], dtype=np.int64))
    n = int(np.prod([d for i, d in enumerate(rhs.shape)
                     if i not in set(rc) | set(rb)], dtype=np.int64))
    return 2 * batch * m * n * contract


def _eqn_flops(eqn) -> int:
    name = eqn.primitive.name
    if name == "dot_general":
        return _dot_general_flops(eqn)
    if name in _ELEMENTWISE:
        out = _aval_elems(eqn.outvars[0].aval)
        return out or 0
    if name in _REDUCERS:
        src = _aval_elems(eqn.invars[0].aval)
        return src or 0
    if name in ("top_k", "sort"):
        src = _aval_elems(eqn.invars[0].aval) or 0
        return src * max(1, math.ceil(math.log2(max(src, 2))))
    return 0


def _sub_jaxprs(param_value):
    vals = param_value if isinstance(param_value, (tuple, list)) \
        else (param_value,)
    for v in vals:
        inner = getattr(v, "jaxpr", None)
        if inner is not None and hasattr(inner, "eqns"):
            yield inner
        elif hasattr(v, "eqns"):
            yield v


def jaxpr_cost(jaxpr, *, resident_bytes: int = RESIDENT_BYTES,
               _counted=None) -> Cost:
    """Walk one (possibly nested) jaxpr; returns intermediate-only Cost.

    Input/output traffic is added by :func:`closed_jaxpr_cost` — this
    function prices equations so control-flow recursion can scale it.
    ``_counted`` collects ids of vars whose bytes were already charged,
    so top-level outvars are not double-counted.
    """
    cost = Cost()
    counted = _counted if _counted is not None else set()
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name

        if name == "scan":
            inner = Cost()
            for sub in _sub_jaxprs(eqn.params["jaxpr"]):
                inner.merge(jaxpr_cost(sub, resident_bytes=resident_bytes))
            cost.merge(inner, times=int(eqn.params.get("length", 1)))
            # stacked ys / final carries land as this eqn's outvars:
            # price them with the standard rule below
        elif name == "while":
            cost.while_loops += 1
            for key in ("cond_jaxpr", "body_jaxpr"):
                for sub in _sub_jaxprs(eqn.params[key]):
                    cost.merge(jaxpr_cost(sub,
                                          resident_bytes=resident_bytes))
        elif name == "cond":
            branches = [Cost() for _ in eqn.params["branches"]]
            for acc, br in zip(branches, eqn.params["branches"]):
                for sub in _sub_jaxprs(br):
                    acc.merge(jaxpr_cost(sub,
                                         resident_bytes=resident_bytes))
            if branches:
                cost.merge(max(branches, key=lambda c: (c.flops, c.bytes)))
        else:
            recursed = False
            for key in _CALL_PARAMS:
                if key in eqn.params:
                    for sub in _sub_jaxprs(eqn.params[key]):
                        cost.merge(jaxpr_cost(
                            sub, resident_bytes=resident_bytes))
                        recursed = True
            if not recursed:
                cost.add_flops(name, _eqn_flops(eqn))

        # traffic: outputs of this eqn (call-like eqns included — their
        # result buffers are written once at this level)
        if name in _FREE_PRIMS:
            continue
        for v in eqn.outvars:
            b = _aval_bytes(v.aval)
            if b is None:
                continue
            if name in _MATERIALIZING or b > resident_bytes:
                cost.add_bytes(name, b)
                counted.add(id(v))
    return cost


def closed_jaxpr_cost(closed, *, resident_bytes: int = RESIDENT_BYTES
                      ) -> Cost:
    """Full traffic model: invars (read once) + eqns + uncounted outvars."""
    counted: set = set()
    cost = jaxpr_cost(closed.jaxpr, resident_bytes=resident_bytes,
                      _counted=counted)
    for v in closed.jaxpr.invars:
        b = _aval_bytes(v.aval)
        if b is not None:
            cost.add_bytes("<inputs>", b)
    for v in closed.jaxpr.outvars:
        if id(v) in counted:
            continue
        b = _aval_bytes(getattr(v, "aval", None))
        if b is not None:
            cost.add_bytes("<outputs>", b)
    return cost


def classify_bound(intensity: float,
                   rooflines: Tuple[RooflineSpec, ...] = ROOFLINES
                   ) -> Dict[str, str]:
    """'memory' below each platform's ridge intensity, 'compute' above."""
    return {r.name: ("compute" if intensity >= r.ridge else "memory")
            for r in rooflines}


def cost_report(manifest, *, resident_bytes: int = RESIDENT_BYTES) -> dict:
    """Trace one manifest at (n, n_alt) and price both; returns the
    machine-readable entry COST_baseline.json pins."""
    fn_big, args_big = manifest.trace(manifest.n)
    big = closed_jaxpr_cost(jax.make_jaxpr(fn_big)(*args_big),
                            resident_bytes=resident_bytes)
    fn_small, args_small = manifest.trace(manifest.n_alt)
    small = closed_jaxpr_cost(jax.make_jaxpr(fn_small)(*args_small),
                              resident_bytes=resident_bytes)
    dn = manifest.n - manifest.n_alt
    flops_per_doc = (big.flops - small.flops) / dn
    bytes_per_doc = (big.bytes - small.bytes) / dn
    intensity = big.flops / big.bytes if big.bytes else float("inf")
    report = {
        "manifest": manifest.name,
        "n": manifest.n,
        "flops": big.flops,
        "hbm_bytes": big.bytes,
        "flops_per_doc": flops_per_doc,
        "bytes_per_doc": bytes_per_doc,
        "intensity": intensity,
        "bound": classify_bound(intensity),
        "while_loops": big.while_loops,
        "prim_flops": dict(sorted(big.prim_flops.items(),
                                  key=lambda kv: -kv[1])),
        "prim_bytes": dict(sorted(big.prim_bytes.items(),
                                  key=lambda kv: -kv[1])),
    }
    contract = getattr(manifest, "cost", None)
    violations: List[CostViolation] = []
    if contract is not None:
        if (contract.max_flops_per_doc is not None
                and flops_per_doc > contract.max_flops_per_doc):
            violations.append(CostViolation(
                manifest.name, "contract",
                f"flops_per_doc {flops_per_doc:.1f} exceeds the declared "
                f"envelope {contract.max_flops_per_doc:.1f} "
                f"(top FLOP primitives: {_top(big.prim_flops)})"))
        if (contract.max_bytes_per_doc is not None
                and bytes_per_doc > contract.max_bytes_per_doc):
            violations.append(CostViolation(
                manifest.name, "contract",
                f"bytes_per_doc {bytes_per_doc:.1f} exceeds the declared "
                f"envelope {contract.max_bytes_per_doc:.1f} "
                f"(top traffic primitives: {_top(big.prim_bytes)})"))
    report["violations"] = [v.to_json() for v in violations]
    report["ok"] = not violations
    return report


def _top(prim_map: Dict[str, int], k: int = 3) -> str:
    items = sorted(prim_map.items(), key=lambda kv: -kv[1])[:k]
    return ", ".join(f"{p}={v:.3g}" for p, v in items)


def _prim_deltas(cur: Dict[str, float], base: Dict[str, float],
                 k: int = 3) -> str:
    """Name the primitive chain responsible for an inflation."""
    deltas = {p: cur.get(p, 0) - base.get(p, 0)
              for p in set(cur) | set(base)}
    worst = sorted(deltas.items(), key=lambda kv: -kv[1])[:k]
    worst = [(p, d) for p, d in worst if d > 0]
    if not worst:
        return "no single primitive dominates"
    return ", ".join(f"{p} +{d:.3g}" for p, d in worst)


# metrics gated against the committed baseline (all "lower is better")
_GATED_METRICS = ("flops", "hbm_bytes", "flops_per_doc", "bytes_per_doc")


def check_against_baseline(reports: List[dict], baseline: dict,
                           tolerance: float = 0.10) -> List[CostViolation]:
    """Drift gate: each report's gated metrics vs the committed entry.

    Fails on any metric rising beyond ``tolerance`` (improvements pass —
    refresh the baseline to bank them), on entry points missing from the
    baseline (regenerate with ``jaxlint --cost --write-cost-baseline``),
    and carries the offending per-primitive deltas in the message.
    """
    out: List[CostViolation] = []
    entries = baseline.get("entries", {})
    for r in reports:
        name = r["manifest"]
        base = entries.get(name)
        if base is None:
            out.append(CostViolation(
                name, "baseline",
                "no entry in COST_baseline.json — regenerate with "
                "`python tools/jaxlint.py --cost --write-cost-baseline`"))
            continue
        for metric in _GATED_METRICS:
            cur_v, base_v = float(r[metric]), float(base[metric])
            if cur_v > base_v * (1.0 + tolerance) + 1e-9:
                which = "prim_flops" if "flops" in metric else "prim_bytes"
                out.append(CostViolation(
                    name, "drift",
                    f"{metric} {base_v:.6g} -> {cur_v:.6g} "
                    f"(+{(cur_v - base_v) / base_v:.0%} > tol "
                    f"{tolerance:.0%}); offending primitives: "
                    f"{_prim_deltas(r.get(which, {}), base.get(which, {}))}"
                ))
    known = {r["manifest"] for r in reports}
    for name in entries:
        if name not in known:
            out.append(CostViolation(
                name, "baseline",
                "baseline entry has no registered manifest — regenerate "
                "the baseline after removing/renaming entry points"))
    return out


# ---------------------------------------------------------------------------
# Baseline artifact I/O
# ---------------------------------------------------------------------------

BASELINE_PATH = Path(__file__).resolve().parents[3] / "COST_baseline.json"


def load_baseline(path=None) -> Optional[dict]:
    p = Path(path) if path is not None else BASELINE_PATH
    if not p.exists():
        return None
    with open(p) as f:
        return json.load(f)


def write_baseline(reports: List[dict], path=None) -> Path:
    p = Path(path) if path is not None else BASELINE_PATH
    entries = {}
    for r in reports:
        entries[r["manifest"]] = {
            "flops": r["flops"],
            "hbm_bytes": r["hbm_bytes"],
            "flops_per_doc": r["flops_per_doc"],
            "bytes_per_doc": r["bytes_per_doc"],
            "intensity": r["intensity"],
            "bound": r["bound"],
            "while_loops": r["while_loops"],
            "prim_flops": r["prim_flops"],
            "prim_bytes": r["prim_bytes"],
        }
    payload = {
        "schema": 1,
        "resident_bytes": RESIDENT_BYTES,
        "rooflines": {r.name: {"peak_flops": r.peak_flops,
                               "hbm_bw": r.hbm_bw, "ridge": r.ridge}
                      for r in ROOFLINES},
        "entries": dict(sorted(entries.items())),
    }
    with open(p, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return p
