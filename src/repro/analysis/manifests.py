"""Declarative memory-budget manifests for every search entry point.

Each ``BudgetManifest`` registers one hot-path entry point with the jaxpr
budget analyzer (``repro.analysis.jaxpr_budget``): a ``trace`` callable
returning ``(fn, args)`` built from ``jax.ShapeDtypeStruct`` leaves at a
given corpus size, plus the contract numbers the traced program must
honor:

  * ``max_block_bytes`` — the largest intermediate whose size does NOT
    grow with the corpus (the blocked-scan working set). PR 5's
    hand-written 64 MB `search_flat` test is the `search_flat` entry
    here.
  * ``max_bytes_per_doc`` — the growth-per-document allowance for
    intermediates that DO scale with N. Doc ids (4 B), validity masks
    (1 B) and code-payload handling fit; a (B, N) float score matrix
    (32 B/doc at B=8) or the unblocked (B, Mq, N, Md) gather (~2 KB/doc)
    do not.
  * ``out_dtypes`` — the result dtype contract: float32 scores + int32
    doc ids everywhere except hamming, whose popcount scores stay int32
    end to end.
  * ``cost`` — an optional ``CostContract`` (max FLOPs/doc, max HBM
    bytes/doc) checked by the jaxpr cost model
    (``repro.analysis.cost_model``, ``jaxlint --cost``). Like the
    memory numbers these are *design* envelopes with headroom, not
    today's measurements — drift against today's numbers is gated
    separately by ``COST_baseline.json``.

The trace geometry is deliberately small everywhere except N (B=8, Mq=8,
Md=16, D=16, K=256): budgets scale linearly in those, and a small
constant footprint keeps the corpus-scaling term — the thing the
analyzer exists to catch — from hiding under block-working-set noise.
``n`` and ``n_alt`` are both multiples of every block/bucket/beam size in
play, so the two traces are structurally identical and intermediates
pair positionally.

Registering a new entry point (docs/design.md §8): implement
``IndexBackend.abstract_state`` for the backend, add a ``BudgetManifest``
to ``_MANIFESTS`` with a trace builder, and pick the two budget numbers
from the entry point's design envelope — not from what it happens to
allocate today.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.cost_model import CostContract
from repro.core import scan as scan_mod
from repro.retrieval.base import Query, code_dtype, get_backend
from repro.retrieval.config import HPCConfig
from repro.retrieval.retriever import Retriever

__all__ = ["BudgetManifest", "get_manifest", "manifests"]

# Trace geometry: small constants, symbolic-large corpus.
B = 8          # query batch
MQ = 8         # query patches
MD = 16        # doc patches
D = 16         # embedding dim
K = 256        # codebook size
TOP_K = 16     # result depth
RERANK = 64    # facade rerank candidate depth
N = 1 << 20    # corpus size (primary trace)
N_ALT = 1 << 19  # secondary trace for growth classification
IVF_N_LIST = 1024  # routing clusters at corpus scale (cap = 2N/n_list)

# The analyzer pins the jnp block scorer: the Pallas path lowers to a
# custom call whose jaxpr hides its internals, while the jnp path
# exposes every intermediate the budget must bound. Same block size as
# the production default.
SCAN = scan_mod.ScanConfig(block_docs=256, impl="jnp")

MiB = 2**20


@dataclasses.dataclass(frozen=True)
class BudgetManifest:
    """One entry point's memory/dtype contract (see module docstring)."""

    name: str
    trace: Callable[[int], Tuple[Callable, tuple]]
    max_block_bytes: int = 64 * MiB
    max_bytes_per_doc: float = 16.0
    out_dtypes: Optional[Tuple] = (jnp.float32, jnp.int32)
    n: int = N
    n_alt: int = N_ALT
    cost: Optional[CostContract] = None
    notes: str = ""


def abstract_query(b: int = B, mq: int = MQ, d: int = D) -> Query:
    """Shape-only Query matching the trace geometry."""
    sds = jax.ShapeDtypeStruct
    return Query(embeddings=sds((b, mq, d), jnp.float32),
                 mask=sds((b, mq), jnp.bool_),
                 salience=sds((b, mq), jnp.float32))


def _backend_trace(backend_name: str, **knobs):
    """Trace builder for `backend.search` over its abstract state."""
    def trace(n: int):
        backend = get_backend(backend_name)
        state = backend.abstract_state(n=n, md=MD, d=D, k=K, **knobs)
        query = abstract_query()

        def fn(state, query):
            return backend.search(state, query, k=TOP_K, scan=SCAN)
        return fn, (state, query)
    return trace


def _segmented_trace(backend_name: str, seg_fn: Callable[[int], Tuple],
                     **knobs):
    """Trace builder for `backend.search` over a *segmented* state.

    `seg_fn(n)` maps the corpus-size axis to the per-segment capacity
    tuple (for ivf: per-segment *bucket* caps), so the n / n_alt traces
    keep identical segment structure and intermediates pair positionally.
    """
    def trace(n: int):
        backend = get_backend(backend_name)
        state = backend.abstract_state(n=n, md=MD, d=D, k=K,
                                       segments=seg_fn(n), **knobs)
        query = abstract_query()

        def fn(state, query):
            return backend.search(state, query, k=TOP_K, scan=SCAN)
        return fn, (state, query)
    return trace


def _lsm_segments(n: int) -> Tuple[int, int, int]:
    """The steady churn shape: one base segment, one grown delta, one
    fresh small append — all block-aligned so the two traces pair."""
    return (n, n >> 4, 256)


def _rerank_trace(n: int):
    """Facade rerank: gather candidate codes, rescore unpruned."""
    r = Retriever(HPCConfig(backend="flat", scan_block_docs=SCAN.block_docs,
                            scan_impl=SCAN.impl))
    state = get_backend("flat").abstract_state(n=n, md=MD, d=D, k=K)
    query = abstract_query()
    sds = jax.ShapeDtypeStruct
    scores = sds((B, RERANK), jnp.float32)
    ids = sds((B, RERANK), jnp.int32)

    def fn(state, query, scores, ids):
        return r._rerank(state, query, scores, ids, k=TOP_K)
    return fn, (state, query, scores, ids)


def _scan_quantized_shared_trace(n: int):
    """The scan engine itself, shared-corpus layout (flat's hot path)."""
    sds = jax.ShapeDtypeStruct
    q = abstract_query()
    codes = sds((n, MD), code_dtype(K))
    mask = sds((n, MD), jnp.bool_)
    cb = sds((K, D), jnp.float32)

    def fn(qe, qm, codes, mask, cb):
        return scan_mod.quantized_maxsim_topk(qe, qm, codes, mask, cb,
                                              k=TOP_K, scan=SCAN)
    return fn, (q.embeddings, q.mask, codes, mask, cb)


def _scan_quantized_per_query_trace(n: int):
    """Per-query candidate-pool layout (ivf buckets / hnsw beam / rerank).

    `n` is the per-query pool size here — the layout's corpus-scaling
    axis — so growth classification bounds bytes per pooled candidate.
    """
    sds = jax.ShapeDtypeStruct
    q = abstract_query()
    codes = sds((B, n, MD), code_dtype(K))
    mask = sds((B, n, MD), jnp.bool_)
    cb = sds((K, D), jnp.float32)
    ids = sds((B, n), jnp.int32)
    valid = sds((B, n), jnp.bool_)

    def fn(qe, qm, codes, mask, cb, ids, valid):
        return scan_mod.quantized_maxsim_topk(qe, qm, codes, mask, cb,
                                              k=TOP_K, doc_ids=ids,
                                              valid=valid, scan=SCAN)
    return fn, (q.embeddings, q.mask, codes, mask, cb, ids, valid)


def _scan_maxsim_trace(n: int):
    """Float scan over an uncompressed (N, Md, D) corpus."""
    sds = jax.ShapeDtypeStruct
    q = abstract_query()
    docs = sds((n, MD, D), jnp.float32)
    mask = sds((n, MD), jnp.bool_)

    def fn(qe, qm, docs, mask):
        return scan_mod.maxsim_topk(qe, qm, docs, mask, k=TOP_K, scan=SCAN)
    return fn, (q.embeddings, q.mask, docs, mask)


def _scan_hamming_trace(n: int):
    """Popcount scan over b-bit binary codes (int32 scores)."""
    sds = jax.ShapeDtypeStruct
    q_codes = sds((B, MQ), jnp.uint8)
    q_mask = sds((B, MQ), jnp.bool_)
    d_codes = sds((n, MD), jnp.uint8)
    d_mask = sds((n, MD), jnp.bool_)

    def fn(qc, qm, dc, dm):
        return scan_mod.hamming_maxsim_topk(qc, qm, dc, dm, bits=8,
                                            k=TOP_K, scan=SCAN)
    return fn, (q_codes, q_mask, d_codes, d_mask)


_MANIFESTS: Dict[str, BudgetManifest] = {}


def _register(m: BudgetManifest) -> None:
    if m.name in _MANIFESTS:
        raise ValueError(f"duplicate manifest {m.name!r}")
    _MANIFESTS[m.name] = m


for _m in (
    BudgetManifest(
        name="search_flat",
        trace=_backend_trace("flat"),
        cost=CostContract(max_flops_per_doc=4096, max_bytes_per_doc=512),
        notes="PR 5's hand-written 64 MB jaxpr test, as a manifest. The "
              "blocked scan may keep doc ids / validity O(N); the (B, N) "
              "score matrix (32 B/doc at B=8) must never come back."),
    BudgetManifest(
        name="search_float_flat",
        trace=_backend_trace("float_flat"),
        cost=CostContract(max_flops_per_doc=65536,
                          max_bytes_per_doc=12288),
        notes="Uncompressed baseline: the (N, Md, D) corpus is an input, "
              "not an intermediate — blocks of it are sliced, never "
              "padded/copied whole."),
    BudgetManifest(
        name="search_hamming",
        trace=_backend_trace("hamming"),
        out_dtypes=(jnp.int32, jnp.int32),
        cost=CostContract(max_flops_per_doc=16384,
                          max_bytes_per_doc=8192),
        notes="Popcount MaxSim: scores stay int32 end to end (the dtype "
              "contract half of this entry)."),
    BudgetManifest(
        name="search_ivf",
        trace=_backend_trace("ivf", n_list=IVF_N_LIST, n_probe=8),
        notes="Probed-bucket gathers scale with bucket cap = 2N/n_list: "
              "~2 B/doc each for codes+mask at n_list=1024, n_probe=8."),
    BudgetManifest(
        name="search_hnsw",
        trace=_backend_trace("hnsw"),
        notes="The beam's visited bitmask is (B, N) bool = 8 B/doc at "
              "B=8; everything else is O(ef_search)."),
    BudgetManifest(
        name="search_cascade",
        trace=_backend_trace("cascade", p1=1024, p2=64),
        cost=CostContract(max_flops_per_doc=16384,
                          max_bytes_per_doc=12288),
        notes="Staged funnel: the hamming prefilter is the only O(N) "
              "pass (blocked, like search_hamming); the ADC and float "
              "stages gather per-query (B, p1)/(B, p2) pools — "
              "O(budget), never a full-corpus gather. Float scores out "
              "(exact rerank)."),
    BudgetManifest(
        name="search_flat_segmented",
        trace=_segmented_trace("flat", _lsm_segments),
        notes="LSM segment sweep: same blocked scan per segment with the "
              "(B, k) merge buffer carried across — per-segment ids/valid "
              "stay O(cap), nothing new scales with N."),
    BudgetManifest(
        name="search_float_flat_segmented",
        trace=_segmented_trace("float_flat", _lsm_segments),
        notes="Float segment sweep: block slices per segment; tombstone "
              "live-bits add 1 B/slot."),
    BudgetManifest(
        name="search_hamming_segmented",
        trace=_segmented_trace("hamming", _lsm_segments),
        out_dtypes=(jnp.int32, jnp.int32),
        notes="Binary segment sweep: int32 popcount scores end to end, "
              "merge buffer carried across segments."),
    BudgetManifest(
        name="search_ivf_segmented",
        trace=_segmented_trace(
            "ivf", lambda n: (2 * n // IVF_N_LIST, 8),
            n_list=IVF_N_LIST, n_probe=8),
        notes="Shared routing centroids scored once; per-segment probed "
              "gathers scale with that segment's bucket cap (2N/n_list "
              "for the base, O(1) for deltas)."),
    BudgetManifest(
        name="search_hnsw_segmented",
        trace=_segmented_trace("hnsw", lambda n: (n,)),
        notes="Single growable graph segment: the walk is the monolithic "
              "one plus an O(N) live-bit lookup folded into the validity "
              "mask."),
    BudgetManifest(
        name="search_cascade_segmented",
        trace=_segmented_trace("cascade", _lsm_segments, p1=1024, p2=64),
        notes="Segmented funnel: hamming prefilter sweeps segments "
              "blocked; ADC/float stages resolve global ids via pos_of_id "
              "(O(B * budget) gathers) across segments."),
    BudgetManifest(
        name="retriever_rerank",
        trace=_rerank_trace,
        notes="Candidate gather from the unpruned (N, Md) code corpus: "
              "all intermediates are O(B * rerank depth), none scale "
              "with N."),
    BudgetManifest(
        name="scan_quantized_shared",
        trace=_scan_quantized_shared_trace,
        cost=CostContract(max_flops_per_doc=4096, max_bytes_per_doc=512),
        notes="The scan engine itself, shared-corpus layout."),
    BudgetManifest(
        name="scan_quantized_per_query",
        trace=_scan_quantized_per_query_trace,
        max_bytes_per_doc=48.0,
        cost=CostContract(max_flops_per_doc=8192,
                          max_bytes_per_doc=2048),
        notes="Per-query pools carry (B, P) ids/valid by construction: "
              "B * 5 B per pooled candidate before scoring starts."),
    BudgetManifest(
        name="scan_maxsim",
        trace=_scan_maxsim_trace,
        cost=CostContract(max_flops_per_doc=65536,
                          max_bytes_per_doc=12288),
        notes="Float scan: block slices of the fp32 corpus are the "
              "working set; nothing else may scale with N."),
    BudgetManifest(
        name="scan_hamming",
        trace=_scan_hamming_trace,
        out_dtypes=(jnp.int32, jnp.int32),
        cost=CostContract(max_flops_per_doc=16384,
                          max_bytes_per_doc=8192),
        notes="Binary scan: int32 popcount scores, packed-code blocks."),
):
    _register(_m)


def manifests() -> Tuple[BudgetManifest, ...]:
    """Every registered manifest, name-ordered (stable CLI/CI output)."""
    return tuple(_MANIFESTS[k] for k in sorted(_MANIFESTS))


def get_manifest(name: str) -> BudgetManifest:
    try:
        return _MANIFESTS[name]
    except KeyError:
        raise KeyError(
            f"no manifest {name!r}; registered: {sorted(_MANIFESTS)}"
        ) from None
