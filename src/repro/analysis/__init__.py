"""`repro.analysis`: static-analysis subsystem (docs/design.md §8).

Three engines keep the repo's memory/compute envelope a *checked contract*
instead of a convention:

  * `jaxpr_budget` + `manifests` — trace every registered search entry
    point at symbolic corpus size, walk the closed jaxpr (into pjit /
    scan / while / cond sub-jaxprs), and enforce the declarative
    per-entry-point budget manifest: max intermediate bytes, "no aval
    scales with N beyond the declared per-document allowance", and
    output dtype contracts.
  * `recompile` — a runtime sentry counting distinct lowered signatures
    per jitted entry point, so the serving ladder provably compiles
    exactly its declared rung set (weak-dtype / non-static-arg leaks
    otherwise grow the jit cache without bound).
  * `lintcore` + `astchecks` — the shared AST lint framework (the ruff
    fallback rules E9/F401/F811/F541 with `# noqa[: CODE]` semantics)
    plus the JAX-aware rules JAX01-JAX04.

`tools/jaxlint.py` is the CLI driving all three; `tools/astlint.py` is a
thin shim over `lintcore` so the CI fallback linter cannot drift from the
framework.
"""
from repro.analysis.jaxpr_budget import (BudgetViolation, analyze_manifest,
                                         intermediate_avals, iter_jaxprs)
from repro.analysis.lintcore import Finding, Rule, check_source, run_paths
from repro.analysis.manifests import BudgetManifest, get_manifest, manifests
from repro.analysis.recompile import (RecompileGuardError, RecompileSentry,
                                      ladder_signatures)

__all__ = [
    "BudgetManifest",
    "BudgetViolation",
    "Finding",
    "RecompileGuardError",
    "RecompileSentry",
    "Rule",
    "analyze_manifest",
    "check_source",
    "get_manifest",
    "intermediate_avals",
    "iter_jaxprs",
    "ladder_signatures",
    "manifests",
    "run_paths",
]
